"""Telemetry subsystem tests: histogram bucket semantics, counter
monotonicity (including across snapshot/restore), registry exposition
and state round-trips, span tracing, the flight-recorder ring, and the
runtime integration — compile-once with the sink on, flight dumps on
injected NaN payloads, and the bounded detections log."""
import json

import jax
import numpy as np
import pytest

from repro.data import make_har_dataset
from repro.data.pipeline import anomaly_eval_arrays, train_test_split
from repro.data.synthetic import AnomalyDataset
from repro.fleet import DriftEvent, init_fleet, make_fleet_streams, ring
from repro.fleet.faults import FaultInjector, FaultSpec
from repro.fleet.robust import RobustConfig
from repro.obs import (
    Counter,
    FlightRecorder,
    Histogram,
    MetricsRegistry,
    TelemetryConfig,
    TelemetrySink,
    Tracer,
    load_dump,
    phase_timer,
)
from repro.runtime import (
    DetectorConfig,
    FleetRuntime,
    GovernorConfig,
    RuntimeConfig,
    TickFeed,
)

RIDGE = 1e-3
H_RT = 16

# ------------------------------------------------------------------- metrics


def test_counter_monotone():
    c = Counter()
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    assert c.value == 3.5


def test_histogram_bucket_edges_are_inclusive_upper_bounds():
    h = Histogram(buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 1.5, 2.0, 4.0, 100.0):
        h.observe(v)
    # le semantics: a value equal to an edge lands in that edge's bucket
    assert h.counts == [2, 2, 1, 1]  # le=1, le=2, le=4, +Inf
    assert h.count == 6
    assert h.vmin == 0.5 and h.vmax == 100.0
    assert h.sum == pytest.approx(109.0)


def test_histogram_rejects_bad_edges():
    with pytest.raises(ValueError):
        Histogram(buckets=())
    with pytest.raises(ValueError):
        Histogram(buckets=(1.0, 1.0, 2.0))
    with pytest.raises(ValueError):
        Histogram(buckets=(2.0, 1.0))


def test_histogram_observe_many_matches_sequential_observe():
    rng = np.random.default_rng(0)
    values = rng.gamma(1.0, 2.0, size=257)
    one = Histogram(buckets=(0.5, 1.0, 2.0, 8.0), sample_cap=100)
    many = Histogram(buckets=(0.5, 1.0, 2.0, 8.0), sample_cap=100)
    for v in values:
        one.observe(v)
    many.observe_many(values)
    assert one.counts == many.counts
    assert one.count == many.count
    assert one.sum == pytest.approx(many.sum)
    assert list(one.samples) == pytest.approx(list(many.samples))
    assert one.quantile(0.5) == pytest.approx(many.quantile(0.5))


def test_histogram_sample_window_is_bounded():
    h = Histogram(buckets=(1.0,), sample_cap=8)
    h.observe_many(np.arange(100, dtype=np.float64))
    assert len(h.samples) == 8
    assert list(h.samples) == list(range(92, 100))  # most recent retained
    assert h.count == 100  # aggregate stats still see everything


def test_registry_labels_and_redeclare():
    r = MetricsRegistry()
    fam = r.counter("merge_bytes_total", labels=("precision",))
    fam.labels(precision="f32").inc(100)
    fam.labels(precision="int8").inc(25)
    assert fam.labels(precision="f32").value == 100
    # same (name, kind, labels) → the same object
    assert r.counter("merge_bytes_total", labels=("precision",)) is fam
    with pytest.raises(ValueError):
        r.gauge("merge_bytes_total")  # one name, one meaning
    with pytest.raises(ValueError):
        fam.labels(wrong="x")
    with pytest.raises(ValueError):
        r.counter("bad name!")


def test_registry_exposition_well_formed():
    r = MetricsRegistry()
    r.counter("ticks_total", "ticks").inc(5)
    r.gauge("quarantined_devices").set(2)
    h = r.histogram("lat_seconds", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(10.0)
    text = r.exposition()
    assert "# TYPE ticks_total counter" in text
    assert "ticks_total 5" in text
    assert "# TYPE quarantined_devices gauge" in text
    assert "# TYPE lat_seconds histogram" in text
    # buckets are CUMULATIVE and +Inf equals the total count
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="1"} 2' in text
    assert 'lat_seconds_bucket{le="+Inf"} 3' in text
    assert "lat_seconds_count 3" in text


def test_registry_state_roundtrip():
    r = MetricsRegistry()
    r.counter("ticks_total").inc(7)
    r.gauge("level").set(-1.5)
    fam = r.counter("bytes_total", labels=("precision",))
    fam.labels(precision="f32").inc(64)
    h = r.histogram("lat", buckets=(1.0, 2.0))
    h.observe_many([0.5, 1.5, 9.0])

    state = json.loads(json.dumps(r.state()))  # must survive JSON

    r2 = MetricsRegistry()
    r2.counter("ticks_total")
    r2.gauge("level")
    r2.counter("bytes_total", labels=("precision",))
    r2.histogram("lat", buckets=(1.0, 2.0))
    r2.load_state(state)
    assert r2.counter("ticks_total").value == 7
    assert r2.gauge("level").value == -1.5
    assert r2.counter(
        "bytes_total", labels=("precision",)
    ).labels(precision="f32").value == 64
    h2 = r2.histogram("lat", buckets=(1.0, 2.0))
    assert h2.counts == h.counts and h2.count == 3
    assert h2.quantile(0.5) == h.quantile(0.5)


def test_registry_load_rejects_bucket_mismatch():
    r = MetricsRegistry()
    r.histogram("lat", buckets=(1.0, 2.0)).observe(0.5)
    state = r.state()
    r2 = MetricsRegistry()
    r2.histogram("lat", buckets=(1.0, 4.0))
    with pytest.raises(ValueError):
        r2.load_state(state)


def test_phase_timer_fences_device_work():
    seen = []
    with phase_timer(seen.append) as handle:
        x = jax.numpy.ones((256, 256)) @ jax.numpy.ones((256, 256))
        handle.fence(x)
    assert len(seen) == 1 and seen[0] > 0
    # fencing nothing still observes
    with phase_timer(seen.append):
        pass
    assert len(seen) == 2


# --------------------------------------------------------------------- trace


def test_tracer_writes_parseable_jsonl(tmp_path):
    path = tmp_path / "trace.jsonl"
    tr = Tracer(path)
    with tr.span("merge", tick=3):
        pass
    tr.emit({"name": "flight_dump", "tick": 3})
    tr.close()
    events = [json.loads(line) for line in path.read_text().splitlines()]
    assert [e["name"] for e in events] == ["merge", "flight_dump"]
    assert events[0]["tick"] == 3
    assert events[0]["dur_s"] >= 0
    assert tr.events_emitted == 2


def test_tracer_disabled_is_noop(tmp_path):
    tr = Tracer(None)
    assert not tr.enabled
    with tr.span("x"):
        pass
    assert tr.events_emitted == 0


# -------------------------------------------------------------------- flight


def test_flight_ring_bounded():
    fr = FlightRecorder(capacity=4)
    for t in range(10):
        fr.record({"tick": t})
    assert len(fr) == 4
    assert fr.records_total == 10
    assert [r["tick"] for r in fr.records()] == [6, 7, 8, 9]


def test_flight_dump_roundtrip_and_rate_limit(tmp_path):
    fr = FlightRecorder(capacity=8, max_dumps=2)
    # records may carry numpy leaves; the dump must still serialize
    fr.record({"tick": 0, "losses": np.asarray([1.0, 2.0], np.float32),
               "n": np.int64(3)})
    inputs = np.arange(12, dtype=np.float32).reshape(2, 2, 3)
    path = fr.dump(tmp_path, 0, "nonfinite", inputs=inputs,
                   extra={"count": np.int32(2)})
    assert path is not None
    dump = load_dump(path)
    assert dump["reason"] == "nonfinite"
    assert dump["ring"][0]["losses"] == [1.0, 2.0]
    assert dump["extra"]["count"] == 2
    np.testing.assert_array_equal(dump["inputs"], inputs)
    assert dump["inputs"].dtype == np.float32

    assert fr.dump(tmp_path, 1, "nonfinite") is not None  # budget: 2
    assert fr.dump(tmp_path, 2, "nonfinite") is None      # over budget
    # a NEW reason always gets its first dump, even over budget
    assert fr.dump(tmp_path, 3, "slo") is not None
    assert len(fr.dumps) == 3


def test_flight_state_roundtrip():
    fr = FlightRecorder(capacity=4, max_dumps=1)
    for t in range(6):
        fr.record({"tick": t})
    state = json.loads(json.dumps(fr.state()))
    fr2 = FlightRecorder(capacity=4)
    fr2.load_state(state)
    assert fr2.records() == fr.records()
    assert fr2.records_total == 6


# ---------------------------------------------------------- sink + runtime


def _har3():
    ds = make_har_dataset(seed=0, samples_per_class=100)
    lo, hi = ds.x.min(0), ds.x.max(0)
    ds = ds._replace(x=((ds.x - lo) / (hi - lo + 1e-6)).astype(np.float32))
    train, test = train_test_split(ds, 0.8, seed=0)

    def sub(d):
        m = d.y < 3
        return AnomalyDataset(d.name, d.x[m], d.y[m], d.class_names[:3])

    return sub(train), sub(test)


@pytest.fixture(scope="module")
def obs_scenario():
    """8 devices, 60 ticks, 2 drifting mid-soak — small enough that the
    telemetry integration tests stay cheap."""
    train3, test3 = _har3()
    ticks, batch = 60, 2
    drift = tuple(
        DriftEvent(device=d, step=60 + 11 * i, new_pattern=2)
        for i, d in enumerate((2, 5))
    )
    fs = make_fleet_streams(
        train3, 8, ticks * batch, n_init=2 * H_RT, drift=drift, seed=0,
        n_assign=2,
    )
    x_eval, y_eval = anomaly_eval_arrays(test3, [0, 1], anomaly_ratio=0.3, seed=0)
    return train3, fs, batch


def _mk_runtime(fs, n_features, *, telemetry=None, **cfg_kw):
    fleet = init_fleet(
        jax.random.PRNGKey(0), fs.n_devices, n_features, H_RT, fs.x_init,
        activation="identity", ridge=RIDGE,
    )
    cfg_kw.setdefault("governor", GovernorConfig(merge_every=16))
    cfg = RuntimeConfig(
        topology=ring(fs.n_devices, hops=2), ridge=RIDGE,
        detector=DetectorConfig(),
        telemetry=telemetry, **cfg_kw,
    )
    return FleetRuntime(fleet, cfg)


def test_runtime_compile_once_with_telemetry(obs_scenario):
    """Enabling the sink must not add a single retrace."""
    train3, fs, batch = obs_scenario
    rt = _mk_runtime(
        fs, train3.n_features, telemetry=TelemetryConfig(band_sample_every=1)
    )
    rt.run(TickFeed(fs, batch))
    sizes = rt.assert_compile_once()
    assert all(v == 1 for v in sizes.values())
    summary = rt.finalize_telemetry()
    assert summary["ticks"] == 60
    assert summary["merge_rounds"] == rt.governor.state.merges
    assert summary["bytes_total"] == rt.governor.state.bytes_spent
    # band histograms sampled every tick here: calibrated devices observed
    assert summary["metrics"]["detector_band_width"]["series"][0]["count"] > 0
    # every phase that ran has latency stats
    assert {"poison", "ingest", "govern"} <= set(summary["phases"])


def test_runtime_telemetry_counters_survive_restore(tmp_path, obs_scenario):
    """Kill/restore continuity: the restored sink resumes the counter
    trajectory (ticks, merges, bytes) instead of restarting from zero."""
    train3, fs, batch = obs_scenario

    def fresh():
        return _mk_runtime(
            fs, train3.n_features, telemetry=TelemetryConfig(),
            snapshot_every=20, snapshot_dir=tmp_path,
        )

    rt = fresh()
    feed = TickFeed(fs, batch)
    rt.run(feed, ticks=40)
    rt.snapshot()
    before = rt.telemetry.state()

    rt2 = fresh()
    assert rt2.restore() == 40
    assert int(rt2.telemetry.ticks.value) == 40
    assert rt2.telemetry.state()["registry"] == before["registry"]
    assert rt2.detections_total == rt.detections_total
    # counters keep climbing from the restored base, monotonically
    rt2.tick(feed.tick_batch(40))
    assert int(rt2.telemetry.ticks.value) == 41
    assert rt2.telemetry.tick_seconds.count == 41


def test_runtime_flight_dump_on_nan_payload(tmp_path, obs_scenario):
    """An injected NaN payload must trigger a ``flight_<tick>.json``
    whose captured inputs are the failing tick's post-poison batch."""
    train3, fs, batch = obs_scenario
    rt = _mk_runtime(
        fs, train3.n_features,
        telemetry=TelemetryConfig(dir=str(tmp_path / "tel")),
        governor=GovernorConfig(merge_every=8),
        robust=RobustConfig(trim=1),
        faults=FaultInjector(
            (FaultSpec(kind="nan", frac=0.2, start_tick=4, seed=3),),
            fs.n_devices, seed=0,
        ),
    )
    feed = TickFeed(fs, batch)
    reports = rt.run(feed)
    summary = rt.finalize_telemetry()
    assert summary["nonfinite_payloads_total"] > 0
    assert summary["flight"]["dumps"], "no flight dump written"
    dump = load_dump(summary["flight"]["dumps"][0])
    assert dump["reason"] == "nonfinite"
    t = dump["tick"]
    assert reports[t].nonfinite_payloads > 0
    np.testing.assert_array_equal(dump["inputs"], feed.tick_batch(t))
    # the ring's newest record is the failing tick itself
    assert dump["ring"][-1]["tick"] == t
    assert dump["ring"][-1]["losses"] == pytest.approx(
        np.asarray(reports[t].losses, np.float64), rel=1e-6
    )


def test_runtime_detections_log_is_bounded(obs_scenario):
    train3, fs, batch = obs_scenario
    rt = _mk_runtime(fs, train3.n_features, detections_cap=3)
    rt.run(TickFeed(fs, batch))
    assert len(rt.detections) <= 3
    assert rt.detections_total >= len(rt.detections)
    assert rt.detections_total > 0  # the drifted devices did flag


def test_sink_rejects_unknown_phase():
    sink = TelemetrySink(TelemetryConfig())
    with pytest.raises(ValueError):
        sink.phase("warp")
    with sink.phase("ingest"):
        pass
    assert sink.phase_seconds.labels(phase="ingest").count == 1


# ------------------------------------------------- ingress metrics (PR 9)


def test_sink_ingress_stats_in_summary():
    """The serving front-end books everything through the runtime sink —
    summary() carries an ingress block with admission outcomes, the
    degraded-ladder position, and submit-to-ack latency."""
    sink = TelemetrySink(TelemetryConfig(trace=False))
    sink.ingress_accepted.inc(5)
    sink.ingress_acked.inc(4)
    sink.ingress_retried.inc(2)
    sink.ingress_stale.inc()
    sink.ingress_shed.labels(reason="queue_full").inc(3)
    sink.ingress_deferred.labels(reason="backpressure").inc(2)
    sink.ingress_deferred.labels(reason="comm_budget").inc()
    sink.ingress_degraded_mode.set(2)
    sink.ingress_transitions.labels(mode="stale_scores").inc()
    sink.ingress_request_seconds.observe(0.004)
    sink.ingress_request_seconds.observe(0.019)

    ing = sink.summary()["ingress"]
    assert ing["accepted"] == 5 and ing["acked"] == 4
    assert ing["retried"] == 2 and ing["stale_served"] == 1
    assert ing["shed"] == {"queue_full": 3}
    assert ing["deferred"] == {"backpressure": 2, "comm_budget": 1}
    assert ing["degraded_mode"] == 2
    assert ing["degraded_transitions"] == {"stale_scores": 1}
    assert ing["request_latency"]["count"] == 2
    assert ing["request_latency"]["p99_s"] > 0
    assert ing["admission_latency"] is None  # nothing observed yet


def test_sink_ingress_counters_survive_state_roundtrip():
    """Ingress counters ride the same snapshot blob the runtime
    persists, so a kill/restore keeps the serving counters continuous
    instead of resetting them to zero."""
    sink = TelemetrySink(TelemetryConfig(trace=False))
    sink.ingress_accepted.inc(7)
    sink.ingress_shed.labels(reason="degraded").inc(2)
    sink.ingress_replayed.inc(3)

    sink2 = TelemetrySink(TelemetryConfig(trace=False))
    sink2.load_state_bytes(sink.state_bytes())
    ing = sink2.ingress_stats()
    assert ing["accepted"] == 7
    assert ing["shed"] == {"degraded": 2}
    assert ing["replayed_ticks"] == 3
