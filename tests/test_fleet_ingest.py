"""Parity + dispatch tests for the fused fleet-ingest kernel family.

The Pallas ingest kernel (interpret=True on CPU) must match the
``_fleet_train`` vmap-of-scan reference to ≤1e-5 under odd device/Ñ/T
remainders and a forgetting factor λ<1, the fused XLA Woodbury lowering
must match its (real-arithmetic-exact) sequential chain, padded sample
slots must be exact identity steps, and the ``kernel=`` dispatches on
``fleet_train`` / ``fleet_train_rounds`` / ``oselm_train_sequential`` /
``FleetRuntime`` must reproduce their XLA baselines — the runtime tick
to identical ``TickReport``s with zero retracing.

NB on tolerances: RLS parity in f32 degrades as κ(P)² — fixtures use
identity activations or well-ridged sigmoids so the comparison tests
the kernels, not the conditioning (same convention as the merge-kernel
parity tests).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ae_score,
    init_oselm,
    init_slfn,
    oselm_step_k1,
    oselm_train_sequential,
)
from repro.fleet import (
    fleet_merge,
    fleet_merge_sharded,
    fleet_train,
    fleet_train_rounds,
    fleet_train_sharded,
    init_fleet,
    ring,
    star,
)
from repro.fleet.fleet import _fleet_train
from repro.kernels.fleet_ingest import (
    fleet_ingest,
    fleet_ingest_kernel,
    fleet_ingest_xla,
    ingest_padding,
)
from repro.launch.sharding import shard_fleet
from repro.runtime import FleetRuntime, RuntimeConfig

# odd everywhere: D misses the block_d grid, T the sublane tile, Ñ the
# lane/sublane tiles, F the lane tile
D_ODD, T_ODD, F_ODD, NH_ODD = 13, 17, 37, 10
RIDGE = 1e-3


def _fleet(d=D_ODD, f=F_ODD, nh=NH_ODD, *, activation="identity",
           forget=1.0, ridge=RIDGE, seed=0):
    key = jax.random.PRNGKey(seed)
    x_init = jax.random.uniform(key, (d, 4 * nh, f))
    return init_fleet(
        key, d, f, nh, x_init, activation=activation, ridge=ridge, forget=forget
    )


def _window(d=D_ODD, t=T_ODD, f=F_ODD, seed=1):
    return jax.random.uniform(jax.random.PRNGKey(seed), (d, t, f))


def _assert_state_close(got, ref, *, rtol=1e-5, atol=1e-5):
    np.testing.assert_allclose(np.asarray(got.p), np.asarray(ref.p),
                               rtol=rtol, atol=atol)
    np.testing.assert_allclose(np.asarray(got.beta), np.asarray(ref.beta),
                               rtol=rtol, atol=atol)


@pytest.mark.parametrize("activation,forget", [
    ("sigmoid", 1.0),       # paper default
    ("identity", 0.95),     # forgetting factor λ<1
    ("identity", 1.0),
])
def test_ingest_kernel_matches_scan_reference(activation, forget):
    """Pallas ingest == _fleet_train + pre-train score, ≤1e-5, odd
    D/T/Ñ/F remainders (D=13 with block_d=4 leaves a ragged device
    block; T=17 pads to the sublane tile)."""
    ridge = 5e-2 if activation == "sigmoid" else RIDGE
    fleet = _fleet(activation=activation, forget=forget, ridge=ridge)
    win = _window()
    ref = _fleet_train(fleet, win)
    ref_loss = jax.vmap(lambda s, xb: jnp.mean(ae_score(s, xb)))(fleet, win)
    got, loss = fleet_ingest_kernel(fleet, win, block_d=4, interpret=True)
    _assert_state_close(got, ref)
    np.testing.assert_allclose(np.asarray(loss), np.asarray(ref_loss),
                               rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("forget", [1.0, 0.95])
@pytest.mark.parametrize("block_t", [5, 17, 32])
def test_ingest_xla_matches_scan_reference(forget, block_t):
    """Fused Woodbury lowering == the sequential chain, ragged tail
    blocks (17 % 5 != 0) included. The c×c Cholesky reorders the f32
    accumulation, so the bound is a touch wider than the Pallas
    kernel's — the identity is exact in real arithmetic."""
    fleet = _fleet(forget=forget)
    win = _window()
    ref = _fleet_train(fleet, win)
    ref_loss = jax.vmap(lambda s, xb: jnp.mean(ae_score(s, xb)))(fleet, win)
    got, loss = fleet_ingest_xla(fleet, win, block_t=block_t)
    _assert_state_close(got, ref, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(loss), np.asarray(ref_loss),
                               rtol=1e-5, atol=1e-7)


def test_ingest_padding_is_identity():
    """Padded sample slots are masked identity steps: a T=17 window
    (pallas pads to 24 slots, xla's 8-block to 24) gives bit-identical
    results to processing exactly those 17 samples — and the padding
    accounting helper reports what each lowering pads."""
    fleet = _fleet(forget=0.9)   # λ<1 would expose a pad slot decaying P
    win = _window(t=17)
    ref = _fleet_train(fleet, win)
    got_k, _ = fleet_ingest_kernel(fleet, win, block_d=4, interpret=True)
    got_x, _ = fleet_ingest_xla(fleet, win, block_t=8)
    _assert_state_close(got_k, ref)
    _assert_state_close(got_x, ref, rtol=2e-4, atol=2e-5)
    # sublane pad / block pad (block_t caps at T: one block, no pad)
    assert ingest_padding(17) == (7, 0)
    assert ingest_padding(17, block_t=8) == (7, 7)
    assert ingest_padding(32) == (0, 0)


def test_ingest_supervised_targets():
    """The optional targets window (m != n) matches the supervised
    sequential chain — the path oselm_train_sequential(kernel=True)
    rides."""
    f, nh, m, t = 21, 6, 9, 13
    key = jax.random.PRNGKey(0)
    params = init_slfn(key, f, nh)
    x0 = jax.random.uniform(key, (3 * nh, f))
    t0 = jax.random.uniform(jax.random.PRNGKey(5), (3 * nh, m))
    st = init_oselm(params, x0, t0, activation="identity", ridge=1e-2, forget=0.9)
    xs = jax.random.uniform(jax.random.PRNGKey(1), (t, f))
    ts = jax.random.uniform(jax.random.PRNGKey(2), (t, m))
    ref = oselm_train_sequential(st, xs, ts)
    for kw in (dict(backend="pallas", interpret=True), dict(backend="xla")):
        got = oselm_train_sequential(st, xs, ts, kernel=True, **kw)
        _assert_state_close(got, ref, rtol=1e-4, atol=1e-5)


def test_oselm_step_k1_kernel_wired():
    """Satellite: the (previously orphaned) fused single-step kernel is
    reachable through core.oselm's kernel= flag and matches the plain
    step."""
    f, nh = F_ODD, NH_ODD
    key = jax.random.PRNGKey(3)
    params = init_slfn(key, f, nh)
    x0 = jax.random.uniform(key, (4 * nh, f))
    st = init_oselm(params, x0, x0, activation="sigmoid", ridge=1e-2)
    x = jax.random.uniform(jax.random.PRNGKey(4), (f,))
    ref = oselm_step_k1(st, x, x)
    got = oselm_step_k1(st, x, x, kernel=True, interpret=True)
    _assert_state_close(got, ref, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_fleet_train_kernel_dispatch(backend):
    """fleet_train(kernel=True) == fleet_train, both backends."""
    fleet = _fleet()
    win = _window()
    ref = fleet_train(fleet, win)
    got = fleet_train(fleet, win, kernel=True, backend=backend)
    tol = dict(rtol=1e-5, atol=1e-5) if backend == "pallas" else dict(rtol=2e-4, atol=2e-5)
    _assert_state_close(got, ref, **tol)


def test_fleet_train_rounds_kernel_dispatch(caplog):
    """fleet_train_rounds(kernel=True) == the XLA rounds loop, and the
    padded per-round window logs the masked-identity padding warning on
    top of the existing tail-truncation warning."""
    d = D_ODD
    fleet = _fleet(d=d)
    streams = _window(d=d, t=19, seed=7)   # 19 = 4 rounds of 4 + tail 3
    topo = ring(d, hops=2)
    with caplog.at_level("WARNING", logger="repro.fleet.fleet"):
        ref = fleet_train_rounds(fleet, streams, topo, rounds=4, ridge=RIDGE)
        got = fleet_train_rounds(
            fleet, streams, topo, rounds=4, ridge=RIDGE, kernel=True
        )
        # the pallas lowering pads each 4-sample round window to the
        # 8-row sublane tile → the masked-identity padding warning
        got_p = fleet_train_rounds(
            fleet, streams, topo, rounds=4, ridge=RIDGE,
            kernel=True, backend="pallas",
        )
    _assert_state_close(got, ref, rtol=1e-4, atol=1e-5)
    _assert_state_close(got_p, ref, rtol=1e-4, atol=1e-5)
    msgs = [r.message for r in caplog.records]
    assert any("dropping the tail" in m for m in msgs)
    assert any("masked identity slots" in m for m in msgs)


def test_fleet_ingest_dispatcher_validates_backend():
    fleet = _fleet(d=4)
    with pytest.raises(ValueError, match="backend"):
        fleet_ingest(fleet, _window(d=4), backend="cuda")


def test_fleet_train_sharded_single_shard_matches_unsharded():
    """shard_map'd ingest over a 1-shard mesh == fleet_train, both the
    scan and kernel paths (the 8-real-shard equality lives in
    tests/test_distribution.py as a subprocess test)."""
    d = 12
    fleet = _fleet(d=d)
    win = _window(d=d)
    mesh = jax.make_mesh((1,), ("data",))
    fleet_s = shard_fleet(fleet, mesh)
    ref = fleet_train(fleet, win)
    got = fleet_train_sharded(fleet_s, win, mesh, ("data",))
    _assert_state_close(got, ref, rtol=1e-5, atol=1e-6)
    got_k = fleet_train_sharded(
        fleet_s, win, mesh, ("data",), kernel=True, backend="xla"
    )
    _assert_state_close(got_k, ref, rtol=2e-4, atol=2e-5)


def test_halo_ring_merge_single_shard_matches_fleet_merge():
    """Open-ring halo-exchange merge (1-shard degenerate: the circular
    wrap) == fleet_merge; an over-wide band is rejected with the
    shards-adjacency error."""
    d = 12
    fleet = _fleet(d=d)
    fleet = fleet_train(fleet, _window(d=d))
    mesh = jax.make_mesh((1,), ("data",))
    fleet_s = shard_fleet(fleet, mesh)
    # hops=0 is the degenerate self-merge band: no halo may be shipped
    # (w[-0:] is the WHOLE shard block, not an empty halo)
    for hops in (0, 1, 2):
        ref = fleet_merge(fleet, ring(d, hops=hops), ridge=RIDGE)
        got = fleet_merge_sharded(
            fleet_s, ring(d, hops=hops), mesh, ("data",), ridge=RIDGE
        )
        _assert_state_close(got, ref, rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------ runtime parity


def _mk_runtime(fleet, topo, **kw):
    return FleetRuntime(fleet, RuntimeConfig(topology=topo, ridge=RIDGE, **kw))


@pytest.mark.parametrize("ingest_backend", ["xla", "pallas"])
def test_runtime_tick_parity_kernel_vs_xla_ingest(ingest_backend):
    """Kernel ingest ↔ XLA ingest produce identical TickReports (same
    losses, same detector flags, same merge decisions) and the kernel
    runtime stays compile-once."""
    from repro.runtime import GovernorConfig

    d, f, nh, b = 12, 24, 8, 8
    topo = star(d)
    gov = GovernorConfig(merge_every=3)
    rt_ref = _mk_runtime(_fleet(d=d, f=f, nh=nh), topo, governor=gov)
    rt_k = _mk_runtime(
        _fleet(d=d, f=f, nh=nh), topo, governor=gov,
        use_ingest_kernel=True, ingest_backend=ingest_backend,
    )
    rng = np.random.default_rng(0)
    merges = 0
    for _ in range(8):
        batch = rng.random((d, b, f), np.float32)
        rep_ref = rt_ref.tick(batch)
        rep_k = rt_k.tick(batch)
        np.testing.assert_allclose(rep_k.losses, rep_ref.losses,
                                   rtol=1e-5, atol=1e-7)
        assert np.array_equal(rep_k.drifted, rep_ref.drifted)
        assert np.array_equal(rep_k.fresh_detections, rep_ref.fresh_detections)
        assert rep_k.decision.merge == rep_ref.decision.merge
        assert rep_k.decision.participants == rep_ref.decision.participants
        merges += rep_ref.decision.merge
    assert merges > 0, "soak never merged — parity test lost its teeth"
    sizes = rt_k.assert_compile_once()
    assert sizes["ingest_detect"] == 1
    _assert_state_close(rt_k.states, rt_ref.states, rtol=1e-4, atol=1e-5)


# --------------------------------------------------------- history/regression


def test_bench_history_record_and_gate(tmp_path):
    """Satellite: BENCH_history.jsonl appends entries per run and the
    gate fails only on a >25% wall-clock regression vs the previous
    same-backend baseline (first run seeds it). A failing run is still
    recorded (artifact-first) but marked regressed, so it never becomes
    the baseline a re-run would silently pass against."""
    import json

    from benchmarks.history import check_regression, record, record_and_gate

    path = tmp_path / "hist.jsonl"
    assert record("b1", {"x_us": 100.0}, path=path) is None     # seeds
    prev = record("b1", {"x_us": 110.0}, path=path)             # +10%: fine
    assert prev is not None and prev["metrics"]["x_us"] == 100.0
    assert check_regression(prev, {"x_us": 110.0}) == []
    assert check_regression(prev, {"x_us": 130.0}) != []        # +30%: fails
    # non-_us keys and new keys never gate
    assert check_regression(prev, {"x_us": 101.0, "aux": 9e9, "new_us": 5}) == []
    with pytest.raises(AssertionError, match="regression"):
        record_and_gate("b1", {"x_us": 200.0}, path=path)
    # the failing run was recorded (artifact-first), flagged regressed...
    entries = [json.loads(line) for line in path.read_text().splitlines()]
    assert entries[-1]["metrics"]["x_us"] == 200.0
    assert entries[-1]["regressed"] is True
    # ...and did NOT ratchet the baseline: the next run still gates
    # against the last GOOD entry (110), so re-running the regressed
    # timing fails again instead of self-healing
    with pytest.raises(AssertionError, match="regression"):
        record_and_gate("b1", {"x_us": 200.0}, path=path)
    assert record("b1", {"x_us": 1.0}, path=path)["metrics"]["x_us"] == 110.0


def test_ingest_rejects_per_device_bases():
    """A fleet stacked from independent per-device SLFN bases cannot be
    fused-ingested (the kernel projects through ONE shared basis) —
    validated at every concrete entry point instead of silently using
    device 0's basis."""
    from repro.core import init_autoencoder

    d, f, nh = 6, 16, 4
    keys = jax.random.split(jax.random.PRNGKey(0), d)
    x_init = jax.random.uniform(jax.random.PRNGKey(1), (d, 4 * nh, f))
    per_dev = [init_autoencoder(k, f, nh, x0, activation="identity", ridge=1e-2)
               for k, x0 in zip(keys, x_init)]
    stacked = jax.tree.map(lambda *leaves: jnp.stack(leaves), *per_dev)
    win = _window(d=d, t=8, f=f)
    with pytest.raises(ValueError, match="shared SLFN basis"):
        fleet_ingest(stacked, win)
    with pytest.raises(ValueError, match="shared SLFN basis"):
        fleet_train_rounds(stacked, win, star(d), rounds=2, kernel=True)
    with pytest.raises(ValueError, match="shared SLFN basis"):
        FleetRuntime(stacked, RuntimeConfig(topology=star(d),
                                            use_ingest_kernel=True))
    # the reference scan path handles per-device bases fine
    fleet_train(stacked, win)


def test_fleet_train_sharded_compile_once():
    """The sharded ingest is a serve-loop hot path: repeated calls with
    the same (mesh, axes, kernel, backend) reuse ONE jitted callable
    instead of re-tracing per call."""
    from repro.fleet.sharded import _SHARDED_JIT_CACHE

    d = 8
    fleet = _fleet(d=d)
    mesh = jax.make_mesh((1,), ("data",))
    fleet_s = shard_fleet(fleet, mesh)
    _SHARDED_JIT_CACHE.clear()
    sizes = []
    for seed in (2, 3, 4, 5):
        fleet_s = fleet_train_sharded(
            fleet_s, _window(d=d, seed=seed), mesh, ("data",)
        )
        assert len(_SHARDED_JIT_CACHE) == 1  # one callable, not one per call
        sizes.append(next(iter(_SHARDED_JIT_CACHE.values()))._cache_size())
    # the device_put input and the jit-output sharding may compile once
    # each; after that the trace count must be FLAT across ticks
    assert sizes[-1] == sizes[1] <= 2, sizes
