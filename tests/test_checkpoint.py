"""CheckpointManager durability contract: keep-last-N retention GC,
atomic snapshot writes (a crash mid-save leaves only a *.tmp turd,
never a torn checkpoint), and the restore walk-back as the last line
of defense when the newest file is corrupt anyway."""
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(v: float) -> dict:
    return {"w": np.full((4, 3), v, np.float32),
            "step": np.asarray(int(v), np.int64)}


def _steps(mgr: CheckpointManager) -> list[int]:
    return sorted(int(p.stem.split("_")[1]) for p in mgr.dir.glob("ckpt_*.npz"))


def test_keep_last_retention_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=3)
    for s in range(8):
        mgr.save(s, _tree(float(s)))
    assert _steps(mgr) == [5, 6, 7]
    tree, step = mgr.restore(_tree(0.0))
    assert step == 7
    np.testing.assert_array_equal(tree["w"], _tree(7.0)["w"])


def test_keep_none_retains_everything(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=None)
    for s in range(6):
        mgr.save(s, _tree(float(s)))
    assert _steps(mgr) == list(range(6))


def test_keep_must_be_positive(tmp_path):
    with pytest.raises(ValueError, match="keep >= 1"):
        CheckpointManager(tmp_path, keep_last=0)


def test_atomic_write_cleans_interrupted_tmp(tmp_path):
    """A crash mid-save leaves a *.tmp file, never a torn checkpoint
    under the real name; the next save garbage-collects the turd."""
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(0, _tree(0.0))
    # simulate a previous process dying mid-write
    turd = tmp_path / "ckpt_00000001.npz.12345.tmp"
    turd.write_bytes(b"half a zip file")
    mgr.save(1, _tree(1.0))
    assert not turd.exists()
    assert _steps(mgr) == [0, 1]
    # the turd never shadowed a real checkpoint name
    _, step = mgr.restore(_tree(0.0))
    assert step == 1


def test_walkback_survives_corrupt_newest(tmp_path):
    """Atomicity protects against OUR crash; the walk-back protects
    against the disk corrupting a fully-renamed file after the fact."""
    mgr = CheckpointManager(tmp_path, keep=4)
    for s in range(3):
        mgr.save(s, _tree(float(s)))
    newest = tmp_path / "ckpt_00000002.npz"
    newest.write_bytes(newest.read_bytes()[: newest.stat().st_size // 2])
    tree, step = mgr.restore(_tree(0.0))
    assert step == 1
    np.testing.assert_array_equal(tree["w"], _tree(1.0)["w"])
    # an explicitly requested corrupt step still fails loudly
    with pytest.raises(Exception):
        mgr.restore(_tree(0.0), step=2)
