"""Byzantine-robust merges, deterministic fault injection, and runtime
hardening: kernel↔XLA parity of the trimmed segment sums, the trimmed
estimator's breakdown property (≤ trim-budget adversaries cannot drag a
coordinate outside the honest range), bit-for-bit equality with the
plain masked merge when the defense is off (trim=0, no clip), the
non-finite (U, V) guards, corrupt-checkpoint fallback, the governor's
strike/calm quarantine hysteresis, and crash/restore tick-identity of
the hardened runtime."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property-based in CI; deterministic sweep where hypothesis is absent
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.checkpoint import CheckpointManager
from repro.core import UV
from repro.fleet import (
    FaultInjector,
    FaultSpec,
    RobustConfig,
    finite_payload_mask,
    fleet_from_uv,
    fleet_merge_masked,
    fleet_merge_masked_kernel,
    fleet_merge_robust,
    fleet_to_uv,
    hierarchical,
    init_fleet,
    payload_clip,
    payload_outlier_scores,
    ring,
    star,
)
from repro.fleet.fleet import _solve_uv
from repro.kernels import (
    robust_segment_combine,
    robust_segment_sum_mix,
    robust_segment_sum_xla,
)
from repro.runtime import FleetRuntime, GovernorConfig, MergeGovernor, RuntimeConfig
from repro.scenarios import SCENARIOS, make_scenario, run_scenario
from repro.scenarios.evaluate import scenario_topology

jax.config.update("jax_platform_name", "cpu")

D, H, RIDGE = 8, 6, 1e-3


def _fleet(seed=0, d=D, n=10, h=H):
    key = jax.random.PRNGKey(seed)
    x0 = jax.random.normal(jax.random.PRNGKey(seed + 1), (d, 3 * h, n))
    return init_fleet(key, d, n, h, x0, ridge=RIDGE)


def _payload(fleet):
    uv = fleet_to_uv(fleet, ridge=RIDGE)
    return jnp.concatenate([uv.u, uv.v], axis=-1)


# ------------------------------------------------ kernel ↔ XLA oracle parity


@pytest.mark.parametrize("trim", [0, 1, 2])
def test_robust_segment_sum_kernel_matches_xla(trim):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(D, 4, 12)), jnp.float32)
    cids = np.asarray([0, 0, 0, 1, 1, 1, 2, 2], np.int32)
    mask = jnp.asarray([1, 1, 0, 1, 1, 1, 1, 1], jnp.float32)
    scale = jnp.asarray(rng.uniform(0.2, 1.0, size=D), jnp.float32)
    got = robust_segment_sum_mix(x, cids, mask, scale, 3, trim, interpret=True)
    want = robust_segment_sum_xla(x, cids, mask, scale, 3, trim)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-5)
    counts = jnp.asarray([2.0, 3.0, 2.0])
    est = robust_segment_combine(*got, counts, trim)
    assert np.isfinite(np.asarray(est)).all()
    if trim == 0:
        np.testing.assert_array_equal(np.asarray(est), np.asarray(got[0]))


# --------------------------------------- trimmed-estimator breakdown bound


def _check_trim_budget(seed: int, trim: int, n_adv: int, magnitude: float):
    """≤ trim adversaries per segment cannot drag any coordinate of the
    trimmed estimate outside the honest values' range (the classic
    trimmed-mean breakdown bound, in Eq. 8 sum units)."""
    n_adv = min(n_adv, trim)
    rng = np.random.default_rng(seed)
    d = 3 + 2 * trim + rng.integers(0, 4)  # enough survivors to trim
    x = rng.normal(size=(d, 2, 6)).astype(np.float32)
    adv = rng.choice(d, size=n_adv, replace=False)
    x_adv = x.copy()
    # adversaries push extremes in per-coordinate random directions
    x_adv[adv] = magnitude * np.sign(rng.normal(size=(n_adv, 2, 6))).astype(
        np.float32
    )
    cids = np.zeros(d, np.int32)
    ones = jnp.ones(d, jnp.float32)
    tot, lo, hi = robust_segment_sum_xla(
        jnp.asarray(x_adv), cids, ones, ones, 1, trim
    )
    est = np.asarray(
        robust_segment_combine(tot, lo, hi, jnp.asarray([float(d)]), trim)
    )[0]
    honest = np.delete(x, adv, axis=0)
    # estimate is count × trimmed-mean — compare in mean units
    mean_est = est / d
    eps = 1e-4
    assert (mean_est >= honest.min(axis=0) - eps).all()
    assert (mean_est <= honest.max(axis=0) + eps).all()


if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(
        st.integers(min_value=0, max_value=2**31 - 1),
        st.integers(min_value=1, max_value=2),
        st.integers(min_value=0, max_value=2),
        st.floats(min_value=10.0, max_value=1e6),
    )
    def test_trim_budget_breakdown_bound(seed, trim, n_adv, magnitude):
        _check_trim_budget(seed, trim, n_adv, magnitude)

else:

    @pytest.mark.parametrize("seed", range(8))
    @pytest.mark.parametrize("trim,n_adv", [(1, 1), (2, 1), (2, 2), (1, 0)])
    def test_trim_budget_breakdown_bound(seed, trim, n_adv):
        _check_trim_budget(seed, trim, n_adv, magnitude=1e4)


# ------------------------------------------- defense-off bit-for-bit parity


@pytest.mark.parametrize("topo_fn", [
    lambda: star(D),
    lambda: ring(D, hops=1),
    lambda: hierarchical(D, n_clusters=2),
])
def test_trim0_no_clip_is_bitexact_masked_merge(topo_fn):
    """With the defense off (trim=0, clip=∞) the robust entry point is
    the EXACT paper merge — same arrays, same summation order — on both
    the XLA and the kernel path."""
    fleet = _fleet()
    topo = topo_fn()
    mask = jnp.asarray([1, 1, 0, 1, 1, 1, 0, 1], jnp.float32)
    cfg = RobustConfig(trim=0, clip_norm=None)
    want = fleet_merge_masked(fleet, topo, mask, ridge=RIDGE)
    got, scores = fleet_merge_robust(
        fleet, topo, config=cfg, mask=mask, ridge=RIDGE
    )
    np.testing.assert_array_equal(np.asarray(got.beta), np.asarray(want.beta))
    np.testing.assert_array_equal(np.asarray(got.p), np.asarray(want.p))
    assert np.isfinite(np.asarray(scores)).all()

    want_k = fleet_merge_masked_kernel(fleet, topo, mask, ridge=RIDGE)
    got_k, _ = fleet_merge_robust(
        fleet, topo, config=cfg, mask=mask, ridge=RIDGE, kernel=True
    )
    np.testing.assert_array_equal(np.asarray(got_k.beta), np.asarray(want_k.beta))
    np.testing.assert_array_equal(np.asarray(got_k.p), np.asarray(want_k.p))


# --------------------------------------------- end-to-end trimmed defense


@pytest.mark.parametrize("topo_fn,kernel", [
    (lambda: star(D), False),
    (lambda: star(D), True),
    (lambda: ring(D, hops=1), False),
    (lambda: hierarchical(D, n_clusters=2), False),
])
def test_robust_merge_bounds_byzantine_influence(topo_fn, kernel):
    """One ×−50 attacker: the trimmed merge stays finite and lands near
    the clean merge, the naive merge is destroyed (non-finite solve or
    dragged an order of magnitude further), and the attacker's
    contribution-outlier score dominates every honest one. The fleet
    uses large init chunks so the honest Grams concentrate — the regime
    the trimmed-mean bound is about."""
    key = jax.random.PRNGKey(0)
    x0 = jax.random.normal(jax.random.PRNGKey(1), (D, 400, 10))
    fleet = init_fleet(key, D, 10, H, x0, ridge=RIDGE)
    topo = topo_fn()
    mask = jnp.ones(D, jnp.float32)
    w = _payload(fleet)
    attacker = 2
    w_adv = w.at[attacker].multiply(-50.0)
    cfg = RobustConfig(trim=1)

    from repro.fleet.robust import robust_merge_from_w

    clean = fleet_merge_masked(fleet, topo, mask, ridge=RIDGE)
    robust, scores = robust_merge_from_w(
        fleet, topo, mask, w_adv, cfg, RIDGE, kernel=kernel
    )
    naive_uv = UV(u=w_adv[:, :, :H], v=w_adv[:, :, H:])
    from repro.fleet.fleet import _masked_merge_body
    naive = _masked_merge_body(fleet, topo, mask, RIDGE, uv=naive_uv)

    honest = [d for d in range(D) if d != attacker]
    rb, cb = np.asarray(robust.beta)[honest], np.asarray(clean.beta)[honest]
    nb = np.asarray(naive.beta)[honest]
    assert np.isfinite(np.asarray(robust.beta)).all()
    assert np.isfinite(np.asarray(scores)).all()
    robust_err = np.abs(rb - cb).max()
    # the defense holds the honest merge well inside the clean betas'
    # own scale...
    assert robust_err < 0.5 * np.abs(cb).max(), robust_err
    # ...while the naive Eq. 8 sum is destroyed by the same payload
    naive_destroyed = (
        not np.isfinite(nb).all() or np.abs(nb - cb).max() > 10.0 * robust_err
    )
    assert naive_destroyed
    s = np.asarray(scores)
    assert s[attacker] > 10.0 * max(s[h] for h in honest), s


def test_payload_clip_and_outlier_scores():
    fleet = _fleet()
    w = _payload(fleet)
    w_adv = w.at[5].multiply(1e4)
    clipped, scale = payload_clip(w_adv, 10.0)
    norms = np.linalg.norm(
        np.asarray(clipped).reshape(D, -1), axis=1
    )
    assert (norms <= 10.0 + 1e-4).all()
    assert scale is not None and float(np.asarray(scale)[5]) < 1e-2
    passthrough, none_scale = payload_clip(w_adv, None)
    assert passthrough is w_adv and none_scale is None

    scores = np.asarray(payload_outlier_scores(w_adv, jnp.ones(D)))
    assert scores[5] > 5.0 and np.isfinite(scores).all()

    w_nan = w.at[1, 0, 0].set(jnp.nan).at[3, 0, 0].set(jnp.inf)
    fin = np.asarray(finite_payload_mask(w_nan))
    np.testing.assert_array_equal(
        fin, [True, False, True, False, True, True, True, True]
    )


# ------------------------------------------------- deterministic faults


def test_fault_injection_is_deterministic_and_windowed():
    specs = (
        FaultSpec(kind="scale", frac=0.25, magnitude=-25.0, seed=7),
        FaultSpec(kind="noise", devices=(1,), magnitude=0.5,
                  start_tick=4, end_tick=8, seed=2),
        FaultSpec(kind="nan", devices=(5,), start_tick=6, period=2),
        FaultSpec(kind="crash", devices=(0,), start_tick=3, end_tick=5),
    )
    a = FaultInjector(specs, D, seed=11)
    b = FaultInjector(specs, D, seed=11)
    shape = (D, 4, 6)
    for t in range(10):
        for ga, gb in zip(a.payload_ops(t, shape), b.payload_ops(t, shape)):
            np.testing.assert_array_equal(ga, gb)
        np.testing.assert_array_equal(a.crash_mask(t), b.crash_mask(t))

    # windows honored: outside [4, 8) device 1's noise is exactly zero
    _, noise_pre, _ = a.payload_ops(3, shape)
    _, noise_in, _ = a.payload_ops(5, shape)
    assert not noise_pre[1].any() and noise_in[1].any()
    # nan schedule: start 6, period 2 → ticks 6, 8, ... only
    for t, want in [(5, 0), (6, 1), (7, 0), (8, 1)]:
        _, _, nonfin = a.payload_ops(t, shape)
        assert nonfin[5] == want
    # crash window [3, 5)
    assert not a.crash_mask(2)[0]
    assert a.crash_mask(3)[0] and a.crash_mask(4)[0]
    assert not a.crash_mask(5)[0]
    # crash victims are faulty, not Byzantine — payload attackers are
    byz = a.byzantine_devices
    assert 0 not in byz and 1 in byz and 5 in byz
    # a seed change moves the frac-resolved victim set eventually;
    # at minimum the resolution is itself deterministic
    assert (
        FaultInjector(specs, D, seed=11).byzantine_devices == byz
    )

    # clean tick returns the SAME batch object (no copy on the hot path)
    batch = np.zeros((D, 2, 3), np.float32)
    clean = FaultInjector(
        (FaultSpec(kind="poison", devices=(2,), start_tick=5),), D
    )
    assert clean.poison_batch(batch, 0) is batch
    poisoned = clean.poison_batch(batch, 5)
    assert poisoned is not batch
    assert poisoned[2].any() and not poisoned[0].any()
    assert not batch[2].any()  # original untouched


@pytest.mark.parametrize("bad", [
    dict(kind="emp"),                                  # unknown kind
    dict(kind="scale", devices=(1,), frac=0.5),        # both selectors
    dict(kind="scale"),                                # neither selector
    dict(kind="scale", frac=1.5),
    dict(kind="scale", devices=(1,), period=0),
    dict(kind="scale", devices=(1,), start_tick=8, end_tick=4),
])
def test_fault_spec_validation_rejects(bad):
    with pytest.raises(ValueError):
        FaultSpec(**bad)


def test_fault_injector_rejects_out_of_range_devices():
    with pytest.raises(ValueError):
        FaultInjector((FaultSpec(kind="scale", devices=(9,)),), 4)


# ----------------------------------------------- non-finite (U, V) guards


def test_fleet_from_uv_rejects_and_repairs_nonfinite():
    fleet = _fleet()
    uv = fleet_to_uv(fleet, ridge=RIDGE)
    bad = UV(u=uv.u.at[1, 0, 0].set(jnp.nan),
             v=uv.v.at[4, 0, 0].set(jnp.inf))
    with pytest.raises(ValueError, match=r"\[1, 4\]"):
        fleet_from_uv(fleet, bad, ridge=RIDGE)
    repaired = fleet_from_uv(fleet, bad, ridge=RIDGE, nonfinite="repair")
    assert np.isfinite(np.asarray(repaired.beta)).all()
    assert np.isfinite(np.asarray(repaired.p)).all()
    # repaired devices reset to (I, 0): zero detector output
    np.testing.assert_allclose(np.asarray(repaired.beta[1]), 0.0)
    # untouched devices keep the exact clean solve
    clean = fleet_from_uv(fleet, uv, ridge=RIDGE)
    np.testing.assert_array_equal(
        np.asarray(repaired.beta[0]), np.asarray(clean.beta[0])
    )
    with pytest.raises(ValueError, match="nonfinite"):
        fleet_from_uv(fleet, uv, ridge=RIDGE, nonfinite="ignore")


def test_solve_uv_guard():
    fleet = _fleet()
    uv = fleet_to_uv(fleet, ridge=RIDGE)
    with pytest.raises(ValueError, match="non-finite"):
        _solve_uv(jnp.full((H, H), jnp.nan), uv.v[0], RIDGE)
    p, beta = _solve_uv(
        jnp.full((H, H), jnp.nan), uv.v[0], RIDGE, nonfinite="repair"
    )
    assert np.isfinite(np.asarray(p)).all()
    assert np.isfinite(np.asarray(beta)).all()
    # traced contexts skip the eager check instead of crashing the trace
    jitted = jax.jit(lambda u, v: _solve_uv(u, v, RIDGE))
    jp, _ = jitted(uv.u[0], uv.v[0])
    assert np.isfinite(np.asarray(jp)).all()


# ------------------------------------------- corrupt-checkpoint fallback


def test_checkpoint_restore_falls_back_past_corrupt_latest(tmp_path, caplog):
    cm = CheckpointManager(tmp_path, keep=4)
    tree = {"a": np.arange(6, dtype=np.int64).reshape(2, 3)}
    cm.save(1, tree)
    cm.save(2, {"a": tree["a"] + 1})
    latest = tmp_path / "ckpt_00000002.npz"
    latest.write_bytes(latest.read_bytes()[:40])  # torn write
    with caplog.at_level("WARNING"):
        got, step = cm.restore(tree)
    assert step == 1
    np.testing.assert_array_equal(got["a"], tree["a"])
    assert "falling back" in caplog.text

    # zero-byte snapshot falls through too
    cm.save(3, tree)
    (tmp_path / "ckpt_00000003.npz").write_bytes(b"")
    _, step = cm.restore(tree)
    assert step == 1

    # an explicitly requested step still fails loudly
    with pytest.raises(Exception):
        cm.restore(tree, step=3)

    # every candidate unreadable → FileNotFoundError, not a silent reset
    (tmp_path / "ckpt_00000001.npz").write_bytes(b"junk")
    with pytest.raises(FileNotFoundError, match="all unreadable"):
        cm.restore(tree)


# ------------------------------------- governor strike/calm hysteresis


def test_governor_escalation_and_readmission():
    cfg = RobustConfig(
        trim=1, score_threshold=4.0, score_readmit=2.0,
        escalate_after=2, readmit_after=3,
    )
    gov = MergeGovernor(star(4), H, 10, GovernorConfig(), robust=cfg)
    hot = np.asarray([1.0, 9.0, 1.0, 1.0])
    calm = np.asarray([1.0, 1.0, 1.0, 1.0])

    gov.observe_robust(hot)          # strike 1 — not yet quarantined
    assert not gov.robust_quarantined.any()
    gov.observe_robust(hot)          # strike 2 → quarantine
    assert gov.robust_quarantined.tolist() == [False, True, False, False]
    # quarantined devices are masked out of participation
    mask = gov.participation(np.zeros(4, bool), np.zeros(4))
    assert mask.tolist() == [True, False, True, True]

    # a single hot round among calm ones resets the calm counter
    gov.observe_robust(calm)
    gov.observe_robust(calm)
    gov.observe_robust(hot)
    assert gov.robust_quarantined[1]
    # readmit_after consecutive calm rounds release the device
    for _ in range(3):
        assert gov.robust_quarantined[1]
        gov.observe_robust(calm)
    assert not gov.robust_quarantined.any()
    # strikes must be consecutive: hot/calm alternation never escalates
    gov2 = MergeGovernor(star(4), H, 10, GovernorConfig(), robust=cfg)
    for _ in range(4):
        gov2.observe_robust(hot)
        gov2.observe_robust(calm)
    assert not gov2.robust_quarantined.any()


def test_robust_config_validation():
    with pytest.raises(ValueError):
        RobustConfig(trim=-1)
    with pytest.raises(ValueError):
        RobustConfig(clip_norm=0.0)
    with pytest.raises(ValueError):
        RobustConfig(score_threshold=1.0, score_readmit=2.0)
    with pytest.raises(ValueError):
        RobustConfig(escalate_after=0)


# --------------------------------------------- hardened runtime end-to-end


def _adversarial_spec(kind="scale", **kw):
    fault = FaultSpec(kind=kind, devices=(1,), start_tick=8, seed=3, **kw)
    return dataclasses.replace(
        make_scenario("driving", n_devices=6, ticks=32), faults=(fault,)
    )


def test_runtime_rejects_nonfinite_payloads_and_scores_merges():
    spec = _adversarial_spec(kind="nan")
    res = run_scenario(spec, "star", merge_every=8)
    assert res.robust is not None              # "auto" armed the defense
    assert spec.fault_devices() == (1,)
    rejected = sum(r.nonfinite_payloads for r in res.reports)
    assert rejected > 0
    merge_reports = [r for r in res.reports if r.decision.merge]
    assert merge_reports and all(
        r.robust_scores is not None for r in merge_reports
    )
    assert np.isfinite(res.merged_aucs).all()
    assert all(v == 1 for v in res.jit_cache_sizes.values())


def test_adversarial_preset_registered():
    assert "adversarial" in SCENARIOS
    spec = make_scenario("adversarial", n_devices=6, ticks=24)
    assert spec.faults and spec.fault_devices()
    # clean presets stay fault-free — their golden locks ride the exact
    # bit-for-bit merge path
    assert not make_scenario("har").faults


def test_runtime_crash_restore_is_tick_identical(tmp_path):
    """Kill the hardened runtime mid-soak, restore from the snapshot,
    replay: reports and final state must match an uninterrupted run."""
    spec = _adversarial_spec(kind="scale", magnitude=-25.0)
    sc = spec.build()
    key = jax.random.PRNGKey(0)
    feed = sc.feed()
    topo = scenario_topology("star", spec.n_devices)

    def config(snap=False):
        return RuntimeConfig(
            topology=topo, ridge=spec.ridge, detector=spec.detector,
            governor=GovernorConfig(merge_every=8),
            robust=RobustConfig(trim=1), faults=spec.fault_injector(),
            snapshot_every=8 if snap else None,
            snapshot_dir=tmp_path if snap else None,
        )

    ref = FleetRuntime(sc.init_fleet(key), config())
    ref_reports = ref.run(feed)

    doomed = FleetRuntime(sc.init_fleet(key), config(snap=True))
    doomed.run(feed, ticks=20)      # killed between snapshots
    del doomed

    revived = FleetRuntime(sc.init_fleet(key), config(snap=True))
    t0 = revived.restore()
    assert t0 == 16
    replay = [revived.tick(feed.tick_batch(t)) for t in range(t0, spec.ticks)]

    for r_ref, r_new in zip(ref_reports[t0:], replay):
        np.testing.assert_allclose(
            r_ref.losses, r_new.losses, rtol=0, atol=1e-6
        )
        np.testing.assert_array_equal(r_ref.drifted, r_new.drifted)
        assert r_ref.decision.merge == r_new.decision.merge
        assert r_ref.nonfinite_payloads == r_new.nonfinite_payloads
        if r_ref.robust_scores is not None:
            np.testing.assert_allclose(
                r_ref.robust_scores, r_new.robust_scores, rtol=0, atol=1e-5
            )
    np.testing.assert_allclose(
        np.asarray(ref.states.beta), np.asarray(revived.states.beta),
        rtol=0, atol=1e-6,
    )
    np.testing.assert_array_equal(
        ref.governor.robust_quarantined, revived.governor.robust_quarantined
    )
    revived.assert_compile_once()
