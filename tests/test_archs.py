"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates a REDUCED variant of the same
family (≤2 layers, d_model ≤ 512, ≤4 experts) and runs one forward /
train step on CPU asserting output shapes + no NaNs, plus a
prefill→decode continuation check. Full configs are only exercised via
the dry-run (ShapeDtypeStruct; see launch/dryrun.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import (
    active_param_count,
    decode_step,
    encoder_forward,
    init_params,
    input_specs,
    lm_loss,
    param_count,
    prefill,
)
from repro.models.config import INPUT_SHAPES

KEY = jax.random.PRNGKey(0)
ALL_ARCHS = sorted(ARCHS)


def _reduced_setup(name, B=2, S=32):
    cfg = get_config(name).reduced()
    params = init_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    fe = None
    if cfg.frontend is not None:
        fe = jax.random.normal(KEY, (B, cfg.n_frontend_tokens, cfg.d_frontend))
    return cfg, params, tokens, fe


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_step_smoke(name):
    cfg, params, tokens, fe = _reduced_setup(name)
    loss, metrics = jax.jit(
        lambda p, t, f: lm_loss(p, cfg, t, t, frontend=f)
    )(params, tokens, fe)
    assert np.isfinite(float(loss))
    assert metrics["features"].shape == (2, cfg.d_model)
    assert np.isfinite(np.asarray(metrics["features"])).all()
    # gradient flows and is finite
    g = jax.grad(lambda p: lm_loss(p, cfg, tokens, tokens, frontend=fe)[0])(params)
    flat = jax.tree.leaves(g)
    assert all(np.isfinite(np.asarray(x)).all() for x in flat)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_smoke(name):
    B, S = 2, 33
    cfg, params, tokens, fe = _reduced_setup(name, B, S)
    enc_out = encoder_forward(params, cfg, fe) if fe is not None else None
    logits, caches, feats = prefill(params, cfg, tokens[:, : S - 1], frontend=fe, cache_len=S + 4)
    assert logits.shape == (B, cfg.vocab)
    lg, new_caches = decode_step(
        params, cfg, tokens[:, S - 1], caches, jnp.asarray(S - 1, jnp.int32),
        enc_out=enc_out, max_seq=S + 4,
    )
    assert lg.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(lg)).all()
    if not cfg.n_experts:  # capacity-based MoE routing differs per grouping
        full, _, _ = prefill(params, cfg, tokens, frontend=fe)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_layer_pattern_matches_spec(name):
    cfg = get_config(name)
    pat = cfg.layer_pattern()
    assert len(pat) == cfg.n_layers
    if name == "gemma3-1b":
        assert pat.count("dense") == 4 and pat.count("swa") == 22  # 5:1
    if name == "hymba-1.5b":
        assert pat.count("hymba") == 3 and pat.count("hymba_swa") == 29
    if name == "xlstm-1.3b":
        assert pat.count("slstm") == 6 and pat.count("mlstm") == 42
    if name == "llama-3.2-vision-11b":
        assert pat.count("xattn") == 8 and pat.count("dense") == 32
    if name == "arctic-480b":
        assert set(pat) == {"arctic"}
    if name == "seamless-m4t-medium":
        assert set(pat) == {"dec"} and len(cfg.encoder_pattern()) == 12


# expected total parameter counts for the FULL configs (±20%), computed
# from the published sizes; validates the config numbers without allocating
EXPECTED_PARAMS = {
    "llama3-405b": 405e9,
    "arctic-480b": 482e9,
    "granite-34b": 34e9,
    "llama-3.2-vision-11b": 9.8e9,   # text side only (ViT is stubbed)
    "granite-3-2b": 2.6e9,
    "gemma3-1b": 1.0e9,
    "hymba-1.5b": 1.6e9,
    "xlstm-1.3b": 1.0e9,   # backbone approximation (no proj-factor-2 up/down)
    "granite-moe-3b-a800m": 3.4e9,
    "seamless-m4t-medium": 0.75e9,  # backbone only, conv frontend stubbed
}


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_full_config_param_count(name):
    cfg = get_config(name)
    shapes = jax.eval_shape(lambda k: init_params(k, cfg), KEY)
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    expected = EXPECTED_PARAMS[name]
    assert 0.7 * expected < n < 1.35 * expected, f"{name}: {n/1e9:.1f}B vs {expected/1e9:.0f}B"


def test_active_params_moe():
    cfg = get_config("granite-moe-3b-a800m").reduced()
    params = init_params(KEY, cfg)
    total = param_count(params)
    active = active_param_count(params, cfg)
    assert active < total


@pytest.mark.parametrize("name", ALL_ARCHS)
@pytest.mark.parametrize("shape", sorted(INPUT_SHAPES))
def test_input_specs_structure(name, shape):
    cfg = get_config(name)
    sh = INPUT_SHAPES[shape]
    specs = input_specs(cfg, sh)
    if sh.kind == "train":
        assert specs["tokens"].shape == (sh.global_batch, sh.seq_len)
    elif sh.kind == "prefill":
        assert specs["tokens"].shape == (sh.global_batch, sh.seq_len)
    else:
        assert specs["token"].shape == (sh.global_batch,)
        assert "caches" in specs
        # windowed kinds cap their cache at the window size
        if cfg.sliding_window:
            swa_kind = "swa" if "swa" in specs["caches"] else None
            if swa_kind:
                assert specs["caches"][swa_kind]["k"].shape[2] <= cfg.sliding_window


def test_long500k_support_flags():
    assert get_config("hymba-1.5b").supports_long_decode()
    assert get_config("xlstm-1.3b").supports_long_decode()
    assert get_config("gemma3-1b").supports_long_decode()
    assert not get_config("llama3-405b").supports_long_decode()
    assert not get_config("arctic-480b").supports_long_decode()
    from repro.configs.granite_3_2b import SWA_VARIANT
    assert SWA_VARIANT.supports_long_decode()


def test_fp8_kv_cache_option():
    """kv_cache_dtype='float8_e4m3fn' halves decode cache bytes and stays
    within a few percent of the bf16-cache logits."""
    import dataclasses

    r = dataclasses.replace(
        get_config("granite-3-2b").reduced(), param_dtype="bfloat16"
    )
    r8 = dataclasses.replace(r, kv_cache_dtype="float8_e4m3fn")
    params = init_params(KEY, r)
    B, S = 2, 33
    tokens = jax.random.randint(KEY, (B, S), 0, r.vocab)
    outs = {}
    for cfg, name in ((r, "bf16"), (r8, "f8")):
        _, caches, _ = prefill(params, cfg, tokens[:, : S - 1], cache_len=S + 4)
        if name == "f8":
            assert caches["dense"]["k"].dtype == jnp.float8_e4m3fn
        lg, _ = decode_step(
            params, cfg, tokens[:, S - 1], caches, jnp.asarray(S - 1, jnp.int32),
            max_seq=S + 4,
        )
        outs[name] = np.asarray(lg, np.float32)
    rel = np.abs(outs["bf16"] - outs["f8"]).max() / np.abs(outs["bf16"]).max()
    assert rel < 0.15, rel
