"""Tests for sharding rules, optimizers, checkpointing, and the mesh
federation (subprocess with 8 host devices)."""
import os
import subprocess
import sys
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.launch.sharding import param_specs, spec_for_leaf
from repro.optim import adam, clip_by_global_norm, sgd


class FakeMesh:
    """Just enough mesh surface for spec computation."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = SimpleNamespace(shape=shape)


MESH = FakeMesh((16, 16), ("data", "model"))


def test_spec_divisible_dims_shard():
    assert spec_for_leaf("wq", (16384, 16384), MESH) == P("data", "model")
    assert spec_for_leaf("down", (53248, 16384), MESH) == P("model", "data")
    assert spec_for_leaf("embed", (128256, 16384), MESH) == P("model", "data")


def test_spec_indivisible_dims_replicate():
    # hymba vocab 32001 is not divisible by 16 → replicated dim
    assert spec_for_leaf("embed", (32001, 1600), MESH) == P(None, "data")
    # granite-moe 40 experts not divisible by 16 → per-expert d_ff takes
    # the "model" axis instead (§Perf iteration 3.3 — otherwise every
    # model-axis device recomputes identical expert work)
    assert spec_for_leaf("w_gate", (40, 1536, 512), MESH) == P(None, "data", "model")
    assert spec_for_leaf("w_down", (40, 512, 1536), MESH) == P(None, "model", "data")
    # arctic 128 experts divisible
    assert spec_for_leaf("w_gate", (128, 7168, 4864), MESH) == P("model", "data", None)


def test_spec_layer_stacked_leading_none():
    # stacked layers get a leading None
    assert spec_for_leaf("wq", (126, 16384, 16384), MESH) == P(None, "data", "model")


def test_spec_unknown_name_replicates():
    assert spec_for_leaf("mystery", (64, 64), MESH) == P()
    assert spec_for_leaf("gate", (), MESH) == P()  # VLM scalar gate


def test_param_specs_tree():
    from repro.configs import get_config
    from repro.models import init_params

    cfg = get_config("gemma3-1b")
    shapes = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    specs = param_specs(shapes, MESH)
    # embed: 262144 % 16 == 0 → model; 1152 % 16 == 0 → data
    assert specs["embed"] == P("model", "data")
    assert specs["final_norm"] == P()
    swa = specs["layers"]["swa"]
    assert swa["attn"]["wq"] == P(None, "data", "model")


# ------------------------------------------------------------- optimizers


def test_adam_converges_quadratic():
    opt = adam(0.1)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adam_bf16_moments():
    opt = adam(1e-3, moment_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((4, 4))}
    state = opt.init(params)
    assert state.mu["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4, 4))}
    params2, state2 = opt.update(g, state, params)
    assert state2.mu["w"].dtype == jnp.bfloat16
    assert float(params2["w"][0, 0]) < 1.0


def test_sgd_momentum():
    opt = sgd(0.1, momentum=0.9)
    params = jnp.asarray(4.0)
    state = opt.init(params)
    for _ in range(100):
        params, state = opt.update(jax.grad(lambda w: w**2)(params), state, params)
    assert abs(float(params)) < 5e-2


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 10.0, "b": jnp.ones(2) * 10.0}
    clipped = clip_by_global_norm(g, 1.0)
    norm = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(float(norm), 1.0, rtol=1e-5)
    small = {"a": jnp.ones(2) * 0.1}
    same = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 0.1, rtol=1e-4)


# ------------------------------------------------------------ checkpoints


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones(4, jnp.bfloat16)},
            "lst": [jnp.zeros(2), jnp.full((1,), 7.0)]}
    save_pytree(tree, tmp_path / "x.npz")
    back = load_pytree(tree, tmp_path / "x.npz")
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_allclose(
            np.asarray(x, dtype=np.float32), np.asarray(y, dtype=np.float32)
        )


def test_checkpoint_manager_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"w": jnp.ones(3)}
    for step in (10, 20, 30):
        mgr.save(step, tree)
    assert mgr.latest_step() == 30
    files = sorted(p.name for p in tmp_path.glob("ckpt_*.npz"))
    assert len(files) == 2  # keep-last-2
    restored, step = mgr.restore(tree)
    assert step == 30
    np.testing.assert_allclose(np.asarray(restored["w"]), 1.0)


def test_checkpoint_oselm_state(tmp_path):
    from repro.core import init_oselm, init_slfn, oselm_predict

    params = init_slfn(jax.random.PRNGKey(0), 16, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    st = init_oselm(params, x, x, activation="sigmoid", ridge=1e-3)
    save_pytree(st, tmp_path / "det.npz")
    back = load_pytree(st, tmp_path / "det.npz")
    np.testing.assert_allclose(
        np.asarray(oselm_predict(st, x[:4])), np.asarray(oselm_predict(back, x[:4])),
        rtol=1e-6,
    )


# --------------------------------------------- mesh federation, 8 devices

_SUBPROC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import init_oselm, init_slfn, oselm_train_sequential, to_uv, cooperative_update
from repro.federated import mesh_cooperative_update
mesh = jax.make_mesh((8,), ("data",))
params = init_slfn(jax.random.PRNGKey(0), 24, 12)
states, xs = [], []
for s in range(8):
    x = jax.random.normal(jax.random.PRNGKey(s + 1), (64, 24))
    st = init_oselm(params, x[:32], x[:32], activation="sigmoid", ridge=1e-4)
    st = oselm_train_sequential(st, x[32:], x[32:])
    states.append(st); xs.append(x)
stacked = jax.tree.map(lambda *a: jnp.stack(a), *states)
merged = mesh_cooperative_update(stacked, mesh, ("data",), ridge=1e-4)
ref = cooperative_update(states[0], *[to_uv(s) for s in states[1:]])
diff = float(jnp.max(jnp.abs(merged.beta[0] - ref.beta)))
identical = bool(jnp.allclose(merged.beta[0], merged.beta[7], atol=1e-5))
print("RESULT", diff, identical)
assert diff < 2e-2 and identical
"""


@pytest.mark.slow
def test_mesh_federation_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC_SCRIPT], env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
        capture_output=True, text=True, timeout=300,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "RESULT" in out.stdout


_SHARDED_FLEET_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.fleet import (init_fleet, fleet_train, fleet_merge, fleet_merge_sharded,
                         star, hierarchical, all_to_all)
from repro.launch.sharding import shard_fleet

mesh = jax.make_mesh((8,), ("data",))
D, H, F = 32, 8, 24
key = jax.random.PRNGKey(0)
x_init = jax.random.uniform(key, (D, 2 * H, F))
fleet = init_fleet(key, D, F, H, x_init, activation="identity", ridge=1e-3)
fleet = fleet_train(fleet, jax.random.uniform(jax.random.PRNGKey(1), (D, 16, F)))
fleet_s = shard_fleet(fleet, mesh)
worst = 0.0
for topo in (all_to_all(D), star(D), hierarchical(D, 4),
             hierarchical(D, 4, head_exchange=False)):
    ref = fleet_merge(fleet, topo, ridge=1e-3)
    got = fleet_merge_sharded(fleet_s, topo, mesh, ("data",), ridge=1e-3)
    worst = max(worst, float(jnp.max(jnp.abs(np.asarray(got.beta) - np.asarray(ref.beta)))))
print("RESULT", worst)
assert worst < 1e-4
"""


@pytest.mark.slow
def test_sharded_fleet_merge_subprocess():
    """psum-of-segment-sums fleet merge across 8 real host shards equals
    the single-process fleet_merge (O(clusters) collective payloads)."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_FLEET_SCRIPT], env=env,
        cwd=os.path.dirname(os.path.dirname(__file__)),
        capture_output=True, text=True, timeout=300,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "RESULT" in out.stdout


_SHARDED_TRAIN_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.fleet import (init_fleet, fleet_train, fleet_train_sharded,
                         fleet_merge, fleet_merge_sharded, ring)
from repro.launch.sharding import shard_fleet

mesh = jax.make_mesh((8,), ("data",))
D, H, F, T = 32, 8, 24, 16
key = jax.random.PRNGKey(0)
x_init = jax.random.uniform(key, (D, 2 * H, F))
fleet = init_fleet(key, D, F, H, x_init, activation="identity", ridge=1e-3)
streams = jax.random.uniform(jax.random.PRNGKey(1), (D, T, F))
ref = fleet_train(fleet, streams)

fleet_s = shard_fleet(fleet, mesh)
worst = 0.0
# per-shard ingest (no collectives), scan and fused-kernel paths
for kw in (dict(), dict(kernel=True, backend="xla"),
           dict(kernel=True, backend="pallas", interpret=True)):
    got = fleet_train_sharded(fleet_s, streams, mesh, ("data",), **kw)
    worst = max(worst, float(jnp.max(jnp.abs(
        np.asarray(got.beta) - np.asarray(ref.beta)))))

# open-ring halo-exchange merge across the 8 real shards (hops < D/8
# stays within adjacent shards; hops == D/8 == 4 is the edge case)
trained_s = fleet_train_sharded(fleet_s, streams, mesh, ("data",))
for hops in (1, 2, 4):
    mref = fleet_merge(ref, ring(D, hops=hops), ridge=1e-3)
    mgot = fleet_merge_sharded(trained_s, ring(D, hops=hops), mesh, ("data",),
                               ridge=1e-3)
    worst = max(worst, float(jnp.max(jnp.abs(
        np.asarray(mgot.beta) - np.asarray(mref.beta)))))
try:  # a band wider than a shard straddles non-adjacent shards
    fleet_merge_sharded(trained_s, ring(D, hops=5), mesh, ("data",))
    raise SystemExit("expected halo hops validation to fire")
except ValueError as e:
    assert "halo" in str(e), e
print("RESULT", worst)
assert worst < 1e-4
"""


@pytest.mark.slow
def test_sharded_fleet_train_subprocess():
    """shard_map'd tick ingest (scan AND fused-kernel paths) across 8
    real host shards equals the single-process fleet_train, and the
    open-ring halo-exchange merge equals fleet_merge — sharded training
    and banded merges compose end-to-end."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_TRAIN_SCRIPT], env=env,
        cwd=os.path.dirname(os.path.dirname(__file__)),
        capture_output=True, text=True, timeout=300,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "RESULT" in out.stdout
