"""Kernel-vs-reference parity for the fused topology-merge path.

The Pallas ``topology_mix`` family and the fused ``from_uv_solve`` /
``banded_merge_solve`` kernels must match ``Topology.mix`` +
``fleet_from_uv`` (Cholesky) to ≤1e-5 for all four topologies, under
interpret=True on CPU and with odd D/Ñ tile remainders (nothing
aligned to the (8, 128) f32 tile)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fleet import (
    all_to_all,
    fleet_from_uv,
    fleet_merge,
    fleet_merge_kernel,
    fleet_merge_sharded,
    fleet_to_uv,
    fleet_train,
    fleet_train_rounds,
    hierarchical,
    init_fleet,
    ring,
    star,
)
from repro.core import UV
from repro.kernels import (
    banded_merge_solve,
    banded_mix,
    dense_mix,
    from_uv_solve,
    segment_broadcast,
    segment_sum_mix,
    topology_mix,
)
from repro.launch.sharding import fleet_stack_spec, shard_fleet

# odd everywhere: D, R, C all miss the (8, 128) tile grid
D_ODD, R_ODD, C_ODD = 13, 10, 37
RIDGE = 1e-3

TOPO_FNS = {
    "all_to_all": all_to_all,
    "star": star,
    "ring2": lambda n: ring(n, hops=2),
    "ring_closed": lambda n: ring(n, hops=(n + 1) // 2),
    "hierarchical": lambda n: hierarchical(n, 3),
    "hierarchical_isolated": lambda n: hierarchical(n, 3, head_exchange=False),
}


def _rand(seed, shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape)


@pytest.fixture(scope="module")
def small_fleet():
    d, feat, hid = 12, 24, 8
    key = jax.random.PRNGKey(0)
    x_init = jax.random.uniform(key, (d, 2 * hid, feat))
    fleet = init_fleet(key, d, feat, hid, x_init, activation="identity", ridge=RIDGE)
    streams = jax.random.uniform(jax.random.PRNGKey(1), (d, 16, feat))
    return fleet_train(fleet, streams), d


@pytest.mark.parametrize("topo_name", sorted(TOPO_FNS))
@pytest.mark.parametrize("d,r,c", [(D_ODD, R_ODD, C_ODD), (16, 8, 128)])
def test_topology_mix_kernel_matches_reference(topo_name, d, r, c):
    """Pallas mix == dense-matrix einsum == Topology.mix, ragged and
    tile-aligned shapes."""
    topo = TOPO_FNS[topo_name](d)
    x = _rand(d * r + c, (d, r, c))
    want = jnp.einsum("ij,j...->i...", jnp.asarray(topo.dense_matrix()), x)
    got_xla = topo.mix(x)
    got_kernel = topology_mix(x, topo, interpret=True)
    np.testing.assert_allclose(np.asarray(got_xla), np.asarray(want), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_kernel), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_banded_mix_rejects_overwide_band():
    x = _rand(0, (5, 8, 16))
    with pytest.raises(ValueError, match="band"):
        banded_mix(x, 3, interpret=True)


def test_segment_kernels_roundtrip():
    """segment_sum_mix + segment_broadcast == segment_sum + gather."""
    cids = np.array([0] * 4 + [1] * 6 + [2] * 3, np.int32)
    x = _rand(2, (13, R_ODD, C_ODD))
    sums = segment_sum_mix(x, cids, 3, interpret=True)
    want = jax.ops.segment_sum(x, jnp.asarray(cids), num_segments=3)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(want), rtol=1e-5, atol=1e-5)
    back = segment_broadcast(sums, cids, interpret=True)
    np.testing.assert_allclose(np.asarray(back), np.asarray(want)[cids], rtol=1e-5, atol=1e-5)


def test_segment_sum_mix_rejects_unsorted_ids():
    """The kernel accumulates contiguous cluster runs; unsorted ids
    would silently drop partial sums, so they must be rejected."""
    x = _rand(8, (3, 8, 16))
    with pytest.raises(ValueError, match="sorted"):
        segment_sum_mix(x, np.array([0, 1, 0], np.int32), 2, interpret=True)


def test_dense_mix_tile_remainders():
    """Tiled dense kernel == einsum on shapes straddling block edges."""
    d, r, c = 33, 5, 29
    m = (np.random.default_rng(0).random((d, d)) < 0.3).astype(np.float32)
    np.fill_diagonal(m, 1.0)
    x = _rand(3, (d, r, c))
    got = dense_mix(x, m, interpret=True)
    want = jnp.einsum("ij,j...->i...", jnp.asarray(m), x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_from_uv_solve_matches_cholesky():
    """Fused Gauss-Jordan kernel == invert_u/solve_beta (Cholesky) to
    ≤1e-5, odd Ñ and odd device count."""
    dn, n, m = D_ODD, R_ODD, 23
    h = _rand(4, (dn, 5 * n, n))
    u = jnp.einsum("dkn,dkm->dnm", h, h)
    v = _rand(5, (dn, n, m))
    p, beta = from_uv_solve(u, v, ridge=RIDGE, interpret=True)
    ureg = u + RIDGE * jnp.eye(n)
    np.testing.assert_allclose(
        np.asarray(p), np.asarray(jnp.linalg.inv(ureg)), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(beta), np.asarray(jnp.linalg.solve(ureg, v)), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("hops", [1, 2])
def test_banded_merge_solve_fuses_mix_and_solve(hops):
    """One kernel: neighbor-sum + ridge-add + solve == roll-sum then
    Cholesky."""
    dn, n, m = D_ODD, R_ODD, 23
    h = _rand(6, (dn, 5 * n, n))
    u = jnp.einsum("dkn,dkm->dnm", h, h)
    v = _rand(7, (dn, n, m))
    w = jnp.concatenate([u, v], axis=2)
    p, beta = banded_merge_solve(w, hops, ridge=RIDGE, interpret=True)
    wm = sum(jnp.roll(w, o, axis=0) for o in range(-hops, hops + 1))
    ureg = wm[:, :, :n] + RIDGE * jnp.eye(n)
    np.testing.assert_allclose(
        np.asarray(p), np.asarray(jnp.linalg.inv(ureg)), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(beta), np.asarray(jnp.linalg.solve(ureg, wm[:, :, n:])),
        rtol=1e-5, atol=1e-5,
    )


@pytest.mark.parametrize("topo_name", sorted(TOPO_FNS))
def test_fleet_merge_kernel_matches_fleet_merge(small_fleet, topo_name):
    """End-to-end: the Pallas merge (mix kernels + fused solve) equals
    the XLA fleet_merge for every topology."""
    fleet, d = small_fleet
    topo = TOPO_FNS[topo_name](d)
    ref = fleet_merge(fleet, topo, ridge=RIDGE)
    got = fleet_merge_kernel(fleet, topo, ridge=RIDGE, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got.beta), np.asarray(ref.beta), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(got.p), np.asarray(ref.p), rtol=1e-5, atol=1e-5
    )


def test_fleet_merge_matches_mix_plus_from_uv(small_fleet):
    """The structure-aware merge (cluster-level solves) is exactly the
    naive mix-then-solve-per-device reference."""
    fleet, d = small_fleet
    for topo in (star(d), hierarchical(d, 3), hierarchical(d, 3, head_exchange=False)):
        uv = fleet_to_uv(fleet, ridge=RIDGE)
        mixed = UV(u=topo.mix(uv.u), v=topo.mix(uv.v))
        ref = fleet_from_uv(fleet, mixed, ridge=RIDGE)
        got = fleet_merge(fleet, topo, ridge=RIDGE)
        np.testing.assert_allclose(
            np.asarray(got.beta), np.asarray(ref.beta), rtol=1e-4, atol=1e-5
        )


def test_n_clusters_precomputed():
    """Satellite: n_clusters is frozen at construction, not re-derived
    from cluster_ids.max() on every mix call."""
    assert hierarchical(12, 5).n_clusters == 5
    assert star(9).n_clusters == 1
    assert hierarchical(12, 5, head_exchange=False).n_clusters == 5


def test_fleet_train_rounds_warns_on_truncation(small_fleet, caplog):
    """Satellite: steps % rounds != 0 drops the tail and logs it."""
    fleet, d = small_fleet
    streams = jax.random.uniform(jax.random.PRNGKey(2), (d, 17, 24))
    with caplog.at_level("WARNING", logger="repro.fleet.fleet"):
        out = fleet_train_rounds(fleet, streams, star(d), rounds=4, ridge=RIDGE)
    assert any("dropping the tail" in r.message for r in caplog.records)
    # truncation is exact: equals training on the first 16 steps only
    caplog.clear()
    with caplog.at_level("WARNING", logger="repro.fleet.fleet"):
        ref = fleet_train_rounds(fleet, streams[:, :16], star(d), rounds=4, ridge=RIDGE)
    assert not caplog.records
    np.testing.assert_allclose(
        np.asarray(out.beta), np.asarray(ref.beta), rtol=1e-5, atol=1e-6
    )


def test_fleet_train_rounds_scan_matches_python_loop(small_fleet):
    """The compile-once lax.scan equals the old train/merge round loop."""
    fleet, d = small_fleet
    streams = jax.random.uniform(jax.random.PRNGKey(3), (d, 16, 24))
    topo = ring(d, hops=2)
    got = fleet_train_rounds(fleet, streams, topo, rounds=4, ridge=RIDGE)
    st = fleet
    chunks = streams.reshape(d, 4, 4, 24)
    for r in range(4):
        st = fleet_train(st, chunks[:, r])
        st = fleet_merge(st, topo, ridge=RIDGE)
    np.testing.assert_allclose(
        np.asarray(got.beta), np.asarray(st.beta), rtol=1e-4, atol=1e-5
    )


def test_fleet_merge_sharded_single_shard(small_fleet):
    """psum-of-segment-sums merge on a 1-shard mesh equals fleet_merge
    for every cluster-wise-constant topology; the open ring now takes
    the ppermute halo-exchange path (its 1-shard degenerate form is the
    circular wrap); arbitrary sparse dense-mask topologies stay
    rejected."""
    from repro.fleet.topology import Topology

    fleet, d = small_fleet
    mesh = jax.make_mesh((1,), ("data",))
    assert fleet_stack_spec(("data",)) == jax.sharding.PartitionSpec(("data",))
    fleet_s = shard_fleet(fleet, mesh)
    for topo in (all_to_all(d), star(d), hierarchical(d, 3),
                 hierarchical(d, 3, head_exchange=False), ring(d, hops=d // 2),
                 ring(d, hops=1), ring(d, hops=2)):
        ref = fleet_merge(fleet, topo, ridge=RIDGE)
        got = fleet_merge_sharded(fleet_s, topo, mesh, ("data",), ridge=RIDGE)
        np.testing.assert_allclose(
            np.asarray(got.beta), np.asarray(ref.beta), rtol=1e-4, atol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(got.p), np.asarray(ref.p), rtol=1e-4, atol=1e-5
        )
    m = np.eye(d, dtype=np.float32)
    m[0, 1] = m[1, 0] = 1.0  # sparse custom mask: not cluster-wise constant
    custom = Topology(name="custom", n_devices=d, kind="dense", matrix=m)
    with pytest.raises(NotImplementedError, match="neighbor sets"):
        fleet_merge_sharded(fleet_s, custom, mesh, ("data",))
