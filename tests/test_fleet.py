"""Fleet simulator tests: topology merges vs the paper's Eq. 8 sum,
async staleness, the non-IID partitioner, and communication accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cooperative_update, oselm_train_sequential, to_uv
from repro.data import make_har_dataset
from repro.data.synthetic import AnomalyDataset
from repro.fleet import (
    DriftEvent,
    StalenessSchedule,
    all_to_all,
    device_state,
    fedavg_total_cost,
    fleet_merge,
    fleet_score,
    fleet_to_uv,
    fleet_train,
    fleet_train_async,
    fleet_train_rounds,
    hierarchical,
    init_fleet,
    make_fleet_streams,
    make_topology,
    model_nbytes,
    payload_nbytes,
    random_drift_schedule,
    ring,
    star,
    topology_round_cost,
)

D, H, STEPS, RIDGE = 12, 8, 32, 1e-3


@pytest.fixture(scope="module")
def har2():
    """Normalized 2-pattern HAR subset (cheap, well-conditioned)."""
    ds = make_har_dataset(seed=0, samples_per_class=80, n_features=48)
    lo, hi = ds.x.min(0), ds.x.max(0)
    ds = ds._replace(x=((ds.x - lo) / (hi - lo + 1e-6)).astype(np.float32))
    mask = ds.y < 2
    return AnomalyDataset(ds.name, ds.x[mask], ds.y[mask], ds.class_names[:2])


@pytest.fixture(scope="module")
def trained_fleet(har2):
    fs = make_fleet_streams(har2, D, STEPS, n_init=2 * H, seed=0)
    fleet = init_fleet(
        jax.random.PRNGKey(0), D, har2.n_features, H, fs.x_init,
        activation="identity", ridge=RIDGE,
    )
    return fleet_train(fleet, fs.xs), fs


def test_fleet_train_matches_per_device_sequential(trained_fleet, har2):
    """vmap-over-devices training is exactly per-device scan training."""
    fleet, fs = trained_fleet
    init = init_fleet(
        jax.random.PRNGKey(0), D, har2.n_features, H, fs.x_init,
        activation="identity", ridge=RIDGE,
    )
    for d in (0, D - 1):
        ref = oselm_train_sequential(
            device_state(init, d), jnp.asarray(fs.xs[d]), jnp.asarray(fs.xs[d])
        )
        np.testing.assert_allclose(
            np.asarray(device_state(fleet, d).beta), np.asarray(ref.beta),
            rtol=1e-4, atol=1e-5,
        )


def test_all_to_all_matches_pairwise_cooperative_update(trained_fleet):
    """The stacked all-to-all merge IS the paper's Eq. 8 cooperative
    update: identical to sequential pairwise uv_add on device 0."""
    fleet, _ = trained_fleet
    merged = fleet_merge(fleet, all_to_all(D), ridge=0.0)
    states = [device_state(fleet, d) for d in range(D)]
    ref = cooperative_update(states[0], *[to_uv(s) for s in states[1:]])
    np.testing.assert_allclose(
        np.asarray(device_state(merged, 0).beta), np.asarray(ref.beta),
        rtol=1e-3, atol=1e-4,
    )
    # and every device ends up with the identical merged model
    np.testing.assert_allclose(
        np.asarray(merged.beta), np.asarray(merged.beta[:1]).repeat(D, 0),
        rtol=1e-5, atol=1e-6,
    )


@pytest.mark.parametrize(
    "topo_fn",
    [
        star,
        lambda n: ring(n, hops=(n - 1 + 1) // 2),  # ring closed into full mesh
        lambda n: hierarchical(n, 3),
        lambda n: hierarchical(n, 1),
    ],
    ids=["star", "full_ring", "hierarchical", "hierarchical_single_cluster"],
)
def test_fully_connected_topologies_equal_all_to_all(trained_fleet, topo_fn):
    """Acceptance: every topology's merged state equals the all-to-all
    Eq. 8 sum when the graph is fully connected."""
    fleet, _ = trained_fleet
    topo = topo_fn(D)
    assert topo.is_fully_connected
    ref = fleet_merge(fleet, all_to_all(D), ridge=RIDGE)
    out = fleet_merge(fleet, topo, ridge=RIDGE)
    np.testing.assert_allclose(
        np.asarray(out.beta), np.asarray(ref.beta), rtol=1e-3, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(out.p), np.asarray(ref.p), rtol=1e-3, atol=1e-4
    )


def test_partial_ring_matches_manual_neighbor_sum(trained_fleet):
    """A 1-hop ring merge equals the hand-built Eq. 8 sum over
    {i-1, i, i+1} for each device."""
    fleet, _ = trained_fleet
    topo = ring(D, hops=1)
    assert not topo.is_fully_connected
    merged = fleet_merge(fleet, topo, ridge=0.0)
    uv = fleet_to_uv(fleet, ridge=0.0)
    for d in (0, 5):
        nbrs = [(d - 1) % D, d, (d + 1) % D]
        u_ref = sum(np.asarray(uv.u[j]) for j in nbrs)
        got = fleet_to_uv(
            jax.tree.map(lambda l: l[d][None], merged), ridge=0.0
        )
        np.testing.assert_allclose(np.asarray(got.u[0]), u_ref, rtol=1e-3, atol=0.5)


def test_hierarchical_segment_sum_matches_dense_matrix(trained_fleet):
    """The segment-sum implementation equals mixing with the equivalent
    dense matrix, with and without head exchange."""
    fleet, _ = trained_fleet
    uv = fleet_to_uv(fleet, ridge=RIDGE)
    for head_exchange in (True, False):
        topo = hierarchical(D, 4, head_exchange=head_exchange)
        dense = jnp.einsum("ij,j...->i...", jnp.asarray(topo.dense_matrix()), uv.u)
        np.testing.assert_allclose(
            np.asarray(topo.mix(uv.u)), np.asarray(dense), rtol=1e-5, atol=1e-5
        )


def test_isolated_clusters_do_not_mix(trained_fleet):
    """Without head exchange, devices in different clusters keep
    different merged models."""
    fleet, _ = trained_fleet
    topo = hierarchical(D, 3, head_exchange=False)
    merged = fleet_merge(fleet, topo, ridge=RIDGE)
    cids = topo.cluster_ids
    same = np.flatnonzero(cids == cids[0])
    other = np.flatnonzero(cids != cids[0])
    b = np.asarray(merged.beta)
    np.testing.assert_allclose(b[same[0]], b[same[-1]], rtol=1e-4, atol=1e-5)
    assert np.max(np.abs(b[same[0]] - b[other[0]])) > 1e-4


def test_async_zero_lag_equals_synchronous(trained_fleet, har2):
    fs = trained_fleet[1]

    def fresh():
        return init_fleet(
            jax.random.PRNGKey(0), D, har2.n_features, H, fs.x_init,
            activation="identity", ridge=RIDGE,
        )

    topo = ring(D, hops=2)
    sync = fleet_train_rounds(fresh(), fs.xs, topo, rounds=4, ridge=RIDGE)
    azero = fleet_train_async(
        fresh(), fs.xs, topo, StalenessSchedule.uniform(D, 0),
        rounds=4, ridge=RIDGE,
    )
    np.testing.assert_allclose(
        np.asarray(azero.beta), np.asarray(sync.beta), rtol=1e-4, atol=1e-5
    )


def test_async_lagged_merge_stays_finite_and_differs(trained_fleet, har2):
    fs = trained_fleet[1]

    def fresh():
        return init_fleet(
            jax.random.PRNGKey(0), D, har2.n_features, H, fs.x_init,
            activation="identity", ridge=RIDGE,
        )

    topo = star(D)
    sched = StalenessSchedule.random(D, max_lag=2, seed=3, stragglers=0.25)
    assert sched.max_lag == 2 and (sched.lags >= 0).all()
    lagged = fleet_train_async(fresh(), fs.xs, topo, sched, rounds=4, ridge=RIDGE)
    sync = fleet_train_async(
        fresh(), fs.xs, topo, StalenessSchedule.uniform(D, 0),
        rounds=4, ridge=RIDGE,
    )
    assert bool(jnp.isfinite(lagged.beta).all())
    assert float(jnp.max(jnp.abs(lagged.beta - sync.beta))) > 1e-6


def test_rounds_validation(trained_fleet, har2):
    fleet, fs = trained_fleet
    for rounds in (0, STEPS + 1):
        with pytest.raises(ValueError, match="rounds"):
            fleet_train_rounds(fleet, fs.xs, star(D), rounds=rounds)
        with pytest.raises(ValueError, match="rounds"):
            fleet_train_async(
                fleet, fs.xs, star(D), StalenessSchedule.uniform(D, 0), rounds=rounds
            )


def test_partitioner_drift_order_independent(har2):
    """A later-step drift wins even when the schedule is supplied out
    of order."""
    drift = (
        DriftEvent(device=0, step=20, new_pattern=1),
        DriftEvent(device=0, step=5, new_pattern=0),
    )
    fs = make_fleet_streams(har2, 1, 24, n_init=4, drift=drift, seed=0)
    assert (fs.pattern_of_device[0, 5:20] == 0).all()
    assert (fs.pattern_of_device[0, 20:] == 1).all()


def test_partitioner_round_robin_and_drift(har2):
    drift = (DriftEvent(device=1, step=10, new_pattern=0),)
    fs = make_fleet_streams(har2, 4, 24, n_init=8, drift=drift, seed=0)
    assert fs.xs.shape == (4, 24, har2.n_features)
    assert fs.x_init.shape == (4, 8, har2.n_features)
    # round robin: device d starts on pattern d % 2
    for d in range(4):
        assert fs.initial_pattern(d) == d % 2
    # drift: device 1 switches to pattern 0 at step 10
    assert (fs.pattern_of_device[1, :10] == 1).all()
    assert (fs.pattern_of_device[1, 10:] == 0).all()
    # non-drifting device keeps its pattern
    assert (fs.pattern_of_device[0] == 0).all()
    # stream samples actually come from the labeled pattern's pool
    pool0 = har2.pattern(0)
    assert all(
        (pool0 == fs.xs[1, t]).all(1).any() for t in (10, 23)
    )


def test_partitioner_dirichlet_mixture(har2):
    fs = make_fleet_streams(
        har2, 8, 64, n_init=4, assignment="dirichlet", alpha=100.0, seed=0
    )
    # near-IID at huge alpha: every device sees both patterns
    for d in range(8):
        assert len(np.unique(fs.pattern_of_device[d])) == 2
    with pytest.raises(ValueError):
        make_fleet_streams(har2, 2, 8, assignment="nope")


def test_random_drift_schedule_bounds(har2):
    drift = random_drift_schedule(20, 40, 2, frac=0.25, seed=1)
    assert len(drift) == 5
    for ev in drift:
        assert 0 <= ev.device < 20
        assert 10 <= ev.step < 30
        assert 0 <= ev.new_pattern < 2
        # never a no-op: the drift target differs from the device's
        # round-robin home pattern
        assert ev.new_pattern != ev.device % 2
    with pytest.raises(ValueError):
        random_drift_schedule(4, 8, 1)


def test_comm_cost_formulas():
    n, m = 16, 48  # Ñ, features
    pb = payload_nbytes(n, m)
    assert pb == n * (n + m) * 4  # the paper's Ñ(Ñ+m) floats
    assert topology_round_cost(all_to_all(D), n, m).payloads == D * (D - 1)
    assert topology_round_cost(star(D), n, m).payloads == 2 * (D - 1)
    assert topology_round_cost(ring(D, hops=1), n, m).payloads == 2 * D
    h = hierarchical(D, 3)
    assert topology_round_cost(h, n, m).payloads == 2 * (D - 3) + 3 * 2
    assert topology_round_cost(h, n, m).bytes_total == h.payloads_per_round * pb
    fed = fedavg_total_cost(D, 10, m, n, m)
    assert fed.payloads == 2 * D * 10
    assert fed.bytes_total == fed.payloads * model_nbytes(m, n, m)
    # the paper's claim at protocol level: one star round beats R-round
    # FedAvg whenever Ñ(Ñ+m) < R · model size
    assert topology_round_cost(star(D), n, m).bytes_total < fed.bytes_total


def test_make_topology_registry():
    t = make_topology("ring", 10, hops=3)
    assert t.n_devices == 10 and t.name == "ring3"
    assert make_topology("hierarchical", 16).kind == "segment"
    with pytest.raises(ValueError):
        make_topology("torus", 10)
    with pytest.raises(ValueError):
        hierarchical(4, 9)


def test_fleet_score_shape(trained_fleet, har2):
    fleet, _ = trained_fleet
    x = jnp.asarray(har2.x[:7])
    assert fleet_score(fleet, x).shape == (D, 7)


def test_topology_is_static_and_frozen():
    t = all_to_all(4)
    with pytest.raises(dataclasses.FrozenInstanceError):
        t.name = "x"
    assert hash(t) != hash(all_to_all(4))  # identity hash → valid jit static arg
