"""Serving front-end tests: request/ack protocol, dynamic batcher
(whole-request windows, padding, served masks), write-ahead log
durability + contiguity, admission policy branches, degraded-ladder
hysteresis, and the async ServeFrontend end-to-end — including
in-process crash-recovery equivalence (snapshot + WAL replay restores
the exact pre-crash fleet) and the skip-merge governor veto."""
import asyncio

import jax
import numpy as np
import pytest

try:  # property-based in CI; deterministic sweep where hypothesis is absent
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.fleet import init_fleet, ring
from repro.obs import TelemetryConfig
from repro.runtime import FleetRuntime, GovernorConfig, RuntimeConfig
from repro.serve import (
    AdmissionConfig,
    AdmissionController,
    DegradedLadder,
    LadderConfig,
    Mode,
    SampleRequest,
    ServeConfig,
    ServeFrontend,
    WindowBuilder,
    WriteAheadLog,
)

D, F, H, B = 8, 6, 4, 3
RIDGE = 1e-3


def _rng(seed=0):
    return np.random.default_rng(seed)


def _req(device=0, k=1, client="c", seed=1):
    return SampleRequest(
        device=device,
        x=_rng(seed).normal(size=(k, F)).astype(np.float32),
        client=client,
    )


def _runtime(tmp_path=None, *, snapshot_every=None, merge_every=4, d=D):
    rng = _rng(0)
    x_init = rng.normal(size=(d, 2 * H, F)).astype(np.float32)
    fleet = init_fleet(
        jax.random.PRNGKey(0), d, F, H, x_init,
        activation="identity", ridge=RIDGE,
    )
    return FleetRuntime(fleet, RuntimeConfig(
        topology=ring(d, hops=1),
        governor=GovernorConfig(merge_every=merge_every),
        snapshot_dir=None if tmp_path is None else str(tmp_path / "snap"),
        snapshot_every=snapshot_every,
        telemetry=TelemetryConfig(trace=False),
    ))


# ------------------------------------------------------------------ protocol


def test_request_promotes_1d_and_validates():
    r = SampleRequest(device=1, x=np.zeros(F, np.float32))
    assert r.x.shape == (1, F) and r.n_samples == 1
    with pytest.raises(ValueError, match="k>=1"):
        SampleRequest(device=0, x=np.zeros((0, F), np.float32))
    with pytest.raises(ValueError):
        SampleRequest(device=0, x=np.zeros((2, 2, F), np.float32))


def test_request_ids_unique():
    ids = {_req(seed=i).request_id for i in range(32)}
    assert len(ids) == 32


# ------------------------------------------------------------------- batcher


def _builder():
    return WindowBuilder(D, B, np.zeros((D, F), np.float32))


def test_batcher_window_shapes_and_served_mask():
    wb = _builder()
    wb.add(_req(device=2, k=2, seed=1))
    wb.add(_req(device=5, k=1, seed=2))
    w = wb.close(0)
    assert w.batch.shape == (D, B, F)
    assert w.served.tolist() == [d in (2, 5) for d in range(D)]
    assert w.n_requests == 2 and w.n_samples == 3
    assert wb.depth == 0
    # un-served rows padded with the fallback (zeros here)
    np.testing.assert_array_equal(w.batch[0], 0.0)
    # partially-filled served rows pad by cycling their own samples
    np.testing.assert_array_equal(w.batch[2][2], w.batch[2][0])


def test_batcher_takes_whole_requests_only():
    wb = _builder()
    wb.add(_req(device=1, k=2, seed=1))
    wb.add(_req(device=1, k=2, seed=2))  # 2+2 > B=3: must wait a window
    w = wb.close(0)
    assert w.n_requests == 1 and w.n_samples == 2
    assert wb.depth == 1
    w2 = wb.close(1)
    assert w2.n_requests == 1
    assert wb.close(2) is None  # empty: no window


def test_batcher_fallback_tracks_last_served_sample():
    wb = _builder()
    r = _req(device=3, k=2, seed=5)
    wb.add(r)
    wb.close(0)
    np.testing.assert_array_equal(wb.fallback[3], r.x[1])


def test_batcher_rejects_misfits():
    wb = _builder()
    with pytest.raises(ValueError, match="does not fit"):
        wb.add(_req(device=D, k=1))       # device out of range
    with pytest.raises(ValueError, match="does not fit"):
        wb.add(_req(device=0, k=B + 1))   # burst over budget
    assert not wb.can_fit(
        SampleRequest(device=0, x=np.zeros((1, F + 1), np.float32))
    )


# ----------------------------------------------------------------------- wal


def test_wal_roundtrip_and_gc(tmp_path):
    wal = WriteAheadLog(tmp_path)
    wb = _builder()
    for seq in range(3):
        wb.add(_req(device=seq, k=1, seed=seq))
        wal.append(wb.close(seq))
    assert wal.entries() == [0, 1, 2]
    batch, served, allow = wal.load(1)
    assert batch.shape == (D, B, F) and served[1] and allow
    assert wal.gc(before=2) == 2
    assert wal.entries() == [2]


def test_wal_contiguity_gap_raises(tmp_path):
    wal = WriteAheadLog(tmp_path)
    wb = _builder()
    for seq in (4, 5, 7):  # hole at 6
        wb.add(_req(device=0, k=1, seed=seq))
        wal.append(wb.close(seq))
    with pytest.raises(RuntimeError, match="gap"):
        wal.replayable(4)
    # entries below from_seq are covered by the snapshot: not a gap
    assert wal.replayable(7) == [7]


def test_wal_cleans_stale_tmp(tmp_path):
    (tmp_path / "wal_00000009.npz.123.tmp").write_bytes(b"torn")
    wal = WriteAheadLog(tmp_path)
    assert wal.entries() == []
    assert not list(tmp_path.glob("*.tmp"))


# ----------------------------------------------------------------- admission


def test_admission_policy_branches():
    cfg = AdmissionConfig(
        max_queue_per_device=2, client_cap=4, depth_high_frac=0.5,
        slo_p99_s=0.1, slo_min_depth_frac=0.25, budget_defer_frac=0.9,
    )
    ctl = AdmissionController(cfg, capacity=16)
    base = dict(
        mode=Mode.NORMAL, device_depth=0, client_inflight=0,
        total_depth=0, tick_p99_s=None, budget_utilization=0.0,
    )
    req = _req()
    assert ctl.decide(req, **base) == ("admit", "admit")
    assert ctl.decide(req, **{**base, "mode": Mode.SHED}) == ("shed", "degraded")
    assert ctl.decide(req, **{**base, "mode": Mode.STALE_SCORES}) == (
        "stale", "degraded")
    assert ctl.decide(req, **{**base, "device_depth": 2}) == (
        "defer", "queue_full")
    assert ctl.decide(req, **{**base, "client_inflight": 4}) == (
        "defer", "client_cap")
    assert ctl.decide(req, **{**base, "total_depth": 8}) == (
        "defer", "backpressure")
    # p99 breach alone (quiet queue) does NOT defer — no deadlock
    assert ctl.decide(req, **{**base, "tick_p99_s": 0.2}) == ("admit", "admit")
    assert ctl.decide(req, **{**base, "tick_p99_s": 0.2, "total_depth": 4}) == (
        "defer", "slo")
    assert ctl.decide(req, **{**base, "budget_utilization": 0.95}) == (
        "defer", "comm_budget")
    shed_cfg = AdmissionConfig(max_queue_per_device=2, overflow="shed")
    shed_ctl = AdmissionController(shed_cfg, capacity=16)
    assert shed_ctl.decide(req, **{**base, "device_depth": 2}) == (
        "shed", "queue_full")
    with pytest.raises(ValueError, match="defer|shed"):
        AdmissionConfig(overflow="drop")


# -------------------------------------------------------------------- ladder


def test_ladder_hysteresis_one_rung_at_a_time():
    ladder = DegradedLadder(LadderConfig(escalate_after=2, recover_after=3))
    assert ladder.check(True) == Mode.NORMAL      # 1 strike: no move
    assert ladder.check(True) == Mode.SKIP_MERGE  # 2 strikes: one rung
    assert ladder.check(True) == Mode.SKIP_MERGE
    assert ladder.check(True) == Mode.STALE_SCORES
    ladder.check(True), ladder.check(True)
    assert ladder.mode == Mode.SHED
    ladder.check(True)
    assert ladder.mode == Mode.SHED               # ceiling holds
    for _ in range(2):
        ladder.check(False)
    assert ladder.mode == Mode.SHED               # 2 calm < recover_after
    assert ladder.check(False) == Mode.STALE_SCORES
    ladder.check(True)                            # pressure resets calm run
    for _ in range(3):
        ladder.check(False)
    assert ladder.mode == Mode.SKIP_MERGE
    for _ in range(3):
        ladder.check(False)
    assert ladder.mode == Mode.NORMAL
    assert len(ladder.transitions) == 6


# ------------------------------------------------------------------ frontend


def _frontend(rt, **kw):
    kw.setdefault("batch", B)
    kw.setdefault("max_delay_s", 0.003)
    kw.setdefault("close_at_requests", 4)
    kw.setdefault("warmup", False)  # tiny fleets compile in ms
    return ServeFrontend(rt, ServeConfig(**kw))


def test_frontend_serves_and_acks_every_request():
    rt = _runtime()
    fe = _frontend(rt)
    rng = _rng(3)

    async def drive():
        await fe.start()
        acks = await asyncio.gather(*[
            fe.submit_with_retries(SampleRequest(
                device=int(rng.integers(D)),
                x=rng.normal(size=(1, F)).astype(np.float32),
                client=f"c{i % 3}",
            )) for i in range(24)
        ])
        await fe.stop()
        return acks

    acks = asyncio.run(drive())
    assert all(a.ok for a in acks), {a.status for a in acks}
    assert all(a.score is not None and a.latency_s > 0 for a in acks)
    ing = rt.telemetry.summary()["ingress"]
    assert ing["accepted"] == 24
    assert ing["acked"] == 24
    assert rt.tick_no > 0
    rt.assert_compile_once()
    assert not fe._futures and not fe._client_inflight  # nothing leaked


def test_frontend_rejects_malformed_without_crashing():
    rt = _runtime()
    fe = _frontend(rt)

    async def drive():
        await fe.start()
        bad_dev = await fe.submit(_req(device=D + 3))
        bad_burst = await fe.submit(_req(device=0, k=B + 2))
        ok = await fe.submit(_req(device=0, k=1))
        await fe.stop()
        return bad_dev, bad_burst, ok

    bad_dev, bad_burst, ok = asyncio.run(drive())
    assert bad_dev.status == "shed" and "out of range" in bad_dev.reason
    assert bad_burst.status == "shed"
    assert ok.ok


def test_frontend_crash_recovery_restores_exact_state(tmp_path):
    """Snapshot + WAL replay reconstructs the pre-crash fleet exactly:
    a fresh runtime recovered from disk matches the original's model
    and detector state bit-for-bit, with telemetry continuous."""
    rt = _runtime(tmp_path, snapshot_every=4)
    fe = _frontend(rt, wal_dir=str(tmp_path / "wal"))
    rng = _rng(9)

    async def drive():
        await fe.start()
        for _ in range(6):  # several windows: snapshots + WAL-only tail
            await asyncio.gather(*[
                fe.submit_with_retries(SampleRequest(
                    device=int(rng.integers(D)),
                    x=rng.normal(size=(1, F)).astype(np.float32),
                )) for _ in range(6)
            ])
        await fe.stop()

    asyncio.run(drive())
    assert rt.tick_no > 4  # at least one snapshot plus a WAL tail
    beta_ref = np.asarray(rt.states.beta)
    ewma_ref = np.asarray(rt.det.ewma)
    ticks_ref = rt.tick_no

    # "crash": the original objects are simply never consulted again
    rt2 = _runtime(tmp_path, snapshot_every=4)
    fe2 = _frontend(rt2, wal_dir=str(tmp_path / "wal"))
    restored, replayed = fe2.recover()
    assert restored < ticks_ref and replayed == ticks_ref - restored
    assert rt2.tick_no == ticks_ref
    np.testing.assert_array_equal(np.asarray(rt2.states.beta), beta_ref)
    np.testing.assert_array_equal(np.asarray(rt2.det.ewma), ewma_ref)
    # counters rode the snapshot and advanced through the replay
    assert int(rt2.telemetry.ticks.value) == ticks_ref
    assert int(rt2.telemetry.ingress_replayed.value) == replayed


def test_frontend_skip_merge_vetoes_governor():
    rt = _runtime(merge_every=2)
    # recover_after astronomically high: the pinned degraded mode stays
    # pinned no matter how many calm watchdog checks accumulate
    fe = _frontend(rt, ladder=LadderConfig(recover_after=10**9))
    fe.ladder.mode = Mode.SKIP_MERGE  # pin the ladder: windows veto merges

    async def drive():
        await fe.start()
        for _ in range(8):
            await asyncio.gather(*[
                fe.submit(_req(device=d, k=1, seed=d)) for d in range(D)
            ])
        await fe.stop()

    asyncio.run(drive())
    assert rt.governor.state.merges == 0
    assert rt.governor.state.deferred_degraded > 0
    assert rt.tick_no >= 4  # ticks kept flowing while merges were vetoed


def test_frontend_requires_telemetry():
    rng = _rng(0)
    x_init = rng.normal(size=(D, 2 * H, F)).astype(np.float32)
    fleet = init_fleet(
        jax.random.PRNGKey(0), D, F, H, x_init,
        activation="identity", ridge=RIDGE,
    )
    bare = FleetRuntime(fleet, RuntimeConfig(topology=ring(D, hops=1)))
    with pytest.raises(ValueError, match="telemetry"):
        ServeFrontend(bare, ServeConfig(batch=B))


# -------------------------------------------------- batcher edge cases


def test_batcher_head_blocked_close_raises_pre_mutation():
    """A head request larger than the window budget can never ride any
    window; close() must raise BEFORE popping anything so the depth
    invariant (Σ queue lengths == depth) survives the failed close."""
    wb = _builder()
    wb.add(_req(device=1, k=1, seed=1))
    wb.add(_req(device=4, k=2, seed=2))
    oversized = SampleRequest(
        device=4, x=_rng(3).normal(size=(B + 2, F)).astype(np.float32)
    )
    wb.pending[4].appendleft(oversized)  # bypasses add()'s burst cap
    wb.depth += 1
    before = [list(q) for q in wb.pending]
    with pytest.raises(ValueError, match="head-blocked"):
        wb.close(0)
    # nothing was dequeued: queues and depth are exactly pre-close
    assert [list(q) for q in wb.pending] == before
    assert wb.depth == sum(len(q) for q in wb.pending) == 3
    # unblocking the head lets the very next close drain normally
    assert wb.pending[4].popleft() is oversized
    wb.depth -= 1
    w = wb.close(0)
    assert w.n_requests == 2
    assert wb.depth == 0


def _check_window_partition(bursts, closes_between):
    """WindowBuilder invariants under an arbitrary admit/close script:
    depth always equals Σ queue lengths, and every admitted request
    lands in EXACTLY one window (no loss, no double-dispatch)."""
    wb = _builder()
    admitted: list[str] = []
    dispatched: list[str] = []
    seq = 0
    script = list(bursts)
    while script or wb.depth:
        for device, k in script[:closes_between]:
            r = _req(device=device, k=k, seed=len(admitted))
            wb.add(r)
            admitted.append(r.request_id)
            assert wb.depth == sum(len(q) for q in wb.pending)
        script = script[closes_between:]
        w = wb.close(seq, allow_merge=bool(seq % 2))
        seq += 1
        if w is not None:
            dispatched.extend(r.request_id for r in w.requests)
            assert w.served.sum() > 0
            assert w.n_samples <= D * B
        assert wb.depth == sum(len(q) for q in wb.pending)
        assert len(set(dispatched)) == len(dispatched), "double-dispatch"
    assert wb.close(seq) is None  # drained: empty tick, no window
    assert sorted(dispatched) == sorted(admitted), "lost or dropped request"


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=50)
    @given(
        bursts=st.lists(
            st.tuples(st.integers(0, D - 1), st.integers(1, B)),
            max_size=24,
        ),
        closes_between=st.integers(1, 6),
    )
    def test_batcher_partition_property(bursts, closes_between):
        _check_window_partition(bursts, closes_between)
else:
    @pytest.mark.parametrize("seed,n,closes_between", [
        (0, 0, 1), (1, 7, 1), (2, 24, 2), (3, 24, 5), (4, 13, 3), (5, 24, 6),
    ])
    def test_batcher_partition_property(seed, n, closes_between):
        rng = _rng(seed)
        bursts = [
            (int(rng.integers(0, D)), int(rng.integers(1, B + 1)))
            for _ in range(n)
        ]
        _check_window_partition(bursts, closes_between)


def test_wal_warns_on_malformed_filename(tmp_path, caplog):
    wal = WriteAheadLog(tmp_path)
    wb = _builder()
    wb.add(_req(device=0, k=1, seed=0))
    wal.append(wb.close(3))
    (tmp_path / "wal_corrupted.npz").write_bytes(b"junk")
    import logging

    with caplog.at_level(logging.WARNING, logger="repro.serve.wal"):
        assert wal.entries() == [3]  # junk skipped, real entry kept
    assert any("wal_corrupted.npz" in rec.message for rec in caplog.records)
    # replay over the surviving entries still works end to end
    assert wal.replayable(3) == [3]
