"""Coverage for federated/selection.py strategies and the
federated/protocol.py Payload serialization + CommLog accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import UV, init_autoencoder, to_uv
from repro.federated import (
    EdgeDevice,
    FederationServer,
    Payload,
    all_clients,
    loss_threshold_selection,
    resource_constrained_selection,
)
from repro.federated.protocol import cooperative_round

IDS = ["a", "b", "c", "d"]


# --------------------------------------------------- selection strategies

def test_all_clients_is_identity():
    assert list(all_clients(IDS)) == IDS


def test_resource_constrained_selection_filters_by_budget():
    budgets = {"a": 1.0, "b": 5.0, "c": 2.5}  # "d" unknown → inf → excluded
    select = resource_constrained_selection(budgets, threshold=2.5)
    assert list(select(IDS)) == ["a", "c"]
    # tight deadline excludes everyone
    assert list(resource_constrained_selection(budgets, threshold=0.5)(IDS)) == []


def test_loss_threshold_selection_excludes_unsatisfying_models():
    losses = {"a": 0.01, "b": 9.0, "c": 0.2, "d": 0.19}
    select = loss_threshold_selection(losses, max_loss=0.2)
    assert list(select(IDS)) == ["a", "c", "d"]
    # missing id → inf loss → excluded
    assert list(loss_threshold_selection({}, max_loss=1e9)(IDS)) == []


# ------------------------------------------------- Payload serialization

@pytest.fixture(scope="module")
def uv():
    x = np.random.default_rng(0).normal(size=(64, 24)).astype(np.float32)
    st = init_autoencoder(
        jax.random.PRNGKey(0), 24, 8, jnp.asarray(x), ridge=1e-3
    )
    return to_uv(st)


def test_payload_round_trip(uv):
    p = Payload.from_uv("dev-0", uv, version=3)
    assert p.device_id == "dev-0" and p.version == 3
    back = p.to_uv()
    assert isinstance(back, UV)
    np.testing.assert_array_equal(np.asarray(back.u), np.asarray(uv.u))
    np.testing.assert_array_equal(np.asarray(back.v), np.asarray(uv.v))


def test_payload_nbytes_is_the_papers_claim(uv):
    p = Payload.from_uv("dev-0", uv)
    n_hidden, m = uv.u.shape[0], uv.v.shape[1]
    # Ñ(Ñ+m) floats — independent of how much data was trained
    assert p.nbytes == n_hidden * (n_hidden + m) * 4
    assert p.nbytes == uv.nbytes


def test_server_commlog_accounting(uv):
    server = FederationServer()
    for i in range(3):
        server.upload(Payload.from_uv(f"dev-{i}", uv, version=1))
    assert server.log.uploads == 3
    assert server.log.bytes_up == 3 * uv.nbytes
    assert sorted(server.peers_of("dev-0")) == ["dev-1", "dev-2"]
    got = server.download("dev-1")
    assert got.device_id == "dev-1"
    assert server.log.downloads == 1
    assert server.log.bytes_down == uv.nbytes
    # re-upload overwrites the stored version, not a new slot
    server.upload(Payload.from_uv("dev-1", uv, version=2))
    assert server.store["dev-1"].version == 2
    assert len(server.store) == 3


# ------------------------------------------- cooperative_round + select

def _make_devices(n: int, n_features: int = 24, n_hidden: int = 8):
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)  # shared basis, as the paper requires
    devs = []
    for i in range(n):
        x = rng.normal(size=(64, n_features)).astype(np.float32) * 0.1 + i
        d = EdgeDevice(f"dev-{i}", key, n_features, n_hidden, x[:32], ridge=1e-3)
        d.train(x[32:])
        devs.append(d)
    return devs


def test_cooperative_round_respects_selection():
    devs = _make_devices(3)
    before = [np.asarray(d.state.beta).copy() for d in devs]
    server = FederationServer()

    def select(ids):
        return [i for i in ids if i != "dev-2"]

    cooperative_round(devs, server, select=select)
    # selected devices merged (beta moved), excluded one untouched
    assert np.max(np.abs(np.asarray(devs[0].state.beta) - before[0])) > 1e-6
    assert np.max(np.abs(np.asarray(devs[1].state.beta) - before[1])) > 1e-6
    np.testing.assert_array_equal(np.asarray(devs[2].state.beta), before[2])
    # everyone uploads; only the 2 chosen download their 1 peer each
    assert server.log.uploads == 3
    assert server.log.downloads == 2


def test_cooperative_round_default_merges_everyone():
    devs = _make_devices(3)
    server = FederationServer()
    cooperative_round(devs, server)
    assert server.log.uploads == 3
    assert server.log.downloads == 3 * 2
    # all devices converge to the identical merged model
    b0 = np.asarray(devs[0].state.beta)
    for d in devs[1:]:
        np.testing.assert_allclose(np.asarray(d.state.beta), b0, rtol=1e-3, atol=1e-4)
