"""Property-based tests (hypothesis) for the system's invariants.

The merge algebra (Eq. 8) is the load-bearing invariant of the whole
framework — associativity/commutativity is what legalizes running the
paper's cooperative update as a psum all-reduce on a TPU mesh.
"""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    cooperative_update,
    init_oselm,
    init_slfn,
    oselm_step,
    oselm_train_sequential,
    to_uv,
    train_elm,
    uv_add,
    uv_sub,
)

jax.config.update("jax_platform_name", "cpu")

dims = st.tuples(
    st.integers(min_value=4, max_value=24),   # n features
    st.integers(min_value=2, max_value=12),   # hidden
    st.integers(min_value=40, max_value=96),  # rows
    st.integers(min_value=0, max_value=2**31 - 1),
)


def _mk(n, nh, rows, seed):
    params = init_slfn(jax.random.PRNGKey(seed), n, max(2, min(nh, n - 1)))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (rows, n))
    return params, x


@settings(max_examples=15, deadline=None)
@given(dims)
def test_sequential_equals_batch(d):
    """∀ shapes/seeds: OS-ELM streaming == batch ELM (Eq. 12 ≡ Eq. 5)."""
    n, nh, rows, seed = d
    params, x = _mk(n, nh, rows, seed)
    init_rows = max(2 * params.n_hidden, 8)
    st_ = init_oselm(params, x[:init_rows], x[:init_rows], activation="sigmoid", ridge=1e-4)
    st_ = oselm_train_sequential(st_, x[init_rows:], x[init_rows:])
    elm = train_elm(params, x, x, activation="sigmoid", ridge=1e-4)
    np.testing.assert_allclose(st_.beta, elm.beta, rtol=5e-2, atol=5e-3)


@settings(max_examples=15, deadline=None)
@given(dims)
def test_merge_commutative_associative(d):
    """UV algebra is commutative/associative (exactly, up to f32 add)."""
    n, nh, rows, seed = d
    params, x = _mk(n, nh, rows, seed)
    third = rows // 3
    init_rows = max(2 * params.n_hidden, 4)
    parts = []
    for i in range(3):
        seg = x[i * third:(i + 1) * third]
        if seg.shape[0] < init_rows:
            return
        stt = init_oselm(params, seg, seg, activation="sigmoid", ridge=1e-4)
        parts.append(to_uv(stt))
    ab_c = uv_add(uv_add(parts[0], parts[1]), parts[2])
    a_bc = uv_add(parts[0], uv_add(parts[1], parts[2]))
    ba_c = uv_add(uv_add(parts[1], parts[0]), parts[2])
    np.testing.assert_allclose(ab_c.u, a_bc.u, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ab_c.v, ba_c.v, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(dims)
def test_add_sub_roundtrip(d):
    n, nh, rows, seed = d
    params, x = _mk(n, nh, rows, seed)
    half = rows // 2
    init_rows = max(2 * params.n_hidden, 4)
    if half < init_rows:
        return
    st_a = init_oselm(params, x[:half], x[:half], activation="identity", ridge=1e-4)
    st_b = init_oselm(params, x[half:], x[half:], activation="identity", ridge=1e-4)
    uva, uvb = to_uv(st_a), to_uv(st_b)
    rt = uv_sub(uv_add(uva, uvb), uvb)
    np.testing.assert_allclose(rt.u, uva.u, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(rt.v, uva.v, rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(dims, st.integers(min_value=2, max_value=6))
def test_nway_merge_equals_batch(d, nparts):
    """N-device one-shot merge == batch over the union, ∀ N — the psum
    legalization property."""
    n, nh, rows, seed = d
    params, x = _mk(n, nh, rows * nparts, seed)
    init_rows = max(2 * params.n_hidden, 4)
    if rows < init_rows:
        return
    states = []
    for i in range(nparts):
        seg = x[i * rows:(i + 1) * rows]
        stt = init_oselm(params, seg[:init_rows], seg[:init_rows], activation="sigmoid", ridge=1e-4)
        stt = oselm_train_sequential(stt, seg[init_rows:], seg[init_rows:])
        states.append(stt)
    merged = cooperative_update(states[0], *[to_uv(s) for s in states[1:]])
    elm = train_elm(params, x, x, activation="sigmoid", ridge=nparts * 1e-4)
    np.testing.assert_allclose(merged.beta, elm.beta, rtol=5e-2, atol=1e-2)


# ------------------------------------------------- scenario-spec properties

from repro.scenarios import ScenarioSpec  # noqa: E402


@settings(max_examples=10, deadline=None)
@given(
    n_devices=st.integers(min_value=1, max_value=9),
    ticks=st.integers(min_value=2, max_value=12),
    batch=st.integers(min_value=1, max_value=4),
    assignment=st.sampled_from(["round_robin", "dirichlet"]),
    drift_frac=st.floats(min_value=0.0, max_value=1.0),
    normal=st.sampled_from([(0, 1), (3, 4), (0, 3, 4), (1,)]),
    anomaly=st.sampled_from([(5,), (2, 5)]),
    seed=st.integers(min_value=0, max_value=1),
)
def test_scenario_specs_always_yield_valid_feeds(
    n_devices, ticks, batch, assignment, drift_frac, normal, anomaly, seed
):
    """∀ generated specs: the built feed is valid — phase boundaries
    ordered and in-range, the held-out anomaly pool disjoint from every
    pre-drift training stream, and the per-device pattern assignment
    covers the whole fleet."""
    spec = ScenarioSpec(
        name="prop", dataset="har",
        n_devices=n_devices, ticks=ticks, batch=batch,
        normal_classes=normal, anomaly_classes=anomaly,
        assignment=assignment, drift_frac=drift_frac,
        samples_per_class=40, seed=seed,
    )
    sc = spec.build()
    steps = spec.steps
    homes = set(range(spec.n_normal))
    anoms = set(spec.remapped_anomaly_classes())
    assert not homes & anoms

    # the drift schedule itself is well-formed: in-range steps/devices,
    # targets drawn from the held-out pool only
    events = sc.streams.drift
    for ev in events:
        assert 0 <= ev.device < n_devices
        assert 0 <= ev.step < steps
        assert ev.new_pattern in anoms
    first_drift = {d: steps for d in range(n_devices)}
    for ev in events:
        first_drift[ev.device] = min(first_drift[ev.device], ev.step)

    assert sc.streams.xs.shape == (n_devices, steps, sc.n_features)
    assert sc.streams.x_init.shape[0] == n_devices
    assert np.isfinite(sc.streams.xs).all()

    for d in range(n_devices):
        # phase boundaries strictly increasing, starting at 0, in-range
        bounds = sc.streams.phase_boundaries(d)
        assert bounds[0] == 0
        assert all(b2 > b1 for b1, b2 in zip(bounds, bounds[1:]))
        assert all(0 <= b < steps for b in bounds)
        pats = set(sc.streams.pattern_of_device[d].tolist())
        pre = set(sc.streams.pattern_of_device[d, : first_drift[d]].tolist())
        # anomaly pool held out of every pre-drift training stream
        assert not pre & anoms
        # assignment covers the fleet: every device draws from its homes
        assert pre <= homes or first_drift[d] == 0
        if d not in sc.streams.drifted_devices():
            assert pats <= homes
            if assignment == "round_robin":
                assert pats == {d % spec.n_normal}

    # eval arrays: both classes present, positives subsampled
    assert set(np.unique(sc.y_eval).tolist()) == {0, 1}
    assert (sc.y_eval == 1).sum() >= 1


@settings(max_examples=10, deadline=None)
@given(dims, st.integers(min_value=1, max_value=8))
def test_batchk_equals_k1(d, k):
    """Eq. 12 with batch k == k applications of the k=1 fast path."""
    n, nh, rows, seed = d
    params, x = _mk(n, nh, rows, seed)
    init_rows = max(2 * params.n_hidden, 8)
    if rows < init_rows + k:
        return
    st0 = init_oselm(params, x[:init_rows], x[:init_rows], activation="tanh", ridge=1e-4)
    chunk = x[init_rows:init_rows + k]
    st_k = oselm_step(st0, chunk, chunk)
    st_1 = st0
    for i in range(k):
        from repro.core import oselm_step_k1
        st_1 = oselm_step_k1(st_1, chunk[i], chunk[i])
    np.testing.assert_allclose(st_k.beta, st_1.beta, rtol=5e-2, atol=5e-3)
