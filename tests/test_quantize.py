"""Quantized (U, V) merge payloads: tile-codec error bounds, error
feedback (telescoping/unbiasedness), Pallas pack-kernel parity against
the XLA reference, mixed-precision byte accounting, and the quantized
merge through the fleet simulator and the resident runtime."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property-based in CI; deterministic sweep where hypothesis is absent
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.data.metrics import roc_auc
from repro.data.pipeline import anomaly_eval_arrays, class_subset, normalize_minmax
from repro.data.synthetic import make_har_dataset
from repro.fleet import (
    fleet_merge,
    fleet_merge_masked,
    fleet_merge_quantized,
    fleet_score,
    fleet_train,
    init_fleet,
    init_residual,
    make_fleet_streams,
    payload_nbytes,
    ring,
    topology_round_cost,
)
from repro.fleet.quantize import (
    TILE_COLS,
    apply_codec,
    dequantize_tiles,
    n_col_tiles,
    payload_precision_nbytes,
    quantize_roundtrip,
    quantize_tiles,
    validate_precision,
)
from repro.fleet.staleness import StalenessSchedule
from repro.kernels import quantize_pack, quantize_pack_xla
from repro.runtime import (
    FleetRuntime,
    GovernorConfig,
    RuntimeConfig,
    TickFeed,
)

jax.config.update("jax_platform_name", "cpu")

D, H, STEPS, RIDGE = 6, 8, 48, 1e-3


# ------------------------------------------------------------- tile codec


def _varied_payload(d=3, r=16, c=300, seed=0, spread=True):
    """Payload whose column tiles live at very different magnitudes —
    the U-vs-V condition the per-tile scales exist for."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(d, r, c)).astype(np.float32)
    if spread:
        nt = n_col_tiles(c)
        for t in range(nt):
            x[:, :, t * TILE_COLS:(t + 1) * TILE_COLS] *= 10.0 ** (t - 1)
    return jnp.asarray(x)


def test_validate_precision_rejects_unknown():
    for p in ("f32", "f16", "int8"):
        validate_precision(p)
    with pytest.raises(ValueError, match="unknown payload precision"):
        validate_precision("int4")


def test_int8_roundtrip_error_bounded_per_tile():
    """|x − dq(q(x))| ≤ scale/2 elementwise, with each tile's OWN scale
    — the per-tile guarantee a single global scale cannot give."""
    x = _varied_payload()
    codes, scales = quantize_tiles(x)
    assert codes.dtype == jnp.int8
    assert scales.shape == (3, n_col_tiles(300))
    err = np.abs(np.asarray(x - dequantize_tiles(codes, scales)))
    s = np.asarray(scales)
    for t in range(s.shape[1]):
        tile_err = err[:, :, t * TILE_COLS:(t + 1) * TILE_COLS]
        bound = s[:, t][:, None, None] * 0.5 + 1e-7
        assert (tile_err <= bound).all(), (t, tile_err.max(), s[:, t])


def test_int8_all_zero_tile_is_exact():
    x = jnp.zeros((2, 4, 2 * TILE_COLS))
    codes, scales = quantize_tiles(x)
    np.testing.assert_array_equal(np.asarray(scales), 1.0)  # no 0-divide
    np.testing.assert_array_equal(np.asarray(dequantize_tiles(codes, scales)), 0.0)


def test_f16_roundtrip_error_bounded():
    x = _varied_payload(seed=1)
    rt = quantize_roundtrip(x, "f16")
    # half precision: ≤ 2^-11 relative error per element
    err = np.abs(np.asarray(rt - x))
    assert (err <= np.abs(np.asarray(x)) * 2.0 ** -10 + 1e-7).all()


def test_ragged_tail_tile_columns_roundtrip():
    # C not a multiple of TILE_COLS: the pad columns must not leak into
    # the tail tile's amax (they are zeros) or the output shape
    x = _varied_payload(c=TILE_COLS + 7, seed=2)
    codes, scales = quantize_tiles(x)
    assert codes.shape == x.shape and scales.shape == (3, 2)
    err = np.abs(np.asarray(x - dequantize_tiles(codes, scales)))
    assert err.max() <= np.asarray(scales).max() * 0.5 + 1e-7


# --------------------------------------------------------- error feedback


def _check_error_feedback_telescopes(seed, rounds, magnitude):
    """Unbiasedness of the EF stream: published_t = (w_t + r_{t−1}) − r_t,
    so Σ published = Σ w − r_final, and r stays bounded by half a tile
    quantum — repeated lossy merges never accumulate quantization bias."""
    rng = np.random.default_rng(seed)
    ws = [
        jnp.asarray(rng.normal(size=(2, 4, 37)).astype(np.float32)) * magnitude
        for _ in range(rounds)
    ]
    r = jnp.zeros_like(ws[0])
    published = []
    for w in ws:
        p, r = apply_codec(w, "int8", residual=r)
        published.append(np.asarray(p, np.float64))
    total_pub = sum(published)
    total_w = sum(np.asarray(w, np.float64) for w in ws)
    np.testing.assert_allclose(
        total_pub + np.asarray(r, np.float64), total_w,
        rtol=0, atol=magnitude * 1e-3,
    )
    # the backlog is one round's quantization error, not an accumulation
    assert np.abs(np.asarray(r)).max() <= magnitude * 0.5 + 1e-6


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=20)
    @given(
        seed=st.integers(0, 2 ** 16),
        rounds=st.integers(1, 5),
        magnitude=st.sampled_from([1e-3, 1.0, 64.0]),
    )
    def test_error_feedback_telescopes_to_true_sum(seed, rounds, magnitude):
        _check_error_feedback_telescopes(seed, rounds, magnitude)
else:
    @pytest.mark.parametrize("seed,rounds,magnitude", [
        (0, 1, 1.0), (1, 3, 1e-3), (2, 5, 64.0), (3, 4, 1.0), (4, 2, 1e-3),
    ])
    def test_error_feedback_telescopes_to_true_sum(seed, rounds, magnitude):
        _check_error_feedback_telescopes(seed, rounds, magnitude)


def test_apply_codec_fp_and_participation_masks():
    w = _varied_payload(d=4, c=64, seed=3, spread=False)
    r0 = jnp.asarray(
        np.random.default_rng(4).normal(size=w.shape).astype(np.float32) * 0.01
    )
    fp = jnp.asarray([True, False, False, False])
    live = jnp.asarray([True, True, False, True])
    pub, r1 = apply_codec(w, "int8", residual=r0, fp_mask=fp, participate=live)
    pub, r1 = np.asarray(pub), np.asarray(r1)
    # fp device: exact payload on the wire, backlog superseded (cleared)
    np.testing.assert_array_equal(pub[0], np.asarray(w)[0])
    np.testing.assert_array_equal(r1[0], 0.0)
    # quantized participant: EF round-trip, residual = input − published
    np.testing.assert_allclose(
        pub[1] + r1[1], np.asarray(w + r0)[1], rtol=0, atol=1e-5
    )
    assert np.abs(pub[1] - np.asarray(w)[1]).max() > 0  # actually lossy
    # masked-out device: publishes nothing (exact row the merge mask
    # zeroes), residual untouched
    np.testing.assert_array_equal(pub[2], np.asarray(w)[2])
    np.testing.assert_array_equal(r1[2], np.asarray(r0)[2])
    # f32 is a pure passthrough
    pub32, r32 = apply_codec(w, "f32", residual=r0)
    assert pub32 is w and r32 is r0


# ------------------------------------------------------- byte accounting


def test_payload_precision_nbytes_accounting():
    n, m = 16, 561
    numel = n * (n + m)
    assert payload_precision_nbytes(n, m, "f32") == numel * 4
    assert payload_precision_nbytes(n, m, "f16") == numel * 2
    nt = n_col_tiles(n + m)
    assert payload_precision_nbytes(n, m, "int8") == numel + nt * 4
    # the scales overhead is tiny: int8 stays within 2% of a flat 4x
    assert payload_precision_nbytes(n, m, "f32") / payload_precision_nbytes(
        n, m, "int8"
    ) > 3.9
    # payload_nbytes routes precision-aware accounting
    assert payload_nbytes(n, m, precision="int8") == numel + nt * 4
    assert payload_nbytes(n, m) == numel * 4


def test_topology_round_cost_precision():
    topo = ring(8, hops=1)
    full = topology_round_cost(topo, H, 48)
    q = topology_round_cost(topo, H, 48, precision="int8")
    assert q.precision == "int8" and full.precision == "f32"
    assert q.payloads == full.payloads  # codec changes bytes, not edges
    assert full.bytes_total / q.bytes_total > 3.5


# ------------------------------------------------- Pallas pack-kernel parity


@pytest.mark.parametrize("shape", [
    (5, 16, 209),   # multi-tile ragged tail
    (3, 32, 752),   # row dim at the int8 sublane size
    (4, 8, 29),     # single partial tile, tiny rows
    (2, 12, 116),   # D=2, unaligned rows AND columns
    (1, 7, 300),    # single device, odd rows
])
@pytest.mark.parametrize("with_residual", [False, True])
def test_quantize_pack_kernel_matches_xla(shape, with_residual):
    """The fused Pallas pack (concat + EF add + per-tile quantize) is
    bit-identical to the jnp reference on codes, scales AND residuals —
    including row/column padding remainders."""
    d, n, m = shape
    rng = np.random.default_rng(7)
    u = jnp.asarray(rng.normal(size=(d, n, n)).astype(np.float32) * 10)
    v = jnp.asarray(rng.normal(size=(d, n, m)).astype(np.float32) * 0.1)
    res = (
        jnp.asarray(rng.normal(size=(d, n, n + m)).astype(np.float32) * 0.01)
        if with_residual else None
    )
    codes, scales, r = quantize_pack(u, v, res, interpret=True)
    codes_x, scales_x, r_x = quantize_pack_xla(u, v, res)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(codes_x))
    np.testing.assert_array_equal(np.asarray(scales), np.asarray(scales_x))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(r_x))


# ---------------------------------------------------- fleet merge parity


@pytest.fixture(scope="module")
def trained_fleet():
    """Fleet trained on patterns {0, 1} plus the §5.3.1 eval protocol
    (those patterns normal, the rest anomalous)."""
    ds = normalize_minmax(make_har_dataset(seed=0, samples_per_class=60, n_features=48))
    train = class_subset(ds, range(2))
    fs = make_fleet_streams(train, D, STEPS, n_init=2 * H, seed=0)
    fleet = init_fleet(
        jax.random.PRNGKey(0), D, ds.n_features, H, fs.x_init,
        activation="identity", ridge=RIDGE,
    )
    x_eval, y_eval = anomaly_eval_arrays(ds, [0, 1], anomaly_ratio=0.3, seed=0)
    return fleet_train(fleet, jnp.asarray(fs.xs)), jnp.asarray(x_eval), y_eval


def test_fleet_merge_f32_codec_is_identity(trained_fleet):
    fleet, _, _ = trained_fleet
    exact = fleet_merge(fleet, ring(D, hops=1), ridge=RIDGE)
    via_codec = fleet_merge(
        fleet, ring(D, hops=1), ridge=RIDGE, payload_precision="f32"
    )
    np.testing.assert_array_equal(
        np.asarray(exact.beta), np.asarray(via_codec.beta)
    )


@pytest.mark.parametrize("precision", ["f16", "int8"])
def test_fleet_merge_quantized_preserves_auc(trained_fleet, precision):
    """The paper-facing invariant: a one-shot lossy merge keeps every
    device's anomaly AUC close to the exact merge. (Raw betas are NOT
    elementwise-close — the (U+εI)⁻¹V solve amplifies the ~0.4% tile
    error along ill-conditioned directions — but the scores the
    detection protocol consumes are stable.) This H=8 micro-fixture is
    harsher than any paper configuration, so the band here is 0.05; the
    ±0.02 paper band is locked at scenario scale by
    test_golden_quantized_comm_ratio and benchmarks/paper_eval.py."""
    fleet, x_eval, y_eval = trained_fleet
    topo = ring(D, hops=1)
    exact = fleet_merge(fleet, topo, ridge=RIDGE)
    lossy = fleet_merge(fleet, topo, ridge=RIDGE, payload_precision=precision)
    assert bool(jnp.isfinite(lossy.beta).all())
    se = np.asarray(fleet_score(exact, x_eval))
    sl = np.asarray(fleet_score(lossy, x_eval))
    for dev in range(D):
        auc_e, auc_l = roc_auc(se[dev], y_eval), roc_auc(sl[dev], y_eval)
        assert abs(auc_l - auc_e) <= 0.05, (precision, dev, auc_e, auc_l)


def test_fleet_merge_quantized_fp_everywhere_is_exact(trained_fleet):
    """An all-risk round degrades to the exact masked merge: every
    device ships f32, so the stateful path must reproduce
    fleet_merge_masked bit-for-bit and keep a zero residual."""
    fleet, _, _ = trained_fleet
    topo = ring(D, hops=1)
    mask = jnp.ones(D, bool)
    exact = fleet_merge_masked(fleet, topo, mask, ridge=RIDGE)
    merged, r = fleet_merge_quantized(
        fleet, topo, residual=init_residual(fleet),
        payload_precision="int8", ridge=RIDGE, mask=mask,
        fp_mask=jnp.ones(D, bool),
    )
    np.testing.assert_array_equal(np.asarray(merged.beta), np.asarray(exact.beta))
    np.testing.assert_array_equal(np.asarray(r), 0.0)


def test_fleet_merge_quantized_kernel_matches_xla_path(trained_fleet):
    fleet, _, _ = trained_fleet
    topo = ring(D, hops=1)
    resid = init_residual(fleet)
    mask = jnp.ones(D, bool)
    ref, r_ref = fleet_merge_quantized(
        fleet, topo, residual=resid, payload_precision="int8",
        ridge=RIDGE, mask=mask, kernel=False,
    )
    ker, r_ker = fleet_merge_quantized(
        fleet, topo, residual=resid, payload_precision="int8",
        ridge=RIDGE, mask=mask, kernel=True, interpret=True,
    )
    # the pack kernel is bit-exact, so residuals agree exactly; the
    # merged states go through the banded solve (documented ~1e-4 tol)
    np.testing.assert_array_equal(np.asarray(r_ker), np.asarray(r_ref))
    np.testing.assert_allclose(
        np.asarray(ker.beta), np.asarray(ref.beta), rtol=1e-4, atol=1e-4
    )


# ------------------------------------------------- runtime end-to-end


def _runtime_fixture(tmpdir=None, precision="int8"):
    ds = normalize_minmax(make_har_dataset(seed=0, samples_per_class=60, n_features=48))
    fs = make_fleet_streams(ds, D, 96, n_init=2 * H, seed=0)
    fleet = init_fleet(
        jax.random.PRNGKey(0), D, ds.n_features, H, fs.x_init,
        activation="identity", ridge=RIDGE,
    )
    cfg = RuntimeConfig(
        topology=ring(D, hops=1), ridge=RIDGE,
        governor=GovernorConfig(merge_every=16),
        payload_precision=precision,
        **(dict(snapshot_every=100, snapshot_dir=tmpdir) if tmpdir else {}),
    )
    return FleetRuntime(fleet, cfg), TickFeed(fs, 2)


def test_runtime_int8_compile_once_and_cheaper_than_f32():
    rt_q, feed = _runtime_fixture(precision="int8")
    rt_f, _ = _runtime_fixture(precision="f32")
    rt_q.run(feed)
    rt_f.run(feed)
    assert all(v == 1 for v in rt_q.assert_compile_once().values())
    assert bool(jnp.isfinite(rt_q.states.beta).all())
    assert rt_q.governor.state.merges == rt_f.governor.state.merges > 0
    # same admitted rounds, ~4x fewer bytes on the governor's ledger
    ratio = rt_f.governor.state.bytes_spent / rt_q.governor.state.bytes_spent
    assert ratio > 3.5, ratio
    # the EF accumulator is live (some device carries quantization error)
    assert np.abs(np.asarray(rt_q._residual)).max() > 0


def test_runtime_int8_snapshot_restores_residual(tmp_path):
    rt, feed = _runtime_fixture(tmpdir=tmp_path)
    rt.run(feed, ticks=40)
    rt.snapshot()
    rt2, _ = _runtime_fixture(tmpdir=tmp_path)
    assert rt2.restore() == 40
    np.testing.assert_array_equal(
        np.asarray(rt2.states.beta), np.asarray(rt.states.beta)
    )
    np.testing.assert_array_equal(
        np.asarray(rt2._residual), np.asarray(rt._residual)
    )
    rep = rt2.tick(feed.tick_batch(40))
    assert rep.tick == 40


def test_runtime_rejects_quantized_staleness():
    ds = normalize_minmax(make_har_dataset(seed=0, samples_per_class=40, n_features=48))
    fs = make_fleet_streams(ds, D, 16, n_init=2 * H, seed=0)
    fleet = init_fleet(
        jax.random.PRNGKey(0), D, ds.n_features, H, fs.x_init,
        activation="identity", ridge=RIDGE,
    )
    with pytest.raises(ValueError, match="stale"):
        FleetRuntime(fleet, RuntimeConfig(
            topology=ring(D, hops=1), ridge=RIDGE,
            payload_precision="int8",
            staleness=StalenessSchedule.uniform(D, 1),
        ))
