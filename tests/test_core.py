"""Unit tests for repro.core — ELM / OS-ELM / E²LM algebra (paper §3–§4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ae_score,
    ae_train_step_guarded,
    ae_train_stream,
    bank_score,
    bank_train_instance,
    cooperative_update,
    from_uv,
    hidden,
    init_autoencoder,
    init_oselm,
    init_slfn,
    make_bank,
    oselm_loss,
    oselm_predict,
    oselm_step,
    oselm_step_k1,
    oselm_train_sequential,
    predict_elm,
    to_uv,
    train_elm,
    uv_add,
    uv_replace,
    uv_sub,
)

KEY = jax.random.PRNGKey(0)


def make_data(k, rows=256, n=24):
    return jax.random.normal(jax.random.PRNGKey(k), (rows, n))


@pytest.fixture(scope="module")
def slfn():
    return init_slfn(KEY, 24, 12)


# ---------------------------------------------------------------- ELM


def test_elm_fits_linear_map(slfn):
    """ELM with enough hidden units fits its own hidden-space projection
    exactly: train on t = H·β* and recover β*."""
    x = make_data(1)
    h = hidden(slfn, x, "sigmoid")
    beta_star = jax.random.normal(jax.random.PRNGKey(9), (12, 4))
    t = h @ beta_star
    model = train_elm(slfn, x, t, activation="sigmoid")
    np.testing.assert_allclose(model.beta, beta_star, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(predict_elm(model, x), t, rtol=1e-3, atol=1e-3)


def test_elm_activation_variants(slfn):
    x = make_data(2)
    for act in ("sigmoid", "identity", "tanh", "relu"):
        m = train_elm(slfn, x, x, activation=act)
        assert m.beta.shape == (12, 24)
        assert np.isfinite(np.asarray(m.beta)).all()


# ------------------------------------------------------------- OS-ELM


def test_oselm_init_matches_elm(slfn):
    """β₀ from Eq. 13 equals the batch ELM solution on the init chunk."""
    x = make_data(3, rows=64)
    st = init_oselm(slfn, x, x, activation="sigmoid")
    elm = train_elm(slfn, x, x, activation="sigmoid")
    np.testing.assert_allclose(st.beta, elm.beta, rtol=1e-4, atol=1e-4)


def test_oselm_sequential_equals_batch(slfn):
    """The paper's foundation: OS-ELM trained sample-by-sample equals the
    one-shot batch ELM solution (global optimum, no local minima)."""
    x = make_data(4)
    st = init_oselm(slfn, x[:32], x[:32], activation="sigmoid")
    st = oselm_train_sequential(st, x[32:], x[32:])
    elm = train_elm(slfn, x, x, activation="sigmoid")
    np.testing.assert_allclose(st.beta, elm.beta, rtol=1e-3, atol=1e-4)


def test_oselm_batchk_equals_k1(slfn):
    x = make_data(5, rows=48)
    st = init_oselm(slfn, x[:32], x[:32], activation="sigmoid")
    st_k = oselm_step(st, x[32:], x[32:])
    st_1 = st
    for i in range(32, 48):
        st_1 = oselm_step_k1(st_1, x[i], x[i])
    np.testing.assert_allclose(st_k.beta, st_1.beta, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(st_k.p, st_1.p, rtol=1e-3, atol=1e-4)


def test_oselm_p_symmetric_positive(slfn):
    x = make_data(6)
    st = init_oselm(slfn, x[:32], x[:32], activation="sigmoid")
    st = oselm_train_sequential(st, x[32:], x[32:])
    p = np.asarray(st.p)
    np.testing.assert_allclose(p, p.T, atol=1e-4)
    assert np.linalg.eigvalsh(p).min() > 0


def test_forgetting_discounts_old_data(slfn):
    """With λ<1 old data is down-weighted: after a long stream of pattern
    B, a forgetting model reconstructs old pattern A worse than λ=1."""
    a = make_data(7, rows=200)
    b = make_data(8, rows=300) + 4.0
    st_f = init_oselm(slfn, a[:32], a[:32], activation="sigmoid", forget=0.99)
    st_n = init_oselm(slfn, a[:32], a[:32], activation="sigmoid", forget=1.0)
    st_f = oselm_train_sequential(st_f, jnp.concatenate([a[32:], b]), jnp.concatenate([a[32:], b]))
    st_n = oselm_train_sequential(st_n, jnp.concatenate([a[32:], b]), jnp.concatenate([a[32:], b]))
    loss_f = float(oselm_loss(st_f, a[:64], a[:64]).mean())
    loss_n = float(oselm_loss(st_n, a[:64], a[:64]).mean())
    assert loss_f > loss_n  # forgot more of A


# ---------------------------------------------------------- E²LM merge


def test_merge_two_devices_equals_batch(slfn):
    """§4.2: device-A merging device-B's (U,V) equals batch training on
    the union of both datasets — the merged-model accuracy claim."""
    x = make_data(10)
    a, b = x[:128], x[128:]
    st_a = init_oselm(slfn, a[:32], a[:32], activation="sigmoid")
    st_a = oselm_train_sequential(st_a, a[32:], a[32:])
    st_b = init_oselm(slfn, b[:32], b[:32], activation="sigmoid")
    st_b = oselm_train_sequential(st_b, b[32:], b[32:])
    merged = cooperative_update(st_a, to_uv(st_b))
    elm = train_elm(slfn, x, x, activation="sigmoid")
    np.testing.assert_allclose(merged.beta, elm.beta, rtol=1e-3, atol=1e-4)


def test_merge_symmetry(slfn):
    """A-merges-B and B-merges-A are identical (paper §5.2.1 note)."""
    x = make_data(11)
    a, b = x[:128], x[128:]
    st_a = init_oselm(slfn, a[:32], a[:32], activation="sigmoid")
    st_a = oselm_train_sequential(st_a, a[32:], a[32:])
    st_b = init_oselm(slfn, b[:32], b[:32], activation="sigmoid")
    st_b = oselm_train_sequential(st_b, b[32:], b[32:])
    ab = cooperative_update(st_a, to_uv(st_b))
    ba = cooperative_update(st_b, to_uv(st_a))
    np.testing.assert_allclose(ab.beta, ba.beta, rtol=1e-3, atol=1e-4)


def test_merge_then_continue_training(slfn):
    """§4.2 step 6: after the merge, sequential training continues from
    the merged (P, β) and stays consistent with full-batch ELM."""
    x = make_data(12, rows=300)
    a, b, c = x[:100], x[100:200], x[200:]
    st_a = init_oselm(slfn, a[:32], a[:32], activation="sigmoid")
    st_a = oselm_train_sequential(st_a, a[32:], a[32:])
    st_b = init_oselm(slfn, b[:32], b[:32], activation="sigmoid")
    st_b = oselm_train_sequential(st_b, b[32:], b[32:])
    merged = cooperative_update(st_a, to_uv(st_b))
    merged = oselm_train_sequential(merged, c, c)
    elm = train_elm(slfn, x, x, activation="sigmoid")
    np.testing.assert_allclose(merged.beta, elm.beta, rtol=1e-3, atol=2e-4)


def test_uv_sub_removes_dataset(slfn):
    """E²LM subtraction: (A∪B) − B == A."""
    x = make_data(13)
    a, b = x[:128], x[128:]
    st_a = init_oselm(slfn, a[:32], a[:32], activation="sigmoid")
    st_a = oselm_train_sequential(st_a, a[32:], a[32:])
    st_b = init_oselm(slfn, b[:32], b[:32], activation="sigmoid")
    st_b = oselm_train_sequential(st_b, b[32:], b[32:])
    uv_ab = uv_add(to_uv(st_a), to_uv(st_b))
    uv_a_rec = uv_sub(uv_ab, to_uv(st_b))
    rec = from_uv(st_a, uv_a_rec)
    np.testing.assert_allclose(rec.beta, st_a.beta, rtol=1e-2, atol=1e-3)


def test_uv_replace(slfn):
    x = make_data(14)
    a, b = x[:128], x[128:]
    st_a = init_oselm(slfn, a[:32], a[:32], activation="sigmoid")
    st_b = init_oselm(slfn, b[:32], b[:32], activation="sigmoid")
    uva, uvb = to_uv(st_a), to_uv(st_b)
    total = uv_add(uva, uvb)
    swapped = uv_replace(total, uva, uvb)  # now 2×B
    np.testing.assert_allclose(swapped.u, 2 * uvb.u, rtol=1e-4, atol=1e-4)


def test_uv_payload_size(slfn):
    """Communication cost: the payload is Ñ(Ñ+m) floats, data-size
    independent (the paper's communication-cost argument)."""
    x = make_data(15)
    st = init_oselm(slfn, x[:32], x[:32], activation="sigmoid")
    uv = to_uv(st)
    assert uv.nbytes == 4 * (12 * 12 + 12 * 24)


# ------------------------------------------------------- autoencoder


def test_autoencoder_detects_anomaly():
    x = make_data(16, n=32)
    ae = init_autoencoder(KEY, 32, 8, x[:64])
    ae = ae_train_stream(ae, x[64:])
    normal = float(ae_score(ae, x[:32]).mean())
    anom = float(ae_score(ae, x[:32] + 6.0).mean())
    assert anom > 5 * normal


def test_autoencoder_requires_bottleneck():
    x = make_data(17, n=16)
    with pytest.raises(ValueError):
        init_autoencoder(KEY, 16, 16, x[:32])


def test_guarded_training_rejects_outliers():
    x = make_data(18, n=32)
    ae = init_autoencoder(KEY, 32, 8, x[:64])
    ae = ae_train_stream(ae, x[64:])
    thr = jnp.asarray(float(ae_score(ae, x[:64]).mean()) * 3.0)
    _, acc_normal = ae_train_step_guarded(ae, x[0], thr)
    _, acc_anom = ae_train_step_guarded(ae, x[0] + 8.0, thr)
    assert bool(acc_normal) and not bool(acc_anom)


def test_bank_min_score_and_instance_update():
    xa = make_data(19, n=32)
    xb = make_data(20, n=32) + 3.0
    ae_a = init_autoencoder(jax.random.PRNGKey(1), 32, 8, xa[:64])
    ae_a = ae_train_stream(ae_a, xa[64:])
    ae_b = init_autoencoder(jax.random.PRNGKey(2), 32, 8, xb[:64])
    ae_b = ae_train_stream(ae_b, xb[64:])
    bank = make_bank([ae_a, ae_b])
    # bank covers both patterns
    assert float(bank_score(bank, xa[:16]).mean()) < 3.0
    assert float(bank_score(bank, xb[:16]).mean()) < 3.0
    bank2 = bank_train_instance(bank, 0, xa[0])
    assert bank2.states.beta.shape == bank.states.beta.shape


def test_oselm_predict_shapes(slfn):
    x = make_data(21)
    st = init_oselm(slfn, x[:32], x[:32], activation="identity")
    y = oselm_predict(st, x[:7])
    assert y.shape == (7, 24)
    l = oselm_loss(st, x[:7], x[:7])
    assert l.shape == (7,)
