"""Cohort-paged arena tests: host arena round-trips, schedule
rotation, the two-tier merge's ≤1e-5 agreement with the flat resident
merge on every claimed topology (both kernel paths), tier-traffic
accounting, and the ``CohortFleetRuntime`` vs ``FleetRuntime``
tick-by-tick differential (the paged runtime must be an implementation
detail, not a semantics change)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fleet import (
    CohortMerger,
    CohortSchedule,
    FleetArena,
    cohort_round_cost,
    cohort_tree_reduce,
    fleet_merge_masked,
    hierarchical,
    init_arena,
    init_fleet,
    ring,
    star,
)
from repro.fleet.topology import Topology, all_to_all
from repro.runtime import (
    CohortFleetRuntime,
    DetectorConfig,
    FleetRuntime,
    GovernorConfig,
    RuntimeConfig,
)

D, C, F, NH, B = 32, 8, 8, 4, 4
RIDGE = 1e-2
N_INIT = 16


@pytest.fixture(scope="module")
def fleet():
    key = jax.random.PRNGKey(0)
    x0 = jax.random.normal(key, (D, N_INIT, F)) * 0.5
    return init_fleet(jax.random.PRNGKey(1), D, F, NH, x0, ridge=RIDGE)


def _arena(fleet) -> FleetArena:
    return FleetArena.from_fleet(fleet)


def _config(topology, **kw) -> RuntimeConfig:
    return RuntimeConfig(
        topology=topology, ridge=RIDGE,
        detector=DetectorConfig(warmup=4, warmup_skip=1),
        governor=GovernorConfig(merge_every=3),
        use_ingest_kernel=True, ingest_backend="xla", **kw,
    )


# ------------------------------------------------------------------ arena


def test_arena_from_fleet_roundtrip(fleet):
    arena = _arena(fleet)
    assert (arena.n_devices, arena.n_hidden, arena.n_out) == (D, NH, F)
    assert arena.alpha.shape == (F, NH)  # stored ONCE, not (D, F, NH)
    back = arena.to_fleet()
    np.testing.assert_array_equal(np.asarray(back.p), np.asarray(fleet.p))
    np.testing.assert_array_equal(np.asarray(back.beta), np.asarray(fleet.beta))
    np.testing.assert_array_equal(
        np.asarray(back.params.alpha), np.asarray(fleet.params.alpha)
    )
    # nbytes: basis once + per-device (P, β)
    expect = arena.alpha.nbytes + arena.bias.nbytes + 4 * D * (NH * NH + NH * F)
    assert arena.nbytes == expect


def test_arena_page_is_a_view(fleet):
    arena = _arena(fleet)
    page = arena.page(8, 16)
    assert page.p.shape == (8, NH, NH)
    assert page.params.alpha.ndim == 2  # unstacked shared basis
    assert np.shares_memory(page.p, arena.p)  # zero-copy
    arena.write_page(8, 16, np.zeros((8, NH, NH)), np.zeros((8, NH, F)),
                     where=np.arange(8) < 2)
    assert np.all(arena.p[8:10] == 0) and not np.all(arena.p[10:16] == 0)


def test_arena_rejects_per_device_bases(fleet):
    bad = fleet.replace(
        params=fleet.params._replace(alpha=fleet.params.alpha.at[0].add(1.0))
    )
    with pytest.raises(ValueError, match="share"):
        FleetArena.from_fleet(bad)


def test_init_arena_matches_per_device_init():
    """Paged init is Eq. 13 per device — identical to the resident
    ``init_fleet`` given the same key and boot chunks."""
    key = jax.random.PRNGKey(3)
    x0 = np.asarray(jax.random.normal(key, (D, N_INIT, F))) * 0.5
    arena = init_arena(
        jax.random.PRNGKey(4), D, F, NH, lambda lo, hi: x0[lo:hi],
        cohort_size=C, ridge=RIDGE,
    )
    resident = init_fleet(
        jax.random.PRNGKey(4), D, F, NH, jnp.asarray(x0), ridge=RIDGE
    )
    np.testing.assert_allclose(
        arena.p, np.asarray(resident.p), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        arena.beta, np.asarray(resident.beta), rtol=1e-4, atol=1e-5
    )
    with pytest.raises(ValueError, match="bottleneck"):
        init_arena(key, D, F, F, lambda lo, hi: x0[lo:hi], cohort_size=C)


# --------------------------------------------------------------- schedule


def test_schedule_validation():
    with pytest.raises(ValueError, match="divisible"):
        CohortSchedule(32, 5)
    with pytest.raises(ValueError, match="cohort_size"):
        CohortSchedule(32, 64)
    with pytest.raises(ValueError, match="active_per_tick"):
        CohortSchedule(32, 8, active_per_tick=5)
    s = CohortSchedule(32, 8)
    assert s.n_cohorts == 4
    assert s.bounds(2) == (16, 24)
    assert s.bounds() == [(0, 8), (8, 16), (16, 24), (24, 32)]


def test_schedule_round_robin_covers_all_cohorts():
    s = CohortSchedule(32, 8, active_per_tick=2)
    assert s.active(0) == [0, 1]
    assert s.active(1) == [2, 3]
    served = set()
    for t in range(2):
        served.update(s.active(t))
    assert served == {0, 1, 2, 3}
    # active_per_tick=None serves everyone
    assert CohortSchedule(32, 8).active(7) == [0, 1, 2, 3]


# -------------------------------------------------------- two-tier merges

CLAIMED_TOPOLOGIES = [
    star(D),
    all_to_all(D),
    ring(D, hops=2),
    ring(D, hops=9),
    ring(D, hops=D // 2),  # closed band → fleet-wide constant
    hierarchical(D, 4),    # head exchange → global
    hierarchical(D, 4, head_exchange=False),   # nests evenly in cohorts
    hierarchical(D, 6, head_exchange=False),   # straddles cohort bounds
    hierarchical(D, 16, head_exchange=False),  # two clusters per cohort
]


@pytest.mark.parametrize("kernel", [False, True], ids=["xla", "pallas"])
@pytest.mark.parametrize(
    "topology", CLAIMED_TOPOLOGIES, ids=lambda t: t.name
)
def test_two_tier_merge_matches_flat(fleet, topology, kernel):
    """Eq. 8 through the cohort tree == the flat resident merge ≤1e-5
    under a participation mask, for every claimed topology and both
    tier-1 lowerings."""
    rng = np.random.default_rng(42)
    mask = rng.random(D) > 0.25
    mask[:2] = True  # keep every run a real merge
    arena = _arena(fleet)
    merger = CohortMerger(
        topology, CohortSchedule(D, C), ridge=RIDGE, kernel=kernel
    )
    cost = merger.merge(arena, mask)
    flat = fleet_merge_masked(
        fleet, topology, jnp.asarray(mask, jnp.float32), ridge=RIDGE
    )
    np.testing.assert_allclose(
        arena.beta, np.asarray(flat.beta), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        arena.p, np.asarray(flat.p), rtol=1e-5, atol=1e-5
    )
    # non-participants bit-for-bit untouched
    skip = ~mask
    np.testing.assert_array_equal(
        arena.beta[skip], np.asarray(fleet.beta)[skip]
    )
    assert cost.bytes_total > 0


def test_star_merge_collapses_fleet_to_one_state(fleet):
    """A full-participation star round solves ONE global (ΣU, ΣV) and
    broadcasts it: every device row must land bit-identical, across
    cohort pages — the scatter-back can't fragment the consensus."""
    arena = _arena(fleet)
    merger = CohortMerger(star(D), CohortSchedule(D, C), ridge=RIDGE)
    merger.merge(arena, np.ones(D, bool))
    np.testing.assert_array_equal(arena.p, np.broadcast_to(arena.p[:1], arena.p.shape))
    np.testing.assert_array_equal(
        arena.beta, np.broadcast_to(arena.beta[:1], arena.beta.shape)
    )


def test_merger_compile_once_across_pages_and_masks(fleet):
    arena = _arena(fleet)
    merger = CohortMerger(
        hierarchical(D, 4, head_exchange=False),
        CohortSchedule(D, C), ridge=RIDGE,
    )
    rng = np.random.default_rng(0)
    for _ in range(3):
        merger.merge(arena, rng.random(D) > 0.3)
    assert all(v <= 1 for v in merger.jit_cache_sizes().values()), (
        merger.jit_cache_sizes()
    )


def test_merger_rejects_undecomposable_topologies():
    # unsorted cluster ids: the paged segment sums assume contiguity
    cids = np.array([0, 1] * (D // 2), np.int32)
    scrambled = Topology(
        name="scrambled", n_devices=D, kind="segment",
        cluster_ids=cids, n_clusters=2, head_exchange=False,
        payloads_per_round=2 * D,
    )
    with pytest.raises(ValueError, match="sorted"):
        CohortMerger(scrambled, CohortSchedule(D, C))
    # a dense topology that is NOT fleet-wide constant cannot decompose
    dense = Topology(
        name="arbitrary_dense", n_devices=D, kind="dense",
        matrix=np.eye(D, dtype=np.float32), payloads_per_round=0,
    )
    with pytest.raises(NotImplementedError):
        CohortMerger(dense, CohortSchedule(D, C))
    merger = CohortMerger(star(D), CohortSchedule(D, C))
    with pytest.raises(ValueError, match="mask"):
        merger.merge(_arena_of_zeros(), np.ones(D + 1, bool))


def _arena_of_zeros() -> FleetArena:
    return FleetArena(
        alpha=np.zeros((F, NH), np.float32), bias=np.zeros(NH, np.float32),
        p=np.stack([np.eye(NH, dtype=np.float32)] * D),
        beta=np.zeros((D, NH, F), np.float32),
    )


def test_cohort_tree_reduce_matches_sum():
    rng = np.random.default_rng(1)
    for n in (1, 2, 3, 5, 8):
        stack = rng.normal(size=(n, NH, NH + F)).astype(np.float32)
        out = cohort_tree_reduce(jnp.asarray(stack))
        np.testing.assert_allclose(
            np.asarray(out), stack.sum(axis=0), rtol=1e-5, atol=1e-5
        )


# ----------------------------------------------------------- tier costs


def test_tier_cost_accounting():
    sched = CohortSchedule(D, C)  # 4 cohorts
    # global mode: devices↔cohort head, then a head tree
    c = cohort_round_cost(star(D), sched, NH, F)
    assert (c.tier1_payloads, c.tier2_payloads) == (2 * (D - 4), 2 * 3)
    assert c.bytes_total == c.bytes_tier1 + c.bytes_tier2
    # clusters nested evenly in cohorts: NOTHING crosses the overlay
    c = cohort_round_cost(hierarchical(D, 4, head_exchange=False), sched, NH, F)
    assert c.tier2_payloads == 0
    # straddling clusters pay exactly their extra cohort incidences
    c = cohort_round_cost(hierarchical(D, 6, head_exchange=False), sched, NH, F)
    assert c.tier2_payloads > 0
    assert c.tier2_payloads < 2 * 6 * sched.n_cohorts
    # open ring: the halo is 2·hops per boundary, O(cohorts)
    c = cohort_round_cost(ring(D, hops=2), sched, NH, F)
    assert c.tier2_payloads == 2 * 2 * sched.n_cohorts
    # tier 2 stays O(cohorts) while tier 1 carries the O(D) bulk
    assert c.tier1_payloads > c.tier2_payloads


# ------------------------------------------------- paged runtime (tentpole)


def _tick_batches(n_ticks: int, seed: int = 7, drift_dev: int | None = None,
                  drift_from: int = 10**9):
    rng = np.random.default_rng(seed)
    for t in range(n_ticks):
        batch = rng.normal(scale=0.5, size=(D, B, F)).astype(np.float32)
        if drift_dev is not None and t >= drift_from:
            batch[drift_dev] += 2.0
        yield batch


@pytest.mark.parametrize(
    "topology",
    [star(D), hierarchical(D, 6, head_exchange=False), ring(D, hops=2)],
    ids=lambda t: t.name,
)
def test_paged_runtime_matches_resident(fleet, topology):
    """The ISSUE's differential: the paged runtime's TickReport stream
    is the resident runtime's, tick by tick — losses, drift flags,
    fresh detections, merge decisions — through merge rounds, a
    post-merge rebase tick, and a drift detection."""
    cfg = _config(topology)
    resident = FleetRuntime(fleet, cfg)
    paged = CohortFleetRuntime(_arena(fleet), cfg, cohort_size=C)
    for t, batch in enumerate(_tick_batches(12, drift_dev=3, drift_from=8)):
        r1 = resident.tick(batch)
        r2 = paged.tick(batch)
        np.testing.assert_allclose(
            r1.losses, r2.losses, rtol=1e-5, atol=1e-6
        )
        assert np.array_equal(r1.drifted, r2.drifted), t
        assert np.array_equal(r1.fresh_detections, r2.fresh_detections), t
        assert r1.decision == r2.decision, (t, r1.decision, r2.decision)
        assert (r1.merge_seconds is None) == (r2.merge_seconds is None)
    assert resident.governor.state.merges > 0  # the stream merged
    np.testing.assert_allclose(
        np.asarray(resident.states.beta), paged.arena.beta,
        atol=5e-5, rtol=0,
    )
    assert paged.detections_total == resident.detections_total
    assert list(paged.detections) == list(resident.detections)
    paged.assert_compile_once()


def test_paged_runtime_served_mask_and_callable_batch(fleet):
    """Un-served devices keep state bit-for-bit; a callable batch
    source deals per-cohort slices and never materializes (D, B, F)."""
    cfg = _config(star(D))
    paged = CohortFleetRuntime(_arena(fleet), cfg, cohort_size=C)
    p0 = paged.arena.p.copy()
    det0 = jax.tree_util.tree_map(np.asarray, paged.det)
    served = np.ones(D, bool)
    served[5] = served[20] = False
    rng = np.random.default_rng(0)
    batch = rng.normal(size=(D, B, F)).astype(np.float32)
    calls = []

    def batch_fn(lo, hi):
        calls.append((lo, hi))
        return batch[lo:hi]

    rep = paged.tick(batch_fn, served=served)
    assert calls == CohortSchedule(D, C).bounds()
    np.testing.assert_array_equal(paged.arena.p[5], p0[5])
    np.testing.assert_array_equal(paged.arena.p[20], p0[20])
    assert np.asarray(paged.det.count)[5] == det0.count[5]
    assert not np.array_equal(paged.arena.p[6], p0[6])
    np.testing.assert_array_equal(rep.served, served)


def test_paged_runtime_cohort_rotation(fleet):
    """active_per_tick < n_cohorts: inactive cohorts report NaN losses
    and keep model + detector state; rotation serves everyone across
    the window."""
    cfg = _config(star(D))
    paged = CohortFleetRuntime(
        _arena(fleet), cfg, cohort_size=C, active_per_tick=2
    )
    p0 = paged.arena.p.copy()
    batch = np.random.default_rng(0).normal(size=(D, B, F)).astype(np.float32)
    rep = paged.tick(batch)
    # tick 0 serves cohorts {0, 1} = devices [0, 16)
    assert np.isfinite(rep.losses[:16]).all()
    assert np.isnan(rep.losses[16:]).all()
    np.testing.assert_array_equal(rep.served, np.arange(D) < 16)
    np.testing.assert_array_equal(paged.arena.p[16:], p0[16:])
    assert (np.asarray(paged.det.count)[16:] == 0).all()
    rep = paged.tick(batch)  # tick 1 serves cohorts {2, 3}
    assert np.isnan(rep.losses[:16]).all()
    assert np.isfinite(rep.losses[16:]).all()
    assert (np.asarray(paged.det.count) == 1).all()


def test_paged_runtime_rejects_unsupported_config(fleet):
    from repro.fleet import FaultInjector, RobustConfig, StalenessSchedule

    arena = _arena(fleet)
    base = dict(topology=star(D), ridge=RIDGE)
    for bad in (
        dict(staleness=StalenessSchedule.random(D, max_lag=2, seed=0)),
        dict(robust=RobustConfig()),
        dict(faults=FaultInjector(n_devices=D, specs=())),
        dict(payload_precision="int8"),
        dict(snapshot_every=4, snapshot_dir="/tmp/nope"),
    ):
        with pytest.raises(ValueError):
            CohortFleetRuntime(
                arena, RuntimeConfig(**base, **bad), cohort_size=C
            )
    with pytest.raises(ValueError, match="cohort_size"):
        CohortFleetRuntime(arena, RuntimeConfig(**base))
    with pytest.raises(ValueError, match="topology"):
        CohortFleetRuntime(
            arena, RuntimeConfig(topology=star(D * 2), ridge=RIDGE),
            cohort_size=C,
        )


def test_paged_runtime_telemetry_gauges(fleet, tmp_path):
    from repro.obs import TelemetryConfig

    cfg = _config(star(D), telemetry=TelemetryConfig(dir=tmp_path))
    paged = CohortFleetRuntime(_arena(fleet), cfg, cohort_size=C)
    for batch in _tick_batches(3):
        paged.tick(batch)
    tel = paged.telemetry
    assert tel.ticks.value == 3
    assert tel.cohort_pages.value == 3 * (D // C)
    assert tel.arena_bytes.value == paged.arena.nbytes
    assert tel.arena_resident_devices.value == D
    assert tel.merge_rounds.value == paged.merge_round > 0
    tiers = {k: c.value for k, c in tel.merge_tier_bytes.children.items()}
    assert tiers.get(("intra",), 0) > tiers.get(("inter",), 0) > 0
    summary = paged.finalize_telemetry()
    assert summary["ticks"] == 3
