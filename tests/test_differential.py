"""Differential backend parity suite over the scenario layer.

PR 2–4 accumulated three equivalent implementations of the tick-ingest
hot path (the ``_fleet_train`` vmap-of-scan reference, the fused XLA
block-Woodbury lowering, and the Pallas VMEM-resident kernel in
interpret mode) and two of every topology merge (XLA reference vs the
Pallas kernel family, masked and unmasked). This suite drives
IDENTICAL scenario ticks — real paper-analog feeds from
``repro.scenarios``, not random fixtures — through each implementation
and asserts agreement within the documented f32 bounds:

- Pallas ingest vs scan: ≤1e-5 per window (``kernels/fleet_ingest``
  docstring), 1e-4/1e-5 after a multi-tick runtime accumulation;
- fused XLA Woodbury vs scan: 2e-4/2e-5 (the c×c Cholesky reorders the
  f32 accumulation; exact in real arithmetic);
- merge kernels vs reference: ≤1e-5 (same convention as
  ``tests/test_topology_kernels.py``).

Covered axes: λ<1 (forgetting), masked participation (including
all-masked), odd D/T/Ñ remainders (device counts off the block grid,
tick windows off the sublane tile), and full ``TickReport`` agreement
(losses, detector flags, merge decisions) across runtime backends.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fleet import (
    all_to_all,
    fleet_merge,
    fleet_merge_kernel,
    fleet_merge_masked,
    fleet_merge_masked_kernel,
    hierarchical,
    ring,
    star,
)
from repro.fleet.fleet import _fleet_train
from repro.kernels.fleet_ingest import fleet_ingest_kernel, fleet_ingest_xla
from repro.runtime import FleetRuntime, GovernorConfig, RuntimeConfig
from repro.scenarios import make_scenario

jax.config.update("jax_platform_name", "cpu")

RIDGE = 1e-3

# odd everywhere: D off the block_d grid, T (= spec.batch) off the
# sublane tile, Ñ off the lane/sublane tiles
SPEC_ODD = dict(n_devices=5, ticks=10, batch=3, n_hidden=10)


def _scenario(name="har", *, forget=1.0, **kw):
    """A tiny paper-analog scenario (odd dims by default) shared by the
    ingest/merge/runtime differential tests."""
    over = {**SPEC_ODD, **kw}
    if forget != 1.0:
        over["forget"] = forget
    return make_scenario(name, **over).build()


def _assert_state_close(got, ref, *, rtol, atol):
    np.testing.assert_allclose(np.asarray(got.p), np.asarray(ref.p),
                               rtol=rtol, atol=atol)
    np.testing.assert_allclose(np.asarray(got.beta), np.asarray(ref.beta),
                               rtol=rtol, atol=atol)


# ---------------------------------------------------- ingest window parity


@pytest.mark.parametrize("forget", [1.0, 0.95])
@pytest.mark.parametrize("scenario", ["har", "mnist_like"])
def test_ingest_backends_agree_on_scenario_windows(scenario, forget):
    """One scenario tick window through scan, Woodbury and
    Pallas-interpret: all three agree within the documented bounds —
    including λ<1 and an odd (D=5, T=3·4, Ñ=10) layout."""
    sc = _scenario(scenario, forget=forget)
    fleet = sc.init_fleet(jax.random.PRNGKey(0))
    # four consecutive tick batches as one window: T = 12 (odd vs the
    # pallas sublane pad of 16, and a ragged tail for block_t=5)
    feed = sc.feed()
    win = jnp.concatenate([jnp.asarray(feed.tick_batch(t)) for t in range(4)], axis=1)

    ref = _fleet_train(fleet, win)
    got_x, _ = fleet_ingest_xla(fleet, win, block_t=5)
    _assert_state_close(got_x, ref, rtol=2e-4, atol=2e-5)
    got_p, _ = fleet_ingest_kernel(fleet, win, block_d=4, interpret=True)
    _assert_state_close(got_p, ref, rtol=1e-5, atol=1e-5)


def test_ingest_losses_agree_on_scenario_windows():
    """The pre-train drift-signal losses (what the detector consumes)
    agree across the fused lowerings on a real scenario window."""
    from repro.core import ae_score

    sc = _scenario("har")
    fleet = sc.init_fleet(jax.random.PRNGKey(0))
    win = jnp.asarray(sc.feed().tick_batch(0))
    ref_loss = jax.vmap(lambda s, xb: jnp.mean(ae_score(s, xb)))(fleet, win)
    _, loss_x = fleet_ingest_xla(fleet, win)
    _, loss_p = fleet_ingest_kernel(fleet, win, interpret=True)
    np.testing.assert_allclose(np.asarray(loss_x), np.asarray(ref_loss),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(loss_p), np.asarray(ref_loss),
                               rtol=1e-5, atol=1e-7)


# ------------------------------------------------------ merge-state parity


def _topologies(d):
    return {
        "ring_open": ring(d, hops=1),
        "star": star(d),
        "hierarchical_isolated": hierarchical(d, 2, head_exchange=False),
        "all_to_all": all_to_all(d),
    }


@pytest.mark.parametrize("topo_name", sorted(_topologies(5)))
def test_merge_kernel_parity_on_scenario_fleet(topo_name):
    """Reference merge vs the Pallas merge-kernel family on a
    scenario-trained fleet (odd D=5, Ñ=10), every topology kind."""
    sc = _scenario("har")
    fleet = sc.init_fleet(jax.random.PRNGKey(0))
    fleet = _fleet_train(fleet, jnp.asarray(sc.streams.xs))
    topo = _topologies(sc.spec.n_devices)[topo_name]
    ref = fleet_merge(fleet, topo, ridge=RIDGE)
    got = fleet_merge_kernel(fleet, topo, ridge=RIDGE, interpret=True)
    _assert_state_close(got, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("topo_name", sorted(_topologies(5)))
@pytest.mark.parametrize("mask", [
    (1, 1, 1, 1, 1),
    (1, 0, 1, 1, 0),   # quarantine two devices
    (0, 0, 0, 0, 0),   # everyone quarantined (ridge keeps solves posed)
])
def test_masked_merge_parity_on_scenario_fleet(topo_name, mask):
    """Masked participation: reference vs kernel agree, and masked-out
    devices keep their exact pre-merge state on both paths."""
    sc = _scenario("har")
    fleet = sc.init_fleet(jax.random.PRNGKey(0))
    fleet = _fleet_train(fleet, jnp.asarray(sc.streams.xs))
    topo = _topologies(sc.spec.n_devices)[topo_name]
    m = jnp.asarray(mask, jnp.float32)
    ref = fleet_merge_masked(fleet, topo, m, ridge=RIDGE)
    got = fleet_merge_masked_kernel(fleet, topo, m, ridge=RIDGE, interpret=True)
    _assert_state_close(got, ref, rtol=1e-5, atol=1e-5)
    out = np.flatnonzero(np.asarray(mask) == 0)
    np.testing.assert_array_equal(np.asarray(ref.beta)[out],
                                  np.asarray(fleet.beta)[out])
    np.testing.assert_array_equal(np.asarray(got.beta)[out],
                                  np.asarray(fleet.beta)[out])


# --------------------------------------------------- runtime tick differential


def _runtime(sc, topo, **kw):
    return FleetRuntime(
        sc.init_fleet(jax.random.PRNGKey(0)),
        RuntimeConfig(
            topology=topo,
            ridge=sc.spec.ridge,
            detector=sc.spec.detector,
            governor=GovernorConfig(merge_every=4),
            **kw,
        ),
    )


@pytest.mark.parametrize("forget", [1.0, 0.97])
@pytest.mark.parametrize("topo_fn", [lambda d: ring(d, hops=1), star])
def test_runtime_tick_reports_agree_across_backends(topo_fn, forget):
    """Identical scenario ticks through the scan-ingest runtime, the
    fused-XLA runtime and the Pallas-interpret runtime: TickReports
    agree tick by tick (losses within bounds; detector flags, merge
    decisions and participant counts exactly), the merged states agree
    at the end, and every runtime stays compile-once."""
    sc = _scenario("har", forget=forget)
    topo = topo_fn(sc.spec.n_devices)
    rt_ref = _runtime(sc, topo)
    rt_x = _runtime(sc, topo, use_ingest_kernel=True, ingest_backend="xla")
    rt_p = _runtime(sc, topo, use_ingest_kernel=True, ingest_backend="pallas")

    feed = sc.feed()
    merges = 0
    for t in range(feed.n_ticks):
        batch = feed.tick_batch(t)
        rep_ref = rt_ref.tick(batch)
        rep_x = rt_x.tick(batch)
        rep_p = rt_p.tick(batch)
        for rep, tol in ((rep_x, 2e-4), (rep_p, 1e-5)):
            np.testing.assert_allclose(rep.losses, rep_ref.losses,
                                       rtol=tol, atol=1e-6)
            assert np.array_equal(rep.drifted, rep_ref.drifted)
            assert np.array_equal(rep.fresh_detections, rep_ref.fresh_detections)
            assert rep.decision.merge == rep_ref.decision.merge
            assert rep.decision.participants == rep_ref.decision.participants
            assert rep.decision.round_bytes == rep_ref.decision.round_bytes
        merges += rep_ref.decision.merge
    assert merges > 0, "no merge admitted — the differential lost its teeth"
    _assert_state_close(rt_x.states, rt_ref.states, rtol=5e-4, atol=5e-5)
    _assert_state_close(rt_p.states, rt_ref.states, rtol=1e-4, atol=1e-5)
    for rt in (rt_ref, rt_x, rt_p):
        rt.assert_compile_once()


def test_runtime_merge_kernel_differential_end_to_end():
    """The merge-kernel runtime and the reference-merge runtime agree
    on a whole gated scenario run (merge path differential, scan
    ingest held fixed)."""
    sc = _scenario("har")
    topo = ring(sc.spec.n_devices, hops=1)
    rt_ref = _runtime(sc, topo)
    rt_k = _runtime(sc, topo, use_merge_kernel=True)
    feed = sc.feed()
    for t in range(feed.n_ticks):
        batch = feed.tick_batch(t)
        rep_ref = rt_ref.tick(batch)
        rep_k = rt_k.tick(batch)
        assert rep_k.decision.merge == rep_ref.decision.merge
    _assert_state_close(rt_k.states, rt_ref.states, rtol=1e-4, atol=1e-5)


def test_differential_covers_odd_remainders():
    """The shared fixture really exercises the ragged paths: D=5 is off
    the block_d=4 grid, the 12-sample window is off both the Pallas
    sublane tile (16) and the block_t=5 Woodbury chain (ragged tail of
    2), and Ñ=10 is off the lane tile."""
    from repro.kernels.fleet_ingest import ingest_padding

    sc = _scenario("har")
    assert sc.spec.n_devices % 4 != 0
    win_t = 4 * sc.spec.batch
    pallas_pad, xla_pad = ingest_padding(win_t, block_t=5)
    assert pallas_pad > 0 and xla_pad > 0
    assert sc.spec.n_hidden % 8 != 0
