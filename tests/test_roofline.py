"""Tests for the HLO cost walker and roofline report."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.analysis import RooflineReport
from repro.roofline.hlo_costs import analyze_hlo, parse_hlo


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_dot_flops_exact():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    txt = _compile_text(lambda x, y: x @ y, a, b)
    r = analyze_hlo(txt)
    assert r["flops"] == 2 * 64 * 32 * 128


def test_scan_multiplies_trip_count():
    w = jax.ShapeDtypeStruct((9, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)

    def f(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        out, _ = jax.lax.scan(body, x, w)
        return out

    txt = _compile_text(f, w, x)
    r = analyze_hlo(txt)
    # 9 iterations of (8,32)@(32,32) — XLA may unroll or keep the loop,
    # either way the count must be exact
    assert r["flops"] == 9 * 2 * 8 * 32 * 32


def test_nested_scan_multiplies():
    w = jax.ShapeDtypeStruct((5, 4, 16, 16), jnp.float32)
    x = jax.ShapeDtypeStruct((16,), jnp.float32)

    def f(w, x):
        def outer(c, wo):
            def inner(ci, wi):
                return jnp.tanh(wi @ ci), None
            c2, _ = jax.lax.scan(inner, c, wo)
            return c2, None
        out, _ = jax.lax.scan(outer, x, w)
        return out

    txt = _compile_text(f, w, x)
    r = analyze_hlo(txt)
    assert r["flops"] == 5 * 4 * 2 * 16 * 16


def test_remat_counts_recompute():
    """jax.checkpoint makes the backward re-run the forward — the walker
    must see the extra dots (that's the point of the useful-FLOPs ratio)."""
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

    def loss_plain(w, x):
        return jnp.sum(jnp.tanh(x @ w) @ w.T)

    def loss_remat(w, x):
        return jnp.sum(jax.checkpoint(lambda w, x: jnp.tanh(x @ w) @ w.T)(w, x))

    t_plain = _compile_text(jax.grad(loss_plain), w, x)
    t_remat = _compile_text(jax.grad(loss_remat), w, x)
    assert analyze_hlo(t_remat)["flops"] >= analyze_hlo(t_plain)["flops"]


def test_dus_in_loop_counts_update_not_buffer():
    """A loop that writes one row per iteration into a big carried buffer
    must cost ~rows, not trips × full-buffer traffic (KV-cache pattern)."""
    cache = jax.ShapeDtypeStruct((1024, 256), jnp.float32)
    rows = jax.ShapeDtypeStruct((64, 256), jnp.float32)

    def f(cache, rows):
        def body(c, i):
            c = jax.lax.dynamic_update_slice(c, rows[i][None], (i, 0))
            return c, None
        out, _ = jax.lax.scan(body, cache, jnp.arange(64))
        return out

    txt = _compile_text(f, cache, rows)
    r = analyze_hlo(txt)
    buffer_bytes = 1024 * 256 * 4
    # naive counting would charge ≥ 64 × 2 × buffer ≈ 134 MB; the alias-
    # aware model must stay within a few full-buffer equivalents
    assert r["bytes"] < 6 * buffer_bytes, r["bytes"]


def test_parse_hlo_finds_entry():
    txt = _compile_text(lambda x: x * 2, jax.ShapeDtypeStruct((4,), jnp.float32))
    comps, entry = parse_hlo(txt)
    assert entry and entry in comps


def test_roofline_report_terms():
    r = RooflineReport(
        arch="x", shape="train_4k", mesh="16x16", chips=256,
        hlo_flops=256 * 197e12,          # exactly 1 s of compute
        hlo_bytes=256 * 819e9 * 2,       # 2 s of HBM
        attn_interior_bytes=256 * 819e9,  # 1 s of it is attention-interior
        coll_bytes=256 * 50e9 * 0.5,     # 0.5 s of ICI
        coll_breakdown={}, model_flops=256 * 197e12 * 0.8,
        per_device_memory={},
    )
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 2.0) < 1e-9
    assert abs(r.t_memory_fused_attn - 1.0) < 1e-9
    assert abs(r.t_collective - 0.5) < 1e-9
    assert r.dominant == "memory"
    assert abs(r.useful_flops_ratio - 0.8) < 1e-9


def test_collective_bytes_counted():
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (run under XLA_FLAGS device_count)")
