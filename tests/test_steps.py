"""Tests for the train/serve step factories (grad accumulation math,
detector step)."""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_detector_step, make_optimizer, make_train_step
from repro.models import init_params


def _setup(microbatches):
    cfg = get_config("granite-3-2b").reduced()
    cfg = dataclasses.replace(cfg, num_microbatches=microbatches)
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    return cfg, params, batch


def test_microbatch_equals_full_batch():
    """Grad accumulation over M microbatches == one full-batch step."""
    cfg1, params, batch = _setup(1)
    cfg2, _, _ = _setup(2)
    opt1 = make_optimizer(cfg1)
    opt2 = make_optimizer(cfg2)
    s1 = opt1.init(params)
    s2 = opt2.init(params)
    p1, _, m1 = jax.jit(make_train_step(cfg1, opt1))(params, s1, batch)
    p2, _, m2 = jax.jit(make_train_step(cfg2, opt2))(params, s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-2, atol=2e-3
        )


def test_train_step_reduces_loss_over_steps():
    cfg, params, batch = _setup(1)
    opt = make_optimizer(cfg, lr=5e-3)
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    losses = []
    for _ in range(8):
        params, state, metrics = step(params, state, batch)  # memorize one batch
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]


def test_features_shape_and_finite():
    cfg, params, batch = _setup(2)
    opt = make_optimizer(cfg)
    state = opt.init(params)
    _, _, metrics = jax.jit(make_train_step(cfg, opt))(params, state, batch)
    feats = np.asarray(metrics["features"])
    assert feats.shape == (4, cfg.d_model)
    assert np.isfinite(feats).all()


def test_detector_step_single_shard():
    """On a 1-device mesh the psum merge degenerates to Eq. 15 roundtrip."""
    from repro.core import init_oselm, init_slfn, oselm_step

    mesh = jax.make_mesh((1,), ("data",))
    params = init_slfn(jax.random.PRNGKey(0), 32, 8)
    x0 = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    st = init_oselm(params, x0, x0, activation="identity", ridge=1e-3)
    stacked = jax.tree.map(lambda l: l[None], st)
    feats = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 32))

    det = make_detector_step(mesh, ("data",), merge=True, ridge=1e-3)
    out = det(stacked, feats)
    ref = oselm_step(st, feats[0], feats[0])
    np.testing.assert_allclose(
        np.asarray(out.beta[0]), np.asarray(ref.beta), rtol=5e-2, atol=5e-3
    )
