"""Resident runtime tests: sequential drift detector (false-positive
rate, detection, re-admission, common-mode rebase), masked-participation
merges (reference + Pallas kernels), the merge governor's comm-budget
SLO, staleness validation, and the end-to-end quarantine AUC claim."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_har_dataset
from repro.data.metrics import roc_auc
from repro.data.pipeline import anomaly_eval_arrays, train_test_split
from repro.data.synthetic import AnomalyDataset
from repro.fleet import (
    DriftEvent,
    StalenessSchedule,
    all_to_all,
    fleet_merge,
    fleet_merge_masked,
    fleet_merge_masked_kernel,
    fleet_score,
    fleet_train,
    fleet_train_async,
    hierarchical,
    init_fleet,
    make_fleet_streams,
    random_drift_schedule,
    ring,
    star,
)
from repro.fleet.staleness import _lagged_gather
from repro.runtime import (
    DetectorConfig,
    FleetRuntime,
    GovernorConfig,
    MergeGovernor,
    RuntimeConfig,
    TickFeed,
    detector_update,
    init_detector,
)

D, H, RIDGE = 12, 8, 1e-3
H_RT = 16  # runtime-scenario detector width (matches the soak benchmark)


# ------------------------------------------------------------------- detector


def _scan_detector(losses, cfg, state=None):
    """Run detector_update over a (T, D) loss matrix; returns final
    state plus the (T, D) drifted/fresh trajectories."""
    state = init_detector(losses.shape[1]) if state is None else state

    def step(s, x):
        s, drifted, fresh = detector_update(s, x, cfg)
        return s, (drifted, fresh)

    return jax.lax.scan(step, state, jnp.asarray(losses))


def test_detector_stationary_false_positive_rate():
    """Acceptance satellite: on stationary streams the sequential
    detector must not fire — zero flags over 64 devices × 400 ticks."""
    rng = np.random.default_rng(0)
    losses = rng.gamma(4.0, 2.5e-4, size=(400, 64)).astype(np.float32)
    cfg = DetectorConfig()
    _, (drifted, fresh) = _scan_detector(losses, cfg)
    assert int(np.asarray(fresh).sum()) == 0
    assert not bool(np.asarray(drifted)[-1].any())


def test_detector_flags_step_change_fast_then_readmits():
    rng = np.random.default_rng(1)
    base = rng.gamma(4.0, 2.5e-4, size=(200, 4)).astype(np.float32)
    losses = base.copy()
    losses[100:140, 2] *= 12.0  # device 2 drifts, then re-converges
    cfg = DetectorConfig()
    _, (drifted, fresh) = _scan_detector(losses, cfg)
    drifted = np.asarray(drifted)
    fresh = np.asarray(fresh)
    first = int(np.flatnonzero(fresh[:, 2])[0])
    assert 100 <= first <= 105  # detected within a few ticks
    assert fresh[:, [0, 1, 3]].sum() == 0  # nobody else flagged
    assert drifted[120, 2]  # quarantined while drifted
    # re-converged at 140 → re-admitted after the hysteresis patience
    assert not drifted[140 + cfg.patience + 8, 2]


def test_detector_rebase_absorbs_common_mode_shift():
    """A post-merge loss step shared by the fleet must not flag when the
    runtime marks the rebase tick — and must flag when it does not."""
    rng = np.random.default_rng(2)
    losses = rng.gamma(16.0, 1e-4, size=(60, 8)).astype(np.float32)
    cfg = DetectorConfig()
    state, _ = _scan_detector(losses, cfg)
    shifted = jnp.asarray(losses[-1] * 5.0)  # fleet-wide 5x step

    s_rebase, drifted, fresh = detector_update(state, shifted, cfg, rebase=True)
    assert int(np.asarray(fresh).sum()) == 0
    # the band followed the common-mode shift
    assert float(np.asarray(s_rebase.mean).mean()) > float(np.asarray(state.mean).mean()) * 3

    def step(s, x):
        s, d, f = detector_update(s, x, cfg)
        return s, f

    _, fresh_traj = jax.lax.scan(
        step, state, jnp.tile(shifted[None], (4, 1))
    )
    assert bool(np.asarray(fresh_traj).any())  # without rebase: flags


def test_detector_rebase_keeps_idiosyncratic_drift_detectable():
    rng = np.random.default_rng(3)
    losses = rng.gamma(16.0, 1e-4, size=(60, 8)).astype(np.float32)
    cfg = DetectorConfig()
    state, _ = _scan_detector(losses, cfg)
    shifted = losses[-1] * 2.0
    shifted[5] = losses[-1][5] * 40.0  # device 5 genuinely drifts
    s1, _, fresh1 = detector_update(state, jnp.asarray(shifted), cfg, rebase=True)
    assert int(np.asarray(fresh1).sum()) == 0  # rebase tick never flags
    s2, _, fresh2 = detector_update(s1, jnp.asarray(shifted), cfg)
    assert bool(np.asarray(fresh2)[5])  # ...but the outlier fires next tick
    assert int(np.asarray(fresh2).sum()) == 1


# ---------------------------------------------------------------- masked merge


@pytest.fixture(scope="module")
def trained_fleet():
    key = jax.random.PRNGKey(0)
    ds = make_har_dataset(seed=0, samples_per_class=60, n_features=48)
    lo, hi = ds.x.min(0), ds.x.max(0)
    ds = ds._replace(x=((ds.x - lo) / (hi - lo + 1e-6)).astype(np.float32))
    mask = ds.y < 2
    ds2 = AnomalyDataset(ds.name, ds.x[mask], ds.y[mask], ds.class_names[:2])
    fs = make_fleet_streams(ds2, D, 24, n_init=2 * H, seed=0)
    fleet = init_fleet(
        key, D, ds2.n_features, H, fs.x_init, activation="identity", ridge=RIDGE
    )
    return fleet_train(fleet, fs.xs)


TOPOLOGIES = [
    ("all_to_all", lambda: all_to_all(D)),
    ("star", lambda: star(D)),
    ("ring2", lambda: ring(D, hops=2)),
    ("hier", lambda: hierarchical(D, 3)),
    ("hier_iso", lambda: hierarchical(D, 3, head_exchange=False)),
]


@pytest.mark.parametrize("topo_fn", [f for _, f in TOPOLOGIES],
                         ids=[n for n, _ in TOPOLOGIES])
def test_masked_merge_all_ones_equals_fleet_merge(trained_fleet, topo_fn):
    topo = topo_fn()
    ref = fleet_merge(trained_fleet, topo, ridge=RIDGE)
    out = fleet_merge_masked(trained_fleet, topo, jnp.ones(D), ridge=RIDGE)
    np.testing.assert_allclose(
        np.asarray(out.beta), np.asarray(ref.beta), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(out.p), np.asarray(ref.p), rtol=1e-5, atol=1e-6
    )


@pytest.mark.parametrize("topo_fn", [f for _, f in TOPOLOGIES],
                         ids=[n for n, _ in TOPOLOGIES])
def test_masked_merge_quarantines_and_matches_subfleet(trained_fleet, topo_fn):
    topo = topo_fn()
    mask = jnp.ones(D).at[3].set(0).at[8].set(0)
    out = fleet_merge_masked(trained_fleet, topo, mask, ridge=RIDGE)
    # quarantined devices keep their own model bit-for-bit
    for d in (3, 8):
        np.testing.assert_array_equal(
            np.asarray(out.beta[d]), np.asarray(trained_fleet.beta[d])
        )
        np.testing.assert_array_equal(
            np.asarray(out.p[d]), np.asarray(trained_fleet.p[d])
        )
    # participants merged exactly the participating sub-fleet (checked
    # against a hand-built dense masked mix on the all-to-all case)
    if topo.name == "all_to_all":
        from repro.fleet import fleet_from_uv, fleet_to_uv

        uv = fleet_to_uv(trained_fleet, ridge=RIDGE)
        mf = np.asarray(mask)[:, None, None]
        su = (np.asarray(uv.u) * mf).sum(0)
        sv = (np.asarray(uv.v) * mf).sum(0)
        from repro.core import UV

        ref = fleet_from_uv(
            trained_fleet,
            UV(u=jnp.broadcast_to(su, uv.u.shape), v=jnp.broadcast_to(sv, uv.v.shape)),
            ridge=RIDGE,
        )
        np.testing.assert_allclose(
            np.asarray(out.beta[0]), np.asarray(ref.beta[0]), rtol=1e-4, atol=1e-5
        )


@pytest.mark.parametrize("topo_fn", [f for _, f in TOPOLOGIES],
                         ids=[n for n, _ in TOPOLOGIES])
def test_masked_merge_kernel_matches_reference(trained_fleet, topo_fn):
    topo = topo_fn()
    mask = jnp.ones(D).at[1].set(0).at[6].set(0).at[7].set(0)
    ref = fleet_merge_masked(trained_fleet, topo, mask, ridge=RIDGE)
    out = fleet_merge_masked_kernel(trained_fleet, topo, mask, ridge=RIDGE)
    np.testing.assert_allclose(
        np.asarray(out.beta), np.asarray(ref.beta), rtol=1e-3, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(out.p), np.asarray(ref.p), rtol=1e-3, atol=1e-4
    )


def test_masked_segment_sum_kernel_matches_segment_sum():
    from repro.kernels.topology_merge import masked_segment_sum_mix

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(10, 8, 24)).astype(np.float32))
    cids = np.repeat(np.arange(4), [3, 3, 2, 2]).astype(np.int32)
    mask = jnp.asarray(rng.integers(0, 2, size=10).astype(np.float32))
    out = masked_segment_sum_mix(x, cids, mask, 4)
    ref = jax.ops.segment_sum(x * mask[:, None, None], jnp.asarray(cids), num_segments=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError, match="sorted"):
        masked_segment_sum_mix(x, cids[::-1].copy(), mask, 4)


# ------------------------------------------------------------------- governor


def test_governor_budget_defers_and_recovers():
    topo = star(16)
    gov = MergeGovernor(
        topo, H, 48,
        GovernorConfig(merge_every=4, budget_bytes_per_tick=None),
    )
    mask = np.ones(16, bool)
    assert not gov.decide(0, mask).merge      # off-cadence
    assert gov.decide(3, mask).merge          # cadence tick, no budget cap
    full = gov.round_bytes(16)

    tight = MergeGovernor(
        topo, H, 48,
        GovernorConfig(merge_every=4, budget_bytes_per_tick=full / 7.0),
    )
    d0 = tight.decide(3, mask)                # full/4 per tick > full/7 → defer
    assert not d0.merge and d0.reason == "budget"
    d1 = tight.decide(7, mask)                # full/8 per tick ≤ full/7 → merge
    assert d1.merge
    assert tight.state.deferred_budget == 1
    assert tight.state.bytes_spent == full
    # participation scales the admitted round's cost
    assert tight.round_bytes(8) == full // 2


def test_governor_min_participants():
    gov = MergeGovernor(star(8), H, 48, GovernorConfig(merge_every=1, min_participants=3))
    d = gov.decide(0, np.asarray([True, True] + [False] * 6))
    assert not d.merge and d.reason == "participants"


# ------------------------------------------------------------------ staleness


def test_staleness_schedule_validation():
    with pytest.raises(ValueError, match=">= 0"):
        StalenessSchedule(np.asarray([0, -1, 2]))
    with pytest.raises(ValueError, match="vector"):
        StalenessSchedule(np.zeros((2, 2), np.int32))


def test_lagged_gather_rejects_short_history():
    hist = jnp.zeros((2, 4, 3, 3))
    with pytest.raises(ValueError, match="history"):
        _lagged_gather(hist, jnp.asarray([0, 1, 2, 0]), 5)
    # in-range lags pass
    _lagged_gather(hist, jnp.asarray([0, 1, 1, 0]), 5)


def test_fleet_train_async_history_validation(trained_fleet):
    xs = jnp.zeros((D, 8, trained_fleet.params.alpha.shape[0]))
    sched = StalenessSchedule.uniform(D, 2)
    with pytest.raises(ValueError, match="history"):
        fleet_train_async(
            trained_fleet, xs, star(D), sched, rounds=2, ridge=RIDGE, history=2
        )


# ------------------------------------------------------------------ partition


def test_drift_schedule_targets_and_homes():
    drift = random_drift_schedule(
        24, 80, 3, frac=0.5, seed=0, home_classes=2, targets=(2,)
    )
    assert len(drift) == 12
    for ev in drift:
        assert ev.new_pattern == 2
        assert 20 <= ev.step < 60


def test_make_fleet_streams_n_assign():
    ds = make_har_dataset(seed=0, samples_per_class=60, n_features=48)
    sub = ds.y < 3
    ds3 = AnomalyDataset(ds.name, ds.x[sub], ds.y[sub], ds.class_names[:3])
    drift = (DriftEvent(device=1, step=10, new_pattern=2),)
    fs = make_fleet_streams(ds3, 4, 20, n_init=4, drift=drift, seed=0, n_assign=2)
    # homes round-robin over the first 2 patterns only...
    for d in range(4):
        assert fs.initial_pattern(d) == d % 2
    assert (fs.pattern_of_device[[0, 2, 3]] < 2).all()
    # ...while drift may target the held-out pattern 2
    assert (fs.pattern_of_device[1, 10:] == 2).all()
    with pytest.raises(ValueError, match="n_assign"):
        make_fleet_streams(ds3, 4, 20, n_assign=9)


# -------------------------------------------------------------------- runtime


def _har3():
    # full-width HAR: the reduced feature grids cap the achievable AUC
    # well below the level the gating claim is asserted against
    ds = make_har_dataset(seed=0, samples_per_class=100)
    lo, hi = ds.x.min(0), ds.x.max(0)
    ds = ds._replace(x=((ds.x - lo) / (hi - lo + 1e-6)).astype(np.float32))
    train, test = train_test_split(ds, 0.8, seed=0)

    def sub(d):
        m = d.y < 3
        return AnomalyDataset(d.name, d.x[m], d.y[m], d.class_names[:3])

    return sub(train), sub(test)


@pytest.fixture(scope="module")
def drift_scenario():
    """16 devices, 120 ticks, 4 devices drift to the held-out pattern.

    Drift lands mid-soak (ticks 50–66), late enough that the quarantine
    governs several of the remaining merge rounds. That window is what
    the gating claim is about: once a drifted device re-converges and
    is re-admitted, its payload is legitimately shared (the paper's
    concept-following) and gated / ungated fleets converge again — the
    protection is the span between detection and re-admission."""
    train3, test3 = _har3()
    ticks, batch = 120, 2
    steps = ticks * batch
    drift = tuple(
        DriftEvent(device=d, step=100 + 11 * i, new_pattern=2)
        for i, d in enumerate((2, 5, 8, 14))
    )
    fs = make_fleet_streams(
        train3, 16, steps, n_init=2 * H_RT, drift=drift, seed=0, n_assign=2
    )
    x_eval, y_eval = anomaly_eval_arrays(test3, [0, 1], anomaly_ratio=0.3, seed=0)
    return train3, fs, jnp.asarray(x_eval), y_eval, batch


def _run_runtime(fs, n_features, batch, *, gate, **cfg_kw):
    fleet = init_fleet(
        jax.random.PRNGKey(0), fs.n_devices, n_features, H_RT, fs.x_init,
        activation="identity", ridge=RIDGE,
    )
    cfg = RuntimeConfig(
        topology=ring(fs.n_devices, hops=2), ridge=RIDGE,
        governor=GovernorConfig(merge_every=16), gate_merges=gate, **cfg_kw,
    )
    rt = FleetRuntime(fleet, cfg)
    rt.run(TickFeed(fs, batch))
    return rt


def test_runtime_quarantine_recovers_post_merge_auc(drift_scenario):
    """The ROADMAP's drift-adaptive-selection claim, quantified: with
    quarantine the clean devices' post-merge AUC against the drifted
    concept beats the merge-everyone baseline."""
    train3, fs, x_eval, y_eval, batch = drift_scenario
    gated = _run_runtime(fs, train3.n_features, batch, gate=True)
    ungated = _run_runtime(fs, train3.n_features, batch, gate=False)

    drifted_devs = {ev.device for ev in fs.drift}
    clean = [d for d in range(fs.n_devices) if d not in drifted_devs]

    def clean_auc(rt):
        scores = np.asarray(fleet_score(rt.states, x_eval))
        return float(np.mean([roc_auc(scores[d], y_eval) for d in clean]))

    auc_gated, auc_ungated = clean_auc(gated), clean_auc(ungated)
    assert auc_gated > auc_ungated, (auc_gated, auc_ungated)
    # sanity floor for this small fixture; the absolute >0.9 claim is
    # asserted at D=256 scale by benchmarks/serve_runtime.py
    assert auc_gated > 0.8
    # gated run detected every injected drift, flagged nobody else
    flagged = {dev for _, dev in gated.detections}
    assert flagged == drifted_devs
    # and quarantined rounds shipped fewer bytes
    assert gated.governor.state.bytes_spent < ungated.governor.state.bytes_spent


def test_runtime_compile_once(drift_scenario):
    train3, fs, _, _, batch = drift_scenario
    rt = _run_runtime(fs, train3.n_features, batch, gate=True)
    sizes = rt.assert_compile_once()
    assert all(v == 1 for v in sizes.values())


def test_runtime_snapshot_restore_roundtrip(tmp_path, drift_scenario):
    train3, fs, _, _, batch = drift_scenario

    def fresh(snapdir):
        fleet = init_fleet(
            jax.random.PRNGKey(0), fs.n_devices, train3.n_features, H_RT, fs.x_init,
            activation="identity", ridge=RIDGE,
        )
        cfg = RuntimeConfig(
            topology=ring(fs.n_devices, hops=2), ridge=RIDGE,
            governor=GovernorConfig(merge_every=16),
            snapshot_every=20, snapshot_dir=snapdir,
        )
        return FleetRuntime(fleet, cfg)

    rt = fresh(tmp_path)
    feed = TickFeed(fs, batch)
    rt.run(feed, ticks=40)
    rt.snapshot()

    rt2 = fresh(tmp_path)
    assert rt2.restore() == 40
    np.testing.assert_array_equal(np.asarray(rt2.states.beta), np.asarray(rt.states.beta))
    np.testing.assert_array_equal(np.asarray(rt2.det.ewma), np.asarray(rt.det.ewma))
    np.testing.assert_array_equal(
        np.asarray(rt2.det.drifted), np.asarray(rt.det.drifted)
    )
    assert rt2.tick_no == rt.tick_no
    assert rt2.governor.state.merges == rt.governor.state.merges
    assert rt2.governor.state.bytes_spent == rt.governor.state.bytes_spent
    # the restored runtime continues ticking where the original left off
    rep = rt2.tick(feed.tick_batch(40))
    assert rep.tick == 40


def test_runtime_stale_zero_lag_matches_fresh(drift_scenario):
    """A staleness-aware runtime with all-zero lags reproduces the fresh
    masked-merge path exactly (same invariant as fleet_train_async)."""
    train3, fs, _, _, batch = drift_scenario
    fresh_rt = _run_runtime(fs, train3.n_features, batch, gate=True)
    stale_rt = _run_runtime(
        fs, train3.n_features, batch, gate=True,
        staleness=StalenessSchedule.uniform(fs.n_devices, 0),
    )
    np.testing.assert_allclose(
        np.asarray(stale_rt.states.beta), np.asarray(fresh_rt.states.beta),
        rtol=1e-4, atol=1e-5,
    )
    assert stale_rt.assert_compile_once()


def test_runtime_lagged_merges_stay_finite(drift_scenario):
    train3, fs, _, _, batch = drift_scenario
    rt = _run_runtime(
        fs, train3.n_features, batch, gate=True,
        staleness=StalenessSchedule.random(fs.n_devices, max_lag=2, seed=1),
    )
    assert bool(jnp.isfinite(rt.states.beta).all())
    assert rt.governor.state.merges > 0


# ---------------------------------------------------------------------- feed


def test_tick_feed_shapes_and_drift_ticks():
    train3, _ = _har3()
    drift = (DriftEvent(device=1, step=13, new_pattern=2),)
    fs = make_fleet_streams(train3, 4, 26, n_init=4, drift=drift, seed=0, n_assign=2)
    feed = TickFeed(fs, batch=4)
    assert feed.n_ticks == 6  # 26 // 4, tail dropped
    assert feed.tick_batch(0).shape == (4, 4, train3.n_features)
    assert feed.drift_ticks() == {1: 3}  # step 13 → tick 3
    with pytest.raises(IndexError):
        feed.tick_batch(6)
    with pytest.raises(ValueError):
        TickFeed(fs, batch=0)
    with pytest.raises(ValueError):
        TickFeed(fs, batch=27)


def test_tick_feed_truncated_tail_drift(caplog):
    """A drift event scheduled entirely in the dropped tail (steps=26,
    batch=4 → ticks 0..5 serve steps [0, 24); step 25 is never dealt)
    must be excluded from ground truth — not mapped to a phantom tick —
    and the device reported as truncated so detection accounting skips
    it in every denominator."""
    import logging

    train3, _ = _har3()
    drift = (
        DriftEvent(device=1, step=13, new_pattern=2),   # tick 3: served
        DriftEvent(device=2, step=25, new_pattern=2),   # tail: never served
    )
    fs = make_fleet_streams(
        train3, 4, 26, n_init=4, drift=drift, seed=0, n_assign=2
    )
    feed = TickFeed(fs, batch=4)
    assert feed.n_ticks == 6
    assert feed.truncated_drift_devices == frozenset({2})
    with caplog.at_level(logging.WARNING, logger="repro.runtime.feed"):
        ticks = feed.drift_ticks()
        feed.drift_ticks()  # warned once, not per call
    assert ticks == {1: 3}  # device 2 absent — NOT {2: 6}
    warned = [r for r in caplog.records if "truncated tail" in r.message]
    assert len(warned) == 1 and "[2]" in warned[0].getMessage()
    # a device with one tail event and one served event is NOT truncated
    fs2 = make_fleet_streams(
        train3, 4, 26, n_init=4, seed=0, n_assign=2, drift=(
            DriftEvent(device=2, step=9, new_pattern=2),
            DriftEvent(device=2, step=25, new_pattern=1),
        ),
    )
    feed2 = TickFeed(fs2, batch=4)
    assert feed2.truncated_drift_devices == frozenset()
    assert feed2.drift_ticks() == {2: 2}
    # detection_stats: flags on the truncated device are neither
    # detections nor false positives; its drift is not "missed"
    from repro.scenarios import detection_stats

    stats = detection_stats(
        [(4, 1), (5, 2)], ticks,
        truncated_devices=feed.truncated_drift_devices,
    )
    assert stats["delays"] == [1]          # device 1 caught at tick 4
    assert stats["false_positives"] == []  # device 2's flag doesn't count
    assert stats["missed"] == []
    assert stats["truncated_drift_devices"] == [2]


def test_runtime_rejects_mismatched_topology(drift_scenario):
    train3, fs, _, _, _ = drift_scenario
    fleet = init_fleet(
        jax.random.PRNGKey(0), fs.n_devices, train3.n_features, H_RT, fs.x_init,
        activation="identity", ridge=RIDGE,
    )
    with pytest.raises(ValueError, match="topology"):
        FleetRuntime(fleet, RuntimeConfig(topology=ring(fs.n_devices + 1, hops=1)))


def test_detector_config_frozen():
    cfg = DetectorConfig()
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.alpha = 0.5


# ------------------------------------------------- serving hooks (PR 9)


def _mini_runtime(merge_every=4, d=8, f=6, h=4):
    rng = np.random.default_rng(0)
    x_init = rng.normal(size=(d, 2 * h, f)).astype(np.float32)
    fleet = init_fleet(
        jax.random.PRNGKey(0), d, f, h, x_init,
        activation="identity", ridge=RIDGE,
    )
    return FleetRuntime(fleet, RuntimeConfig(
        topology=ring(d, hops=1),
        governor=GovernorConfig(merge_every=merge_every),
    ))


def _mini_batch(t, d=8, f=6, b=3):
    rng = np.random.default_rng(100 + t)
    return rng.normal(size=(d, b, f)).astype(np.float32)


def test_tick_served_mask_freezes_unserved_devices():
    """Devices outside the served mask keep model AND detector state
    bit-for-bit — a padded window row must not leak into their update.
    (Merge rounds legitimately touch every device, so keep them out of
    frame with a long cadence.)"""
    rt = _mini_runtime(merge_every=100)
    for t in range(3):
        rt.tick(_mini_batch(t))
    beta0 = np.asarray(rt.states.beta).copy()
    ewma0 = np.asarray(rt.det.ewma).copy()

    served = np.ones(8, bool)
    served[[2, 5]] = False
    rep = rt.tick(_mini_batch(3), served=served)

    beta1, ewma1 = np.asarray(rt.states.beta), np.asarray(rt.det.ewma)
    for dev in (2, 5):  # unserved: frozen exactly
        np.testing.assert_array_equal(beta1[dev], beta0[dev])
        np.testing.assert_array_equal(ewma1[dev], ewma0[dev])
    served_devs = np.flatnonzero(served)
    assert not np.array_equal(beta1[served_devs], beta0[served_devs])
    np.testing.assert_array_equal(np.asarray(rep.served), served)


def test_tick_all_served_equals_default_path():
    rt_a, rt_b = _mini_runtime(), _mini_runtime()
    for t in range(4):
        rt_a.tick(_mini_batch(t))
        rt_b.tick(_mini_batch(t), served=np.ones(8, bool))
    np.testing.assert_array_equal(
        np.asarray(rt_a.states.beta), np.asarray(rt_b.states.beta)
    )
    np.testing.assert_array_equal(
        np.asarray(rt_a.det.ewma), np.asarray(rt_b.det.ewma)
    )


def test_tick_allow_merge_veto_defers_candidate_rounds():
    """allow_merge=False (the degraded ladder's skip-merge rung) vetoes
    every governor-candidate round and books it as a degraded deferral."""
    vetoed = _mini_runtime(merge_every=2)
    for t in range(8):
        vetoed.tick(_mini_batch(t), allow_merge=False)
    assert vetoed.governor.state.merges == 0
    assert vetoed.governor.state.deferred_degraded == 4  # ticks 2,4,6,8

    normal = _mini_runtime(merge_every=2)
    for t in range(8):
        normal.tick(_mini_batch(t))
    assert normal.governor.state.merges == 4
    assert normal.governor.state.deferred_degraded == 0


def test_tick_batch_validation_errors():
    rt = _mini_runtime()
    with pytest.raises(ValueError, match="n_devices=8"):
        rt.tick(np.zeros((7, 3, 6), np.float32))  # wrong device count
    with pytest.raises(ValueError, match="n_devices=8"):
        rt.tick(np.zeros((8, 6), np.float32))  # missing batch axis
    with pytest.raises(ValueError, match="all-shed"):
        rt.tick(np.zeros((8, 0, 6), np.float32))  # B=0 window
    with pytest.raises(ValueError, match=r"served mask must be \(8,\)"):
        rt.tick(_mini_batch(0), served=np.ones(5, bool))


def test_runtime_run_truncates_exhausted_feed(caplog):
    """Asking run() for more ticks than the feed holds processes what
    exists and warns, instead of raising mid-soak."""
    train3, _ = _har3()
    fs = make_fleet_streams(train3, 4, 24, n_init=4, seed=0, n_assign=2)
    feed = TickFeed(fs, batch=4)  # 6 ticks
    fleet = init_fleet(
        jax.random.PRNGKey(0), 4, train3.n_features, H_RT, fs.x_init,
        activation="identity", ridge=RIDGE,
    )
    rt = FleetRuntime(fleet, RuntimeConfig(
        topology=ring(4, hops=1), governor=GovernorConfig(merge_every=16),
    ))
    with caplog.at_level("WARNING", logger="repro.runtime.runtime"):
        reports = rt.run(feed, ticks=50)
    assert len(reports) == feed.n_ticks == 6
    assert rt.tick_no == 6
    assert "truncating" in caplog.text
