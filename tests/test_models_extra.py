"""Extra model-substrate tests: attention equivalences, MoE dispatch
parity, GLA engine properties, loss chunking invariance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.layers import (
    blockwise_attention,
    blockwise_attention_fwd_only,
    local_attention,
    rope_tables,
    apply_rope,
)
from repro.models.moe import moe_ffn
from repro.models.ssm import chunked_linear_attention, linear_attention_decode_step


def _naive_attn(q, k, v, causal=True, window=0):
    b, s, h, hd = q.shape
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    qs = jnp.arange(s)[:, None]
    ks = jnp.arange(k.shape[1])[None, :]
    m = jnp.ones((s, k.shape[1]), bool)
    if causal:
        m = m & (ks <= qs)
    if window:
        m = m & (qs - ks < window)
    sc = jnp.where(m[None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(min_value=8, max_value=160),
    st.integers(min_value=8, max_value=96),
    st.booleans(),
)
def test_blockwise_attention_property(s, chunk, causal):
    ks = jax.random.split(jax.random.PRNGKey(s * 7 + chunk), 3)
    q = jax.random.normal(ks[0], (1, s, 2, 16))
    k = jax.random.normal(ks[1], (1, s, 2, 16))
    v = jax.random.normal(ks[2], (1, s, 2, 16))
    out = blockwise_attention(q, k, v, causal=causal, chunk=chunk)
    ref = _naive_attn(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(min_value=16, max_value=160),
    st.integers(min_value=4, max_value=64),
)
def test_local_attention_property(s, w):
    ks = jax.random.split(jax.random.PRNGKey(s * 13 + w), 3)
    q = jax.random.normal(ks[0], (1, s, 2, 16))
    k = jax.random.normal(ks[1], (1, s, 2, 16))
    v = jax.random.normal(ks[2], (1, s, 2, 16))
    out = local_attention(q, k, v, window=w)
    ref = _naive_attn(q, k, v, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)


def test_flash_vjp_matches_fwd_only():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (2, 96, 2, 32))
    k = jax.random.normal(ks[1], (2, 96, 2, 32))
    v = jax.random.normal(ks[2], (2, 96, 2, 32))
    a = blockwise_attention(q, k, v, chunk=32)
    b = blockwise_attention_fwd_only(q, k, v, chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_flash_grad_vs_naive():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (1, 80, 2, 16))
    k = jax.random.normal(ks[1], (1, 80, 2, 16))
    v = jax.random.normal(ks[2], (1, 80, 2, 16))
    g1 = jax.grad(lambda q, k, v: jnp.sum(jnp.tanh(
        blockwise_attention(q, k, v, chunk=32))), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda q, k, v: jnp.sum(jnp.tanh(
        _naive_attn(q, k, v))), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-5)


def test_rope_relative_property():
    """RoPE: ⟨q_m, k_n⟩ depends only on m − n."""
    cos, sin = rope_tables(32, 16, 10000.0)
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))

    def dot_at(m, n):
        qm = apply_rope(q, cos[m:m + 1], sin[m:m + 1])
        kn = apply_rope(k, cos[n:n + 1], sin[n:n + 1])
        return float(jnp.sum(qm * kn))

    assert abs(dot_at(5, 2) - dot_at(13, 10)) < 1e-4
    assert abs(dot_at(0, 0) - dot_at(20, 20)) < 1e-4


# ---------------------------------------------------------------- MoE


def _moe_params(key, d=32, e=8, f=16):
    ks = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(ks[0], (d, e)) * 0.1,
        "w_gate": jax.random.normal(ks[1], (e, d, f)) * 0.1,
        "w_up": jax.random.normal(ks[2], (e, d, f)) * 0.1,
        "w_down": jax.random.normal(ks[3], (e, f, d)) * 0.1,
    }


def test_moe_dispatch_parity():
    """scatter and einsum dispatch implement identical capacity routing."""
    p = _moe_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    y1, a1 = moe_ffn(p, x, n_experts=8, top_k=2, tokens_per_group=32, dispatch="einsum")
    y2, a2 = moe_ffn(p, x, n_experts=8, top_k=2, tokens_per_group=32, dispatch="scatter")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(a1["lb_loss"]), float(a2["lb_loss"]), rtol=1e-5)


def test_moe_capacity_drops_tokens():
    """With capacity_factor ≪ 1 tokens get dropped and the output shrinks."""
    p = _moe_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
    _, a_small = moe_ffn(p, x, n_experts=8, top_k=2, tokens_per_group=64,
                         capacity_factor=0.25, dispatch="einsum")
    _, a_big = moe_ffn(p, x, n_experts=8, top_k=2, tokens_per_group=64,
                       capacity_factor=4.0, dispatch="einsum")
    assert float(a_small["dropped_frac"]) > 0.0
    assert float(a_big["dropped_frac"]) == 0.0


def test_moe_lb_loss_penalizes_imbalance():
    p = _moe_params(jax.random.PRNGKey(0))
    # collapse routing to expert 0
    p_collapsed = dict(p, router=p["router"] * 0.0 + jnp.eye(32, 8) * 50.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
    _, a_bal = moe_ffn(p, x, n_experts=8, top_k=2, tokens_per_group=64)
    _, a_col = moe_ffn(p_collapsed, x, n_experts=8, top_k=2, tokens_per_group=64)
    assert float(a_col["lb_loss"]) > float(a_bal["lb_loss"])


# ------------------------------------------------------------- GLA engine


@settings(max_examples=8, deadline=None)
@given(
    st.integers(min_value=4, max_value=80),
    st.integers(min_value=2, max_value=40),
)
def test_gla_chunk_invariance(s, chunk):
    """Same result for every chunk size (the chunked factorization is
    exact, not an approximation)."""
    ks = jax.random.split(jax.random.PRNGKey(s * 31 + chunk), 4)
    q = jax.random.normal(ks[0], (1, s, 2, 8))
    k = jax.random.normal(ks[1], (1, s, 2, 8))
    v = jax.random.normal(ks[2], (1, s, 2, 8))
    log_a = -jax.nn.softplus(jax.random.normal(ks[3], (1, s, 2)))
    y1, s1 = chunked_linear_attention(q, k, v, log_a, chunk=chunk)
    y2, s2 = chunked_linear_attention(q, k, v, log_a, chunk=s)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4, atol=2e-5)


def test_gla_decode_continues_prefill():
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    s0 = 40
    q = jax.random.normal(ks[0], (1, s0 + 3, 2, 8))
    k = jax.random.normal(ks[1], (1, s0 + 3, 2, 8))
    v = jax.random.normal(ks[2], (1, s0 + 3, 2, 8))
    log_a = -jax.nn.softplus(jax.random.normal(ks[3], (1, s0 + 3, 2)))
    y_full, _ = chunked_linear_attention(q, k, v, log_a, chunk=16)
    _, state = chunked_linear_attention(
        q[:, :s0], k[:, :s0], v[:, :s0], log_a[:, :s0], chunk=16
    )
    for t in range(s0, s0 + 3):
        state, y_t = linear_attention_decode_step(
            state, q[:, t], k[:, t], v[:, t], log_a[:, t]
        )
    np.testing.assert_allclose(
        np.asarray(y_t), np.asarray(y_full[:, -1]), rtol=1e-4, atol=1e-5
    )


# ------------------------------------------------------------ loss chunking


def test_lm_loss_chunk_invariance():
    from repro.configs import get_config
    from repro.models import init_params, lm_loss

    cfg = get_config("granite-3-2b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    l1, _ = lm_loss(params, cfg, tokens, tokens, loss_chunk=16)
    l2, _ = lm_loss(params, cfg, tokens, tokens, loss_chunk=64)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
