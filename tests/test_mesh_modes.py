"""Tests for the mesh federation's periodic-merge mode and the
single-device degenerate cases (no multi-device requirement)."""
import jax
import numpy as np

from repro.core import (
    init_oselm,
    init_slfn,
    oselm_loss,
    oselm_train_sequential,
    to_uv,
    from_uv,
)
from repro.federated import mesh_cooperative_update, mesh_federated_train


def _mesh1():
    return jax.make_mesh((1,), ("data",))


def test_mesh_train_single_shard_equals_sequential():
    """On a 1-device mesh the federated train (merge at end) equals plain
    sequential training followed by a U/V round-trip."""
    mesh = _mesh1()
    params = init_slfn(jax.random.PRNGKey(0), 16, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (80, 16))
    st = init_oselm(params, x[:32], x[:32], activation="sigmoid", ridge=1e-3)
    stacked = jax.tree.map(lambda l: l[None], st)
    xs = x[32:][None]

    merged = mesh_federated_train(stacked, xs, mesh, ("data",), ridge=1e-3)
    ref = oselm_train_sequential(st, x[32:], x[32:])
    ref = from_uv(ref, to_uv(ref, ridge=1e-3), ridge=1e-3)
    np.testing.assert_allclose(
        np.asarray(merged.beta[0]), np.asarray(ref.beta), rtol=5e-2, atol=5e-3
    )


def test_mesh_train_periodic_merge_mode():
    """merge_every chunks the stream and merges after each chunk — the
    paper's 'repeatedly applied to synchronize' mode. On one shard the
    result must stay consistent with end-only merging."""
    mesh = _mesh1()
    params = init_slfn(jax.random.PRNGKey(0), 16, 8)
    x = jax.random.normal(jax.random.PRNGKey(2), (96, 16))
    st = init_oselm(params, x[:32], x[:32], activation="identity", ridge=1e-3)
    stacked = jax.tree.map(lambda l: l[None], st)
    xs = x[32:][None]  # 64 steps

    periodic = mesh_federated_train(
        stacked, xs, mesh, ("data",), merge_every=16, ridge=1e-3
    )
    oneshot = mesh_federated_train(stacked, xs, mesh, ("data",), ridge=1e-3)
    l1 = float(oselm_loss(
        jax.tree.map(lambda l: l[0], periodic), x[:16], x[:16]).mean())
    l2 = float(oselm_loss(
        jax.tree.map(lambda l: l[0], oneshot), x[:16], x[:16]).mean())
    # repeated self-merge with ridge re-regularizes but must stay close
    assert abs(l1 - l2) < 0.1 * max(l2, 0.05)


def test_mesh_merge_idempotent_on_one_shard():
    mesh = _mesh1()
    params = init_slfn(jax.random.PRNGKey(0), 16, 8)
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 16))
    st = init_oselm(params, x, x, activation="sigmoid", ridge=1e-3)
    stacked = jax.tree.map(lambda l: l[None], st)
    m1 = mesh_cooperative_update(stacked, mesh, ("data",), ridge=0.0)
    m2 = mesh_cooperative_update(m1, mesh, ("data",), ridge=0.0)
    np.testing.assert_allclose(
        np.asarray(m1.beta), np.asarray(m2.beta), rtol=1e-3, atol=1e-4
    )
