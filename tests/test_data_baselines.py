"""Tests for the data substrate and the BP-NN / FedAvg baselines."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import (
    bpnn3_config,
    bpnn5_config,
    bpnn_score,
    init_bpnn,
    run_fedavg,
    train_bpnn,
)
from repro.baselines.fedavg import FedAvgConfig, average_params
from repro.data import (
    make_driving_dataset,
    make_har_dataset,
    make_mnist_like_dataset,
)
from repro.data.metrics import roc_auc
from repro.data.pipeline import (
    anomaly_eval_arrays,
    make_pattern_stream,
    make_sharded_streams,
    train_test_split,
)


def test_dataset_shapes_match_paper_table2():
    d = make_driving_dataset(seed=0, samples_per_class=20)
    assert d.n_features == 225 and d.n_classes == 3
    h = make_har_dataset(seed=0, samples_per_class=20)
    assert h.n_features == 561 and h.n_classes == 6
    m = make_mnist_like_dataset(seed=0, samples_per_class=20)
    assert m.n_features == 784 and m.n_classes == 10
    assert m.x.min() >= 0.0 and m.x.max() <= 1.0  # paper: normalized /255


def test_driving_patterns_distinguishable():
    d = make_driving_dataset(seed=0, samples_per_class=50)
    normal = d.pattern("normal")
    aggr = d.pattern("aggressive")
    # centroid distance dwarfs intra-class spread
    dist = np.linalg.norm(normal.mean(0) - aggr.mean(0))
    spread = np.linalg.norm(normal - normal.mean(0), axis=1).mean()
    assert dist > 0.3 * spread


def test_transition_tables_are_row_normalized():
    d = make_driving_dataset(seed=1, samples_per_class=10)
    tables = d.x.reshape(-1, 15, 15)
    rows = tables.sum(axis=2)
    assert ((np.abs(rows - 1.0) < 1e-5) | (rows == 0.0)).all()


def test_split_and_eval_protocol():
    h = make_har_dataset(seed=0, samples_per_class=50)
    tr, te = train_test_split(h, 0.8, seed=0)
    assert len(tr.x) == 6 * 40 and len(te.x) == 6 * 10
    x, y = anomaly_eval_arrays(te, [0, 3], anomaly_ratio=0.1, seed=0)
    n_norm = (y == 0).sum()
    n_anom = (y == 1).sum()
    assert n_anom == max(1, int(n_norm * 0.1))


def test_pattern_stream_and_shards():
    h = make_har_dataset(seed=0, samples_per_class=30)
    s = make_pattern_stream(h, "laying", seed=0, limit=10)
    assert s.shape == (10, 561)
    sh = make_sharded_streams(h, 4, 20, seed=0)
    assert sh.xs.shape == (4, 20, 561)
    assert list(sh.pattern_of_shard) == [0, 1, 2, 3]


def test_roc_auc_metric():
    scores = np.array([0.1, 0.2, 0.3, 0.9, 0.8, 0.95])
    labels = np.array([0, 0, 0, 1, 1, 1])
    assert roc_auc(scores, labels) == 1.0
    assert abs(roc_auc(-scores, labels)) < 1e-9
    rng = np.random.default_rng(0)
    s = rng.normal(size=2000)
    l = rng.integers(0, 2, size=2000)
    assert abs(roc_auc(s, l) - 0.5) < 0.05
    # ties get half credit
    assert roc_auc(np.zeros(10), np.array([0] * 5 + [1] * 5)) == 0.5


def test_bpnn3_learns_and_detects():
    h = make_har_dataset(seed=0, samples_per_class=60)
    # normalize into (0,1) for the sigmoid output (paper standardizes HAR)
    lo, hi = h.x.min(0), h.x.max(0)
    xn = (h.x - lo) / (hi - lo + 1e-6)
    normal = xn[h.y == 3]
    cfg = bpnn3_config(561, 64, batch=8, epochs=5)
    params = train_bpnn(jax.random.PRNGKey(0), cfg, jnp.asarray(normal))
    s_norm = float(bpnn_score(params, cfg, jnp.asarray(normal[:32])).mean())
    anom = xn[h.y == 5][:32]
    s_anom = float(bpnn_score(params, cfg, jnp.asarray(anom)).mean())
    assert s_anom > 1.5 * s_norm


def test_bpnn5_shapes():
    cfg = bpnn5_config(100, 32, 16, 32, batch=4, epochs=1)
    params = init_bpnn(jax.random.PRNGKey(0), cfg)
    assert [p["w"].shape for p in params] == [(100, 32), (32, 16), (16, 32), (32, 100)]
    x = jax.random.uniform(jax.random.PRNGKey(1), (12, 100))
    out = train_bpnn(jax.random.PRNGKey(2), cfg, x)
    s = bpnn_score(out, cfg, x)
    assert s.shape == (12,) and np.isfinite(np.asarray(s)).all()


def test_average_params_is_mean():
    a = [{"w": jnp.ones((2, 2)), "b": jnp.zeros(2)}]
    b = [{"w": jnp.zeros((2, 2)), "b": jnp.ones(2) * 2}]
    avg = average_params([a, b])
    np.testing.assert_allclose(np.asarray(avg[0]["w"]), 0.5)
    np.testing.assert_allclose(np.asarray(avg[0]["b"]), 1.0)


def test_fedavg_two_clients_covers_both_patterns():
    """BP-NN3-FL: after R rounds the global model reconstructs both
    clients' patterns (the paper's FL baseline behavior)."""
    h = make_har_dataset(seed=0, samples_per_class=60)
    lo, hi = h.x.min(0), h.x.max(0)
    xn = (h.x - lo) / (hi - lo + 1e-6)
    c1 = jnp.asarray(xn[h.y == 3][:48])
    c2 = jnp.asarray(xn[h.y == 5][:48])
    cfg = bpnn3_config(561, 64, batch=8, epochs=1)
    params = run_fedavg(
        jax.random.PRNGKey(0), cfg, [c1, c2], FedAvgConfig(rounds=8, local_epochs=1)
    )
    s1 = float(bpnn_score(params, cfg, c1).mean())
    s2 = float(bpnn_score(params, cfg, c2).mean())
    anom = jnp.asarray(xn[h.y == 0][:32])
    sa = float(bpnn_score(params, cfg, anom).mean())
    assert sa > s1 and sa > s2
