"""Per-kernel correctness: sweep shapes/dtypes, assert allclose vs the
ref.py pure-jnp oracles (kernels run interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import init_oselm, init_slfn, oselm_step_k1
from repro.kernels import (
    hidden_proj,
    matmul_atb,
    oselm_step_k1_kernel,
    rank1_add,
    uv_accum,
)
from repro.kernels.ref import (
    atb_ref,
    hidden_proj_ref,
    oselm_step_k1_ref,
    rank1_add_ref,
)

KEY = jax.random.PRNGKey(0)


def rnd(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape)
    return x.astype(dtype)


SHAPES_MM = [
    (8, 16, 8),        # tiny, heavy padding
    (64, 64, 64),
    (128, 128, 128),   # exactly one tile
    (200, 150, 100),   # ragged
    (256, 384, 128),   # multi-tile
    (33, 257, 129),    # off-by-one everywhere
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("m,k,n", SHAPES_MM)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("act", ["sigmoid", "identity", "relu"])
def test_hidden_proj_matches_ref(m, k, n, dtype, act):
    x = rnd(1, (m, k), dtype)
    a = rnd(2, (k, n), dtype)
    b = rnd(3, (n,), dtype)
    got = hidden_proj(x, a, b, activation=act, interpret=True)
    want = hidden_proj_ref(x, a, b, act)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("k,n1,n2", SHAPES_MM)
@pytest.mark.parametrize("dtype", DTYPES)
def test_matmul_atb_matches_ref(k, n1, n2, dtype):
    a = rnd(4, (k, n1), dtype)
    b = rnd(5, (k, n2), dtype)
    got = matmul_atb(a, b, interpret=True)
    want = atb_ref(a, b)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("k,n", [(50, 40), (128, 128), (300, 64), (64, 300)])
def test_uv_accum_is_spd_and_matches(k, n):
    h = rnd(6, (k, n), jnp.float32)
    t = rnd(7, (k, 24), jnp.float32)
    u, v = uv_accum(h, t, interpret=True)
    np.testing.assert_allclose(np.asarray(u), np.asarray(atb_ref(h, h)), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(v), np.asarray(atb_ref(h, t)), rtol=1e-4, atol=1e-4)
    w = np.linalg.eigvalsh(np.asarray(u))
    assert w.min() > -1e-3  # PSD up to roundoff


@pytest.mark.parametrize("n1,n2", [(16, 16), (128, 128), (100, 60), (257, 129), (8, 512)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_rank1_add_matches_ref(n1, n2, dtype):
    x = rnd(8, (n1, n2), dtype)
    u = rnd(9, (n1,), dtype)
    v = rnd(10, (n2,), dtype)
    got = rank1_add(x, u, v, -0.37, interpret=True)
    want = rank1_add_ref(x, u, v, -0.37)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("n,nh,m", [(24, 12, 24), (100, 40, 100), (561, 128, 561)])
def test_oselm_step_kernel_vs_math_ref(n, nh, m):
    """Fused kernel step == ref.py closed form == core.oselm step."""
    params = init_slfn(KEY, n, nh)
    x0 = rnd(11, (2 * nh, n), jnp.float32)
    st = init_oselm(params, x0, x0, activation="sigmoid", ridge=1e-4)
    x = rnd(12, (n,), jnp.float32)

    got = oselm_step_k1_kernel(st, x, x, interpret=True)
    want = oselm_step_k1(st, x, x)
    np.testing.assert_allclose(np.asarray(got.p), np.asarray(want.p), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got.beta), np.asarray(want.beta), rtol=1e-3, atol=1e-4)

    # and against the standalone closed-form oracle
    from repro.core.elm import hidden as hidden_fn
    h = hidden_fn(params, x[None, :], "sigmoid")[0]
    p_ref, b_ref = oselm_step_k1_ref(st.p, st.beta, h, x)
    np.testing.assert_allclose(np.asarray(got.p), np.asarray(p_ref), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got.beta), np.asarray(b_ref), rtol=1e-3, atol=1e-4)


def test_kernel_blockspec_tile_variants():
    """Same result across block shapes (tiling must not change math)."""
    x = rnd(13, (70, 90), jnp.float32)
    a = rnd(14, (90, 50), jnp.float32)
    b = rnd(15, (50,), jnp.float32)
    base = hidden_proj(x, a, b, activation="tanh", interpret=True)
    for bm, bn, bk in [(8, 128, 128), (128, 256, 8), (16, 128, 32)]:
        alt = hidden_proj(x, a, b, activation="tanh", bm=bm, bn=bn, bk=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(alt), np.asarray(base), rtol=1e-5, atol=1e-5)


# ------------------------------------------------------- flash attention


@pytest.mark.parametrize("s,cq,ck,causal", [
    (64, 32, 32, True), (128, 128, 128, True),
    (200, 64, 128, False), (96, 128, 32, True), (33, 16, 16, True),
])
def test_flash_attention_matches_blockwise(s, cq, ck, causal):
    from repro.kernels.flash_attn import flash_attention
    from repro.models.layers import blockwise_attention_fwd_only

    ks = jax.random.split(jax.random.PRNGKey(s), 3)
    q = jax.random.normal(ks[0], (2, s, 3, 64))
    k = jax.random.normal(ks[1], (2, s, 3, 64))
    v = jax.random.normal(ks[2], (2, s, 3, 64))
    got = flash_attention(q, k, v, causal=causal, cq=cq, ck=ck, interpret=True)
    want = blockwise_attention_fwd_only(q, k, v, causal=causal, chunk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    from repro.kernels.flash_attn import flash_attention
    from repro.models.layers import blockwise_attention_fwd_only

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 128, 2, 32)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 128, 2, 32)).astype(dtype)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    want = blockwise_attention_fwd_only(q, k, v, causal=True, chunk=128)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


# ----------------------------------------------------------- GLA kernel


@pytest.mark.parametrize("s,chunk", [(64, 32), (128, 128), (100, 32), (256, 64), (33, 16)])
def test_gla_kernel_matches_engine(s, chunk):
    from repro.kernels.gla_scan import gla_forward
    from repro.models.ssm import chunked_linear_attention

    ks = jax.random.split(jax.random.PRNGKey(s), 4)
    q = jax.random.normal(ks[0], (2, s, 3, 16))
    k = jax.random.normal(ks[1], (2, s, 3, 16))
    v = jax.random.normal(ks[2], (2, s, 3, 8))
    la = -jax.nn.softplus(jax.random.normal(ks[3], (2, s, 3)))
    got = gla_forward(q, k, v, la, chunk=chunk, interpret=True)
    want, _ = chunked_linear_attention(q, k, v, la, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gla_kernel_dtypes(dtype):
    from repro.kernels.gla_scan import gla_forward
    from repro.models.ssm import chunked_linear_attention

    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (1, 64, 2, 8)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 64, 2, 8)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 64, 2, 8)).astype(dtype)
    la = -jax.nn.softplus(jax.random.normal(ks[3], (1, 64, 2)))
    got = gla_forward(q, k, v, la, chunk=32, interpret=True)
    want, _ = chunked_linear_attention(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), la, chunk=32
    )
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=tol, atol=tol
    )
