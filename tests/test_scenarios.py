"""Scenario layer: spec validation, end-to-end runtime runs on every
topology, golden-metric regression locks, and the shared evaluation
path the paper-facing benchmarks route through.

The golden tests pin small fixed-seed scenario runs to checked-in
expected values (tolerance-banded AUC, exact merge counts, detection
delay/miss/FP counts) so a merge or ingest refactor cannot silently
shift the paper-facing numbers — if one of these moves, a paper table
moved with it.
"""
import jax
import numpy as np
import pytest

from repro.data.pipeline import class_subset, normalize_minmax
from repro.data.synthetic import make_har_dataset
from repro.scenarios import (
    SCENARIOS,
    ScenarioSpec,
    make_scenario,
    run_scenario,
)
from repro.scenarios.evaluate import pair_merge_eval, pattern_loss_rows

jax.config.update("jax_platform_name", "cpu")


# ------------------------------------------------------------- spec validity


def test_make_scenario_registry_and_overrides():
    for name in ("driving", "har", "mnist_like"):
        assert SCENARIOS[name]().name == name
    spec = make_scenario("har", n_devices=4, ticks=10)
    assert (spec.n_devices, spec.ticks) == (4, 10)
    with pytest.raises(ValueError, match="unknown scenario"):
        make_scenario("cifar")


@pytest.mark.parametrize("bad", [
    dict(normal_classes=()),                      # no homes
    dict(anomaly_classes=()),                     # no held-out pool
    dict(normal_classes=(0, 1), anomaly_classes=(1,)),   # overlap
    dict(normal_classes=(0, 0), anomaly_classes=(2,)),   # duplicate home
    dict(drift_frac=1.5),
    dict(drift_targets=(0,)),                     # drift into a home class
    dict(assignment="sorted"),
    dict(n_devices=0),
    dict(forget=0.0),
    dict(dataset="imagenet"),
])
def test_spec_validation_rejects(bad):
    base = dict(
        name="t", dataset="har", n_devices=4, ticks=8,
        normal_classes=(0, 1), anomaly_classes=(5,),
    )
    with pytest.raises(ValueError):
        ScenarioSpec(**{**base, **bad})


def test_build_produces_valid_feed():
    spec = make_scenario("har", n_devices=6, ticks=12, samples_per_class=40)
    sc = spec.build()
    assert sc.train.n_classes == spec.n_normal + len(spec.anomaly_classes)
    assert sc.streams.xs.shape == (6, spec.steps, sc.n_features)
    feed = sc.feed()
    assert feed.n_ticks == spec.ticks
    assert feed.tick_batch(0).shape == (6, spec.batch, sc.n_features)
    # eval arrays carry both classes; positives are the held-out pool
    assert set(np.unique(sc.y_eval)) == {0, 1}
    # anomaly pool held out: no pre-drift sample carries an anomaly id
    anoms = set(spec.remapped_anomaly_classes())
    for d in range(6):
        bounds = sc.streams.phase_boundaries(d)
        pre = sc.streams.pattern_of_device[d, : (bounds[1] if len(bounds) > 1
                                                 else spec.steps)]
        assert not (set(pre.tolist()) & anoms)


# ------------------------------------------------- end-to-end runtime green


@pytest.mark.parametrize("topology", ["ring", "star"])
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_scenarios_run_end_to_end(scenario, topology):
    """Every registered scenario runs green through ``FleetRuntime`` on
    ring + star: ≥1 admitted merge, finite AUCs, compile-once (asserted
    inside run_scenario), cooperative updates not worse than local
    training on the clean fleet."""
    spec = make_scenario(scenario, n_devices=6, ticks=40)
    res = run_scenario(spec, topology, merge_every=8)
    s = res.auc_summary()
    assert res.merges >= 1
    assert all(np.isfinite(v) for v in s.values()), s
    assert res.comm_bytes > 0
    assert all(v == 1 for v in res.jit_cache_sizes.values())


def test_hierarchical_and_all_to_all_topologies_also_run():
    # D=16 gives the hierarchical default two location clusters
    spec = make_scenario("har", n_devices=16, ticks=24)
    for topo in ("hierarchical", "all_to_all"):
        res = run_scenario(spec, topo, merge_every=8)
        assert res.merges >= 1


# -------------------------------------------------------- golden regression


# Checked-in expected metrics of small fixed-seed runs (ring, hops=1,
# merge_every=16, key_seed=0). AUC bands are ±0.03 (float noise across
# BLAS builds); merge/detection counts are exact. If one of these
# moves, a paper-facing number moved with it — regenerate ONLY after
# confirming the shift is intended (see benchmarks/paper_eval.py).
GOLDEN_SIZES = {
    "driving": dict(n_devices=8, ticks=80),
    "har": dict(n_devices=12, ticks=80),
    "mnist_like": dict(),                   # preset size (D=16)
}
GOLDEN = {
    "driving": dict(local=1.0000, merged=1.0000, clean=1.0000, merges=5,
                    delay=0.0, missed=0, fp=0, events=2),
    "har": dict(local=0.8535, merged=0.8179, clean=1.0000, merges=5,
                delay=0.0, missed=0, fp=0, events=3),
    "mnist_like": dict(local=0.6367, merged=0.7608, clean=0.8185, merges=5,
                       delay=1.75, missed=0, fp=0, events=4),
}
AUC_BAND = 0.03
DELAY_BAND = 1.0


@pytest.mark.parametrize("scenario", sorted(GOLDEN))
def test_golden_scenario_metrics(scenario):
    res = run_scenario(
        make_scenario(scenario, **GOLDEN_SIZES[scenario]),
        "ring", merge_every=16, key_seed=0,
    )
    g = GOLDEN[scenario]
    s = res.auc_summary()
    assert abs(s["local_auc_mean"] - g["local"]) <= AUC_BAND, s
    assert abs(s["merged_auc_mean"] - g["merged"]) <= AUC_BAND, s
    assert abs(s["clean_merged_auc_mean"] - g["clean"]) <= AUC_BAND, s
    assert res.merges == g["merges"]
    d = res.detection
    assert d["n_drift_events"] == g["events"]
    assert len(d["missed"]) == g["missed"], d
    assert len(d["false_positives"]) == g["fp"], d
    assert abs(d["delay_mean"] - g["delay"]) <= DELAY_BAND, d


def test_golden_quantized_comm_ratio():
    """int8 wire-format lock on the driving golden run: the SAME merge
    cadence and in-band AUC at ≥3.8× fewer merge bytes than f32. This
    is the codec half of the paper-eval ≥60× comm-vs-FedAvg claim (the
    FedAvg half is history-gated by benchmarks/paper_eval.py)."""
    spec = make_scenario("driving", **GOLDEN_SIZES["driving"])
    f32 = run_scenario(spec, "ring", merge_every=16, key_seed=0)
    q = run_scenario(
        spec, "ring", merge_every=16, key_seed=0, payload_precision="int8"
    )
    g = GOLDEN["driving"]
    assert q.payload_precision == "int8"
    assert q.merges == f32.merges == g["merges"]
    assert abs(float(q.merged_aucs.mean()) - g["merged"]) <= AUC_BAND
    ratio = f32.comm_bytes / q.comm_bytes
    assert ratio >= 3.8, (f32.comm_bytes, q.comm_bytes)


# --------------------------------------------------------- shared eval path


def _two_devices():
    from repro.core import ae_train_stream, init_autoencoder

    ds = normalize_minmax(make_har_dataset(seed=0, samples_per_class=60))
    test = class_subset(ds, (3, 4, 5))
    key = jax.random.PRNGKey(0)
    devs = []
    for pat in (0, 1):   # remapped sitting / standing
        x = test.pattern(pat)
        st = init_autoencoder(key, ds.n_features, 8, x[:24], ridge=1e-2,
                              activation="identity")
        devs.append(ae_train_stream(st, x[24:]))
    return devs, test


def test_pair_merge_eval_lifts_auc():
    """The shared two-device path reproduces the paper's core effect:
    merging B into A lifts A's AUC on the {p_A, p_B} protocol."""
    (dev_a, dev_b), test = _two_devices()
    before, after = pair_merge_eval(dev_a, dev_b, test, (0, 1), seed=0)
    assert 0.0 <= before <= 1.0 and 0.0 <= after <= 1.0
    assert after >= before - 0.02


def test_pattern_loss_rows_transfer():
    """Loss rows: A inherits B's competence on B's pattern."""
    (dev_a, dev_b), test = _two_devices()
    rows = pattern_loss_rows(dev_a, dev_b, test)
    p_b = test.class_names[1]
    assert rows[p_b]["A_after"] < rows[p_b]["A_before"] + 1e-9
    assert set(rows) == set(test.class_names)


# ------------------------------------------------------------ full grid (slow)


@pytest.mark.slow
def test_paper_eval_full_grid():
    """The full topology grid (bigger fleets, all four topologies) —
    CI runs the smoke grid; this is the `-m slow` long-form."""
    from benchmarks.paper_eval import FULL_TOPOLOGIES, check_claims, run_bench

    report = run_bench(smoke=False)
    claims = check_claims(report, FULL_TOPOLOGIES)
    assert claims["all_green"], claims["green"]
    assert claims["auc_and_comm_scenarios"], report["scenarios"]
