"""Tests for the federated protocol layer (client/server + selection)."""
import jax
import numpy as np
import pytest

from repro.core import to_uv
from repro.data import make_har_dataset
from repro.data.pipeline import make_pattern_stream
from repro.federated import EdgeDevice, FederationServer
from repro.federated.protocol import Payload, cooperative_round
from repro.federated.selection import (
    all_clients,
    loss_threshold_selection,
    resource_constrained_selection,
)


@pytest.fixture(scope="module")
def har():
    return make_har_dataset(seed=0, samples_per_class=120)


def make_device(har, device_id, pattern, key, n_hidden=48):
    xs = make_pattern_stream(har, pattern, seed=7)
    dev = EdgeDevice(device_id, key, har.n_features, n_hidden, xs[: 2 * n_hidden], ridge=1e-3)
    dev.train(xs[2 * n_hidden :])
    return dev


def test_paper_scenario_device_b_normal_becomes_normal_at_a(har):
    """§5.2 scenario: after A merges B, B's pattern reconstructs on A."""
    key = jax.random.PRNGKey(0)
    dev_a = make_device(har, "A", "sitting", key)
    dev_b = make_device(har, "B", "laying", key)
    laying = har.pattern("laying")[:64]

    before = dev_a.score(laying).mean()
    server = FederationServer()
    dev_b.share(server)
    dev_a.merge_from(server, ["B"])
    after = dev_a.score(laying).mean()
    assert after < before / 5.0  # loss collapses (paper Fig. 7)


def test_merge_symmetry_between_devices(har):
    """'Device-A that has merged Device-B' == 'Device-B that has merged
    Device-A' (§5.2.1)."""
    key = jax.random.PRNGKey(0)
    dev_a = make_device(har, "A", "sitting", key)
    dev_b = make_device(har, "B", "laying", key)
    server = FederationServer()
    cooperative_round([dev_a, dev_b], server)
    np.testing.assert_allclose(
        np.asarray(dev_a.state.beta), np.asarray(dev_b.state.beta), rtol=1e-3, atol=1e-4
    )


def test_comm_cost_independent_of_data_size(har):
    """The payload is Ñ(Ñ+m) floats no matter how many samples trained."""
    key = jax.random.PRNGKey(0)
    small = make_device(har, "S", "walking", key)
    big = make_device(har, "B", "walking", key)
    big.train(make_pattern_stream(har, "standing", seed=11))
    server = FederationServer()
    small.share(server)
    big.share(server)
    assert server.store["S"].nbytes == server.store["B"].nbytes
    assert server.log.uploads == 2


def test_payload_roundtrip(har):
    key = jax.random.PRNGKey(1)
    dev = make_device(har, "X", "walking", key)
    uv = to_uv(dev.state)
    p = Payload.from_uv("X", uv, 3)
    rt = p.to_uv()
    np.testing.assert_allclose(np.asarray(rt.u), np.asarray(uv.u))
    assert p.version == 3


def test_selection_strategies():
    ids = ["a", "b", "c"]
    assert list(all_clients(ids)) == ids
    sel = resource_constrained_selection({"a": 1.0, "b": 10.0, "c": 2.0}, threshold=5.0)
    assert list(sel(ids)) == ["a", "c"]
    sel2 = loss_threshold_selection({"a": 0.1, "b": 9.0}, max_loss=1.0)
    assert list(sel2(ids)) == ["a"]  # unknown c excluded too


def test_selective_round_excludes_bad_client(har):
    """Ref [20]-style: a device trained on garbage is excluded from the
    merge, so it does not poison the others."""
    key = jax.random.PRNGKey(0)
    dev_a = make_device(har, "A", "sitting", key)
    dev_b = make_device(har, "B", "laying", key)
    dev_c = make_device(har, "C", "walking", key)
    # poison C
    rng = np.random.default_rng(0)
    dev_c.train(rng.normal(size=(200, har.n_features)).astype(np.float32) * 50.0)

    server = FederationServer()
    sel = loss_threshold_selection({"A": 0.1, "B": 0.1, "C": 99.0}, max_loss=1.0)
    cooperative_round([dev_a, dev_b, dev_c], server, select=sel)
    sitting = har.pattern("sitting")[:64]
    laying = har.pattern("laying")[:64]
    assert dev_a.score(sitting).mean() < 1.0
    assert dev_a.score(laying).mean() < 1.0
