"""Activation sharding constraints (GSPMD anchoring).

Without explicit constraints GSPMD is free to replicate the batch dim
and shard activations along d_model — which it chose to do for our
FSDP-style weight shardings, inflating per-device activation traffic by
the data-parallel degree. ``constrain_batch`` re-anchors the batch dim
of every block's output onto the ("pod","data") axes.

The mesh is installed by the launcher (dryrun/train) via ``use_mesh``;
without it every call is a no-op, so CPU unit tests and the federated
benchmarks never notice.
"""
from __future__ import annotations

import contextlib
import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: dict[str, Any] = {"mesh": None, "batch_axes": ("data",)}


@contextlib.contextmanager
def use_mesh(mesh: Mesh, batch_axes: tuple[str, ...]):
    old = dict(_CTX)
    _CTX["mesh"] = mesh
    _CTX["batch_axes"] = tuple(batch_axes)
    try:
        yield
    finally:
        _CTX.update(old)


def _dp_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return math.prod(sizes[a] for a in axes)


def constrain_batch(x: jax.Array, *, batch_dim: int = 0):
    """Anchor x's batch dim on the data axes (no-op without a mesh or
    when the batch doesn't divide — e.g. long_500k's batch=1)."""
    mesh = _CTX["mesh"]
    if mesh is None or not hasattr(x, "shape") or x.ndim == 0:
        return x
    axes = _CTX["batch_axes"]
    if x.shape[batch_dim] % _dp_size(mesh, axes):
        return x
    dims: list = [None] * x.ndim
    dims[batch_dim] = axes
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*dims)))


def constrain_seq(x: jax.Array, *, seq_dim: int):
    """Context parallelism: anchor a sequence dim on the data axes
    (long_500k decode caches)."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    axes = _CTX["batch_axes"]
    if x.shape[seq_dim] % _dp_size(mesh, axes):
        return x
    dims: list = [None] * x.ndim
    dims[seq_dim] = axes
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*dims)))
