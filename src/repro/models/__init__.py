from repro.models.config import INPUT_SHAPES, ArchConfig, ShapeConfig
from repro.models.model import (
    active_param_count,
    cache_shape_structs,
    decode_step,
    encoder_forward,
    forward,
    init_params,
    input_specs,
    lm_loss,
    param_count,
    prefill,
)

__all__ = [
    "INPUT_SHAPES", "ArchConfig", "ShapeConfig",
    "active_param_count", "cache_shape_structs", "decode_step",
    "encoder_forward", "forward", "init_params", "input_specs",
    "lm_loss", "param_count", "prefill",
]
