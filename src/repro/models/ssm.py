"""Recurrent sequence mixers: selective SSM (Mamba-style), xLSTM's
mLSTM and sLSTM.

TPU adaptation (DESIGN.md §4): instead of a per-timestep scan (latency-
bound on a systolic machine) the linear-recurrent mixers use a
**chunked gated-linear-attention engine** — the Mamba-2/SSD
factorization. Per chunk of length C the recurrence

    h_t = a_t · h_{t-1} + k_t v_tᵀ ,    y_t = h_t q_t

is computed with three MXU matmuls (intra-chunk (C×C) decay-masked
attention, state broadcast, state update) and a ``lax.scan`` only over
chunks. Everything is exact (log-space cumulative decays), and the
largest transient is (B, H, C, C) — no (B, S, d, n) scan element ever
materializes.

sLSTM has a genuinely nonlinear recurrence (h_{t-1} feeds the gates), so
it keeps a per-timestep ``lax.scan`` — the paper-faithful choice; xLSTM
places sLSTM in only 1/8 of the blocks.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


# --------------------------------------------------------------- engine


@partial(jax.jit, static_argnames=("chunk",))
def chunked_linear_attention(
    q: jnp.ndarray,        # (B, S, H, dk)
    k: jnp.ndarray,        # (B, S, H, dk)
    v: jnp.ndarray,        # (B, S, H, dv)
    log_a: jnp.ndarray,    # (B, S, H) per-token log decay (≤ 0)
    *,
    chunk: int = 128,
    init_state: jnp.ndarray | None = None,  # (B, H, dk, dv)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """y_t = q_tᵀ h_t with h_t = a_t h_{t-1} + k_t v_tᵀ. Returns (y, h_S)."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    n = -(-s // c)
    pad = n * c - s

    def pad_seq(x):
        return jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))

    # zero decay (log a = 0 → a = 1) on padding keeps the state unchanged
    qp = pad_seq(q).reshape(b, n, c, h, dk)
    kp = pad_seq(k).reshape(b, n, c, h, dk)
    vp = pad_seq(v).reshape(b, n, c, h, dv)
    lap = pad_seq(log_a).reshape(b, n, c, h)
    # padded k/v must not contribute: zero them
    if pad:
        valid = (jnp.arange(n * c).reshape(n, c) < s)[None, :, :, None]
        kp = kp * valid[..., None]
        vp = vp * valid[..., None]

    cum = jnp.cumsum(lap, axis=2)          # (B, n, C, H) inclusive Σ log a
    total = cum[:, :, -1, :]               # (B, n, H)

    def step(state, inp):
        q_c, k_c, v_c, cum_c, tot_c = inp  # leading dim B
        # inter-chunk: y += (q ⊙ e^{cum}) S_prev
        decay_q = jnp.exp(cum_c)                         # (B,C,H)
        y_inter = jnp.einsum(
            "bchk,bhkv->bchv", q_c * decay_q[..., None], state
        )
        # intra-chunk: scores[t,τ] = q_t·k_τ · e^{cum_t − cum_τ}, τ ≤ t
        scores = jnp.einsum("bchk,bdhk->bhcd", q_c, k_c).astype(jnp.float32)
        rel = cum_c.transpose(0, 2, 1)[:, :, :, None] - cum_c.transpose(0, 2, 1)[:, :, None, :]
        causal = jnp.tril(jnp.ones((c, c), bool))
        gate = jnp.where(causal[None, None], jnp.exp(rel), 0.0)
        y_intra = jnp.einsum(
            "bhcd,bdhv->bchv", (scores * gate).astype(v_c.dtype), v_c
        )
        # state update: S ← e^{tot} S + Σ_τ e^{tot − cum_τ} k_τ v_τᵀ
        w = jnp.exp(tot_c[:, None, :] - cum_c)           # (B,C,H)
        s_new = state * jnp.exp(tot_c)[:, :, None, None]  # tot_c: (B,H)
        s_new = s_new + jnp.einsum("bchk,bchv->bhkv", k_c * w[..., None], v_c)
        return s_new, (y_inter + y_intra).astype(q_c.dtype)

    s0 = (
        init_state
        if init_state is not None
        else jnp.zeros((b, h, dk, dv), jnp.float32)
    )
    xs = (
        qp.transpose(1, 0, 2, 3, 4),
        kp.transpose(1, 0, 2, 3, 4),
        vp.transpose(1, 0, 2, 3, 4),
        cum.transpose(1, 0, 2, 3),
        total.transpose(1, 0, 2),
    )
    final, ys = jax.lax.scan(step, s0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, n * c, h, dv)[:, :s]
    return y, final


def linear_attention_decode_step(
    state: jnp.ndarray,    # (B, H, dk, dv)
    q: jnp.ndarray,        # (B, H, dk)
    k: jnp.ndarray,
    v: jnp.ndarray,        # (B, H, dv)
    log_a: jnp.ndarray,    # (B, H)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """O(1) recurrent decode: h ← a·h + k vᵀ; y = qᵀ h."""
    a = jnp.exp(log_a)[..., None, None]
    state = state * a + k[..., :, None] * v[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", q, state)
    return state, y


# ---------------------------------------------------------------- Mamba


def mamba_mix(p: dict, x: jnp.ndarray, *, n_heads: int, ssm_state: int, chunk: int = 128):
    """Selective SSM with per-head scalar decay (Mamba-2 style heads).

    x: (B, S, D). Params: in_proj (D, 2·Di), dt_proj (Di→H via mean pool
    per head), B/C projections (Di, n), A_log (H,), D_skip (Di,),
    out_proj (Di, D). Di = D (mamba_expand=1 for Hymba heads).

    The depthwise causal conv1d of the original Mamba is omitted
    (documented in DESIGN.md §8 — negligible FLOPs, no TPU analogue
    needed for the roofline).
    """
    b, s, d = x.shape
    xz = x @ p["in_proj"]                          # (B,S,2Di)
    di = xz.shape[-1] // 2
    xs, z = jnp.split(xz, 2, axis=-1)
    dh = di // n_heads

    dt = jax.nn.softplus(xs @ p["dt_proj"] + p["dt_bias"])   # (B,S,H)
    log_a = -dt * jnp.exp(p["a_log"])[None, None, :]          # (B,S,H), ≤0
    bmat = (xs @ p["b_proj"]).reshape(b, s, n_heads, ssm_state)
    cmat = (xs @ p["c_proj"]).reshape(b, s, n_heads, ssm_state)
    vv = (xs * dt.repeat(dh, axis=-1)).reshape(b, s, n_heads, dh)

    y, state = chunked_linear_attention(cmat, bmat, vv, log_a, chunk=chunk)
    y = y.reshape(b, s, di) + xs * p["d_skip"]
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return y @ p["out_proj"], state


def mamba_decode_step(p: dict, state: jnp.ndarray, x: jnp.ndarray, *, n_heads: int, ssm_state: int):
    """x: (B, D) one token; state: (B, H, n, dh)."""
    b, d = x.shape
    xz = x @ p["in_proj"]
    di = xz.shape[-1] // 2
    xs, z = jnp.split(xz, 2, axis=-1)
    dh = di // n_heads
    dt = jax.nn.softplus(xs @ p["dt_proj"] + p["dt_bias"])    # (B,H)
    log_a = -dt * jnp.exp(p["a_log"])[None, :]
    bmat = (xs @ p["b_proj"]).reshape(b, n_heads, ssm_state)
    cmat = (xs @ p["c_proj"]).reshape(b, n_heads, ssm_state)
    vv = (xs * dt.repeat(dh, axis=-1)).reshape(b, n_heads, dh)
    state, y = linear_attention_decode_step(state, cmat, bmat, vv, log_a)
    y = y.reshape(b, di) + xs * p["d_skip"]
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return state, y @ p["out_proj"]


# ---------------------------------------------------------------- mLSTM


def mlstm_mix(p: dict, x: jnp.ndarray, *, n_heads: int, chunk: int = 128):
    """xLSTM matrix-memory block mixer.

    C_t = f_t C_{t-1} + i_t v_t k_tᵀ;  n_t = f_t n_{t-1} + i_t k_t;
    y_t = (C_t q_t) / max(|n_t·q_t|, 1).

    Mapped onto the chunked engine by augmenting v with a ones column so
    numerator and normalizer come out of one pass. Exponential-gate
    stabilization is folded into the per-token log decay (log f is kept
    in log space end-to-end; i_t is applied as a scale on k).
    """
    b, s, d = x.shape
    dh = d // n_heads
    q = (x @ p["wq"]).reshape(b, s, n_heads, dh)
    k = (x @ p["wk"]).reshape(b, s, n_heads, dh) / jnp.sqrt(dh)
    v = (x @ p["wv"]).reshape(b, s, n_heads, dh)
    log_f = jax.nn.log_sigmoid((x @ p["wf"]) + p["bf"])       # (B,S,H) ≤ 0
    log_i = (x @ p["wi"]) + p["bi"]                            # (B,S,H)
    i_gate = jnp.exp(jnp.minimum(log_i, 0.0))                  # stabilized input gate
    o_gate = jax.nn.sigmoid(x @ p["wo_gate"] + p["bo"])        # (B,S,H)

    k_scaled = k * i_gate[..., None]
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    y_aug, state = chunked_linear_attention(q, k_scaled, v_aug, log_f, chunk=chunk)
    num, den = y_aug[..., :-1], y_aug[..., -1:]
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    y = y * o_gate[..., None]
    return y.reshape(b, s, d) @ p["out_proj"], state


def mlstm_decode_step(p: dict, state: jnp.ndarray, x: jnp.ndarray, *, n_heads: int):
    """state: (B, H, dh, dh+1) — matrix memory with normalizer column."""
    b, d = x.shape
    dh = d // n_heads
    q = (x @ p["wq"]).reshape(b, n_heads, dh)
    k = (x @ p["wk"]).reshape(b, n_heads, dh) / jnp.sqrt(dh)
    v = (x @ p["wv"]).reshape(b, n_heads, dh)
    log_f = jax.nn.log_sigmoid((x @ p["wf"]) + p["bf"])
    log_i = (x @ p["wi"]) + p["bi"]
    i_gate = jnp.exp(jnp.minimum(log_i, 0.0))
    o_gate = jax.nn.sigmoid(x @ p["wo_gate"] + p["bo"])
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    state, y_aug = linear_attention_decode_step(
        state, q, k * i_gate[..., None], v_aug, log_f
    )
    y = y_aug[..., :-1] / jnp.maximum(jnp.abs(y_aug[..., -1:]), 1.0)
    y = y * o_gate[..., None]
    return state, y.reshape(b, d) @ p["out_proj"]


# ---------------------------------------------------------------- sLSTM


def slstm_mix(p: dict, x: jnp.ndarray, *, n_heads: int):
    """xLSTM scalar-memory block: true nonlinear recurrence (h feeds the
    gates) → per-timestep lax.scan, exponential gating with the m_t
    stabilizer of the xLSTM paper."""
    b, s, d = x.shape
    dh = d // n_heads
    gates_x = x @ p["w_gates"] + p["b_gates"]                 # (B,S,4D)

    def step(carry, gx):
        h, c, n, m = carry                                    # each (B, D)
        rec = jnp.einsum("bhd,hde->bhe", h.reshape(b, n_heads, dh), p["r_gates"]).reshape(b, 4 * d)
        gi, gf, gz, go = jnp.split(gx + rec, 4, axis=-1)
        log_f = jax.nn.log_sigmoid(gf)
        m_new = jnp.maximum(log_f + m, gi)
        i_s = jnp.exp(gi - m_new)
        f_s = jnp.exp(log_f + m - m_new)
        c_new = f_s * c + i_s * jnp.tanh(gz)
        n_new = f_s * n + i_s
        h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1.0)
        return (h_new, c_new, n_new, m_new), h_new

    z = jnp.zeros((b, d), jnp.float32)
    m0 = jnp.full((b, d), -1e30, jnp.float32)
    final, hs = jax.lax.scan(
        step, (z, z, z, m0), gates_x.astype(jnp.float32).transpose(1, 0, 2)
    )
    y = hs.transpose(1, 0, 2).astype(x.dtype)                 # (B,S,D)
    return y @ p["out_proj"], final


def slstm_decode_step(p: dict, state, x: jnp.ndarray, *, n_heads: int):
    """state: (h, c, n, m) each (B, D)."""
    b, d = x.shape
    dh = d // n_heads
    h, c, n, m = state
    gx = x @ p["w_gates"] + p["b_gates"]
    rec = jnp.einsum("bhd,hde->bhe", h.reshape(b, n_heads, dh), p["r_gates"]).reshape(b, 4 * d)
    gi, gf, gz, go = jnp.split((gx + rec).astype(jnp.float32), 4, axis=-1)
    log_f = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(log_f + m, gi)
    i_s = jnp.exp(gi - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * jnp.tanh(gz)
    n_new = f_s * n + i_s
    h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1.0)
    y = h_new.astype(x.dtype) @ p["out_proj"]
    return (h_new, c_new, n_new, m_new), y
