"""Architecture config schema for the assigned model pool.

One frozen dataclass covers all six families (dense / moe / ssm /
hybrid / audio / vlm); per-arch modules in ``repro.configs`` fill it in
with the exact published numbers and cite their source.

``layer_pattern()`` returns the per-layer block kind — the model
substrate groups consecutive runs of the same kind into ``lax.scan``
calls over stacked weights (HLO stays one-block-sized regardless of
depth; essential to compile 126-layer models on this 2-core container).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]

# Block kinds (see repro.models.blocks):
#   dense      full-attention + SwiGLU
#   swa        sliding-window attention + SwiGLU
#   moe        full-attention + top-k MoE FFN
#   arctic     full-attention + (dense FFN ∥ top-k MoE) residual
#   hymba      parallel (attention ∥ mamba) heads + SwiGLU; swa variant
#   mlstm      xLSTM matrix-memory block
#   slstm      xLSTM scalar-memory block (sequential scan)
#   enc        bidirectional attention + FFN (encoder)
#   dec        causal attention + cross-attention + FFN (decoder)
#   xattn      cross-attention + SwiGLU (VLM image-fusion layer)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    source: str                       # paper / model-card citation
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None       # default d_model // n_heads

    # --- attention pattern ------------------------------------------------
    sliding_window: int = 0           # 0 = full attention everywhere
    global_every: int = 0             # gemma3: one global layer per N
    global_layers: tuple[int, ...] = ()  # hymba: explicit global layer ids

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    dense_residual: bool = False      # arctic: dense FFN in parallel with MoE
    tokens_per_group: int = 1024
    capacity_factor: float = 1.25
    moe_dispatch: str = "einsum"      # "einsum" (GSPMD-friendly) | "scatter" (refuted — see EXPERIMENTS §Perf)

    # --- SSM / recurrent ----------------------------------------------------
    ssm_state: int = 0
    mamba_expand: int = 1             # d_inner = expand * d_model
    slstm_every: int = 0              # xlstm: sLSTM block every N layers

    # --- encoder-decoder / multimodal ---------------------------------------
    encoder_layers: int = 0           # seamless: bidirectional encoder depth
    cross_attn_every: int = 0         # vlm: cross-attn block every N layers
    frontend: str | None = None       # "audio" | "vision" (STUB — DESIGN.md §5)
    n_frontend_tokens: int = 0        # frames / image patches
    d_frontend: int = 0               # frontend embedding width

    # --- numerics / training knobs -------------------------------------------
    ffn_type: str = "swiglu"          # "swiglu" | "gelu_mlp" (GPT-BigCode style)
    kv_cache_dtype: str = "param"     # "param" | "float8_e4m3fn" (decode-memory opt)
    rope_theta: float = 10000.0
    param_dtype: str = "bfloat16"
    moment_dtype: str = "float32"     # bf16 for the ≥100B archs (HBM fit)
    num_microbatches: int = 1         # grad-accumulation chunks in train_step
    norm_eps: float = 1e-5

    # --- detector (the paper's technique) ------------------------------------
    detector_hidden: int = 64         # OS-ELM autoencoder Ñ for the feature tap

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, "GQA needs H % KV == 0"

    # ------------------------------------------------------------------ utils
    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def layer_pattern(self) -> tuple[str, ...]:
        """Per-layer (decoder) block kinds."""
        L = self.n_layers
        if self.family == "ssm":
            return tuple(
                "slstm" if self.slstm_every and i % self.slstm_every == 0 else "mlstm"
                for i in range(L)
            )
        if self.family == "hybrid":
            return tuple(
                "hymba" if i in self.global_layers else "hymba_swa" for i in range(L)
            )
        if self.family == "moe":
            return tuple(("arctic" if self.dense_residual else "moe") for _ in range(L))
        if self.family == "audio":
            return tuple("dec" for _ in range(L))
        if self.family == "vlm":
            k = self.cross_attn_every
            return tuple(
                "xattn" if k and (i + 1) % k == 0 else "dense" for i in range(L)
            )
        # dense
        if self.sliding_window and self.global_every:
            # gemma3: (global_every - 1) local then 1 global, repeating
            return tuple(
                "dense" if (i + 1) % self.global_every == 0 else "swa"
                for i in range(L)
            )
        if self.sliding_window:
            return tuple("swa" for _ in range(L))
        return tuple("dense" for _ in range(L))

    def encoder_pattern(self) -> tuple[str, ...]:
        return tuple("enc" for _ in range(self.encoder_layers))

    def supports_long_decode(self) -> bool:
        """Sub-quadratic decode feasibility (DESIGN.md long_500k table)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return bool(self.sliding_window)

    def supports_decode(self) -> bool:
        return True  # all assigned archs have a decoder

    def reduced(self, *, n_layers: int = 2, d_model: int = 256) -> "ArchConfig":
        """Smoke-test variant: same family/kind structure, tiny dims."""
        d_model = min(d_model, 512)
        n_heads = max(2, min(4, self.n_heads))
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=d_model // n_heads,
            d_ff=2 * d_model if self.d_ff else 0,
            vocab=512,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.experts_per_token else 0,
            tokens_per_group=64,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            global_every=min(self.global_every, 2) if self.global_every else 0,
            global_layers=(0,) if self.global_layers else (),
            slstm_every=2 if self.slstm_every else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            cross_attn_every=2 if self.cross_attn_every else 0,
            n_frontend_tokens=16 if self.n_frontend_tokens else 0,
            d_frontend=64 if self.d_frontend else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            num_microbatches=1,
            detector_hidden=16,
            param_dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


INPUT_SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
