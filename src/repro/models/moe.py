"""Mixture-of-Experts FFN: top-k routing with capacity-factor dispatch.

Switch/Flaxformer-style einsum dispatch: tokens are bucketed into groups
(``tokens_per_group``), each token picks top-k experts, a per-expert
capacity ``cap = ts·k/E·cf`` bounds the dispatch tensor to
(groups, ts, E, cap) — overflowing tokens are dropped (standard
capacity-based MoE semantics). The expert dimension is sharded over the
"model" mesh axis when divisible, which makes the dispatch/return
einsums lower to all-to-alls under GSPMD (the collective term of the
MoE roofline).

Aux losses: router z-loss + load-balance loss (Switch Transformer),
returned for the train step.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def moe_ffn(
    p: dict,
    x: jnp.ndarray,                 # (B, S, D)
    *,
    n_experts: int,
    top_k: int,
    tokens_per_group: int,
    capacity_factor: float = 1.25,
    dispatch: str = "einsum",
) -> tuple[jnp.ndarray, dict]:
    """dispatch="scatter" (default): tokens are scatter-added into
    per-expert capacity buckets and gathered back — zero matmul FLOPs
    for routing (§Perf iteration 3: the einsum dispatch costs
    tokens·E·cap·d MACs, 25× granite-moe's useful compute).
    dispatch="einsum": the classic Switch/Flaxformer one-hot form,
    kept as the paper-faithful-baseline comparison point.
    """
    b, s, d = x.shape
    tokens = b * s
    ts = min(tokens_per_group, tokens)
    g = -(-tokens // ts)
    pad = g * ts - tokens
    xf = x.reshape(tokens, d)
    if pad:  # pad to a whole number of groups; padded tokens are dropped on return
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    xg = xf.reshape(g, ts, d)
    if dispatch == "scatter":
        return _moe_scatter(
            p, xg, b, s, d, tokens,
            n_experts=n_experts, top_k=top_k, capacity_factor=capacity_factor,
        )

    logits = (xg @ p["router"].astype(xg.dtype)).astype(jnp.float32)  # (g, ts, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, top_k)                      # (g, ts, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = max(1, int(ts * top_k / n_experts * capacity_factor))

    counts = jnp.zeros((g, n_experts), jnp.int32)
    dispatch = jnp.zeros((g, ts, n_experts, cap), xg.dtype)
    combine = jnp.zeros((g, ts, n_experts, cap), jnp.float32)
    for kk in range(top_k):  # K is small and static — unrolled
        m = jax.nn.one_hot(idx[:, :, kk], n_experts, dtype=jnp.int32)   # (g,ts,E)
        pos = counts[:, None, :] + jnp.cumsum(m, axis=1) - m            # slot before me
        keep = (pos < cap) & (m > 0)
        oh = jax.nn.one_hot(pos, cap, dtype=xg.dtype) * keep[..., None].astype(xg.dtype)
        dispatch = dispatch + oh
        combine = combine + oh.astype(jnp.float32) * gate_vals[:, :, kk, None, None]
        counts = counts + m.sum(axis=1)

    # dispatch → per-expert buffers (all-to-all under expert sharding)
    expert_in = jnp.einsum("gtec,gtd->gecd", dispatch, xg)              # (g,E,cap,D)
    h_gate = jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"])
    h_up = jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"])
    h = jax.nn.silu(h_gate.astype(jnp.float32)).astype(h_up.dtype) * h_up
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["w_down"])           # (g,E,cap,D)
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(expert_out.dtype), expert_out)
    y = y.reshape(g * ts, d)[:tokens]  # drop grouping pad

    # --- aux losses (Switch Transformer §2.2) ---------------------------------
    # load balance: E · Σ_e fraction_tokens_e · mean_prob_e
    top1 = jax.nn.one_hot(idx[:, :, 0], n_experts, dtype=jnp.float32)
    frac_tokens = top1.mean(axis=1)                                    # (g, E)
    mean_prob = probs.mean(axis=1)
    lb_loss = n_experts * jnp.mean(jnp.sum(frac_tokens * mean_prob, axis=-1))
    z_loss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    dropped = 1.0 - (dispatch.sum(axis=(2, 3)) > 0).astype(jnp.float32).mean()

    aux = {"lb_loss": lb_loss, "z_loss": z_loss, "dropped_frac": dropped}
    return y.reshape(b, s, d), aux


def _moe_scatter(
    p: dict,
    xg: jnp.ndarray,               # (g, ts, D)
    b: int, s: int, d: int, tokens: int,
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float,
) -> tuple[jnp.ndarray, dict]:
    """Scatter/gather dispatch: identical capacity semantics to the
    einsum path (same slot assignment, same drops), but tokens move via
    scatter-add and gather instead of one-hot matmuls."""
    g, ts, _ = xg.shape
    logits = (xg @ p["router"].astype(xg.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, top_k)                  # (g, ts, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = max(1, int(ts * top_k / n_experts * capacity_factor))
    n_slots = n_experts * cap

    # slot assignment — same order as the einsum path's cumsum
    counts = jnp.zeros((g, n_experts), jnp.int32)
    slot_list, keep_list = [], []
    for kk in range(top_k):
        m = jax.nn.one_hot(idx[:, :, kk], n_experts, dtype=jnp.int32)
        pos = counts[:, None, :] + jnp.cumsum(m, axis=1) - m
        pos_k = jnp.take_along_axis(pos, idx[:, :, kk:kk + 1], axis=-1)[..., 0]
        keep = pos_k < cap
        slot = idx[:, :, kk] * cap + jnp.minimum(pos_k, cap - 1)
        slot_list.append(jnp.where(keep, slot, n_slots))          # dump slot
        keep_list.append(keep)
        counts = counts + m.sum(axis=1)
    slots = jnp.stack(slot_list, axis=-1)                          # (g, ts, K)
    keeps = jnp.stack(keep_list, axis=-1)

    def per_group(xg_i, slots_i, gates_i, keeps_i):
        buf = jnp.zeros((n_slots + 1, d), xg_i.dtype)
        flat_slots = slots_i.reshape(-1)                           # (ts*K,)
        tok_idx = jnp.repeat(jnp.arange(ts), top_k)
        buf = buf.at[flat_slots].add(xg_i[tok_idx])                # scatter-add
        expert_in = buf[:n_slots].reshape(n_experts, cap, d)
        h_g = jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"])
        h_u = jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"])
        h = jax.nn.silu(h_g.astype(jnp.float32)).astype(h_u.dtype) * h_u
        expert_out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
        out_flat = jnp.concatenate(
            [expert_out.reshape(n_slots, d), jnp.zeros((1, d), expert_out.dtype)]
        )
        y_tok = out_flat[slots_i]                                  # (ts, K, d) gather
        w = (gates_i * keeps_i).astype(y_tok.dtype)
        return (y_tok * w[..., None]).sum(axis=1)

    y = jax.vmap(per_group)(xg, slots, gate_vals, keeps)           # (g, ts, d)
    y = y.reshape(g * ts, d)[:tokens].reshape(b, s, d)

    top1 = jax.nn.one_hot(idx[:, :, 0], n_experts, dtype=jnp.float32)
    lb_loss = n_experts * jnp.mean(
        jnp.sum(top1.mean(axis=1) * probs.mean(axis=1), axis=-1)
    )
    z_loss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    dropped = 1.0 - keeps.astype(jnp.float32).mean()
    return y, {"lb_loss": lb_loss, "z_loss": z_loss, "dropped_frac": dropped}
