"""Model assembly: embedding → pattern-grouped layer scans → loss/decode.

Key structural choices (DESIGN.md §6):
- layers are stacked **per block kind in pattern order** and driven by
  ``lax.scan`` over each consecutive run of the same kind → the HLO
  contains one body per kind regardless of depth;
- every scanned block is wrapped in ``jax.checkpoint`` (full remat per
  layer) so the train working set is one layer's activations;
- the LM cross-entropy is computed in sequence chunks (``lax.map``) so
  (B, S, V) logits never materialize — at 405B/128k-vocab scale the full
  logits tensor would dwarf HBM;
- RoPE tables are computed inside the jitted function (no multi-hundred-
  MB weak-type constants baked into the HLO at 500k context).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.blocks import BlockCtx, block_decode, block_fwd, cache_spec, init_block
from repro.models.config import ArchConfig, ShapeConfig
from repro.models.layers import rmsnorm, rope_tables
from repro.models.partitioning import constrain_batch

PyTree = Any


# ---------------------------------------------------------------- params


def group_runs(pattern: tuple[str, ...]) -> list[tuple[str, int]]:
    """Consecutive same-kind runs: ('a','a','b','a') → [(a,2),(b,1),(a,1)]."""
    runs: list[tuple[str, int]] = []
    for k in pattern:
        if runs and runs[-1][0] == k:
            runs[-1] = (k, runs[-1][1] + 1)
        else:
            runs.append((k, 1))
    return runs


def _stack_layers(key, kinds: tuple[str, ...], cfg: ArchConfig, dtype) -> dict:
    """Init each layer then stack per kind (pattern order preserved)."""
    per_kind: dict[str, list] = {}
    keys = jax.random.split(key, len(kinds))
    for k, kind in zip(keys, kinds):
        per_kind.setdefault(kind, []).append(init_block(k, kind, cfg, dtype))
    return {
        kind: jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
        for kind, layers in per_kind.items()
    }


def param_dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32


def init_params(key: jax.Array, cfg: ArchConfig) -> dict:
    dtype = param_dtype(cfg)
    k_embed, k_layers, k_enc, k_front = jax.random.split(key, 4)
    params: dict = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab, cfg.d_model)) * 0.02).astype(dtype),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "layers": _stack_layers(k_layers, cfg.layer_pattern(), cfg, dtype),
    }
    if cfg.is_encdec:
        params["enc_layers"] = _stack_layers(k_enc, cfg.encoder_pattern(), cfg, dtype)
        params["enc_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if cfg.frontend is not None:
        params["frontend_proj"] = (
            jax.random.normal(k_front, (cfg.d_frontend, cfg.d_model))
            * cfg.d_frontend ** -0.5
        ).astype(dtype)
    return params


def param_count(params: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def active_param_count(params: dict, cfg: ArchConfig) -> int:
    """MoE-aware: per-token active params (top-k of E experts)."""
    total = param_count(params)
    if not cfg.n_experts:
        return total
    expert_leaves = 0
    for kind in ("moe", "arctic"):
        stack = params["layers"].get(kind)
        if stack is not None and "moe" in stack:
            for name in ("w_gate", "w_up", "w_down"):
                expert_leaves += int(stack["moe"][name].size)
    active_frac = cfg.experts_per_token / cfg.n_experts
    return int(total - expert_leaves * (1.0 - active_frac))


# --------------------------------------------------------------- forward


def _run_layers(
    layers: dict,
    pattern: tuple[str, ...],
    x: jnp.ndarray,
    ctx: BlockCtx,
    *,
    remat: bool = True,
):
    """Scan pattern runs. Returns (x, aux_sum[3], caches_by_kind|None)."""
    offsets: dict[str, int] = {}
    aux_total = jnp.zeros(3, jnp.float32)
    caches: dict[str, list] = {}
    for kind, count in group_runs(pattern):
        off = offsets.get(kind, 0)
        offsets[kind] = off + count
        p_run = jax.tree.map(
            lambda a: jax.lax.slice_in_dim(a, off, off + count), layers[kind]
        )

        def body(xc, pl, _kind=kind):
            xo, aux, cache = block_fwd(_kind, pl, xc, ctx)
            return constrain_batch(xo), aux, cache

        if remat:
            body = jax.checkpoint(body)

        def scan_body(xc, pl):
            xo, aux, cache = body(xc, pl)
            return xo, (aux, cache)

        x, (auxs, cache_run) = jax.lax.scan(scan_body, x, p_run)
        aux_total = aux_total + auxs.sum(axis=0)
        if ctx.collect_cache:
            caches.setdefault(kind, []).append(cache_run)
    if ctx.collect_cache:
        stacked = {
            kind: jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *parts)
            for kind, parts in caches.items()
        }
        return x, aux_total, stacked
    return x, aux_total, None


def encoder_forward(params: dict, cfg: ArchConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """Audio/vision frontend STUB consumption: precomputed embeddings →
    projection → bidirectional encoder stack (seamless) or straight
    projection (VLM)."""
    x = constrain_batch(frames.astype(param_dtype(cfg)) @ params["frontend_proj"])
    if cfg.is_encdec:
        cos, sin = rope_tables(x.shape[1], cfg.head_dim, cfg.rope_theta)
        ctx = BlockCtx(cfg=cfg, rope_cos=cos, rope_sin=sin, causal=False)
        x, _, _ = _run_layers(params["enc_layers"], cfg.encoder_pattern(), x, ctx)
        x = rmsnorm(x, params["enc_norm"], cfg.norm_eps)
    return x


def forward(
    params: dict,
    cfg: ArchConfig,
    tokens: jnp.ndarray,              # (B, S) int32
    *,
    frontend: jnp.ndarray | None = None,  # (B, T, d_frontend) stub embeddings
    collect_cache: bool = False,
    cache_len: int = 0,               # decode-cache capacity (≥ S) when collecting
) -> tuple[jnp.ndarray, jnp.ndarray, PyTree]:
    """→ (hidden (B,S,D), aux[3], caches|None)."""
    b, s = tokens.shape
    x = constrain_batch(params["embed"][tokens])  # vocab-sharded gather
    enc_out = None
    if frontend is not None:
        enc_out = encoder_forward(params, cfg, frontend)
    cos, sin = rope_tables(s, cfg.head_dim, cfg.rope_theta)
    ctx = BlockCtx(
        cfg=cfg, rope_cos=cos, rope_sin=sin, enc_out=enc_out,
        collect_cache=collect_cache, cache_len=max(cache_len, s),
    )
    x, aux, caches = _run_layers(params["layers"], cfg.layer_pattern(), x, ctx)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux, caches


# ------------------------------------------------------------------ loss

LB_WEIGHT = 0.01
Z_WEIGHT = 1e-3


def lm_loss(
    params: dict,
    cfg: ArchConfig,
    tokens: jnp.ndarray,
    labels: jnp.ndarray,
    *,
    frontend: jnp.ndarray | None = None,
    loss_chunk: int = 512,
) -> tuple[jnp.ndarray, dict]:
    hidden, aux, _ = forward(params, cfg, tokens, frontend=frontend)
    b, s, d = hidden.shape
    embed = params["embed"]
    c = min(loss_chunk, s)
    n = s // c  # shapes are powers of two in all assigned configs

    hid = hidden[:, : n * c].reshape(b, n, c, d).transpose(1, 0, 2, 3)
    lab = labels[:, : n * c].reshape(b, n, c).transpose(1, 0, 2)

    def chunk_ce(args):
        h_c, l_c = args                      # (B, c, D), (B, c)
        logits = (h_c @ embed.T).astype(jnp.float32)          # (B, c, V)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, l_c[..., None], axis=-1)[..., 0]
        return (lse - ll).sum()

    total = jax.lax.map(chunk_ce, (hid, lab)).sum()
    ce = total / (b * n * c)
    loss = ce + LB_WEIGHT * aux[0] + Z_WEIGHT * aux[1]
    # the paper's feature tap: pooled final hidden state, detached
    features = jax.lax.stop_gradient(hidden.mean(axis=1).astype(jnp.float32))
    metrics = {
        "ce": ce, "lb_loss": aux[0], "z_loss": aux[1], "dropped_frac": aux[2],
        "features": features,
    }
    return loss, metrics


# --------------------------------------------------------------- serving


def prefill(
    params: dict,
    cfg: ArchConfig,
    tokens: jnp.ndarray,
    *,
    frontend: jnp.ndarray | None = None,
    cache_len: int = 0,
) -> tuple[jnp.ndarray, PyTree, jnp.ndarray]:
    """→ (last-token logits (B,V), caches, features (B,D))."""
    hidden, _, caches = forward(
        params, cfg, tokens, frontend=frontend, collect_cache=True,
        cache_len=cache_len,
    )
    last = hidden[:, -1]
    logits = (last @ params["embed"].T).astype(jnp.float32)
    features = jax.lax.stop_gradient(hidden.mean(axis=1).astype(jnp.float32))
    return logits, caches, features


def decode_step(
    params: dict,
    cfg: ArchConfig,
    token: jnp.ndarray,              # (B,) int32 — the newest token
    caches: dict,                    # {kind: stacked layer caches}
    pos: jnp.ndarray,                # scalar int32 current position
    *,
    enc_out: jnp.ndarray | None = None,
    max_seq: int = 0,
) -> tuple[jnp.ndarray, dict]:
    """One serve step: emit logits for the next token, update caches."""
    x = constrain_batch(params["embed"][token])       # (B, D)
    assert max_seq > 0, "decode_step needs max_seq for the RoPE table"
    cos, sin = rope_tables(max_seq + 1, cfg.head_dim, cfg.rope_theta)
    ctx = BlockCtx(cfg=cfg, rope_cos=cos, rope_sin=sin, enc_out=enc_out, pos=pos)

    pattern = cfg.layer_pattern()
    offsets: dict[str, int] = {}
    new_caches = {k: v for k, v in caches.items()}
    for kind, count in group_runs(pattern):
        off = offsets.get(kind, 0)
        offsets[kind] = off + count
        p_run = jax.tree.map(
            lambda a: jax.lax.slice_in_dim(a, off, off + count),
            params["layers"][kind],
        )
        cache_run = jax.tree.map(
            lambda a: jax.lax.slice_in_dim(a, off, off + count), new_caches[kind]
        )

        def scan_body(xc, inp, _kind=kind):
            pl, cl = inp
            xo, c_new = block_decode(_kind, pl, xc, cl, ctx)
            return xo, c_new

        x, cache_out = jax.lax.scan(scan_body, x, (p_run, cache_run))
        new_caches[kind] = jax.tree.map(
            lambda full, upd: jax.lax.dynamic_update_slice_in_dim(full, upd, off, 0),
            new_caches[kind], cache_out,
        )
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["embed"].T).astype(jnp.float32)
    return logits, new_caches


# ------------------------------------------------------------ input specs


def cache_shape_structs(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    """{kind: stacked ShapeDtypeStruct tree} matching decode_step's caches."""
    pattern = cfg.layer_pattern()
    counts: dict[str, int] = {}
    for k in pattern:
        counts[k] = counts.get(k, 0) + 1
    out = {}
    for kind, n in counts.items():
        spec = cache_spec(kind, cfg, batch, seq_len)
        out[kind] = jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct((n, *sd[0]), sd[1]),
            spec,
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple),
        )
    return out


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    specs: dict[str, Any] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.frontend is not None:
            specs["frontend"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, cfg.d_frontend), f32
            )
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.frontend is not None:
            specs["frontend"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, cfg.d_frontend), f32
            )
    else:  # decode: one token against a seq_len cache
        specs["token"] = jax.ShapeDtypeStruct((b,), i32)
        specs["caches"] = cache_shape_structs(cfg, b, s)
        specs["pos"] = jax.ShapeDtypeStruct((), i32)
        if cfg.frontend is not None:
            specs["enc_out"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, cfg.d_model), f32
            )
    return specs
