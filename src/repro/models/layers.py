"""Shared transformer layers: RMSNorm, RoPE, blockwise attention.

Attention is implemented *blockwise* (flash-style online softmax,
``lax.scan`` over KV chunks) so prefill at 32k never materializes the
(S × S) score tensor — per-chunk scores are (bq × bk). This is the pure
JAX path used by the multi-pod dry-run; `repro.kernels.flash_attn`
carries the Pallas version of the same algorithm for on-TPU execution.

Sliding-window attention uses the exact two-chunk formulation (each
query chunk attends its own and the previous chunk, intra-window
masked), so local layers really do cost O(S·2w) — the roofline sees the
window, not a masked S².
"""
from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope_tables(seq_len: int, head_dim: int, theta: float, dtype=jnp.float32):
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    pos = jnp.arange(seq_len, dtype=jnp.float32)
    ang = pos[:, None] * freqs[None, :]
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, hd); cos/sin: (S, hd/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def _expand_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """GQA: repeat kv heads to match query heads. (B,S,KV,hd)->(B,S,H,hd)."""
    if groups == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.repeat(k, groups, axis=2)


@partial(jax.jit, static_argnames=("causal", "chunk"))
def blockwise_attention_fwd_only(
    q: jnp.ndarray,            # (B, Sq, H, hd)
    k: jnp.ndarray,            # (B, Sk, H, hd)
    v: jnp.ndarray,            # (B, Sk, H, hd)
    *,
    causal: bool = True,
    chunk: int = 512,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Flash-style attention forward: scan over KV chunks with online
    softmax. Never materializes more than (B, H, chunk_q, chunk_k)
    scores — but jax.grad through the scan SAVES every chunk's scores as
    residuals, so training uses ``blockwise_attention`` (custom VJP that
    recomputes scores in the backward — §Perf iteration 1).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = hd ** -0.5
    cq = min(chunk, sq)
    ck = min(chunk, sk)
    nq = -(-sq // cq)
    nk = -(-sk // ck)
    # pad to chunk multiples
    qp = jnp.pad(q, ((0, 0), (0, nq * cq - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * ck - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * ck - sk), (0, 0), (0, 0)))

    qp = qp.reshape(b, nq, cq, h, hd).transpose(1, 0, 3, 2, 4)  # (nq,B,H,cq,hd)
    kp = kp.reshape(b, nk, ck, h, hd).transpose(1, 0, 3, 2, 4)
    vp = vp.reshape(b, nk, ck, h, hd).transpose(1, 0, 3, 2, 4)

    q_pos = q_offset + jnp.arange(nq * cq).reshape(nq, cq)
    k_pos = jnp.arange(nk * ck).reshape(nk, ck)
    k_valid = k_pos < sk

    def per_qchunk(qi, q_blk):
        qpos = q_pos[qi]                     # (cq,)

        def kv_step(carry, inp):
            m, l, acc = carry
            k_blk, v_blk, kpos, kval = inp
            s = jnp.einsum("bhqd,bhkd->bhqk", q_blk, k_blk).astype(jnp.float32) * scale
            mask = kval[None, None, None, :]
            if causal:
                mask = mask & (kpos[None, None, None, :] <= qpos[None, None, :, None])
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, cq), jnp.float32)
        a0 = jnp.zeros((b, h, cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kp, vp, k_pos, k_valid))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(lambda args: per_qchunk(*args), (jnp.arange(nq), qp))
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, nq * cq, h, hd)[:, :sq]
    return out.astype(q.dtype)


# ----------------------------------------------------------------------
# Flash attention with a memory-O(S) backward (custom VJP).
#
# §Perf iteration 1 (EXPERIMENTS.md): differentiating through the
# forward's online-softmax scan makes jax save every (cq × ck) score
# chunk as a scan residual — ~137 GB/layer for llama3-405b @ 4k — so the
# backward RECOMPUTES scores per chunk pair from (q, k, v, out, m, l)
# exactly like the Dao flash-attention backward. Costs ~+25% attention
# FLOPs in exchange for O(S) attention memory.
# ----------------------------------------------------------------------


def _fa_chunks(x, c):
    b, s, h, hd = x.shape
    n = -(-s // c)
    xp = jnp.pad(x, ((0, 0), (0, n * c - s), (0, 0), (0, 0)))
    return xp.reshape(b, n, c, h, hd).transpose(1, 0, 3, 2, 4), n  # (n,B,H,c,hd)


def _fa_forward(q, k, v, causal: bool, chunk: int):
    with jax.named_scope("flash_attention_fwd"):
        return _fa_forward_inner(q, k, v, causal, chunk)


def _fa_forward_inner(q, k, v, causal: bool, chunk: int):
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = hd ** -0.5
    cq, ck = min(chunk, sq), min(chunk, sk)
    qp, nq = _fa_chunks(q, cq)
    kp, nk = _fa_chunks(k, ck)
    vp, _ = _fa_chunks(v, ck)
    q_pos = jnp.arange(nq * cq).reshape(nq, cq)
    k_pos = jnp.arange(nk * ck).reshape(nk, ck)
    k_valid = k_pos < sk

    def per_qchunk(args):
        qi, q_blk = args
        qpos = q_pos[qi]

        def kv_step(carry, inp):
            m, l, acc = carry
            k_blk, v_blk, kpos, kval = inp
            s = jnp.einsum("bhqd,bhkd->bhqk", q_blk, k_blk).astype(jnp.float32) * scale
            mask = kval[None, None, None, :]
            if causal:
                mask = mask & (kpos[None, None, None, :] <= qpos[None, None, :, None])
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, cq), jnp.float32)
        a0 = jnp.zeros((b, h, cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kp, vp, k_pos, k_valid))
        return acc / jnp.maximum(l, 1e-30)[..., None], m, l

    out_c, m_c, l_c = jax.lax.map(per_qchunk, (jnp.arange(nq), qp))
    out = out_c.transpose(1, 0, 3, 2, 4).reshape(b, nq * cq, h, hd)[:, :sq]
    return out.astype(q.dtype), (m_c, l_c)  # stats stay chunked: (nq,B,H,cq)


@partial(jax.jit, static_argnames=("causal", "chunk"))
def _fa_backward_impl(q, k, v, out, m_c, l_c, dout, *, causal: bool, chunk: int):
    with jax.named_scope("flash_attention_bwd"):
        return _fa_backward_inner(q, k, v, out, m_c, l_c, dout, causal=causal, chunk=chunk)


def _fa_backward_inner(q, k, v, out, m_c, l_c, dout, *, causal: bool, chunk: int):
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = hd ** -0.5
    cq, ck = min(chunk, sq), min(chunk, sk)
    qp, nq = _fa_chunks(q, cq)
    kp, nk = _fa_chunks(k, ck)
    vp, _ = _fa_chunks(v, ck)
    dop, _ = _fa_chunks(dout.astype(jnp.float32), cq)
    outp, _ = _fa_chunks(out.astype(jnp.float32), cq)
    delta = (dop * outp).sum(-1)  # (nq,B,H,cq)
    q_pos = jnp.arange(nq * cq).reshape(nq, cq)
    k_pos = jnp.arange(nk * ck).reshape(nk, ck)
    k_valid = k_pos < sk

    def per_qchunk(carry, inp):
        dk_acc, dv_acc = carry                    # (nk,B,H,ck,hd) f32
        qi, q_blk, do_blk, m_i, l_i, delta_i = inp

        def kv_step(carry2, j):
            dq_i, dk_a, dv_a = carry2
            k_blk = kp[j]
            v_blk = vp[j]
            s = jnp.einsum("bhqd,bhkd->bhqk", q_blk, k_blk).astype(jnp.float32) * scale
            mask = k_valid[j][None, None, None, :]
            if causal:
                mask = mask & (
                    k_pos[j][None, None, None, :] <= q_pos[qi][None, None, :, None]
                )
            s = jnp.where(mask, s, NEG_INF)
            p = jnp.exp(s - m_i[..., None]) / jnp.maximum(l_i, 1e-30)[..., None]
            dv_j = jnp.einsum("bhqk,bhqd->bhkd", p, do_blk)
            dp = jnp.einsum("bhqd,bhkd->bhqk", do_blk, v_blk.astype(jnp.float32))
            ds = p * (dp - delta_i[..., None]) * scale
            dq_i = dq_i + jnp.einsum("bhqk,bhkd->bhqd", ds, k_blk.astype(jnp.float32))
            dk_j = jnp.einsum("bhqk,bhqd->bhkd", ds, q_blk.astype(jnp.float32))
            dk_a = dk_a.at[j].add(dk_j)
            dv_a = dv_a.at[j].add(dv_j)
            return (dq_i, dk_a, dv_a), None

        dq0 = jnp.zeros((b, h, cq, hd), jnp.float32)
        (dq_i, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc), jnp.arange(nk)
        )
        return (dk_acc, dv_acc), dq_i

    dk0 = jnp.zeros((nk, b, h, ck, hd), jnp.float32)
    dv0 = jnp.zeros((nk, b, h, ck, hd), jnp.float32)
    (dk_c, dv_c), dq_c = jax.lax.scan(
        per_qchunk, (dk0, dv0),
        (jnp.arange(nq), qp, dop, m_c, l_c, delta),
    )

    def unchunk(xc, s, dtype):
        n = xc.shape[0]
        c = xc.shape[3]
        return (
            xc.transpose(1, 0, 3, 2, 4).reshape(b, n * c, h, hd)[:, :s].astype(dtype)
        )

    return unchunk(dq_c, sq, q.dtype), unchunk(dk_c, sk, k.dtype), unchunk(dv_c, sk, v.dtype)


@functools.lru_cache(maxsize=None)
def _make_flash(causal: bool, chunk: int):
    @jax.custom_vjp
    def fa(q, k, v):
        out, _ = _fa_forward(q, k, v, causal, chunk)
        return out

    def fwd(q, k, v):
        out, (m_c, l_c) = _fa_forward(q, k, v, causal, chunk)
        return out, (q, k, v, out, m_c, l_c)

    def bwd(res, dout):
        q, k, v, out, m_c, l_c = res
        return _fa_backward_impl(
            q, k, v, out, m_c, l_c, dout, causal=causal, chunk=chunk
        )

    fa.defvjp(fwd, bwd)
    return fa


def blockwise_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    *, causal: bool = True, chunk: int = 512, q_offset: int = 0,
) -> jnp.ndarray:
    """Flash attention with O(S)-memory forward AND backward."""
    if q_offset:
        return blockwise_attention_fwd_only(
            q, k, v, causal=causal, chunk=chunk, q_offset=q_offset
        )
    return _make_flash(causal, chunk)(q, k, v)


@partial(jax.jit, static_argnames=("window",))
def local_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *, window: int
) -> jnp.ndarray:
    """Exact causal sliding-window attention, O(S · 2w).

    Queries are chunked at the window size; each chunk attends its own
    and the previous chunk with the in-window causal mask.
    """
    b, s, h, hd = q.shape
    w = window
    scale = hd ** -0.5
    n = -(-s // w)
    pad = n * w - s
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))).reshape(b, n, w, h, hd)
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).reshape(b, n, w, h, hd)
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).reshape(b, n, w, h, hd)
    # previous chunk of K/V (zeros for the first)
    k_prev = jnp.concatenate([jnp.zeros_like(kp[:, :1]), kp[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vp[:, :1]), vp[:, :-1]], axis=1)
    kk = jnp.concatenate([k_prev, kp], axis=2)  # (B,n,2w,H,hd)
    vv = jnp.concatenate([v_prev, vp], axis=2)

    srel_q = jnp.arange(w)
    srel_k = jnp.arange(2 * w) - w  # position relative to chunk start
    # causal within window: k_rel <= q_rel and q_rel - k_rel < w
    mask_rel = (srel_k[None, :] <= srel_q[:, None]) & (
        srel_q[:, None] - srel_k[None, :] < w
    )  # (w, 2w)
    chunk_ids = jnp.arange(n)
    k_abs = chunk_ids[:, None] * w + srel_k[None, :]  # (n, 2w) absolute position
    valid_abs = (k_abs >= 0) & (k_abs < s)  # kills chunk-0 "previous" and tail pad

    scores = jnp.einsum("bnqhd,bnkhd->bnhqk", qp, kk).astype(jnp.float32) * scale
    m = mask_rel[None, None, None, :, :] & valid_abs[None, :, None, None, :]
    scores = jnp.where(m, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(vv.dtype)
    out = jnp.einsum("bnhqk,bnkhd->bnqhd", p, vv)
    return out.reshape(b, n * w, h, hd)[:, :s].astype(q.dtype)


def cross_attention_blockwise(q, k, v, *, chunk: int = 512) -> jnp.ndarray:
    """Full (non-causal) attention — encoder-decoder / VLM image fusion."""
    return blockwise_attention(q, k, v, causal=False, chunk=chunk)


@partial(jax.jit, static_argnames=("window",))
def decode_attention(
    q1: jnp.ndarray,        # (B, 1, H, hd) — the new token's query
    cache_k: jnp.ndarray,   # (B, S, KV, hd)
    cache_v: jnp.ndarray,
    pos: jnp.ndarray,       # scalar int32: number of valid cache entries
    *,
    window: int = 0,
) -> jnp.ndarray:
    """Single-token decode attention over a (possibly windowed) KV cache."""
    b, s, kv, hd = cache_k.shape
    h = q1.shape[2]
    groups = h // kv
    k = _expand_kv(cache_k, groups)
    v = _expand_kv(cache_v, groups)
    scale = hd ** -0.5
    s_pos = jnp.arange(s)
    valid = s_pos[None, None, :] < pos
    if window:
        valid = valid & (s_pos[None, None, :] >= pos - window)
    scores = jnp.einsum("bqhd,bshd->bhqs", q1, k).astype(jnp.float32) * scale
    scores = jnp.where(valid[:, :, None, :] if valid.ndim == 3 else valid, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", p, v).astype(q1.dtype)


def swiglu(x: jnp.ndarray, gate: jnp.ndarray, up: jnp.ndarray, down: jnp.ndarray) -> jnp.ndarray:
    g = x @ gate
    u = x @ up
    return (jax.nn.silu(g.astype(jnp.float32)).astype(u.dtype) * u) @ down


def gelu_mlp(x: jnp.ndarray, up: jnp.ndarray, down: jnp.ndarray) -> jnp.ndarray:
    """GPT-BigCode-style MLP (granite code models): up → GELU → down."""
    u = x @ up
    return jax.nn.gelu(u.astype(jnp.float32), approximate=True).astype(u.dtype) @ down
