"""Transformer blocks for every kind in ``ArchConfig.layer_pattern()``.

Each kind defines three things, all operating on ONE layer's params
(the model stacks layers per kind and drives these with ``lax.scan``):

  init_<kind>(key, cfg)                      -> params pytree
  fwd(kind, p, x, ctx)                       -> (x, aux[3])
  decode(kind, p, x_tok, cache, ctx)         -> (x_tok, new_cache)

aux is a fixed-size f32[3] = (load_balance, z_loss, dropped_frac) so
heterogeneous blocks stack in one scan (zeros for non-MoE kinds).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import (
    apply_rope,
    blockwise_attention,
    cross_attention_blockwise,
    decode_attention,
    gelu_mlp,
    local_attention,
    rmsnorm,
    swiglu,
)


def _ffn_apply(cfg: ArchConfig, p_ffn: dict, h):
    if cfg.ffn_type == "gelu_mlp":
        return gelu_mlp(h, p_ffn["up"], p_ffn["down"])
    return swiglu(h, p_ffn["gate"], p_ffn["up"], p_ffn["down"])
from repro.models.moe import moe_ffn
from repro.models.ssm import (
    mamba_decode_step,
    mamba_mix,
    mlstm_decode_step,
    mlstm_mix,
    slstm_decode_step,
    slstm_mix,
)


@dataclasses.dataclass(frozen=True)
class BlockCtx:
    """Loop-invariant context threaded through the layer scans."""

    cfg: ArchConfig
    rope_cos: jnp.ndarray | None = None    # (S, hd/2)
    rope_sin: jnp.ndarray | None = None
    enc_out: jnp.ndarray | None = None     # (B, T_enc, D) for dec/xattn kinds
    causal: bool = True
    pos: jnp.ndarray | None = None         # decode: current position scalar
    attn_chunk: int = 512
    collect_cache: bool = False            # prefill: emit decode caches
    cache_len: int = 0                     # prefill: decode-cache capacity (≥ S)


ZERO_AUX = jnp.zeros(3, jnp.float32)


# ------------------------------------------------------------------ init


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def init_attn(key, cfg: ArchConfig, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": _dense_init(k1, (d, h * hd), dtype),
        "wk": _dense_init(k2, (d, kv * hd), dtype),
        "wv": _dense_init(k3, (d, kv * hd), dtype),
        "wo": _dense_init(k4, (h * hd, d), dtype),
    }


def init_ffn(key, cfg: ArchConfig, dtype, d_ff=None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.ffn_type == "gelu_mlp":  # GPT-BigCode style: up/gelu/down
        return {
            "up": _dense_init(k2, (d, f), dtype),
            "down": _dense_init(k3, (f, d), dtype),
        }
    return {
        "gate": _dense_init(k1, (d, f), dtype),
        "up": _dense_init(k2, (d, f), dtype),
        "down": _dense_init(k3, (f, d), dtype),
    }


def init_moe(key, cfg: ArchConfig, dtype) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": _dense_init(k1, (d, e), jnp.float32),
        "w_gate": _dense_init(k2, (e, d, f), dtype),
        "w_up": _dense_init(k3, (e, d, f), dtype),
        "w_down": _dense_init(k4, (e, f, d), dtype),
    }


def init_mamba(key, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    di = cfg.mamba_expand * d
    h, n = cfg.n_heads, cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * di), dtype),
        "dt_proj": _dense_init(ks[1], (di, h), dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "b_proj": _dense_init(ks[2], (di, h * n), dtype),
        "c_proj": _dense_init(ks[3], (di, h * n), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((di,), dtype),
        "out_proj": _dense_init(ks[4], (di, d), dtype),
    }


def init_mlstm(key, cfg: ArchConfig, dtype) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "wq": _dense_init(ks[0], (d, d), dtype),
        "wk": _dense_init(ks[1], (d, d), dtype),
        "wv": _dense_init(ks[2], (d, d), dtype),
        "wf": _dense_init(ks[3], (d, h), dtype),
        "bf": jnp.full((h,), 3.0, dtype),     # open forget gates at init
        "wi": _dense_init(ks[4], (d, h), dtype),
        "bi": jnp.zeros((h,), dtype),
        "wo_gate": _dense_init(ks[5], (d, h), dtype),
        "bo": jnp.zeros((h,), dtype),
        "out_proj": _dense_init(ks[6], (d, d), dtype),
    }


def init_slstm(key, cfg: ArchConfig, dtype) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 3)
    return {
        "w_gates": _dense_init(ks[0], (d, 4 * d), dtype),
        "b_gates": jnp.concatenate(
            [jnp.zeros((d,), dtype), jnp.full((d,), 3.0, dtype), jnp.zeros((2 * d,), dtype)]
        ),
        "r_gates": (_dense_init(ks[1], (h * dh, 4 * dh), dtype)).reshape(h, dh, 4 * dh),
        "out_proj": _dense_init(ks[2], (d, d), dtype),
    }


def init_block(key, kind: str, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 8)

    def ln():
        return jnp.zeros((d,), jnp.float32)

    if kind in ("dense", "swa", "enc"):
        return {"ln1": ln(), "attn": init_attn(ks[0], cfg, dtype), "ln2": ln(),
                "ffn": init_ffn(ks[1], cfg, dtype)}
    if kind == "moe":
        return {"ln1": ln(), "attn": init_attn(ks[0], cfg, dtype), "ln2": ln(),
                "moe": init_moe(ks[1], cfg, dtype)}
    if kind == "arctic":
        return {"ln1": ln(), "attn": init_attn(ks[0], cfg, dtype), "ln2": ln(),
                "ffn": init_ffn(ks[1], cfg, dtype), "moe": init_moe(ks[2], cfg, dtype)}
    if kind in ("hymba", "hymba_swa"):
        return {"ln1": ln(), "attn": init_attn(ks[0], cfg, dtype),
                "mamba": init_mamba(ks[1], cfg, dtype), "ln2": ln(),
                "ffn": init_ffn(ks[2], cfg, dtype)}
    if kind == "mlstm":
        return {"ln1": ln(), "mlstm": init_mlstm(ks[0], cfg, dtype)}
    if kind == "slstm":
        return {"ln1": ln(), "slstm": init_slstm(ks[0], cfg, dtype)}
    if kind == "dec":
        return {"ln1": ln(), "attn": init_attn(ks[0], cfg, dtype),
                "ln_x": ln(), "xattn": init_attn(ks[1], cfg, dtype), "ln2": ln(),
                "ffn": init_ffn(ks[2], cfg, dtype)}
    if kind == "xattn":
        return {"ln_x": ln(), "xattn": init_attn(ks[0], cfg, dtype),
                "gate": jnp.zeros((), jnp.float32), "ln2": ln(),
                "ffn": init_ffn(ks[1], cfg, dtype)}
    raise ValueError(f"unknown block kind {kind!r}")


# --------------------------------------------------------------- forward


def _qkv(p, x, cfg: ArchConfig, ctx: BlockCtx, *, rope: bool = True):
    """Returns (q, k, v) GQA-expanded plus the pre-repeat (k, v) for the
    decode cache."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, kv, hd)
    v = (x @ p["wv"]).reshape(b, s, kv, hd)
    if rope and ctx.rope_cos is not None:
        q = apply_rope(q, ctx.rope_cos[:s], ctx.rope_sin[:s])
        k = apply_rope(k, ctx.rope_cos[:s], ctx.rope_sin[:s])
    k_c, v_c = k, v
    if h != kv:
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
    return q, k, v, k_c, v_c


def _rolled_cache(k_c: jnp.ndarray, cache_len: int) -> jnp.ndarray:
    """Place the last ``min(cache_len, S)`` entries at their rolling slots
    (slot = abs_pos %% cache_len) so decode can continue seamlessly.
    ``cache_len`` may exceed S (pre-allocated decode capacity)."""
    b, s, kv, hd = k_c.shape
    n_keep = min(cache_len, s)
    tail = k_c[:, s - n_keep:]
    slots = (jnp.arange(s - n_keep, s)) % cache_len
    out = jnp.zeros((b, cache_len, kv, hd), k_c.dtype)
    return out.at[:, slots].set(tail)


def _self_attn(p, x, cfg, ctx: BlockCtx, *, window: int = 0, causal: bool = True):
    b, s, _ = x.shape
    q, k, v, k_c, v_c = _qkv(p, x, cfg, ctx)
    if window and s > window:
        o = local_attention(q, k, v, window=window)
    else:
        o = blockwise_attention(q, k, v, causal=causal, chunk=ctx.attn_chunk)
    cache = None
    if ctx.collect_cache:
        cap = max(ctx.cache_len, s)
        cl = min(window, cap) if window else cap
        cdt = (jnp.float8_e4m3fn if cfg.kv_cache_dtype == "float8_e4m3fn"
               else k_c.dtype)
        cache = {"k": _rolled_cache(k_c.astype(cdt), cl),
                 "v": _rolled_cache(v_c.astype(cdt), cl)}
    return o.reshape(b, s, cfg.n_heads * cfg.head_dim) @ p["wo"], cache


def _cross_attn(p, x, enc_out, cfg, ctx: BlockCtx):
    b, s, _ = x.shape
    t = enc_out.shape[1]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (enc_out @ p["wk"]).reshape(b, t, kv, hd)
    v = (enc_out @ p["wv"]).reshape(b, t, kv, hd)
    if h != kv:
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
    o = cross_attention_blockwise(q, k, v, chunk=ctx.attn_chunk)
    return o.reshape(b, s, h * hd) @ p["wo"]


def block_fwd(kind: str, p: dict, x: jnp.ndarray, ctx: BlockCtx):
    """Returns (x, aux[3], cache) — cache is None unless ctx.collect_cache."""
    cfg = ctx.cfg
    eps = cfg.norm_eps
    aux = ZERO_AUX
    cache = None

    if kind in ("dense", "swa", "enc"):
        window = cfg.sliding_window if kind == "swa" else 0
        causal = kind != "enc"
        o, cache = _self_attn(p["attn"], rmsnorm(x, p["ln1"], eps), cfg, ctx,
                              window=window, causal=causal)
        x = x + o
        h = rmsnorm(x, p["ln2"], eps)
        x = x + _ffn_apply(cfg, p["ffn"], h)
        return x, aux, cache

    if kind in ("moe", "arctic"):
        o, cache = _self_attn(p["attn"], rmsnorm(x, p["ln1"], eps), cfg, ctx)
        x = x + o
        h = rmsnorm(x, p["ln2"], eps)
        y, moe_aux = moe_ffn(
            p["moe"], h,
            n_experts=cfg.n_experts, top_k=cfg.experts_per_token,
            tokens_per_group=cfg.tokens_per_group,
            capacity_factor=cfg.capacity_factor,
            dispatch=cfg.moe_dispatch,
        )
        if kind == "arctic":  # dense FFN residual in parallel with the MoE
            y = y + _ffn_apply(cfg, p["ffn"], h)
        x = x + y
        aux = jnp.stack([moe_aux["lb_loss"], moe_aux["z_loss"], moe_aux["dropped_frac"]])
        return x, aux, cache

    if kind in ("hymba", "hymba_swa"):
        h = rmsnorm(x, p["ln1"], eps)
        window = cfg.sliding_window if kind == "hymba_swa" else 0
        attn_out, attn_cache = _self_attn(p["attn"], h, cfg, ctx, window=window)
        mamba_out, ssm_state = mamba_mix(
            p["mamba"], h, n_heads=cfg.n_heads, ssm_state=cfg.ssm_state
        )
        x = x + 0.5 * (attn_out + mamba_out)     # parallel heads, fused mean
        h2 = rmsnorm(x, p["ln2"], eps)
        x = x + _ffn_apply(cfg, p["ffn"], h2)
        if ctx.collect_cache:
            # engine state is (B,H,dk=n,dv=dh) — matches cache_spec "ssm"
            cache = {**attn_cache, "ssm": ssm_state}
        return x, aux, cache

    if kind == "mlstm":
        y, mem = mlstm_mix(p["mlstm"], rmsnorm(x, p["ln1"], eps), n_heads=cfg.n_heads)
        x = x + y
        if ctx.collect_cache:
            cache = {"mem": mem}
        return x, aux, cache

    if kind == "slstm":
        y, st = slstm_mix(p["slstm"], rmsnorm(x, p["ln1"], eps), n_heads=cfg.n_heads)
        x = x + y
        if ctx.collect_cache:
            cache = {"h": st[0], "c": st[1], "n": st[2], "m": st[3]}
        return x, aux, cache

    if kind == "dec":
        o, cache = _self_attn(p["attn"], rmsnorm(x, p["ln1"], eps), cfg, ctx)
        x = x + o
        x = x + _cross_attn(p["xattn"], rmsnorm(x, p["ln_x"], eps), ctx.enc_out, cfg, ctx)
        h = rmsnorm(x, p["ln2"], eps)
        x = x + _ffn_apply(cfg, p["ffn"], h)
        return x, aux, cache

    if kind == "xattn":
        g = jnp.tanh(p["gate"])
        o = _cross_attn(p["xattn"], rmsnorm(x, p["ln_x"], eps), ctx.enc_out, cfg, ctx)
        x = x + (g * o).astype(x.dtype)  # f32 gate must not promote the carry
        h = rmsnorm(x, p["ln2"], eps)
        x = x + _ffn_apply(cfg, p["ffn"], h)
        if ctx.collect_cache:
            cache = {}  # xattn layers are stateless in decode
        return x, aux, cache

    raise ValueError(f"unknown block kind {kind!r}")


# ---------------------------------------------------------------- decode


def cache_spec(kind: str, cfg: ArchConfig, batch: int, seq_len: int) -> Any:
    """Shapes of one layer's decode cache (ShapeDtypeStruct-compatible)."""
    kv, hd, h = cfg.n_kv_heads, cfg.head_dim, cfg.n_heads
    d = cfg.d_model
    if cfg.kv_cache_dtype == "float8_e4m3fn":
        dt = jnp.float8_e4m3fn   # §Perf bonus: halves decode cache traffic vs bf16
    else:
        dt = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    if kind in ("dense", "moe", "arctic", "dec"):
        return {"k": ((batch, seq_len, kv, hd), dt), "v": ((batch, seq_len, kv, hd), dt)}
    if kind == "xattn":
        return {}  # stateless: cross-attn k/v recomputed from enc_out
    if kind == "swa":
        w = min(cfg.sliding_window or seq_len, seq_len)
        return {"k": ((batch, w, kv, hd), dt), "v": ((batch, w, kv, hd), dt)}
    if kind in ("hymba", "hymba_swa"):
        w = seq_len if kind == "hymba" else min(cfg.sliding_window or seq_len, seq_len)
        di = cfg.mamba_expand * cfg.d_model
        return {
            "k": ((batch, w, kv, hd), dt), "v": ((batch, w, kv, hd), dt),
            "ssm": ((batch, h, cfg.ssm_state, di // h), jnp.float32),
        }
    if kind == "mlstm":
        dh = d // h
        return {"mem": ((batch, h, dh, dh + 1), jnp.float32)}
    if kind == "slstm":
        return {
            "h": ((batch, d), jnp.float32), "c": ((batch, d), jnp.float32),
            "n": ((batch, d), jnp.float32), "m": ((batch, d), jnp.float32),
        }
    raise ValueError(kind)


def _decode_self_attn(p, x_tok, cache_k, cache_v, cfg, ctx: BlockCtx, *, window: int = 0):
    """One-token attention against a (possibly rolling) cache.

    Writes the token's k/v at slot pos %% cache_len, then attends over
    min(pos+1, cache_len) valid slots — exact sliding window semantics
    when cache_len == window.
    """
    b = x_tok.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pos = ctx.pos
    cache_len = cache_k.shape[1]
    q = (x_tok @ p["wq"]).reshape(b, 1, h, hd)
    k1 = (x_tok @ p["wk"]).reshape(b, 1, kv, hd)
    v1 = (x_tok @ p["wv"]).reshape(b, 1, kv, hd)
    if ctx.rope_cos is not None:
        cos = jax.lax.dynamic_slice_in_dim(ctx.rope_cos, pos, 1)
        sin = jax.lax.dynamic_slice_in_dim(ctx.rope_sin, pos, 1)
        q = apply_rope(q, cos, sin)
        k1 = apply_rope(k1, cos, sin)
    slot = pos % cache_len
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k1.astype(cache_k.dtype), slot, 1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v1.astype(cache_v.dtype), slot, 1)
    n_valid = jnp.minimum(pos + 1, cache_len)
    if cache_k.dtype == jnp.float8_e4m3fn:  # upcast at the MXU boundary
        o = decode_attention(q, cache_k.astype(jnp.bfloat16),
                             cache_v.astype(jnp.bfloat16), n_valid)
    else:
        o = decode_attention(q, cache_k, cache_v, n_valid)
    o = o.reshape(b, h * hd) @ p["wo"]
    return o, cache_k, cache_v


def block_decode(kind: str, p: dict, x_tok: jnp.ndarray, cache: dict, ctx: BlockCtx):
    """x_tok: (B, D) single-token hidden state."""
    cfg = ctx.cfg
    eps = cfg.norm_eps

    if kind in ("dense", "swa", "moe", "arctic"):
        h = rmsnorm(x_tok, p["ln1"], eps)
        o, ck, cv = _decode_self_attn(p["attn"], h, cache["k"], cache["v"], cfg, ctx)
        x_tok = x_tok + o
        h2 = rmsnorm(x_tok, p["ln2"], eps)
        if kind in ("moe", "arctic"):
            y, _ = moe_ffn(
                p["moe"], h2[:, None, :],
                n_experts=cfg.n_experts, top_k=cfg.experts_per_token,
                tokens_per_group=min(cfg.tokens_per_group, x_tok.shape[0]),
                capacity_factor=cfg.capacity_factor,
                dispatch=cfg.moe_dispatch,
            )
            y = y[:, 0]
            if kind == "arctic":
                y = y + _ffn_apply(cfg, p["ffn"], h2)
        else:
            y = _ffn_apply(cfg, p["ffn"], h2)
        x_tok = x_tok + y
        return x_tok, {**cache, "k": ck, "v": cv}

    if kind in ("hymba", "hymba_swa"):
        h = rmsnorm(x_tok, p["ln1"], eps)
        o, ck, cv = _decode_self_attn(p["attn"], h, cache["k"], cache["v"], cfg, ctx)
        ssm, ym = mamba_decode_step(
            p["mamba"], cache["ssm"], h, n_heads=cfg.n_heads, ssm_state=cfg.ssm_state
        )
        x_tok = x_tok + (0.5 * (o + ym)).astype(x_tok.dtype)
        h2 = rmsnorm(x_tok, p["ln2"], eps)
        x_tok = x_tok + _ffn_apply(cfg, p["ffn"], h2)
        return x_tok, {"k": ck, "v": cv, "ssm": ssm}

    if kind == "mlstm":
        h = rmsnorm(x_tok, p["ln1"], eps)
        mem, y = mlstm_decode_step(p["mlstm"], cache["mem"], h, n_heads=cfg.n_heads)
        return x_tok + y.astype(x_tok.dtype), {"mem": mem.astype(jnp.float32)}

    if kind == "slstm":
        h = rmsnorm(x_tok, p["ln1"], eps)
        st = (cache["h"], cache["c"], cache["n"], cache["m"])
        st, y = slstm_decode_step(p["slstm"], st, h, n_heads=cfg.n_heads)
        return x_tok + y, {"h": st[0], "c": st[1], "n": st[2], "m": st[3]}

    if kind == "dec":
        h = rmsnorm(x_tok, p["ln1"], eps)
        o, ck, cv = _decode_self_attn(p["attn"], h, cache["k"], cache["v"], cfg, ctx)
        x_tok = x_tok + o
        hx = rmsnorm(x_tok, p["ln_x"], eps)
        x_tok = x_tok + _cross_attn(p["xattn"], hx[:, None, :], ctx.enc_out, cfg, ctx)[:, 0]
        h2 = rmsnorm(x_tok, p["ln2"], eps)
        x_tok = x_tok + _ffn_apply(cfg, p["ffn"], h2)
        return x_tok, {**cache, "k": ck, "v": cv}

    if kind == "xattn":
        g = jnp.tanh(p["gate"])
        hx = rmsnorm(x_tok, p["ln_x"], eps)
        o = _cross_attn(p["xattn"], hx[:, None, :], ctx.enc_out, cfg, ctx)[:, 0]
        x_tok = x_tok + (g * o).astype(x_tok.dtype)
        h2 = rmsnorm(x_tok, p["ln2"], eps)
        x_tok = x_tok + _ffn_apply(cfg, p["ffn"], h2)
        return x_tok, cache

    raise ValueError(kind)
