"""Lightweight span tracing: JSONL traces + optional profiler hooks.

A span is one named, attributed interval — ``span("merge", tick=t)`` —
written as a single JSON line the moment it closes:

    {"name": "merge", "ts": <unix-epoch start>, "dur_s": <seconds>,
     "tick": 12, ...}

The JSONL format loads with one ``json.loads`` per line (no trailing
comma framing, torn final lines are skippable), which is exactly what
post-mortem tooling over a chaos soak wants.

With ``annotations=True`` every span also enters a
``jax.profiler.TraceAnnotation`` scope, so spans line up with XLA
activity in TensorBoard/perfetto captures taken around the run — the
host-side tick phases and the device timeline share names.

A ``Tracer`` constructed with ``path=None`` and no annotations is a
near-free no-op (one perf_counter pair per span), so instrumented code
never needs a second "telemetry off" code path.
"""
from __future__ import annotations

import contextlib
import json
import time
from pathlib import Path

__all__ = ["Tracer"]


class Tracer:
    """Buffered JSONL span writer (flush on ``close``/``flush`` or every
    ``buffer`` events)."""

    def __init__(
        self,
        path: str | Path | None = None,
        *,
        annotations: bool = False,
        buffer: int = 256,
    ) -> None:
        self.path = Path(path) if path is not None else None
        self.annotations = annotations
        self._buf: list[str] = []
        self._buffer = max(1, buffer)
        self.events_emitted = 0
        if self.path is not None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            # truncate: one trace file per run, not an append-across-runs log
            self.path.write_text("")

    @property
    def enabled(self) -> bool:
        return self.path is not None or self.annotations

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        """One traced interval; ``attrs`` must be JSON-able scalars."""
        if not self.enabled:
            yield
            return
        ann = (
            _profiler_annotation(name)
            if self.annotations else contextlib.nullcontext()
        )
        ts = time.time()
        t0 = time.perf_counter()
        try:
            with ann:
                yield
        finally:
            self.emit({
                "name": name, "ts": ts,
                "dur_s": time.perf_counter() - t0, **attrs,
            })

    def emit(self, event: dict) -> None:
        """Record one pre-built event (spans use this internally)."""
        self.events_emitted += 1
        if self.path is None:
            return
        self._buf.append(json.dumps(event))
        if len(self._buf) >= self._buffer:
            self.flush()

    def flush(self) -> None:
        if self.path is None or not self._buf:
            return
        with open(self.path, "a") as fh:
            fh.write("\n".join(self._buf) + "\n")
        self._buf.clear()

    def close(self) -> None:
        self.flush()


def _profiler_annotation(name: str):
    """A ``jax.profiler.TraceAnnotation`` scope, or a null context on
    jax builds that lack it — tracing must never be the thing that
    crashes a soak."""
    try:
        from jax.profiler import TraceAnnotation
    except ImportError:  # pragma: no cover - depends on jax build
        return contextlib.nullcontext()
    return TraceAnnotation(name)
