"""Crash flight recorder: a bounded ring of recent tick records.

Debugging a chaos soak (fault injection, Byzantine payloads, SLO
breaches) needs the ticks *leading up to* the event, not just the
event: which devices were quarantined, what the governor decided, what
faults were active, what the losses looked like. The flight recorder
keeps the last ``capacity`` per-tick records (JSON-able dicts the
runtime assembles) and, when something goes wrong — an exception, a
non-finite payload rejection, a tick-latency SLO breach — dumps the
whole ring plus the failing tick's *input batch* to
``flight_<tick>.json``. The inputs make the dump replayable: feeding
them back through an identically-configured runtime reproduces the
failing tick bit-for-bit (fault schedules are deterministic), which is
what ``benchmarks/serve_runtime.py``'s flight probe asserts.

Dumps are rate-limited (``max_dumps`` total, but the first occurrence
of each distinct reason always dumps) so a soak with a persistent
fault does not grind itself into the disk. The ring itself is part of
the runtime's snapshot state: a kill/restore resumes with the same
recent history it crashed with.
"""
from __future__ import annotations

import json
from collections import deque
from pathlib import Path

import numpy as np

__all__ = ["FlightRecorder", "jsonable"]


def jsonable(obj):
    """Recursively coerce numpy scalars/arrays into JSON-able Python."""
    if isinstance(obj, dict):
        return {str(k): jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    return obj


class FlightRecorder:
    """Last-N tick records + triggered dumps."""

    def __init__(self, capacity: int = 64, *, max_dumps: int = 4) -> None:
        if capacity < 1:
            raise ValueError(f"need capacity >= 1, got {capacity}")
        self.capacity = capacity
        self.max_dumps = max_dumps
        self._ring: deque[dict] = deque(maxlen=capacity)
        self.records_total = 0
        self.dumps: list[str] = []           # paths written this process
        self._dumped_reasons: set[str] = set()

    def __len__(self) -> int:
        return len(self._ring)

    def record(self, rec: dict) -> None:
        """Append one per-tick record. The dict is stored as-is — the
        hot tick loop must not pay a recursive coercion walk — and
        ``records()`` (the only read path: dumps and snapshots) applies
        ``jsonable`` lazily, so a dump still never fails on a stray
        numpy leaf. Callers hand over a fresh dict per tick and do not
        mutate it afterwards."""
        self._ring.append(rec)
        self.records_total += 1

    def records(self) -> list[dict]:
        return [jsonable(r) for r in self._ring]

    def should_dump(self, reason: str) -> bool:
        """First occurrence of a reason always dumps; after that the
        total budget gates (a soak with NaN payloads every round must
        not write hundreds of dumps)."""
        return reason not in self._dumped_reasons or len(self.dumps) < self.max_dumps

    def dump(
        self,
        directory: str | Path,
        tick: int,
        reason: str,
        *,
        inputs: np.ndarray | None = None,
        extra: dict | None = None,
    ) -> Path | None:
        """Write ``flight_<tick>.json`` with the ring, the trigger, and
        (when given) the failing tick's input batch. Returns the path,
        or None when rate-limited."""
        if not self.should_dump(reason):
            return None
        self._dumped_reasons.add(reason)
        directory = Path(directory)
        directory.mkdir(parents=True, exist_ok=True)
        path = directory / f"flight_{tick:08d}.json"
        payload = {
            "reason": reason,
            "tick": int(tick),
            "ring": self.records(),
            "extra": jsonable(extra or {}),
        }
        if inputs is not None:
            inputs = np.asarray(inputs)
            payload["inputs"] = {
                "shape": list(inputs.shape),
                "dtype": str(inputs.dtype),
                "values": inputs.tolist(),
            }
        tmp = path.with_suffix(".tmp")
        tmp.write_text(json.dumps(payload))
        tmp.replace(path)  # atomic: a torn dump never shadows a good one
        self.dumps.append(str(path))
        return path

    # ------------------------------------------------------------- snapshot

    def state(self) -> dict:
        return {
            "capacity": self.capacity,
            "ring": self.records(),
            "records_total": self.records_total,
            "dumped_reasons": sorted(self._dumped_reasons),
        }

    def load_state(self, state: dict) -> None:
        self._ring.clear()
        self._ring.extend(state.get("ring", ()))
        self.records_total = int(state.get("records_total", len(self._ring)))
        self._dumped_reasons = set(state.get("dumped_reasons", ()))


def load_dump(path: str | Path) -> dict:
    """Read a ``flight_<tick>.json`` dump, reconstructing the input
    batch as a numpy array when present."""
    with open(path) as fh:
        payload = json.load(fh)
    if "inputs" in payload:
        spec = payload["inputs"]
        payload["inputs"] = np.asarray(
            spec["values"], dtype=np.dtype(spec["dtype"])
        ).reshape(spec["shape"])
    return payload


__all__.append("load_dump")
