"""Typed host-side metrics: counters, gauges, histograms, phase timers.

The fleet runtime's observable signals — per-tick phase wall-clock,
merge-round bytes by wire precision, quarantine populations, detector
band dynamics — were previously scattered across ad-hoc locals in every
benchmark. ``MetricsRegistry`` is the one cheap, zero-dependency
instrumentation surface: plain Python objects updated between jitted
calls (never inside a trace), so telemetry can ride the compile-once
tick loop without adding a single retrace.

Conventions (Prometheus-flavored, but deliberately tiny):

- **Counter** — monotone accumulator (``inc`` rejects negative deltas);
  restore-continuity across snapshot round-trips is what the
  monotonicity tests lock.
- **Gauge** — last-write-wins level (quarantine population, EF-residual
  norm).
- **Histogram** — fixed upper-bound bucket edges (``le`` semantics,
  +Inf implicit) plus a bounded window of raw samples so quantiles
  (``quantile(0.99)``) are exact over the retained window instead of
  bucket-interpolated.
- **Labels** — a metric declared with ``labels=("phase",)`` is a family;
  ``family.labels(phase="merge")`` lazily materializes one child per
  label value. Children are ordinary metrics.

``phase_timer`` wraps one tick phase in a wall-clock measurement with
an explicit *fence*: the caller hands the phase's output pytree to
``handle.fence(...)`` and the timer ``block_until_ready``-s it before
reading the clock, so async dispatch cannot attribute a phase's compute
to whichever later phase happens to synchronize first.
"""
from __future__ import annotations

import bisect
import contextlib
import json
import math
import time
from collections import deque
from typing import Callable, Iterable

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "LATENCY_BUCKETS_S",
    "MetricsRegistry",
    "phase_timer",
]

# wall-clock seconds buckets spanning 10 µs .. 10 s (tick phases on CPU
# land mid-range; compile ticks in the top buckets)
LATENCY_BUCKETS_S = (
    1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1, 1.0, 3.0, 10.0,
)


class Counter:
    """Monotone accumulator."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counters are monotone: inc({n}) rejected")
        self.value += n


class Gauge:
    """Last-write-wins level."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram with a bounded raw-sample window.

    ``buckets`` are inclusive upper bounds (Prometheus ``le``); an
    implicit +Inf bucket catches the tail. ``quantile`` is computed
    over the retained raw samples (the most recent ``sample_cap``
    observations) — exact for runs shorter than the cap, a sliding
    window beyond it.
    """

    __slots__ = (
        "buckets", "_edges", "counts", "count", "sum", "vmin", "vmax", "samples",
    )

    def __init__(
        self,
        buckets: Iterable[float] = LATENCY_BUCKETS_S,
        *,
        sample_cap: int = 4096,
    ) -> None:
        edges = tuple(float(b) for b in buckets)
        if not edges or any(nxt <= prev for nxt, prev in zip(edges[1:], edges)):
            raise ValueError(f"bucket edges must strictly increase: {edges}")
        self.buckets = edges
        self._edges = np.asarray(edges)        # for vectorized searchsorted
        self.counts = [0] * (len(edges) + 1)   # last = +Inf overflow
        self.count = 0
        self.sum = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.samples: deque[float] = deque(maxlen=sample_cap)

    def observe(self, v: float) -> None:
        v = float(v)
        # bisect on the edge tuple: ~20x cheaper per call than a numpy
        # searchsorted (which re-wraps the scalar) — observe() runs
        # several times per serving tick
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        self.samples.append(v)

    def observe_many(self, values: np.ndarray) -> None:
        values = np.asarray(values, np.float64).ravel()
        if values.size == 0:
            return
        idx = np.searchsorted(self._edges, values, side="left")
        for i, n in enumerate(np.bincount(idx, minlength=len(self.counts))):
            if n:
                self.counts[i] += int(n)
        self.count += int(values.size)
        self.sum += float(values.sum())
        self.vmin = min(self.vmin, float(values.min()))
        self.vmax = max(self.vmax, float(values.max()))
        self.samples.extend(values.tolist())

    def quantile(self, q: float) -> float | None:
        """q-quantile over the retained sample window; None when empty."""
        if not self.samples:
            return None
        return float(np.percentile(np.fromiter(self.samples, np.float64), 100 * q))

    def snapshot(self) -> dict:
        return {
            "buckets": list(self.buckets),
            "counts": list(self.counts),
            "count": self.count,
            "sum": self.sum,
            "min": None if self.count == 0 else self.vmin,
            "max": None if self.count == 0 else self.vmax,
            "samples": list(self.samples),
        }

    def load(self, state: dict) -> None:
        if tuple(state["buckets"]) != self.buckets:
            raise ValueError(
                f"histogram bucket mismatch: snapshot {state['buckets']} vs "
                f"declared {list(self.buckets)}"
            )
        self.counts = [int(c) for c in state["counts"]]
        self.count = int(state["count"])
        self.sum = float(state["sum"])
        self.vmin = math.inf if state["min"] is None else float(state["min"])
        self.vmax = -math.inf if state["max"] is None else float(state["max"])
        self.samples.clear()
        self.samples.extend(float(s) for s in state["samples"])


class _Family:
    """Lazily-materialized labeled children of one declared metric."""

    __slots__ = ("name", "label_names", "_ctor", "children")

    def __init__(self, name: str, label_names: tuple[str, ...], ctor: Callable):
        self.name = name
        self.label_names = label_names
        self._ctor = ctor
        self.children: dict[tuple[str, ...], Counter | Gauge | Histogram] = {}

    def labels(self, **labels: str):
        if tuple(sorted(labels)) != tuple(sorted(self.label_names)):
            raise ValueError(
                f"{self.name}: expected labels {self.label_names}, "
                f"got {tuple(sorted(labels))}"
            )
        key = tuple(str(labels[k]) for k in self.label_names)
        child = self.children.get(key)
        if child is None:
            child = self.children[key] = self._ctor()
        return child


def _valid_name(name: str) -> str:
    if not name or not all(c.isalnum() or c == "_" for c in name):
        raise ValueError(f"metric names are [A-Za-z0-9_]+, got {name!r}")
    return name


class MetricsRegistry:
    """Declaration-ordered registry of named metrics.

    Declaring the same name twice returns the SAME object (so a sink
    and a benchmark can both ask for ``merge_rounds_total`` without
    coordinating), but re-declaring with a different type or label set
    is an error — one name, one meaning.
    """

    def __init__(self) -> None:
        self._metrics: dict[str, tuple[str, tuple[str, ...], object, str]] = {}

    def _declare(self, kind: str, name: str, help: str,
                 labels: tuple[str, ...], ctor: Callable):
        _valid_name(name)
        labels = tuple(labels)
        existing = self._metrics.get(name)
        if existing is not None:
            ekind, elabels, obj, _ = existing
            if ekind != kind or elabels != labels:
                raise ValueError(
                    f"metric {name!r} already declared as {ekind}{elabels}, "
                    f"cannot re-declare as {kind}{labels}"
                )
            return obj
        obj = _Family(name, labels, ctor) if labels else ctor()
        self._metrics[name] = (kind, labels, obj, help)
        return obj

    def counter(self, name: str, help: str = "",
                labels: tuple[str, ...] = ()) -> Counter | _Family:
        return self._declare("counter", name, help, labels, Counter)

    def gauge(self, name: str, help: str = "",
              labels: tuple[str, ...] = ()) -> Gauge | _Family:
        return self._declare("gauge", name, help, labels, Gauge)

    def histogram(
        self, name: str, help: str = "", labels: tuple[str, ...] = (),
        *, buckets: Iterable[float] = LATENCY_BUCKETS_S, sample_cap: int = 4096,
    ) -> Histogram | _Family:
        return self._declare(
            "histogram", name, help, labels,
            lambda: Histogram(buckets, sample_cap=sample_cap),
        )

    # ------------------------------------------------------------ iteration

    def _children(self, name: str):
        """Yield (label_dict, metric) pairs of one declared name."""
        kind, labels, obj, _ = self._metrics[name]
        if not labels:
            yield {}, obj
            return
        for key, child in sorted(obj.children.items()):
            yield dict(zip(labels, key)), child

    # ------------------------------------------------------------ exposition

    def exposition(self) -> str:
        """Prometheus-style text exposition of every declared metric."""
        out = []
        for name, (kind, _labels, _obj, help) in self._metrics.items():
            if help:
                out.append(f"# HELP {name} {help}")
            out.append(f"# TYPE {name} {kind}")
            for lbl, m in self._children(name):
                tag = (
                    "{" + ",".join(f'{k}="{v}"' for k, v in lbl.items()) + "}"
                    if lbl else ""
                )
                if kind in ("counter", "gauge"):
                    out.append(f"{name}{tag} {_fmt(m.value)}")
                else:
                    cum = 0
                    for edge, c in zip(m.buckets, m.counts):
                        cum += c
                        le = dict(lbl, le=_fmt(edge))
                        ltag = "{" + ",".join(
                            f'{k}="{v}"' for k, v in le.items()) + "}"
                        out.append(f"{name}_bucket{ltag} {cum}")
                    inf = "{" + ",".join(
                        f'{k}="{v}"' for k, v in dict(lbl, le="+Inf").items()
                    ) + "}"
                    out.append(f"{name}_bucket{inf} {m.count}")
                    out.append(f"{name}_sum{tag} {_fmt(m.sum)}")
                    out.append(f"{name}_count{tag} {m.count}")
        return "\n".join(out) + "\n"

    # ------------------------------------------------------- summary / state

    def summary(self) -> dict:
        """Flat JSON-able view: one entry per (metric, label) child."""
        out: dict[str, dict] = {}
        for name, (kind, _labels, _obj, _help) in self._metrics.items():
            rows = []
            for lbl, m in self._children(name):
                if kind in ("counter", "gauge"):
                    rows.append({"labels": lbl, "value": m.value})
                else:
                    rows.append({
                        "labels": lbl,
                        "count": m.count,
                        "sum": m.sum,
                        "mean": m.sum / m.count if m.count else None,
                        "min": None if m.count == 0 else m.vmin,
                        "max": None if m.count == 0 else m.vmax,
                        "p50": m.quantile(0.50),
                        "p99": m.quantile(0.99),
                    })
            out[name] = {"type": kind, "series": rows}
        return out

    def state(self) -> dict:
        """Full restorable state (JSON-able) — what snapshots persist."""
        out = []
        for name, (kind, _labels, _obj, _help) in self._metrics.items():
            for lbl, m in self._children(name):
                row = {"name": name, "kind": kind, "labels": lbl}
                if kind in ("counter", "gauge"):
                    row["value"] = m.value
                else:
                    row["histogram"] = m.snapshot()
                out.append(row)
        return {"metrics": out}

    def load_state(self, state: dict) -> None:
        """Restore a ``state()`` snapshot into the declared metrics.

        Snapshot entries whose name is not declared here are ignored
        (a telemetry schema can grow without stranding old snapshots);
        declared metrics missing from the snapshot keep their current
        values."""
        for row in state.get("metrics", ()):
            declared = self._metrics.get(row["name"])
            if declared is None:
                continue
            kind, labels, obj, _ = declared
            if kind != row["kind"]:
                raise ValueError(
                    f"{row['name']}: snapshot kind {row['kind']} vs "
                    f"declared {kind}"
                )
            m = obj.labels(**row["labels"]) if labels else obj
            if kind in ("counter", "gauge"):
                m.value = float(row["value"])
            else:
                m.load(row["histogram"])

    def roundtrip_check(self) -> None:  # pragma: no cover - debugging aid
        json.dumps(self.state())


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


class _PhaseHandle:
    """Mutable holder the timed block parks its output pytree in."""

    __slots__ = ("_tree",)

    def __init__(self) -> None:
        self._tree = None

    def fence(self, tree) -> None:
        self._tree = tree


@contextlib.contextmanager
def phase_timer(observe: Callable[[float], None]):
    """Time one phase, fencing whatever the block handed to
    ``handle.fence(...)`` before the clock is read — jax's async
    dispatch otherwise bills a phase's compute to the next caller of
    ``block_until_ready``. ``observe`` receives the fenced seconds."""
    handle = _PhaseHandle()
    t0 = time.perf_counter()
    try:
        yield handle
    finally:
        if handle._tree is not None:
            import jax

            jax.block_until_ready(handle._tree)
        observe(time.perf_counter() - t0)
