"""TelemetrySink — one export surface for the whole serving path.

Owns the three telemetry organs and their output files:

- a ``MetricsRegistry`` pre-declared with the runtime's metric catalog
  (phase latencies, merge bytes by precision, quarantine populations,
  detector band dynamics, fault/nonfinite counters — see README
  "Observability" for the full catalog),
- a ``Tracer`` writing a per-run JSONL trace (optionally mirrored into
  ``jax.profiler.TraceAnnotation`` scopes),
- a ``FlightRecorder`` ring dumped on exception / non-finite payload /
  SLO breach.

``FleetRuntime``, ``launch/serve.py`` and ``scenarios.evaluate
.run_scenario`` all emit through a sink, and the benchmarks read their
assertions from ``summary()`` — one instrumentation surface, every
consumer. All sink state is host-side Python: enabling telemetry never
adds a trace, and its wall-clock cost is itself measured (the serve
soak gates it at ≤5%).

``TelemetryConfig(dir=None)`` keeps everything in memory (no trace
file, no flight dumps, exposition on demand) — cheap enough to leave
on in tests.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

from repro.obs.flight import FlightRecorder
from repro.obs.metrics import LATENCY_BUCKETS_S, MetricsRegistry, phase_timer
from repro.obs.trace import Tracer

__all__ = ["TelemetryConfig", "TelemetrySink", "TICK_PHASES"]

# the runtime tick's phase decomposition, in execution order;
# "quantize" is the host-side precision-policy step of the quantized
# payload path (the codec itself runs fused inside the merge jit);
# "page_in"/"page_out" are the cohort-paged runtime's host↔device
# transfer phases (staging a cohort's arena slice onto the device and
# writing the updated slice back) — zero for the resident runtime
TICK_PHASES = (
    "poison", "page_in", "ingest", "page_out", "govern", "quantize",
    "merge", "snapshot",
)

# detector band widths / loss ratios are dimensionless O(1) quantities
_RATIO_BUCKETS = (1e-4, 1e-3, 1e-2, 0.1, 0.3, 1.0, 3.0, 10.0, 100.0)


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Static telemetry knobs (frozen: lives inside ``RuntimeConfig``)."""

    dir: str | None = None            # output dir for trace.jsonl,
                                      # exposition.txt and flight dumps;
                                      # None = in-memory only
    flight_capacity: int = 64         # ring length, in ticks
    max_flight_dumps: int = 4         # total dump budget per run
    slo_tick_seconds: float | None = None  # tick-latency SLO; breach dumps
    trace: bool = True                # write the JSONL span trace
    profiler_annotations: bool = False  # mirror spans into jax.profiler
    sample_cap: int = 4096            # histogram raw-sample window
    band_sample_every: int = 4        # sample the detector band-width /
                                      # loss-ratio histograms every Nth
                                      # tick (they read detector state
                                      # off-device; 1 = every tick)


class TelemetrySink:
    """Live telemetry state for one runtime (or one serving loop)."""

    def __init__(self, config: TelemetryConfig | None = None) -> None:
        self.config = config or TelemetryConfig()
        cfg = self.config
        self.dir = Path(cfg.dir) if cfg.dir is not None else None
        self.registry = MetricsRegistry()
        self.tracer = Tracer(
            self.dir / "trace.jsonl" if (self.dir and cfg.trace) else None,
            annotations=cfg.profiler_annotations,
        )
        self.flight = FlightRecorder(
            cfg.flight_capacity, max_dumps=cfg.max_flight_dumps
        )

        r, cap = self.registry, cfg.sample_cap
        self.ticks = r.counter("ticks_total", "serving ticks processed")
        self.phase_seconds = r.histogram(
            "tick_phase_seconds", "fenced wall-clock per tick phase",
            labels=("phase",), buckets=LATENCY_BUCKETS_S, sample_cap=cap,
        )
        self.tick_seconds = r.histogram(
            "tick_seconds", "fenced wall-clock of the whole tick",
            buckets=LATENCY_BUCKETS_S, sample_cap=cap,
        )
        self.merge_rounds = r.counter(
            "merge_rounds_total", "admitted cooperative merge rounds"
        )
        self.merge_bytes = r.counter(
            "merge_bytes_total", "merge payload traffic by wire precision",
            labels=("precision",),
        )
        # ---- cohort-paging catalog (the million-device arena runtime;
        # zero-valued for the resident runtime, same registry so both
        # runtimes share one exposition surface)
        self.merge_tier_bytes = r.counter(
            "merge_tier_bytes_total",
            "two-tier merge traffic by tier (intra=within-cohort device "
            "payloads, inter=cohort-head tree payloads)",
            labels=("tier",),
        )
        self.cohort_pages = r.counter(
            "cohort_pages_total", "cohort pages streamed through the device"
        )
        self.arena_bytes = r.gauge(
            "arena_bytes", "host-side fleet arena footprint"
        )
        self.arena_resident_devices = r.gauge(
            "arena_resident_devices",
            "devices whose state is currently staged on the device "
            "(the active cohort window), out of the arena's total",
        )
        self.detections = r.counter(
            "detections_total", "fresh drift-detector flags"
        )
        self.nonfinite = r.counter(
            "nonfinite_payloads_total",
            "payloads rejected by the finite guard",
        )
        self.fault_events = r.counter(
            "fault_events_total", "injected fault activations by kind",
            labels=("kind",),
        )
        self.slo_breaches = r.counter(
            "slo_breaches_total", "ticks over the latency SLO"
        )
        self.flight_dumps = r.counter(
            "flight_dumps_total", "flight-recorder dumps written"
        )
        self.quarantined = r.gauge(
            "quarantined_devices", "drift-quarantined devices"
        )
        self.robust_quarantined = r.gauge(
            "robust_quarantined_devices",
            "devices quarantined by robust-score escalation",
        )
        self.ef_residual_norm = r.gauge(
            "ef_residual_norm", "error-feedback residual Frobenius norm"
        )
        self.band_width = r.histogram(
            "detector_band_width", "calibrated detection band widths k·σ",
            buckets=_RATIO_BUCKETS, sample_cap=cap,
        )
        self.loss_ratio = r.histogram(
            "detector_loss_ratio", "tick loss / baseline mean (calibrated)",
            buckets=_RATIO_BUCKETS, sample_cap=cap,
        )

        # ---- ingress catalog (the async serving front-end's families;
        # pre-declared here so serve-loop state rides the SAME registry
        # snapshot the runtime persists — counters stay continuous
        # across a kill/restore and no benchmark forks its accounting)
        self.ingress_queue_depth = r.gauge(
            "ingress_queue_depth", "admitted requests waiting in windows"
        )
        self.ingress_accepted = r.counter(
            "ingress_accepted_total", "requests admitted into a tick window"
        )
        self.ingress_acked = r.counter(
            "ingress_acked_total", "requests acked with a served result"
        )
        self.ingress_shed = r.counter(
            "ingress_shed_total", "requests shed by reason",
            labels=("reason",),
        )
        self.ingress_deferred = r.counter(
            "ingress_deferred_total", "requests deferred (retryable) by reason",
            labels=("reason",),
        )
        self.ingress_retried = r.counter(
            "ingress_retried_total", "client retries after a deferral"
        )
        self.ingress_stale = r.counter(
            "ingress_stale_served_total",
            "requests answered from the stale-score cache (degraded)",
        )
        self.ingress_replayed = r.counter(
            "ingress_replayed_ticks_total",
            "tick windows replayed from the write-ahead log on recovery",
        )
        self.ingress_degraded_mode = r.gauge(
            "ingress_degraded_mode",
            "current degraded-ladder rung (0=normal 1=skip-merge "
            "2=stale-scores 3=shed)",
        )
        self.ingress_transitions = r.counter(
            "ingress_degraded_transitions_total",
            "degraded-ladder transitions by target mode",
            labels=("mode",),
        )
        self.ingress_admission_seconds = r.histogram(
            "ingress_admission_seconds",
            "submit-to-admission-decision latency",
            buckets=LATENCY_BUCKETS_S, sample_cap=cap,
        )
        self.ingress_request_seconds = r.histogram(
            "ingress_request_seconds",
            "submit-to-ack latency of served requests",
            buckets=LATENCY_BUCKETS_S, sample_cap=cap,
        )
        # bound observe callables once — phase() sits on the tick path
        self._phase_observe = {
            p: self.phase_seconds.labels(phase=p).observe for p in TICK_PHASES
        }

    # ---------------------------------------------------------------- timing

    def phase(self, name: str):
        """Context manager timing one tick phase into the phase
        histogram (``handle.fence(tree)`` fences before the read)."""
        observe = self._phase_observe.get(name)
        if observe is None:
            raise ValueError(f"unknown phase {name!r}; have {TICK_PHASES}")
        return phase_timer(observe)

    def span(self, name: str, **attrs):
        return self.tracer.span(name, **attrs)

    # --------------------------------------------------------------- flight

    def maybe_dump(self, tick: int, reason: str, *, inputs=None,
                   extra: dict | None = None):
        """Rate-limited flight dump; no-op without an output dir."""
        if self.dir is None:
            return None
        path = self.flight.dump(
            self.dir, tick, reason, inputs=inputs, extra=extra
        )
        if path is not None:
            self.flight_dumps.inc()
            self.tracer.emit({"name": "flight_dump", "tick": int(tick),
                              "reason": reason, "path": str(path)})
        return path

    # --------------------------------------------------------------- export

    def phase_stats(self) -> dict[str, dict]:
        """Per-phase latency stats (seconds) over the retained window."""
        out = {}
        for phase in TICK_PHASES:
            h = self.phase_seconds.children.get((phase,))
            if h is None or h.count == 0:
                continue
            out[phase] = {
                "count": h.count,
                "mean_s": h.sum / h.count,
                "p50_s": h.quantile(0.50),
                "p99_s": h.quantile(0.99),
                "max_s": h.vmax,
            }
        return out

    def bytes_by_precision(self) -> dict[str, int]:
        return {
            key[0]: int(child.value)
            for key, child in sorted(self.merge_bytes.children.items())
        }

    def ingress_stats(self) -> dict:
        """The serving front-end's view: admission outcomes, queue
        depth, degraded-ladder position, and submit-to-ack latency."""
        def _latency(h):
            if h.count == 0:
                return None
            return {
                "count": h.count,
                "mean_s": h.sum / h.count,
                "p50_s": h.quantile(0.50),
                "p99_s": h.quantile(0.99),
                "max_s": h.vmax,
            }

        return {
            "accepted": int(self.ingress_accepted.value),
            "acked": int(self.ingress_acked.value),
            "retried": int(self.ingress_retried.value),
            "stale_served": int(self.ingress_stale.value),
            "replayed_ticks": int(self.ingress_replayed.value),
            "queue_depth": int(self.ingress_queue_depth.value),
            "shed": {
                key[0]: int(child.value)
                for key, child in sorted(self.ingress_shed.children.items())
            },
            "deferred": {
                key[0]: int(child.value)
                for key, child in sorted(self.ingress_deferred.children.items())
            },
            "degraded_mode": int(self.ingress_degraded_mode.value),
            "degraded_transitions": {
                key[0]: int(child.value)
                for key, child in sorted(self.ingress_transitions.children.items())
            },
            "admission_latency": _latency(self.ingress_admission_seconds),
            "request_latency": _latency(self.ingress_request_seconds),
        }

    def summary(self) -> dict:
        """End-of-run summary dict — the one surface benchmarks consume."""
        t = self.tick_seconds
        return {
            "ticks": int(self.ticks.value),
            "merge_rounds": int(self.merge_rounds.value),
            "bytes_by_precision": self.bytes_by_precision(),
            "bytes_total": sum(self.bytes_by_precision().values()),
            "detections_total": int(self.detections.value),
            "nonfinite_payloads_total": int(self.nonfinite.value),
            "slo_breaches_total": int(self.slo_breaches.value),
            "fault_events": {
                key[0]: int(child.value)
                for key, child in sorted(self.fault_events.children.items())
            },
            "tick_latency": None if t.count == 0 else {
                "count": t.count,
                "mean_s": t.sum / t.count,
                "p50_s": t.quantile(0.50),
                "p99_s": t.quantile(0.99),
                "max_s": t.vmax,
            },
            "phases": self.phase_stats(),
            "ingress": self.ingress_stats(),
            "flight": {
                "recorded": self.flight.records_total,
                "ring_len": len(self.flight),
                "dumps": list(self.flight.dumps),
            },
            "metrics": self.registry.summary(),
        }

    def exposition(self) -> str:
        return self.registry.exposition()

    def write_outputs(self) -> None:
        """Flush the trace and write the text exposition (dir mode)."""
        self.tracer.flush()
        if self.dir is not None:
            self.dir.mkdir(parents=True, exist_ok=True)
            (self.dir / "exposition.txt").write_text(self.exposition())

    def close(self) -> None:
        self.write_outputs()
        self.tracer.close()

    # ------------------------------------------------------------- snapshot

    def state(self) -> dict:
        """JSON-able restorable state: registry + flight ring."""
        return {"registry": self.registry.state(),
                "flight": self.flight.state()}

    def load_state(self, state: dict) -> None:
        self.registry.load_state(state.get("registry", {}))
        self.flight.load_state(state.get("flight", {}))

    def state_bytes(self) -> bytes:
        return json.dumps(self.state()).encode()

    def load_state_bytes(self, raw: bytes) -> None:
        self.load_state(json.loads(bytes(raw).decode()))
