"""repro.obs — zero-dependency fleet telemetry.

Structured metrics (``MetricsRegistry``: typed counters / gauges /
histograms with labels, Prometheus-style text exposition), span tracing
(``Tracer``: JSONL trace per run + optional ``jax.profiler``
annotations), and a crash flight recorder (``FlightRecorder``: bounded
ring of recent tick records, dumped to ``flight_<tick>.json`` on
exception, non-finite payload rejection, or SLO breach). A
``TelemetrySink`` composes the three behind the single export surface
the runtime, the serving driver, and every benchmark consume.

Everything is host-side Python updated between jitted calls — the
compile-once tick loop stays compile-once with telemetry on, and the
serve soak gates the overhead at ≤5% wall-clock.
"""
from repro.obs.flight import FlightRecorder, load_dump
from repro.obs.metrics import (
    LATENCY_BUCKETS_S,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    phase_timer,
)
from repro.obs.sink import TICK_PHASES, TelemetryConfig, TelemetrySink
from repro.obs.trace import Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "phase_timer",
    "LATENCY_BUCKETS_S",
    "Tracer",
    "FlightRecorder", "load_dump",
    "TelemetryConfig", "TelemetrySink", "TICK_PHASES",
]
