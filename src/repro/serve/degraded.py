"""Degraded-mode ladder — load shedding as policy, not accident.

Four rungs, ordered by how much of the serving contract they give up:

- ``NORMAL``       — full service: train, score, merge on cadence
- ``SKIP_MERGE``   — ticks still train and score, but cooperative
                     merges are vetoed (``allow_merge=False``); sheds
                     the most expensive tick phase first
- ``STALE_SCORES`` — new requests are answered from the device's last
                     known score without training; the runtime only
                     drains already-admitted windows
- ``SHED``         — new requests are rejected outright

A watchdog evaluates pressure once per closed window: a stalled tick
(worker stuck past the deadline), a tick-latency p99 over the SLO (the
PR 8 phase timers), or queue depth near capacity. Escalation needs
``escalate_after`` consecutive pressured checks and recovery
``recover_after`` consecutive calm ones — the same hysteresis shape as
the drift detector's quarantine/re-admission, for the same reason: a
single slow tick must not flap the fleet in and out of degraded
service.
"""
from __future__ import annotations

import dataclasses
import enum

__all__ = ["Mode", "LadderConfig", "DegradedLadder"]


class Mode(enum.IntEnum):
    NORMAL = 0
    SKIP_MERGE = 1
    STALE_SCORES = 2
    SHED = 3


@dataclasses.dataclass(frozen=True)
class LadderConfig:
    escalate_after: int = 2   # consecutive pressured checks per rung up
    recover_after: int = 4    # consecutive calm checks per rung down


class DegradedLadder:
    """Hysteresis state machine over ``Mode``."""

    def __init__(self, cfg: LadderConfig | None = None) -> None:
        self.cfg = cfg or LadderConfig()
        self.mode = Mode.NORMAL
        self.pressured_checks = 0
        self.calm_checks = 0
        self.transitions: list[tuple[int, Mode]] = []  # (check_no, new mode)
        self._checks = 0

    def check(self, pressured: bool) -> Mode:
        """Fold one watchdog observation; returns the (possibly new)
        mode. One rung per transition — pressure during SKIP_MERGE
        escalates to STALE_SCORES, not straight to SHED."""
        self._checks += 1
        if pressured:
            self.pressured_checks += 1
            self.calm_checks = 0
            if (
                self.pressured_checks >= self.cfg.escalate_after
                and self.mode < Mode.SHED
            ):
                self.mode = Mode(self.mode + 1)
                self.pressured_checks = 0
                self.transitions.append((self._checks, self.mode))
        else:
            self.calm_checks += 1
            self.pressured_checks = 0
            if (
                self.calm_checks >= self.cfg.recover_after
                and self.mode > Mode.NORMAL
            ):
                self.mode = Mode(self.mode - 1)
                self.calm_checks = 0
                self.transitions.append((self._checks, self.mode))
        return self.mode
