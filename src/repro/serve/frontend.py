"""ServeFrontend — the async ingress tier in front of ``FleetRuntime``.

Wires the serving pieces into one fault-tolerant loop:

- concurrent clients ``await submit(SampleRequest)`` and get exactly
  one ``Ack`` back;
- an ``AdmissionController`` decides admit/defer/shed/stale per
  submission from live pressure (queue depth, tick p99, the merge
  governor's comm-budget utilization, the degraded ladder);
- admitted requests accumulate in a ``WindowBuilder``; a batch loop
  closes windows on max-batch-or-max-delay deadlines, logs each to the
  ``WriteAheadLog``, and hands it to a single worker thread that runs
  the (blocking, jitted) ``runtime.tick`` off the event loop;
- a watchdog task folds stall/p99/depth pressure into the
  ``DegradedLadder`` (skip-merge → stale-scores → shed) and back out;
- ``recover()`` resumes after a crash: newest runtime snapshot, then
  contiguous WAL replay — the same ticks, bit-identical, so every
  admitted-but-unacked window trains exactly once.

All metrics flow through the runtime's own ``TelemetrySink`` (the
ingress catalog pre-declared in ``repro.obs.sink``): one registry, one
snapshot-riding state blob, no forked accounting.
"""
from __future__ import annotations

import asyncio
import dataclasses
import queue
import random
import threading
import time
from pathlib import Path
from typing import Callable

import numpy as np

from repro.runtime.runtime import FleetRuntime, TickReport
from repro.serve.admission import (
    ADMIT,
    DEFER,
    SHED,
    STALE,
    AdmissionConfig,
    AdmissionController,
)
from repro.serve.batcher import TickWindow, WindowBuilder
from repro.serve.degraded import DegradedLadder, LadderConfig, Mode
from repro.serve.protocol import Ack, SampleRequest
from repro.serve.wal import WriteAheadLog

__all__ = ["RetryConfig", "ServeConfig", "ServeFrontend"]


@dataclasses.dataclass(frozen=True)
class RetryConfig:
    """Jittered exponential backoff for deferred (busy) submissions."""

    max_attempts: int = 4
    base_s: float = 0.005
    max_s: float = 0.25
    jitter: float = 0.5      # uniform ±fraction of the computed delay

    def delay(self, attempt: int, rng: random.Random) -> float:
        d = min(self.base_s * (2.0 ** attempt), self.max_s)
        return d * (1.0 + self.jitter * (2.0 * rng.random() - 1.0))


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static knobs of one serving front-end."""

    batch: int                       # B — per-device samples per tick window
    max_delay_s: float = 0.01        # deadline: close a non-full window
    close_at_requests: int | None = None  # fullness target (None = n_devices)
    max_inflight_windows: int = 2    # closed-but-unfinished window bound
    admission: AdmissionConfig = dataclasses.field(
        default_factory=AdmissionConfig
    )
    ladder: LadderConfig = dataclasses.field(default_factory=LadderConfig)
    retry: RetryConfig = dataclasses.field(default_factory=RetryConfig)
    wal_dir: str | Path | None = None  # None = no write-ahead log (no replay)
    tick_deadline_s: float = 1.0     # worker stall threshold (watchdog)
    watchdog_interval_s: float = 0.02
    drain_timeout_s: float = 30.0
    warmup: bool = True              # compile the tick jits in start(), so
                                     # first-tick XLA compilation can't trip
                                     # the stall watchdog into degraded mode
    seed: int = 0                    # retry-jitter rng seed
    pre_tick: Callable[[TickWindow], None] | None = None  # test/bench hook,
                                     # runs on the worker thread before each
                                     # tick (stall injection)


class ServeFrontend:
    """One ingress tier bound to one resident runtime."""

    def __init__(
        self,
        runtime: FleetRuntime,
        config: ServeConfig,
        *,
        fallback: np.ndarray | None = None,
    ) -> None:
        if runtime.telemetry is None:
            raise ValueError(
                "ServeFrontend requires RuntimeConfig(telemetry=...): the "
                "ingress counters, the degraded watchdog's p99 signal, and "
                "crash-continuity all live in the telemetry sink"
            )
        self.runtime = runtime
        self.config = config
        self.telemetry = runtime.telemetry
        d = runtime.n_devices
        if fallback is None:
            # (D, F, Ñ) stacked input weights carry the feature dim
            n_features = int(runtime.states.params.alpha.shape[1])
            fallback = np.zeros((d, n_features), np.float32)
        self.builder = WindowBuilder(d, config.batch, fallback)
        self.admission = AdmissionController(
            config.admission, capacity=d * config.admission.max_queue_per_device
        )
        self.ladder = DegradedLadder(config.ladder)
        self.wal = (
            WriteAheadLog(config.wal_dir) if config.wal_dir is not None else None
        )
        self._close_at = (
            config.close_at_requests if config.close_at_requests is not None else d
        )
        self._rng = random.Random(config.seed)
        self._seq = runtime.tick_no
        self._futures: dict[int, asyncio.Future] = {}
        self._submit_t: dict[int, float] = {}
        self._client_inflight: dict[str, int] = {}
        self._last_scores = np.full(d, np.nan, np.float64)
        self._last_drifted = np.zeros(d, bool)
        self._inflight_windows = 0
        self._tick_started: float | None = None
        self._failed: str | None = None
        self._running = False
        self._tasks: list[asyncio.Task] = []
        self._worker: threading.Thread | None = None
        self._dispatch_q: queue.Queue = queue.Queue()
        self._loop: asyncio.AbstractEventLoop | None = None
        self._have_work = asyncio.Event()
        self._full = asyncio.Event()
        self._slots = asyncio.Semaphore(config.max_inflight_windows)
        self._idle = asyncio.Event()
        self._idle.set()

    # ------------------------------------------------------------- lifecycle

    async def start(self) -> None:
        if self._running:
            return
        self._loop = asyncio.get_running_loop()
        if self.config.warmup:
            await self._loop.run_in_executor(
                None, self.runtime.warmup, self.config.batch
            )
        self._running = True
        self._worker = threading.Thread(
            target=self._worker_loop, name="serve-tick-worker", daemon=True
        )
        self._worker.start()
        self._tasks = [
            asyncio.create_task(self._batch_loop(), name="serve-batcher"),
            asyncio.create_task(self._watchdog_loop(), name="serve-watchdog"),
        ]

    async def stop(self, *, drain: bool = True) -> None:
        if drain and self._running:
            try:
                await asyncio.wait_for(
                    self._drained(), timeout=self.config.drain_timeout_s
                )
            except asyncio.TimeoutError:
                pass
        self._running = False
        self._have_work.set()  # wake the batch loop so it can exit
        for task in self._tasks:
            task.cancel()
        for task in self._tasks:
            try:
                await task
            except (asyncio.CancelledError, Exception):
                pass
        self._tasks = []
        if self._worker is not None:
            self._dispatch_q.put(None)
            await asyncio.get_running_loop().run_in_executor(
                None, self._worker.join
            )
            self._worker = None

    async def _drained(self) -> None:
        while self.builder.depth > 0 or self._inflight_windows > 0:
            self._idle.clear()
            await self._idle.wait()

    # --------------------------------------------------------------- ingress

    async def submit(self, req: SampleRequest) -> Ack:
        """One submission, one eventual Ack. Shed/busy/stale answer
        immediately; admitted requests resolve when their tick lands."""
        tel = self.telemetry
        t0 = time.perf_counter()
        if self._failed is not None:
            tel.ingress_shed.labels(reason="failed").inc()
            return Ack(req.request_id, "shed", reason=self._failed)
        if not self.builder.can_fit(req):
            tel.ingress_shed.labels(reason="malformed").inc()
            return Ack(
                req.request_id, "shed",
                reason=f"device/burst/features out of range for this fleet "
                       f"(D={self.builder.n_devices}, B={self.builder.batch}, "
                       f"F={self.builder.n_features})",
            )
        t99 = self.telemetry.tick_seconds
        verdict, reason = self.admission.decide(
            req,
            mode=self.ladder.mode,
            device_depth=self.builder.device_depth(req.device),
            client_inflight=self._client_inflight.get(req.client, 0),
            total_depth=self.builder.depth,
            tick_p99_s=t99.quantile(0.99) if t99.count else None,
            budget_utilization=self.runtime.governor.budget_utilization(),
        )
        tel.ingress_admission_seconds.observe(time.perf_counter() - t0)
        if verdict == SHED:
            tel.ingress_shed.labels(reason=reason).inc()
            return Ack(req.request_id, "shed", reason=reason)
        if verdict == DEFER:
            tel.ingress_deferred.labels(reason=reason).inc()
            return Ack(req.request_id, "busy", reason=reason)
        if verdict == STALE:
            tel.ingress_stale.inc()
            score = self._last_scores[req.device]
            return Ack(
                req.request_id, "stale",
                score=None if np.isnan(score) else float(score),
                drifted=bool(self._last_drifted[req.device]),
                latency_s=time.perf_counter() - t0,
                reason=reason,
            )
        assert verdict == ADMIT
        fut: asyncio.Future = asyncio.get_running_loop().create_future()
        self._futures[req.request_id] = fut
        self._submit_t[req.request_id] = t0
        self._client_inflight[req.client] = (
            self._client_inflight.get(req.client, 0) + 1
        )
        self.builder.add(req)
        tel.ingress_accepted.inc()
        tel.ingress_queue_depth.set(self.builder.depth)
        self._have_work.set()
        self._idle.clear()
        if self.builder.depth >= self._close_at:
            self._full.set()
        return await fut

    async def submit_with_retries(self, req: SampleRequest) -> Ack:
        """submit() plus jittered exponential backoff on ``busy``."""
        cfg = self.config.retry
        ack = await self.submit(req)
        attempt = 0
        while ack.status == "busy" and attempt + 1 < cfg.max_attempts:
            await asyncio.sleep(cfg.delay(attempt, self._rng))
            attempt += 1
            self.telemetry.ingress_retried.inc()
            ack = await self.submit(req)
        return dataclasses.replace(ack, attempts=attempt + 1)

    # ------------------------------------------------------------ batch loop

    async def _batch_loop(self) -> None:
        cfg = self.config
        while self._running:
            await self._have_work.wait()
            if not self._running:
                break
            try:
                await asyncio.wait_for(
                    self._full.wait(), timeout=cfg.max_delay_s
                )
            except asyncio.TimeoutError:
                pass
            self._full.clear()
            # backpressure on the runtime itself: never more than
            # max_inflight_windows closed-but-unfinished windows
            await self._slots.acquire()
            window = self.builder.close(
                self._seq, allow_merge=self.ladder.mode < Mode.SKIP_MERGE
            )
            if window is None:
                self._slots.release()
                self._have_work.clear()
                continue
            if self.wal is not None:
                self.wal.append(window)
            self._seq += 1
            self._inflight_windows += 1
            self.telemetry.ingress_queue_depth.set(self.builder.depth)
            if self.builder.depth == 0:
                self._have_work.clear()
            self._dispatch_q.put(window)

    def _worker_loop(self) -> None:
        """Single consumer of closed windows — runtime.tick is blocking
        and stateful, so it runs here, strictly in seq order."""
        while True:
            window = self._dispatch_q.get()
            if window is None:
                return
            self._tick_started = time.perf_counter()
            report: TickReport | None = None
            err: BaseException | None = None
            try:
                if self.config.pre_tick is not None:
                    self.config.pre_tick(window)
                report = self.runtime.tick(
                    window.batch,
                    served=window.served,
                    allow_merge=window.allow_merge,
                )
                snap_every = self.runtime.config.snapshot_every
                if (
                    self.wal is not None
                    and self.runtime.ckpt is not None
                    and snap_every
                    and self.runtime.tick_no % snap_every == 0
                ):
                    # the runtime just snapshotted: everything below
                    # tick_no is durable, the log can shrink
                    self.wal.gc(self.runtime.tick_no)
            except BaseException as e:  # noqa: BLE001 — must reach the acks
                err = e
            finally:
                self._tick_started = None
            assert self._loop is not None
            self._loop.call_soon_threadsafe(
                self._complete_window, window, report, err
            )

    def _complete_window(
        self,
        window: TickWindow,
        report: TickReport | None,
        err: BaseException | None,
    ) -> None:
        tel = self.telemetry
        now = time.perf_counter()
        if report is not None:
            served = np.flatnonzero(window.served)
            self._last_scores[served] = report.losses[served]
            self._last_drifted = report.drifted.astype(bool)
        elif err is not None:
            # fail-stop: a raised tick desynchronizes window seq from
            # runtime.tick_no, so this front-end stops admitting; the
            # durable path (snapshot + WAL) is the recovery story
            self._failed = f"tick {window.seq} raised: {err!r}"
        for req in window.requests:
            fut = self._futures.pop(req.request_id, None)
            t0 = self._submit_t.pop(req.request_id, now)
            n = self._client_inflight.get(req.client, 0)
            if n <= 1:
                self._client_inflight.pop(req.client, None)
            else:
                self._client_inflight[req.client] = n - 1
            if fut is None or fut.done():
                continue
            if err is not None:
                fut.set_result(Ack(
                    req.request_id, "failed",
                    latency_s=now - t0, reason=repr(err),
                ))
                continue
            assert report is not None
            latency = now - t0
            tel.ingress_acked.inc()
            tel.ingress_request_seconds.observe(latency)
            fut.set_result(Ack(
                req.request_id, "ok",
                tick=report.tick,
                score=float(report.losses[req.device]),
                drifted=bool(report.drifted[req.device]),
                latency_s=latency,
            ))
        self._inflight_windows -= 1
        self._slots.release()
        if self.builder.depth == 0 and self._inflight_windows == 0:
            self._idle.set()

    # -------------------------------------------------------------- watchdog

    async def _watchdog_loop(self) -> None:
        cfg = self.config
        tel = self.telemetry
        while self._running:
            await asyncio.sleep(cfg.watchdog_interval_s)
            started = self._tick_started
            stalled = (
                started is not None
                and time.perf_counter() - started > cfg.tick_deadline_s
            )
            slo = cfg.admission.slo_p99_s
            t99 = tel.tick_seconds
            p99_over = (
                slo is not None and t99.count > 0 and t99.quantile(0.99) > slo
            )
            depth_high = (
                self.builder.depth / self.admission.capacity
                >= cfg.admission.depth_high_frac
            )
            before = self.ladder.mode
            after = self.ladder.check(stalled or p99_over or depth_high)
            if after != before:
                tel.ingress_degraded_mode.set(int(after))
                tel.ingress_transitions.labels(mode=after.name.lower()).inc()

    # -------------------------------------------------------------- recovery

    def recover(self) -> tuple[int, int]:
        """Crash-restart entry point (call BEFORE ``start()``): restore
        the newest runtime snapshot, then replay the contiguous WAL
        suffix — bit-identical inputs, so the replayed ticks equal the
        lost ones and admitted-but-unacked windows train exactly once.
        Returns (restored_tick, replayed_windows)."""
        if self._running:
            raise RuntimeError("recover() must run before start()")
        try:
            restored = self.runtime.restore()
        except FileNotFoundError:
            restored = self.runtime.tick_no  # no snapshot yet: cold start
        replayed = 0
        if self.wal is not None:
            self.wal.gc(restored)
            for seq in self.wal.replayable(restored):
                batch, served, allow = self.wal.load(seq)
                report = self.runtime.tick(
                    batch, served=served, allow_merge=allow
                )
                live = np.flatnonzero(served)
                self._last_scores[live] = report.losses[live]
                self._last_drifted = report.drifted.astype(bool)
                self.telemetry.ingress_replayed.inc()
                replayed += 1
        self._seq = self.runtime.tick_no
        return restored, replayed
