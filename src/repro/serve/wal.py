"""Write-ahead log of closed tick windows — the crash-replay source.

The durability contract of the serving front-end: a window is logged
*before* its tick is dispatched, and the log entry carries everything
the tick consumed (the exact padded ``(D, B, F)`` batch, the served
mask, the merge veto). After a SIGKILL the restart resumes from the
newest runtime snapshot and replays every logged window with a seq at
or past the restored tick — bit-identical inputs, so the replayed
ticks reproduce the lost ticks exactly and every request that was
admitted-but-unacked at the kill gets trained and acked exactly once.

Entries are one ``.npz`` per window, written tmp + ``os.replace`` like
the checkpoint store: a crash mid-write can only ever leave a ``*.tmp``
turd, never a torn entry under the real name. ``gc(before)`` prunes
entries already covered by a snapshot (called after each runtime
snapshot), so the log stays bounded by the snapshot cadence.
"""
from __future__ import annotations

import logging
import os
import tempfile
from pathlib import Path

import numpy as np

from repro.serve.batcher import TickWindow

__all__ = ["WriteAheadLog"]

_FMT = "wal_{:08d}.npz"


class WriteAheadLog:
    """Directory of per-window npz entries keyed by tick seq."""

    def __init__(self, dir: str | Path) -> None:
        self.dir = Path(dir)
        self.dir.mkdir(parents=True, exist_ok=True)
        # a previous process's in-flight write is junk by definition
        for tmp in self.dir.glob("*.tmp"):
            tmp.unlink(missing_ok=True)

    def _path(self, seq: int) -> Path:
        return self.dir / _FMT.format(seq)

    def append(self, window: TickWindow) -> Path:
        """Durably log one closed window (atomic rename)."""
        path = self._path(window.seq)
        fd, tmp = tempfile.mkstemp(
            dir=self.dir, prefix=path.name, suffix=".tmp"
        )
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(
                    f,
                    seq=np.asarray(window.seq, np.int64),
                    batch=window.batch,
                    served=window.served,
                    allow_merge=np.asarray(window.allow_merge, np.int64),
                )
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            Path(tmp).unlink(missing_ok=True)
            raise
        return path

    def entries(self) -> list[int]:
        """Logged seqs, ascending. A ``wal_*.npz`` name that does not
        parse as a seq is skipped with a warning naming the file: a
        corrupted rename would otherwise masquerade as a benign gap and
        make the resulting ``replayable()`` failure undiagnosable."""
        seqs = []
        for p in self.dir.glob("wal_*.npz"):
            try:
                seqs.append(int(p.stem.split("_")[1]))
            except (IndexError, ValueError):
                logging.getLogger(__name__).warning(
                    "WriteAheadLog: skipping malformed WAL filename %s "
                    "(expected wal_<seq:08d>.npz) — if a replay gap "
                    "follows, this file is the suspect", p,
                )
                continue
        return sorted(seqs)

    def load(self, seq: int) -> tuple[np.ndarray, np.ndarray, bool]:
        """(batch, served, allow_merge) of one logged window."""
        with np.load(self._path(seq)) as z:
            return (
                z["batch"],
                z["served"].astype(bool),
                bool(int(z["allow_merge"])),
            )

    def replayable(self, from_seq: int) -> list[int]:
        """Contiguous run of logged seqs starting at ``from_seq``.

        Entries below ``from_seq`` are already inside the snapshot
        being restored. A gap mid-run means the log lost a window some
        later logged window's tick depended on — replay past it would
        silently diverge from the pre-crash trajectory, so that is an
        error; entries from a contiguous prefix are safe."""
        seqs = [s for s in self.entries() if s >= from_seq]
        run: list[int] = []
        want = from_seq
        for s in seqs:
            if s != want:
                raise RuntimeError(
                    f"write-ahead log gap: expected seq {want}, found {s} "
                    f"(entries {seqs}); the log cannot replay past a hole"
                )
            run.append(s)
            want += 1
        return run

    def gc(self, before: int) -> int:
        """Drop entries with seq < ``before`` (covered by a snapshot)."""
        dropped = 0
        for s in self.entries():
            if s < before:
                self._path(s).unlink(missing_ok=True)
                dropped += 1
        return dropped
