"""Request/ack protocol of the async serving front-end.

One ``SampleRequest`` is one client's sample burst for one device: up
to a tick window's per-device budget of feature rows. The front-end
answers every submission with exactly one ``Ack`` — immediately for
shed/busy outcomes, after the tick that trained on the samples for
admitted ones. The ack carries the drift-signal score (the device's
mean ae_score of the batch it rode in) so a client sees the same
number the runtime's TickReport records.

Statuses:

- ``ok``      — admitted, trained, scored in the ack'd tick
- ``stale``   — answered from the last known score without training
                (the STALE_SCORES degraded rung); samples NOT ingested
- ``shed``    — rejected outright (queue full under a shed policy, or
                the SHED degraded rung); safe to retry later
- ``busy``    — deferred by backpressure; retry with backoff
                (``submit_with_retries`` automates this)
- ``failed``  — the tick that carried the request raised
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

__all__ = ["SampleRequest", "Ack", "request_id"]

_ids = itertools.count()


def request_id() -> int:
    """Process-unique monotonically increasing request id."""
    return next(_ids)


@dataclasses.dataclass(frozen=True)
class SampleRequest:
    """One client's sample burst for one device."""

    device: int
    x: np.ndarray          # (k, n_features) sample rows, k >= 1
    client: str = "anon"   # fair-share accounting key
    request_id: int = dataclasses.field(default_factory=request_id)

    def __post_init__(self):
        x = np.asarray(self.x, np.float32)
        if x.ndim == 1:
            x = x[None, :]
        if x.ndim != 2 or x.shape[0] < 1:
            raise ValueError(
                f"request samples must be (k, n_features) with k>=1; "
                f"got shape {np.asarray(self.x).shape}"
            )
        object.__setattr__(self, "x", x)

    @property
    def n_samples(self) -> int:
        return self.x.shape[0]


@dataclasses.dataclass(frozen=True)
class Ack:
    """The front-end's single, final answer to one submission."""

    request_id: int
    status: str                  # "ok" | "stale" | "shed" | "busy" | "failed"
    tick: int | None = None      # tick that served it (ok), else None
    score: float | None = None   # device mean ae_score (ok/stale)
    drifted: bool | None = None  # device quarantine flag after the tick
    attempts: int = 1            # submissions incl. retries (retry helper)
    latency_s: float | None = None  # submit-to-ack wall clock
    reason: str | None = None    # shed/busy cause, or the tick's error

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    @property
    def retryable(self) -> bool:
        return self.status in ("busy", "shed")
