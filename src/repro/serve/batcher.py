"""Dynamic batcher: admitted requests → constant-shape tick windows.

The runtime's compile-once tick wants a dense ``(D, B, F)`` batch every
time; live traffic is ragged — some devices got several requests this
window, most got none. ``WindowBuilder`` bridges the two: admitted
requests accumulate in per-device FIFO queues, and ``close()`` cuts a
``TickWindow`` that

- takes whole requests per device while their samples fit the ``B``
  budget (a request is never split across ticks — its ack must
  correspond to exactly one tick),
- pads a partially-filled device row by cycling its own taken samples
  (harmless extra k=1 steps on real data from this window),
- pads a completely idle device with its last-known sample (the
  ``fallback`` row) and clears its bit in the ``served`` mask, so the
  runtime's where-merge keeps that device's model and detector state
  bit-for-bit untouched.

The window also records exactly which requests it carries — the unit
of acking, and the unit of write-ahead-log replay after a crash.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.serve.protocol import SampleRequest

__all__ = ["TickWindow", "WindowBuilder"]


@dataclasses.dataclass
class TickWindow:
    """One closed window: the dense batch plus its provenance."""

    seq: int                   # tick number this window is destined for
    batch: np.ndarray          # (D, B, F) dense tick batch
    served: np.ndarray         # (D,) bool — devices carrying real samples
    allow_merge: bool          # degraded skip-merge veto, frozen at close
    requests: list[SampleRequest]  # exactly the requests aboard
    n_samples: int             # real (non-padding) sample rows aboard

    @property
    def n_requests(self) -> int:
        return len(self.requests)


class WindowBuilder:
    """Per-device request queues + deadline-window assembly."""

    def __init__(self, n_devices: int, batch: int, fallback: np.ndarray):
        fallback = np.asarray(fallback, np.float32)
        if fallback.shape[0] != n_devices or fallback.ndim != 2:
            raise ValueError(
                f"fallback must be (n_devices={n_devices}, n_features); "
                f"got {fallback.shape}"
            )
        self.n_devices = n_devices
        self.batch = batch
        self.n_features = fallback.shape[1]
        self.fallback = fallback.copy()
        self.pending: list[deque[SampleRequest]] = [
            deque() for _ in range(n_devices)
        ]
        self.depth = 0  # admitted requests not yet cut into a window

    def device_depth(self, device: int) -> int:
        return len(self.pending[device])

    def can_fit(self, req: SampleRequest) -> bool:
        """Shape admissibility (not load!): the request must be able to
        ride SOME window — device in range, burst within the budget."""
        return (
            0 <= req.device < self.n_devices
            and req.n_samples <= self.batch
            and req.x.shape[1] == self.n_features
        )

    def add(self, req: SampleRequest) -> None:
        if not self.can_fit(req):
            raise ValueError(
                f"request {req.request_id} does not fit: device "
                f"{req.device}/{self.n_devices}, burst {req.n_samples}/"
                f"{self.batch}, features {req.x.shape[1]}/{self.n_features}"
            )
        self.pending[req.device].append(req)
        self.depth += 1

    def close(self, seq: int, *, allow_merge: bool = True) -> TickWindow | None:
        """Cut one window. Returns None when nothing is pending (an
        empty tick is not dispatched — the runtime rejects zero-sample
        batches by contract)."""
        if self.depth == 0:
            return None
        d, b, f = self.n_devices, self.batch, self.n_features
        # Head-blocked scan BEFORE any queue mutation: a head request
        # that alone exceeds the window budget can never ride ANY window
        # (every later close hits the same head), so padding around it
        # would leak it in `depth` forever. Raising pre-mutation keeps
        # the depth invariant exact — no request was popped yet.
        for dev in range(d):
            q = self.pending[dev]
            if q and q[0].n_samples > b:
                head = q[0]
                raise ValueError(
                    f"head-blocked queue on device {dev}: request "
                    f"{head.request_id} carries {head.n_samples} samples "
                    f"but the window budget is {b}; it can never be "
                    f"dispatched (admission via add() caps bursts at the "
                    f"budget — this request bypassed it)"
                )
        batch = np.empty((d, b, f), np.float32)
        served = np.zeros(d, bool)
        taken: list[SampleRequest] = []
        n_samples = 0
        for dev in range(d):
            q = self.pending[dev]
            if not q:
                # idle device: pad with its last-known sample; served
                # stays False so the runtime leaves its state untouched
                batch[dev] = self.fallback[dev]
                continue
            rows: list[np.ndarray] = []
            used = 0
            while q and used + q[0].n_samples <= b:
                req = q.popleft()
                self.depth -= 1
                taken.append(req)
                rows.append(req.x)
                used += req.n_samples
            dense = np.concatenate(rows, axis=0)
            n_samples += used
            if used < b:
                # cycle this window's own samples into the padding rows:
                # extra k=1 steps on data the device legitimately served
                reps = -(-b // used)  # ceil
                dense = np.tile(dense, (reps, 1))[:b]
            batch[dev] = dense
            served[dev] = True
            self.fallback[dev] = dense[used - 1]
        if not taken:
            return None
        return TickWindow(
            seq=seq, batch=batch, served=served,
            allow_merge=allow_merge, requests=taken, n_samples=n_samples,
        )
