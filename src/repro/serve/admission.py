"""Admission controller — who gets into the next tick window.

Every submission gets an explicit decision before it touches a queue:

1. the degraded ladder's SHED / STALE_SCORES rungs answer immediately;
2. a full per-device queue applies the overflow policy (``"defer"``
   asks the client to retry with backoff, ``"shed"`` rejects);
3. a client over its fair-share cap of in-flight requests is deferred
   (one flooding client must not starve the rest — the cap is the
   flood leg's bound);
4. global queue depth near capacity defers (backpressure);
5. a tick-latency p99 over the SLO defers — but only while the queue
   is also non-trivially loaded, so a breach measured during a quiet
   period cannot deadlock admission shut;
6. the governor's comm-budget utilization near its ceiling defers
   (admitting more traffic only grows a queue the merge cadence
   cannot drain).

Decisions are (verdict, reason) so the telemetry shed/deferred
counters record WHY — the benchmark asserts on the reasons.
"""
from __future__ import annotations

import dataclasses

from repro.serve.degraded import Mode
from repro.serve.protocol import SampleRequest

__all__ = ["AdmissionConfig", "AdmissionController", "ADMIT", "DEFER", "SHED", "STALE"]

ADMIT = "admit"
DEFER = "defer"   # retryable: client backs off and resubmits
SHED = "shed"     # rejected outright
STALE = "stale"   # answered from the stale-score cache, not ingested


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    max_queue_per_device: int = 8    # pending requests per device queue
    client_cap: int = 64             # in-flight requests per client
    depth_high_frac: float = 0.9     # global depth fraction that defers
    slo_p99_s: float | None = None   # tick p99 SLO; None = not enforced
    slo_min_depth_frac: float = 0.25  # p99 deferral needs this much load
    budget_defer_frac: float | None = 0.95  # governor budget utilization
                                            # that defers; None = ignore
    overflow: str = "defer"          # "defer" | "shed" on a full device queue

    def __post_init__(self):
        if self.overflow not in ("defer", "shed"):
            raise ValueError(f"overflow must be defer|shed, got {self.overflow!r}")


class AdmissionController:
    """Stateless policy over live pressure signals (the state lives in
    the builder queues, the ladder, and the governor)."""

    def __init__(self, cfg: AdmissionConfig, capacity: int) -> None:
        self.cfg = cfg
        self.capacity = max(capacity, 1)  # global depth ceiling (requests)

    def decide(
        self,
        req: SampleRequest,
        *,
        mode: Mode,
        device_depth: int,
        client_inflight: int,
        total_depth: int,
        tick_p99_s: float | None,
        budget_utilization: float,
    ) -> tuple[str, str]:
        cfg = self.cfg
        if mode >= Mode.SHED:
            return SHED, "degraded"
        if mode >= Mode.STALE_SCORES:
            return STALE, "degraded"
        if device_depth >= cfg.max_queue_per_device:
            if cfg.overflow == "shed":
                return SHED, "queue_full"
            return DEFER, "queue_full"
        if client_inflight >= cfg.client_cap:
            return DEFER, "client_cap"
        depth_frac = total_depth / self.capacity
        if depth_frac >= cfg.depth_high_frac:
            return DEFER, "backpressure"
        if (
            cfg.slo_p99_s is not None
            and tick_p99_s is not None
            and tick_p99_s > cfg.slo_p99_s
            and depth_frac >= cfg.slo_min_depth_frac
        ):
            return DEFER, "slo"
        if (
            cfg.budget_defer_frac is not None
            and budget_utilization >= cfg.budget_defer_frac
        ):
            return DEFER, "comm_budget"
        return ADMIT, "admit"
