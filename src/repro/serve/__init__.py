"""repro.serve — fault-tolerant async serving front-end.

The production entry path in front of ``FleetRuntime``: many
concurrent clients submit per-device sample bursts, a dynamic batcher
closes constant-shape tick windows on max-batch-or-max-delay
deadlines, an admission controller applies backpressure (queue depth,
tick p99 SLO, the merge governor's comm budget) with explicit
shed-vs-defer outcomes and per-client fair-share caps, a degraded-mode
ladder (skip-merge → serve-stale-scores → shed) keeps the fleet
answering under overload, and a write-ahead log of closed windows
makes a SIGKILL recoverable: restore the newest snapshot, replay the
logged suffix bit-identically, ack every admitted request exactly
once.

See README "Serving under load" and ``benchmarks/serve_ingress.py``
for the measured contract.
"""
from repro.serve.admission import (
    ADMIT,
    DEFER,
    SHED,
    STALE,
    AdmissionConfig,
    AdmissionController,
)
from repro.serve.batcher import TickWindow, WindowBuilder
from repro.serve.degraded import DegradedLadder, LadderConfig, Mode
from repro.serve.frontend import RetryConfig, ServeConfig, ServeFrontend
from repro.serve.protocol import Ack, SampleRequest, request_id
from repro.serve.wal import WriteAheadLog

__all__ = [
    "ADMIT", "DEFER", "SHED", "STALE",
    "AdmissionConfig", "AdmissionController",
    "TickWindow", "WindowBuilder",
    "DegradedLadder", "LadderConfig", "Mode",
    "RetryConfig", "ServeConfig", "ServeFrontend",
    "Ack", "SampleRequest", "request_id",
    "WriteAheadLog",
]
