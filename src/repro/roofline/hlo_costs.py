"""Exact-ish HLO cost model with while-trip-count propagation.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE. Our
programs put everything interesting inside scans (layers, microbatches,
flash KV chunks), so its FLOPs under-count by orders of magnitude. This
module re-derives the roofline inputs by walking the post-SPMD optimized
HLO text:

  • computation multipliers: entry = 1; while bodies/conds inherit
    caller × known_trip_count (nested scans multiply);
  • FLOPs: 2 · |result| · |contraction| per dot (models are
    dot-dominated; elementwise FLOPs are ignored and noted);
  • HBM bytes: for every instruction in a CONTROL computation (entry /
    while / conditional / call targets) — result bytes + operand-read
    bytes. Instructions inside fused computations stay in registers/VMEM
    and are skipped; the fusion instruction itself carries the traffic.
  • collective bytes: result bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute (× multiplier).

All numbers are PER DEVICE (post-SPMD shapes are per-device).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def _dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    opcode: str
    operands: list[str]
    attrs: str
    raw_operands: str = ""


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    shapes: dict[str, str]          # symbol -> result type string


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*\{")
_INSTR = re.compile(r"^\s*(ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")


def _split_result_op(rest: str) -> tuple[str, str, str]:
    """'f32[2]{0} dot(%a, %b), attrs' → (result_type, opcode, tail)."""
    rest = rest.strip()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        result = rest[: i + 1]
        rest2 = rest[i + 1:].strip()
    else:
        sp = rest.find(" ")
        result = rest[:sp]
        rest2 = rest[sp + 1:].strip()
    m = re.match(r"([\w\-]+)\((.*)$", rest2)
    if not m:
        return result, "", ""
    return result, m.group(1), m.group(2)


def parse_hlo(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = ""
    current: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        is_header = line.endswith("{") and ") -> " in line and not line.lstrip().startswith("%param")
        hdr = _COMP_HDR.match(line.strip()) if is_header else None
        if hdr:
            name = hdr.group(2)
            current = Computation(name=name, instrs=[], shapes={})
            comps[name] = current
            if hdr.group(1):
                entry = name
            # parameters carry shapes in the header
            for pm in re.finditer(r"([\w.\-]+):\s*([a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)", hdr.group(3)):
                current.shapes[pm.group(1)] = pm.group(2)
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        im = _INSTR.match(line)
        if not im:
            continue
        name = im.group(2)
        result, opcode, tail = _split_result_op(im.group(3))
        # operand list: %names up to the matching close paren
        depth, j = 1, 0
        for j, ch in enumerate(tail):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        operand_str = tail[:j]
        attrs = tail[j + 1:]
        operands = re.findall(r"%([\w.\-]+)", operand_str)
        current.shapes[name] = result
        current.instrs.append(Instr(name, result, opcode, operands, attrs, operand_str))
    return comps, entry


def _multipliers(comps: dict[str, Computation], entry: str) -> dict[str, float]:
    """Effective execution count per computation."""
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # topological-ish propagation: iterate until fixpoint (call graph is a DAG)
    changed = True
    guard = 0
    while changed and guard < 100:
        changed = False
        guard += 1
        for cname, comp in comps.items():
            m = mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for ins in comp.instrs:
                trip = 1
                tm = re.search(r'known_trip_count"?\s*[:=]\s*\{\s*"?n"?\s*[:=]\s*"?(\d+)', ins.attrs)
                if tm:
                    trip = int(tm.group(1))
                for kind, factor in (("body", trip), ("condition", trip + 1),
                                     ("calls", 1), ("to_apply", 1)):
                    for cm in re.finditer(kind + r"=%?([\w.\-]+)", ins.attrs):
                        tgt = cm.group(1)
                        want = m * factor
                        if abs(mult.get(tgt, 0.0) - want) > 1e-9 and want > mult.get(tgt, 0.0):
                            mult[tgt] = want
                            changed = True
                bm = re.search(r"branch_computations=\{([^}]*)\}", ins.attrs)
                if bm:
                    for t in re.findall(r"%?([\w.\-]+)", bm.group(1)):
                        if t in comps and mult.get(t, 0.0) < m:
                            mult[t] = m
                            changed = True
    return dict(mult)


def _fused_targets(comps: dict[str, Computation]) -> set[str]:
    """Computations reached via fusion/reduce/map etc. — no HBM traffic
    of their own; plus everything transitively called from them."""
    fused: set[str] = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.opcode in ("fusion", "reduce", "map", "scatter", "sort",
                              "reduce-window", "select-and-scatter", "all-reduce",
                              "reduce-scatter"):
                for cm in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", ins.attrs):
                    fused.add(cm.group(1))
    # transitive closure
    changed = True
    while changed:
        changed = False
        for f in list(fused):
            comp = comps.get(f)
            if not comp:
                continue
            for ins in comp.instrs:
                for cm in re.finditer(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)", ins.attrs):
                    if cm.group(1) not in fused:
                        fused.add(cm.group(1))
                        changed = True
    return fused


def _fusion_traffic(ins: Instr, comp: Computation, comps: dict) -> int | None:
    """Effective HBM traffic of one fusion call, or None → default model.

    Refinements (both ubiquitous in scanned programs):
      • a fusion parameter consumed ONLY by dynamic-slice/gather reads
        just the extracted regions (per-layer weight slicing out of the
        stacked buffer, row gathers) — not the full buffer every
        iteration;
      • a fusion containing dynamic-update-slice whose result aliases an
        operand (while-carry KV caches / grad stacks) writes only the
        update region.
    """
    m = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
    if not m:
        return None
    target = comps.get(m.group(1))
    if not target or not target.instrs:
        return None

    # fusion parameter index → parameter symbol (`%p = f32[..] parameter(0)`
    # — the index is the raw operand text)
    param_sym: dict[int, str] = {}
    for i in target.instrs:
        if i.opcode == "parameter":
            pm = re.match(r"\s*(\d+)", i.raw_operands)
            if pm:
                param_sym[int(pm.group(1))] = i.name

    consumers: dict[str, list[Instr]] = {}
    for i in target.instrs:
        for op in i.operands:
            consumers.setdefault(op, []).append(i)

    res_b = _shape_elems_bytes(ins.result_type)
    op_bytes = [_shape_elems_bytes(comp.shapes.get(op, "")) for op in ins.operands]

    total = 0
    aliased = any(i.opcode == "dynamic-update-slice" for i in target.instrs) and \
        op_bytes and max(op_bytes) == res_b
    seen_alias = False
    for k in range(len(ins.operands)):
        full = op_bytes[k]
        if aliased and not seen_alias and full == res_b:
            seen_alias = True  # pass-through buffer: reads accounted below
            continue
        eff = full
        sym = param_sym.get(k)
        if sym is not None:
            cons = consumers.get(sym, [])
            if cons and all(c.opcode in ("dynamic-slice", "gather") for c in cons):
                eff = min(full, sum(_shape_elems_bytes(c.result_type) for c in cons))
        total += eff

    if aliased:
        for d in target.instrs:
            if d.opcode == "dynamic-update-slice" and len(d.operands) > 1:
                upd = _shape_elems_bytes(target.shapes.get(d.operands[1], ""))
                total += 2 * upd  # read-modify-write of the region
    else:
        total += res_b
    return total


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "partition-id", "replica-id", "iota",
}


def analyze_hlo(text: str) -> dict[str, float]:
    comps, entry = parse_hlo(text)
    mult = _multipliers(comps, entry)
    fused = _fused_targets(comps)

    flops = 0.0
    bytes_hbm = 0.0
    bytes_attn_interior = 0.0  # traffic inside flash-attention regions —
    # vanishes when attention runs as one fused Pallas kernel (VMEM-resident
    # score chunks); reported separately for the fused-attention roofline.
    coll = {op: 0.0 for op in COLLECTIVES}

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        control = cname not in fused
        for ins in comp.instrs:
            # ---- FLOPs: dots anywhere (fused or not) --------------------
            if ins.opcode == "dot":
                res = _dims(ins.result_type)
                n_res = 1
                for d in res:
                    n_res *= d
                lhs_shape = comp.shapes.get(ins.operands[0], "") if ins.operands else ""
                lhs_dims = _dims(lhs_shape)
                cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
                contract = 1
                if cm and lhs_dims:
                    for d in cm.group(1).split(","):
                        if d:
                            contract *= lhs_dims[int(d)]
                flops += m * 2.0 * n_res * contract
            # ---- collective bytes --------------------------------------
            opbase = ins.opcode.replace("-start", "")
            if opbase in COLLECTIVES and not ins.opcode.endswith("-done"):
                coll[opbase] += m * _shape_elems_bytes(ins.result_type)
            # ---- HBM traffic --------------------------------------------
            if control and ins.opcode not in _SKIP_BYTES_OPS and not ins.opcode.endswith("-done"):
                res_b = _shape_elems_bytes(ins.result_type)
                op_bytes = [
                    _shape_elems_bytes(comp.shapes.get(op, "")) for op in ins.operands
                ]
                if ins.opcode in ("dynamic-slice", "gather"):
                    # reads only the extracted region, not the source buffer
                    b = 2 * res_b
                elif ins.opcode == "dynamic-update-slice":
                    # in-place: read + write only the updated region
                    upd = op_bytes[1] if len(op_bytes) > 1 else res_b
                    b = 2 * upd
                elif ins.opcode == "fusion":
                    ft = _fusion_traffic(ins, comp, comps)
                    b = ft if ft is not None else res_b + sum(op_bytes)
                else:
                    b = res_b + sum(op_bytes)
                bytes_hbm += m * b
                if ("_fa_" in ins.attrs or "flash_attention" in ins.attrs
                        or "fa_forward" in ins.attrs):
                    bytes_attn_interior += m * b
    return {
        "flops": flops,
        "bytes": bytes_hbm,
        "bytes_attn_interior": bytes_attn_interior,
        "collective_bytes": sum(coll.values()),
        "collectives": coll,
    }
