"""Three-term roofline from a compiled dry-run artifact (deliverable g).

    compute    = HLO_FLOPs   / (chips × peak_FLOP/s)
    memory     = HLO_bytes   / (chips × HBM_bw)
    collective = coll_bytes  / (chips × link_bw)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``. Collective
bytes are NOT in cost_analysis: we parse the post-SPMD optimized HLO
text and sum the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op, scaled by any
enclosing while-loop trip count (layer scans place collectives inside
while bodies — without the trip-count scaling a 126-layer model would
report one layer's collectives).
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


@dataclasses.dataclass
class Hardware:
    name: str
    peak_flops: float          # per chip, bf16
    hbm_bw: float              # bytes/s per chip
    link_bw: float             # bytes/s per chip (ICI)


HW_V5E = Hardware(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9)


def _shape_bytes(shape_str: str) -> int:
    """'f32[16,128]' → bytes; tuples handled by the caller."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dt)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def _result_bytes(line: str) -> int:
    """Sum all shapes on the lhs of `%x = <shapes> op(...)`."""
    lhs = line.split("=", 1)[1]
    op_pos = min(
        (lhs.find(op) for op in COLLECTIVE_OPS if lhs.find(op) >= 0), default=-1
    )
    if op_pos < 0:
        return 0
    shapes_part = lhs[:op_pos]
    return sum(
        _shape_bytes(s) for s in re.findall(r"[a-z0-9]+\[[0-9,]*\]", shapes_part)
    )


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-op-type collective bytes, scaled by while-loop trip counts.

    Trip counts are inferred per while body from XLA's
    `known_trip_count={"n":"K"}` / `trip_count="K"` annotations when
    present; collectives outside loops count once. Returns GLOBAL bytes
    (sum over all participating devices' result shapes is approximated
    as result_bytes × 1 — shapes in post-SPMD HLO are already
    per-device).
    """
    totals: dict[str, float] = {op: 0.0 for op in COLLECTIVE_OPS}

    # map computation name -> trip count multiplier from while annotations
    trip: dict[str, int] = {}
    for m in re.finditer(
        r'body=%?([\w.\-]+).*?known_trip_count=\{"?n"?[:=]"?(\d+)"?\}', hlo_text
    ):
        trip[m.group(1)] = int(m.group(2))
    # also catch: while(...), ... backend_config or trip_count attr
    for m in re.finditer(r'body=%?([\w.\-]+)[^\n]*?trip_count="?(\d+)"?', hlo_text):
        trip.setdefault(m.group(1), int(m.group(2)))

    current_comp = None
    for line in hlo_text.splitlines():
        comp_m = re.match(r"^\s*%?([\w.\-]+)\s*\([^)]*\)\s*->", line)
        if comp_m:
            current_comp = comp_m.group(1)
            continue
        if "ENTRY" in line:
            current_comp = "__entry__"
            continue
        if "=" not in line:
            continue
        if not any(op in line for op in COLLECTIVE_OPS):
            continue
        if "-start" in line and "-done" not in line:
            pass  # async start carries the shape; done repeats it
        if "-done" in line:
            continue
        b = _result_bytes(line)
        if b == 0:
            continue
        mult = trip.get(current_comp or "", 1)
        for op in COLLECTIVE_OPS:
            if op in line.split("=", 1)[1]:
                totals[op] += b * mult
                break
    return totals


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    attn_interior_bytes: float
    coll_bytes: float
    coll_breakdown: dict[str, float]
    model_flops: float
    per_device_memory: dict[str, float]
    hw: Hardware = dataclasses.field(default_factory=lambda: HW_V5E)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * self.hw.peak_flops)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * self.hw.hbm_bw)

    @property
    def t_memory_fused_attn(self) -> float:
        """Memory term if attention runs as one fused Pallas kernel
        (kernels/flash_attn.py): score chunks stay in VMEM."""
        return (self.hlo_bytes - self.attn_interior_bytes) / (self.chips * self.hw.hbm_bw)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * self.hw.link_bw)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes, "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_memory_fused_attn_s": self.t_memory_fused_attn,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "per_device_memory": self.per_device_memory,
        }


def roofline_from_compiled(
    compiled,
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    model_flops: float,
) -> RooflineReport:
    """All terms derive from our HLO walk (repro.roofline.hlo_costs) —
    XLA's cost_analysis counts while bodies once and our programs scan
    everything, so its numbers are recorded separately for reference
    (dryrun JSON 'cost_analysis' field) but not used here.

    hlo_costs returns PER-DEVICE numbers; the roofline terms divide
    global work by total chips, so we scale by `chips` first.
    """
    from repro.roofline.hlo_costs import analyze_hlo

    hlo = compiled.as_text()
    walk = analyze_hlo(hlo)
    flops = walk["flops"] * chips
    nbytes = walk["bytes"] * chips
    attn_interior = walk.get("bytes_attn_interior", 0.0) * chips
    coll = {k: v * chips for k, v in walk["collectives"].items()}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": float(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": float(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": float(getattr(ma, "temp_size_in_bytes", 0)),
            "peak_bytes": float(
                getattr(ma, "peak_memory_in_bytes", 0) or
                getattr(ma, "temp_size_in_bytes", 0)
            ),
        }
    except Exception:
        pass
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=nbytes, attn_interior_bytes=attn_interior,
        coll_bytes=sum(coll.values()), coll_breakdown=coll,
        model_flops=model_flops, per_device_memory=mem,
    )
