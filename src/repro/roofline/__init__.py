from repro.roofline.analysis import (
    HW_V5E,
    Hardware,
    RooflineReport,
    collective_bytes,
    roofline_from_compiled,
)

__all__ = ["HW_V5E", "Hardware", "RooflineReport", "collective_bytes", "roofline_from_compiled"]
