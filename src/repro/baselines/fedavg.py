"""BP-NN3-FL — the traditional federated learning baseline (paper §5.3.1).

FedAvg [McMahan et al., ref 10]: each communication round, every client
trains the shared global model locally on its own pattern, the server
averages the locally trained parameter trees, and the average becomes
the next round's global model. The paper runs R=50 rounds; the
comparison point for the one-shot OS-ELM merge.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.bpnn import BPNNConfig, init_bpnn, train_bpnn


class FedAvgConfig(NamedTuple):
    rounds: int = 50
    local_epochs: int = 1


def average_params(trees: Sequence) -> list:
    """The FedAvg server step: elementwise mean of client parameter trees."""
    return jax.tree.map(lambda *xs: jnp.mean(jnp.stack(xs), axis=0), *trees)


def fedavg_round(
    key: jax.Array,
    global_params,
    cfg: BPNNConfig,
    client_data: Sequence[jnp.ndarray],
    local_epochs: int = 1,
):
    """One communication round: local train on each client, then average."""
    locals_ = []
    for ci, xc in enumerate(client_data):
        key, k = jax.random.split(key)
        p = train_bpnn(k, cfg, xc, params=jax.tree.map(jnp.copy, global_params), epochs=local_epochs)
        locals_.append(p)
    return average_params(locals_), key


def run_fedavg(
    key: jax.Array,
    cfg: BPNNConfig,
    client_data: Sequence[np.ndarray],
    fl: FedAvgConfig = FedAvgConfig(),
):
    """Full BP-NN3-FL training: R rounds of local-train + average."""
    client_data = [jnp.asarray(c) for c in client_data]
    global_params = init_bpnn(key, cfg)
    for _ in range(fl.rounds):
        global_params, key = fedavg_round(key, global_params, cfg, client_data, fl.local_epochs)
    return global_params
