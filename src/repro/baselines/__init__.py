from repro.baselines.bpnn import (
    BPNNConfig,
    bpnn3_config,
    bpnn5_config,
    init_bpnn,
    bpnn_predict,
    bpnn_loss,
    bpnn_score,
    train_bpnn,
)
from repro.baselines.fedavg import FedAvgConfig, fedavg_round, run_fedavg

__all__ = [
    "BPNNConfig", "bpnn3_config", "bpnn5_config", "init_bpnn",
    "bpnn_predict", "bpnn_loss", "bpnn_score", "train_bpnn",
    "FedAvgConfig", "fedavg_round", "run_fedavg",
]
