"""BP-NN autoencoder baselines (paper §5.1.2, Table 3).

BP-NN3: 3-layer (one hidden) autoencoder — ReLU hidden, Sigmoid output,
MSE loss, Adam. BP-NN5: 5-layer deep autoencoder (three hidden). These
are the backpropagation comparison points for the OS-ELM results
(Figs. 10/11/15/16) and the local model of the BP-NN3-FL federated
baseline.

Implemented in pure JAX (TensorFlow of the paper is unavailable and
unnecessary — the architectures are plain MLPs).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.activations import get_activation
from repro.optim import adam


class BPNNConfig(NamedTuple):
    n_features: int
    hidden: tuple[int, ...]          # (Ñ1,) for BP-NN3; (Ñ1,Ñ2,Ñ3) for BP-NN5
    g_hidden: str = "relu"
    g_out: str = "sigmoid"
    lr: float = 1e-3
    batch: int = 8
    epochs: int = 20


def bpnn3_config(n_features: int, n1: int, *, batch: int = 8, epochs: int = 20) -> BPNNConfig:
    return BPNNConfig(n_features, (n1,), batch=batch, epochs=epochs)


def bpnn5_config(
    n_features: int, n1: int, n2: int, n3: int, *, batch: int = 8, epochs: int = 20
) -> BPNNConfig:
    return BPNNConfig(n_features, (n1, n2, n3), batch=batch, epochs=epochs)


def init_bpnn(key: jax.Array, cfg: BPNNConfig) -> list[dict]:
    """Glorot-initialized MLP: n -> hidden... -> n."""
    sizes = (cfg.n_features, *cfg.hidden, cfg.n_features)
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, k = jax.random.split(key)
        w = jax.random.normal(k, (a, b)) * jnp.sqrt(2.0 / (a + b))
        params.append({"w": w, "b": jnp.zeros((b,))})
    return params


def bpnn_predict(params: Sequence[dict], cfg: BPNNConfig, x: jnp.ndarray) -> jnp.ndarray:
    g_h = get_activation(cfg.g_hidden)
    g_o = get_activation(cfg.g_out)
    h = x
    for layer in params[:-1]:
        h = g_h(h @ layer["w"] + layer["b"])
    return g_o(h @ params[-1]["w"] + params[-1]["b"])


def bpnn_loss(params, cfg: BPNNConfig, x: jnp.ndarray) -> jnp.ndarray:
    y = bpnn_predict(params, cfg, x)
    return jnp.mean((x - y) ** 2)


def bpnn_score(params, cfg: BPNNConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Per-sample reconstruction MSE — the anomaly score."""
    y = bpnn_predict(params, cfg, x)
    return jnp.mean((x - y) ** 2, axis=-1)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _epoch_fn(params, opt_state, xb, cfg: BPNNConfig):
    """One epoch's scan over pre-shuffled batches. Module-level and
    keyed on the (hashable) config so repeated ``train_bpnn`` calls —
    FedAvg retrains every client every round — share one compilation
    per (config, shape) instead of re-tracing a closure per call."""
    opt = adam(cfg.lr)

    def body(carry, batch):
        p, s = carry
        grads = jax.grad(bpnn_loss)(p, cfg, batch)
        p, s = opt.update(grads, s, p)
        return (p, s), None

    (params, opt_state), _ = jax.lax.scan(body, (params, opt_state), xb)
    return params, opt_state


def train_bpnn(
    key: jax.Array,
    cfg: BPNNConfig,
    x_train: jnp.ndarray,
    *,
    params: Sequence[dict] | None = None,
    epochs: int | None = None,
) -> list[dict]:
    """Mini-batch Adam training for ``epochs`` (paper: E epochs, batch k).

    Uses a jitted scan over shuffled batches per epoch (compiled once
    per (config, shape), shared across calls).
    """
    if params is None:
        params = init_bpnn(key, cfg)
    opt_state = adam(cfg.lr).init(params)
    n = x_train.shape[0]
    nb = n // cfg.batch
    epochs = cfg.epochs if epochs is None else epochs

    for e in range(epochs):
        key, k = jax.random.split(key)
        perm = jax.random.permutation(k, n)[: nb * cfg.batch]
        xb = x_train[perm].reshape(nb, cfg.batch, -1)
        params, opt_state = _epoch_fn(params, opt_state, xb, cfg)
    return list(params)
