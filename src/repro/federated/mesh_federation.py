"""The paper's cooperative model update as a mesh collective.

DESIGN.md §1: E²LM's merge (Eq. 8) is a sum of per-device sufficient
statistics, so on a TPU mesh the federation of N edge devices maps to N
data-parallel shards whose (U, V) are combined with **one
``jax.lax.psum``** over the federation axes — the paper's one-shot
cooperative update, executed as a single all-reduce over ICI instead of
uploads to a parameter server.

Each mesh shard:
  1. sequentially trains its own OS-ELM autoencoder on its local
     (non-IID) stream — `oselm_step_k1` scanned over the stream,
  2. computes (U, V) by Eq. 15 — only when a merge is requested,
  3. psums U and V over ("data",) or ("pod", "data"),
  4. recovers P ← U⁻¹, β ← U⁻¹V locally (every shard ends up with the
     identical merged model, like the paper's Device-A/B symmetry).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import UV, OSELMState, from_uv, oselm_step_k1, to_uv
from repro.federated.compat import revary, shard_map_compat as _shard_map


def _stack_spec(axes: Sequence[str]) -> P:
    """Shard the leading (device) axis of every stacked leaf over the
    federation mesh axes."""
    return P(tuple(axes))


def mesh_cooperative_update(
    states: OSELMState,
    mesh: Mesh,
    axes: Sequence[str] = ("data",),
    *,
    ridge: float = 0.0,
) -> OSELMState:
    """One-shot federated merge of per-shard OS-ELM states.

    ``states`` is a stacked OSELMState whose leaves carry a leading
    shard axis of size prod(mesh.shape[a] for a in axes). Returns the
    merged state broadcast back to every shard (identical values).
    """
    spec = _stack_spec(axes)

    def body(st: OSELMState) -> OSELMState:
        local = jax.tree.map(lambda l: l[0], st)          # this shard's state
        uv = to_uv(local, ridge=ridge)
        u = jax.lax.psum(uv.u, tuple(axes))               # Eq. 8 as all-reduce
        v = jax.lax.psum(uv.v, tuple(axes))
        merged = from_uv(local, UV(u=u, v=v), ridge=ridge)
        return jax.tree.map(lambda l: l[None], merged)

    fn = _shard_map(body, mesh, in_specs=spec, out_specs=spec)
    return jax.jit(fn)(states)


def mesh_federated_train(
    states: OSELMState,
    streams: jnp.ndarray,
    mesh: Mesh,
    axes: Sequence[str] = ("data",),
    *,
    merge_every: int | None = None,
    ridge: float = 0.0,
) -> OSELMState:
    """Train every shard on its local stream, then cooperatively merge.

    ``streams``: (n_shards, steps, features) — shard-axis sharded over
    ``axes``. If ``merge_every`` is given, the stream is chunked and a
    cooperative update runs after every chunk (the paper's "repeatedly
    applied to synchronize" mode); otherwise a single one-shot merge
    runs at the end.
    """
    spec = _stack_spec(axes)

    def local_train(st: OSELMState, xs: jnp.ndarray) -> OSELMState:
        def step(s, x):
            return oselm_step_k1(s, x, x), None

        out, _ = jax.lax.scan(step, st, xs)
        return out

    def body(st: OSELMState, xs: jnp.ndarray) -> OSELMState:
        local = jax.tree.map(lambda l: l[0], st)
        stream = xs[0]  # (steps, features)

        def merge(s: OSELMState) -> OSELMState:
            uv = to_uv(s, ridge=ridge)
            u = jax.lax.psum(uv.u, tuple(axes))
            v = jax.lax.psum(uv.v, tuple(axes))
            return from_uv(s, UV(u=u, v=v), ridge=ridge)

        if merge_every is None:
            local = local_train(local, stream)
            local = merge(local)
        else:
            steps = stream.shape[0]
            n_chunks = steps // merge_every
            chunks = stream[: n_chunks * merge_every].reshape(
                n_chunks, merge_every, -1
            )

            def chunk_step(s, chunk):
                s2 = merge(local_train(s, chunk))
                # psum outputs are device-invariant; the scan carry entered
                # as device-varying — restore the varying type (pvary is
                # psum's dual under shard_map's manual-axes typing). On
                # jax without varying-type tracking this reduces to a cast.
                s2 = jax.tree.map(
                    lambda n, o: revary(jnp.asarray(n, o.dtype), axes), s2, s
                )
                return s2, None

            local, _ = jax.lax.scan(chunk_step, local, chunks)
        return jax.tree.map(lambda l: l[None], local)

    fn = _shard_map(body, mesh, in_specs=(spec, spec), out_specs=spec)
    return jax.jit(fn)(states, streams)
