"""Client-selection strategy hooks (paper §4.2 last paragraph).

The paper merges a predefined device set but cites two selection lines
of work: resource-constrained selection (Nishio & Yonetani, ref [19])
and accuracy-driven selection excluding unsatisfying local models
(Qin et al., ref [20]). We provide both as pluggable strategies for
``cooperative_round(select=...)``.
"""
from __future__ import annotations

from typing import Callable, Mapping, Sequence

SelectFn = Callable[[Sequence[str]], Sequence[str]]


def all_clients(ids: Sequence[str]) -> Sequence[str]:
    """Paper default: the predefined device set merges wholesale."""
    return ids


def resource_constrained_selection(
    budgets: Mapping[str, float], threshold: float
) -> SelectFn:
    """Ref [19]-style: only clients whose (estimated) round time fits the
    deadline participate."""

    def select(ids: Sequence[str]) -> Sequence[str]:
        return [i for i in ids if budgets.get(i, float("inf")) <= threshold]

    return select


def loss_threshold_selection(
    local_losses: Mapping[str, float], max_loss: float
) -> SelectFn:
    """Ref [20]-style: exclude unsatisfying local models (high validation
    loss) from the aggregation."""

    def select(ids: Sequence[str]) -> Sequence[str]:
        return [i for i in ids if local_losses.get(i, float("inf")) <= max_loss]

    return select
