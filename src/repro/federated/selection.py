"""Client-selection strategy hooks (paper §4.2 last paragraph).

The paper merges a predefined device set but cites two selection lines
of work: resource-constrained selection (Nishio & Yonetani, ref [19])
and accuracy-driven selection excluding unsatisfying local models
(Qin et al., ref [20]). We provide both as pluggable strategies for
``cooperative_round(select=...)``.

Two API levels:

- **id-level** (``SelectFn``) — callables over client-id sequences, the
  original per-round hooks used by ``federated.protocol``.
- **fleet-level** (``FleetMaskFn``) — vectorized policies over the
  stacked device axis: a (D,) per-device loss/score array in, a (D,)
  0/1 participation mask out. These are the stateful building blocks
  the resident runtime's merge governor composes every round
  (``repro.runtime.governor``): the mask is a *traced* operand of the
  masked topology merge, so selection decisions never retrace the
  compiled merge.
"""
from __future__ import annotations

from typing import Callable, Mapping, Sequence

import numpy as np

SelectFn = Callable[[Sequence[str]], Sequence[str]]

# (D,) per-device losses -> (D,) bool participation mask
FleetMaskFn = Callable[[np.ndarray], np.ndarray]


def all_clients(ids: Sequence[str]) -> Sequence[str]:
    """Paper default: the predefined device set merges wholesale."""
    return ids


def resource_constrained_selection(
    budgets: Mapping[str, float], threshold: float
) -> SelectFn:
    """Ref [19]-style: only clients whose (estimated) round time fits the
    deadline participate."""

    def select(ids: Sequence[str]) -> Sequence[str]:
        return [i for i in ids if budgets.get(i, float("inf")) <= threshold]

    return select


def loss_threshold_selection(
    local_losses: Mapping[str, float], max_loss: float
) -> SelectFn:
    """Ref [20]-style: exclude unsatisfying local models (high validation
    loss) from the aggregation."""

    def select(ids: Sequence[str]) -> Sequence[str]:
        return [i for i in ids if local_losses.get(i, float("inf")) <= max_loss]

    return select


# --------------------------------------------------- fleet-level (array) hooks


def fleet_loss_threshold(max_loss: float) -> FleetMaskFn:
    """Ref [20] at fleet scale: devices whose current per-tick loss
    exceeds ``max_loss`` sit the round out. Non-finite losses are always
    excluded."""

    def select(losses: np.ndarray) -> np.ndarray:
        losses = np.asarray(losses)
        return np.isfinite(losses) & (losses <= max_loss)

    return select


def fleet_resource_budget(round_cost: np.ndarray, deadline: float) -> FleetMaskFn:
    """Ref [19] at fleet scale: a fixed (D,) per-device round-time
    estimate; devices that cannot meet the deadline are excluded
    regardless of their loss."""
    fits = np.asarray(round_cost) <= deadline

    def select(losses: np.ndarray) -> np.ndarray:
        return fits

    return select
