"""jax version compatibility for shard_map manual-axes code.

Two API shifts are bridged for every shard_map user in the repo
(``federated.mesh_federation``, ``fleet.sharded``):

- ``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax``
  proper in jax 0.6.
- jax >= 0.6 tracks varying manual axes: psum outputs are
  device-invariant and must be re-varied (``jax.lax.pvary``) before
  flowing out through a sharded out_spec or back into a device-varying
  scan carry. Older jax (<= 0.4.x) has neither ``jax.typeof`` nor
  ``pvary`` and doesn't track variance, so the re-vary is a no-op.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

try:  # jax >= 0.6
    from jax import shard_map as _shard_map_impl
except ImportError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map_impl

HAS_VARYING_TYPES = hasattr(jax, "typeof") and hasattr(jax.lax, "pvary")


def shard_map_compat(f, mesh, in_specs, out_specs, check_rep=True):
    """``check_rep=False`` is needed for bodies containing ops with no
    replication rule (e.g. ``pallas_call``); jax >= 0.6 renamed the
    kwarg to ``check_vma``, so the flag is translated per version."""
    kw = {}
    if not check_rep:
        import inspect

        params = inspect.signature(_shard_map_impl).parameters
        if "check_vma" in params:  # jax >= 0.6
            kw["check_vma"] = False
        elif "check_rep" in params:
            kw["check_rep"] = False
    return _shard_map_impl(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
    )


def revary(x: jnp.ndarray, axes: Sequence[str]) -> jnp.ndarray:
    """Re-vary a device-invariant value (e.g. a psum output) over
    ``axes``; identity on jax without varying-type tracking."""
    if not HAS_VARYING_TYPES:
        return x
    missing = tuple(a for a in axes if a not in jax.typeof(x).vma)
    return jax.lax.pvary(x, missing) if missing else x
