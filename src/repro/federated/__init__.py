from repro.federated.protocol import (
    CommLog,
    EdgeDevice,
    FederationServer,
    Payload,
)
from repro.federated.selection import (
    all_clients,
    loss_threshold_selection,
    resource_constrained_selection,
)
from repro.federated.mesh_federation import (
    mesh_cooperative_update,
    mesh_federated_train,
)

__all__ = [
    "CommLog", "EdgeDevice", "FederationServer", "Payload",
    "all_clients", "loss_threshold_selection", "resource_constrained_selection",
    "mesh_cooperative_update", "mesh_federated_train",
]
