"""The paper's client/server cooperative-update protocol (§4.2, Fig. 4/5).

Edge devices sequentially train OS-ELM autoencoders; when a cooperative
update is requested they (1) compute (U, V) by Eq. 15, (2) upload to the
server, (3) download the peers' intermediate results they demand,
(4) add them (Eq. 8), and (5) recover (P, β) (Eq. 6). The server is a
dumb exchange — merging can equally run on-device (§2 note in paper).

Communication cost is accounted per payload: Ñ(Ñ+m) floats per upload,
independent of how much data was trained — this is the paper's
communication-cost claim vs. R-round FedAvg.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    OSELMState,
    UV,
    ae_score,
    ae_train_stream,
    from_uv,
    init_autoencoder,
    to_uv,
    uv_add,
)


@dataclasses.dataclass
class Payload:
    """Serialized (U, V) — what actually crosses the network."""

    device_id: str
    u: np.ndarray
    v: np.ndarray
    version: int = 0

    @property
    def nbytes(self) -> int:
        return self.u.nbytes + self.v.nbytes

    def to_uv(self) -> UV:
        return UV(u=jnp.asarray(self.u), v=jnp.asarray(self.v))

    @staticmethod
    def from_uv(device_id: str, uv: UV, version: int = 0) -> "Payload":
        return Payload(device_id, np.asarray(uv.u), np.asarray(uv.v), version)


@dataclasses.dataclass
class CommLog:
    uploads: int = 0
    downloads: int = 0
    bytes_up: int = 0
    bytes_down: int = 0

    def up(self, payload: Payload) -> None:
        self.uploads += 1
        self.bytes_up += payload.nbytes

    def down(self, payload: Payload) -> None:
        self.downloads += 1
        self.bytes_down += payload.nbytes


class FederationServer:
    """Holds the latest intermediate results per device (Fig. 4)."""

    def __init__(self) -> None:
        self.store: dict[str, Payload] = {}
        self.log = CommLog()

    def upload(self, payload: Payload) -> None:
        self.log.up(payload)
        self.store[payload.device_id] = payload

    def download(self, device_id: str, exclude: str | None = None) -> Payload:
        p = self.store[device_id]
        self.log.down(p)
        return p

    def peers_of(self, device_id: str) -> list[str]:
        return [d for d in self.store if d != device_id]


class EdgeDevice:
    """One edge device: OS-ELM autoencoder + the cooperative protocol."""

    def __init__(
        self,
        device_id: str,
        key: jax.Array,
        n_features: int,
        n_hidden: int,
        x_init: np.ndarray,
        *,
        activation: str = "sigmoid",
        ridge: float = 0.0,
    ) -> None:
        self.device_id = device_id
        self.state: OSELMState = init_autoencoder(
            key, n_features, n_hidden, jnp.asarray(x_init), activation=activation, ridge=ridge
        )
        self.version = 0

    # --- local life-cycle -------------------------------------------------
    def train(self, xs: np.ndarray) -> None:
        """Sequential k=1 training on the device's own stream."""
        self.state = ae_train_stream(self.state, jnp.asarray(xs))

    def score(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(ae_score(self.state, jnp.asarray(x)))

    # --- cooperative update (§4.2) -----------------------------------------
    def share(self, server: FederationServer) -> None:
        """Steps 2–3: compute (U,V) by Eq. 15 and upload."""
        uv = to_uv(self.state)
        self.version += 1
        server.upload(Payload.from_uv(self.device_id, uv, self.version))

    def merge_from(self, server: FederationServer, peer_ids: Iterable[str]) -> None:
        """Steps 3–5: download demanded peers, add (Eq. 8), recover (Eq. 6)."""
        merged = to_uv(self.state)
        for pid in peer_ids:
            merged = uv_add(merged, server.download(pid, exclude=self.device_id).to_uv())
        self.state = from_uv(self.state, merged)


def cooperative_round(
    devices: list[EdgeDevice], server: FederationServer, *, select=None
) -> None:
    """One one-shot cooperative model update across a device set.

    ``select(device_ids) -> ids`` is the pluggable client-selection
    strategy hook (refs [19][20]); default merges everyone.
    """
    for d in devices:
        d.share(server)
    ids = [d.device_id for d in devices]
    chosen = list(select(ids)) if select is not None else ids
    for d in devices:
        if d.device_id in chosen:
            peers = [i for i in chosen if i != d.device_id]
            d.merge_from(server, peers)
