"""repro.data — dataset substrate.

Synthetic analogues of the paper's three datasets (the real UAH-DriveSet
/ Smartphone-HAR / MNIST are not available offline — see DESIGN.md §2),
plus the streaming shard pipeline used to feed non-IID pattern streams
to federated edge devices / mesh shards.
"""
from repro.data.synthetic import (
    DATASETS,
    AnomalyDataset,
    make_dataset,
    make_driving_dataset,
    make_har_dataset,
    make_mnist_like_dataset,
)
from repro.data.pipeline import (
    ShardedStream,
    class_subset,
    make_pattern_stream,
    normalize_minmax,
    train_test_split,
)

__all__ = [
    "DATASETS",
    "AnomalyDataset",
    "make_dataset",
    "make_driving_dataset",
    "make_har_dataset",
    "make_mnist_like_dataset",
    "ShardedStream",
    "class_subset",
    "make_pattern_stream",
    "normalize_minmax",
    "train_test_split",
]
