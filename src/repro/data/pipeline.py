"""Streaming / sharding pipeline.

Feeds non-IID per-pattern sample streams to federated edge devices (the
paper's setting: device-A sees only pattern p_A) and, at mesh scale,
deals per-shard streams for the shard_map federation.
"""
from __future__ import annotations

from typing import Iterator, NamedTuple, Sequence

import numpy as np

from repro.data.synthetic import AnomalyDataset


def normalize_minmax(ds: AnomalyDataset) -> AnomalyDataset:
    """Per-feature min-max normalization to [0, 1] (for sigmoid-output
    BP-NNs; also stabilizes OS-ELM identity activations). The single
    normalization convention every paper-facing evaluation uses."""
    lo, hi = ds.x.min(0), ds.x.max(0)
    x = (ds.x - lo) / (hi - lo + 1e-6)
    return ds._replace(x=x.astype(np.float32))


def class_subset(ds: AnomalyDataset, classes: Sequence[int | str]) -> AnomalyDataset:
    """Subset to ``classes`` and REMAP labels: class ``classes[i]`` of
    ``ds`` becomes class ``i`` of the result. This is how a scenario
    carves its normal + held-out-anomaly pools out of a dataset whose
    interesting classes need not be contiguous (e.g. HAR's walking /
    sitting / standing homes with laying as the anomaly)."""
    ids = [
        ds.class_names.index(c) if isinstance(c, str) else int(c) for c in classes
    ]
    if len(set(ids)) != len(ids):
        raise ValueError(f"duplicate classes in subset: {classes!r}")
    for i in ids:
        if not 0 <= i < ds.n_classes:
            raise ValueError(f"class {i} outside dataset with {ds.n_classes} classes")
    xs, ys = [], []
    for new, old in enumerate(ids):
        x = ds.x[ds.y == old]
        xs.append(x)
        ys.append(np.full(len(x), new, dtype=np.int32))
    return AnomalyDataset(
        ds.name,
        np.concatenate(xs),
        np.concatenate(ys),
        tuple(ds.class_names[i] for i in ids),
    )


def train_test_split(
    ds: AnomalyDataset, train_frac: float = 0.8, seed: int = 0
) -> tuple[AnomalyDataset, AnomalyDataset]:
    """80/20 split as in the paper (§5.3.1), stratified per class."""
    rng = np.random.default_rng(seed)
    tr_idx, te_idx = [], []
    for ci in range(ds.n_classes):
        idx = np.flatnonzero(ds.y == ci)
        rng.shuffle(idx)
        cut = int(len(idx) * train_frac)
        tr_idx.append(idx[:cut])
        te_idx.append(idx[cut:])
    tr = np.concatenate(tr_idx)
    te = np.concatenate(te_idx)
    rng.shuffle(tr)
    rng.shuffle(te)
    return (
        AnomalyDataset(ds.name, ds.x[tr], ds.y[tr], ds.class_names),
        AnomalyDataset(ds.name, ds.x[te], ds.y[te], ds.class_names),
    )


def make_pattern_stream(
    ds: AnomalyDataset, pattern: int | str, *, seed: int = 0, limit: int | None = None
) -> np.ndarray:
    """The non-IID stream a single edge device observes: samples of one
    normal pattern only, shuffled."""
    x = ds.pattern(pattern).copy()
    rng = np.random.default_rng(seed)
    rng.shuffle(x)
    return x[:limit] if limit is not None else x


def anomaly_eval_arrays(
    test: AnomalyDataset,
    normal_patterns: Sequence[int],
    *,
    anomaly_ratio: float = 0.1,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """The paper's §5.3.1 protocol: trained patterns are normal test
    data; all others are anomalous, subsampled to 10% of the normal
    count. Returns (x, is_anomalous)."""
    rng = np.random.default_rng(seed)
    normal_mask = np.isin(test.y, np.asarray(list(normal_patterns)))
    x_norm = test.x[normal_mask]
    x_anom = test.x[~normal_mask]
    n_anom = max(1, int(len(x_norm) * anomaly_ratio))
    pick = rng.choice(len(x_anom), size=min(n_anom, len(x_anom)), replace=False)
    x_anom = x_anom[pick]
    x = np.concatenate([x_norm, x_anom])
    y = np.concatenate([np.zeros(len(x_norm)), np.ones(len(x_anom))]).astype(np.int32)
    return x, y


class ShardedStream(NamedTuple):
    """Per-shard non-IID streams for the mesh federation: shard i trains
    on pattern (i mod n_classes). Shapes: (shards, steps, features)."""

    xs: np.ndarray
    pattern_of_shard: np.ndarray  # (shards,)


def make_sharded_streams(
    ds: AnomalyDataset, n_shards: int, steps: int, *, seed: int = 0
) -> ShardedStream:
    rng = np.random.default_rng(seed)
    xs = np.empty((n_shards, steps, ds.n_features), dtype=np.float32)
    pats = np.empty(n_shards, dtype=np.int32)
    for s in range(n_shards):
        pat = s % ds.n_classes
        pool = ds.pattern(pat)
        idx = rng.integers(0, len(pool), size=steps)
        xs[s] = pool[idx]
        pats[s] = pat
    return ShardedStream(xs=xs, pattern_of_shard=pats)


def batched(x: np.ndarray, batch: int) -> Iterator[np.ndarray]:
    for i in range(0, len(x) - batch + 1, batch):
        yield x[i : i + batch]
