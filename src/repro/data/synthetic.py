"""Synthetic analogues of the paper's three datasets (Table 2).

| paper dataset      | features | classes | analogue here                      |
|--------------------|----------|---------|------------------------------------|
| UAH-DriveSet [21]  | 225      | 3       | Markov speed-trace simulator →     |
|                    |          |         | 15×15 state-transition prob table  |
| Smartphone HAR [22]| 561      | 6       | per-activity low-rank Gaussian     |
|                    |          |         | manifolds (sitting≈standing,       |
|                    |          |         | walking* mutually close)           |
| MNIST [23]         | 784      | 10      | smooth per-class prototypes with   |
|                    |          |         | elastic deformations, in [0,1]     |

The real datasets are unavailable offline (DESIGN.md §2); feature
dimensionality, class structure and the semi-supervised protocol match
the paper exactly, so the paper's *relative* claims (loss collapse after
merge, post-merge ROC-AUC parity with BP-NN, latency ratios) remain
testable.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import numpy as np


class AnomalyDataset(NamedTuple):
    name: str
    x: np.ndarray          # (samples, features) float32
    y: np.ndarray          # (samples,) int class labels
    class_names: tuple[str, ...]

    @property
    def n_features(self) -> int:
        return int(self.x.shape[1])

    @property
    def n_classes(self) -> int:
        return len(self.class_names)

    def pattern(self, cls: int | str) -> np.ndarray:
        if isinstance(cls, str):
            cls = self.class_names.index(cls)
        return self.x[self.y == cls]


# ----------------------------------------------------------- driving

_DRIVE_CLASSES = ("normal", "aggressive", "drowsy")

# Markov speed dynamics per driving pattern over 15 speed levels
# (1 level = 10 km/h, as in the paper). (drift, volatility, mean level)
_DRIVE_DYNAMICS = {
    "normal": (0.30, 0.8, 7.0),
    "aggressive": (0.85, 2.6, 11.0),
    "drowsy": (0.12, 0.5, 5.0),
}


def _simulate_speed_trace(rng: np.random.Generator, pattern: str, steps: int) -> np.ndarray:
    """1 Hz speed trace, quantized to 15 levels, as an OU-like process
    whose pull/volatility depend on the driving pattern."""
    pull, vol, mean = _DRIVE_DYNAMICS[pattern]
    v = mean + rng.normal() * 2.0
    levels = np.empty(steps, dtype=np.int32)
    for i in range(steps):
        # aggressive drivers make large jerky corrections; drowsy drift
        v = v + pull * (mean - v) * 0.15 + rng.normal() * vol
        if pattern == "aggressive" and rng.random() < 0.15:
            v += rng.choice((-4.0, 4.0))  # hard brake / hard accel
        v = float(np.clip(v, 0.0, 14.0))
        levels[i] = int(round(v))
    return levels


def _transition_table(levels: np.ndarray, n_states: int = 15) -> np.ndarray:
    """The paper's feature: 15×15 state-transition probability table."""
    counts = np.zeros((n_states, n_states), dtype=np.float64)
    np.add.at(counts, (levels[:-1], levels[1:]), 1.0)
    row = counts.sum(axis=1, keepdims=True)
    probs = np.divide(counts, row, out=np.zeros_like(counts), where=row > 0)
    return probs.reshape(-1).astype(np.float32)


def make_driving_dataset(
    seed: int = 0, samples_per_class: int = 400, window: int = 240
) -> AnomalyDataset:
    rng = np.random.default_rng(seed)
    xs, ys = [], []
    for ci, cls in enumerate(_DRIVE_CLASSES):
        for _ in range(samples_per_class):
            trace = _simulate_speed_trace(rng, cls, window)
            xs.append(_transition_table(trace))
            ys.append(ci)
    return AnomalyDataset(
        name="driving",
        x=np.stack(xs),
        y=np.asarray(ys, dtype=np.int32),
        class_names=_DRIVE_CLASSES,
    )


# ---------------------------------------------------------------- HAR

_HAR_CLASSES = (
    "walking", "walking_upstairs", "walking_downstairs",
    "sitting", "standing", "laying",
)


def make_har_dataset(
    seed: int = 0, samples_per_class: int = 500, n_features: int = 561
) -> AnomalyDataset:
    """Low-rank Gaussian manifold per activity.

    Class geometry mirrors the paper's observations (Fig. 7/9):
    the three walking variants share a common 'dynamic' subspace and are
    mutually close; sitting and standing are similar to each other
    ('there is a similarity between the sitting pattern and standing
    pattern'); laying is far from everything.
    """
    rng = np.random.default_rng(seed)
    rank = 12

    base_dynamic = rng.normal(size=n_features) * 0.8        # shared by walking*
    base_static = rng.normal(size=n_features) * 0.8         # shared by sit/stand
    dyn_factors = rng.normal(size=(rank, n_features)) / np.sqrt(rank)
    stat_factors = rng.normal(size=(rank, n_features)) / np.sqrt(rank)

    means = {
        "walking": base_dynamic + 0.35 * rng.normal(size=n_features),
        "walking_upstairs": base_dynamic + 0.45 * rng.normal(size=n_features),
        "walking_downstairs": base_dynamic + 0.60 * rng.normal(size=n_features),
        "sitting": base_static + 0.25 * rng.normal(size=n_features),
        "standing": base_static + 0.30 * rng.normal(size=n_features),
        "laying": rng.normal(size=n_features) * 1.6,
    }
    factors = {
        c: (dyn_factors if c.startswith("walking") else stat_factors)
        + 0.3 * rng.normal(size=(rank, n_features)) / np.sqrt(rank)
        for c in _HAR_CLASSES
    }

    xs, ys = [], []
    for ci, cls in enumerate(_HAR_CLASSES):
        latent = rng.normal(size=(samples_per_class, rank))
        noise = rng.normal(size=(samples_per_class, n_features)) * 0.08
        xs.append((means[cls] + latent @ factors[cls] + noise).astype(np.float32))
        ys.append(np.full(samples_per_class, ci, dtype=np.int32))
    return AnomalyDataset(
        name="har", x=np.concatenate(xs), y=np.concatenate(ys), class_names=_HAR_CLASSES
    )


# -------------------------------------------------------- MNIST-like

_MNIST_CLASSES = tuple(str(d) for d in range(10))


def _smooth2d(rng: np.random.Generator, size: int = 28, cutoff: int = 5) -> np.ndarray:
    """Random smooth image via low-frequency Fourier synthesis."""
    spec = np.zeros((size, size), dtype=np.complex128)
    for u in range(cutoff):
        for v in range(cutoff):
            spec[u, v] = rng.normal() + 1j * rng.normal()
    img = np.real(np.fft.ifft2(spec))
    img = (img - img.min()) / (img.max() - img.min() + 1e-9)
    return img


def make_mnist_like_dataset(
    seed: int = 0, samples_per_class: int = 500
) -> AnomalyDataset:
    """Per-class smooth prototype + per-sample elastic deformation +
    pixel noise, normalized to [0,1] like the paper's /255 MNIST."""
    rng = np.random.default_rng(seed)
    protos = [_smooth2d(rng) for _ in range(10)]
    xs, ys = [], []
    for ci in range(10):
        p = protos[ci]
        for _ in range(samples_per_class):
            # small random shift (elastic-ish deformation)
            dx, dy = rng.integers(-2, 3, size=2)
            img = np.roll(np.roll(p, dx, axis=0), dy, axis=1)
            img = img * rng.uniform(0.85, 1.15) + rng.normal(size=(28, 28)) * 0.05
            xs.append(np.clip(img, 0.0, 1.0).reshape(-1).astype(np.float32))
            ys.append(ci)
    return AnomalyDataset(
        name="mnist_like",
        x=np.stack(xs),
        y=np.asarray(ys, dtype=np.int32),
        class_names=_MNIST_CLASSES,
    )


DATASETS: dict[str, Callable[..., AnomalyDataset]] = {
    "driving": make_driving_dataset,
    "har": make_har_dataset,
    "mnist_like": make_mnist_like_dataset,
}


def make_dataset(name: str, seed: int = 0, **kw) -> AnomalyDataset:
    try:
        return DATASETS[name](seed=seed, **kw)
    except KeyError as e:
        raise ValueError(f"unknown dataset {name!r}; have {sorted(DATASETS)}") from e
