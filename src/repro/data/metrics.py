"""Evaluation metrics (no sklearn offline): ROC-AUC via the
Mann-Whitney U rank statistic, exactly equivalent to the trapezoidal
ROC integral used by the paper."""
from __future__ import annotations

import numpy as np


def roc_auc(scores: np.ndarray, labels: np.ndarray) -> float:
    """AUC = P(score_anomalous > score_normal), ties counted half.

    ``labels`` is 1 for anomalous, 0 for normal; ``scores`` are anomaly
    scores (higher = more anomalous).
    """
    scores = np.asarray(scores, dtype=np.float64)
    labels = np.asarray(labels)
    if not np.isfinite(scores).all():
        raise ValueError("roc_auc got non-finite scores")
    pos = scores[labels == 1]
    neg = scores[labels == 0]
    if len(pos) == 0 or len(neg) == 0:
        raise ValueError("roc_auc needs both classes present")
    order = np.argsort(np.concatenate([neg, pos]), kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(order) + 1)
    # average ranks for ties
    all_scores = np.concatenate([neg, pos])
    sorted_scores = all_scores[order]
    i = 0
    while i < len(sorted_scores):
        j = i
        while j + 1 < len(sorted_scores) and sorted_scores[j + 1] == sorted_scores[i]:
            j += 1
        if j > i:
            avg = 0.5 * (i + 1 + j + 1)
            ranks[order[i : j + 1]] = avg
        i = j + 1
    r_pos = ranks[len(neg):].sum()
    n_pos, n_neg = len(pos), len(neg)
    u = r_pos - n_pos * (n_pos + 1) / 2.0
    return float(u / (n_pos * n_neg))
