"""Minimal optimizer substrate (optax is not available offline).

Pure-pytree optimizers used by (a) the BP-NN baselines the paper
compares against and (b) the large-model training steps of the 10
assigned architectures. Moments can be kept in a reduced dtype
(bf16) — required to fit Adam state for the ≥100B archs on v5e HBM
(DESIGN.md §6).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree | None
    nu: PyTree | None


class Optimizer(NamedTuple):
    init: Callable[[PyTree], OptState]
    update: Callable[[PyTree, OptState, PyTree], tuple[PyTree, OptState]]


def _cast_like(tree: PyTree, dtype) -> PyTree:
    if dtype is None:
        return tree
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def adam(
    lr: float | Callable[[jnp.ndarray], jnp.ndarray],
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    *,
    moment_dtype=None,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Adam; ``moment_dtype=jnp.bfloat16`` halves optimizer HBM."""

    def init(params: PyTree) -> OptState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, moment_dtype or p.dtype), params
        )
        return OptState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=jax.tree.map(jnp.copy, zeros))

    def update(grads: PyTree, state: OptState, params: PyTree) -> tuple[PyTree, OptState]:
        step = state.step + 1
        lr_t = lr(step) if callable(lr) else lr
        mu = jax.tree.map(lambda m, g: b1 * m.astype(g.dtype) + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v.astype(g.dtype) + (1 - b2) * g * g, state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            delta = lr_t * mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                delta = delta + lr_t * weight_decay * p
            return (p - delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, OptState(step=step, mu=_cast_like(mu, moment_dtype), nu=_cast_like(nu, moment_dtype))

    return Optimizer(init=init, update=update)


def adamw(lr, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params: PyTree) -> OptState:
        mu = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu=None)

    def update(grads: PyTree, state: OptState, params: PyTree):
        step = state.step + 1
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state.mu, grads)
            new = jax.tree.map(lambda p, m: (p - lr * m).astype(p.dtype), params, mu)
            return new, OptState(step=step, mu=mu, nu=None)
        new = jax.tree.map(lambda p, g: (p - lr * g).astype(p.dtype), params, grads)
        return new, OptState(step=step, mu=None, nu=None)

    return Optimizer(init=init, update=update)


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    leaves = jax.tree.leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads)
