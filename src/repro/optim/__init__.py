from repro.optim.optimizers import (
    Optimizer,
    OptState,
    adam,
    adamw,
    clip_by_global_norm,
    sgd,
)

__all__ = ["Optimizer", "OptState", "adam", "adamw", "sgd", "clip_by_global_norm"]
