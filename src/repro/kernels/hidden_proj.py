"""Pallas TPU kernel: fused hidden-layer projection H = G(x·α + b).

The ELM/OS-ELM forward hot spot (Eq. 1). Tiled (bm × bn) output blocks
with a bk contraction loop on the innermost grid axis; partial products
accumulate in an f32 VMEM scratch and the bias + activation are applied
once on the final k-step (fused epilogue — H never round-trips to HBM
in anything but its final form).

Tile sizes default to MXU-aligned multiples of 128 lanes / 8 sublanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.activations import get_activation


def _hidden_kernel(x_ref, a_ref, b_ref, o_ref, acc_ref, *, activation: str, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], a_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        g = get_activation(activation)
        o_ref[...] = g(acc_ref[...] + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("activation", "bm", "bn", "bk", "interpret")
)
def hidden_proj(
    x: jnp.ndarray,
    alpha: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    activation: str = "sigmoid",
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """H = G(x·α + b) for x:(M,K), α:(K,N), b:(N,) → (M,N) f32.

    Shapes are padded up to tile multiples; zero-padded K contributes
    zero to the accumulator so results are exact after slicing.
    """
    m, k = x.shape
    k2, n = alpha.shape
    assert k == k2 and bias.shape == (n,)
    mp, kp, np_ = (-(-m // bm) * bm, -(-k // bk) * bk, -(-n // bn) * bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    ap = jnp.pad(alpha, ((0, kp - k), (0, np_ - n)))
    bp = jnp.pad(bias, (0, np_ - n))[None, :]  # (1, Np) for lane layout
    nk = kp // bk

    out = pl.pallas_call(
        functools.partial(_hidden_kernel, activation=activation, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, ap, bp)
    return out[:m, :n]
