"""jit'd public wrappers over the Pallas kernels.

``interpret`` defaults to True (this container is CPU-only; on TPU pass
interpret=False and the same pallas_calls lower via Mosaic). The fused
k=1 OS-ELM step composes three kernels:

    1. hidden_proj   h  = G(x·α + b)                 (MXU matmul + epilogue)
    2. rowvec matvec ph = P h  (via matmul_atb on a symmetric P)
    3. rank1_add ×2  P' = P − phphᵀ/denom, β' = β + ph errᵀ/denom

with the two scalars (denom) and the m-vector (err) computed inline —
they are O(Ñ + m) work, not worth a kernel launch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.oselm import OSELMState
from repro.kernels.hidden_proj import hidden_proj
from repro.kernels.matmul_atb import matmul_atb, uv_accum
from repro.kernels.rank1_add import rank1_add

__all__ = [
    "hidden_proj",
    "matmul_atb",
    "uv_accum",
    "rank1_add",
    "oselm_step_k1_kernel",
    "uv_from_state_kernel",
]


@functools.partial(jax.jit, static_argnames=("interpret",))
def oselm_step_k1_kernel(
    state: OSELMState, x: jnp.ndarray, t: jnp.ndarray, *, interpret: bool = True
) -> OSELMState:
    """Kernelized Eq. 12 k=1 step — drop-in for `core.oselm.oselm_step_k1`."""
    h = hidden_proj(
        x[None, :], state.params.alpha, state.params.bias,
        activation=state.activation, interpret=interpret,
    )[0]                                            # (Ñ,)
    p = state.p / state.forget
    # ph = P h: P is symmetric, so hᵀP = (Ph)ᵀ → AᵀB with A=h column.
    ph = matmul_atb(h[:, None], p, interpret=interpret)[0]  # (Ñ,)
    denom = 1.0 + h @ ph
    err = t - h @ state.beta                        # (m,)
    p_new = rank1_add(p, ph, ph, -1.0 / denom, interpret=interpret)
    beta_new = rank1_add(state.beta, ph, err, 1.0 / denom, interpret=interpret)
    return state.replace(beta=beta_new, p=p_new)


@functools.partial(jax.jit, static_argnames=("activation", "interpret"))
def uv_from_batch_kernel(
    params_alpha: jnp.ndarray,
    params_bias: jnp.ndarray,
    x: jnp.ndarray,
    t: jnp.ndarray,
    *,
    activation: str = "sigmoid",
    interpret: bool = True,
):
    """Batched E²LM statistics straight from raw data:
    H = G(xα+b); U = HᵀH; V = Hᵀt — the ELM/E²LM training front half."""
    h = hidden_proj(x, params_alpha, params_bias, activation=activation, interpret=interpret)
    return uv_accum(h, t, interpret=interpret)


def uv_from_state_kernel(state: OSELMState, x: jnp.ndarray, *, interpret: bool = True):
    """Autoencoder variant (t = x)."""
    return uv_from_batch_kernel(
        state.params.alpha, state.params.bias, x, x,
        activation=state.activation, interpret=interpret,
    )
