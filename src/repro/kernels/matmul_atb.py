"""Pallas TPU kernel: AᵀB accumulation — the E²LM sufficient statistics.

U = HᵀH and V = Hᵀt (Eq. 6 / Eq. 15) are both AᵀB with the *sample* axis
contracted. The kernel reads A twice under two BlockSpecs (row-block and
column-block views) so Aᵀ is never materialized in HBM; partial products
accumulate in an f32 VMEM scratch over the innermost sample-tile axis.

This contraction has arithmetic intensity ~Ñ on the MXU and is the
compute term of the merge path's roofline (benchmarks/roofline of the
detector path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _atb_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # a_ref block: (bk, bi) sample-major; contract the sample axis.
    acc_ref[...] += jnp.dot(
        a_ref[...].T, b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bi", "bj", "bk", "interpret"))
def matmul_atb(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bi: int = 128,
    bj: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """AᵀB for A:(K,N1), B:(K,N2) → (N1,N2) f32 (K = samples)."""
    k, n1 = a.shape
    k2, n2 = b.shape
    assert k == k2
    kp = -(-k // bk) * bk
    n1p = -(-n1 // bi) * bi
    n2p = -(-n2 // bj) * bj
    ap = jnp.pad(a, ((0, kp - k), (0, n1p - n1)))
    bp = jnp.pad(b, ((0, kp - k), (0, n2p - n2)))
    nk = kp // bk

    out = pl.pallas_call(
        functools.partial(_atb_kernel, nk=nk),
        grid=(n1p // bi, n2p // bj, nk),
        in_specs=[
            pl.BlockSpec((bk, bi), lambda i, j, kk: (kk, i)),
            pl.BlockSpec((bk, bj), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n1p, n2p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bi, bj), jnp.float32)],
        interpret=interpret,
    )(ap, bp)
    return out[:n1, :n2]


def uv_accum(
    h: jnp.ndarray, t: jnp.ndarray, *, interpret: bool = True
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """U = HᵀH, V = Hᵀt in one pass each (paper Eq. 6 intermediates)."""
    u = matmul_atb(h, h, interpret=interpret)
    v = matmul_atb(h, t, interpret=interpret)
    return u, v
