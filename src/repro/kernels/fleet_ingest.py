"""Fused fleet-ingest kernel family — the per-tick training hot path.

One serve tick ingests a window of samples on every device: score the
incoming window under the CURRENT model (the pre-train ``ae_score``
drift signal, §3.4 / arXiv:2203.01077), then run the paper's k=1
sequential OS-ELM updates (Eqs. 9–13, scalar-reciprocal fast path,
forgetting factor λ) over the window. The reference implementation —
a score pass plus ``vmap``-of-``lax.scan`` over single-sample RLS
steps — round-trips each device's P (Ñ×Ñ) and β (Ñ×m) through HBM
once **per sample** and walks the window twice.

This module fuses the whole tick into one pass with two lowerings:

- ``fleet_ingest_kernel`` — ONE ``pallas_call`` whose grid tiles the
  device axis in blocks of ``block_d`` devices. Each program keeps its
  devices' (P, β) resident in VMEM for the entire window: the hidden
  projections H = G(xα+b) for the whole window are one MXU matmul, the
  pre-train reconstruction errors (the drift signal) fall out of the
  same H against the tick-start β, and an in-kernel ``fori_loop`` then
  applies the k=1 rank-1 RLS updates sample by sample. Per-device
  state touches HBM once per tick instead of once per sample. Sample
  slots padded up to the sublane tile are masked to exact identity
  (they never update P/β and contribute nothing to the score).
  ``interpret=True`` on CPU, Mosaic on TPU — same convention as
  ``kernels/topology_merge.py``.

- ``fleet_ingest_xla`` — the same one-pass dataflow lowered through
  XLA for backends without Pallas execution (this container's CPU):
  batched H + pre-train errors, then the window's k=1 chain applied
  one *block* of ``block_t`` samples at a time in its exact batched
  Woodbury form.  c sequential rank-1 RLS steps are algebraically one
  rank-c update — with forgetting they solve
  min_β Σ_t λ^{c-t} ‖h_tβ − t_t‖² + λ^c ‖β − β₀‖²_{K₀} — so

      P' = P/λ^c − (P/λ^c) H̃ᵀ (I + H̃ (P/λ^c) H̃ᵀ)⁻¹ H̃ (P/λ^c)
      β' = β + P' Hᵀ W E₀,      H̃ = W^{1/2} H,  W = diag(λ^{c-t})

  where E₀ = T − Hβ is exactly the pre-train error the drift score
  already computed (the update re-uses it; the window is never walked
  twice). Equality with the sequential chain is exact in real
  arithmetic; in f32 the c×c Cholesky reorders the accumulation, so
  the bit-level drift vs the sequential oracle is a little wider than
  the Pallas kernel's (tests bound both). Padded sample slots carry
  weight 0, which is an exact identity step.

Both lowerings accept an optional supervised ``targets`` window; the
default (``None``) is the paper's autoencoder tick (targets = inputs,
the x block is not duplicated). ``fleet_ingest`` dispatches between
the two (``backend="auto"`` picks Pallas on TPU, the fused XLA form
elsewhere) and is what ``fleet_train(kernel=True)``,
``oselm_train_sequential(kernel=True)`` and the runtime's kernel
ingest ride on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.activations import get_activation
from repro.core.oselm import OSELMState

__all__ = [
    "fleet_ingest",
    "fleet_ingest_kernel",
    "fleet_ingest_paged",
    "fleet_ingest_xla",
    "ingest_padding",
    "resolve_backend",
    "validate_shared_basis",
]

_LANE = 128
_SUBLANE = 8


def _pad_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def resolve_backend(backend: str) -> str:
    """The ONE place the ``"auto"`` ingest dispatch is decided: Pallas
    only where it compiles natively (TPU), the fused XLA form elsewhere.
    Shared by the dispatcher, the padding warning and the sharded
    ingest's check_rep decision so they can never disagree."""
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend not in ("pallas", "xla"):
        raise ValueError(f"unknown ingest backend {backend!r}")
    return backend


def ingest_padding(n_samples: int, block_t: int = 32) -> tuple[int, int]:
    """(pallas_pad, xla_pad): sample slots each lowering pads the window
    with. Padded slots are masked to exact identity steps; callers warn
    when nonzero (see ``fleet_train_rounds``)."""
    bt = min(block_t, n_samples)
    return (
        _pad_up(n_samples, _SUBLANE) - n_samples,
        _pad_up(n_samples, bt) - n_samples,
    )


def validate_shared_basis(states: OSELMState) -> None:
    """Raise if a stacked fleet does NOT carry the fleet-shared SLFN
    basis the fused ingest assumes (``init_fleet`` broadcasts ONE
    (α, b); Eq. 8 merging requires it — see PR 1 note). A fleet stacked
    from per-device random bases would otherwise be silently projected
    through device 0's basis. Spot-checks first vs last device; a no-op
    under tracing (the jitted lowerings can't inspect values), so the
    non-jitted entry points — the ``fleet_ingest`` dispatcher, the
    rounds/sharded wrappers and ``FleetRuntime.__init__`` — call it
    where the arrays are still concrete."""
    alpha = states.params.alpha
    if alpha.ndim != 3 or isinstance(alpha, jax.core.Tracer):
        return
    import numpy as np

    if not np.array_equal(np.asarray(alpha[0]), np.asarray(alpha[-1])):
        raise ValueError(
            "fused ingest requires the fleet-shared SLFN basis "
            "(init_fleet broadcasts one (α, b)); this stack carries "
            "per-device bases, which the kernel cannot honor"
        )


def _shared_basis(states: OSELMState) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The fleet's (α, b): device 0's copy of the shared basis (see
    ``validate_shared_basis``; inside the jitted lowerings the leaves
    are tracers, so the invariant is checked at the concrete entry
    points, not here). Single-device states pass through unchanged."""
    alpha, bias = states.params.alpha, states.params.bias
    if alpha.ndim == 3:  # stacked fleet: (D, n, Ñ) identical copies
        alpha, bias = alpha[0], bias[0]
    return alpha, bias


# ------------------------------------------------------------- pallas kernel


def _ingest_kernel(*refs, tied: bool,
                   t_real: int, t_pad: int, m_real: int, nh_real: int,
                   nh_rows: int, activation: str, forget: float):
    """One grid step = ``block_d`` devices' whole tick, VMEM-resident.

    Layouts (B = block_d, TP/NL/ML/NHL lane- or sublane-padded, NHR
    sublane-padded): x (B, TP, NL), targets (B, TP, ML) — the x block
    itself when ``tied`` — α (NL, NHL), bias (1, 1, NHL),
    P (B, NHR, NHL), β (B, NHR, ML). P/β rows ≥ Ñ and lanes ≥ Ñ
    (resp. m) are zero and stay zero through every update below.
    """
    if tied:
        x_ref, a_ref, b_ref, p_ref, be_ref, po_ref, bo_ref, l_ref = refs
    else:
        x_ref, tt_ref, a_ref, b_ref, p_ref, be_ref, po_ref, bo_ref, l_ref = refs
    xb = x_ref[...].astype(jnp.float32)                       # (B, TP, NL)
    tb = xb if tied else tt_ref[...].astype(jnp.float32)      # (B, TP, ML)
    g = get_activation(activation)
    # hidden projection for the WHOLE window: one MXU matmul + epilogue.
    # Lanes ≥ Ñ are masked off — G(0·α + 0) need not be 0 (sigmoid!).
    h_all = g(
        jax.lax.dot_general(
            xb, a_ref[...], (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        + b_ref[...]
    )
    nh_mask = jax.lax.broadcasted_iota(jnp.int32, (1, 1, h_all.shape[2]), 2) < nh_real
    h_all = jnp.where(nh_mask, h_all, 0.0)                    # (B, TP, NHL)

    p = p_ref[...].astype(jnp.float32)                        # (B, NHR, NHL)
    be = be_ref[...].astype(jnp.float32)                      # (B, NHR, ML)

    # pre-train drift signal: prediction error of the incoming window
    # under the tick-start β — batched, re-using h_all.
    e0 = tb - jax.lax.dot_general(
        h_all[:, :, :nh_rows], be, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    t_mask = jax.lax.broadcasted_iota(jnp.int32, (1, t_pad, 1), 1) < t_real
    loss = jnp.sum(jnp.where(t_mask, e0 * e0, 0.0), axis=(1, 2)) / (t_real * m_real)

    def body(t, carry):
        p, be = carry
        h = jax.lax.dynamic_slice_in_dim(h_all, t, 1, axis=1)[:, 0, :]  # (B, NHL)
        tt = jax.lax.dynamic_slice_in_dim(tb, t, 1, axis=1)[:, 0, :]    # (B, ML)
        h_rows = h[:, :nh_rows]                                         # (B, NHR)
        pf = p / forget
        ph = jax.lax.dot_general(                                       # P h (rows)
            pf, h, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )                                                               # (B, NHR)
        denom = 1.0 + jnp.sum(h_rows * ph, axis=1, keepdims=True)       # (B, 1)
        ph_lane = jnp.pad(ph, ((0, 0), (0, h.shape[1] - nh_rows)))      # (B, NHL)
        p_new = pf - ph[:, :, None] * ph_lane[:, None, :] / denom[:, :, None]
        err = tt - jax.lax.dot_general(                                 # (B, ML)
            h_rows, be, (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )
        # gain = P_new h, computed as the reference does (≡ ph/denom in
        # exact arithmetic; the matvec keeps bit-level drift vs the
        # sequential oracle inside the 1e-5 parity bound)
        gain = jax.lax.dot_general(
            p_new, h, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )                                                               # (B, NHR)
        be_new = be + gain[:, :, None] * err[:, None, :]
        # padded sample slots are exact identity: no update, no λ decay
        valid = t < t_real
        return (
            jnp.where(valid, p_new, p),
            jnp.where(valid, be_new, be),
        )

    p, be = jax.lax.fori_loop(0, t_pad, body, (p, be))
    po_ref[...] = p.astype(po_ref.dtype)
    bo_ref[...] = be.astype(bo_ref.dtype)
    l_ref[...] = jnp.broadcast_to(loss[:, None], l_ref.shape).astype(l_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fleet_ingest_kernel(
    states: OSELMState,
    window: jnp.ndarray,
    targets: jnp.ndarray | None = None,
    *,
    block_d: int = 8,
    interpret: bool = True,
) -> tuple[OSELMState, jnp.ndarray]:
    """Fused Pallas tick ingest over a stacked fleet.

    ``window`` is (D, T, n), ``targets`` (D, T, m) or None for the
    autoencoder tick (targets = window); returns (trained fleet, (D,)
    mean pre-train prediction loss of each device's window — the drift
    signal). Each grid program holds ``block_d`` devices' (P, β) in
    VMEM across the whole window: HBM sees the state once per tick,
    not once per sample.
    """
    window = jnp.asarray(window)
    d, t, n = window.shape
    nh, m = states.beta.shape[1], states.beta.shape[2]
    tied = targets is None
    if tied:
        assert m == n, "autoencoder ingest needs m == n"
    else:
        targets = jnp.asarray(targets)
        assert targets.shape == (d, t, m), (targets.shape, (d, t, m))

    bd = min(block_d, d)
    dp = _pad_up(d, bd)
    tp = _pad_up(t, _SUBLANE)
    nl = _pad_up(n, _LANE)
    ml = _pad_up(m, _LANE)
    nhl = _pad_up(nh, _LANE)
    nhr = _pad_up(nh, _SUBLANE)

    alpha, bias = _shared_basis(states)
    xw = jnp.pad(window, ((0, dp - d), (0, tp - t), (0, nl - n)))
    ap = jnp.pad(alpha, ((0, nl - n), (0, nhl - nh)))
    bp = jnp.pad(bias, (0, nhl - nh))[None, None, :]
    pp = jnp.pad(states.p, ((0, dp - d), (0, nhr - nh), (0, nhl - nh)))
    bep = jnp.pad(states.beta, ((0, dp - d), (0, nhr - nh), (0, ml - m)))

    operands = [xw]
    in_specs = [pl.BlockSpec((bd, tp, nl), lambda i: (i, 0, 0))]
    if not tied:
        operands.append(jnp.pad(targets, ((0, dp - d), (0, tp - t), (0, ml - m))))
        in_specs.append(pl.BlockSpec((bd, tp, ml), lambda i: (i, 0, 0)))
    operands += [ap, bp, pp, bep]
    in_specs += [
        pl.BlockSpec((nl, nhl), lambda i: (0, 0)),
        pl.BlockSpec((1, 1, nhl), lambda i: (0, 0, 0)),
        pl.BlockSpec((bd, nhr, nhl), lambda i: (i, 0, 0)),
        pl.BlockSpec((bd, nhr, ml), lambda i: (i, 0, 0)),
    ]

    kern = functools.partial(
        _ingest_kernel, tied=tied,
        t_real=t, t_pad=tp, m_real=m, nh_real=nh, nh_rows=nhr,
        activation=states.activation, forget=states.forget,
    )
    p_out, b_out, l_out = pl.pallas_call(
        kern,
        grid=(dp // bd,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bd, nhr, nhl), lambda i: (i, 0, 0)),
            pl.BlockSpec((bd, nhr, ml), lambda i: (i, 0, 0)),
            pl.BlockSpec((bd, _LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((dp, nhr, nhl), jnp.float32),
            jax.ShapeDtypeStruct((dp, nhr, ml), jnp.float32),
            jax.ShapeDtypeStruct((dp, _LANE), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
    new_states = states.replace(
        p=p_out[:d, :nh, :nh].astype(states.p.dtype),
        beta=b_out[:d, :nh, :m].astype(states.beta.dtype),
    )
    return new_states, l_out[:d, 0]


# --------------------------------------------------------- fused XLA lowering


@functools.partial(jax.jit, static_argnames=("block_t",))
def fleet_ingest_xla(
    states: OSELMState,
    window: jnp.ndarray,
    targets: jnp.ndarray | None = None,
    *,
    block_t: int = 32,
) -> tuple[OSELMState, jnp.ndarray]:
    """``fleet_ingest_kernel``'s dataflow lowered through plain XLA —
    the hot path on backends where Pallas only interprets (CPU).

    One pass over the window: batched hidden projections, the pre-train
    drift score, and the k=1 chain applied ``block_t`` samples at a time
    in its exact batched Woodbury form (module docstring).
    """
    window = jnp.asarray(window)
    d, t, n = window.shape
    nh, m = states.beta.shape[1], states.beta.shape[2]
    if targets is None:
        assert m == n, "autoencoder ingest needs m == n"
        targets = window
    else:
        targets = jnp.asarray(targets)
        assert targets.shape == (d, t, m), (targets.shape, (d, t, m))
    alpha, bias = _shared_basis(states)
    g = get_activation(states.activation)
    h_all = g(jnp.einsum("dtn,nh->dth", window, alpha) + bias)  # (D, T, Ñ)

    # pre-train drift signal under the tick-start β
    e0_all = targets - jnp.einsum("dth,dhm->dtm", h_all, states.beta)
    losses = jnp.mean(e0_all * e0_all, axis=(1, 2))

    bt = min(block_t, t)
    n_blocks = -(-t // bt)
    tp = n_blocks * bt
    if tp != t:  # ragged tail block: zero-weight (exact identity) slots
        h_all = jnp.pad(h_all, ((0, 0), (0, tp - t), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, tp - t), (0, 0)))
    h_blk = h_all.reshape(d, n_blocks, bt, nh).transpose(1, 0, 2, 3)
    t_blk = targets.reshape(d, n_blocks, bt, m).transpose(1, 0, 2, 3)
    forget = states.forget

    def block_update(p, beta, hb, e0, c):
        """One block's exact rank-c Woodbury update; ``e0`` are the
        PRE-BLOCK errors (targets − hβ under the block-entry β)."""
        # weights λ^{c-1-t} for live slots, 0 for padded ones
        idx = jnp.arange(bt)
        w = jnp.where(idx < c, forget ** (c - 1 - idx).astype(p.dtype), 0.0)
        lam_c = jnp.asarray(forget, p.dtype) ** c
        sw = jnp.sqrt(w)
        hw = hb * sw[None, :, None]                     # W^1/2 H
        pl_ = p / lam_c
        php = jnp.einsum("dtn,dnm->dtm", hw, pl_)       # H̃ P/λ^c
        s = jnp.einsum("dtn,dun->dtu", php, hw)
        s = s + jnp.eye(bt, dtype=s.dtype)
        cho = jax.scipy.linalg.cho_factor(s)
        gain = jax.scipy.linalg.cho_solve(cho, php)     # S⁻¹ H̃ P/λ^c
        p_new = pl_ - jnp.einsum("dtn,dtm->dnm", php, gain)
        # β' = β + P' Hᵀ W E₀
        hwe = jnp.einsum("dtn,dtm->dnm", hw, e0 * sw[None, :, None])
        beta_new = beta + jnp.einsum("dnm,dmk->dnk", p_new, hwe)
        return p_new, beta_new

    # block 0's pre-block β IS the tick-start β, so its errors are the
    # drift-score errors already computed — re-used, not recomputed
    # (block 0 is always fully live: tail padding only reaches the last
    # block, and a padded window implies n_blocks >= 2)
    p, beta = block_update(
        states.p, states.beta, h_blk[0], e0_all[:, :bt], jnp.int32(bt)
    )
    if n_blocks > 1:
        c_real = jnp.minimum(
            jnp.full(n_blocks - 1, bt, jnp.int32),
            t - bt * jnp.arange(1, n_blocks, dtype=jnp.int32),
        )

        def body(carry, blk):
            p, beta = carry
            hb, tb, c = blk
            e0 = tb - jnp.einsum("dtn,dnm->dtm", hb, beta)  # pre-BLOCK errors
            return block_update(p, beta, hb, e0, c), None

        (p, beta), _ = jax.lax.scan(
            body, (p, beta), (h_blk[1:], t_blk[1:], c_real)
        )
    return states.replace(p=p, beta=beta), losses


# ------------------------------------------------------------------ dispatch


def fleet_ingest_paged(
    p: jnp.ndarray,
    beta: jnp.ndarray,
    alpha: jnp.ndarray,
    bias: jnp.ndarray,
    window: jnp.ndarray,
    *,
    activation: str = "sigmoid",
    forget: float = 1.0,
    backend: str = "auto",
    block_d: int = 8,
    block_t: int = 32,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Paged entry of the fused ingest family: one arena page's raw
    leaves instead of a stacked ``OSELMState``.

    The cohort-paged runtime streams (C, Ñ, Ñ) + (C, Ñ, m) pages of a
    host arena through the device while the (n, Ñ) shared SLFN basis
    stays put — so the caller holds no pytree, just the four leaves.
    This wrapper rebuilds the page as an ``OSELMState`` carrying the
    UNSTACKED basis (``_shared_basis`` passes a 2-D (α, b) straight
    through both lowerings; no per-device broadcast is materialized)
    and returns raw leaves again: ``(P', β', losses)`` with the same
    per-device pre-train drift scores as ``fleet_ingest``.
    """
    from repro.core.elm import SLFNParams

    states = OSELMState(
        params=SLFNParams(alpha=alpha, bias=bias),
        beta=beta,
        p=p,
        activation=activation,
        forget=forget,
    )
    trained, losses = fleet_ingest(
        states, window, backend=backend,
        block_d=block_d, block_t=block_t, interpret=interpret,
    )
    return trained.p, trained.beta, losses


def fleet_ingest(
    states: OSELMState,
    window: jnp.ndarray,
    targets: jnp.ndarray | None = None,
    *,
    backend: str = "auto",
    block_d: int = 8,
    block_t: int = 32,
    interpret: bool | None = None,
) -> tuple[OSELMState, jnp.ndarray]:
    """Fused tick ingest: (trained fleet, per-device pre-train score).

    ``backend="pallas"`` runs the VMEM-resident kernel, ``"xla"`` the
    fused Woodbury lowering, ``"auto"`` picks Pallas only where it
    compiles natively (TPU) and the XLA form elsewhere — both are the
    same dataflow and match the sequential reference (tests bound
    both). ``interpret=None`` resolves per backend: Mosaic
    (interpret=False) on TPU, the Pallas interpreter on CPU — so the
    runtime's kernel ingest lowers natively on the hardware it was
    built for without a config knob.
    """
    validate_shared_basis(states)  # no-op when already under a trace
    backend = resolve_backend(backend)
    if backend == "pallas":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return fleet_ingest_kernel(
            states, window, targets, block_d=block_d, interpret=interpret,
        )
    return fleet_ingest_xla(states, window, targets, block_t=block_t)
