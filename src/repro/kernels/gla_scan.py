"""Pallas TPU kernel: chunked gated-linear-attention forward.

The sequence-mixing hot spot of the SSM/hybrid architectures
(mamba heads, mLSTM — `models/ssm.py`). One grid step processes one
(batch·head, chunk) pair with the recurrent state carried in VMEM
scratch across the chunk axis:

    h_t = a_t · h_{t-1} + k_t v_tᵀ        y_t = q_tᵀ h_t

Per chunk (C = chunk length, all MXU matmuls):
    y_intra = (q kᵀ ⊙ decay_mask) v
    y_inter = (q ⊙ e^{cum}) · S
    S ← e^{tot} · S + (k ⊙ e^{tot−cum})ᵀ v

Matches `models.ssm.chunked_linear_attention` (the jnp oracle) exactly;
decays arrive as per-token log-decay and are cumulated in-kernel in f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gla_kernel(q_ref, k_ref, v_ref, la_ref, o_ref, s_scr, *, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    q = q_ref[0].astype(jnp.float32)        # (C, dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)        # (C, dv)
    la = la_ref[0].astype(jnp.float32)      # (C,) log decay, ≤ 0 (0 on padding)

    cum = jnp.cumsum(la)                    # (C,) inclusive
    tot = cum[-1]

    # inter-chunk: y += (q ⊙ e^{cum}) S_prev
    y = jax.lax.dot_general(
        q * jnp.exp(cum)[:, None], s_scr[...],
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )
    # intra-chunk: scores[t,τ] = (q_t·k_τ)·e^{cum_t − cum_τ}, τ ≤ t
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )
    c = q.shape[0]
    rel = cum[:, None] - cum[None, :]
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
        <= jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    )
    gate = jnp.where(tri, jnp.exp(rel), 0.0)
    y = y + jax.lax.dot_general(
        scores * gate, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[0] = y.astype(o_ref.dtype)

    # state update: S ← e^{tot} S + Σ_τ e^{tot − cum_τ} k_τ v_τᵀ
    w = jnp.exp(tot - cum)[:, None]
    s_scr[...] = s_scr[...] * jnp.exp(tot) + jax.lax.dot_general(
        k * w, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def gla_forward(
    q: jnp.ndarray,        # (B, S, H, dk)
    k: jnp.ndarray,
    v: jnp.ndarray,        # (B, S, H, dv)
    log_a: jnp.ndarray,    # (B, S, H)
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused chunked GLA forward. Padding tokens get log_a = 0 and
    zeroed k/v so the carried state is unaffected."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    nc = -(-s // c)
    pad = nc * c - s

    def prep(x, zero_pad):
        xp = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
        if x.ndim == 4:
            return xp.transpose(0, 2, 1, 3).reshape(b * h, nc * c, x.shape[-1])
        return xp.transpose(0, 2, 1).reshape(b * h, nc * c)

    qb = prep(q, False)
    kb = prep(k, True)
    vb = prep(v, True)
    lab = prep(log_a, False)
    if pad:
        valid = (jnp.arange(nc * c) < s)[None, :]
        kb = kb * valid[..., None]
        vb = vb * valid[..., None]
        lab = lab * valid  # log a = 0 → a = 1 on padding

    out = pl.pallas_call(
        functools.partial(_gla_kernel, nc=nc),
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, c, dk), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, c, dk), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, c, dv), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, c), lambda bh, ci: (bh, ci)),
        ],
        out_specs=pl.BlockSpec((1, c, dv), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, nc * c, dv), q.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(qb, kb, vb, lab)
    return out.reshape(b, h, nc * c, dv).transpose(0, 2, 1, 3)[:, :s]
