"""Fused Pallas quantize-on-pack kernel for the quantized merge path.

The lossy merge publish step is pack → error-feedback add → per-tile
int8 quantize (``repro.fleet.quantize``). Running it as separate XLA
ops materializes the packed f32 payload ``w = [U | V]`` AND the
feedback sum ``w + r`` in HBM before the codes are ever produced. This
kernel fuses the whole publish into one VMEM-resident pass per device:

    grid (D,): one program per device payload (≤ ~350 KB of VMEM for
    the largest preset, far under budget)
      1. pack   — concat the logical columns of U (Ñ) and V (m)
      2. EF add — x = [U | V] + residual
      3. per-tile quantize — for each 128-column slab: amax → scale
         (1.0 on an all-zero slab) → round/clip int8 codes, and the
         fresh residual x − dq(codes) in the same pass

    outputs: int8 codes (the wire payload), one f32 scale per
    (device, tile) packed as a lane row, and the next error-feedback
    accumulator — the f32 packed payload never exists in HBM.

int8 outputs use the (32, 128) Mosaic minimum tile (f32 uses (8, 128)),
so rows are padded to 32. The in-kernel concat splits at the unaligned
column Ñ; that relayout is free under ``interpret=True`` (CPU CI) and
acceptable on Mosaic because the whole payload is register/VMEM
resident. ``quantize_pack_xla`` is the bit-identical XLA reference the
CPU parity tests pin against (same reduction/round/clip semantics as
``repro.fleet.quantize.quantize_tiles``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.fleet.quantize import (
    INT8_MAX,
    TILE_COLS,
    dequantize_tiles,
    n_col_tiles,
    quantize_tiles,
)

__all__ = ["quantize_pack", "quantize_pack_xla"]

_LANE = 128
_SUBLANE_I8 = 32  # int8 minimum sublane tile (f32 is 8)


def _pad_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _qpack_kernel(
    u_ref, v_ref, r_ref, codes_ref, scales_ref, resid_ref, *, n: int, m: int, nt: int
):
    u = u_ref[0]
    v = v_ref[0]
    cp = nt * TILE_COLS
    x = jnp.concatenate([u[:, :n], v[:, :m]], axis=1)      # pack [U | V]
    x = jnp.pad(x, ((0, 0), (0, cp - (n + m))))
    x = x + r_ref[0]                                       # error feedback
    codes, resids, scales = [], [], []
    for t in range(nt):                                    # static unroll, nt ≤ 8
        tile = x[:, t * TILE_COLS : (t + 1) * TILE_COLS]
        amax = jnp.max(jnp.abs(tile))
        scale = jnp.where(amax > 0, amax / INT8_MAX, 1.0)
        q = jnp.clip(jnp.round(tile / scale), -INT8_MAX, INT8_MAX)
        codes.append(q.astype(jnp.int8))
        resids.append(tile - q * scale)
        scales.append(scale.reshape(1, 1))
    codes_ref[0] = jnp.concatenate(codes, axis=1)
    resid_ref[0] = jnp.concatenate(resids, axis=1)
    # the ≤ 8 per-tile scales ship as one padded lane row per device
    scales_ref[0] = jnp.concatenate(
        scales + [jnp.zeros((1, _LANE - nt), jnp.float32)], axis=1
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_pack(
    u: jnp.ndarray,
    v: jnp.ndarray,
    residual: jnp.ndarray | None = None,
    *,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused publish step for a stacked fleet: u (D, Ñ, Ñ), v (D, Ñ, m),
    residual (D, Ñ, Ñ+m) or None → ``(codes int8 (D, Ñ, Ñ+m),
    scales f32 (D, nt), residual' f32 (D, Ñ, Ñ+m))``. The network ships
    codes + scales; ``repro.fleet.quantize.dequantize_tiles`` recovers
    the payload on the receive side."""
    d, n, _ = u.shape
    m = v.shape[-1]
    nt = n_col_tiles(n + m)
    if nt > _LANE:
        raise ValueError(f"payload needs {nt} scale tiles > one {_LANE}-lane row")
    cp = nt * TILE_COLS
    if residual is None:
        residual = jnp.zeros((d, n, n + m), jnp.float32)
    rp = _pad_up(n, _SUBLANE_I8)
    up = jnp.pad(u, ((0, 0), (0, rp - n), (0, _pad_up(n, _LANE) - n)))
    vp = jnp.pad(v, ((0, 0), (0, rp - n), (0, _pad_up(m, _LANE) - m)))
    rs = jnp.pad(residual, ((0, 0), (0, rp - n), (0, cp - (n + m))))
    codes, scales, resid = pl.pallas_call(
        functools.partial(_qpack_kernel, n=n, m=m, nt=nt),
        grid=(d,),
        in_specs=[
            pl.BlockSpec((1, rp, up.shape[-1]), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, rp, vp.shape[-1]), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, rp, cp), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, rp, cp), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1, _LANE), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, rp, cp), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, rp, cp), jnp.int8),
            jax.ShapeDtypeStruct((d, 1, _LANE), jnp.float32),
            jax.ShapeDtypeStruct((d, rp, cp), jnp.float32),
        ],
        interpret=interpret,
    )(up, vp, rs)
    return (
        codes[:, :n, : n + m],
        scales[:, 0, :nt],
        resid[:, :n, : n + m],
    )


@jax.jit
def quantize_pack_xla(
    u: jnp.ndarray,
    v: jnp.ndarray,
    residual: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """XLA reference for ``quantize_pack`` — identical semantics (pack,
    error-feedback add, per-tile quantize) through the
    ``repro.fleet.quantize`` codec; the CPU parity baseline."""
    w = jnp.concatenate([u, v], axis=2)
    x = w if residual is None else w + residual
    codes, scales = quantize_tiles(x)
    resid = x - dequantize_tiles(codes, scales)
    return codes, scales, resid
