"""Pallas TPU kernels for the paper's compute hot spots.

Layout per kernel: ``<name>.py`` holds the pallas_call + BlockSpec,
``ops.py`` the jit'd wrappers, ``ref.py`` the pure-jnp oracles the
tests assert against (interpret=True on CPU; Mosaic on TPU).
"""
from repro.kernels.flash_attn import flash_attention
from repro.kernels.fleet_ingest import (
    fleet_ingest,
    fleet_ingest_kernel,
    fleet_ingest_xla,
    ingest_padding,
)
from repro.kernels.gla_scan import gla_forward
from repro.kernels.ops import (
    hidden_proj,
    matmul_atb,
    oselm_step_k1_kernel,
    rank1_add,
    uv_accum,
    uv_from_state_kernel,
)
from repro.kernels.quantize_pack import quantize_pack, quantize_pack_xla
from repro.kernels.robust_merge import (
    robust_segment_combine,
    robust_segment_sum_mix,
    robust_segment_sum_xla,
)
from repro.kernels.topology_merge import (
    banded_merge_solve,
    banded_mix,
    dense_mix,
    from_uv_solve,
    segment_broadcast,
    segment_sum_mix,
    topology_mix,
)

__all__ = [
    "flash_attention",
    "fleet_ingest",
    "fleet_ingest_kernel",
    "fleet_ingest_xla",
    "ingest_padding",
    "gla_forward",
    "quantize_pack",
    "quantize_pack_xla",
    "robust_segment_combine",
    "robust_segment_sum_mix",
    "robust_segment_sum_xla",
    "hidden_proj",
    "matmul_atb",
    "oselm_step_k1_kernel",
    "rank1_add",
    "uv_accum",
    "uv_from_state_kernel",
    "banded_merge_solve",
    "banded_mix",
    "dense_mix",
    "from_uv_solve",
    "segment_broadcast",
    "segment_sum_mix",
    "topology_mix",
]
