"""Pallas kernel family for the fleet topology merge (Eq. 8 at scale).

A fleet merge round is

    mix:   w'ᵢ = Σⱼ Mᵢⱼ wⱼ        w = [U | V]   (D, Ñ, Ñ+m) stacked
    solve: Pᵢ = (U'ᵢ + εI)⁻¹,  βᵢ = (U'ᵢ + εI)⁻¹ V'ᵢ

Materializing the dense D×D mask M costs O(D²·Ñ·(Ñ+m)) FLOPs and HBM
traffic even when the topology touches ≤2·hops neighbors. This module
exploits the adjacency structure directly:

- ``banded_mix`` — ring gossip: grid over (device, col-tile, offset);
  the BlockSpec index map gathers only the ``(d+o) mod D`` neighbor
  blocks (≤ 2·hops+1 of them) per device tile, accumulating in f32
  VMEM. M is never formed.
- ``segment_sum_mix`` / ``segment_broadcast`` — star/hierarchical:
  a scalar-prefetched cluster-id array drives the output (resp. input)
  BlockSpec index map, so member payloads accumulate straight into
  their cluster's aggregate block (contiguous cluster ids → the output
  block is revisited consecutively, the supported TPU accumulation
  pattern) and the merged aggregate is gathered back without a D×D
  product.
- ``dense_mix`` — tiled M @ flatten(w) fallback for arbitrary masks
  (the all-to-all baseline), f32 VMEM accumulation over device tiles.
- ``from_uv_solve`` — the batched §4.2 step-5 solve: one fused
  Gauss-Jordan sweep per device over the augmented system
  [U+εI | I | V] → [I | P | β] held entirely in VMEM/registers, giving
  P and β in a single kernel (no separate Cholesky factor + two
  triangular solves round-tripping through HBM). Elimination without
  pivoting is stable here because U+εI is SPD.
- ``banded_merge_solve`` — the fully fused hot path: neighbor-sum AND
  solve in ONE kernel invocation per device, so the merged (U, V)
  never exists in HBM at all.

All paths run under ``interpret=True`` on CPU (this container) and
lower via Mosaic on TPU, same pattern as ``kernels/ops.py``.
``fleet_merge_kernel`` dispatches a whole stacked ``OSELMState`` merge;
cluster-level solving (one solve per cluster instead of per device when
the merged models are provably identical) comes from
``repro.fleet.fleet.fleet_merge`` which shares the same dispatch logic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.fleet.topology import Topology

__all__ = [
    "banded_mix",
    "segment_sum_mix",
    "masked_segment_sum_mix",
    "segment_broadcast",
    "dense_mix",
    "topology_mix",
    "from_uv_solve",
    "banded_merge_solve",
]

_LANE = 128
_SUBLANE = 8


def _pad_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _pad_stacked(x: jnp.ndarray) -> tuple[jnp.ndarray, int, int]:
    """Pad a stacked (D, R, C) array to f32 tile boundaries (R→8k, C→128k)."""
    d, r, c = x.shape
    rp, cp = _pad_up(r, _SUBLANE), _pad_up(c, _LANE)
    return jnp.pad(x, ((0, 0), (0, rp - r), (0, cp - c))), rp, cp


# --------------------------------------------------------------- banded (ring)


def _banded_kernel(x_ref, o_ref, acc_ref, *, n_off: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += x_ref[...].astype(jnp.float32)

    @pl.when(pl.program_id(2) == n_off - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("hops", "interpret"))
def banded_mix(x: jnp.ndarray, hops: int, *, interpret: bool = True) -> jnp.ndarray:
    """Circular banded neighbor-sum out[d] = Σ_{o=-hops..hops} x[(d+o)%D].

    Requires 2·hops+1 ≤ D (a wider band double-counts; that regime is
    all-to-all and is a plain sum + broadcast)."""
    d, r, c = x.shape
    if 2 * hops + 1 > d:
        raise ValueError(f"band 2*{hops}+1 exceeds n_devices={d}; use a full-sum path")
    xp, rp, cp = _pad_stacked(x)
    n_off = 2 * hops + 1
    out = pl.pallas_call(
        functools.partial(_banded_kernel, n_off=n_off),
        grid=(d, cp // _LANE, n_off),
        in_specs=[
            pl.BlockSpec((1, rp, _LANE), lambda i, j, o: ((i + o - hops) % d, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, rp, _LANE), lambda i, j, o: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((d, rp, cp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, rp, _LANE), jnp.float32)],
        interpret=interpret,
    )(xp)
    return out[:, :r, :c]


# ------------------------------------------------------- segment (star / hier)


def _segsum_kernel(cids_ref, x_ref, o_ref, acc_ref):
    d = pl.program_id(1)
    first = jnp.logical_or(
        d == 0, cids_ref[d] != cids_ref[jnp.maximum(d - 1, 0)]
    )

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += x_ref[...].astype(jnp.float32)
    # the out block tracks this device's segment: the last write of a
    # contiguous cluster run is the completed aggregate
    o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def segment_sum_mix(
    x: jnp.ndarray, cluster_ids, n_clusters: int, *, interpret: bool = True
) -> jnp.ndarray:
    """Cluster aggregates (C, R, Cc) = segment_sum(x, cluster_ids).

    ``cluster_ids`` must be sorted (contiguous clusters, as built by
    ``fleet.topology.hierarchical``) so each output block is revisited
    consecutively — the accumulator resets on every id change, so
    unsorted ids would silently drop earlier partials. Validated here
    on the host array."""
    cids = np.asarray(cluster_ids)
    if not np.all(np.diff(cids) >= 0):
        raise ValueError(
            "segment_sum_mix needs sorted (contiguous-cluster) cluster_ids; "
            "sort the device axis by cluster first"
        )
    return _segment_sum_mix_call(x, jnp.asarray(cids, jnp.int32), n_clusters,
                                 interpret=interpret)


@functools.partial(jax.jit, static_argnames=("n_clusters", "interpret"))
def _segment_sum_mix_call(
    x: jnp.ndarray, cluster_ids: jnp.ndarray, n_clusters: int, *, interpret: bool = True
) -> jnp.ndarray:
    d, r, c = x.shape
    xp, rp, cp = _pad_stacked(x)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(cp // _LANE, d),
        in_specs=[pl.BlockSpec((1, rp, _LANE), lambda j, i, cids: (i, 0, j))],
        out_specs=pl.BlockSpec((1, rp, _LANE), lambda j, i, cids: (cids[i], 0, j)),
        scratch_shapes=[pltpu.VMEM((1, rp, _LANE), jnp.float32)],
    )
    out = pl.pallas_call(
        _segsum_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_clusters, rp, cp), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(cluster_ids, jnp.int32), xp)
    return out[:, :r, :c]


def _masked_segsum_kernel(cids_ref, mask_ref, x_ref, o_ref, acc_ref):
    d = pl.program_id(1)
    first = jnp.logical_or(
        d == 0, cids_ref[d] != cids_ref[jnp.maximum(d - 1, 0)]
    )

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # the participation gate is applied in VMEM as the block streams in:
    # a quarantined device's payload is read but contributes 0, so the
    # masked stack is never materialized in HBM and the mask can change
    # every merge round without retracing (it is a traced operand)
    acc_ref[...] += x_ref[...].astype(jnp.float32) * mask_ref[d].astype(jnp.float32)
    o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def masked_segment_sum_mix(
    x: jnp.ndarray,
    cluster_ids,
    mask: jnp.ndarray,
    n_clusters: int,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Participation-masked cluster aggregates: out[c] = Σ_{d: cid[d]=c}
    mask[d]·x[d]. Same contiguous-cluster requirement as
    ``segment_sum_mix``; ``mask`` is a traced (D,) 0/1 vector prefetched
    next to the cluster ids, so gating devices in and out of a merge
    round never recompiles the kernel."""
    cids = np.asarray(cluster_ids)
    if not np.all(np.diff(cids) >= 0):
        raise ValueError(
            "masked_segment_sum_mix needs sorted (contiguous-cluster) cluster_ids; "
            "sort the device axis by cluster first"
        )
    return _masked_segment_sum_mix_call(
        x, jnp.asarray(cids, jnp.int32), jnp.asarray(mask, jnp.float32),
        n_clusters, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("n_clusters", "interpret"))
def _masked_segment_sum_mix_call(
    x: jnp.ndarray,
    cluster_ids: jnp.ndarray,
    mask: jnp.ndarray,
    n_clusters: int,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    d, r, c = x.shape
    xp, rp, cp = _pad_stacked(x)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(cp // _LANE, d),
        in_specs=[pl.BlockSpec((1, rp, _LANE), lambda j, i, cids, mask: (i, 0, j))],
        out_specs=pl.BlockSpec((1, rp, _LANE), lambda j, i, cids, mask: (cids[i], 0, j)),
        scratch_shapes=[pltpu.VMEM((1, rp, _LANE), jnp.float32)],
    )
    out = pl.pallas_call(
        _masked_segsum_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_clusters, rp, cp), jnp.float32),
        interpret=interpret,
    )(cluster_ids, mask, xp)
    return out[:, :r, :c]


def _gather_kernel(cids_ref, s_ref, o_ref):
    o_ref[...] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def segment_broadcast(
    cluster_sums: jnp.ndarray, cluster_ids: jnp.ndarray, *, interpret: bool = True
) -> jnp.ndarray:
    """Gather each device's cluster aggregate back: out[d] = sums[cid[d]]."""
    d = cluster_ids.shape[0]
    _, r, c = cluster_sums.shape
    sp, rp, cp = _pad_stacked(cluster_sums)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(cp // _LANE, d),
        in_specs=[pl.BlockSpec((1, rp, _LANE), lambda j, i, cids: (cids[i], 0, j))],
        out_specs=pl.BlockSpec((1, rp, _LANE), lambda j, i, cids: (i, 0, j)),
    )
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((d, rp, cp), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(cluster_ids, jnp.int32), sp)
    return out[:, :r, :c]


# -------------------------------------------------------------- dense fallback


def _dense_kernel(m_ref, x_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        m_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bi", "bj", "bk", "interpret"))
def dense_mix(
    x: jnp.ndarray,
    matrix: jnp.ndarray,
    *,
    bi: int = 128,
    bj: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Tiled out = M @ flatten(x) for an arbitrary (D, D) mask — the
    dense baseline the sparse paths are measured against."""
    d, r, c = x.shape
    f = r * c
    xf = x.reshape(d, f)
    dp_i, dp_k, fp = _pad_up(d, bi), _pad_up(d, bk), _pad_up(f, bj)
    mp = jnp.pad(jnp.asarray(matrix, jnp.float32), ((0, dp_i - d), (0, dp_k - d)))
    xfp = jnp.pad(xf, ((0, dp_k - d), (0, fp - f)))
    nk = dp_k // bk
    out = pl.pallas_call(
        functools.partial(_dense_kernel, nk=nk),
        grid=(dp_i // bi, fp // bj, nk),
        in_specs=[
            pl.BlockSpec((bi, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bj), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dp_i, fp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bi, bj), jnp.float32)],
        interpret=interpret,
    )(mp, xfp)
    return out[:d, :f].reshape(d, r, c)


# ------------------------------------------------------------------- dispatch


def topology_mix(
    x: jnp.ndarray, topology: Topology, *, interpret: bool = True
) -> jnp.ndarray:
    """Kernel equivalent of ``Topology.mix`` on a stacked (D, R, C)
    array — same dispatch, Pallas execution."""
    if topology.kind == "segment":
        sums = segment_sum_mix(
            x, topology.cluster_ids, topology.n_clusters, interpret=interpret
        )
        if topology.head_exchange:
            total = jnp.sum(sums, axis=0)  # O(clusters) head exchange
            return jnp.broadcast_to(total[None], x.shape)
        return segment_broadcast(sums, topology.cluster_ids, interpret=interpret)
    if topology.kind == "banded":
        if topology.band_closed:
            total = jnp.sum(x, axis=0)
            return jnp.broadcast_to(total[None], x.shape)
        return banded_mix(x, topology.hops, interpret=interpret)
    return dense_mix(x, topology.dense_matrix(), interpret=interpret)


# ----------------------------------------------- fused Gauss-Jordan (U,V) solve


def _gj_sweep(w: jnp.ndarray, n: int, rows: jnp.ndarray, cols: jnp.ndarray):
    """n in-place elimination steps on the augmented [A | I | V] block;
    afterwards cols n_p:n_p+n hold A⁻¹ and the V block holds A⁻¹V."""

    def body(k, w):
        row_k = jnp.sum(jnp.where(rows == k, w, 0.0), axis=0, keepdims=True)
        pivot = jnp.sum(jnp.where(cols == k, row_k, 0.0))
        row_k = row_k / pivot
        col_k = jnp.sum(jnp.where(cols == k, w, 0.0), axis=1, keepdims=True)
        e_k = jnp.where(rows == k, 1.0, 0.0)
        return w - (col_k - e_k) * row_k

    return jax.lax.fori_loop(0, n, body, w)


def _solve_kernel(w_ref, o_ref, *, n: int, n_p: int, w_p: int):
    rows = jax.lax.broadcasted_iota(jnp.int32, (n_p, 1), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, w_p), 1)
    o_ref[0] = _gj_sweep(w_ref[0], n, rows, cols)


def _augment(u: jnp.ndarray, v: jnp.ndarray, ridge: float, n_p: int, w_p: int):
    """[U+εI | I | V] per device, padded so rows n..n_p are the identity
    (inert under elimination since the sweep only pivots k < n)."""
    dn, n, _ = u.shape
    m = v.shape[-1]
    diag = jnp.concatenate(
        [jnp.full(n, ridge, u.dtype), jnp.ones(n_p - n, u.dtype)]
    )
    a = jnp.pad(u, ((0, 0), (0, n_p - n), (0, n_p - n))) + jnp.diag(diag)[None]
    eye = jnp.broadcast_to(
        jnp.pad(jnp.eye(n, dtype=u.dtype), ((0, n_p - n), (0, 0))), (dn, n_p, n)
    )
    vp = jnp.pad(v, ((0, 0), (0, n_p - n), (0, 0)))
    w = jnp.concatenate([a, eye, vp], axis=2)
    return jnp.pad(w, ((0, 0), (0, 0), (0, w_p - (n_p + n + m))))


@functools.partial(jax.jit, static_argnames=("ridge", "interpret"))
def from_uv_solve(
    u: jnp.ndarray,
    v: jnp.ndarray,
    *,
    ridge: float = 0.0,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched §4.2 step 5 over the leading device axis: ridge-add +
    solve fused into one Gauss-Jordan kernel per device, returning
    P = (U+εI)⁻¹ and β = (U+εI)⁻¹V without an intermediate Cholesky
    factor in HBM. Drop-in for vmap(from_uv)."""
    dn, n, _ = u.shape
    m = v.shape[-1]
    n_p = _pad_up(n, _SUBLANE)
    w_p = _pad_up(n_p + n + m, _LANE)
    w = _augment(u, v, ridge, n_p, w_p)
    out = pl.pallas_call(
        functools.partial(_solve_kernel, n=n, n_p=n_p, w_p=w_p),
        grid=(dn,),
        in_specs=[pl.BlockSpec((1, n_p, w_p), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, n_p, w_p), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((dn, n_p, w_p), jnp.float32),
        interpret=interpret,
    )(w)
    return out[:, :n, n_p : n_p + n], out[:, :n, n_p + n : n_p + n + m]


# ------------------------------------------- fully fused banded merge + solve


def _banded_solve_kernel(*refs, n: int, n_p: int, w_p: int, n_off: int, ridge: float):
    """refs = (x_ref × n_off, p_ref, beta_ref): sum the neighbor blocks
    in VMEM, build the augmented system in registers, eliminate, write
    (P, β) — merged (U, V) never touches HBM.

    The payload blocks are laid out [U (n_p cols, zero-padded) | V (m)].
    """
    x_refs, p_ref, b_ref = refs[:n_off], refs[n_off], refs[n_off + 1]
    wsum = x_refs[0][0].astype(jnp.float32)
    for r in x_refs[1:]:
        wsum = wsum + r[0].astype(jnp.float32)

    rows = jax.lax.broadcasted_iota(jnp.int32, (n_p, 1), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, w_p), 1)
    a_cols = jax.lax.broadcasted_iota(jnp.int32, (1, n_p), 1)
    # augmented [U+εI | I | V] assembled from the summed [U | V] block:
    # ridge on the live diagonal, 1 on the inert padded rows
    reg = jnp.where(
        (rows == a_cols) & (rows < n), ridge, jnp.where(rows == a_cols, 1.0, 0.0)
    )
    a = wsum[:, :n_p] + reg
    eye_blk = jnp.where(
        (rows == jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)) & (rows < n), 1.0, 0.0
    )
    v_blk = wsum[:, n_p:]
    w = jnp.concatenate([a, eye_blk, v_blk], axis=1)
    w = jnp.pad(w, ((0, 0), (0, w_p - w.shape[1])))
    w = _gj_sweep(w, n, rows, cols)
    m = v_blk.shape[1]
    p_ref[0] = jnp.pad(w[:, n_p : n_p + n], ((0, 0), (0, p_ref.shape[-1] - n)))
    b_ref[0] = jnp.pad(w[:, n_p + n : n_p + n + m], ((0, 0), (0, b_ref.shape[-1] - m)))


@functools.partial(jax.jit, static_argnames=("hops", "ridge", "interpret"))
def banded_merge_solve(
    w: jnp.ndarray,
    hops: int,
    *,
    ridge: float = 0.0,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The fused ring hot path: ``w`` is the stacked [U | V] payload
    (D, Ñ, Ñ+m) — Ñ is read off the row dimension; one kernel
    invocation per device gathers its ≤2·hops+1 neighbor blocks, sums
    them in VMEM, and solves for (P, β) in place.
    """
    d, n, nm = w.shape
    m = nm - n
    if 2 * hops + 1 > d:
        raise ValueError(f"band 2*{hops}+1 exceeds n_devices={d}; use a full-sum path")
    n_off = 2 * hops + 1
    n_p = _pad_up(n, _SUBLANE)
    w_p = _pad_up(n_p + n + m, _LANE)
    # re-lay the payload as [U (zero-padded to n_p cols) | V] so the
    # in-kernel column split lands on the sublane-aligned n_p boundary
    wp = jnp.concatenate(
        [jnp.pad(w[:, :, :n], ((0, 0), (0, n_p - n), (0, n_p - n))),
         jnp.pad(w[:, :, n:], ((0, 0), (0, n_p - n), (0, 0)))],
        axis=2,
    )  # (D, n_p, n_p + m)
    p_cols = _pad_up(n, _LANE)
    b_cols = _pad_up(m, _LANE)
    specs = [
        pl.BlockSpec((1, n_p, n_p + m), lambda i, o=o: ((i + o - hops) % d, 0, 0))
        for o in range(n_off)
    ]
    p_out, b_out = pl.pallas_call(
        functools.partial(
            _banded_solve_kernel, n=n, n_p=n_p, w_p=w_p, n_off=n_off, ridge=ridge
        ),
        grid=(d,),
        in_specs=specs,
        out_specs=[
            pl.BlockSpec((1, n_p, p_cols), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n_p, b_cols), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, n_p, p_cols), jnp.float32),
            jax.ShapeDtypeStruct((d, n_p, b_cols), jnp.float32),
        ],
        interpret=interpret,
    )(*([wp] * n_off))
    return p_out[:, :n, :n], b_out[:, :n, :m]
