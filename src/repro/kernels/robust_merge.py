"""Byzantine-robust segment-sum kernel — the clipped/trimmed variant of
``masked_segment_sum_mix``.

The plain Eq. 8 merge sums raw (U, V) payloads, so one device shipping
a scaled or poisoned contribution moves every neighbor's merged model
by an unbounded amount. The robust reduction bounds that influence in
two composable ways, both evaluated INSIDE the streaming segment-sum:

- **clipping** — each device's payload is scaled by a prefetched
  per-device factor (``min(1, clip_norm / ‖w‖_F)``, computed by
  ``repro.fleet.robust.payload_clip``), so no single contribution can
  dominate the sum by magnitude;
- **trimming** — alongside the masked running total, the kernel keeps
  the ``trim`` smallest and ``trim`` largest participating values PER
  COORDINATE in VMEM register chains (classic online k-extrema
  insertion: the chains stay sorted, one min/max swap per register per
  device). The caller combines the three outputs into the
  coordinate-wise trimmed-mean estimate of the segment sum
  (``robust_segment_combine``): with ≤ ``trim`` adversarial devices
  per segment, every surviving coordinate lies within the honest
  participants' range.

Grid/BlockSpec structure is identical to ``_masked_segsum_kernel``
(``repro.kernels.topology_merge``): contiguous sorted cluster ids drive
the output index map, the accumulator and extrema registers reset on
every id change, and the last write of a cluster's contiguous run wins.
``cids``/``mask``/``scale`` are scalar-prefetched so participation and
clipping change every merge round without retracing. ``trim`` is static
(it sizes the register chains).

``robust_segment_sum_xla`` is the sort-based XLA oracle the parity
tests hold the kernel to (≤1e-5); both sanitize the ±inf sentinels of
under-filled registers to 0, so outputs are finite even for segments
with fewer than ``trim`` participants (the combine falls back to the
plain sum there anyway).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.topology_merge import _LANE, _pad_stacked

__all__ = [
    "robust_segment_combine",
    "robust_segment_sum_mix",
    "robust_segment_sum_xla",
]


def _robust_segsum_kernel(
    cids_ref, mask_ref, scale_ref, x_ref, tot_ref, lo_ref, hi_ref,
    acc_ref, *extrema_refs, trim: int,
):
    d = pl.program_id(1)
    first = jnp.logical_or(
        d == 0, cids_ref[d] != cids_ref[jnp.maximum(d - 1, 0)]
    )
    mins, maxs = extrema_refs[:trim], extrema_refs[trim:]

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        for mr in mins:
            mr[...] = jnp.full_like(mr, jnp.inf)
        for xr in maxs:
            xr[...] = jnp.full_like(xr, -jnp.inf)

    # clipping fuses into the stream: the payload block is scaled as it
    # is read, so the clipped stack never exists in HBM
    m = mask_ref[d].astype(jnp.float32)
    v = x_ref[...].astype(jnp.float32) * scale_ref[d]
    acc_ref[...] += v * m

    # online k-extrema insertion chains: each register chain is kept
    # sorted; masked devices insert ±inf sentinels, which never displace
    # a participating value
    lo_v = jnp.where(m > 0, v, jnp.inf)
    for mr in mins:
        cur = mr[...]
        mr[...] = jnp.minimum(cur, lo_v)
        lo_v = jnp.maximum(cur, lo_v)
    hi_v = jnp.where(m > 0, v, -jnp.inf)
    for xr in maxs:
        cur = xr[...]
        xr[...] = jnp.maximum(cur, hi_v)
        hi_v = jnp.minimum(cur, hi_v)

    # the out blocks track this device's segment: the last write of a
    # contiguous cluster run is the completed aggregate. Under-filled
    # registers still hold ±inf — sanitized to 0 so the outputs stay
    # finite (the combine discards lo/hi for such segments anyway).
    tot_ref[...] = acc_ref[...]
    lo_sum = jnp.zeros_like(acc_ref[...])
    for mr in mins:
        cur = mr[...]
        lo_sum = lo_sum + jnp.where(jnp.isfinite(cur), cur, 0.0)
    lo_ref[...] = lo_sum
    hi_sum = jnp.zeros_like(acc_ref[...])
    for xr in maxs:
        cur = xr[...]
        hi_sum = hi_sum + jnp.where(jnp.isfinite(cur), cur, 0.0)
    hi_ref[...] = hi_sum


def robust_segment_sum_mix(
    x: jnp.ndarray,
    cluster_ids,
    mask: jnp.ndarray,
    scale: jnp.ndarray,
    n_clusters: int,
    trim: int,
    *,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Clipped/trimmed masked cluster aggregates.

    Returns ``(total, lo, hi)``, each (n_clusters, R, C):
    ``total[c] = Σ_{d: cid[d]=c} mask[d]·scale[d]·x[d]`` and ``lo``/``hi``
    the coordinate-wise sums of the ``trim`` smallest/largest
    participating scaled values per cluster. Same contiguous-sorted
    cluster-id requirement as ``segment_sum_mix``; ``mask`` and
    ``scale`` are traced (D,) operands, so gating and re-clipping never
    recompile. ``trim=0`` degenerates to ``masked_segment_sum_mix``
    outputs (with zero lo/hi)."""
    cids = np.asarray(cluster_ids)
    if not np.all(np.diff(cids) >= 0):
        raise ValueError(
            "robust_segment_sum_mix needs sorted (contiguous-cluster) "
            "cluster_ids; sort the device axis by cluster first"
        )
    if trim < 0:
        raise ValueError(f"need trim >= 0, got {trim}")
    return _robust_segment_sum_mix_call(
        x, jnp.asarray(cids, jnp.int32), jnp.asarray(mask, jnp.float32),
        jnp.asarray(scale, jnp.float32), n_clusters, trim,
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("n_clusters", "trim", "interpret"))
def _robust_segment_sum_mix_call(
    x: jnp.ndarray,
    cluster_ids: jnp.ndarray,
    mask: jnp.ndarray,
    scale: jnp.ndarray,
    n_clusters: int,
    trim: int,
    *,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    d, r, c = x.shape
    xp, rp, cp = _pad_stacked(x)
    out_spec = pl.BlockSpec((1, rp, _LANE), lambda j, i, cids, mask, scale: (cids[i], 0, j))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(cp // _LANE, d),
        in_specs=[
            pl.BlockSpec((1, rp, _LANE), lambda j, i, cids, mask, scale: (i, 0, j))
        ],
        out_specs=[out_spec, out_spec, out_spec],
        scratch_shapes=[pltpu.VMEM((1, rp, _LANE), jnp.float32)] * (1 + 2 * trim),
    )
    shape = jax.ShapeDtypeStruct((n_clusters, rp, cp), jnp.float32)
    tot, lo, hi = pl.pallas_call(
        functools.partial(_robust_segsum_kernel, trim=trim),
        grid_spec=grid_spec,
        out_shape=[shape, shape, shape],
        interpret=interpret,
    )(cluster_ids, mask, scale, xp)
    return tot[:, :r, :c], lo[:, :r, :c], hi[:, :r, :c]


def robust_segment_sum_xla(
    x: jnp.ndarray,
    cluster_ids,
    mask: jnp.ndarray,
    scale: jnp.ndarray,
    n_clusters: int,
    trim: int,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Sort-based XLA oracle of ``robust_segment_sum_mix`` — identical
    semantics, including the ±inf→0 sanitization of segments with fewer
    than ``trim`` participants. Cluster membership is static (host
    cluster ids), so the per-cluster loop unrolls at trace time."""
    cids = np.asarray(cluster_ids)
    mf = jnp.asarray(mask, jnp.float32)
    v = jnp.asarray(x, jnp.float32) * jnp.asarray(scale, jnp.float32)[:, None, None]
    tot = jax.ops.segment_sum(
        v * mf[:, None, None], jnp.asarray(cids, jnp.int32),
        num_segments=n_clusters,
    )
    if trim == 0:
        z = jnp.zeros_like(tot)
        return tot, z, z
    los, his = [], []
    for cluster in range(n_clusters):
        sel = np.flatnonzero(cids == cluster)
        vc = v[sel]
        live = (mf[sel] > 0)[:, None, None]
        k = min(trim, len(sel))
        lo_k = jnp.sort(jnp.where(live, vc, jnp.inf), axis=0)[:k]
        hi_k = jnp.sort(jnp.where(live, vc, -jnp.inf), axis=0)[len(sel) - k:]
        los.append(jnp.where(jnp.isfinite(lo_k), lo_k, 0.0).sum(0))
        his.append(jnp.where(jnp.isfinite(hi_k), hi_k, 0.0).sum(0))
    return tot, jnp.stack(los), jnp.stack(his)


def robust_segment_combine(
    tot: jnp.ndarray,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    counts: jnp.ndarray,
    trim: int,
) -> jnp.ndarray:
    """Coordinate-wise trimmed-mean estimate of each segment SUM:
    ``(tot − lo − hi) / (count − 2·trim) · count``. Scaling the trimmed
    mean back by the participant count keeps the estimate in Eq. 8's
    sum units, so ``trim=0`` is exactly the plain masked sum and the
    downstream (U+εI)⁻¹ solve is unchanged. Segments with ≤ 2·trim
    participants cannot be trimmed and fall back to their plain sum."""
    if trim == 0:
        return tot
    counts = jnp.asarray(counts, jnp.float32).reshape(-1, 1, 1)
    live = counts - 2.0 * trim
    trimmed = (tot - lo - hi) / jnp.maximum(live, 1.0) * counts
    return jnp.where(live >= 1.0, trimmed, tot)
