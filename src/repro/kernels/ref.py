"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's test sweeps shapes/dtypes and asserts allclose against
these references (kernels run in interpret mode on CPU; on a real TPU
the same pallas_call lowers to Mosaic).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.activations import get_activation


def hidden_proj_ref(x: jnp.ndarray, alpha: jnp.ndarray, bias: jnp.ndarray, activation: str) -> jnp.ndarray:
    """H = G(x·α + b); accumulation in f32."""
    g = get_activation(activation)
    h = jnp.dot(x.astype(jnp.float32), alpha.astype(jnp.float32)) + bias.astype(jnp.float32)
    return g(h)


def atb_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """AᵀB with f32 accumulation — U = HᵀH, V = Hᵀt building block."""
    return jnp.dot(a.astype(jnp.float32).T, b.astype(jnp.float32))


def rank1_add_ref(x: jnp.ndarray, u: jnp.ndarray, v: jnp.ndarray, scale) -> jnp.ndarray:
    """O = X + scale · u vᵀ."""
    return x.astype(jnp.float32) + scale * jnp.outer(u.astype(jnp.float32), v.astype(jnp.float32))


def oselm_step_k1_ref(
    p: jnp.ndarray, beta: jnp.ndarray, h: jnp.ndarray, t: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The fused k=1 OS-ELM update this kernel package implements:

        ph    = P h            (P symmetric)
        denom = 1 + hᵀ P h
        P'    = P − (ph)(ph)ᵀ / denom
        β'    = β + (ph)(t − hᵀβ)ᵀ / denom     [since P'h = ph/denom]
    """
    p = p.astype(jnp.float32)
    h = h.astype(jnp.float32)
    beta = beta.astype(jnp.float32)
    t = t.astype(jnp.float32)
    ph = p @ h
    denom = 1.0 + h @ ph
    p_new = p - jnp.outer(ph, ph) / denom
    err = t - h @ beta
    beta_new = beta + jnp.outer(ph, err) / denom
    return p_new, beta_new
