"""Pallas TPU kernel: blocked rank-1 update O = X + scale · u ⊗ v.

The OS-ELM k=1 sequential step (Eq. 12 with scalar reciprocal) is two
rank-1 updates:

    P' = P − (Ph)(Ph)ᵀ / denom         (scale = −1/denom, u = v = Ph)
    β' = β + (Ph)(t − hᵀβ)ᵀ / denom    (scale = +1/denom, u = Ph, v = err)

Each (bi × bj) output tile touches only bi + bj vector elements — the
kernel is memory-bound (arithmetic intensity ≈ 1 FLOP/byte on X), so the
tiles are sized to stream X through VMEM at full HBM bandwidth. ``u`` is
delivered as a (1, N) row and transposed in-register to a column.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rank1_kernel(x_ref, u_ref, v_ref, s_ref, o_ref):
    scale = s_ref[0, 0]
    u_col = u_ref[...].T  # (bi, 1) in-register transpose
    o_ref[...] = (
        x_ref[...].astype(jnp.float32)
        + scale * u_col.astype(jnp.float32) * v_ref[...].astype(jnp.float32)
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bi", "bj", "interpret"))
def rank1_add(
    x: jnp.ndarray,
    u: jnp.ndarray,
    v: jnp.ndarray,
    scale: jnp.ndarray | float,
    *,
    bi: int = 256,
    bj: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """O = X + scale·u vᵀ for X:(N1,N2), u:(N1,), v:(N2,) → f32."""
    n1, n2 = x.shape
    assert u.shape == (n1,) and v.shape == (n2,)
    n1p = -(-n1 // bi) * bi
    n2p = -(-n2 // bj) * bj
    xp = jnp.pad(x, ((0, n1p - n1), (0, n2p - n2)))
    up = jnp.pad(u, (0, n1p - n1))[None, :]  # (1, N1)
    vp = jnp.pad(v, (0, n2p - n2))[None, :]  # (1, N2)
    s = jnp.asarray(scale, jnp.float32).reshape(1, 1)

    out = pl.pallas_call(
        _rank1_kernel,
        grid=(n1p // bi, n2p // bj),
        in_specs=[
            pl.BlockSpec((bi, bj), lambda i, j: (i, j)),
            pl.BlockSpec((1, bi), lambda i, j: (0, i)),
            pl.BlockSpec((1, bj), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n1p, n2p), jnp.float32),
        interpret=interpret,
    )(xp, up, vp, s)
    return out[:n1, :n2]
