"""Pallas TPU kernel: fused flash attention (forward).

§Perf iteration 1b (EXPERIMENTS.md): the pure-jnp blockwise attention
keeps score chunks *logically* small, but every (cq × ck) f32 chunk
round-trips HBM between XLA fusions — the dominant memory-roofline term
for the attention-heavy architectures. This kernel keeps the online-
softmax state (m, l, acc) and the score chunk in VMEM for the whole KV
sweep; HBM traffic collapses to q/k/v reads + one output write (the
``t_memory_fused_attn`` roofline term).

Layout: grid = (B·H, nq, nk), nk innermost so the VMEM scratch carries
across KV steps of one query block. Blocks are MXU-aligned (cq, ck
multiples of 128 on the lane dim; hd is the contraction).

Causal masking is positional (block offsets), matching
``models.layers.blockwise_attention`` exactly; the jnp oracle for tests
is that function.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, nk: int, cq: int, ck: int, sk: int,
):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                           # (cq, hd)
    k = k_ref[0]                           # (ck, hd)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                              # (cq, ck)

    kpos = kk * ck + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 1)
    valid = kpos < sk
    if causal:
        qi = pl.program_id(1)
        qpos = qi * cq + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 0)
        valid = valid & (kpos <= qpos)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new

    @pl.when(kk == nk - 1)
    def _store():
        o_ref[0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "cq", "ck", "interpret")
)
def flash_attention(
    q: jnp.ndarray,   # (B, S, H, hd)
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    cq: int = 128,
    ck: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused flash attention forward (Pallas, VMEM-resident softmax)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = hd ** -0.5
    cq = min(cq, max(sq, 8))
    ck = min(ck, max(sk, 8))
    nq = -(-sq // cq)
    nk = -(-sk // ck)

    def to_bh(x, s, c, n):
        xp = jnp.pad(x, ((0, 0), (0, n * c - s), (0, 0), (0, 0)))
        return xp.transpose(0, 2, 1, 3).reshape(b * h, n * c, hd)

    qb = to_bh(q, sq, cq, nq)
    kb = to_bh(k, sk, ck, nk)
    vb = to_bh(v, sk, ck, nk)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal,
            nk=nk, cq=cq, ck=ck, sk=sk,
        ),
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, cq, hd), lambda bh, qi, kk: (bh, qi, 0)),
            pl.BlockSpec((1, ck, hd), lambda bh, qi, kk: (bh, kk, 0)),
            pl.BlockSpec((1, ck, hd), lambda bh, qi, kk: (bh, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, cq, hd), lambda bh, qi, kk: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, nq * cq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((cq,), jnp.float32),
            pltpu.VMEM((cq,), jnp.float32),
            pltpu.VMEM((cq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qb, kb, vb)
    return out.reshape(b, h, nq * cq, hd).transpose(0, 2, 1, 3)[:, :sq]
