"""granite-34b [dense] — llama-arch code model, MQA.

[arXiv:2405.04324] Granite Code Models.
88L d_model=6144 48H (kv=1 — multi-query attention) d_ff=24576
vocab=49152.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b",
    family="dense",
    source="arXiv:2405.04324",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    head_dim=128,
    ffn_type="gelu_mlp",       # GPT-BigCode MLP (no gate) — matches the 34B size
    moment_dtype="bfloat16",
    num_microbatches=4,
)
