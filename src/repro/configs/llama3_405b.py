"""llama3-405b [dense] — GQA, 128k vocab.

[arXiv:2407.21783] The Llama 3 Herd of Models.
126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
bf16 Adam moments + 8 grad-accumulation microbatches: required to fit
~405B params of optimizer state into 256×16 GB v5e HBM (DESIGN.md §6).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b",
    family="dense",
    source="arXiv:2407.21783",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab=128256,
    head_dim=128,
    rope_theta=500000.0,
    moment_dtype="bfloat16",
    num_microbatches=8,
)
