"""gemma3-1b [dense] — 5:1 local:global attention, 128k/262k vocab.

[hf:google/gemma-3-1b-pt]
26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144 head_dim=256.
Five sliding-window (512) layers per one global layer. (Gemma 3 uses
rope_theta 1M on global layers / 10k local; we keep a single table —
noted in DESIGN.md §8.)
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab=262144,
    head_dim=256,
    sliding_window=512,
    global_every=6,            # layers 6,12,18,24 global (1-indexed multiple)
)
