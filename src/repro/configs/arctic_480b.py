"""arctic-480b [moe] — 128 experts top-2 + dense residual.

[hf:Snowflake/snowflake-arctic-base]
35L d_model=7168 56H (GQA kv=8) d_ff=4864, MoE 128 experts top-2 with a
dense FFN residual computed in parallel with the MoE branch (Arctic's
dense-MoE hybrid architecture).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    source="hf:Snowflake/snowflake-arctic-base",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab=32000,
    head_dim=128,
    n_experts=128,
    experts_per_token=2,
    dense_residual=True,
    tokens_per_group=1024,
    moment_dtype="bfloat16",
    num_microbatches=4,     # §Perf 2.1: FSDP weight gathers repeat per microbatch
)
