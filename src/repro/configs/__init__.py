"""Architecture registry — one module per assigned architecture.

``get_config(name)`` resolves any of the 10 assigned IDs (plus the
paper's own edge-device OS-ELM config in ``oselm_edge``).
"""
from __future__ import annotations

from repro.models.config import INPUT_SHAPES, ArchConfig, ShapeConfig

from repro.configs.hymba_1_5b import CONFIG as hymba_1_5b
from repro.configs.llama3_405b import CONFIG as llama3_405b
from repro.configs.xlstm_1_3b import CONFIG as xlstm_1_3b
from repro.configs.seamless_m4t_medium import CONFIG as seamless_m4t_medium
from repro.configs.granite_34b import CONFIG as granite_34b
from repro.configs.granite_moe_3b_a800m import CONFIG as granite_moe_3b_a800m
from repro.configs.granite_3_2b import CONFIG as granite_3_2b
from repro.configs.gemma3_1b import CONFIG as gemma3_1b
from repro.configs.arctic_480b import CONFIG as arctic_480b
from repro.configs.llama_3_2_vision_11b import CONFIG as llama_3_2_vision_11b

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in (
        hymba_1_5b,
        llama3_405b,
        xlstm_1_3b,
        seamless_m4t_medium,
        granite_34b,
        granite_moe_3b_a800m,
        granite_3_2b,
        gemma3_1b,
        arctic_480b,
        llama_3_2_vision_11b,
    )
}


def get_config(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError as e:
        raise ValueError(f"unknown arch {name!r}; have {sorted(ARCHS)}") from e


__all__ = ["ARCHS", "INPUT_SHAPES", "ArchConfig", "ShapeConfig", "get_config"]
