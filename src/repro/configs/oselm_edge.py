"""The paper's own edge-device configuration (Table 3).

OS-ELM autoencoder hyperparameters per dataset: activation G, init
distribution p(x), hidden width Ñ, loss L=MSE, batch k=1, epochs E=1,
forget factor λ=1, two detector instances [18].
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class EdgeConfig:
    dataset: str
    n_features: int
    n_hidden: int
    activation: str
    init_dist: str = "uniform"
    batch_k: int = 1
    epochs: int = 1
    forget: float = 1.0
    n_instances: int = 2
    ridge: float = 1e-3  # f32 guard; paper runs f64 with ridge 0


EDGE_CONFIGS: dict[str, EdgeConfig] = {
    "driving": EdgeConfig("driving", 225, 16, "sigmoid"),
    "har": EdgeConfig("har", 561, 128, "identity"),
    "mnist_like": EdgeConfig("mnist_like", 784, 64, "identity"),
}
