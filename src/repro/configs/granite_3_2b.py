"""granite-3-2b [dense] — GQA.

[hf:ibm-granite/granite-3.0-2b-base]
40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.

``long_500k`` runs on the beyond-paper sliding-window serving variant
(window 4096) — see SWA_VARIANT below and DESIGN.md §5.
"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b",
    family="dense",
    source="hf:ibm-granite/granite-3.0-2b-base",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=49155,
    head_dim=64,
)

# serving-only variant for the long_500k dense carve-out
SWA_VARIANT = dataclasses.replace(CONFIG, sliding_window=4096)
