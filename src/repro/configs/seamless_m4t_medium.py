"""seamless-m4t-medium [audio] — encoder-decoder, multimodal.

[arXiv:2308.11596] SeamlessM4T: Massively Multilingual & Multimodal MT.
12L d_model=1024 16H d_ff=4096 vocab=256206. Transformer backbone only:
the mel-spectrogram + conv feature extractor is a STUB — input_specs()
provides precomputed speech-frame embeddings (B, 1024 frames, 1024)
consumed by a 12-layer bidirectional encoder; the 12-layer text decoder
cross-attends to the encoder output (DESIGN.md §5, the allowed
carve-out).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    source="arXiv:2308.11596",
    n_layers=12,               # decoder depth
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    head_dim=64,
    encoder_layers=12,
    frontend="audio",
    n_frontend_tokens=1024,    # speech frames after the (stubbed) conv stack
    d_frontend=1024,
)
