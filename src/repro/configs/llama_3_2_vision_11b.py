"""llama-3.2-vision-11b [vlm] — cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision]
40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256. Every 5th
layer is a cross-attention block (tanh-gated) consuming projected image
patch embeddings. The ViT vision encoder is a STUB — input_specs()
provides precomputed patch embeddings (B, 1601, 1280) (DESIGN.md §5).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    head_dim=128,
    rope_theta=500000.0,
    cross_attn_every=5,
    frontend="vision",
    n_frontend_tokens=1601,    # 1 CLS + 1600 patches
    d_frontend=1280,
    num_microbatches=2,
)
