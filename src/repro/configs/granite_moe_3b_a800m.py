"""granite-moe-3b-a800m [moe] — fine-grained experts, top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base] (family card).
32L d_model=1536 24H (GQA kv=8) d_ff=512 per expert, MoE 40 experts
top-8. NOTE: the assignment text says "MoE 40e top-8" while its
bracket comment says 32 experts — we follow the explicit 40e spec.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,                  # per-expert FFN width (fine-grained experts)
    vocab=49155,
    head_dim=64,
    n_experts=40,
    experts_per_token=8,
    tokens_per_group=128,   # §Perf 3.2: dispatch cost ∝ ts (cap ∝ ts)
)
