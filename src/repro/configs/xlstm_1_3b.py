"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks.

[arXiv:2405.04517] xLSTM: Extended Long Short-Term Memory.
48L d_model=2048 4H d_ff=0 vocab=50304. d_ff=0: xLSTM blocks carry
their own projections and have no separate FFN. One sLSTM block every
8 layers (the paper's 7:1 mLSTM:sLSTM ratio), the rest are mLSTM
(matrix-memory) blocks with chunk-parallel training (DESIGN.md §4).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    source="arXiv:2405.04517",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    head_dim=512,              # 2048 / 4
    slstm_every=8,
)
