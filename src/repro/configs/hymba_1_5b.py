"""hymba-1.5b [hybrid] — parallel attention + mamba heads.

[arXiv:2411.13676] Hymba: A Hybrid-head Architecture for Small LMs.
32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001 ssm_state=16.
Hymba uses full (global) attention in only 3 layers — first, middle,
last — and sliding-window attention elsewhere; the mamba head runs in
parallel with the attention head in every layer and the outputs are
averaged. (The depthwise conv inside the mamba branch and the learnable
meta-tokens are omitted — DESIGN.md §8.)
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    source="arXiv:2411.13676",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    head_dim=64,               # 1600 / 25
    ssm_state=16,
    mamba_expand=1,
    sliding_window=1024,
    global_layers=(0, 15, 31),  # first / middle / last
    detector_hidden=64,
)
