"""Merge governor: *when* and *with whom* the resident fleet merges.

Turns the ``repro.federated.selection`` hooks into stateful fleet-level
policy: every candidate round the governor builds a participation mask
from the drift monitor (quarantine drifted devices out of the topology,
re-admission is the detector's hysteresis) plus any extra fleet
selection policies, and admits the merge only if the per-topology
communication budget allows it.

The comm-budget SLO reuses ``repro.fleet.comm``: a merge round over the
topology costs ``topology_round_cost`` bytes, scaled by the fraction of
participating devices (quarantined devices neither publish nor download
payloads). The governor defers a merge whenever admitting it would push
the *average* bytes/tick above ``budget_bytes_per_tick`` — the serving
SLO knob the ROADMAP asked for — and records every decision so the soak
benchmark can report merge cadence and deferrals.

All decisions are host-side Python between jitted ticks; the masks they
emit are traced operands of the compile-once masked merge
(``fleet_merge_masked``), so governing never retraces anything.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.federated.selection import FleetMaskFn
from repro.fleet.comm import topology_round_cost
from repro.fleet.robust import RobustConfig
from repro.fleet.topology import Topology


@dataclasses.dataclass(frozen=True)
class GovernorConfig:
    """Merge-scheduling policy knobs."""

    merge_every: int = 16                      # candidate cadence, in ticks
    budget_bytes_per_tick: float | None = None  # comm SLO; None = unlimited
    min_participants: int = 2                  # below this a merge is pointless


@dataclasses.dataclass
class GovernorState:
    """Host-side ledger of the governor's decisions."""

    ticks: int = 0
    merges: int = 0
    deferred_budget: int = 0
    deferred_participants: int = 0
    bytes_spent: int = 0
    deferred_degraded: int = 0   # candidate rounds skipped by a degraded
                                 # serving front-end (allow=False)

    @property
    def bytes_per_tick(self) -> float:
        return self.bytes_spent / max(self.ticks, 1)


@dataclasses.dataclass(frozen=True)
class MergeDecision:
    merge: bool
    reason: str            # "merge" | "cadence" | "budget" | "participants"
                           # | "degraded" (caller vetoed: serving front-end
                           #   in a skip-merge degraded mode)
    participants: int
    round_bytes: int
    fp_participants: int = 0   # participants shipping full-precision f32


class MergeGovernor:
    """Stateful merge scheduler for one resident fleet.

    ``payload_precision`` prices rounds at the quantized wire format
    (``repro.fleet.quantize``): a non-f32 precision shrinks
    ``round_bytes``, so the same ``budget_bytes_per_tick`` SLO admits
    more participants (or more frequent merges) at the same traffic.
    Mixed-precision rounds — the detector-gated policy where
    quarantine-risk devices ship exact f32 — are blended per payload
    via the ``fp_participants`` count."""

    def __init__(
        self,
        topology: Topology,
        n_hidden: int,
        n_out: int,
        cfg: GovernorConfig,
        *,
        policies: tuple[FleetMaskFn, ...] = (),
        payload_precision: str = "f32",
        robust: RobustConfig | None = None,
    ) -> None:
        self.topology = topology
        self.cfg = cfg
        self.policies = policies
        self.payload_precision = payload_precision
        self.robust = robust
        self.state = GovernorState()
        # robust-score quarantine ledger (active when ``robust`` is set):
        # consecutive hot merge rounds escalate to quarantine, consecutive
        # calm rounds while quarantined re-admit — hysteresis mirroring
        # the drift detector's, but keyed on the contribution-outlier
        # score (WHO is hostile) instead of the loss signal (who drifted)
        d = topology.n_devices
        self.robust_strikes = np.zeros(d, np.int64)
        self.robust_calm = np.zeros(d, np.int64)
        self.robust_quarantined = np.zeros(d, bool)
        self._full_round_bytes = topology_round_cost(
            topology, n_hidden, n_out
        ).bytes_total
        self._q_round_bytes = topology_round_cost(
            topology, n_hidden, n_out, precision=payload_precision
        ).bytes_total

    def participation(self, drifted: np.ndarray, losses: np.ndarray) -> np.ndarray:
        """Quarantine ∧ robust quarantine ∧ extra selection policies →
        (D,) 0/1 mask."""
        mask = ~np.asarray(drifted, bool)
        if self.robust is not None:
            mask &= ~self.robust_quarantined
        for policy in self.policies:
            mask &= np.asarray(policy(losses), bool)
        return mask

    def observe_robust(self, scores: np.ndarray) -> None:
        """Feed one merge round's contribution-outlier scores into the
        strike/calm escalation ledger. Scores are computed for EVERY
        device (quarantined devices keep publishing payloads that are
        scored but never mixed), so a device that returns to normalcy
        accrues calm rounds and is re-admitted after ``readmit_after``
        of them — the hysteresis twin of ``escalate_after``."""
        if self.robust is None:
            return
        cfg = self.robust
        scores = np.asarray(scores, np.float64)
        hot = scores > cfg.score_threshold
        self.robust_strikes = np.where(hot, self.robust_strikes + 1, 0)
        escalated = ~self.robust_quarantined & (
            self.robust_strikes >= cfg.escalate_after
        )
        self.robust_quarantined |= escalated
        self.robust_strikes[escalated] = 0
        calm_now = self.robust_quarantined & (scores <= cfg.score_readmit)
        self.robust_calm = np.where(calm_now, self.robust_calm + 1, 0)
        released = self.robust_calm >= cfg.readmit_after
        self.robust_quarantined &= ~released
        self.robust_calm[released] = 0

    def round_bytes(self, participants: int, fp_participants: int = 0) -> int:
        """Round traffic with only ``participants`` of D devices live:
        payload counts scale with the participating fraction (a
        quarantined device neither uploads nor downloads). Of those,
        ``fp_participants`` ship f32 payloads and the rest the
        configured wire precision — blended per payload share."""
        d = max(self.topology.n_devices, 1)
        fp = min(fp_participants, participants)
        q = participants - fp
        return int((self._full_round_bytes * fp + self._q_round_bytes * q) / d)

    def round_bytes_by_precision(
        self, participants: int, fp_participants: int = 0
    ) -> dict[str, int]:
        """The same round traffic split by wire format — what the
        telemetry byte counters record. Sums exactly to
        ``round_bytes`` (the quantized share absorbs the int floor)."""
        rb = self.round_bytes(participants, fp_participants)
        if self.payload_precision == "f32":
            return {"f32": rb}
        d = max(self.topology.n_devices, 1)
        fp = min(fp_participants, participants)
        fp_part = min(rb, int(self._full_round_bytes * fp / d))
        return {"f32": fp_part, self.payload_precision: rb - fp_part}

    def budget_utilization(self) -> float:
        """Fraction of the comm-budget SLO currently spent (bytes/tick
        over ``budget_bytes_per_tick``); 0.0 when the budget is
        unlimited. An admission controller uses this as the governor's
        backpressure signal: utilization near 1.0 means the next merge
        round is already at risk of deferral, so accepting more traffic
        only grows the queue it cannot drain."""
        if self.cfg.budget_bytes_per_tick is None:
            return 0.0
        return self.state.bytes_per_tick / self.cfg.budget_bytes_per_tick

    def decide(
        self,
        tick: int,
        mask: np.ndarray,
        fp_mask: np.ndarray | None = None,
        *,
        allow: bool = True,
    ) -> MergeDecision:
        """Admission control for one tick. Call exactly once per tick
        (it advances the budget ledger's tick count). ``fp_mask`` is
        the detector's quarantine-risk vector: participants it covers
        are priced at f32 instead of the governed wire precision.
        ``allow=False`` vetoes the merge regardless of cadence — the
        serving front-end's skip-merge degraded mode — while still
        advancing the tick ledger so budget accounting stays honest."""
        self.state.ticks = tick + 1
        mask = np.asarray(mask)
        participants = int(mask.sum())
        if self.payload_precision == "f32" or fp_mask is None:
            fp = participants if self.payload_precision == "f32" else 0
        else:
            fp = int((mask.astype(bool) & np.asarray(fp_mask, bool)).sum())
        rb = self.round_bytes(participants, fp)
        if not allow:
            if (tick + 1) % self.cfg.merge_every == 0:
                self.state.deferred_degraded += 1
            return MergeDecision(False, "degraded", participants, rb, fp)
        if (tick + 1) % self.cfg.merge_every != 0:
            return MergeDecision(False, "cadence", participants, rb, fp)
        if participants < self.cfg.min_participants:
            self.state.deferred_participants += 1
            return MergeDecision(False, "participants", participants, rb, fp)
        if self.cfg.budget_bytes_per_tick is not None:
            projected = (self.state.bytes_spent + rb) / (tick + 1)
            if projected > self.cfg.budget_bytes_per_tick:
                self.state.deferred_budget += 1
                return MergeDecision(False, "budget", participants, rb, fp)
        self.state.merges += 1
        self.state.bytes_spent += rb
        return MergeDecision(True, "merge", participants, rb, fp)
