"""Online sequential concept-drift detection over ae_score trajectories.

Implements the loss-based sequential detector the follow-up papers run
on-device next to the OS-ELM model: an EWMA of the per-tick
reconstruction loss is compared against a calibrated baseline band
(Yamada & Matsutani, arXiv:2212.09637, sequential detection on OS-ELM
anomaly scores; Sunaga et al., arXiv:2203.01077, loss-threshold retrain
trigger). Per device:

    ewma_t = (1 − α)·ewma_{t−1} + α·loss_t
    drift  ⇔ ewma_t > μ_base + k·σ_base          (one-sided: loss UP)

- **calibration** — the first ``warmup`` ticks only feed the running
  baseline (Welford mean/variance of the tick losses); no flags fire.
- **slow baseline tracking** — while in-band, the baseline keeps
  adapting with rate ``baseline_alpha`` ≪ ``alpha`` so gradual
  nonstationarity (and post-merge loss drops) re-anchor the band
  without chasing abrupt drift.
- **hysteresis re-admission** — a drifted device stays flagged until
  its EWMA returns below the re-entry band μ + k_re·σ (k_re < k) for
  ``patience`` consecutive ticks; on re-admission the baseline mean is
  re-anchored to the current EWMA (the device has re-converged on its
  stream, possibly a new concept).
- **post-merge rebase** — a cooperative merge changes every
  participant's model discontinuously, stepping the fleet's in-band
  loss level; the runtime marks the first post-merge tick and the
  detector rescales participants' bands by the fleet-median loss ratio
  (common-mode correction), so merge shocks do not flag while
  idiosyncratic drift still does.

The whole detector bank is ONE pytree with (D,)-leading leaves updated
by a single vmap-free vectorized ``detector_update`` — it is called
inside the runtime's jitted tick, so detection is part of the
compile-once path. ``n_devices=1`` gives the single-detector monitor
``launch/serve.py`` uses.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    """Static detector hyper-parameters (shared by every device)."""

    alpha: float = 0.3           # EWMA rate of the tick-loss trajectory
    k_sigma: float = 4.5         # detection threshold, in baseline sigmas
    k_readmit: float = 2.0       # re-entry band, in baseline sigmas
    k_track: float = 2.0         # tracking gate: the baseline follows only
                                 # losses within this many sigmas of the
                                 # mean, so a ramp toward the detection
                                 # threshold is not absorbed into the band
    warmup: int = 16             # calibration-only ticks (no flags), incl. skip
    warmup_skip: int = 0         # leading ticks excluded from calibration
                                 # entirely: a freshly-initialized model is
                                 # still converging on its stream, and its
                                 # decaying loss transient would inflate the
                                 # Welford variance (and so the detection
                                 # band) for the rest of the run
    patience: int = 8            # consecutive in-band ticks to re-admit
    baseline_alpha: float = 0.02  # slow in-band baseline tracking rate
    min_sigma: float = 1e-6      # absolute sigma floor (constant streams)
    rel_sigma: float = 0.0       # relative sigma floor, as a fraction of the
                                 # baseline mean: a device whose calibration
                                 # stream is nearly constant would otherwise
                                 # carry a microscopic band and flag harmless
                                 # wiggles a few times its (tiny) sigma

    def __post_init__(self) -> None:
        if self.warmup_skip < 0:
            raise ValueError(f"need warmup_skip >= 0, got {self.warmup_skip}")
        if self.warmup_skip >= self.warmup:
            raise ValueError(
                f"warmup ({self.warmup}) must exceed warmup_skip "
                f"({self.warmup_skip}): flags would otherwise fire against "
                "an empty (zero-width) calibration band"
            )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DetectorState:
    """Per-device sequential-detector state; every leaf is (D,)."""

    ewma: jnp.ndarray       # smoothed tick loss
    mean: jnp.ndarray       # baseline mean (calibration + slow tracking)
    var: jnp.ndarray        # baseline variance
    count: jnp.ndarray      # int32 ticks observed (drives warmup)
    drifted: jnp.ndarray    # bool — currently quarantined
    recovery: jnp.ndarray   # int32 consecutive in-band ticks while drifted

    @property
    def n_devices(self) -> int:
        return self.ewma.shape[0]

    def replace(self, **kw) -> "DetectorState":
        return dataclasses.replace(self, **kw)

    def threshold(self, cfg: DetectorConfig) -> jnp.ndarray:
        """Current per-device detection threshold μ + k·σ."""
        return self.mean + cfg.k_sigma * _sigma(self, cfg)


def _sigma(state: DetectorState, cfg: DetectorConfig) -> jnp.ndarray:
    """Effective per-device sigma: the Welford/tracked estimate, floored
    absolutely (``min_sigma``) and relative to the baseline mean
    (``rel_sigma``) so near-constant calibration streams cannot produce
    a band narrower than the loss level itself warrants."""
    sigma = jnp.sqrt(state.var) + cfg.min_sigma
    return jnp.maximum(sigma, cfg.rel_sigma * state.mean)


def init_detector(n_devices: int) -> DetectorState:
    z = jnp.zeros(n_devices, jnp.float32)
    return DetectorState(
        ewma=z,
        mean=z,
        var=z,
        count=jnp.zeros(n_devices, jnp.int32),
        drifted=jnp.zeros(n_devices, bool),
        recovery=jnp.zeros(n_devices, jnp.int32),
    )


def detector_update(
    state: DetectorState,
    losses: jnp.ndarray,
    cfg: DetectorConfig,
    *,
    rebase: jnp.ndarray | bool = False,
    participants: jnp.ndarray | None = None,
    common: jnp.ndarray | None = None,
) -> tuple[DetectorState, jnp.ndarray, jnp.ndarray]:
    """One sequential-detection step on this tick's per-device losses.

    Returns ``(state', drifted, fresh)`` where ``drifted`` is the (D,)
    quarantine flag after the update and ``fresh`` marks devices whose
    flag rose THIS tick (detection events, for delay accounting).
    Pure and vectorized — safe to call inside a jitted tick.

    ``rebase`` (a traced scalar) marks the first tick after a
    cooperative merge: the ``participants`` of that merge received a
    discontinuously different model, so their in-band loss level shifts
    as a COMMON-MODE step. Their baselines are rescaled by the fleet
    median of (loss / baseline mean) over calibrated, un-drifted
    participants — a merge shock moves every participant's band at
    once, while a genuinely drifted device's idiosyncratic spike towers
    over the median and still fires (one tick later). No flags rise on
    a rebase tick itself.

    ``common`` overrides the in-trace fleet-median ratio with a
    precomputed scalar. The cohort-paged runtime needs this: the median
    is a FLEET-WIDE statistic, but a paged tick only ever sees one
    cohort's slice of the detector bank — it computes the global median
    between the ingest and detect passes (``common_mode_ratio``) and
    feeds the same scalar to every cohort's update, which keeps paged
    rebasing tick-identical with the resident path. ``None`` (the
    resident default) computes it here, unchanged.
    """
    losses = jnp.asarray(losses, jnp.float32)
    if participants is None:
        participants = jnp.ones(losses.shape, bool)
    participants = jnp.asarray(participants).astype(bool)
    rebase = jnp.asarray(rebase)

    calibrated = state.count >= cfg.warmup
    valid = participants & ~state.drifted & calibrated
    if common is None:
        ratio = losses / jnp.maximum(state.mean, cfg.min_sigma)
        common = jnp.nanmedian(jnp.where(valid, ratio, jnp.nan))
    else:
        common = jnp.asarray(common, jnp.float32)
    common = jnp.where(jnp.isfinite(common) & (common > 0), common, 1.0)
    do_rebase = rebase & valid
    state = state.replace(
        mean=jnp.where(do_rebase, state.mean * common, state.mean),
        var=jnp.where(do_rebase, state.var * common**2, state.var),
        # the EWMA must jump with the band: after a loss-DECREASING
        # merge (common < 1) a slowly-decaying EWMA would sit above the
        # already-shrunk band and falsely flag every participant
        ewma=jnp.where(do_rebase, state.ewma * common, state.ewma),
    )

    count = state.count + 1
    warm = state.count < cfg.warmup

    # EWMA trajectory; (re)seeded with the raw observation through the
    # skip window so calibration starts from the converged loss level,
    # not an arbitrary origin (or the init transient)
    ewma = jnp.where(
        state.count <= cfg.warmup_skip, losses,
        (1.0 - cfg.alpha) * state.ewma + cfg.alpha * losses,
    )

    # Welford running baseline during warmup — over the ticks AFTER the
    # skip window only (eff counts calibration samples, not wall ticks)
    eff_prev = state.count - cfg.warmup_skip
    eff = eff_prev + 1
    skipping = eff_prev < 0
    delta = losses - state.mean
    mean_w = state.mean + delta / jnp.maximum(eff, 1)
    var_w = jnp.maximum(
        (state.var * jnp.maximum(eff_prev, 0) + delta * (losses - mean_w))
        / jnp.maximum(eff, 1),
        0.0,
    )
    mean_w = jnp.where(skipping, state.mean, mean_w)
    var_w = jnp.where(skipping, state.var, var_w)

    sigma = _sigma(state, cfg)
    upper = state.mean + cfg.k_sigma * sigma
    readmit_band = state.mean + cfg.k_readmit * sigma

    in_band = ewma <= readmit_band
    # slow tracking once calibrated: the baseline keeps estimating the
    # RAW tick-loss distribution (the same units Welford calibrated),
    # but only from losses within the k_track band — an un-flagged ramp
    # toward the detection threshold must not be absorbed, and a
    # drifted device's band must keep describing the PRE-drift concept
    track = (
        (~warm)
        & (~state.drifted)
        & (losses <= state.mean + cfg.k_track * sigma)
    )
    mean_t = jnp.where(track, (1 - cfg.baseline_alpha) * state.mean
                       + cfg.baseline_alpha * losses, state.mean)
    var_t = jnp.where(
        track,
        (1 - cfg.baseline_alpha) * state.var
        + cfg.baseline_alpha * (losses - state.mean) ** 2,
        state.var,
    )
    mean = jnp.where(warm, mean_w, mean_t)
    var = jnp.where(warm, var_w, var_t)

    fresh = (~warm) & (~state.drifted) & (ewma > upper) & ~do_rebase
    recovery = jnp.where(
        state.drifted & in_band, state.recovery + 1,
        jnp.zeros_like(state.recovery),
    )
    readmitted = state.drifted & (recovery >= cfg.patience)
    drifted = (state.drifted | fresh) & ~readmitted

    # re-anchor the baseline on re-admission: the device has
    # re-converged (possibly on a new concept) — its band restarts there
    mean = jnp.where(readmitted, ewma, mean)
    recovery = jnp.where(readmitted, 0, recovery)

    new = DetectorState(
        ewma=ewma, mean=mean, var=var, count=count,
        drifted=drifted, recovery=recovery,
    )
    return new, drifted, fresh


def common_mode_ratio(
    state: DetectorState,
    losses: jnp.ndarray,
    cfg: DetectorConfig,
    *,
    participants: jnp.ndarray,
) -> jnp.ndarray:
    """The fleet-median (loss / baseline-mean) ratio over calibrated,
    un-drifted participants — EXACTLY the scalar ``detector_update``
    computes in-trace for its post-merge rebase. The cohort-paged
    runtime calls this once on the full-fleet (D,) arrays between its
    ingest and detect passes and passes the result as ``common=`` to
    every per-cohort ``detector_update``; ``state`` must be the
    PRE-update detector bank (the same state the update will consume).
    Same f32 arithmetic as the in-trace path, so resident and paged
    rebasing agree bit-for-bit."""
    losses = jnp.asarray(losses, jnp.float32)
    participants = jnp.asarray(participants).astype(bool)
    valid = participants & ~state.drifted & (state.count >= cfg.warmup)
    ratio = losses / jnp.maximum(state.mean, cfg.min_sigma)
    return jnp.nanmedian(jnp.where(valid, ratio, jnp.nan))


def quarantine_risk(state: DetectorState, cfg: DetectorConfig) -> jnp.ndarray:
    """(D,) bool — devices whose payloads should NOT be lossy this round.

    The quantized merge path's precision policy: a device currently
    quarantined, or calibrated but riding above the re-admission band
    μ + k_re·σ (i.e. trending toward a flag), ships exact f32 payloads;
    everyone else ships the quantized wire format. Devices still in
    warmup are NOT risk — their band is uncalibrated, not suspicious,
    and treating warmup as risk would make the whole first merge round
    full-precision."""
    calibrated = state.count >= cfg.warmup
    elevated = calibrated & (state.ewma > state.mean + cfg.k_readmit * _sigma(state, cfg))
    return state.drifted | elevated
