"""Resident streaming fleet runtime — the paper's deployment loop.

Converts the offline ``fleet_train_rounds`` batch simulator into an
event-driven serving system that keeps the whole fleet resident and
processes a stream of ticks:

1. **ingest** — every device scores its incoming tick batch under its
   CURRENT model (the drift signal: prediction loss on new data) and
   then trains on it with the paper's k=1 sequential updates, as one
   vmapped-scan jitted alongside step 2;
2. **detect** — the vectorized sequential drift detector
   (``repro.runtime.detector``) updates per-device EWMA/baseline state
   in the same compiled tick function;
3. **govern + merge** — between ticks, the merge governor
   (``repro.runtime.governor``) builds a participation mask (quarantine
   drifted devices, re-admit after re-convergence) and admits
   cooperative updates under the topology's comm-budget SLO; admitted
   merges run through the compile-once masked merge
   (``fleet_merge_masked`` / ``fleet_merge_masked_kernel``), optionally
   against STALE neighbor payloads from a published-version ring
   (``StalenessSchedule``), the async model the ROADMAP's serve-loop
   item called for;
4. **snapshot** — the resident fleet (model + detector + ledger, plus
   the payload ring when staleness is on) persists through
   ``CheckpointManager`` so a restart resumes mid-stream.

Every jitted function is owned by the runtime instance and is traced
exactly once for a given (fleet shape, batch, topology) — masks, tick
indices, and payload versions are all runtime operands.
``assert_compile_once()`` turns that property into a hard check the
soak benchmark enforces.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from collections import deque
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import UV, OSELMState, ae_score
from repro.federated.selection import FleetMaskFn
from repro.fleet.faults import FaultInjector
from repro.fleet.fleet import (
    _fleet_train,
    _masked_kernel_merge_from_w,
    _masked_merge_body,
    _quantized_merge_body,
    fleet_from_uv,
    fleet_merge_masked_kernel,
    fleet_to_uv,
)
from repro.fleet.quantize import init_residual, validate_precision
from repro.fleet.robust import (
    RobustConfig,
    finite_payload_mask,
    robust_merge_from_w,
)
from repro.fleet.staleness import StalenessSchedule, _lagged_gather
from repro.fleet.topology import Topology
from repro.kernels.fleet_ingest import fleet_ingest
from repro.obs import TelemetryConfig, TelemetrySink
from repro.runtime.detector import (
    DetectorConfig,
    detector_update,
    init_detector,
    quarantine_risk,
)
from repro.runtime.feed import TickFeed
from repro.runtime.governor import GovernorConfig, MergeDecision, MergeGovernor

logger = logging.getLogger(__name__)


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Static configuration of one resident fleet runtime."""

    topology: Topology
    ridge: float = 1e-3
    detector: DetectorConfig = dataclasses.field(default_factory=DetectorConfig)
    governor: GovernorConfig = dataclasses.field(default_factory=GovernorConfig)
    gate_merges: bool = True          # False: no-quarantine baseline (everyone merges)
    staleness: StalenessSchedule | None = None
    use_merge_kernel: bool = False    # route merges through the Pallas family
    payload_precision: str = "f32"    # merge wire format ("f32" | "f16" | "int8");
                                      # non-f32 runs the error-feedback codec with
                                      # the detector-gated precision policy:
                                      # quarantine-risk devices ship f32 payloads,
                                      # stable devices the quantized format
                                      # (repro.fleet.quantize / detector.quarantine_risk)
    use_ingest_kernel: bool = False   # fused tick ingest (repro.kernels.fleet_ingest)
    ingest_backend: str = "auto"      # "pallas" | "xla" | "auto" (TPU→pallas)
    snapshot_every: int | None = None
    snapshot_dir: str | Path | None = None
    snapshot_keep: int = 3
    robust: RobustConfig | None = None   # Byzantine-robust merge (clip/trim/score
                                         # + governor quarantine escalation); None
                                         # keeps the exact paper merge bit-for-bit
    faults: FaultInjector | None = None  # deterministic fault injection at the
                                         # payload boundary (repro.fleet.faults)
    telemetry: TelemetryConfig | None = None  # structured metrics + tracing +
                                              # crash flight recorder (repro.obs);
                                              # None = zero instrumentation
    detections_cap: int = 4096  # detection-event ring length — the full ledger
                                # of a months-long soak lives in the telemetry
                                # counters/flight ring, not an unbounded list


@dataclasses.dataclass(frozen=True)
class TickReport:
    """What one tick did — the runtime's observable event record."""

    tick: int
    losses: np.ndarray          # (D,) mean ae_score of the incoming batch
    drifted: np.ndarray         # (D,) quarantine flags after detection
    fresh_detections: np.ndarray  # (D,) flags that rose this tick
    decision: MergeDecision
    merge_seconds: float | None  # wall-clock of the admitted merge (full output
                                 # pytree fenced), else None
    robust_scores: np.ndarray | None = None  # (D,) contribution-outlier scores
                                             # of an admitted robust merge round
    nonfinite_payloads: int = 0  # payloads rejected by the finite guard this tick
    ingest_seconds: float | None = None  # fenced wall-clock of ingest + detect
    served: np.ndarray | None = None  # (D,) devices whose batch rows carried
                                      # real (non-padding) samples this tick;
                                      # None = every row (the default path)


def _where_served(keep: jnp.ndarray, new, old):
    """Per-device select over a (D,)-leading pytree: devices with
    ``keep`` take the freshly-computed leaves, the rest keep their old
    state bit-for-bit (an un-served device must not train, and its
    detector must not observe, a padded batch row)."""
    return jax.tree_util.tree_map(
        lambda n, o: jnp.where(
            keep.reshape(keep.shape + (1,) * (n.ndim - 1)), n, o
        ),
        new, old,
    )


class _NullPhase:
    """Zero-cost stand-in for the telemetry phase timer — the same
    ``with``/``fence`` surface, nothing measured. One shared instance
    keeps the telemetry-off tick free of per-phase allocations."""

    __slots__ = ()

    def __enter__(self) -> "_NullPhase":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def fence(self, tree) -> None:
        pass


_NULL_PHASE = _NullPhase()


class FleetRuntime:
    """A live fleet: stacked OS-ELM states + detector bank + governor."""

    def __init__(
        self,
        states: OSELMState,
        config: RuntimeConfig,
        *,
        policies: tuple[FleetMaskFn, ...] = (),
    ) -> None:
        n_devices = states.beta.shape[0]
        if config.topology.n_devices != n_devices:
            raise ValueError(
                f"topology is for {config.topology.n_devices} devices, "
                f"fleet has {n_devices}"
            )
        if config.staleness is not None and len(config.staleness.lags) != n_devices:
            raise ValueError("staleness schedule device count mismatch")
        validate_precision(config.payload_precision)
        if config.payload_precision != "f32" and config.staleness is not None:
            raise ValueError(
                "quantized payloads are not supported with the stale "
                "published-version ring yet (the ring stores exact payloads)"
            )
        hardened = config.robust is not None or config.faults is not None
        if hardened and config.staleness is not None:
            raise ValueError(
                "robust/fault-injected merges are not supported with the "
                "stale published-version ring (the ring replays un-guarded "
                "historical payloads)"
            )
        if hardened and config.payload_precision != "f32":
            raise ValueError(
                "robust/fault-injected merges require payload_precision='f32' "
                "(the quantized codec path has its own publish boundary)"
            )
        if config.faults is not None and config.faults.n_devices != n_devices:
            raise ValueError(
                f"fault injector is for {config.faults.n_devices} devices, "
                f"fleet has {n_devices}"
            )
        self.states = states
        self.config = config
        self.det = init_detector(n_devices)
        # NB: on the stacked fleet pytree beta is (D, Ñ, m), so the
        # scalar-state n_hidden/n_out properties would read (D, Ñ)
        n_hidden, n_out = states.beta.shape[1], states.beta.shape[2]
        self.governor = MergeGovernor(
            config.topology, n_hidden, n_out, config.governor,
            policies=policies, payload_precision=config.payload_precision,
            robust=config.robust,
        )
        self.tick_no = 0
        self.merge_round = 0
        # bounded detection-event ring: recent (tick, device) flags for
        # delay accounting; detections_total keeps the lifetime count a
        # long soak would otherwise grow an unbounded list for
        self.detections: deque[tuple[int, int]] = deque(
            maxlen=config.detections_cap
        )
        self.detections_total = 0
        self.telemetry = (
            TelemetrySink(config.telemetry)
            if config.telemetry is not None else None
        )
        self._tick_inputs: np.ndarray | None = None  # last post-poison batch,
                                                     # carried for flight dumps
        self.ckpt = (
            CheckpointManager(config.snapshot_dir, keep=config.snapshot_keep)
            if config.snapshot_dir is not None else None
        )

        det_cfg = config.detector
        topology, ridge = config.topology, config.ridge

        if config.use_ingest_kernel:
            from repro.kernels.fleet_ingest import validate_shared_basis

            # the tick is jitted (tracers inside), so the kernel-ingest
            # shared-basis precondition is checked once, here, while the
            # fleet is concrete
            validate_shared_basis(states)

            def ingest_detect(fleet, det, batch, rebase, participants, served):
                # the fused ingest family computes the pre-train drift
                # signal and the k=1 window updates in ONE pass over the
                # batch ((P, β) resident across the window) — same
                # losses the two-pass reference produces
                trained, losses = fleet_ingest(
                    fleet, batch, backend=config.ingest_backend
                )
                det_new, drifted, fresh = detector_update(
                    det, losses, det_cfg, rebase=rebase, participants=participants
                )
                # un-served devices (padded rows of a partially-filled
                # serving window) keep model AND detector state — the
                # served mask is a traced operand, so partial windows
                # never retrace; all-ones served is bit-for-bit the
                # unmasked path
                keep = served.astype(bool)
                fleet = _where_served(keep, trained, fleet)
                det = _where_served(keep, det_new, det)
                return fleet, det, losses, det.drifted, fresh & keep
        else:
            def ingest_detect(fleet, det, batch, rebase, participants, served):
                # score BEFORE training: the loss of the incoming data under
                # the current model is the drift signal (§3.4 / 2203.01077)
                losses = jax.vmap(lambda s, xb: jnp.mean(ae_score(s, xb)))(fleet, batch)
                trained = _fleet_train(fleet, batch)  # k=1 sequential updates
                det_new, drifted, fresh = detector_update(
                    det, losses, det_cfg, rebase=rebase, participants=participants
                )
                keep = served.astype(bool)
                fleet = _where_served(keep, trained, fleet)
                det = _where_served(keep, det_new, det)
                return fleet, det, losses, det.drifted, fresh & keep

        self._ingest_detect = jax.jit(ingest_detect)
        # first tick after a merge: participants' bands rebase common-mode
        self._post_merge = False
        self._merge_mask = np.ones(n_devices, bool)
        self._all_served = np.ones(n_devices, bool)

        # error-feedback accumulator of the quantized merge path (None on
        # the exact-f32 path); advanced only on admitted merge rounds
        self._residual = (
            init_residual(states) if config.payload_precision != "f32" else None
        )
        if config.payload_precision != "f32":
            precision = config.payload_precision

            def merge_fresh(fleet, mask, fp_mask, residual):
                # stateful lossy merge: fp_mask (quarantine-risk) devices
                # publish exact f32, the rest the quantized wire format
                # with error feedback — all three masks/accumulators are
                # traced operands, so precision gating never retraces
                return _quantized_merge_body(
                    fleet, topology, residual, precision, ridge,
                    mask, fp_mask, config.use_merge_kernel, True,
                )
        elif config.use_merge_kernel:
            def merge_fresh(fleet, mask):
                return fleet_merge_masked_kernel(fleet, topology, mask, ridge=ridge)
        else:
            def merge_fresh(fleet, mask):
                return _masked_merge_body(fleet, topology, mask, ridge)

        self._merge_fresh = jax.jit(merge_fresh)

        # ---- hardened merge boundary: faults in, robustness out ----
        # One compile-once closure owns the whole payload boundary of an
        # admitted round: extract w=[U|V], apply the tick's fault operands
        # (mult/noise/nonfin — identity when no fault is active, so clean
        # and attacked rounds share ONE trace), reject non-finite payloads
        # (the device publishes its last finite (U, V) instead), then merge
        # robustly (clip/trim/score) or naively (the degradation arm the
        # benchmark measures).
        self._merge_boundary = None
        self._last_good = None
        if hardened:
            robust_cfg = config.robust
            use_kernel = config.use_merge_kernel

            def merge_boundary(fleet, mask, receive, mult, noise, nonfin, last_good):
                uv = fleet_to_uv(fleet, ridge=ridge)
                n = uv.u.shape[1]
                w = jnp.concatenate([uv.u, uv.v], axis=2)
                w = w * mult[:, None, None] + noise
                w = jnp.where((nonfin == 1)[:, None, None], jnp.nan, w)
                w = jnp.where((nonfin == 2)[:, None, None], jnp.inf, w)
                finite = finite_payload_mask(w)
                if robust_cfg is None:
                    # naive arm: whatever the faults produced flows straight
                    # into the plain masked Eq. 8 sum — the baseline the
                    # robust arm is proven against
                    if use_kernel:
                        merged = _masked_kernel_merge_from_w(
                            fleet, topology, mask, w, ridge, True
                        )
                    else:
                        merged = _masked_merge_body(
                            fleet, topology, mask, ridge,
                            uv=UV(u=w[:, :, :n], v=w[:, :, n:]),
                        )
                    scores = jnp.zeros(mask.shape[0], jnp.float32)
                    return merged, last_good, scores, finite
                # finite-payload guard: a non-finite contribution is replaced
                # by that device's last published finite payload, so one
                # overflowing device never NaN-poisons the neighborhood sum
                w_pub = jnp.where(finite[:, None, None], w, last_good)
                new_last = jnp.where(finite[:, None, None], w, last_good)
                merged, scores = robust_merge_from_w(
                    fleet, topology, mask, w_pub, robust_cfg, ridge,
                    kernel=use_kernel, interpret=True, receive=receive,
                )
                return merged, new_last, scores, finite

            self._merge_boundary = jax.jit(merge_boundary)
            uv0 = jax.jit(lambda s: fleet_to_uv(s, ridge=ridge))(states)
            self._last_good = jnp.concatenate([uv0.u, uv0.v], axis=2)

        # ---- staleness-aware merge: published-payload version ring ----
        self._hist_u = self._hist_v = None
        if config.staleness is not None:
            lags = jnp.asarray(config.staleness.lags)
            n_hist = config.staleness.max_lag + 1
            m_off = jnp.asarray(topology.dense_matrix()) - jnp.eye(
                n_devices, dtype=jnp.float32
            )

            # NB: lagged merges mix via the dense m_off einsum (same
            # convention as fleet_train_async — each device needs a
            # DIFFERENT version of each neighbor's payload, which the
            # sparse Topology.mix paths cannot express). O(D²) per
            # merge round; prefer staleness=None at large D until a
            # banded lagged-gather kernel exists.
            def merge_stale(fleet, hist_u, hist_v, mask, r):
                fresh = fleet_to_uv(fleet, ridge=ridge)
                mf = mask.astype(fresh.u.dtype)
                # publish this round's payload (quarantined devices
                # publish too — peers just will not mix them in)
                hist_u = hist_u.at[r % n_hist].set(fresh.u)
                hist_v = hist_v.at[r % n_hist].set(fresh.v)
                stale_u = _lagged_gather(hist_u, lags, r) * mf[:, None, None]
                stale_v = _lagged_gather(hist_v, lags, r) * mf[:, None, None]
                merged = UV(
                    u=fresh.u + jnp.einsum("ij,j...->i...", m_off, stale_u),
                    v=fresh.v + jnp.einsum("ij,j...->i...", m_off, stale_v),
                )
                out = fleet_from_uv(fleet, merged, ridge=ridge)
                keep = (mf > 0)[:, None, None]
                out = fleet.replace(
                    beta=jnp.where(keep, out.beta, fleet.beta),
                    p=jnp.where(keep, out.p, fleet.p),
                )
                return out, hist_u, hist_v

            self._merge_stale = jax.jit(merge_stale)
            # version-0 backfill: until a device has published, peers see
            # its initial payload (same convention as fleet_train_async)
            uv0 = jax.jit(lambda s: fleet_to_uv(s, ridge=ridge))(states)
            self._hist_u = jnp.broadcast_to(uv0.u[None], (n_hist,) + uv0.u.shape)
            self._hist_v = jnp.broadcast_to(uv0.v[None], (n_hist,) + uv0.v.shape)

    @property
    def n_devices(self) -> int:
        return self.det.n_devices

    # ------------------------------------------------------------- tick loop

    def _phase(self, name: str):
        """Phase timer context (a shared no-op when telemetry is off, so
        the uninstrumented tick pays one attribute check per phase)."""
        return _NULL_PHASE if self.telemetry is None else self.telemetry.phase(name)

    def _observe_phase(self, name: str, seconds: float) -> None:
        if self.telemetry is not None:
            self.telemetry._phase_observe[name](seconds)

    def tick(
        self,
        batch: np.ndarray,
        *,
        served: np.ndarray | None = None,
        allow_merge: bool = True,
    ) -> TickReport:
        """Process one serving tick: ingest + detect, then govern and
        (maybe) merge between ticks, then (maybe) snapshot.

        ``served`` is the serving front-end's (D,) admission outcome:
        devices marked False carry padding in their batch row and keep
        their model/detector state untouched (all-ones — the default —
        is bit-for-bit the unmasked tick). ``allow_merge=False`` vetoes
        any merge this tick (the skip-merge degraded mode) while the
        governor's ledger keeps advancing. Both are per-tick operands
        of the compile-once tick function — never a retrace.

        With telemetry configured an escaping exception dumps the
        flight ring (plus this tick's input batch) before propagating."""
        try:
            return self._tick(batch, served, allow_merge)
        except Exception:
            tel = self.telemetry
            if tel is not None:
                tel.maybe_dump(
                    self.tick_no, "exception", inputs=self._tick_inputs
                )
                tel.write_outputs()
            raise

    def _tick(
        self,
        batch: np.ndarray,
        served: np.ndarray | None = None,
        allow_merge: bool = True,
    ) -> TickReport:
        t = self.tick_no
        injector = self.config.faults
        batch = np.asarray(batch)
        d = self.n_devices
        if batch.ndim != 3 or batch.shape[0] != d:
            raise ValueError(
                f"tick batch must be (n_devices={d}, B, features); got "
                f"shape {batch.shape}"
            )
        if batch.shape[1] < 1:
            raise ValueError(
                "tick batch has zero samples per device (B=0) — an "
                "all-shed tick window carries no data to ingest; skip "
                "dispatching the tick, or pad the window and mark the "
                "padded devices via served=..."
            )
        if served is None:
            served_np = self._all_served
        else:
            served_np = np.asarray(served).astype(bool)
            if served_np.shape != (d,):
                raise ValueError(
                    f"served mask must be ({d},); got {served_np.shape}"
                )
        t_start = time.perf_counter()
        with self._phase("poison"):
            if injector is not None:
                # data poisoning attacks through training itself, upstream
                # of the payload boundary (host-side, before jitted ingest)
                batch = injector.poison_batch(np.asarray(batch), t)
        # the post-poison batch is what reaches the model — the thing a
        # flight dump must carry for the failing tick to be replayable
        self._tick_inputs = batch

        t0 = time.perf_counter()
        self.states, self.det, losses, drifted, fresh = self._ingest_detect(
            self.states, self.det, jnp.asarray(batch),
            jnp.asarray(self._post_merge), jnp.asarray(self._merge_mask),
            jnp.asarray(served_np),
        )
        jax.block_until_ready((self.states, self.det, losses))
        ingest_seconds = time.perf_counter() - t0
        self._observe_phase("ingest", ingest_seconds)

        losses_np = np.asarray(losses)
        drifted_np = np.asarray(drifted)
        fresh_np = np.asarray(fresh)
        n_fresh = int(fresh_np.sum())
        self.detections_total += n_fresh
        for dev in np.flatnonzero(fresh_np):
            self.detections.append((t, int(dev)))

        # detector-gated precision policy: on candidate rounds of a
        # quantized runtime, quarantine-risk devices are priced (and
        # shipped) at f32 — computed host-side from the post-update
        # detector state, like the participation mask
        with self._phase("quantize"):
            fp_mask = None
            if (
                self._residual is not None
                and (t + 1) % self.config.governor.merge_every == 0
            ):
                fp_mask = np.asarray(
                    quarantine_risk(self.det, self.config.detector)
                )

        with self._phase("govern"):
            if self.config.gate_merges:
                mask = self.governor.participation(drifted_np, losses_np)
            else:
                mask = np.ones(self.n_devices, bool)
            if injector is not None:
                # crashed devices are down for the window: no publish, no
                # download — regardless of gating mode
                mask = mask & ~injector.crash_mask(t)
            decision = self.governor.decide(t, mask, fp_mask, allow=allow_merge)

        merge_seconds = None
        robust_scores = None
        nonfinite = 0
        if decision.merge:
            t0 = time.perf_counter()
            mask_j = jnp.asarray(mask, jnp.float32)
            if self._merge_boundary is not None:
                shape = tuple(self._last_good.shape)
                if injector is not None:
                    mult, noise, nonfin = injector.payload_ops(t, shape)
                else:
                    mult = np.ones(shape[0], np.float32)
                    noise = np.zeros(shape, np.float32)
                    nonfin = np.zeros(shape[0], np.int32)
                # robust-quarantined devices still DOWNLOAD the merged
                # model (their payload is distrusted, they are not cut
                # off) — unless drift-flagged or crashed this tick
                receive = mask.astype(bool)
                if self.config.robust is not None:
                    rq = self.governor.robust_quarantined & ~drifted_np.astype(bool)
                    if injector is not None:
                        rq = rq & ~injector.crash_mask(t)
                    receive = receive | rq
                (self.states, self._last_good, scores_j, finite_j,
                 ) = self._merge_boundary(
                    self.states, mask_j, jnp.asarray(receive, jnp.float32),
                    jnp.asarray(mult), jnp.asarray(noise),
                    jnp.asarray(nonfin), self._last_good,
                )
                fence = (self.states, self._last_good, scores_j, finite_j)
            elif self.config.staleness is not None:
                self.states, self._hist_u, self._hist_v = self._merge_stale(
                    self.states, self._hist_u, self._hist_v, mask_j,
                    jnp.int32(self.merge_round),
                )
                fence = (self.states, self._hist_u, self._hist_v)
            elif self._residual is not None:
                self.states, self._residual = self._merge_fresh(
                    self.states, mask_j, jnp.asarray(fp_mask), self._residual
                )
                fence = (self.states, self._residual)
            else:
                self.states = self._merge_fresh(self.states, mask_j)
                fence = self.states
            # fence the FULL output pytree, not just states.beta — async
            # dispatch would otherwise bill unfinished ring/residual/score
            # work to whichever later phase synchronizes first
            jax.block_until_ready(fence)
            merge_seconds = time.perf_counter() - t0
            self._observe_phase("merge", merge_seconds)
            if self._merge_boundary is not None:
                robust_scores = np.asarray(scores_j)
                nonfinite = int((~np.asarray(finite_j)).sum())
                if self.config.robust is not None:
                    self.governor.observe_robust(robust_scores)
            self.merge_round += 1

        # serving latency of THIS tick: ingest through merge; snapshots
        # amortize across the snapshot_every window and are timed as
        # their own phase below rather than folded into tick_seconds
        tick_seconds = time.perf_counter() - t_start
        if self.telemetry is not None:
            self._record_telemetry(
                t, batch, losses_np, drifted_np, fresh_np, n_fresh, decision,
                ingest_seconds, merge_seconds, tick_seconds,
                robust_scores, nonfinite, served_np,
            )

        self._post_merge = decision.merge
        if decision.merge:
            self._merge_mask = mask.copy()
        self.tick_no = t + 1
        if (
            self.ckpt is not None
            and self.config.snapshot_every
            and self.tick_no % self.config.snapshot_every == 0
        ):
            with self._phase("snapshot"):
                self.snapshot()
        return TickReport(
            tick=t, losses=losses_np, drifted=drifted_np,
            fresh_detections=fresh_np, decision=decision,
            merge_seconds=merge_seconds, robust_scores=robust_scores,
            nonfinite_payloads=nonfinite, ingest_seconds=ingest_seconds,
            served=None if served is None else served_np,
        )

    def _record_telemetry(
        self, t: int, batch, losses: np.ndarray, drifted: np.ndarray,
        fresh: np.ndarray, n_fresh: int, decision: MergeDecision,
        ingest_seconds: float, merge_seconds: float | None,
        tick_seconds: float, robust_scores: np.ndarray | None, nonfinite: int,
        served: np.ndarray | None = None,
    ) -> None:
        """Fold one tick into the sink: counters/gauges/histograms, the
        flight-ring record, and the nonfinite/SLO dump triggers."""
        tel = self.telemetry
        cfg = self.config
        tel.ticks.inc()
        tel.tick_seconds.observe(tick_seconds)
        if n_fresh:
            tel.detections.inc(n_fresh)
        injector = cfg.faults
        faults = injector.active_faults(t) if injector is not None else []
        for kind, n in faults:
            tel.fault_events.labels(kind=kind).inc(n)
        n_quarantined = int(drifted.sum())
        tel.quarantined.set(n_quarantined)
        if cfg.robust is not None:
            tel.robust_quarantined.set(
                int(self.governor.robust_quarantined.sum())
            )

        # detector band dynamics over calibrated devices, in host numpy
        # (mirrors detector._sigma — the band the flags fire against);
        # sampled every band_sample_every ticks: the three detector-state
        # device reads per observation are the costliest line in the
        # telemetry path and the band moves slowly
        det_cfg = cfg.detector
        if t % tel.config.band_sample_every == 0:
            calibrated = np.asarray(self.det.count) >= det_cfg.warmup
            if calibrated.any():
                mean = np.asarray(self.det.mean)
                sigma = np.maximum(
                    np.sqrt(np.maximum(np.asarray(self.det.var), 0.0))
                    + det_cfg.min_sigma,
                    det_cfg.rel_sigma * mean,
                )
                tel.band_width.observe_many(det_cfg.k_sigma * sigma[calibrated])
                tel.loss_ratio.observe_many(
                    losses[calibrated]
                    / np.maximum(mean[calibrated], det_cfg.min_sigma)
                )

        if decision.merge:
            tel.merge_rounds.inc()
            split = self.governor.round_bytes_by_precision(
                decision.participants, decision.fp_participants
            )
            for precision, nbytes in split.items():
                tel.merge_bytes.labels(precision=precision).inc(nbytes)
            if self._residual is not None:
                tel.ef_residual_norm.set(float(jnp.sqrt(sum(
                    jnp.sum(jnp.square(leaf))
                    for leaf in jax.tree_util.tree_leaves(self._residual)
                ))))
        if nonfinite:
            tel.nonfinite.inc(nonfinite)

        # partially-served windows: padded rows scored padding data, so
        # loss stats aggregate over served devices only
        live = losses if served is None or served.all() else losses[served]
        if live.size == 0:
            live = losses
        rec = {
            "tick": t,
            "loss_mean": float(live.mean()),
            "loss_max": float(live.max()),
            "quarantined": n_quarantined,
            "fresh": np.flatnonzero(fresh).tolist() if n_fresh else [],
            "decision": {
                "merge": decision.merge, "reason": decision.reason,
                "participants": decision.participants,
                "round_bytes": decision.round_bytes,
                "fp_participants": decision.fp_participants,
            },
            "ingest_seconds": ingest_seconds,
            "merge_seconds": merge_seconds,
            "tick_seconds": tick_seconds,
            "nonfinite_payloads": nonfinite,
        }
        if served is not None and not served.all():
            rec["n_served"] = int(served.sum())
        if losses.shape[0] <= 512:
            # small fleets: full loss vector + quarantine set, the replay
            # probe's comparison surface; large fleets keep the ring lean
            # (tolist() already widens f32 to exact Python floats)
            rec["losses"] = losses.tolist()
            rec["drifted"] = (
                np.flatnonzero(drifted).tolist() if n_quarantined else []
            )
        if faults:
            rec["faults"] = faults
        if robust_scores is not None and robust_scores.size:
            top = np.argsort(robust_scores)[::-1][:5]
            rec["robust_outliers"] = [
                (int(d), float(robust_scores[d])) for d in top
            ]
        tel.flight.record(rec)

        if nonfinite:
            tel.maybe_dump(
                t, "nonfinite", inputs=batch,
                extra={"nonfinite_payloads": nonfinite},
            )
        slo = tel.config.slo_tick_seconds
        if slo is not None and tick_seconds > slo:
            tel.slo_breaches.inc()
            tel.maybe_dump(
                t, "slo", inputs=batch,
                extra={"tick_seconds": tick_seconds, "slo_seconds": slo},
            )

    def finalize_telemetry(self) -> dict | None:
        """Flush the sink's outputs (trace + exposition, dir mode) and
        return the end-of-run summary; None when telemetry is off."""
        if self.telemetry is None:
            return None
        self.telemetry.close()
        return self.telemetry.summary()

    def run(self, feed: TickFeed, *, ticks: int | None = None) -> list[TickReport]:
        """Drive the runtime over a feed (all of it by default). Asking
        for more ticks than the feed holds is a truncation, not an
        error: the runtime processes what exists and says so."""
        if ticks is not None and ticks > feed.n_ticks:
            logger.warning(
                "run(ticks=%d) exceeds the feed's %d ticks; truncating",
                ticks, feed.n_ticks,
            )
        n = feed.n_ticks if ticks is None else min(ticks, feed.n_ticks)
        return [self.tick(feed.tick_batch(t)) for t in range(n)]

    def warmup(self, batch_size: int) -> None:
        """Compile the tick-loop jits before live traffic arrives.

        Dispatches the ingest and merge traces on all-zero operands
        with ``served`` all-False and a zero participation mask, then
        DISCARDS every output — no model, detector, governor, or
        telemetry state changes. Without this, the first real tick
        pays multi-second XLA compilation, which a serving watchdog
        cannot tell apart from a stalled runtime. Uses the same shapes
        as real ticks, so compile-once still holds afterwards."""
        d = self.n_devices
        f = int(self.states.params.alpha.shape[1])
        batch = jnp.zeros((d, batch_size, f), jnp.float32)
        none_served = jnp.zeros(d, bool)
        out = self._ingest_detect(
            self.states, self.det, batch,
            jnp.asarray(False), jnp.asarray(np.ones(d, bool)), none_served,
        )
        jax.block_until_ready(out)
        mask = jnp.zeros(d, jnp.float32)
        if self._merge_boundary is not None:
            shape = tuple(self._last_good.shape)
            out = self._merge_boundary(
                self.states, mask, mask,
                jnp.ones(shape[0], jnp.float32),
                jnp.zeros(shape, jnp.float32),
                jnp.zeros(shape[0], jnp.int32),
                self._last_good,
            )
        elif self.config.staleness is not None:
            out = self._merge_stale(
                self.states, self._hist_u, self._hist_v, mask, jnp.int32(0)
            )
        elif self._residual is not None:
            out = self._merge_fresh(
                self.states, mask, jnp.zeros(d, bool), self._residual
            )
        else:
            out = self._merge_fresh(self.states, mask)
        jax.block_until_ready(out)

    # ------------------------------------------------------------ durability

    def _snapshot_tree(self):
        tree = {
            "states": self.states,
            "det": self.det,
            # host-side counters stay numpy (int64-exact through npz)
            "tick": np.asarray(self.tick_no, np.int64),
            "merge_round": np.asarray(self.merge_round, np.int64),
            "gov": np.asarray(
                [self.governor.state.ticks, self.governor.state.merges,
                 self.governor.state.bytes_spent,
                 self.governor.state.deferred_budget,
                 self.governor.state.deferred_participants,
                 self.governor.state.deferred_degraded], np.int64,
            ),
            # (N, 2) detection-event ring; restored whole (shape may
            # differ from the template's — the numpy path allows that)
            "detections": np.asarray(self.detections, np.int64).reshape(-1, 2),
            "detections_total": np.asarray(self.detections_total, np.int64),
            "post_merge": np.asarray(self._post_merge, np.int32),
            "merge_mask": np.asarray(self._merge_mask, np.int32),
        }
        if self.telemetry is not None:
            # registry counters + flight ring as a JSON blob in a uint8
            # leaf: npz round-trips bytes exactly, and the variable
            # length rides the same shape-free numpy restore path the
            # detection ledger uses — so a kill/restore resumes with
            # CONTINUOUS metrics instead of a zeroed registry
            tree["telemetry"] = np.frombuffer(
                self.telemetry.state_bytes(), np.uint8
            )
        if self._hist_u is not None:
            tree["hist_u"] = self._hist_u
            tree["hist_v"] = self._hist_v
        if self._residual is not None:
            tree["residual"] = self._residual
        if self._last_good is not None:
            tree["last_good"] = self._last_good
            tree["robust_gov"] = np.stack([
                self.governor.robust_strikes,
                self.governor.robust_calm,
                self.governor.robust_quarantined.astype(np.int64),
            ])
        return tree

    def snapshot(self) -> Path:
        if self.ckpt is None:
            raise RuntimeError("runtime has no snapshot_dir configured")
        return self.ckpt.save(self.tick_no, self._snapshot_tree())

    def restore(self, step: int | None = None) -> int:
        """Load the latest (or a specific) snapshot into the live
        runtime; returns the restored tick number."""
        if self.ckpt is None:
            raise RuntimeError("runtime has no snapshot_dir configured")
        tree, _ = self.ckpt.restore(self._snapshot_tree(), step)
        self.states = tree["states"]
        self.det = tree["det"]
        self.tick_no = int(tree["tick"])
        self.merge_round = int(tree["merge_round"])
        gov = np.asarray(tree["gov"])
        self.governor.state.ticks = int(gov[0])
        self.governor.state.merges = int(gov[1])
        self.governor.state.bytes_spent = int(gov[2])
        self.governor.state.deferred_budget = int(gov[3])
        self.governor.state.deferred_participants = int(gov[4])
        # PR-8-era snapshots carry a 5-element gov ledger (no
        # deferred_degraded); restoring one resets only that counter
        self.governor.state.deferred_degraded = (
            int(gov[5]) if gov.shape[0] > 5 else 0
        )
        self.detections = deque(
            ((int(t), int(d)) for t, d in np.asarray(tree["detections"])),
            maxlen=self.config.detections_cap,
        )
        self.detections_total = int(tree["detections_total"])
        if self.telemetry is not None:
            self.telemetry.load_state_bytes(
                np.asarray(tree["telemetry"], np.uint8).tobytes()
            )
        self._post_merge = bool(int(tree["post_merge"]))
        self._merge_mask = np.asarray(tree["merge_mask"]).astype(bool)
        if self._hist_u is not None:
            self._hist_u = tree["hist_u"]
            self._hist_v = tree["hist_v"]
        if self._residual is not None:
            self._residual = tree["residual"]
        if self._last_good is not None:
            self._last_good = tree["last_good"]
            rg = np.asarray(tree["robust_gov"])
            self.governor.robust_strikes = rg[0].astype(np.int64)
            self.governor.robust_calm = rg[1].astype(np.int64)
            self.governor.robust_quarantined = rg[2].astype(bool)
        return self.tick_no

    # ---------------------------------------------------------- compile-once

    def jit_cache_sizes(self) -> dict[str, int]:
        sizes = {"ingest_detect": self._ingest_detect._cache_size()}
        if self._merge_boundary is not None:
            # the hardened boundary owns all merges; _merge_fresh is never
            # dispatched (its 0-entry cache would read as a false miss)
            sizes["merge_boundary"] = self._merge_boundary._cache_size()
        else:
            sizes["merge_fresh"] = self._merge_fresh._cache_size()
        if self.config.staleness is not None:
            sizes["merge_stale"] = self._merge_stale._cache_size()
        return sizes

    def assert_compile_once(self) -> dict[str, int]:
        """The tick loop must be a compile-once path: every runtime-owned
        jitted function has at most one trace. Raises on retracing."""
        sizes = self.jit_cache_sizes()
        bad = {k: v for k, v in sizes.items() if v > 1}
        if bad:
            raise AssertionError(f"per-tick retracing detected: {bad}")
        return sizes
