"""Cohort-paged fleet runtime — million-device serving on one host.

``FleetRuntime`` keeps the whole stacked fleet device-resident, which
caps D at accelerator memory. This runtime removes that cap for
D ≫ 10⁵ by splitting the state by its scaling law:

- the O(D·(Ñ² + Ñm)) model state — every device's (P, β) — lives in a
  host-side ``FleetArena`` and only the ACTIVE cohort's page is ever
  device-resident. Pages stream through the fused ingest family
  (``fleet_ingest_paged``) double-buffered: cohort k+1's page is
  staged host→device while cohort k's ingest computes, and k's
  trained page scatters back while k+1 runs.
- the O(D) scalar state — the drift-detector bank, participation
  masks, per-tick losses — stays resident (24 bytes/device: one
  million devices is ~24 MB), so detection runs as ONE full-fleet
  ``detector_update`` per tick, exactly the resident trace.
- merges run as a two-tier tree (``repro.fleet.arena.CohortMerger``):
  intra-cohort masked segment sums on the resident page (tier 1),
  an O(cohorts)-sized inter-cohort reduction (tier 2). Eq. 8 is a sum,
  so the tree reorders but never changes the result — the paged
  runtime's TickReport stream matches the resident runtime's
  tick-by-tick (tests/test_cohort.py's differential test).

One resident-path divergence, by design: the resident detect computes
the post-merge common-mode median in-trace every tick (XLA cannot skip
it — ``rebase`` is traced). Here the host KNOWS whether this tick
rebases, so the O(D log D) median (``common_mode_ratio``) runs only on
actual post-merge ticks and its scalar feeds ``detector_update`` via
``common=`` — same f32 arithmetic on rebase ticks, no sort at all on
the ~(merge_every−1)/merge_every that do not rebase.

Governor, telemetry, and report schema are shared with the resident
runtime; the paging phases show up as ``page_in``/``page_out`` in the
phase histograms and the arena/cohort gauges track residency.
"""
from __future__ import annotations

import logging
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.fleet.arena import (
    CohortMerger,
    CohortSchedule,
    FleetArena,
    TierCost,
)
from repro.kernels.fleet_ingest import fleet_ingest_paged
from repro.obs import TelemetrySink
from repro.runtime.detector import (
    common_mode_ratio,
    detector_update,
    init_detector,
)
from repro.runtime.feed import TickFeed
from repro.runtime.governor import MergeDecision, MergeGovernor
from repro.runtime.runtime import (
    _NULL_PHASE,
    RuntimeConfig,
    TickReport,
    _where_served,
)

__all__ = ["CohortFleetRuntime"]

logger = logging.getLogger(__name__)

_UNSUPPORTED = (
    ("staleness", "the stale published-version ring stores full stacked "
     "payload histories — O(D·lag) device memory, the exact layout the "
     "arena exists to avoid"),
    ("robust", "robust merges score every device's payload jointly; a "
     "paged robust boundary needs its own two-tier scoring pass"),
    ("faults", "the fault injector's payload boundary operates on the "
     "full stacked (U, V) stack"),
)


class CohortFleetRuntime:
    """A paged fleet: host arena + resident detector bank + governor."""

    def __init__(
        self,
        arena: FleetArena,
        config: RuntimeConfig,
        *,
        cohort_size: int | None = None,
        schedule: CohortSchedule | None = None,
        active_per_tick: int | None = None,
        policies: tuple = (),
    ) -> None:
        d = arena.n_devices
        if config.topology.n_devices != d:
            raise ValueError(
                f"topology is for {config.topology.n_devices} devices, "
                f"arena has {d}"
            )
        for attr, why in _UNSUPPORTED:
            if getattr(config, attr) is not None:
                raise ValueError(
                    f"cohort-paged runtime does not support {attr}: {why}"
                )
        if config.payload_precision != "f32":
            raise ValueError(
                "cohort-paged runtime requires payload_precision='f32' "
                "(the quantized codec's error-feedback accumulator is a "
                "second full-fleet stack; page it before enabling this)"
            )
        if config.snapshot_dir is not None or config.snapshot_every:
            raise ValueError(
                "cohort-paged runtime has no snapshot path yet — the "
                "checkpoint store serializes stacked fleets; persist the "
                "arena's numpy leaves directly instead"
            )
        if schedule is None:
            if cohort_size is None:
                raise ValueError("need cohort_size= (or a full schedule=)")
            schedule = CohortSchedule(d, cohort_size, active_per_tick)
        elif schedule.n_devices != d:
            raise ValueError(
                f"schedule D={schedule.n_devices} vs arena D={d}"
            )

        self.arena = arena
        self.schedule = schedule
        self.config = config
        self.det = init_detector(d)
        self.governor = MergeGovernor(
            config.topology, arena.n_hidden, arena.n_out, config.governor,
            policies=policies, payload_precision=config.payload_precision,
        )
        self.merger = CohortMerger(
            config.topology, schedule, ridge=config.ridge,
            kernel=True if config.use_merge_kernel else "auto",
        )
        self.tick_no = 0
        self.merge_round = 0
        self.detections: deque[tuple[int, int]] = deque(
            maxlen=config.detections_cap
        )
        self.detections_total = 0
        self.telemetry = (
            TelemetrySink(config.telemetry)
            if config.telemetry is not None else None
        )
        self._post_merge = False
        self._merge_mask = np.ones(d, bool)
        self._all_served = np.ones(d, bool)

        det_cfg = config.detector
        backend = config.ingest_backend
        alpha_j = jnp.asarray(arena.alpha)
        bias_j = jnp.asarray(arena.bias)
        activation, forget = arena.activation, arena.forget

        def ingest(p, beta, window, served):
            # the fused one-pass ingest on one page; un-served devices
            # keep their page rows bit-for-bit (same served contract as
            # the resident tick — a traced operand, never a retrace)
            p2, b2, losses = fleet_ingest_paged(
                p, beta, alpha_j, bias_j, window,
                activation=activation, forget=forget, backend=backend,
            )
            sel = served.astype(bool)[:, None, None]
            return jnp.where(sel, p2, p), jnp.where(sel, b2, beta), losses

        self._ingest = jax.jit(ingest)

        def detect(det, losses, rebase, participants, served, common):
            det_new, _, fresh = detector_update(
                det, losses, det_cfg, rebase=rebase,
                participants=participants, common=common,
            )
            keep = served.astype(bool)
            det = _where_served(keep, det_new, det)
            return det, det.drifted, fresh & keep

        self._detect = jax.jit(detect)
        self._common = jax.jit(
            lambda det, losses, participants: common_mode_ratio(
                det, losses, det_cfg, participants=participants
            )
        )

    @property
    def n_devices(self) -> int:
        return self.arena.n_devices

    # ------------------------------------------------------------- tick loop

    def _phase(self, name: str):
        return _NULL_PHASE if self.telemetry is None else self.telemetry.phase(name)

    def _observe_phase(self, name: str, seconds: float) -> None:
        if self.telemetry is not None:
            self.telemetry._phase_observe[name](seconds)

    def _resolve_batch(self, batch):
        """Normalize the tick's data source to ``fn(lo, hi) -> (C, B, F)``.

        A full (D, B, F) array works at small D (the differential-test
        surface); at arena scale the full array would be the second
        thing that does not fit, so a callable deals each active
        cohort's slice on demand and the full batch never exists."""
        if callable(batch):
            return batch
        arr = np.asarray(batch)
        d = self.n_devices
        if arr.ndim != 3 or arr.shape[0] != d:
            raise ValueError(
                f"tick batch must be (n_devices={d}, B, features) or a "
                f"callable (lo, hi) -> (cohort, B, features); got shape "
                f"{getattr(arr, 'shape', None)}"
            )
        if arr.shape[1] < 1:
            raise ValueError(
                "tick batch has zero samples per device (B=0) — an "
                "all-shed tick window carries no data to ingest"
            )
        return lambda lo, hi: arr[lo:hi]

    def tick(
        self,
        batch,
        *,
        served: np.ndarray | None = None,
        allow_merge: bool = True,
    ) -> TickReport:
        """One paged serving tick: stream the active cohorts' pages
        through ingest (double-buffered), one full-fleet detect, then
        govern and (maybe) run the two-tier merge on the arena.

        Same surface as the resident ``FleetRuntime.tick`` — ``batch``
        may additionally be a callable ``(lo, hi) -> (cohort, B, F)``
        so the full (D, B, F) window never has to exist at arena scale.
        Devices in cohorts OUTSIDE this tick's active window report
        NaN losses (they served nothing) and keep model + detector
        state untouched."""
        t = self.tick_no
        d = self.n_devices
        sched = self.schedule
        c = sched.cohort_size
        batch_fn = self._resolve_batch(batch)
        if served is None:
            served_np = self._all_served
        else:
            served_np = np.asarray(served).astype(bool)
            if served_np.shape != (d,):
                raise ValueError(
                    f"served mask must be ({d},); got {served_np.shape}"
                )
        active = sched.active(t)
        tel = self.telemetry
        t_start = time.perf_counter()

        # devices actually serving this tick: served ∧ active-cohort
        if len(active) == sched.n_cohorts:
            served_eff = served_np
        else:
            served_eff = np.zeros(d, bool)
            for k in active:
                lo, hi = sched.bounds(k)
                served_eff[lo:hi] = served_np[lo:hi]

        # ---- paged ingest, double-buffered: stage page k+1 while page
        # k's compute is in flight, scatter k back as it lands
        def stage(k: int):
            lo, hi = sched.bounds(k)
            with self._phase("page_in"):
                win = np.asarray(batch_fn(lo, hi), np.float32)
                if win.shape[0] != c or win.ndim != 3 or win.shape[1] < 1:
                    raise ValueError(
                        f"cohort batch for [{lo}, {hi}) must be "
                        f"({c}, B>=1, features); got {win.shape}"
                    )
                return (
                    lo, hi,
                    jax.device_put(self.arena.p[lo:hi]),
                    jax.device_put(self.arena.beta[lo:hi]),
                    jax.device_put(win),
                    jax.device_put(served_np[lo:hi]),
                )

        t0 = time.perf_counter()
        losses_np = np.full(d, np.nan, np.float32)
        cur = stage(active[0])
        for i in range(len(active)):
            lo, hi, pj, bj, wj, sj = cur
            out = self._ingest(pj, bj, wj, sj)      # async dispatch
            cur = stage(active[i + 1]) if i + 1 < len(active) else None
            with self._phase("page_out"):
                p2, b2, lo_j = out
                self.arena.p[lo:hi] = np.asarray(p2)     # blocks on page
                self.arena.beta[lo:hi] = np.asarray(b2)
                losses_np[lo:hi] = np.asarray(lo_j)
            if tel is not None:
                tel.cohort_pages.inc()

        # ---- full-fleet detect (O(D) scalars stay resident). The
        # common-mode median is fleet-wide state the pages cannot see —
        # computed here from the PRE-update bank, only on rebase ticks.
        losses_j = jnp.asarray(losses_np)
        merge_mask_j = jnp.asarray(self._merge_mask)
        if self._post_merge:
            common = self._common(self.det, losses_j, merge_mask_j)
        else:
            common = jnp.float32(1.0)  # unused: no device rebases
        self.det, drifted, fresh = self._detect(
            self.det, losses_j, jnp.asarray(self._post_merge),
            merge_mask_j, jnp.asarray(served_eff), common,
        )
        jax.block_until_ready((self.det, drifted, fresh))
        ingest_seconds = time.perf_counter() - t0
        self._observe_phase("ingest", ingest_seconds)

        drifted_np = np.asarray(drifted)
        fresh_np = np.asarray(fresh)
        n_fresh = int(fresh_np.sum())
        self.detections_total += n_fresh
        for dev in np.flatnonzero(fresh_np):
            self.detections.append((t, int(dev)))

        with self._phase("govern"):
            if self.config.gate_merges:
                mask = self.governor.participation(drifted_np, losses_np)
            else:
                mask = np.ones(d, bool)
            decision = self.governor.decide(t, mask, None, allow=allow_merge)

        merge_seconds = None
        tier_cost: TierCost | None = None
        if decision.merge:
            t0 = time.perf_counter()
            with self._phase("merge"):
                tier_cost = self.merger.merge(self.arena, mask)
            merge_seconds = time.perf_counter() - t0
            self.merge_round += 1

        tick_seconds = time.perf_counter() - t_start
        if tel is not None:
            self._record_telemetry(
                t, losses_np, drifted_np, fresh_np, n_fresh, decision,
                tier_cost, ingest_seconds, merge_seconds, tick_seconds,
                served_eff, len(active),
            )

        self._post_merge = decision.merge
        if decision.merge:
            self._merge_mask = mask.copy()
        self.tick_no = t + 1
        full = served is None and len(active) == sched.n_cohorts
        return TickReport(
            tick=t, losses=losses_np, drifted=drifted_np,
            fresh_detections=fresh_np, decision=decision,
            merge_seconds=merge_seconds, ingest_seconds=ingest_seconds,
            served=None if full else served_eff,
        )

    # ---------------------------------------------------------- telemetry

    def _record_telemetry(
        self, t: int, losses: np.ndarray, drifted: np.ndarray,
        fresh: np.ndarray, n_fresh: int, decision: MergeDecision,
        tier_cost: TierCost | None, ingest_seconds: float,
        merge_seconds: float | None, tick_seconds: float,
        served: np.ndarray, n_active: int,
    ) -> None:
        tel = self.telemetry
        tel.ticks.inc()
        tel.tick_seconds.observe(tick_seconds)
        if n_fresh:
            tel.detections.inc(n_fresh)
        tel.quarantined.set(int(drifted.sum()))
        tel.arena_bytes.set(self.arena.nbytes)
        # residency = the streaming window: active cohorts' devices
        tel.arena_resident_devices.set(n_active * self.schedule.cohort_size)
        if decision.merge:
            tel.merge_rounds.inc()
            split = self.governor.round_bytes_by_precision(
                decision.participants, decision.fp_participants
            )
            for precision, nbytes in split.items():
                tel.merge_bytes.labels(precision=precision).inc(nbytes)
            if tier_cost is not None:
                tel.merge_tier_bytes.labels(tier="intra").inc(
                    tier_cost.bytes_tier1
                )
                tel.merge_tier_bytes.labels(tier="inter").inc(
                    tier_cost.bytes_tier2
                )
        live = losses[served] if not served.all() else losses
        if live.size == 0:
            live = losses
        rec = {
            "tick": t,
            "loss_mean": float(np.nanmean(live)) if live.size else float("nan"),
            "loss_max": float(np.nanmax(live)) if live.size else float("nan"),
            "quarantined": int(drifted.sum()),
            "fresh": np.flatnonzero(fresh).tolist() if n_fresh else [],
            "decision": {
                "merge": decision.merge, "reason": decision.reason,
                "participants": decision.participants,
                "round_bytes": decision.round_bytes,
            },
            "active_cohorts": n_active,
            "ingest_seconds": ingest_seconds,
            "merge_seconds": merge_seconds,
            "tick_seconds": tick_seconds,
        }
        if tier_cost is not None:
            rec["tier_bytes"] = {
                "intra": tier_cost.bytes_tier1,
                "inter": tier_cost.bytes_tier2,
            }
        tel.flight.record(rec)
        slo = tel.config.slo_tick_seconds
        if slo is not None and tick_seconds > slo:
            tel.slo_breaches.inc()
            tel.maybe_dump(
                t, "slo",
                extra={"tick_seconds": tick_seconds, "slo_seconds": slo},
            )

    def finalize_telemetry(self) -> dict | None:
        if self.telemetry is None:
            return None
        self.telemetry.close()
        return self.telemetry.summary()

    # ------------------------------------------------------------- driving

    def run(self, feed: TickFeed, *, ticks: int | None = None) -> list[TickReport]:
        """Drive the runtime over a feed (all of it by default)."""
        if ticks is not None and ticks > feed.n_ticks:
            logger.warning(
                "run(ticks=%d) exceeds the feed's %d ticks; truncating",
                ticks, feed.n_ticks,
            )
        n = feed.n_ticks if ticks is None else min(ticks, feed.n_ticks)
        return [self.tick(feed.tick_batch(t)) for t in range(n)]

    def assert_compile_once(self) -> None:
        """Hard check of the compile-once contract: every jit owned by
        the runtime (and its merger) has traced at most once. The soak
        benchmark calls this after the run — a second trace of the page
        ingest at 1M devices is a multi-second stall per COHORT."""
        sizes = {
            "ingest": self._ingest._cache_size(),
            "detect": self._detect._cache_size(),
            "common": self._common._cache_size(),
        }
        sizes.update(self.merger.jit_cache_sizes())
        bad = {k: v for k, v in sizes.items() if v > 1}
        if bad:
            raise AssertionError(f"jits traced more than once: {bad}")
