"""repro.runtime — resident streaming fleet runtime.

The serving layer on top of the fleet simulator: a live ``FleetRuntime``
owns the stacked OS-ELM fleet and processes a stream of ticks — jitted
ingest (score + k=1 sequential training), a vectorized sequential
concept-drift detector over the ae_score trajectories, a stateful merge
governor that quarantines drifted devices out of the topology merge
(re-admitting them after re-convergence) under a per-topology
comm-budget SLO, optional stale-payload merging, and checkpointed
snapshots so the fleet survives restarts. The whole tick loop is a
compile-once path (``FleetRuntime.assert_compile_once``).

Merge payloads can ship quantized (``RuntimeConfig(payload_precision=
"int8"|"f16")``): the error-feedback wire codec of
``repro.fleet.quantize`` with a detector-gated precision policy —
``quarantine_risk`` devices (drift-flagged, or re-admission hysteresis
still elevated) publish exact f32 payloads while stable devices
publish the quantized format, and the governor's byte ledger blends
the two per round.

With ``RuntimeConfig(telemetry=TelemetryConfig(...))`` the whole tick
loop emits through ``repro.obs``: per-phase fenced wall-clock
histograms, merge bytes by wire precision, detector band dynamics, and
a crash flight recorder whose ring dumps (with the failing tick's
inputs) on exception, non-finite payload rejection, or SLO breach —
all host-side, so the compile-once property is unchanged.
"""
from repro.runtime.cohort import CohortFleetRuntime
from repro.runtime.detector import (
    DetectorConfig,
    DetectorState,
    common_mode_ratio,
    detector_update,
    init_detector,
    quarantine_risk,
)
from repro.runtime.feed import TickFeed
from repro.runtime.governor import (
    GovernorConfig,
    GovernorState,
    MergeDecision,
    MergeGovernor,
)
from repro.runtime.runtime import FleetRuntime, RuntimeConfig, TickReport

__all__ = [
    "CohortFleetRuntime",
    "DetectorConfig", "DetectorState", "common_mode_ratio",
    "detector_update", "init_detector", "quarantine_risk",
    "TickFeed",
    "GovernorConfig", "GovernorState", "MergeDecision", "MergeGovernor",
    "FleetRuntime", "RuntimeConfig", "TickReport",
]
