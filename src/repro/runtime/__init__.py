"""repro.runtime — resident streaming fleet runtime.

The serving layer on top of the fleet simulator: a live ``FleetRuntime``
owns the stacked OS-ELM fleet and processes a stream of ticks — jitted
ingest (score + k=1 sequential training), a vectorized sequential
concept-drift detector over the ae_score trajectories, a stateful merge
governor that quarantines drifted devices out of the topology merge
(re-admitting them after re-convergence) under a per-topology
comm-budget SLO, optional stale-payload merging, and checkpointed
snapshots so the fleet survives restarts. The whole tick loop is a
compile-once path (``FleetRuntime.assert_compile_once``).
"""
from repro.runtime.detector import (
    DetectorConfig,
    DetectorState,
    detector_update,
    init_detector,
)
from repro.runtime.feed import TickFeed
from repro.runtime.governor import (
    GovernorConfig,
    GovernorState,
    MergeDecision,
    MergeGovernor,
)
from repro.runtime.runtime import FleetRuntime, RuntimeConfig, TickReport

__all__ = [
    "DetectorConfig", "DetectorState", "detector_update", "init_detector",
    "TickFeed",
    "GovernorConfig", "GovernorState", "MergeDecision", "MergeGovernor",
    "FleetRuntime", "RuntimeConfig", "TickReport",
]
