"""Tick-batched ingest view over ``FleetStreams``.

The resident runtime consumes per-device streams in fixed-size tick
batches: tick t serves samples [t·B, (t+1)·B) of every device's stream
simultaneously, shaped (D, B, features). ``TickFeed`` is the cursorless
host-side view that deals those slices (constant shape → the jitted
ingest compiles once) and maps the partitioner's step-indexed
``DriftEvent`` schedule onto tick indices so detection delay can be
measured in the same clock the detector runs on.
"""
from __future__ import annotations

import numpy as np

from repro.fleet.partition import FleetStreams


class TickFeed:
    """Deal (D, batch, features) tick batches from a ``FleetStreams``."""

    def __init__(self, streams: FleetStreams, batch: int) -> None:
        if batch < 1:
            raise ValueError(f"need batch >= 1, got {batch}")
        steps = streams.xs.shape[1]
        if batch > steps:
            raise ValueError(f"batch={batch} exceeds stream length {steps}")
        self.streams = streams
        self.batch = batch
        self.n_ticks = steps // batch
        tail = steps - self.n_ticks * batch
        if tail:
            # same contract as fleet_train_rounds: constant tick shapes
            # beat a ragged final batch (which would retrace the ingest)
            import logging

            logging.getLogger(__name__).warning(
                "TickFeed: %d trailing samples per stream dropped "
                "(steps=%d not divisible by batch=%d)", tail, steps, batch,
            )

    @property
    def n_devices(self) -> int:
        return self.streams.n_devices

    def tick_batch(self, t: int) -> np.ndarray:
        """Samples every device serves during tick ``t``: (D, B, F)."""
        if not 0 <= t < self.n_ticks:
            raise IndexError(f"tick {t} outside [0, {self.n_ticks})")
        lo = t * self.batch
        return self.streams.xs[:, lo : lo + self.batch]

    def drift_ticks(self) -> dict[int, int]:
        """device -> tick at which its first scheduled drift event lands
        (ground truth for detection-delay accounting)."""
        out: dict[int, int] = {}
        for ev in sorted(self.streams.drift, key=lambda e: e.step):
            tick = ev.step // self.batch
            if ev.device not in out and tick < self.n_ticks:
                out[ev.device] = tick
        return out
