"""Tick-batched ingest view over ``FleetStreams``.

The resident runtime consumes per-device streams in fixed-size tick
batches: tick t serves samples [t·B, (t+1)·B) of every device's stream
simultaneously, shaped (D, B, features). ``TickFeed`` is the cursorless
host-side view that deals those slices (constant shape → the jitted
ingest compiles once) and maps the partitioner's step-indexed
``DriftEvent`` schedule onto tick indices so detection delay can be
measured in the same clock the detector runs on.
"""
from __future__ import annotations

import numpy as np

from repro.fleet.partition import FleetStreams


class TickFeed:
    """Deal (D, batch, features) tick batches from a ``FleetStreams``."""

    def __init__(self, streams: FleetStreams, batch: int) -> None:
        if batch < 1:
            raise ValueError(f"need batch >= 1, got {batch}")
        steps = streams.xs.shape[1]
        if batch > steps:
            raise ValueError(f"batch={batch} exceeds stream length {steps}")
        self.streams = streams
        self.batch = batch
        self.n_ticks = steps // batch
        self._warned_truncated = False
        tail = steps - self.n_ticks * batch
        if tail:
            # same contract as fleet_train_rounds: constant tick shapes
            # beat a ragged final batch (which would retrace the ingest)
            import logging

            logging.getLogger(__name__).warning(
                "TickFeed: %d trailing samples per stream dropped "
                "(steps=%d not divisible by batch=%d)", tail, steps, batch,
            )

    @property
    def n_devices(self) -> int:
        return self.streams.n_devices

    def tick_batch(self, t: int) -> np.ndarray:
        """Samples every device serves during tick ``t``: (D, B, F)."""
        if not 0 <= t < self.n_ticks:
            raise IndexError(f"tick {t} outside [0, {self.n_ticks})")
        lo = t * self.batch
        return self.streams.xs[:, lo : lo + self.batch]

    def drift_ticks(self) -> dict[int, int]:
        """device -> tick at which its first scheduled drift event lands
        (ground truth for detection-delay accounting).

        Events whose step falls in the truncated tail (``tick >=
        n_ticks``) never reach the runtime, so a device whose drift is
        scheduled *only* there is excluded here — and must be excluded
        from every consumer's denominator too (``truncated_drift_devices``
        is the set ``detection_stats`` needs to stay consistent)."""
        out: dict[int, int] = {}
        for ev in sorted(self.streams.drift, key=lambda e: e.step):
            tick = ev.step // self.batch
            if ev.device not in out and tick < self.n_ticks:
                out[ev.device] = tick
        truncated = self.truncated_drift_devices
        if truncated and not self._warned_truncated:
            self._warned_truncated = True
            import logging

            logging.getLogger(__name__).warning(
                "TickFeed.drift_ticks: drift for device(s) %s is scheduled "
                "entirely past tick %d (the truncated tail) and will never "
                "be served — excluding them from drift ground truth",
                sorted(truncated), self.n_ticks,
            )
        return out

    @property
    def truncated_drift_devices(self) -> frozenset[int]:
        """Devices whose *every* scheduled drift event lands past the
        last full tick: their drift is silently unservable, so detection
        accounting must not count a flag on them as a false positive nor
        their (never-delivered) drift as missed."""
        first_served: set[int] = set()
        scheduled: set[int] = set()
        for ev in self.streams.drift:
            scheduled.add(ev.device)
            if ev.step // self.batch < self.n_ticks:
                first_served.add(ev.device)
        return frozenset(scheduled - first_served)
