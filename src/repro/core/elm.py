"""ELM — batch Extreme Learning Machine (paper §3.1, Eqs. 1–3).

Single hidden-layer feedforward network (SLFN):

    y = G(x·α + b) · β

The input weight ``α`` and bias ``b`` are random and frozen; only the
output weight ``β`` is trained, analytically and in one shot:

    β̂ = H† t,   H = G(x·α + b)

With ``rank H = Ñ`` the pseudo-inverse decomposes (Eq. 4) as
``H† = (HᵀH)⁻¹ Hᵀ`` — the form E²LM (``e2lm.py``) builds on. ``HᵀH`` is
symmetric positive (semi-)definite so we solve via Cholesky; a ridge
``εI`` is available (default 0.0 — faithful to the paper, which assumes
nonsingularity).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.activations import get_activation


class SLFNParams(NamedTuple):
    """Frozen random projection of the SLFN (shared by ELM / OS-ELM).

    The paper assumes ``α`` and ``b`` are identical across federated
    devices (Section 4.2) — achieved by seeding with the same key.
    """

    alpha: jnp.ndarray  # (n, n_hidden) input weights — random, frozen
    bias: jnp.ndarray   # (n_hidden,) hidden bias — random, frozen

    @property
    def n_in(self) -> int:
        return self.alpha.shape[0]

    @property
    def n_hidden(self) -> int:
        return self.alpha.shape[1]


def init_slfn(
    key: jax.Array,
    n_in: int,
    n_hidden: int,
    *,
    dist: str = "uniform",
    dtype=jnp.float32,
) -> SLFNParams:
    """Random frozen projection; ``dist`` matches the paper's p(x)=Uniform."""
    ka, kb = jax.random.split(key)
    if dist == "uniform":
        alpha = jax.random.uniform(ka, (n_in, n_hidden), dtype, -1.0, 1.0)
        bias = jax.random.uniform(kb, (n_hidden,), dtype, -1.0, 1.0)
    elif dist == "normal":
        alpha = jax.random.normal(ka, (n_in, n_hidden), dtype)
        bias = jax.random.normal(kb, (n_hidden,), dtype)
    else:
        raise ValueError(f"unknown init dist {dist!r}")
    return SLFNParams(alpha=alpha, bias=bias)


def hidden(params: SLFNParams, x: jnp.ndarray, activation: str = "sigmoid") -> jnp.ndarray:
    """H = G(x·α + b) for a chunk x of shape (k, n)."""
    g = get_activation(activation)
    return g(x @ params.alpha + params.bias)


class ELMModel(NamedTuple):
    params: SLFNParams
    beta: jnp.ndarray  # (n_hidden, m)
    activation: str = "sigmoid"


def train_elm(
    params: SLFNParams,
    x: jnp.ndarray,
    t: jnp.ndarray,
    *,
    activation: str = "sigmoid",
    ridge: float = 0.0,
) -> ELMModel:
    """One-shot batch solve β̂ = (HᵀH + εI)⁻¹ Hᵀ t (Eqs. 4–5)."""
    h = hidden(params, x, activation)
    u = h.T @ h
    v = h.T @ t
    beta = solve_beta(u, v, ridge=ridge)
    return ELMModel(params=params, beta=beta, activation=activation)


def solve_beta(u: jnp.ndarray, v: jnp.ndarray, *, ridge: float = 0.0) -> jnp.ndarray:
    """β = U⁻¹V via Cholesky (U is SPD up to rank deficiency).

    Falls back to the paper-faithful plain solve semantics: with
    ridge=0 this is numerically the same system the paper inverts.
    """
    n = u.shape[0]
    u_reg = u + ridge * jnp.eye(n, dtype=u.dtype)
    cho = jax.scipy.linalg.cho_factor(u_reg)
    return jax.scipy.linalg.cho_solve(cho, v)


def invert_u(u: jnp.ndarray, *, ridge: float = 0.0) -> jnp.ndarray:
    """P = U⁻¹ via Cholesky; used when re-entering sequential training."""
    n = u.shape[0]
    u_reg = u + ridge * jnp.eye(n, dtype=u.dtype)
    cho = jax.scipy.linalg.cho_factor(u_reg)
    return jax.scipy.linalg.cho_solve(cho, jnp.eye(n, dtype=u.dtype))


def predict_elm(model: ELMModel, x: jnp.ndarray) -> jnp.ndarray:
    h = hidden(model.params, x, model.activation)
    return h @ model.beta
