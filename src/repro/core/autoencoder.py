"""OS-ELM autoencoder for semi-supervised anomaly detection (paper §3.4).

Autoencoder specialization: n == m (input reconstructs itself), Ñ < n
(bottleneck). Training uses x as its own target; the reconstruction MSE
is the anomaly score. Incoming data with loss above ``reject_threshold``
is rejected before training ("incoming data with high loss value should
be automatically rejected before training for stable anomaly
detection", §3.4).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.elm import init_slfn
from repro.core.oselm import (
    OSELMState,
    init_oselm,
    oselm_loss,
    oselm_step_k1,
    oselm_train_sequential,
)


def init_autoencoder(
    key: jax.Array,
    n_features: int,
    n_hidden: int,
    x0: jnp.ndarray,
    *,
    activation: str = "sigmoid",
    ridge: float = 0.0,
    forget: float = 1.0,
) -> OSELMState:
    """Build the SLFN (Ñ < n enforced) and run the Eq. 13 init step with
    x0 as both input and target."""
    if n_hidden >= n_features:
        raise ValueError(f"autoencoder needs a bottleneck: Ñ={n_hidden} >= n={n_features}")
    params = init_slfn(key, n_features, n_hidden)
    return init_oselm(params, x0, x0, activation=activation, ridge=ridge, forget=forget)


def ae_score(state: OSELMState, x: jnp.ndarray) -> jnp.ndarray:
    """Anomaly score = reconstruction MSE per sample; high = anomalous."""
    return oselm_loss(state, x, x)


def ae_train_step(state: OSELMState, x: jnp.ndarray) -> OSELMState:
    """One k=1 sequential autoencoder update (t = x)."""
    return oselm_step_k1(state, x, x)


def ae_train_stream(state: OSELMState, xs: jnp.ndarray) -> OSELMState:
    """Scan the k=1 update across a stream of samples."""
    return oselm_train_sequential(state, xs, xs)


def ae_train_step_guarded(
    state: OSELMState, x: jnp.ndarray, reject_threshold: jnp.ndarray
) -> tuple[OSELMState, jnp.ndarray]:
    """Train only if the sample is not anomalous under the current model
    (§3.4 rejection rule). Returns (state, accepted?)."""
    score = ae_score(state, x[None, :])[0]
    accept = score <= reject_threshold
    new_state = oselm_step_k1(state, x, x)
    merged = jax.tree.map(
        lambda a, b: jnp.where(accept, a, b), new_state, state
    )
    return merged, accept


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DetectorBank:
    """Multiple on-device learning instances, one per normal pattern
    (ref [18]); states are stacked along a leading axis and driven with
    vmap. The bank's anomaly score is the min over instances."""

    states: OSELMState  # stacked: every leaf has leading axis n_instances

    @property
    def n_instances(self) -> int:
        return self.states.beta.shape[0]


def make_bank(states: list[OSELMState]) -> DetectorBank:
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
    return DetectorBank(states=stacked)


def bank_score(bank: DetectorBank, x: jnp.ndarray) -> jnp.ndarray:
    """min over instances of the reconstruction loss: a sample is normal
    if *any* specialized instance reconstructs it."""
    per_inst = jax.vmap(lambda s: ae_score(s, x))(bank.states)  # (I, k)
    return jnp.min(per_inst, axis=0)


def bank_train_instance(bank: DetectorBank, idx: int, x: jnp.ndarray) -> DetectorBank:
    """Sequentially train one instance of the bank on a sample."""
    inst = jax.tree.map(lambda leaf: leaf[idx], bank.states)
    inst = ae_train_step(inst, x)
    new_states = jax.tree.map(
        lambda leaf, new: leaf.at[idx].set(new), bank.states, inst
    )
    return DetectorBank(states=new_states)
