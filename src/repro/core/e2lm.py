"""E²LM intermediate form + the paper's cooperative model update (§3.2, §4).

E²LM expresses the ELM solution through additive sufficient statistics

    U = HᵀH,   V = Hᵀt,   β̂ = U⁻¹V                     (Eq. 6)

which combine across datasets by plain addition (Eq. 8):

    U' = U + ΔU,   V' = V + ΔV

The paper's §4.1 modification extracts (U, V) from a *sequentially*
trained OS-ELM without storing past data (Eq. 15):

    Uᵢ = Kᵢ = Pᵢ⁻¹,   Vᵢ = Uᵢ βᵢ

and §4.2 defines the cooperative model update: devices exchange (U, V),
add them, and recover P ← U'⁻¹, β ← U'⁻¹V'.

Because Eq. 8 is associative and commutative, the N-device merge is an
all-reduce — `merge_mesh` in `repro.federated.mesh_federation` runs it
as one `jax.lax.psum`. Here we implement the algebra itself, including
the subtraction/replacement operations the paper notes E²LM supports.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.elm import invert_u, solve_beta
from repro.core.oselm import OSELMState


class UV(NamedTuple):
    """The exchanged intermediate results — the *only* payload devices
    share (never raw data; the paper's privacy argument)."""

    u: jnp.ndarray  # (Ñ, Ñ)  = Σ HᵀH
    v: jnp.ndarray  # (Ñ, m)  = Σ Hᵀt

    @property
    def nbytes(self) -> int:
        return int(self.u.size * self.u.dtype.itemsize + self.v.size * self.v.dtype.itemsize)


def to_uv(state: OSELMState, *, ridge: float = 0.0) -> UV:
    """Eq. 15: U = P⁻¹, V = Uβ.

    Only computed when results are shipped (the paper notes there is no
    need to maintain U,V per input chunk).
    """
    u = invert_u(state.p, ridge=ridge)
    u = 0.5 * (u + u.T)  # P is SPD in exact arithmetic; re-symmetrize f32 noise
    v = u @ state.beta
    return UV(u=u, v=v)


def uv_add(a: UV, b: UV) -> UV:
    """Eq. 8 — dataset union."""
    return UV(u=a.u + b.u, v=a.v + b.v)


def uv_sub(a: UV, b: UV) -> UV:
    """Dataset removal (supported by E²LM per §3.2 last paragraph)."""
    return UV(u=a.u - b.u, v=a.v - b.v)


def uv_replace(a: UV, old: UV, new: UV) -> UV:
    """Dataset replacement = subtraction followed by addition."""
    return uv_add(uv_sub(a, old), new)


def uv_sum(parts: Sequence[UV]) -> UV:
    """N-way merge (tree-sum; order-independent up to f32 rounding)."""
    u = jnp.sum(jnp.stack([p.u for p in parts]), axis=0)
    v = jnp.sum(jnp.stack([p.v for p in parts]), axis=0)
    return UV(u=u, v=v)


def from_uv(state: OSELMState, uv: UV, *, ridge: float = 0.0) -> OSELMState:
    """§4.2 step 5: P ← U⁻¹, β ← U⁻¹V — re-enter sequential training
    with the merged model."""
    p = invert_u(uv.u, ridge=ridge)
    beta = solve_beta(uv.u, uv.v, ridge=ridge)
    return state.replace(beta=beta, p=p)


@jax.jit
def cooperative_update(state: OSELMState, *remote: UV) -> OSELMState:
    """The full one-shot cooperative model update (§4.2 steps 2–5) as a
    single jitted call: local (U,V) + Σ remote (U,V) → merged state."""
    local = to_uv(state)
    merged = local
    for r in remote:
        merged = uv_add(merged, r)
    return from_uv(state, merged)
