"""Activation registry for ELM/OS-ELM hidden layers.

The paper (Table 3) uses Sigmoid for UAH-DriveSet and Identity for
HAR/MNIST. We register both plus the usual suspects so configs can name
them by string.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Activation = Callable[[jnp.ndarray], jnp.ndarray]

_REGISTRY: dict[str, Activation] = {
    "identity": lambda x: x,
    "sigmoid": lambda x: 1.0 / (1.0 + jnp.exp(-x)),
    "tanh": jnp.tanh,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "gelu": lambda x: 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3))),
    "silu": lambda x: x / (1.0 + jnp.exp(-x)),
}


def get_activation(name: str) -> Activation:
    try:
        return _REGISTRY[name]
    except KeyError as e:
        raise ValueError(f"unknown activation {name!r}; have {sorted(_REGISTRY)}") from e


def register_activation(name: str, fn: Activation) -> None:
    _REGISTRY[name] = fn
