"""OS-ELM — Online Sequential ELM (paper §3.3, Eqs. 9–13).

Sequential recursive-least-squares update of the output weight β with
state P = K⁻¹ where K accumulates Σ HᵀH:

    P_i = P_{i-1} − P_{i-1} Hᵢᵀ (I + Hᵢ P_{i-1} Hᵢᵀ)⁻¹ Hᵢ P_{i-1}
    β_i = β_{i-1} + P_i Hᵢᵀ (tᵢ − Hᵢ β_{i-1})

The paper fixes batch size k=1 so the k×k inverse becomes a scalar
reciprocal (§3.3 last paragraph) — `oselm_step_k1` is that fast path
and the shape targeted by the Pallas kernel (`repro.kernels.oselm_step`).

A low-cost exponential forgetting factor λ (ref [2]) is supported:
K_i = λ K_{i-1} + HᵀH  ⇔  P pre-scaled by 1/λ. λ=1 (paper default)
disables it.

``OSELMState`` is a registered pytree whose ``activation``/``forget``
fields are static aux data, so states scan/vmap/psum cleanly while the
activation name stays a Python string.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.elm import SLFNParams, hidden, invert_u, solve_beta


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class OSELMState:
    """Sequential training state. Arrays are pytree leaves; the
    activation name and forgetting factor are static metadata."""

    params: SLFNParams
    beta: jnp.ndarray   # (n_hidden, m)
    p: jnp.ndarray      # (n_hidden, n_hidden) = K⁻¹
    activation: str = dataclasses.field(default="sigmoid", metadata=dict(static=True))
    forget: float = dataclasses.field(default=1.0, metadata=dict(static=True))

    @property
    def n_hidden(self) -> int:
        return self.beta.shape[0]

    @property
    def n_out(self) -> int:
        return self.beta.shape[1]

    def replace(self, **kw) -> "OSELMState":
        return dataclasses.replace(self, **kw)


def init_oselm(
    params: SLFNParams,
    x0: jnp.ndarray,
    t0: jnp.ndarray,
    *,
    activation: str = "sigmoid",
    ridge: float = 0.0,
    forget: float = 1.0,
) -> OSELMState:
    """Eq. 13: P₀ = (H₀ᵀH₀)⁻¹, β₀ = P₀H₀ᵀt₀.

    The paper requires the initial chunk to have at least Ñ rows so that
    H₀ᵀH₀ is nonsingular; ``ridge`` relaxes that when needed.
    """
    h0 = hidden(params, x0, activation)
    u0 = h0.T @ h0
    p0 = invert_u(u0, ridge=ridge)
    beta0 = solve_beta(u0, h0.T @ t0, ridge=ridge)
    return OSELMState(params=params, beta=beta0, p=p0, activation=activation, forget=forget)


def oselm_step(state: OSELMState, x: jnp.ndarray, t: jnp.ndarray) -> OSELMState:
    """Eq. 12 for an arbitrary batch k (k×k solve)."""
    h = hidden(state.params, x, state.activation)  # (k, Ñ)
    p = state.p / state.forget
    k = h.shape[0]
    ph = p @ h.T                                     # (Ñ, k)
    s = jnp.eye(k, dtype=p.dtype) + h @ ph           # (k, k)
    gain = ph @ jnp.linalg.inv(s)                    # (Ñ, k)  — Kalman gain
    p_new = p - gain @ ph.T
    beta_new = state.beta + p_new @ h.T @ (t - h @ state.beta)
    return state.replace(beta=beta_new, p=p_new)


def oselm_step_k1(
    state: OSELMState,
    x: jnp.ndarray,
    t: jnp.ndarray,
    *,
    kernel: bool = False,
    interpret: bool = True,
) -> OSELMState:
    """k=1 fast path (paper's deployed configuration).

    The (I + hPhᵀ) inverse is a scalar reciprocal — no SVD/QRD. ``x`` and
    ``t`` are single samples shaped (n,) and (m,). ``kernel=True`` runs
    the step through the fused Pallas kernels
    (``repro.kernels.ops.oselm_step_k1_kernel``: hidden_proj +
    matmul_atb + rank1_add; interpret=True on CPU) — same dispatch
    convention as ``fleet_train(kernel=True)``.
    """
    if kernel:
        # lazy import: repro.kernels.ops itself imports this module
        from repro.kernels.ops import oselm_step_k1_kernel

        return oselm_step_k1_kernel(state, x, t, interpret=interpret)
    h = hidden(state.params, x[None, :], state.activation)[0]  # (Ñ,)
    p = state.p / state.forget
    ph = p @ h                                   # (Ñ,)
    denom = 1.0 + h @ ph                         # scalar
    p_new = p - jnp.outer(ph, ph) / denom
    err = t - h @ state.beta                     # (m,)
    beta_new = state.beta + jnp.outer(p_new @ h, err)
    return state.replace(beta=beta_new, p=p_new)


def oselm_predict(state: OSELMState, x: jnp.ndarray) -> jnp.ndarray:
    h = hidden(state.params, x, state.activation)
    return h @ state.beta


def oselm_loss(state: OSELMState, x: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """Per-sample MSE loss L(x,y) = 1/n Σ (xᵢ−yᵢ)² (paper's L)."""
    y = oselm_predict(state, x)
    return jnp.mean((t - y) ** 2, axis=-1)


@jax.jit
def _scan_train(state: OSELMState, xs: jnp.ndarray, ts: jnp.ndarray) -> OSELMState:
    def body(s, xt):
        x, t = xt
        return oselm_step_k1(s, x, t), None

    out, _ = jax.lax.scan(body, state, (xs, ts))
    return out


def oselm_train_sequential(
    state: OSELMState,
    xs: jnp.ndarray,
    ts: jnp.ndarray,
    *,
    kernel: bool = False,
    backend: str = "auto",
    interpret: bool | None = None,
) -> OSELMState:
    """Stream samples one at a time (k=1), jitted scan over the stream.

    ``kernel=True`` fuses the whole stream into one ingest-kernel call
    (``repro.kernels.fleet_ingest`` with a singleton device axis): the
    hidden projections batch into one matmul and (P, β) stay resident
    across the stream instead of round-tripping HBM per sample."""
    if kernel:
        from repro.kernels.fleet_ingest import fleet_ingest

        stacked = jax.tree.map(lambda leaf: leaf[None], state)
        out, _ = fleet_ingest(
            stacked, jnp.asarray(xs)[None], jnp.asarray(ts)[None],
            backend=backend, interpret=interpret,
        )
        return jax.tree.map(lambda leaf: leaf[0], out)
    return _scan_train(state, xs, ts)
