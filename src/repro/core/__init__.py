"""repro.core — the paper's primary contribution.

ELM / OS-ELM sequential training, the E²LM intermediate form, and the
one-shot cooperative model update (federated merge), plus the OS-ELM
autoencoder anomaly detector the paper deploys on edge devices.
"""
from repro.core.activations import get_activation, register_activation
from repro.core.autoencoder import (
    DetectorBank,
    ae_score,
    ae_train_step,
    ae_train_step_guarded,
    ae_train_stream,
    bank_score,
    bank_train_instance,
    init_autoencoder,
    make_bank,
)
from repro.core.e2lm import (
    UV,
    cooperative_update,
    from_uv,
    to_uv,
    uv_add,
    uv_replace,
    uv_sub,
    uv_sum,
)
from repro.core.elm import (
    ELMModel,
    SLFNParams,
    hidden,
    init_slfn,
    invert_u,
    predict_elm,
    solve_beta,
    train_elm,
)
from repro.core.oselm import (
    OSELMState,
    init_oselm,
    oselm_loss,
    oselm_predict,
    oselm_step,
    oselm_step_k1,
    oselm_train_sequential,
)

__all__ = [
    "get_activation", "register_activation",
    "DetectorBank", "ae_score", "ae_train_step", "ae_train_step_guarded",
    "ae_train_stream", "bank_score", "bank_train_instance",
    "init_autoencoder", "make_bank",
    "UV", "cooperative_update", "from_uv", "to_uv", "uv_add",
    "uv_replace", "uv_sub", "uv_sum",
    "ELMModel", "SLFNParams", "hidden", "init_slfn", "invert_u",
    "predict_elm", "solve_beta", "train_elm",
    "OSELMState", "init_oselm", "oselm_loss", "oselm_predict",
    "oselm_step", "oselm_step_k1", "oselm_train_sequential",
]
