"""Paper-fidelity scenario layer — workloads as streaming fleet feeds.

The paper's whole evaluation (§5) is three streaming anomaly-detection
workloads — a car-driving dataset, a human-activity dataset, and MNIST
— each run as a fleet of non-IID edge devices that train online, merge
cooperatively, and are scored on held-out anomalous concepts. The repo
has every *mechanism* (topology merges, fused ingest kernels, the
resident runtime with drift gating); ``ScenarioSpec`` is the layer that
turns a workload into something those mechanisms can run end-to-end:

- **per-device pattern assignment** — which normal concept(s) each
  device observes (round-robin "Device-A/B/C" homes, or Dirichlet user
  skew), restricted to the spec's ``normal_classes``;
- **normal/anomalous phases** — every device starts in its home
  (normal) phase; a ``drift_frac`` fraction switches mid-stream to a
  drift target drawn from the held-out anomaly pool, so the drifted
  concept is exactly what the eval protocol labels anomalous
  (``FleetStreams.phase_boundaries`` exposes the phase starts);
- **held-out anomaly pools** — ``anomaly_classes`` are carved out of
  the dataset (``class_subset`` remaps them after the homes), never
  appear in any training stream before a drift event, and form the
  positive class of the §5.3.1 eval arrays;
- **a tick feed** — the built scenario wraps its streams in the
  runtime's ``TickFeed`` so one spec drives ``FleetRuntime`` unchanged
  on every topology.

Three paper-analog presets are registered (``make_scenario``):
``driving`` (multi-regime correlated sensor channels — normal + drowsy
regimes home, the high-entropy aggressive regime held out), ``har``
(segmented activity windows with per-device Dirichlet user skew —
sitting/standing home, laying held out), and ``mnist_like``
(high-dimensional digit-pattern analog — digits 0–7 home, 8/9 held
out). The evaluation
harness on top lives in ``repro.scenarios.evaluate``; the headline
tables in ``benchmarks/paper_eval.py``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple

import numpy as np

from repro.data.pipeline import (
    anomaly_eval_arrays,
    class_subset,
    normalize_minmax,
    train_test_split,
)
from repro.data.synthetic import DATASETS, AnomalyDataset, make_dataset
from repro.fleet.faults import FaultInjector, FaultSpec
from repro.fleet.partition import (
    DriftEvent,
    FleetStreams,
    make_fleet_streams,
    random_drift_schedule,
)
from repro.runtime.detector import DetectorConfig

__all__ = [
    "SCENARIOS",
    "Scenario",
    "ScenarioSpec",
    "make_scenario",
]


@functools.lru_cache(maxsize=8)
def _raw_dataset(name: str, seed: int, samples_per_class: int) -> AnomalyDataset:
    """Synthesizing a dataset is the expensive part of a build (the
    driving analog runs a Markov simulator per sample); every consumer
    of the same (name, seed, size) shares one copy. Treated as
    immutable by the whole pipeline."""
    return make_dataset(name, seed=seed, samples_per_class=samples_per_class)


class Scenario(NamedTuple):
    """A built scenario: everything needed to drive a fleet end-to-end."""

    spec: "ScenarioSpec"
    train: AnomalyDataset     # remapped (homes 0.., anomalies after) + normalized
    test: AnomalyDataset
    streams: FleetStreams     # per-device non-IID streams + drift schedule
    x_eval: np.ndarray        # §5.3.1 eval arrays: trained patterns normal,
    y_eval: np.ndarray        # held-out anomaly pool positive

    @property
    def n_features(self) -> int:
        return self.train.n_features

    def feed(self, batch: int | None = None):
        """The runtime's tick view of the streams (fresh cursorless view
        per call; the default batch is the spec's)."""
        from repro.runtime.feed import TickFeed

        return TickFeed(self.streams, self.spec.batch if batch is None else batch)

    def init_fleet(self, key, **overrides):
        """The spec's stacked fleet (shared SLFN basis, per-device Eq. 13
        init chunks) — ``overrides`` forward to ``init_fleet``."""
        from repro.fleet.fleet import init_fleet

        kw = dict(
            activation=self.spec.activation,
            ridge=self.spec.ridge,
            forget=self.spec.forget,
        )
        kw.update(overrides)
        return init_fleet(
            key, self.spec.n_devices, self.n_features, self.spec.n_hidden,
            self.streams.x_init, **kw,
        )


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One workload as a streaming non-IID fleet feed.

    Class ids refer to the UNDERLYING dataset (``repro.data.synthetic``
    names/order); ``build`` remaps them so homes occupy 0..n_normal−1
    and the anomaly pool follows — downstream code never sees the
    original ids.
    """

    name: str
    dataset: str                              # repro.data.synthetic generator
    n_devices: int
    ticks: int
    batch: int = 2                            # samples per device per tick
    n_hidden: int = 16
    n_init: int | None = None                 # Eq. 13 chunk; default 2·n_hidden
    normal_classes: tuple[int, ...] = (0, 1)  # per-device home patterns
    anomaly_classes: tuple[int, ...] = (2,)   # held-out anomaly pool
    assignment: str = "round_robin"           # or "dirichlet" (user skew)
    alpha: float = 0.5                        # Dirichlet concentration
    drift_frac: float = 0.25                  # fraction of devices that drift
    drift_targets: tuple[int, ...] | None = None  # default: whole anomaly pool
    activation: str = "identity"
    ridge: float = 1e-3
    forget: float = 1.0                       # λ
    # scenario detector convention: skip the fresh fleet's convergence
    # transient, calibrate across the first cooperative merge (warmup 20
    # spans the merge-every-16 default, so the post-merge loss regime is
    # inside every device's band), and floor sigma at a fraction of the
    # baseline mean (near-pure-pattern devices calibrate microscopic
    # bands otherwise). Drift injection starts at tick ticks//4 — keep
    # warmup at or below that or early drifts are absorbed as baseline.
    detector: DetectorConfig = dataclasses.field(
        default_factory=lambda: DetectorConfig(
            warmup=20, warmup_skip=6, rel_sigma=0.25
        )
    )
    samples_per_class: int = 150
    anomaly_ratio: float = 0.3                # eval positives / negatives
    train_frac: float = 0.8                   # §5.3.1 split
    seed: int = 0
    # deterministic fault schedules (repro.fleet.faults) applied at the
    # payload boundary — Byzantine payloads, crashes, poisoned streams.
    # A tuple of frozen FaultSpecs keeps the spec hashable (the local-AUC
    # cache and jit static args depend on that).
    faults: tuple[FaultSpec, ...] = ()

    def __post_init__(self) -> None:
        if self.dataset not in DATASETS:
            raise ValueError(
                f"unknown dataset {self.dataset!r}; have {sorted(DATASETS)}"
            )
        for field, lo in (("n_devices", 1), ("ticks", 1), ("batch", 1),
                          ("n_hidden", 1), ("samples_per_class", 8)):
            if getattr(self, field) < lo:
                raise ValueError(f"need {field} >= {lo}, got {getattr(self, field)}")
        if not self.normal_classes:
            raise ValueError("need at least one normal (home) class")
        if not self.anomaly_classes:
            raise ValueError("need a non-empty held-out anomaly pool")
        for label, classes in (("normal", self.normal_classes),
                               ("anomaly", self.anomaly_classes)):
            if len(set(classes)) != len(classes):
                raise ValueError(f"duplicate {label} classes: {classes!r}")
        overlap = set(self.normal_classes) & set(self.anomaly_classes)
        if overlap:
            raise ValueError(
                f"anomaly pool must be held out of the training streams; "
                f"classes {sorted(overlap)} are in both"
            )
        if not 0.0 <= self.drift_frac <= 1.0:
            raise ValueError(f"need 0 <= drift_frac <= 1, got {self.drift_frac}")
        targets = self.drift_targets
        if targets is not None and not set(targets) <= set(self.anomaly_classes):
            raise ValueError(
                "drift targets must come from the held-out anomaly pool "
                f"(targets={targets!r}, pool={self.anomaly_classes!r}) — a "
                "drift into a home class would blur the normal/anomalous "
                "phase boundary the eval protocol scores against"
            )
        if self.assignment not in ("round_robin", "dirichlet"):
            raise ValueError(f"unknown assignment {self.assignment!r}")
        if not 0.0 < self.train_frac < 1.0:
            raise ValueError(f"need 0 < train_frac < 1, got {self.train_frac}")
        if not 0.0 < self.forget <= 1.0:
            raise ValueError(f"need 0 < forget <= 1, got {self.forget}")
        for fs in self.faults:
            if not isinstance(fs, FaultSpec):
                raise ValueError(
                    f"faults must be FaultSpec instances, got {type(fs).__name__}"
                )
            bad = [d for d in fs.devices if d >= self.n_devices]
            if bad:
                raise ValueError(
                    f"fault devices {bad} out of range for a "
                    f"{self.n_devices}-device scenario"
                )

    # ------------------------------------------------------------ derived

    @property
    def n_normal(self) -> int:
        return len(self.normal_classes)

    @property
    def steps(self) -> int:
        """Stream length: every tick ingests ``batch`` samples/device."""
        return self.ticks * self.batch

    @property
    def init_chunk(self) -> int:
        return 2 * self.n_hidden if self.n_init is None else self.n_init

    def remapped_anomaly_classes(self) -> tuple[int, ...]:
        """The anomaly pool's ids AFTER the build's class remap (homes
        first): n_normal, n_normal+1, ..."""
        return tuple(range(self.n_normal, self.n_normal + len(self.anomaly_classes)))

    def drift_schedule(self) -> tuple[DriftEvent, ...]:
        """The spec's reproducible drift injection: ``drift_frac`` of the
        fleet switches mid-stream to a held-out target (remapped ids)."""
        if self.drift_frac == 0.0:
            return ()
        targets = self.drift_targets or self.anomaly_classes
        remap = {c: self.n_normal + i for i, c in enumerate(self.anomaly_classes)}
        return random_drift_schedule(
            self.n_devices,
            self.steps,
            self.n_normal + len(self.anomaly_classes),
            frac=self.drift_frac,
            seed=self.seed + 1,
            home_classes=self.n_normal,
            targets=tuple(remap[t] for t in targets),
        )

    def fault_injector(self) -> FaultInjector | None:
        """The spec's resolved fault schedules (None when clean). Seeded
        by the spec seed, so victim choice is part of the scenario's
        reproducible identity."""
        if not self.faults:
            return None
        return FaultInjector(self.faults, self.n_devices, seed=self.seed)

    def fault_devices(self) -> tuple[int, ...]:
        """Byzantine device ids (payload/poison victims) — excluded from
        "honest fleet" AUC summaries the way drifted devices are."""
        inj = self.fault_injector()
        return () if inj is None else inj.byzantine_devices

    # -------------------------------------------------------------- build

    def build(self) -> Scenario:
        """Synthesize the workload into a runnable scenario: dataset →
        remap/normalize/split → non-IID streams with drift → eval
        arrays. Deterministic in the spec (same spec, same bits)."""
        ds = _raw_dataset(self.dataset, self.seed, self.samples_per_class)
        ds = class_subset(ds, self.normal_classes + self.anomaly_classes)
        ds = normalize_minmax(ds)
        train, test = train_test_split(ds, self.train_frac, seed=self.seed)
        streams = make_fleet_streams(
            train,
            self.n_devices,
            self.steps,
            n_init=self.init_chunk,
            assignment=self.assignment,
            alpha=self.alpha,
            drift=self.drift_schedule(),
            seed=self.seed,
            n_assign=self.n_normal,
        )
        x_eval, y_eval = anomaly_eval_arrays(
            test,
            list(range(self.n_normal)),
            anomaly_ratio=self.anomaly_ratio,
            seed=self.seed,
        )
        return Scenario(
            spec=self, train=train, test=test, streams=streams,
            x_eval=x_eval, y_eval=y_eval,
        )


# ------------------------------------------------------- paper-analog presets


def _driving_spec() -> ScenarioSpec:
    """UAH-DriveSet analog: 15×15 speed-transition tables from three
    correlated Markov driving regimes. Devices home on the normal and
    drowsy regimes; the high-entropy aggressive regime (volatile Markov
    dynamics → diffuse transition tables an AE trained on calm regimes
    cannot reconstruct) is held out, and a quarter of the fleet drifts
    into it mid-stream — exactly the concept the detector must flag."""
    return ScenarioSpec(
        name="driving", dataset="driving",
        n_devices=12, ticks=80,
        normal_classes=(0, 2),      # normal, drowsy
        anomaly_classes=(1,),       # aggressive — held out
        n_hidden=16, samples_per_class=160,
    )


def _har_spec() -> ScenarioSpec:
    """Smartphone-HAR analog: segmented activity windows with per-device
    user skew — each device draws its own Dirichlet mixture over the
    sitting / standing manifolds (the paper notes their similarity; no
    two users split alike), and the laying pattern (far from everything,
    Fig. 7/9) is the held-out anomaly concept."""
    return ScenarioSpec(
        name="har", dataset="har",
        n_devices=12, ticks=80,
        normal_classes=(3, 4),      # sitting, standing
        anomaly_classes=(5,),       # laying — held out
        assignment="dirichlet", alpha=0.5,
        n_hidden=16, samples_per_class=150,
    )


def _mnist_spec() -> ScenarioSpec:
    """MNIST analog: 784-dim digit-pattern streams from the smooth
    per-class prototypes. Digits 0–7 are the per-device home patterns
    (round-robin, the paper's Device-A/B/C setting scaled up); digits
    8/9 are the held-out anomaly pool. The drifted-digit loss elevation
    is brief (the k=1 RLS chain learns the new prototype within a few
    ticks), so the preset detector runs a faster EWMA and a tighter
    threshold than the scenario default."""
    return ScenarioSpec(
        name="mnist_like", dataset="mnist_like",
        n_devices=16, ticks=80,
        normal_classes=tuple(range(8)),
        anomaly_classes=(8, 9),
        n_hidden=32, samples_per_class=120,
        detector=DetectorConfig(
            warmup=20, warmup_skip=6, rel_sigma=0.25, alpha=0.6, k_sigma=3.5
        ),
    )


def _adversarial_spec() -> ScenarioSpec:
    """Byzantine fleet: the HAR workload with 10% of devices mounting a
    payload scale attack (×−25 — one such contribution swamps an honest
    neighborhood's Eq. 8 sum under the naive merge). The evaluation path
    auto-enables the robust merge for fault-carrying specs
    (``run_scenario(robust="auto")``), so this preset runs green through
    the same grid as the clean presets while ``benchmarks/robust_fleet``
    measures the naive arm's degradation against it."""
    return dataclasses.replace(
        _har_spec(),
        name="adversarial",
        faults=(FaultSpec(kind="scale", frac=0.1, magnitude=-25.0, seed=7),),
    )


SCENARIOS: dict[str, Callable[[], ScenarioSpec]] = {
    "driving": _driving_spec,
    "har": _har_spec,
    "mnist_like": _mnist_spec,
    "adversarial": _adversarial_spec,
}


def make_scenario(name: str, **overrides) -> ScenarioSpec:
    """A registered paper-analog spec, optionally resized/retuned —
    ``make_scenario("har", n_devices=6, ticks=40)`` is how the smoke
    harness shrinks the workloads without touching their structure."""
    try:
        base = SCENARIOS[name]()
    except KeyError as e:
        raise ValueError(f"unknown scenario {name!r}; have {sorted(SCENARIOS)}") from e
    return dataclasses.replace(base, **overrides) if overrides else base
