"""Shared scenario evaluation path — ONE scoring surface for every
paper-facing number.

Before this module, each benchmark re-implemented its own ad-hoc local
loop over ``ae_score``/``roc_auc`` (``rocauc_grid``'s per-pair grids,
``merge_loss``'s loss rows, ``serve_runtime``'s detection accounting).
They now all route through here, so a merge/ingest refactor that shifts
a paper-facing number fails in exactly one place:

- ``device_auc`` / ``fleet_aucs`` / ``bpnn_auc`` — the §5.3.1 protocol
  (trained patterns normal, held-out pool anomalous) for a single
  OS-ELM state, a stacked fleet, and the BP-NN baselines;
- ``pair_merge_eval`` / ``pattern_loss_rows`` — the two-device
  cooperative-update evaluations behind the paper's Figs. 6–17;
- ``detection_stats`` — drift detection delay / missed / false-positive
  accounting in the tick clock;
- ``run_scenario`` — a whole ``ScenarioSpec`` end-to-end through
  ``FleetRuntime`` on any topology: local (pre-merge) per-device AUC,
  post-merge AUC, merge cadence, comm bytes, detection stats.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.bpnn import BPNNConfig, bpnn_score
from repro.core import ae_score, cooperative_update, to_uv
from repro.data.metrics import roc_auc
from repro.data.pipeline import anomaly_eval_arrays
from repro.data.synthetic import AnomalyDataset
from repro.fleet.fleet import fleet_score, fleet_train
from repro.fleet.robust import RobustConfig
from repro.fleet.topology import Topology, make_topology
from repro.obs import TelemetryConfig
from repro.runtime.governor import GovernorConfig
from repro.runtime.runtime import FleetRuntime, RuntimeConfig, TickReport
from repro.scenarios.spec import ScenarioSpec

__all__ = [
    "ScenarioResult",
    "bpnn_auc",
    "detection_stats",
    "device_auc",
    "fleet_aucs",
    "pair_merge_eval",
    "pattern_loss_rows",
    "run_scenario",
    "scenario_topology",
]


# ------------------------------------------------------------ AUC primitives


def device_auc(
    state,
    test: AnomalyDataset,
    normal_patterns,
    *,
    anomaly_ratio: float = 0.1,
    seed: int = 0,
) -> float:
    """§5.3.1 ROC-AUC of one OS-ELM state: ``normal_patterns`` of
    ``test`` are negatives, every other class is subsampled positives."""
    x, y = anomaly_eval_arrays(
        test, list(normal_patterns), anomaly_ratio=anomaly_ratio, seed=seed
    )
    return roc_auc(np.asarray(ae_score(state, jnp.asarray(x))), y)


def fleet_aucs(
    states, x_eval: np.ndarray, y_eval: np.ndarray, *, nonfinite: str = "strict"
) -> np.ndarray:
    """Per-device AUC of a stacked fleet on shared eval arrays: (D,).

    ``nonfinite="coerce"`` scores a device whose model produces
    non-finite outputs as 0.5 (an unusable detector is a coin flip) —
    the honest way to chart how badly a NaN-poisoned naive merge
    degrades without the chart itself crashing. The default stays
    strict: clean paths treat non-finite scores as the bug they are."""
    scores = np.asarray(fleet_score(states, jnp.asarray(x_eval)))
    out = []
    for d in range(scores.shape[0]):
        if nonfinite == "coerce" and not np.isfinite(scores[d]).all():
            out.append(0.5)
        else:
            out.append(roc_auc(scores[d], y_eval))
    return np.asarray(out)


def bpnn_auc(
    params, cfg: BPNNConfig, x_eval: np.ndarray, y_eval: np.ndarray
) -> float:
    """The BP-NN baselines scored under the identical protocol."""
    return roc_auc(np.asarray(bpnn_score(params, cfg, jnp.asarray(x_eval))), y_eval)


# -------------------------------------------- two-device paper evaluations


def pair_merge_eval(
    dev_a,
    dev_b,
    test: AnomalyDataset,
    patterns: tuple[int, int],
    *,
    anomaly_ratio: float = 0.1,
    seed: int = 0,
) -> tuple[float, float]:
    """The Figs. 8–17 cell: Device-A's AUC before and after the one-shot
    cooperative update with Device-B, eval normals = both trained
    patterns. Returns ``(auc_before, auc_after)``."""
    before = device_auc(
        dev_a, test, patterns, anomaly_ratio=anomaly_ratio, seed=seed
    )
    merged = cooperative_update(dev_a, to_uv(dev_b))
    after = device_auc(
        merged, test, patterns, anomaly_ratio=anomaly_ratio, seed=seed
    )
    return before, after


def pattern_loss_rows(
    dev_a, dev_b, test: AnomalyDataset, *, limit: int = 64
) -> dict[str, dict[str, float]]:
    """The Figs. 6/7 bars: per-pattern mean reconstruction loss of
    Device-A before the merge, Device-B, and A after merging B."""
    merged = cooperative_update(dev_a, to_uv(dev_b))
    rows: dict[str, dict[str, float]] = {}
    for pat in test.class_names:
        x = jnp.asarray(test.pattern(pat)[:limit])
        rows[pat] = {
            "A_before": float(ae_score(dev_a, x).mean()),
            "B": float(ae_score(dev_b, x).mean()),
            "A_after": float(ae_score(merged, x).mean()),
        }
    return rows


# ------------------------------------------------------ detection accounting


def detection_stats(
    detections: list[tuple[int, int]],
    drift_ticks: dict[int, int],
    *,
    truncated_devices: frozenset[int] | set[int] = frozenset(),
) -> dict:
    """Detection-delay accounting in the tick clock: flags BEFORE a
    device's scheduled drift are false positives (they fired on a
    stationary stream); the first flag at/after it is the detection.

    ``truncated_devices`` (``TickFeed.truncated_drift_devices``) are
    devices whose scheduled drift fell entirely in the feed's truncated
    tail: their drift was never served, so a flag on them is neither a
    detection nor a false positive — they are excluded from every
    denominator and reported separately."""
    truncated = frozenset(truncated_devices)
    flags_by_dev: dict[int, list[int]] = {}
    for tick, dev in detections:
        flags_by_dev.setdefault(dev, []).append(tick)
    delays, missed, false_pos = [], [], []
    for dev, flagged in flags_by_dev.items():
        if dev in truncated:
            continue
        if dev not in drift_ticks or min(flagged) < drift_ticks[dev]:
            false_pos.append(dev)
    for dev, t0 in drift_ticks.items():
        post = [t for t in flags_by_dev.get(dev, []) if t >= t0]
        if post:
            delays.append(min(post) - t0)
        else:
            missed.append(dev)
    return {
        "n_drift_events": len(drift_ticks),
        "delays": sorted(delays),
        "delay_mean": float(np.mean(delays)) if delays else None,
        "delay_max": int(np.max(delays)) if delays else None,
        "missed": sorted(missed),
        "false_positives": sorted(false_pos),
        "truncated_drift_devices": sorted(truncated),
    }


# --------------------------------------------------- scenario → FleetRuntime


def scenario_topology(name: str, n_devices: int, **kw) -> Topology:
    """A topology sized to a scenario's fleet. Ring defaults to the
    minimal ±1 gossip band (the paper-eval comm comparisons quote it)."""
    if name == "ring":
        kw.setdefault("hops", 1)
    return make_topology(name, n_devices, **kw)


@dataclasses.dataclass(frozen=True)
class ScenarioResult:
    """One scenario × topology, end-to-end through the runtime."""

    spec: ScenarioSpec
    topology: str
    local_aucs: np.ndarray      # (D,) stream-trained only — pre-merge
    merged_aucs: np.ndarray     # (D,) after the runtime's cooperative updates
    merges: int
    comm_bytes: int             # governor ledger: bytes the merges shipped
    detection: dict             # detection_stats output
    reports: list[TickReport]
    jit_cache_sizes: dict[str, int]
    payload_precision: str = "f32"   # wire format the merges shipped at
    robust: RobustConfig | None = None  # robust-merge config the run used
    telemetry: dict | None = None    # TelemetrySink.summary() of the run
                                     # (None when telemetry was off)

    @property
    def clean_devices(self) -> list[int]:
        """Honest, non-drifted devices — the fleet whose AUC the locks
        and the robustness claims are stated over (Byzantine devices'
        own models are the attacker's problem)."""
        drifted = {ev.device for ev in self.spec.drift_schedule()}
        drifted |= set(self.spec.fault_devices())
        return [d for d in range(self.spec.n_devices) if d not in drifted]

    def auc_summary(self) -> dict[str, float]:
        clean = self.clean_devices
        return {
            "local_auc_mean": float(self.local_aucs.mean()),
            "merged_auc_mean": float(self.merged_aucs.mean()),
            "merged_auc_min": float(self.merged_aucs.min()),
            "clean_merged_auc_mean": float(self.merged_aucs[clean].mean()),
        }


# local (no-cooperation) baselines are topology-independent: cache them
# per (spec, key_seed) so a topology grid trains the baseline fleet once
_LOCAL_AUC_CACHE: dict[tuple[ScenarioSpec, int], np.ndarray] = {}


def _local_aucs(sc, key, key_seed: int) -> np.ndarray:
    cache_key = (sc.spec, key_seed)
    if cache_key not in _LOCAL_AUC_CACHE:
        if len(_LOCAL_AUC_CACHE) > 32:
            _LOCAL_AUC_CACHE.clear()
        local = fleet_train(sc.init_fleet(key), jnp.asarray(sc.streams.xs))
        _LOCAL_AUC_CACHE[cache_key] = fleet_aucs(local, sc.x_eval, sc.y_eval)
    return _LOCAL_AUC_CACHE[cache_key]


def run_scenario(
    spec: ScenarioSpec,
    topology: str = "ring",
    *,
    topology_kwargs: dict | None = None,
    merge_every: int = 16,
    gate_merges: bool = True,
    use_merge_kernel: bool = False,
    use_ingest_kernel: bool = False,
    ingest_backend: str = "auto",
    payload_precision: str = "f32",
    key_seed: int = 0,
    scenario=None,
    robust: RobustConfig | str | None = "auto",
    telemetry: TelemetryConfig | None = None,
) -> ScenarioResult:
    """Drive one built scenario end-to-end through ``FleetRuntime``.

    Two numbers bracket the paper's claim: ``local_aucs`` (the same
    initial fleet trained on the same streams with NO cooperation — the
    "before" column) and ``merged_aucs`` (the runtime's tick loop with
    governed cooperative updates — the "after" column). Both fleets
    share the init key, so the delta is the merges.

    ``scenario`` accepts the pre-built ``spec.build()`` so a topology
    grid shares one stream synthesis; the local baseline is likewise
    cached per (spec, key_seed) across topologies.

    ``robust`` selects the merge's Byzantine defense: ``"auto"``
    (default) enables a parameter-free trimmed merge exactly when the
    spec carries fault schedules — clean presets keep the existing
    bit-exact merge path and their golden locks; pass an explicit
    ``RobustConfig`` to force it, or ``None`` to run fault-carrying
    specs through the naive merge (the degradation baseline
    ``benchmarks/robust_fleet.py`` measures).

    ``telemetry`` threads a ``repro.obs.TelemetryConfig`` into the
    runtime; the finalized ``TelemetrySink.summary()`` rides back on
    ``ScenarioResult.telemetry`` so benchmarks can cross-check their
    ledger-derived numbers against the instrumented ones."""
    sc = spec.build() if scenario is None else scenario
    key = jax.random.PRNGKey(key_seed)
    topo = scenario_topology(topology, spec.n_devices, **(topology_kwargs or {}))
    if robust == "auto":
        robust = RobustConfig(trim=1) if spec.faults else None
    rt = FleetRuntime(
        sc.init_fleet(key),
        RuntimeConfig(
            topology=topo,
            ridge=spec.ridge,
            detector=spec.detector,
            governor=GovernorConfig(merge_every=merge_every),
            gate_merges=gate_merges,
            use_merge_kernel=use_merge_kernel,
            use_ingest_kernel=use_ingest_kernel,
            ingest_backend=ingest_backend,
            payload_precision=payload_precision,
            robust=robust,
            faults=spec.fault_injector(),
            telemetry=telemetry,
        ),
    )
    feed = sc.feed()
    reports = rt.run(feed)
    merged_aucs = fleet_aucs(
        rt.states, sc.x_eval, sc.y_eval,
        nonfinite="coerce" if spec.faults else "strict",
    )
    local_aucs = _local_aucs(sc, key, key_seed)

    return ScenarioResult(
        spec=spec,
        topology=topo.name,
        local_aucs=local_aucs,
        merged_aucs=merged_aucs,
        merges=rt.governor.state.merges,
        comm_bytes=rt.governor.state.bytes_spent,
        detection=detection_stats(
            rt.detections, feed.drift_ticks(),
            truncated_devices=feed.truncated_drift_devices,
        ),
        reports=reports,
        jit_cache_sizes=rt.assert_compile_once(),
        payload_precision=payload_precision,
        robust=robust,
        telemetry=rt.finalize_telemetry(),
    )
