"""repro.scenarios — paper-fidelity workloads as streaming fleet feeds.

``ScenarioSpec`` turns a workload (dataset + per-device pattern
assignment + normal/anomalous phases + drift injection + held-out
anomaly pool) into a runnable scenario that drives ``FleetRuntime``
end-to-end on any topology; three paper-analog presets (``driving``,
``har``, ``mnist_like``) mirror the paper's §5 evaluation. The shared
evaluation path (``repro.scenarios.evaluate``) is the single scoring
surface every paper-facing benchmark routes through.
"""
from repro.scenarios.evaluate import (
    ScenarioResult,
    bpnn_auc,
    detection_stats,
    device_auc,
    fleet_aucs,
    pair_merge_eval,
    pattern_loss_rows,
    run_scenario,
    scenario_topology,
)
from repro.scenarios.spec import SCENARIOS, Scenario, ScenarioSpec, make_scenario

__all__ = [
    "SCENARIOS", "Scenario", "ScenarioSpec", "make_scenario",
    "ScenarioResult", "bpnn_auc", "detection_stats", "device_auc",
    "fleet_aucs", "pair_merge_eval", "pattern_loss_rows", "run_scenario",
    "scenario_topology",
]
