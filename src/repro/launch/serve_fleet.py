"""Async fleet-ingress driver: concurrent clients → ServeFrontend →
resident FleetRuntime.

The runnable face of the serving-under-load stack (README "Serving
under load"): N synthetic clients stream per-device sample bursts
through the deadline batcher, the admission controller applies
backpressure, and the run exits with the ingress summary — accepted /
acked / shed / deferred, admission and request latency percentiles —
from the runtime's own telemetry sink.

    PYTHONPATH=src python -m repro.launch.serve_fleet \
        --devices 64 --batch 2 --requests 2000 --clients 8

    # durable mode: snapshots + write-ahead log, resumable after a kill
    PYTHONPATH=src python -m repro.launch.serve_fleet \
        --devices 64 --snapshot-dir /tmp/fleet-snap --wal-dir /tmp/fleet-wal
"""
from __future__ import annotations

import argparse
import asyncio
import json

import jax
import numpy as np

from repro.fleet import init_fleet, ring
from repro.obs import TelemetryConfig
from repro.runtime import FleetRuntime, GovernorConfig, RuntimeConfig
from repro.serve import (
    AdmissionConfig,
    SampleRequest,
    ServeConfig,
    ServeFrontend,
)


def build_frontend(args) -> tuple[FleetRuntime, ServeFrontend]:
    rng = np.random.default_rng(args.seed)
    d, f, h = args.devices, args.features, args.hidden
    x_init = rng.normal(size=(d, 2 * h, f)).astype(np.float32)
    fleet = init_fleet(
        jax.random.PRNGKey(args.seed), d, f, h, x_init,
        activation="identity", ridge=1e-3,
    )
    runtime = FleetRuntime(fleet, RuntimeConfig(
        topology=ring(d, hops=2),
        governor=GovernorConfig(merge_every=args.merge_every),
        snapshot_every=args.snapshot_every if args.snapshot_dir else None,
        snapshot_dir=args.snapshot_dir,
        telemetry=TelemetryConfig(dir=args.telemetry_dir),
    ))
    frontend = ServeFrontend(runtime, ServeConfig(
        batch=args.batch,
        max_delay_s=args.max_delay_ms / 1e3,
        admission=AdmissionConfig(
            slo_p99_s=args.slo_ms / 1e3 if args.slo_ms else None,
        ),
        wal_dir=args.wal_dir,
        seed=args.seed,
    ), fallback=x_init[:, -1, :])
    return runtime, frontend


async def run_clients(frontend: ServeFrontend, args) -> list:
    rng = np.random.default_rng(args.seed + 1)
    per_client = -(-args.requests // args.clients)

    async def client(c: int) -> list:
        acks = []
        for i in range(per_client):
            dev = int(rng.integers(args.devices))
            x = rng.normal(size=(1, args.features)).astype(np.float32)
            acks.append(await frontend.submit_with_retries(
                SampleRequest(device=dev, x=x, client=f"client-{c}")
            ))
        return acks

    nested = await asyncio.gather(*[client(c) for c in range(args.clients)])
    return [a for acks in nested for a in acks]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--devices", type=int, default=64)
    ap.add_argument("--features", type=int, default=16)
    ap.add_argument("--hidden", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2,
                    help="per-device samples per tick window")
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--merge-every", type=int, default=16)
    ap.add_argument("--max-delay-ms", type=float, default=5.0)
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="tick p99 SLO driving admission backpressure")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--snapshot-dir", default=None)
    ap.add_argument("--snapshot-every", type=int, default=64)
    ap.add_argument("--wal-dir", default=None)
    ap.add_argument("--recover", action="store_true",
                    help="restore newest snapshot + replay WAL before serving")
    ap.add_argument("--telemetry-dir", default=None)
    args = ap.parse_args()
    for name in ("devices", "batch", "requests", "clients", "merge_every"):
        if getattr(args, name) < 1:
            ap.error(f"--{name.replace('_', '-')} must be >= 1 "
                     f"(got {getattr(args, name)})")
    if args.recover and not args.snapshot_dir:
        ap.error("--recover requires --snapshot-dir")

    runtime, frontend = build_frontend(args)
    if args.recover:
        restored, replayed = frontend.recover()
        print(f"recovered: tick {restored} + {replayed} replayed windows")

    async def serve() -> list:
        await frontend.start()
        try:
            return await run_clients(frontend, args)
        finally:
            await frontend.stop()

    acks = asyncio.run(serve())
    by_status: dict[str, int] = {}
    for a in acks:
        by_status[a.status] = by_status.get(a.status, 0) + 1
    summary = runtime.finalize_telemetry()
    print(json.dumps({
        "acks": by_status,
        "ticks": runtime.tick_no,
        "merges": runtime.governor.state.merges,
        "ingress": summary["ingress"],
    }, indent=2, default=str))


if __name__ == "__main__":
    main()
