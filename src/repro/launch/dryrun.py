"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture × input shape) combination on the
production meshes — (16,16) single-pod and (2,16,16) multi-pod — using
ShapeDtypeStruct stand-ins (no allocation), printing memory_analysis()
and cost_analysis() and dumping per-combo JSON roofline artifacts to
``artifacts/dryrun/``.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices so
# jax.make_mesh can build the production mesh. jax locks the device
# count on first init, so these MUST be the first two lines — before any
# other import, including `from repro...`.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "").replace(
        "--xla_force_host_platform_device_count=512", ""
    )
).strip()

import argparse
import gzip
import json
import math
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.configs.granite_3_2b import SWA_VARIANT as GRANITE_SWA
from repro.launch.mesh import data_axes, make_production_mesh
from repro.launch.sharding import (
    batch_specs,
    cache_specs_sharding,
    opt_state_specs,
    param_specs,
)
from repro.launch.steps import (
    make_decode_step,
    make_optimizer,
    make_prefill_step,
    make_train_step,
)
from repro.models import init_params, input_specs
from repro.models.config import INPUT_SHAPES, ArchConfig, ShapeConfig
from repro.models.partitioning import use_mesh
from repro.roofline import roofline_from_compiled

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def model_flops(cfg: ArchConfig, shape: ShapeConfig, n_params: int, n_active: int) -> float:
    """MODEL_FLOPS = 6·N·D (training) / 2·N·D (inference) with N = active
    params; D = processed tokens."""
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch * 1  # decode: one token


def _effective_cfg(arch: str, shape_name: str) -> ArchConfig:
    cfg = GRANITE_SWA if (arch == "granite-3-2b" and shape_name == "long_500k") else get_config(arch)
    return cfg


def combo_supported(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.supports_long_decode():
        return False, "full-attention arch; no sub-quadratic variant (DESIGN.md §5)"
    return True, ""


def lower_combo(arch: str, shape_name: str, mesh, *, verbose: bool = True):
    """Build, lower and compile one (arch × shape × mesh) program.

    Returns (compiled, meta) — meta carries model-FLOPs bookkeeping.
    """
    cfg = _effective_cfg(arch, shape_name)
    shape = INPUT_SHAPES[shape_name]
    dp = data_axes(mesh)

    params_struct = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    p_specs = param_specs(params_struct, mesh)
    n_params = sum(math.prod(x.shape) for x in jax.tree.leaves(params_struct))
    # active params from struct: reuse counting on shapes
    if cfg.n_experts:
        expert = 0
        for kind in ("moe", "arctic"):
            st = params_struct["layers"].get(kind)
            if st is not None and "moe" in st:
                for nm in ("w_gate", "w_up", "w_down"):
                    expert += math.prod(st["moe"][nm].shape)
        n_active = int(n_params - expert * (1 - cfg.experts_per_token / cfg.n_experts))
    else:
        n_active = n_params

    specs_in = input_specs(cfg, shape)

    def ns(tree):  # PartitionSpec tree -> NamedSharding tree (jit API needs it)
        return jax.tree.map(
            lambda sp: NamedSharding(mesh, sp),
            tree,
            is_leaf=lambda x: isinstance(x, P),
        )

    if shape.kind == "train":
        opt = make_optimizer(cfg)
        opt_struct = jax.eval_shape(opt.init, params_struct)
        o_specs = opt_state_specs(opt_struct, p_specs)
        step = make_train_step(cfg, opt)
        b_specs = batch_specs("train", dp, mesh, cfg)
        batch_struct = {k: specs_in[k] for k in b_specs}
        fn = jax.jit(
            step,
            in_shardings=(ns(p_specs), ns(o_specs), ns(b_specs)),
            out_shardings=(ns(p_specs), ns(o_specs), None),
        )
        with use_mesh(mesh, dp):
            lowered = fn.lower(params_struct, opt_struct, batch_struct)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg)
        b_specs = batch_specs("prefill", dp, mesh, cfg)
        batch_struct = {k: specs_in[k] for k in b_specs}
        fn = jax.jit(step, in_shardings=(ns(p_specs), ns(b_specs)), out_shardings=None)
        with use_mesh(mesh, dp):
            lowered = fn.lower(params_struct, batch_struct)
    else:  # decode
        step = make_decode_step(cfg, max_seq=shape.seq_len)
        cache_struct = specs_in["caches"]
        shard_seq = shape.name == "long_500k"  # batch=1 → context parallelism
        c_specs = cache_specs_sharding(cache_struct, mesh, dp, shard_seq=shard_seq)
        tok_spec = P(dp) if shape.global_batch % _dp_size(mesh, dp) == 0 else P()
        args = [params_struct, specs_in["token"], cache_struct, specs_in["pos"]]
        shard = [p_specs, tok_spec, c_specs, P()]
        if cfg.frontend is not None:
            args.append(specs_in["enc_out"])
            shard.append(P(dp, None, None) if shape.global_batch % _dp_size(mesh, dp) == 0 else P())
        fn = jax.jit(step, in_shardings=tuple(ns(sh) for sh in shard), out_shardings=None)
        with use_mesh(mesh, dp):
            lowered = fn.lower(*args)

    t0 = time.time()
    compiled = lowered.compile()
    dt = time.time() - t0
    if verbose:
        print(f"  compiled in {dt:.1f}s")
        print("  memory_analysis:", compiled.memory_analysis())
    meta = {
        "n_params": n_params,
        "n_active": n_active,
        "model_flops": model_flops(cfg, shape, n_params, n_active),
        "compile_s": dt,
    }
    return compiled, meta


def _dp_size(mesh, dp_axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in dp_axes:
        n *= sizes[a]
    return n


def run_combo(arch: str, shape_name: str, *, multi_pod: bool, out_dir: Path = ARTIFACTS,
              tag: str = "") -> dict | None:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cfg = _effective_cfg(arch, shape_name)
    shape = INPUT_SHAPES[shape_name]
    ok, why = combo_supported(cfg, shape)
    label = f"{arch} × {shape_name} × {mesh_name}"
    suffix = f"-{tag}" if tag else ""
    if not ok:
        print(f"SKIP {label}: {why}")
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": why}
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{arch}--{shape_name}--{mesh_name}{suffix}.json").write_text(
            json.dumps(rec, indent=1)
        )
        return rec
    print(f"LOWER {label}")
    try:
        compiled, meta = lower_combo(arch, shape_name, mesh)
    except Exception as e:
        traceback.print_exc()
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "failed", "error": f"{type(e).__name__}: {e}"}
    chips = int(jnp.prod(jnp.asarray(mesh.devices.shape)))
    report = roofline_from_compiled(
        compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
        chips=chips, model_flops=meta["model_flops"],
    )
    rec = {"status": "ok", **report.to_dict(), **meta}
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    rec["cost_analysis"] = {k: float(v) for k, v in cost.items() if isinstance(v, (int, float))}
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"-{tag}" if tag else ""
    stem = f"{arch}--{shape_name}--{mesh_name}{suffix}"
    out = out_dir / f"{stem}.json"
    # persist the optimized HLO so roofline metrics can be re-derived
    # offline without recompiling (gzip: ~10x smaller)
    with gzip.open(out_dir / f"{stem}.hlo.gz", "wt") as f:
        f.write(compiled.as_text())
    out.write_text(json.dumps(rec, indent=1))
    print(
        f"  FLOPs={report.hlo_flops:.3e} bytes={report.hlo_bytes:.3e} "
        f"coll={report.coll_bytes:.3e} dominant={report.dominant} "
        f"useful={report.useful_flops_ratio:.2f}"
    )
    return rec


def reanalyze(out_dir: Path = ARTIFACTS) -> None:
    """Re-derive roofline metrics from saved HLO (no recompilation)."""
    from repro.roofline.analysis import RooflineReport
    from repro.roofline.hlo_costs import analyze_hlo

    for jf in sorted(out_dir.glob("*.json")):
        rec = json.loads(jf.read_text())
        if rec.get("status") != "ok":
            continue
        hf = jf.with_suffix("").with_suffix("")  # strip .json
        hf = jf.parent / (jf.stem + ".hlo.gz")
        if not hf.exists():
            continue
        with gzip.open(hf, "rt") as f:
            walk = analyze_hlo(f.read())
        chips = rec["chips"]
        report = RooflineReport(
            arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"], chips=chips,
            hlo_flops=walk["flops"] * chips, hlo_bytes=walk["bytes"] * chips,
            attn_interior_bytes=walk.get("bytes_attn_interior", 0.0) * chips,
            coll_bytes=walk["collective_bytes"] * chips,
            coll_breakdown={k: v * chips for k, v in walk["collectives"].items()},
            model_flops=rec["model_flops"],
            per_device_memory=rec.get("per_device_memory", {}),
        )
        rec.update(report.to_dict())
        jf.write_text(json.dumps(rec, indent=1))
        print(f"reanalyzed {jf.name}: dominant={report.dominant} "
              f"useful={report.useful_flops_ratio:.2f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--reanalyze", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    if args.reanalyze:
        reanalyze()
        return

    combos: list[tuple[str, str]] = []
    if args.all:
        for a in sorted(ARCHS):
            for s in sorted(INPUT_SHAPES):
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    results = []
    for multi_pod in meshes:
        for arch, shape in combos:
            mesh_name = "2x16x16" if multi_pod else "16x16"
            out = ARTIFACTS / f"{arch}--{shape}--{mesh_name}{('-' + args.tag) if args.tag else ''}.json"
            if args.skip_existing and out.exists():
                print(f"EXISTS {arch} × {shape} × {mesh_name}")
                continue
            results.append(run_combo(arch, shape, multi_pod=multi_pod, tag=args.tag))
    failed = [r for r in results if r and r.get("status") == "failed"]
    print(f"\n{len([r for r in results if r and r['status'] == 'ok'])} ok, "
          f"{len(failed)} failed, "
          f"{len([r for r in results if r and r['status'] == 'skipped'])} skipped")
    if failed:
        for f in failed:
            print("FAILED:", f["arch"], f["shape"], f["mesh"], f["error"][:200])
        raise SystemExit(1)


if __name__ == "__main__":
    main()
