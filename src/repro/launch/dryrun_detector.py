"""Dry-run of the paper's own program: the federated OS-ELM detector
step (local batch update + one-shot psum cooperative merge) on the
production meshes.

This is the mesh-scale version of the paper's Table-4 merge cost: the
exchanged payload per device is U (Ñ×Ñ) + V (Ñ×m) floats regardless of
how much data each shard trained on — compare with the gradient
all-reduce of any of the 10 LM architectures, which moves the full
parameter size every step.

    PYTHONPATH=src python -m repro.launch.dryrun_detector [--multi-pod]
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "").replace(
        "--xla_force_host_platform_device_count=512", ""
    )
).strip()

import argparse
import gzip
import json

import jax
import jax.numpy as jnp

from repro.core import init_oselm, init_slfn
from repro.launch.dryrun import ARTIFACTS
from repro.launch.mesh import data_axes, make_production_mesh
from repro.launch.steps import make_detector_step
from repro.roofline import roofline_from_compiled


def run(multi_pod: bool, *, d_model: int = 4096, n_hidden: int = 128, k: int = 256):
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    dp = data_axes(mesh)
    n_shards = 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for a in dp:
        n_shards *= sizes[a]

    params = init_slfn(jax.random.PRNGKey(0), d_model, n_hidden)
    warm = jax.random.normal(jax.random.PRNGKey(1), (2 * n_hidden, d_model))
    st = init_oselm(params, warm, warm, activation="identity", ridge=1e-2)
    st_struct = jax.eval_shape(lambda s: s, st)
    stacked = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((n_shards, *l.shape), l.dtype), st_struct
    )
    feats = jax.ShapeDtypeStruct((n_shards, k, d_model), jnp.float32)

    step = make_detector_step(mesh, dp, merge=True, ridge=1e-2)
    lowered = step.lower(stacked, feats)
    compiled = lowered.compile()
    print(f"detector × {mesh_name}: compiled")
    print("  memory_analysis:", compiled.memory_analysis())
    # per-merge exchanged payload (the paper's communication cost):
    payload = 4 * (n_hidden * n_hidden + n_hidden * d_model)
    report = roofline_from_compiled(
        compiled, arch="oselm-detector", shape=f"batch{k}_d{d_model}",
        mesh_name=mesh_name, chips=int(jnp.prod(jnp.asarray(mesh.devices.shape))),
        # detector model FLOPs: hidden proj + batch-k RLS update + merge solve
        model_flops=float(n_shards) * (
            2 * k * d_model * n_hidden            # H = xα
            + 2 * k * n_hidden * n_hidden * 2     # PHᵀ, gain
            + 2 * n_hidden ** 3 / 3 * 2           # U⁻¹ via Cholesky + solve
        ),
    )
    rec = {"status": "ok", **report.to_dict(), "uv_payload_bytes": payload}
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    stem = f"oselm-detector--batch{k}_d{d_model}--{mesh_name}"
    with gzip.open(ARTIFACTS / f"{stem}.hlo.gz", "wt") as f:
        f.write(compiled.as_text())
    (ARTIFACTS / f"{stem}.json").write_text(json.dumps(rec, indent=1))
    print(
        f"  FLOPs={report.hlo_flops:.3e} bytes={report.hlo_bytes:.3e} "
        f"coll={report.coll_bytes:.3e} ({payload} B U/V payload per device) "
        f"dominant={report.dominant}"
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true")
    args = ap.parse_args()
    for mp in ([False, True] if args.both else [args.multi_pod]):
        run(mp)


if __name__ == "__main__":
    main()
