"""Production mesh definition (deliverable e).

Single pod: (16, 16) = ("data", "model") — 256 v5e chips.
Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips; the
"pod" axis is pure data parallelism across pods (weights replicated
over it, gradients and the paper's (U, V) merge all-reduced over it).

Defined as FUNCTIONS so importing this module never touches jax device
state (jax locks the device count on first backend init — dryrun.py
must set XLA_FLAGS before any jax usage).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int | None = None, n_model: int = 1):
    """Small mesh over however many host devices exist (tests/examples)."""
    n = len(jax.devices())
    n_data = n_data or (n // n_model)
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """The federation/batch axes: ("pod","data") on multi-pod meshes."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
