"""Batched serving driver: continuous request loop with KV caches and
the paper's OS-ELM drift monitor scoring every batch.

The monitor is the resident runtime's sequential detector
(``repro.runtime.detector``) run at n_devices=1: the OS-ELM
autoencoder is warmed up on the first batch's features BEFORE any
score is taken (an untrained detector's round-0 score is
meaningless), every round's features are scored exactly once against
the current detector, and the EWMA/threshold detector turns the raw
score trajectory into an explicit DETECTED flag.

With ``--telemetry-dir`` the loop emits through a ``repro.obs``
``TelemetrySink``: per-round latency/score series as spans in
``trace.jsonl``, round counters and the drift gauge in
``exposition.txt``, and a summary line at exit.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --rounds 4 --batch 4 --prompt-len 64 --new-tokens 16

``--fleet`` switches to the async fleet ingress driver
(``repro.launch.serve_fleet``): concurrent synthetic clients streaming
per-device samples through a ``ServeFrontend`` in front of a resident
``FleetRuntime`` — the serving-under-load path the README documents.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import ae_score, ae_train_stream, init_autoencoder, oselm_step
from repro.models import decode_step, encoder_forward, init_params, prefill
from repro.obs import TelemetryConfig, TelemetrySink
from repro.runtime import DetectorConfig, detector_update, init_detector


def main() -> None:
    if "--fleet" in sys.argv[1:]:
        # the async fleet-ingress driver owns its own arg surface
        from repro.launch.serve_fleet import main as fleet_main

        sys.argv.remove("--fleet")
        fleet_main()
        return
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--drift-round", type=int, default=-1,
                    help="inject a shifted-distribution batch at this round")
    ap.add_argument("--seed", type=int, default=0,
                    help="base PRNG seed (prompts, params, drift injection)")
    ap.add_argument("--telemetry-dir", default=None,
                    help="emit trace.jsonl/exposition.txt into this directory")
    args = ap.parse_args()
    # a zero-round or zero-batch run would exit silently green — make
    # the misconfiguration loud instead
    if args.rounds < 1:
        ap.error(f"--rounds must be >= 1 (got {args.rounds}): a zero-round "
                 "serving loop does nothing")
    if args.batch < 1:
        ap.error(f"--batch must be >= 1 (got {args.batch}): every round "
                 "serves at least one request")

    sink = (
        TelemetrySink(TelemetryConfig(dir=args.telemetry_dir))
        if args.telemetry_dir else None
    )
    if sink is not None:
        rounds_total = sink.registry.counter(
            "serve_rounds_total", "serving rounds completed"
        )
        round_seconds = sink.registry.histogram(
            "serve_round_seconds", "wall-clock per serving round"
        )
        tokens_total = sink.registry.counter(
            "serve_tokens_total", "tokens decoded"
        )
        drift_score = sink.registry.gauge(
            "serve_drift_score", "monitor's latest mean ae_score"
        )
        drift_flags = sink.registry.counter(
            "serve_drift_flags_total", "rounds the monitor flagged"
        )

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    B, S = args.batch, args.prompt_len
    max_seq = S + args.new_tokens
    drift_round = args.drift_round if args.drift_round >= 0 else args.rounds - 1

    fe = None
    if cfg.frontend is not None:
        fe = jax.random.normal(key, (B, cfg.n_frontend_tokens, cfg.d_frontend))
    enc_out = encoder_forward(params, cfg, fe) if fe is not None else None

    prefill_fn = jax.jit(
        lambda p, t, f: prefill(p, cfg, t, frontend=f, cache_len=max_seq)
    )
    decode_fn = jax.jit(
        lambda p, t, c, pos, e: decode_step(p, cfg, t, c, pos, enc_out=e, max_seq=max_seq)
    )

    # Warm up the monitor BEFORE the serving loop: prefill a couple of
    # in-distribution batches the loop will never serve, and train the
    # detector on their features. Round 0 is then scored OUT-of-sample
    # against a calibrated detector — previously the first round scored
    # the very features the detector had just been initialized on, so
    # the round-0 "drift score" was trivially ~0 and poisoned the
    # monitor's baseline.
    warm_feats = []
    for w in range(2):
        kw = jax.random.fold_in(key, 10_000 + w)  # disjoint from round keys
        wp = jax.random.randint(kw, (B, S), 0, cfg.vocab)
        _, _, f = prefill_fn(params, wp, fe)
        warm_feats.append(f)
    warm = jnp.concatenate(warm_feats)
    detector = init_autoencoder(
        jax.random.fold_in(key, 7), cfg.d_model, cfg.detector_hidden,
        jnp.tile(warm, (2 * cfg.detector_hidden // warm.shape[0] + 1, 1)),
        activation="identity", ridge=1e-2,
    )
    detector = ae_train_stream(detector, warm)

    monitor = init_detector(1)
    mon_cfg = DetectorConfig(alpha=0.7, k_sigma=4.0, warmup=2, patience=1)
    for rnd in range(args.rounds):
        k = jax.random.fold_in(key, rnd)
        prompts = jax.random.randint(k, (B, S), 0, cfg.vocab)
        if rnd == drift_round:  # distribution shift: permuted vocabulary
            prompts = (prompts * 31 + 17) % cfg.vocab

        t0 = time.time()
        span = (
            sink.span("serve_round", round=rnd)
            if sink is not None else contextlib.nullcontext()
        )
        with span:
            logits, caches, features = prefill_fn(params, prompts, fe)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            for i in range(args.new_tokens):
                logits, caches = decode_fn(
                    params, tok, caches, jnp.asarray(S + i, jnp.int32), enc_out
                )
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
            jax.block_until_ready(tok)
        dt = time.time() - t0

        # single scoring site: every round (incl. round 0) is scored
        # against the current detector, THEN the detector trains on it
        score = float(ae_score(detector, features).mean())
        monitor, flagged, _ = detector_update(
            monitor, jnp.asarray([score]), mon_cfg
        )
        detector = oselm_step(detector, features, features)
        if sink is not None:
            rounds_total.inc()
            round_seconds.observe(dt)
            tokens_total.inc(B * args.new_tokens)
            drift_score.set(score)
            if bool(flagged[0]):
                drift_flags.inc()
        flag = "  << DRIFT" if rnd == drift_round else ""
        if bool(flagged[0]):
            flag += "  [DETECTED]"
        print(
            f"round {rnd}: {B} reqs × {args.new_tokens} tok in {dt:.2f}s "
            f"({B*args.new_tokens/dt:.1f} tok/s) drift_score={score:.5f}{flag}"
        )

    if sink is not None:
        sink.close()
        print("telemetry:", json.dumps({
            "dir": args.telemetry_dir,
            "rounds": int(rounds_total.value),
            "tokens": int(tokens_total.value),
            "drift_flags": int(drift_flags.value),
        }))


if __name__ == "__main__":
    main()
