"""Batched serving driver: continuous request loop with KV caches and
the paper's OS-ELM drift monitor scoring every batch.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --rounds 4 --batch 4 --prompt-len 64 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import ae_score, init_autoencoder, oselm_step
from repro.models import decode_step, encoder_forward, init_params, prefill


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--drift-round", type=int, default=-1,
                    help="inject a shifted-distribution batch at this round")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S = args.batch, args.prompt_len
    max_seq = S + args.new_tokens
    drift_round = args.drift_round if args.drift_round >= 0 else args.rounds - 1

    fe = None
    if cfg.frontend is not None:
        fe = jax.random.normal(key, (B, cfg.n_frontend_tokens, cfg.d_frontend))
    enc_out = encoder_forward(params, cfg, fe) if fe is not None else None

    prefill_fn = jax.jit(
        lambda p, t, f: prefill(p, cfg, t, frontend=f, cache_len=max_seq)
    )
    decode_fn = jax.jit(
        lambda p, t, c, pos, e: decode_step(p, cfg, t, c, pos, enc_out=e, max_seq=max_seq)
    )

    detector = None
    for rnd in range(args.rounds):
        k = jax.random.fold_in(key, rnd)
        prompts = jax.random.randint(k, (B, S), 0, cfg.vocab)
        if rnd == drift_round:  # distribution shift: permuted vocabulary
            prompts = (prompts * 31 + 17) % cfg.vocab

        t0 = time.time()
        logits, caches, features = prefill_fn(params, prompts, fe)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(args.new_tokens):
            logits, caches = decode_fn(params, tok, caches, jnp.asarray(S + i, jnp.int32), enc_out)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(tok)
        dt = time.time() - t0

        if detector is None:  # warm up the monitor on the first batch
            detector = init_autoencoder(
                jax.random.PRNGKey(7), cfg.d_model, cfg.detector_hidden,
                jnp.tile(features, (2 * cfg.detector_hidden // B + 1, 1)),
                activation="identity", ridge=1e-2,
            )
            score = float(ae_score(detector, features).mean())
        else:
            score = float(ae_score(detector, features).mean())
            detector = oselm_step(detector, features, features)
        flag = "  << DRIFT" if rnd == drift_round else ""
        print(
            f"round {rnd}: {B} reqs × {args.new_tokens} tok in {dt:.2f}s "
            f"({B*args.new_tokens/dt:.1f} tok/s) drift_score={score:.5f}{flag}"
        )


if __name__ == "__main__":
    main()
