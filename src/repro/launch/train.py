"""End-to-end training driver (deliverable b's main example backend).

Trains a (reduced or full) architecture on synthetic token streams with
the paper's OS-ELM representation monitor attached: every step the
feature tap feeds per-shard OS-ELM autoencoders, and every
``--merge-every`` steps the one-shot cooperative model update (psum)
synchronizes them — concept-drift scoring comes along for free.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --reduced \
        --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import init_oselm, init_slfn, oselm_loss
from repro.federated.mesh_federation import mesh_cooperative_update
from repro.launch.mesh import data_axes, make_host_mesh
from repro.launch.steps import make_detector_step, make_optimizer, make_train_step
from repro.models import init_params


def synthetic_batch(key, vocab, batch, seq, step):
    """Markov-ish synthetic token stream (shifted bigram structure) so the
    loss actually decreases; drifts its distribution at step 60+ to give
    the detector something to notice."""
    k = jax.random.fold_in(key, step)
    base = jax.random.randint(k, (batch, seq + 1), 0, vocab)
    # inject structure: every other token repeats (learnable bigram)
    rep = jnp.repeat(base[:, ::2], 2, axis=1)[:, : seq + 1]
    tokens = jnp.where(jnp.arange(seq + 1) % 2 == 0, base, rep)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--merge-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    n_dev = len(jax.devices())
    mesh = make_host_mesh()
    dp = data_axes(mesh)
    print(f"arch={cfg.name} devices={n_dev} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    opt = make_optimizer(cfg, lr=args.lr)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))

    # --- the paper's detector: one OS-ELM autoencoder per data shard -----
    det_hidden = cfg.detector_hidden
    slfn = init_slfn(jax.random.PRNGKey(7), cfg.d_model, det_hidden)
    warm = jax.random.normal(jax.random.PRNGKey(8), (2 * det_hidden, cfg.d_model))
    det0 = init_oselm(slfn, warm, warm, activation="identity", ridge=1e-2)
    det_states = jax.tree.map(lambda l: jnp.stack([l] * n_dev), det0)
    det_step = make_detector_step(mesh, dp, merge=False)
    def det_merge(st):
        return mesh_cooperative_update(st, mesh, dp, ridge=1e-2)

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    losses = []
    for step in range(args.steps):
        batch = synthetic_batch(key, cfg.vocab, args.batch, args.seq, step)
        if step >= int(args.steps * 0.7):  # concept drift: vocabulary shift
            batch = jax.tree.map(lambda t: (t * 7 + 3) % cfg.vocab, batch)
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)

        feats = metrics["features"]                      # (B, D)
        per_shard = feats.reshape(n_dev, -1, feats.shape[-1])
        drift = float(
            jax.vmap(lambda s, f: oselm_loss(s, f, f).mean())(det_states, per_shard).mean()
        )
        det_states = det_step(det_states, per_shard)
        if (step + 1) % args.merge_every == 0:
            det_states = det_merge(det_states)           # one-shot federated merge
        if step % 5 == 0 or step == args.steps - 1:
            print(
                f"step {step:4d} loss={loss:.4f} drift_score={drift:9.3f} "
                f"dt={time.time()-t0:.2f}s"
            )
        if ckpt and (step + 1) % 25 == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})

    assert losses[-1] < losses[0], "training did not reduce loss"
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
