"""Divisibility-aware sharding rules (DESIGN.md §6).

2-D weight sharding (FSDP × TP): the contraction-free ("output") dim of
each matmul weight goes on "model", the d_model dim on "data" — so the
405B/480B archs fit 256×16 GB. A dim is sharded only when divisible by
the mesh axis size; otherwise it stays replicated (e.g. hymba's 32001
vocab, granite-moe's 40 experts). The "pod" axis never carries weights
(pure DP across pods).

Rules are keyed by leaf parameter name; a rule applies only when the
leaf's trailing ndim matches the rule length (stacked layer leaves have
a leading layer axis mapped to None).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# leaf-name → per-dim preferred axes (innermost dims; layer axis prepended)
RULES: dict[str, tuple] = {
    "embed": ("model", "data"),
    # attention
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),
    # dense FFN
    "gate": ("data", "model"),
    "up": ("data", "model"),
    "down": ("model", "data"),
    # MoE
    "router": ("data", None),
    "w_gate": ("model", "data", None),
    "w_up": ("model", "data", None),
    "w_down": ("model", None, "data"),
    # mamba
    "in_proj": ("data", "model"),
    "out_proj": ("model", "data"),
    "b_proj": ("data", None),
    "c_proj": ("data", None),
    "dt_proj": ("data", None),
    "d_skip": (None,),
    "dt_bias": (None,),
    "a_log": (None,),
    # mLSTM / sLSTM
    "w_gates": ("data", "model"),
    "r_gates": (None, None, None),
    "wf": ("data", None),
    "wi": ("data", None),
    "wo_gate": ("data", None),
    "bf": (None,), "bi": (None,), "bo": (None,),
    # frontend stub projection
    "frontend_proj": (None, "data"),
}


def _axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for_leaf(name: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    sizes = _axis_sizes(mesh)
    rule = RULES.get(name)
    if rule is None or len(shape) < len(rule):
        return P()
    # MoE expert weights: if the expert dim can't shard over "model"
    # (granite-moe's 40 % 16 != 0), shard the per-expert FFN dim there
    # instead — otherwise every model-axis device recomputes identical
    # expert work (§Perf iteration 3: 16x redundant FLOPs).
    if name in ("w_gate", "w_up", "w_down") and len(shape) >= 3:
        e_dim = shape[-3]
        if "model" in sizes and e_dim % sizes["model"] != 0:
            rule = (None, "data", "model") if name != "w_down" else (None, "model", "data")
    # leading (layer-stack) dims → None
    lead = len(shape) - len(rule)
    dims: list = [None] * lead
    for dim_size, axis in zip(shape[lead:], rule):
        if axis is not None and axis in sizes and dim_size % sizes[axis] == 0:
            dims.append(axis)
        else:
            dims.append(None)
    return P(*dims)


def param_specs(params: PyTree, mesh: Mesh) -> PyTree:
    """PartitionSpec tree matching ``params`` (works on ShapeDtypeStructs)."""

    def visit(path, leaf):
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        return spec_for_leaf(name or "", leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(visit, params)


def param_shardings(params: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(params, mesh)
    )


def fleet_stack_spec(axes: tuple[str, ...] = ("data",)) -> P:
    """PartitionSpec sharding a stacked fleet's leading device axis over
    the federation mesh axes (all trailing dims replicated)."""
    return P(tuple(axes))


def fleet_shardings(states: PyTree, mesh: Mesh, axes: tuple[str, ...] = ("data",)) -> PyTree:
    """NamedShardings for a stacked ``OSELMState`` fleet: every leaf's
    leading device axis lands on ``axes``; used with
    ``repro.fleet.sharded.fleet_merge_sharded`` so the topology merge
    lowers to a psum of O(clusters) segment sums per shard."""
    sharding = NamedSharding(mesh, fleet_stack_spec(axes))
    return jax.tree.map(lambda _: sharding, states)


def shard_fleet(states: PyTree, mesh: Mesh, axes: tuple[str, ...] = ("data",)) -> PyTree:
    """Place a stacked fleet on the mesh, device axis sharded over ``axes``."""
    return jax.device_put(states, fleet_shardings(states, mesh, axes))


def opt_state_specs(opt_state: PyTree, params_specs_tree: PyTree) -> PyTree:
    """Adam moments follow their parameter's sharding; step is replicated."""
    from repro.optim.optimizers import OptState

    mu = opt_state.mu and params_specs_tree
    nu = opt_state.nu and params_specs_tree
    return OptState(step=P(), mu=mu, nu=nu)


def batch_specs(batch_kind: str, dp_axes: tuple[str, ...], mesh: Mesh, cfg=None) -> dict:
    """Input shardings per shape kind. Batch dim on the data(+pod) axes."""
    if batch_kind == "train":
        specs = {"tokens": P(dp_axes, None), "labels": P(dp_axes, None)}
        if cfg is not None and cfg.frontend is not None:
            specs["frontend"] = P(dp_axes, None, None)
        return specs
    if batch_kind == "prefill":
        specs = {"tokens": P(dp_axes, None)}
        if cfg is not None and cfg.frontend is not None:
            specs["frontend"] = P(dp_axes, None, None)
        return specs
    raise ValueError(batch_kind)


def cache_specs_sharding(
    caches: PyTree, mesh: Mesh, dp_axes: tuple[str, ...], *, shard_seq: bool = False
) -> PyTree:
    """Decode caches: (L, B, S, KV, hd) attention caches and recurrent
    states. Batch on data axes; for long-context batch=1 decodes,
    ``shard_seq`` puts the cache sequence dim on "data" instead (context
    parallelism — DESIGN.md §6)."""
    sizes = _axis_sizes(mesh)

    def visit(leaf):
        shp = leaf.shape
        if len(shp) == 5:  # (L, B, S, KV, hd) attention cache
            l, b, s, kv, hd = shp
            if shard_seq and s % int(np.prod([sizes[a] for a in dp_axes])) == 0:
                return P(None, None, dp_axes, None, None)
            bspec = dp_axes if b % int(np.prod([sizes[a] for a in dp_axes])) == 0 else None
            return P(None, bspec, None, None, None)
        if len(shp) >= 2:  # recurrent states (L, B, ...)
            l, b = shp[0], shp[1]
            bspec = dp_axes if b % int(np.prod([sizes[a] for a in dp_axes])) == 0 else None
            return P(None, bspec, *([None] * (len(shp) - 2)))
        return P()

    return jax.tree.map(visit, caches)
