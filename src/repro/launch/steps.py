"""Jittable train / serve / detector step factories.

train_step: grad-accumulation over ``cfg.num_microbatches`` (lax.scan),
global-norm clipping, Adam (moment dtype per config). Returns the
detector feature tap alongside metrics so the paper's OS-ELM monitor
can consume it.

detector_step: the paper's technique as a first-class mesh program —
every (pod, data) shard batch-updates its OS-ELM autoencoder on its
local feature stream and the one-shot cooperative update (Eq. 8/15)
runs as a single psum. This is the program whose roofline represents
the paper itself (EXPERIMENTS.md §Perf pair 3).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import UV, OSELMState, from_uv, oselm_step, to_uv
from repro.models import decode_step, lm_loss, prefill
from repro.models.config import ArchConfig
from repro.optim import Optimizer, adam, clip_by_global_norm

try:
    from jax import shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map

PyTree = Any


def make_optimizer(cfg: ArchConfig, lr: float = 3e-4) -> Optimizer:
    moment_dtype = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else None
    return adam(lr, moment_dtype=moment_dtype)


def make_train_step(cfg: ArchConfig, optimizer: Optimizer) -> Callable:
    M = max(1, cfg.num_microbatches)

    def loss_fn(params, tokens, labels, frontend):
        return lm_loss(params, cfg, tokens, labels, frontend=frontend)

    def train_step(params, opt_state, batch):
        tokens = batch["tokens"]
        labels = batch["labels"]
        frontend = batch.get("frontend")
        b = tokens.shape[0]

        if M == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, tokens, labels, frontend
            )
            features = metrics["features"]
        else:
            mb = b // M

            def resh(x):
                return None if x is None else x.reshape(M, mb, *x.shape[1:])

            mbs = {"tokens": resh(tokens), "labels": resh(labels)}
            fr = resh(frontend)

            def mb_step(carry, inp):
                gacc, lacc = carry
                if fr is None:
                    t, l = inp
                    f = None
                else:
                    t, l, f = inp
                (loss_i, met_i), g_i = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, t, l, f
                )
                gacc = jax.tree.map(lambda a, gg: a + gg.astype(a.dtype), gacc, g_i)
                return (gacc, lacc + loss_i), met_i["features"]

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            xs = (mbs["tokens"], mbs["labels"]) + ((fr,) if fr is not None else ())
            (gsum, lsum), feats = jax.lax.scan(mb_step, (g0, jnp.zeros((), jnp.float32)), xs)
            grads = jax.tree.map(lambda g: g / M, gsum)
            loss = lsum / M
            features = feats.reshape(b, -1)
            metrics = {"ce": loss}

        grads = clip_by_global_norm(grads, 1.0)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        out_metrics = {
            "loss": loss,
            "features": features,
            "grad_norm": jnp.sqrt(
                sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
            ),
        }
        return new_params, new_opt, out_metrics

    return train_step


def make_prefill_step(cfg: ArchConfig) -> Callable:
    def prefill_step(params, batch):
        logits, caches, features = prefill(
            params, cfg, batch["tokens"], frontend=batch.get("frontend")
        )
        return {"logits": logits, "caches": caches, "features": features}

    return prefill_step


def make_decode_step(cfg: ArchConfig, max_seq: int) -> Callable:
    def serve_step(params, token, caches, pos, enc_out=None):
        logits, new_caches = decode_step(
            params, cfg, token, caches, pos, enc_out=enc_out, max_seq=max_seq
        )
        return logits, new_caches

    return serve_step


# ------------------------------------------------- the paper's program


def make_detector_step(
    mesh, axes: tuple[str, ...], *, merge: bool = True, ridge: float = 1e-3
) -> Callable:
    """OS-ELM detector update + one-shot cooperative merge on the mesh.

    states: stacked OSELMState (leading shard axis), features:
    (shards, k, D) per-shard feature chunks from the train/serve taps.
    One psum pair = the paper's entire communication round.
    """
    from jax.sharding import PartitionSpec as P

    spec = P(axes)

    def body(st: OSELMState, feats: jnp.ndarray) -> OSELMState:
        local = jax.tree.map(lambda l: l[0], st)
        x = feats[0]                                  # (k, D) local chunk
        local = oselm_step(local, x, x)               # Eq. 12, batch k
        if merge:
            uv = to_uv(local, ridge=ridge)
            u = jax.lax.psum(uv.u, axes)              # Eq. 8 as all-reduce
            v = jax.lax.psum(uv.v, axes)
            local = from_uv(local, UV(u=u, v=v), ridge=ridge)
        return jax.tree.map(lambda l: l[None], local)

    fn = shard_map(body, mesh=mesh, in_specs=(spec, spec), out_specs=spec)
    return jax.jit(fn)
