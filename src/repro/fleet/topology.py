"""Merge topologies for fleet-scale cooperative updates.

The paper's cooperative update (Eq. 8) is a plain sum of per-device
(U, V) sufficient statistics, so *any* federation topology reduces to a
sparse summation pattern over the stacked device axis:

    merged Uᵢ = Σⱼ Mᵢⱼ Uⱼ          M ∈ {0,1}^(D×D), Mᵢᵢ = 1

Four topologies are provided, spanning the related-work design space:

- ``all_to_all``  — the paper's baseline: every device exchanges with
  every peer (D2D full mesh). M = 1.
- ``star``        — Fig. 4/5 server exchange: devices upload to a hub,
  the hub sums, and broadcasts the merged result back. The *result* is
  identical to all-to-all (M = 1) but the communication cost is O(D)
  payloads instead of O(D²).
- ``ring``        — gossip: each device merges with its ``hops``
  nearest ring neighbors per round. Partial mixing; repeated rounds
  diffuse information around the ring.
- ``hierarchical``— Jung et al. (Sensors 2024) two-tier aggregation:
  location clusters sum locally (segment-sum), cluster heads exchange
  cluster aggregates, and broadcast back. With head exchange the result
  equals all-to-all at a fraction of the traffic; without it, clusters
  stay isolated (block-diagonal M).

``Topology.mix`` applies M to any stacked (D, ...) array **without ever
forming M** on the sparse kinds — this is no longer future work:

- ``kind="banded"`` (ring): a circular banded neighbor-sum,
  Σ_{o=-hops..hops} roll(x, o) — O(D·hops·F) instead of the dense
  einsum's O(D²·F); a ring that touches 2 neighbors costs 2 adds/row.
- ``kind="segment"`` (star / hierarchical): ``jax.ops.segment_sum``
  over the precomputed ``n_clusters`` cluster ids, plus an O(clusters)
  head exchange and broadcast.
- ``kind="dense"`` (all_to_all and custom masks): the D×D einsum —
  kept as the measured baseline the sparse paths are benchmarked
  against (``benchmarks/fleet_scale.py --merge-bench``).

The same sparsity structure is exploited by the Pallas kernel family in
``repro.kernels.topology_merge`` (banded gather / segment-sum kernels
fused with the Eq. 8 solve) and by the sharded psum-of-segment-sums
merge in ``repro.fleet.sharded``.

Communication accounting lives in ``repro.fleet.comm``; each topology
reports its per-round payload transmission count via
``payloads_per_round``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: identity hash, so
class Topology:                                # a Topology can be a jit static arg
    """A merge pattern over ``n_devices`` stacked learners.

    ``kind`` selects the mixing implementation:
      - "dense": ``matrix`` (D, D) 0/1 mask, einsum neighbor-sum
      - "banded": circular ±``hops`` neighbor-sum (ring); M is never
        materialized
      - "segment": two-tier segment-sum over ``cluster_ids`` (+ head
        exchange when ``head_exchange``); ``n_clusters`` is frozen at
        construction so ``mix`` never re-derives it from the ids
    """

    name: str
    n_devices: int
    kind: str  # "dense" | "banded" | "segment"
    matrix: np.ndarray | None = None          # (D, D) float32, incl. diagonal
    cluster_ids: np.ndarray | None = None     # (D,) int32, for kind="segment"
    n_clusters: int | None = None             # precomputed segment count
    hops: int | None = None                   # for kind="banded"
    head_exchange: bool = True
    payloads_per_round: int = 0               # payload transmissions per merge round

    def dense_matrix(self) -> np.ndarray:
        """The equivalent (D, D) mixing mask, whatever the kind — used by
        the async-staleness path and by tests cross-checking the sparse
        implementations."""
        if self.matrix is not None:
            return self.matrix
        if self.kind == "banded":
            assert self.hops is not None
            idx = np.arange(self.n_devices)
            dist = np.abs(idx[:, None] - idx[None, :])
            circ = np.minimum(dist, self.n_devices - dist)
            return (circ <= self.hops).astype(np.float32)
        assert self.cluster_ids is not None
        same = self.cluster_ids[:, None] == self.cluster_ids[None, :]
        m = np.ones_like(same, dtype=np.float32) if self.head_exchange \
            else same.astype(np.float32)
        return m

    @property
    def band_closed(self) -> bool:
        """A banded ring whose ±hops window already covers every device
        (equivalent to all-to-all; the banded sum would double count)."""
        return self.kind == "banded" and 2 * self.hops + 1 >= self.n_devices

    def mix(self, stacked: jnp.ndarray) -> jnp.ndarray:
        """Neighbor-sum a stacked (D, ...) array: out[i] = Σⱼ Mᵢⱼ x[j].

        Sparse kinds never materialize M (see module docstring)."""
        if self.kind == "segment":
            cids = jnp.asarray(self.cluster_ids)
            cluster_sums = jax.ops.segment_sum(
                stacked, cids, num_segments=self.n_clusters
            )
            if self.head_exchange:
                # heads exchange cluster aggregates → every cluster ends
                # up with the global sum, broadcast back to members
                total = jnp.sum(cluster_sums, axis=0)
                return jnp.broadcast_to(total[None], stacked.shape)
            return cluster_sums[cids]
        if self.kind == "banded":
            if self.band_closed:  # full mesh: one sum + broadcast
                total = jnp.sum(stacked, axis=0)
                return jnp.broadcast_to(total[None], stacked.shape)
            return sum(
                jnp.roll(stacked, o, axis=0)
                for o in range(-self.hops, self.hops + 1)
            )
        m = jnp.asarray(self.matrix)
        return jnp.einsum("ij,j...->i...", m, stacked)

    @property
    def is_fully_connected(self) -> bool:
        if self.kind == "segment":
            return self.head_exchange or self.n_clusters == 1
        if self.kind == "banded":
            return self.band_closed
        return bool((self.dense_matrix() > 0).all())


def all_to_all(n_devices: int) -> Topology:
    """Paper baseline: full D2D mesh — every device downloads every
    peer's (U, V). D(D−1) payload transmissions per round."""
    return Topology(
        name="all_to_all",
        n_devices=n_devices,
        kind="dense",
        matrix=np.ones((n_devices, n_devices), dtype=np.float32),
        payloads_per_round=n_devices * (n_devices - 1),
    )


def star(n_devices: int) -> Topology:
    """Fig. 4/5 server topology: upload to hub, hub sums, broadcast.
    Merged result is identical to all-to-all; traffic is 2(D−1)
    payloads (D−1 uploads + D−1 merged downloads; the hub is local to
    itself). Implemented as the single-cluster segment path so the
    mix is the O(D) sum-and-broadcast the hub actually performs, not
    a dense D×D einsum."""
    return Topology(
        name="star",
        n_devices=n_devices,
        kind="segment",
        cluster_ids=np.zeros(n_devices, dtype=np.int32),
        n_clusters=1,
        head_exchange=True,
        payloads_per_round=2 * (n_devices - 1),
    )


def ring(n_devices: int, hops: int = 1) -> Topology:
    """Gossip ring: device i merges with its ±1..hops ring neighbors.
    With hops ≥ ⌈(D−1)/2⌉ the ring closes into a full mesh. The mixing
    matrix is never materialized (kind="banded"); ``dense_matrix`` can
    still reconstruct it for cross-checks and the staleness model."""
    degree = min(2 * hops, n_devices - 1)  # neighbors actually sent to
    return Topology(
        name=f"ring{hops}" if hops != 1 else "ring",
        n_devices=n_devices,
        kind="banded",
        hops=hops,
        payloads_per_round=n_devices * degree,
    )


def hierarchical(
    n_devices: int, n_clusters: int, *, head_exchange: bool = True
) -> Topology:
    """Jung et al. two-tier location clusters (contiguous blocks):
    members upload to their cluster head, heads exchange cluster
    aggregates all-to-all, heads broadcast the merged result back.

    Per-round payloads: (D − C) member uploads + C(C−1) head exchanges
    + (D − C) member downloads.
    """
    if not 1 <= n_clusters <= n_devices:
        raise ValueError(f"need 1 <= n_clusters={n_clusters} <= n_devices={n_devices}")
    cluster_ids = (np.arange(n_devices) * n_clusters // n_devices).astype(np.int32)
    n_members_traffic = n_devices - n_clusters  # non-head members, up + down each
    head_traffic = n_clusters * (n_clusters - 1) if head_exchange else 0
    return Topology(
        name="hierarchical" if head_exchange else "hierarchical_isolated",
        n_devices=n_devices,
        kind="segment",
        cluster_ids=cluster_ids,
        n_clusters=n_clusters,
        head_exchange=head_exchange,
        payloads_per_round=2 * n_members_traffic + head_traffic,
    )


TOPOLOGIES = {
    "all_to_all": all_to_all,
    "star": star,
    "ring": ring,
    "hierarchical": lambda n, **kw: hierarchical(n, max(1, n // 8), **kw),
}


def make_topology(name: str, n_devices: int, **kw) -> Topology:
    try:
        return TOPOLOGIES[name](n_devices, **kw)
    except KeyError as e:
        raise ValueError(f"unknown topology {name!r}; have {sorted(TOPOLOGIES)}") from e
