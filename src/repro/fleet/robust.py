"""Byzantine-robust cooperative merges — bounded-influence Eq. 8.

The paper's cooperative update sums raw (U, V) sufficient statistics,
so a single hostile or broken device corrupts every participant's model
in one round. This module makes the merge path survive such devices
with three composable defenses, all operating on the stacked published
payload ``w = [U | V]`` at the same boundary the wire codec uses:

- **norm clipping** (``payload_clip``) — each device's payload is
  scaled by ``min(1, clip_norm / ‖w‖_F)``, bounding the magnitude any
  one contribution can inject;
- **coordinate-wise trimmed reduction** (``RobustConfig.trim``) — each
  neighborhood sum drops the ``trim`` smallest and largest
  participating values per coordinate and rescales the mean of the
  rest back to sum units (``trim=0`` IS the plain masked merge,
  bit-for-bit). With ≤ ``trim`` adversaries per neighborhood the
  merged coordinate stays within the honest participants' range;
- **contribution-outlier scores** (``payload_outlier_scores``) — the
  Frobenius distance of each device's clipped payload from the
  participant coordinate-wise median, normalized by the participant
  median distance. Honest devices score ≈1; Byzantine payloads score
  orders of magnitude higher. The runtime feeds these to the governor
  next to the drift detector for quarantine escalation with hysteresis
  re-admission (``MergeGovernor.observe_robust``).

Topology dispatch mirrors ``_masked_merge_body``: segment topologies
(star / hierarchical, plus every fully-connected equivalence class)
trim per cluster via the Pallas ``robust_segment_sum_mix`` kernel or
its XLA oracle, the open ring trims per ±hops neighborhood via an
explicit gather, and hierarchical head exchange sums the per-cluster
robust estimates. Custom dense masks with ``trim > 0`` are rejected
(no neighborhood structure to trim within) — clip + scores still work
there through the ``trim=0`` path.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import UV, OSELMState
from repro.fleet.fleet import (
    _bcast,
    _masked_kernel_merge_from_w,
    _masked_merge_body,
    _solve_uv,
    fleet_from_uv,
    fleet_to_uv,
)
from repro.fleet.topology import Topology

__all__ = [
    "RobustConfig",
    "finite_payload_mask",
    "fleet_merge_robust",
    "payload_clip",
    "payload_outlier_scores",
    "robust_merge_from_w",
]

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class RobustConfig:
    """Robust-merge knobs (frozen/hashable — a static jit argument).

    ``trim``/``clip_norm`` shape the aggregation itself;
    ``score_threshold``..``readmit_after`` drive the governor's
    robust-score quarantine escalation (strike/calm hysteresis — see
    ``MergeGovernor.observe_robust``)."""

    clip_norm: float | None = None  # Frobenius clip of w=[U|V]; None = off
    trim: int = 1                   # values trimmed per side per coordinate
    score_threshold: float = 4.0    # outlier score that counts a strike
    score_readmit: float = 2.0      # score below which calm ticks accrue
    escalate_after: int = 2         # consecutive hot rounds → quarantine
    readmit_after: int = 3          # consecutive calm rounds → re-admission

    def __post_init__(self) -> None:
        if self.trim < 0:
            raise ValueError(f"need trim >= 0, got {self.trim}")
        if self.clip_norm is not None and self.clip_norm <= 0:
            raise ValueError(f"need clip_norm > 0, got {self.clip_norm}")
        if self.score_readmit > self.score_threshold:
            raise ValueError(
                "hysteresis needs score_readmit <= score_threshold "
                f"({self.score_readmit} > {self.score_threshold})"
            )
        if self.escalate_after < 1 or self.readmit_after < 1:
            raise ValueError("escalate_after and readmit_after must be >= 1")


def payload_clip(
    w: jnp.ndarray, clip_norm: float | None
) -> tuple[jnp.ndarray, jnp.ndarray | None]:
    """Per-device Frobenius norm clip of the stacked payload (D, R, C).

    Returns ``(clipped, scale)``; ``scale`` is the (D,) multiplier fed
    to the fused kernel path, or None when clipping is off (the
    payload passes through untouched — bit-for-bit, no ×1.0)."""
    if clip_norm is None:
        return w, None
    norms = jnp.sqrt(jnp.sum(w * w, axis=(1, 2)))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norms, _EPS))
    return w * scale[:, None, None], scale


def finite_payload_mask(w: jnp.ndarray) -> jnp.ndarray:
    """(D,) bool — devices whose whole published payload is finite."""
    return jnp.isfinite(w).all(axis=(1, 2))


def payload_outlier_scores(w: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Contribution-outlier score per device (computed for ALL devices,
    so a quarantined device's return to normalcy is still observable).

    ``dist_d = ‖w_d − median_participants(w)‖_F`` (coordinate-wise
    median over participating devices), normalized by the participant
    median distance: honest payloads score ≈1, Byzantine ones ≫1. The
    score is what the governor escalates on — it identifies WHO is
    hostile, while clip/trim bound WHAT a hostile payload can do in
    the meantime."""
    mf = jnp.asarray(mask) > 0
    sentinel = jnp.where(mf[:, None, None], w, jnp.nan)
    med = jnp.nanmedian(sentinel, axis=0)                      # (R, C)
    dist = jnp.sqrt(jnp.nansum((w - med[None]) ** 2, axis=(1, 2)))
    ref = jnp.nanmedian(jnp.where(mf, dist, jnp.nan))
    scores = dist / (jnp.maximum(ref, 0.0) + _EPS)
    return jnp.where(jnp.isfinite(scores), scores, 0.0)


def _segment_counts(mask: jnp.ndarray, cids: jnp.ndarray, n_clusters: int):
    return jax.ops.segment_sum(mask, cids, num_segments=n_clusters)


def _repair_u(est: jnp.ndarray, n: int, eps: float = 1e-4) -> jnp.ndarray:
    """PSD-repair the U half of trimmed estimates ``est`` (..., R, n+m).

    A coordinate-wise trimmed mean of PSD Gram matrices is not itself
    guaranteed PSD — with few participants per neighborhood (open ring
    ±1 hop: three values, trim=1 keeps the coordinate median) the
    estimate can go indefinite and blow up the (U+εI)⁻¹ solve.
    Symmetrize and clamp the spectrum to a small positive floor; for
    honest, well-populated neighborhoods the eigenvalues are already
    comfortably positive and this is an f32-rounding no-op. Only the
    trim > 0 paths pay this (trim=0 stays bit-for-bit exact)."""
    u = est[..., :, :n]
    u = 0.5 * (u + jnp.swapaxes(u, -1, -2))
    evals, evecs = jnp.linalg.eigh(u)
    floor = eps * jnp.maximum(jnp.abs(evals).max(axis=-1, keepdims=True), 1.0)
    evals = jnp.maximum(evals, floor)
    u = jnp.einsum("...ij,...j,...kj->...ik", evecs, evals, evecs)
    return jnp.concatenate([u, est[..., :, n:]], axis=-1)


def _robust_segments(
    w: jnp.ndarray,
    scale: jnp.ndarray | None,
    cluster_ids,
    mask: jnp.ndarray,
    n_clusters: int,
    trim: int,
    kernel: bool,
    interpret: bool,
) -> jnp.ndarray:
    """Per-cluster robust sum estimates (n_clusters, R, C)."""
    from repro.kernels.robust_merge import (
        robust_segment_combine,
        robust_segment_sum_mix,
        robust_segment_sum_xla,
    )

    d = w.shape[0]
    sc = jnp.ones(d, jnp.float32) if scale is None else scale
    if kernel:
        tot, lo, hi = robust_segment_sum_mix(
            w, cluster_ids, mask, sc, n_clusters, trim, interpret=interpret
        )
    else:
        tot, lo, hi = robust_segment_sum_xla(w, cluster_ids, mask, sc, n_clusters, trim)
    counts = _segment_counts(mask, jnp.asarray(cluster_ids, jnp.int32), n_clusters)
    return robust_segment_combine(tot, lo, hi, counts, trim)


def _robust_banded(
    w: jnp.ndarray, mask: jnp.ndarray, hops: int, trim: int
) -> jnp.ndarray:
    """Per-device robust neighborhood estimates on the open ring: an
    explicit (D, 2·hops+1) neighbor gather, trimmed over the offset
    axis. A ±hops band with ≤ 2·trim participants cannot be trimmed
    and falls back to its plain masked sum (same combine guard as the
    segment path)."""
    d = w.shape[0]
    idx = (jnp.arange(d)[:, None] + jnp.arange(-hops, hops + 1)[None, :]) % d
    vals = w[idx]                                   # (D, n_off, R, C)
    mm = mask[idx]                                  # (D, n_off)
    live = (mm > 0)[:, :, None, None]
    tot = jnp.sum(jnp.where(live, vals, 0.0), axis=1)
    counts = mm.sum(1)
    n_off = 2 * hops + 1
    k = min(trim, n_off)
    lo = jnp.sort(jnp.where(live, vals, jnp.inf), axis=1)[:, :k]
    hi = jnp.sort(jnp.where(live, vals, -jnp.inf), axis=1)[:, n_off - k:]
    lo = jnp.where(jnp.isfinite(lo), lo, 0.0).sum(1)
    hi = jnp.where(jnp.isfinite(hi), hi, 0.0).sum(1)
    live_n = (counts - 2.0 * trim)[:, None, None]
    trimmed = (tot - lo - hi) / jnp.maximum(live_n, 1.0) * counts[:, None, None]
    return jnp.where(live_n >= 1.0, trimmed, tot)


def robust_merge_from_w(
    states: OSELMState,
    topology: Topology,
    mask: jnp.ndarray,
    w: jnp.ndarray,
    cfg: RobustConfig,
    ridge: float,
    *,
    kernel: bool = False,
    interpret: bool = True,
    receive: jnp.ndarray | None = None,
) -> tuple[OSELMState, jnp.ndarray]:
    """Robust participation-masked merge of published payloads ``w``
    (finite — the runtime's finite guard runs upstream). Returns
    ``(merged_states, outlier_scores)``; non-participants keep their
    own (P, β) exactly like ``_masked_merge_body``, unless ``receive``
    widens the download set (a robust-quarantined device's payload is
    distrusted, but it still receives the fleet model — that is what
    lets its published payload re-converge and earn re-admission)."""
    n = states.p.shape[-1]
    n_dev = topology.n_devices
    mf = jnp.asarray(mask).astype(w.dtype)
    w_clip, scale = payload_clip(w, cfg.clip_norm)
    scores = payload_outlier_scores(w_clip, mf)

    if cfg.trim == 0:
        # no trimming: the clipped payload goes through the EXACT masked
        # merge paths (with clipping off this is bit-for-bit
        # fleet_merge_masked — same arrays, same summation order)
        if kernel:
            return _masked_kernel_merge_from_w(
                states, topology, mf, w_clip, ridge, interpret, receive=receive
            ), scores
        uv = UV(u=w_clip[:, :, :n], v=w_clip[:, :, n:])
        return _masked_merge_body(
            states, topology, mf, ridge, uv=uv, receive=receive
        ), scores

    if topology.kind == "segment":
        # per-cluster trim; the kernel path feeds raw payload + clip
        # scale so clipping happens inside the streaming segment-sum
        est = _robust_segments(
            w if kernel else w_clip, scale if kernel else None,
            topology.cluster_ids, mf, topology.n_clusters, cfg.trim,
            kernel, interpret,
        )
        est = _repair_u(est, n)
        if topology.head_exchange:
            # heads exchange their cluster-level ROBUST estimates — the
            # attacker is trimmed inside its own cluster before the
            # global sum ever sees its contribution
            total = est.sum(0)
            p, beta = _solve_uv(total[:, :n], total[:, n:], ridge)
            merged = states.replace(beta=_bcast(beta, n_dev), p=_bcast(p, n_dev))
        else:
            cids = jnp.asarray(topology.cluster_ids)
            pc, betac = jax.vmap(partial(_solve_uv, ridge=ridge))(
                est[:, :, :n], est[:, :, n:]
            )
            merged = states.replace(beta=betac[cids], p=pc[cids])
    elif topology.is_fully_connected:
        # closed ring / all-ones dense mask: one global segment
        est = _robust_segments(
            w if kernel else w_clip, scale if kernel else None,
            np.zeros(n_dev, np.int32), mf, 1, cfg.trim, kernel, interpret,
        )[0]
        est = _repair_u(est, n)
        p, beta = _solve_uv(est[:, :n], est[:, n:], ridge)
        merged = states.replace(beta=_bcast(beta, n_dev), p=_bcast(p, n_dev))
    elif topology.kind == "banded":
        est = _repair_u(_robust_banded(w_clip, mf, topology.hops, cfg.trim), n)
        merged = fleet_from_uv(
            states, UV(u=est[:, :, :n], v=est[:, :, n:]), ridge=ridge
        )
    else:
        raise NotImplementedError(
            "trimmed robust merges need neighborhood structure (segment/"
            "banded/fully-connected); a custom dense mask has none — use "
            f"trim=0 with clipping + outlier scores instead (topology "
            f"{topology.name!r}, trim={cfg.trim})"
        )

    kf = mf if receive is None else jnp.asarray(receive).astype(mf.dtype)
    keep = (kf > 0)[:, None, None]
    return states.replace(
        beta=jnp.where(keep, merged.beta, states.beta),
        p=jnp.where(keep, merged.p, states.p),
    ), scores


@partial(
    jax.jit, static_argnames=("topology", "config", "ridge", "kernel", "interpret")
)
def fleet_merge_robust(
    states: OSELMState,
    topology: Topology,
    *,
    config: RobustConfig,
    mask: jnp.ndarray | None = None,
    ridge: float = 0.0,
    kernel: bool = False,
    interpret: bool = True,
) -> tuple[OSELMState, jnp.ndarray]:
    """``fleet_merge_masked`` with bounded Byzantine influence: clip,
    trim, score. Returns ``(merged_states, outlier_scores)``.

    ``config=RobustConfig(trim=0, clip_norm=None)`` reproduces
    ``fleet_merge_masked`` bit-for-bit (the property the robustness
    tests lock). The runtime composes the same body with its fault
    boundary and finite-payload guard (``FleetRuntime``)."""
    uv = fleet_to_uv(states, ridge=ridge)
    w = jnp.concatenate([uv.u, uv.v], axis=2)
    if mask is None:
        mask = jnp.ones(topology.n_devices, jnp.float32)
    return robust_merge_from_w(
        states, topology, jnp.asarray(mask), w, config, ridge,
        kernel=kernel, interpret=interpret,
    )
