"""Deterministic fleet fault injection at the payload boundary.

Hostile and broken devices are a deployment condition, not a test-only
corner: one device shipping a scaled, poisoned, or non-finite (U, V)
contribution corrupts every Eq. 8 participant in a single merge round.
This module defines the fault model the robustness layer is proven
against, injected at the SAME boundary the wire codec uses — the
stacked published payload ``w = [U | V]`` — so every topology and
backend inherits it without per-path plumbing.

Everything is seed-driven and deterministic: victim selection derives
from ``(seed, spec.seed)`` and per-tick noise from
``(seed, spec.seed, tick)``, so a fault schedule replays identically
across runs, restores, and backends (crash-recovery tests depend on
tick-identical replay).

Fault taxonomy (``FaultSpec.kind``):

- ``sign_flip`` / ``scale`` — multiplicative payload attacks
  (``−magnitude`` / ``magnitude``), the classic Byzantine scaling
  adversary;
- ``noise`` — additive Gaussian payload noise of scale ``magnitude``;
- ``nan`` / ``inf`` — non-finite payloads (broken device, overflow on
  the wire), exercised by the runtime's finite-payload guard;
- ``crash`` — device down for the tick window: excluded from merge
  participation (its local state persists — payload-boundary
  semantics; a revived device rejoins with whatever it learned);
- ``poison`` — the device's *input samples* are replaced with
  deterministic junk of scale ``magnitude`` (data poisoning upstream
  of the payload, attacking through training itself).

``FaultInjector`` resolves specs to concrete victims and exposes the
three hooks the runtime calls: ``payload_ops`` (multiplier, additive
noise, non-finite markers — identity when nothing is active),
``crash_mask``, and ``poison_batch``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["FAULT_KINDS", "FaultInjector", "FaultSpec"]

FAULT_KINDS = ("sign_flip", "scale", "noise", "nan", "inf", "crash", "poison")

_PAYLOAD_KINDS = ("sign_flip", "scale", "noise", "nan", "inf")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault schedule (frozen/hashable so a
    ``ScenarioSpec`` carrying a tuple of these stays a valid static jit
    argument and cache key).

    Victims are either explicit (``devices``) or a seed-chosen fraction
    of the fleet (``frac`` — at least one device when > 0). The
    schedule is active on ticks ``start_tick <= t < end_tick`` (half
    open; ``None`` = forever), every ``period``-th tick within it."""

    kind: str
    devices: tuple[int, ...] = ()
    frac: float = 0.0
    start_tick: int = 0
    end_tick: int | None = None
    magnitude: float = 1.0
    period: int = 1
    seed: int = 0

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; have {FAULT_KINDS}")
        if self.devices and self.frac:
            raise ValueError("give explicit devices OR frac, not both")
        if not self.devices and not self.frac:
            raise ValueError(f"{self.kind!r} fault needs victims: devices or frac")
        if not 0.0 <= self.frac <= 1.0:
            raise ValueError(f"need 0 <= frac <= 1, got {self.frac}")
        if self.period < 1:
            raise ValueError(f"need period >= 1, got {self.period}")
        if self.end_tick is not None and self.end_tick <= self.start_tick:
            raise ValueError(
                f"empty schedule: end_tick {self.end_tick} <= start_tick "
                f"{self.start_tick}"
            )


class FaultInjector:
    """Resolved, replayable fault schedules for one fleet.

    Construction is where randomness happens (victim choice); after
    that every hook is a pure function of ``tick``, so two injectors
    built from the same ``(specs, n_devices, seed)`` produce identical
    fault streams — the property crash-recovery and differential tests
    rely on."""

    def __init__(
        self, specs: tuple[FaultSpec, ...] | list[FaultSpec],
        n_devices: int, *, seed: int = 0,
    ) -> None:
        self.specs = tuple(specs)
        self.n_devices = int(n_devices)
        self.seed = int(seed)
        self._victims: list[np.ndarray] = []
        for spec in self.specs:
            if not isinstance(spec, FaultSpec):
                raise TypeError(f"expected FaultSpec, got {type(spec).__name__}")
            if spec.devices:
                bad = [d for d in spec.devices if not 0 <= d < n_devices]
                if bad:
                    raise ValueError(
                        f"fault devices {bad} out of range for fleet of {n_devices}"
                    )
                victims = np.asarray(sorted(set(spec.devices)), np.int64)
            else:
                k = max(1, round(spec.frac * n_devices))
                rng = np.random.default_rng([self.seed, spec.seed])
                victims = np.sort(rng.choice(n_devices, size=k, replace=False))
            self._victims.append(victims)

    @staticmethod
    def _active(spec: FaultSpec, tick: int) -> bool:
        if tick < spec.start_tick:
            return False
        if spec.end_tick is not None and tick >= spec.end_tick:
            return False
        return (tick - spec.start_tick) % spec.period == 0

    @property
    def byzantine_devices(self) -> tuple[int, ...]:
        """Devices touched by any payload or poison fault (NOT crashes —
        a crashed device is absent, not hostile); evaluation excludes
        these from "honest fleet" AUC summaries."""
        out: set[int] = set()
        for spec, victims in zip(self.specs, self._victims):
            if spec.kind != "crash":
                out.update(int(d) for d in victims)
        return tuple(sorted(out))

    def payload_ops(
        self, tick: int, shape: tuple[int, ...]
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The tick's payload corruption as three dense operands the
        merge-boundary closure consumes (so changing faults never
        retraces): ``mult`` (D,) multiplier, ``noise`` (D, R, C)
        additive term, ``nonfin`` (D,) int32 markers (0 clean, 1 NaN,
        2 +Inf). Identity (ones/zeros/zeros) when nothing is active."""
        d, r, c = shape
        if d != self.n_devices:
            raise ValueError(f"payload shape {shape} vs fleet of {self.n_devices}")
        mult = np.ones(d, np.float32)
        noise = np.zeros(shape, np.float32)
        nonfin = np.zeros(d, np.int32)
        for spec, victims in zip(self.specs, self._victims):
            if spec.kind not in _PAYLOAD_KINDS or not self._active(spec, tick):
                continue
            if spec.kind == "sign_flip":
                mult[victims] *= -abs(spec.magnitude)
            elif spec.kind == "scale":
                mult[victims] *= spec.magnitude
            elif spec.kind == "noise":
                rng = np.random.default_rng([self.seed, spec.seed, tick])
                noise[victims] += spec.magnitude * rng.standard_normal(
                    (len(victims), r, c)
                ).astype(np.float32)
            elif spec.kind == "nan":
                nonfin[victims] = 1
            else:  # inf
                nonfin[victims] = 2
        return mult, noise, nonfin

    def active_faults(self, tick: int) -> list[tuple[str, int]]:
        """``(kind, victim_count)`` of every schedule active this tick —
        the telemetry layer's fault-event feed (counters + flight
        records), shared with nothing stochastic: pure ``_active``."""
        return [
            (spec.kind, int(len(victims)))
            for spec, victims in zip(self.specs, self._victims)
            if self._active(spec, tick)
        ]

    def crash_mask(self, tick: int) -> np.ndarray:
        """(D,) bool — devices down this tick (merge participation is
        withheld; local state persists until they rejoin)."""
        down = np.zeros(self.n_devices, bool)
        for spec, victims in zip(self.specs, self._victims):
            if spec.kind == "crash" and self._active(spec, tick):
                down[victims] = True
        return down

    def poison_batch(self, batch: np.ndarray, tick: int) -> np.ndarray:
        """Replace active poison victims' sample rows with deterministic
        uniform junk in [−magnitude, magnitude). ``batch`` is the
        (D, per_tick, n_features) host tick window; clean ticks return
        it untouched (same object — zero copies on the hot path)."""
        active = [
            (spec, victims)
            for spec, victims in zip(self.specs, self._victims)
            if spec.kind == "poison" and self._active(spec, tick)
        ]
        if not active:
            return batch
        out = np.array(batch, np.float32, copy=True)
        for spec, victims in active:
            rng = np.random.default_rng([self.seed, spec.seed, tick])
            out[victims] = spec.magnitude * (
                2.0 * rng.random((len(victims),) + out.shape[1:], dtype=np.float32)
                - 1.0
            )
        return out

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        kinds = ",".join(s.kind for s in self.specs) or "none"
        return (
            f"FaultInjector(n_devices={self.n_devices}, seed={self.seed}, "
            f"specs=[{kinds}])"
        )
