"""Non-IID fleet stream partitioner with drift injection.

Builds per-device sample streams from the synthetic datasets
(``repro.data.synthetic``), generalizing ``make_sharded_streams`` to
fleet scale:

- **assignment** — which normal pattern(s) each device observes:
  ``"round_robin"`` (device i sees pattern i mod C, the paper's
  Device-A/B/C setting scaled up) or ``"dirichlet"`` (each device draws
  a pattern mixture ~ Dir(α); small α → near-single-pattern devices,
  large α → near-IID). Dirichlet partitioning is the standard non-IID
  federated benchmark protocol.
- **drift injection** — per-device schedules of concept-drift events:
  at a scheduled step a device's stream switches to a different
  pattern (the scenario the paper's forgetting factor λ and the
  selection hooks exist for). Schedules are explicit and reproducible.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import numpy as np

from repro.data.synthetic import AnomalyDataset


@dataclasses.dataclass(frozen=True)
class DriftEvent:
    """Device ``device`` switches to sampling ``new_pattern`` at
    ``step`` (inclusive) of its stream."""

    device: int
    step: int
    new_pattern: int


class FleetStreams(NamedTuple):
    """Per-device streams + provenance metadata."""

    x_init: np.ndarray        # (D, n_init, features) Eq. 13 init chunks
    xs: np.ndarray            # (D, steps, features) sequential streams
    pattern_of_device: np.ndarray  # (D, steps) int — pattern of each sample
    drift: tuple[DriftEvent, ...]

    @property
    def n_devices(self) -> int:
        return int(self.xs.shape[0])

    @property
    def n_steps(self) -> int:
        return int(self.xs.shape[1])

    def initial_pattern(self, device: int) -> int:
        return int(self.pattern_of_device[device, 0])

    def phase_boundaries(self, device: int) -> tuple[int, ...]:
        """Start steps of ``device``'s concept phases: step 0 (its home
        concept) plus one boundary per scheduled drift event, in stream
        order. Strictly increasing; the scenario layer's validity
        contract (and its hypothesis suite) are written against this."""
        steps = [0]
        for ev in sorted(self.drift, key=lambda e: e.step):
            if ev.device == device and ev.step not in steps:
                steps.append(ev.step)
        return tuple(steps)

    def drifted_devices(self) -> tuple[int, ...]:
        """Devices with at least one scheduled drift event, ascending."""
        return tuple(sorted({ev.device for ev in self.drift}))


def random_drift_schedule(
    n_devices: int,
    steps: int,
    n_classes: int,
    *,
    frac: float = 0.25,
    seed: int = 0,
    home_classes: int | None = None,
    targets: Sequence[int] | None = None,
) -> tuple[DriftEvent, ...]:
    """A ``frac`` fraction of devices drifts once, at a random step in
    the middle half of its stream, to a uniformly-random *other*
    pattern — "other" relative to the round-robin assignment
    (device i starts on pattern i mod C), so no event is a no-op.
    With a single class there is no other pattern to drift to.

    ``home_classes`` restricts the round-robin home assignment to the
    first H classes (matching ``make_fleet_streams(n_assign=H)``), and
    ``targets`` restricts drift destinations — e.g. drift every device
    into a *held-out* pattern so the drifted concept is exactly what
    the fleet's eval protocol labels anomalous."""
    if n_classes < 2:
        raise ValueError("drift needs n_classes >= 2")
    homes = n_classes if home_classes is None else home_classes
    if not 1 <= homes <= n_classes:
        raise ValueError(f"need 1 <= home_classes={homes} <= n_classes={n_classes}")
    rng = np.random.default_rng(seed)
    n_drift = int(round(frac * n_devices))
    devices = rng.choice(n_devices, size=n_drift, replace=False)
    events = []
    for d in devices:
        step = int(rng.integers(steps // 4, max(3 * steps // 4, steps // 4 + 1)))
        current = int(d) % homes
        if targets is None:
            pool = [c for c in range(n_classes) if c != current]
        else:
            pool = [c for c in targets if c != current]
            if not pool:
                raise ValueError(
                    f"no valid drift target for device {d}: targets={targets!r} "
                    f"collapse onto its home pattern {current}"
                )
        new_pat = int(pool[rng.integers(0, len(pool))])
        events.append(DriftEvent(device=int(d), step=step, new_pattern=new_pat))
    return tuple(sorted(events, key=lambda e: (e.device, e.step)))


def _pattern_sequence(
    rng: np.random.Generator,
    device: int,
    steps: int,
    base_probs: np.ndarray,
    drift: Sequence[DriftEvent],
) -> np.ndarray:
    """Per-step pattern ids for one device: mixture draw from
    ``base_probs``, overridden from each drift event's step onward."""
    pats = rng.choice(len(base_probs), size=steps, p=base_probs)
    # apply in step order so a later-step event always wins, whatever
    # order the caller supplied the schedule in
    for ev in sorted(drift, key=lambda e: e.step):
        if ev.device == device:
            pats[ev.step:] = ev.new_pattern
    return pats.astype(np.int32)


def make_fleet_streams(
    ds: AnomalyDataset,
    n_devices: int,
    steps: int,
    *,
    n_init: int = 32,
    assignment: str = "round_robin",
    alpha: float = 0.3,
    drift: Sequence[DriftEvent] = (),
    seed: int = 0,
    n_assign: int | None = None,
) -> FleetStreams:
    """Deal non-IID streams (plus Eq. 13 init chunks) to ``n_devices``
    virtual devices. Init chunks always come from the device's initial
    dominant pattern (a device boots on its own environment).

    ``n_assign`` limits the round-robin home assignment to the first
    ``n_assign`` patterns while drift events may still target ANY
    pattern of ``ds`` — the drift-to-held-out-concept scenario the
    runtime's quarantine benchmark quantifies (trained patterns stay
    {0..n_assign−1}; a drifted device starts serving a pattern the
    eval protocol labels anomalous)."""
    rng = np.random.default_rng(seed)
    n_classes = ds.n_classes
    pools = [ds.pattern(c) for c in range(n_classes)]
    homes = n_classes if n_assign is None else n_assign
    if not 1 <= homes <= n_classes:
        raise ValueError(f"need 1 <= n_assign={homes} <= n_classes={n_classes}")

    if assignment == "round_robin":
        probs = np.zeros((n_devices, n_classes), dtype=np.float64)
        probs[np.arange(n_devices), np.arange(n_devices) % homes] = 1.0
    elif assignment == "dirichlet":
        probs = np.zeros((n_devices, n_classes), dtype=np.float64)
        probs[:, :homes] = rng.dirichlet(np.full(homes, alpha), size=n_devices)
    else:
        raise ValueError(f"unknown assignment {assignment!r}")

    x_init = np.empty((n_devices, n_init, ds.n_features), dtype=np.float32)
    xs = np.empty((n_devices, steps, ds.n_features), dtype=np.float32)
    pattern_of = np.empty((n_devices, steps), dtype=np.int32)
    for d in range(n_devices):
        pats = _pattern_sequence(rng, d, steps, probs[d], drift)
        pattern_of[d] = pats
        init_pat = int(np.argmax(probs[d]))
        pool0 = pools[init_pat]
        x_init[d] = pool0[rng.integers(0, len(pool0), size=n_init)]
        for c in range(n_classes):
            sel = pats == c
            k = int(sel.sum())
            if k:
                pool = pools[c]
                xs[d, sel] = pool[rng.integers(0, len(pool), size=k)]
    return FleetStreams(
        x_init=x_init, xs=xs, pattern_of_device=pattern_of, drift=tuple(drift)
    )
