"""Fleet-scale OS-ELM federation as one stacked pytree.

Hundreds-to-thousands of virtual edge devices are simulated in a single
process: the whole fleet is ONE ``OSELMState`` whose leaves carry a
leading device axis (like ``DetectorBank``), trained with
``vmap``-over-devices of ``scan``-over-stream, and merged with
topology-aware neighbor sums over the stacked (U, V) axis
(``repro.fleet.topology``).

This is exact simulation, not approximation: each virtual device runs
the paper's k=1 sequential update on its own non-IID stream, and the
cooperative update applies Eq. 8 restricted to the topology's neighbor
set. An all-to-all topology reproduces `cooperative_update` /
`mesh_cooperative_update` bit-for-bit (up to f32 summation order).

API sketch::

    fleet = init_fleet(key, n_devices=256, n_features=225, n_hidden=32,
                       x_init=init_chunks, ridge=1e-3)
    fleet = fleet_train(fleet, streams)              # (D, T, n) streams
    fleet = fleet_merge(fleet, ring(256, hops=2), ridge=1e-3)
    scores = fleet_score(fleet, x_eval)              # (D, k) anomaly scores
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import (
    UV,
    OSELMState,
    ae_score,
    from_uv,
    init_oselm,
    init_slfn,
    oselm_step_k1,
    to_uv,
)
from repro.fleet.topology import Topology


def init_fleet(
    key: jax.Array,
    n_devices: int,
    n_features: int,
    n_hidden: int,
    x_init: jnp.ndarray,
    *,
    activation: str = "sigmoid",
    ridge: float = 0.0,
    forget: float = 1.0,
) -> OSELMState:
    """Initialize ``n_devices`` OS-ELM autoencoders as one stacked state.

    Every device gets the SAME random SLFN basis (α, b) — Eq. 8 only
    sums meaningfully when all devices map inputs through an identical
    hidden layer (the paper's devices share the random basis; see the
    shared ``init_slfn`` in ``benchmarks.mesh_merge`` and the shared key
    in ``examples/federated_fleet.py``). Per-device state differs only
    through the Eq. 13 init chunks: ``x_init`` is (D, n_init,
    n_features), each device's own non-IID boot data.
    """
    if n_hidden >= n_features:
        raise ValueError(f"autoencoder needs a bottleneck: Ñ={n_hidden} >= n={n_features}")
    params = init_slfn(key, n_features, n_hidden)

    def one(x0: jnp.ndarray) -> OSELMState:
        return init_oselm(
            params, x0, x0, activation=activation, ridge=ridge, forget=forget
        )

    return jax.vmap(one)(jnp.asarray(x_init))


@jax.jit
def fleet_train(states: OSELMState, streams: jnp.ndarray) -> OSELMState:
    """Every device sequentially trains (k=1 autoencoder steps) on its
    own stream. ``streams``: (D, T, n_features)."""

    def train_one(st: OSELMState, xs: jnp.ndarray) -> OSELMState:
        def step(s, x):
            return oselm_step_k1(s, x, x), None

        out, _ = jax.lax.scan(step, st, xs)
        return out

    return jax.vmap(train_one)(states, jnp.asarray(streams))


def fleet_to_uv(states: OSELMState, *, ridge: float = 0.0) -> UV:
    """Eq. 15 per device: stacked UV with u (D, Ñ, Ñ), v (D, Ñ, m)."""
    return jax.vmap(partial(to_uv, ridge=ridge))(states)


def fleet_from_uv(states: OSELMState, uv: UV, *, ridge: float = 0.0) -> OSELMState:
    """§4.2 step 5 per device: recover (P, β) from each device's merged
    (U, V)."""
    return jax.vmap(partial(from_uv, ridge=ridge))(states, uv)


@partial(jax.jit, static_argnames=("topology", "ridge"))
def fleet_merge(
    states: OSELMState, topology: Topology, *, ridge: float = 0.0
) -> OSELMState:
    """Topology-aware cooperative update: each device's merged (U, V) is
    the Eq. 8 sum over its neighbor set (self included)."""
    uv = fleet_to_uv(states, ridge=ridge)
    mixed = UV(u=topology.mix(uv.u), v=topology.mix(uv.v))
    return fleet_from_uv(states, mixed, ridge=ridge)


@jax.jit
def fleet_score(states: OSELMState, x: jnp.ndarray) -> jnp.ndarray:
    """Per-device anomaly scores on shared eval data: (D, k)."""
    return jax.vmap(lambda s: ae_score(s, x))(states)


def fleet_train_rounds(
    states: OSELMState,
    streams: jnp.ndarray,
    topology: Topology,
    *,
    rounds: int,
    ridge: float = 0.0,
) -> OSELMState:
    """The paper's "repeatedly applied to synchronize" mode at fleet
    scale: chunk each stream into ``rounds`` pieces, train a chunk,
    merge over the topology, repeat. Synchronous (no staleness) —
    see ``repro.fleet.staleness.fleet_train_async`` for the lagged
    variant."""
    streams = jnp.asarray(streams)
    n_dev, steps, feat = streams.shape
    if not 1 <= rounds <= steps:
        raise ValueError(f"need 1 <= rounds={rounds} <= steps={steps}")
    per = steps // rounds
    chunks = streams[:, : rounds * per].reshape(n_dev, rounds, per, feat)
    for r in range(rounds):
        states = fleet_train(states, chunks[:, r])
        states = fleet_merge(states, topology, ridge=ridge)
    return states


def device_state(states: OSELMState, idx: int) -> OSELMState:
    """Slice one device's state out of the stacked fleet."""
    return jax.tree.map(lambda l: l[idx], states)
