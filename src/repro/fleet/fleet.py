"""Fleet-scale OS-ELM federation as one stacked pytree.

Hundreds-to-thousands of virtual edge devices are simulated in a single
process: the whole fleet is ONE ``OSELMState`` whose leaves carry a
leading device axis (like ``DetectorBank``), trained with
``vmap``-over-devices of ``scan``-over-stream, and merged with
topology-aware neighbor sums over the stacked (U, V) axis
(``repro.fleet.topology``).

This is exact simulation, not approximation: each virtual device runs
the paper's k=1 sequential update on its own non-IID stream, and the
cooperative update applies Eq. 8 restricted to the topology's neighbor
set. An all-to-all topology reproduces `cooperative_update` /
`mesh_cooperative_update` bit-for-bit (up to f32 summation order).

The merge is structure-aware end to end: sparse topologies never form
the D×D mixing matrix (``Topology.mix``), and the §4.2 step-5 solve
runs once per *equivalence class* of merged models — one solve for a
fully-connected merge, one per cluster for isolated hierarchical
clusters, per device only when the neighbor sets genuinely differ
(ring). ``fleet_merge_kernel`` runs the same dispatch through the
Pallas kernel family in ``repro.kernels.topology_merge``, including the
fully fused banded mix+solve. ``fleet_train_rounds`` is a single
compile-once ``lax.scan`` over round chunks (buffers donated on
accelerator backends), not a retracing Python loop.

API sketch::

    fleet = init_fleet(key, n_devices=256, n_features=225, n_hidden=32,
                       x_init=init_chunks, ridge=1e-3)
    fleet = fleet_train(fleet, streams)              # (D, T, n) streams
    fleet = fleet_merge(fleet, ring(256, hops=2), ridge=1e-3)
    scores = fleet_score(fleet, x_eval)              # (D, k) anomaly scores
"""
from __future__ import annotations

import logging
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    UV,
    OSELMState,
    ae_score,
    from_uv,
    init_oselm,
    init_slfn,
    oselm_step_k1,
    to_uv,
)
from repro.core.elm import invert_u, solve_beta
from repro.fleet.quantize import apply_codec, quantize_roundtrip
from repro.fleet.topology import Topology

log = logging.getLogger(__name__)


def init_fleet(
    key: jax.Array,
    n_devices: int,
    n_features: int,
    n_hidden: int,
    x_init: jnp.ndarray,
    *,
    activation: str = "sigmoid",
    ridge: float = 0.0,
    forget: float = 1.0,
) -> OSELMState:
    """Initialize ``n_devices`` OS-ELM autoencoders as one stacked state.

    Every device gets the SAME random SLFN basis (α, b) — Eq. 8 only
    sums meaningfully when all devices map inputs through an identical
    hidden layer (the paper's devices share the random basis; see the
    shared ``init_slfn`` in ``benchmarks.mesh_merge`` and the shared key
    in ``examples/federated_fleet.py``). Per-device state differs only
    through the Eq. 13 init chunks: ``x_init`` is (D, n_init,
    n_features), each device's own non-IID boot data.
    """
    if n_hidden >= n_features:
        raise ValueError(f"autoencoder needs a bottleneck: Ñ={n_hidden} >= n={n_features}")
    params = init_slfn(key, n_features, n_hidden)

    def one(x0: jnp.ndarray) -> OSELMState:
        return init_oselm(
            params, x0, x0, activation=activation, ridge=ridge, forget=forget
        )

    return jax.vmap(one)(jnp.asarray(x_init))


def _fleet_train(states: OSELMState, streams: jnp.ndarray) -> OSELMState:
    def train_one(st: OSELMState, xs: jnp.ndarray) -> OSELMState:
        def step(s, x):
            return oselm_step_k1(s, x, x), None

        out, _ = jax.lax.scan(step, st, xs)
        return out

    return jax.vmap(train_one)(states, streams)


def _warn_ingest_padding(steps: int, backend: str, caller: str) -> None:
    """Log (at trace time — once per compiled shape) when the fused
    ingest lowering pads the sample window. Padded slots are masked to
    exact identity steps (they never update P/β), so results are
    unchanged; the warning only surfaces the wasted slots."""
    from repro.kernels.fleet_ingest import ingest_padding, resolve_backend

    backend = resolve_backend(backend)
    pallas_pad, xla_pad = ingest_padding(steps)
    pad = xla_pad if backend == "xla" else pallas_pad
    if pad:
        log.warning(
            "%s: kernel ingest pads the %d-sample window with %d masked "
            "identity slots (tile/block alignment) — results are exact, "
            "but %d slots per device per window are wasted work",
            caller, steps, pad, pad,
        )


@partial(jax.jit, static_argnames=("kernel", "backend", "interpret"))
def fleet_train(
    states: OSELMState,
    streams: jnp.ndarray,
    *,
    kernel: bool = False,
    backend: str = "auto",
    interpret: bool | None = None,
) -> OSELMState:
    """Every device sequentially trains (k=1 autoencoder steps) on its
    own stream. ``streams``: (D, T, n_features).

    ``kernel=True`` routes the whole window through the fused ingest
    family (``repro.kernels.fleet_ingest``) — the Pallas VMEM-resident
    kernel on TPU, its fused-XLA lowering elsewhere (``backend=`` to
    force one) — mirroring ``fleet_merge_kernel``'s dispatch. The
    kernel path requires the fleet-shared SLFN basis ``init_fleet``
    provides; this function is itself jitted, so the shared-basis
    precondition is validated at the concrete entry points
    (``fleet_ingest`` called directly, ``fleet_train_rounds``,
    ``fleet_train_sharded``, ``FleetRuntime``) rather than here."""
    streams = jnp.asarray(streams)
    if kernel:
        from repro.kernels.fleet_ingest import fleet_ingest

        _warn_ingest_padding(streams.shape[1], backend, "fleet_train")
        states, _ = fleet_ingest(
            states, streams, backend=backend, interpret=interpret
        )
        return states
    return _fleet_train(states, streams)


def fleet_to_uv(states: OSELMState, *, ridge: float = 0.0) -> UV:
    """Eq. 15 per device: stacked UV with u (D, Ñ, Ñ), v (D, Ñ, m)."""
    return jax.vmap(partial(to_uv, ridge=ridge))(states)


def fleet_from_uv(
    states: OSELMState, uv: UV, *, ridge: float = 0.0, nonfinite: str = "error"
) -> OSELMState:
    """§4.2 step 5 per device: recover (P, β) from each device's merged
    (U, V).

    A non-finite (U, V) — one NaN payload in an Eq. 8 sum — would
    silently poison the recovered (P, β) of every device it merged
    into. ``nonfinite="error"`` (default) raises a ValueError naming
    the bad devices when the payloads are concrete (inside a jit trace
    the check is skipped — guard at the boundary instead, as
    ``FleetRuntime`` does); ``"repair"`` replaces a bad device's (U, V)
    with (I, 0), resetting it to an untrained-but-solvable state."""
    if nonfinite not in ("error", "repair"):
        raise ValueError(f"nonfinite must be 'error' or 'repair', got {nonfinite!r}")
    ok = jnp.isfinite(uv.u).all(axis=(1, 2)) & jnp.isfinite(uv.v).all(axis=(1, 2))
    if nonfinite == "repair":
        eye = jnp.eye(uv.u.shape[-1], dtype=uv.u.dtype)
        uv = UV(
            u=jnp.where(ok[:, None, None], uv.u, eye[None]),
            v=jnp.where(ok[:, None, None], uv.v, jnp.zeros_like(uv.v)),
        )
    else:
        try:
            ok_np = np.asarray(ok)
        except (jax.errors.ConcretizationTypeError,
                jax.errors.TracerArrayConversionError):
            ok_np = None  # traced — eager check not possible here
        if ok_np is not None and not ok_np.all():
            raise ValueError(
                "non-finite merged (U, V) for devices "
                f"{np.flatnonzero(~ok_np).tolist()} — a corrupt payload "
                "reached the §4.2 solve; reject it upstream "
                "(repro.fleet.robust.finite_payload_mask) or pass "
                "nonfinite='repair' to reset those devices to (I, 0)"
            )
    return jax.vmap(partial(from_uv, ridge=ridge))(states, uv)


def _solve_uv(u: jnp.ndarray, v: jnp.ndarray, ridge: float, nonfinite: str = "error"):
    """One §4.2 step-5 solve: P = (U+εI)⁻¹, β = (U+εI)⁻¹V.

    Same non-finite guard as ``fleet_from_uv``, for the single-matrix
    solves of the fully-connected/cluster merge paths (eager calls
    fail loudly; traced/vmapped calls skip the check)."""
    ok = jnp.isfinite(u).all() & jnp.isfinite(v).all()
    if nonfinite == "repair":
        u = jnp.where(ok, u, jnp.eye(u.shape[-1], dtype=u.dtype))
        v = jnp.where(ok, v, jnp.zeros_like(v))
    else:
        try:
            concrete = bool(ok)
        except jax.errors.ConcretizationTypeError:
            concrete = None
        if concrete is False:
            raise ValueError(
                "non-finite (U, V) reached the §4.2 solve — reject the "
                "corrupt payload upstream or pass nonfinite='repair'"
            )
    return invert_u(u, ridge=ridge), solve_beta(u, v, ridge=ridge)


def _bcast(x: jnp.ndarray, n_devices: int) -> jnp.ndarray:
    return jnp.broadcast_to(x[None], (n_devices,) + x.shape)


def _codec_uv(states: OSELMState, precision: str, ridge: float) -> UV:
    """The lossy wire view of a fleet's (U, V) payloads: pack, one-shot
    quantize→dequantize round-trip at ``precision`` (no feedback state —
    the stateful path is ``fleet_merge_quantized``), unpack."""
    uv = fleet_to_uv(states, ridge=ridge)
    n = uv.u.shape[1]
    w = quantize_roundtrip(jnp.concatenate([uv.u, uv.v], axis=2), precision)
    return UV(u=w[:, :, :n], v=w[:, :, n:])


def _merge_body(
    states: OSELMState, topology: Topology, ridge: float, uv: UV | None = None
) -> OSELMState:
    """Structure-aware Eq. 8 merge: mix sparsely, then solve once per
    equivalence class of merged (U, V) — fully-connected merges produce
    one global model (1 solve, broadcast), isolated clusters one model
    per cluster (C solves, gather), and only genuinely per-device
    neighbor sets (open ring, custom dense masks) pay D solves.

    ``uv`` optionally injects pre-codec'd payloads (the quantized wire
    view) in place of the exact ``fleet_to_uv`` extraction."""
    if uv is None:
        uv = fleet_to_uv(states, ridge=ridge)
    n_dev = topology.n_devices

    if topology.kind == "segment":
        cids = jnp.asarray(topology.cluster_ids)
        su = jax.ops.segment_sum(uv.u, cids, num_segments=topology.n_clusters)
        sv = jax.ops.segment_sum(uv.v, cids, num_segments=topology.n_clusters)
        if topology.head_exchange:
            p, beta = _solve_uv(su.sum(0), sv.sum(0), ridge)
            return states.replace(beta=_bcast(beta, n_dev), p=_bcast(p, n_dev))
        pc, betac = jax.vmap(partial(_solve_uv, ridge=ridge))(su, sv)
        return states.replace(beta=betac[cids], p=pc[cids])

    if topology.is_fully_connected:  # closed ring / all-ones dense mask
        p, beta = _solve_uv(uv.u.sum(0), uv.v.sum(0), ridge)
        return states.replace(beta=_bcast(beta, n_dev), p=_bcast(p, n_dev))

    mixed = UV(u=topology.mix(uv.u), v=topology.mix(uv.v))
    return fleet_from_uv(states, mixed, ridge=ridge)


@partial(jax.jit, static_argnames=("topology", "ridge", "payload_precision"))
def fleet_merge(
    states: OSELMState,
    topology: Topology,
    *,
    ridge: float = 0.0,
    payload_precision: str = "f32",
) -> OSELMState:
    """Topology-aware cooperative update: each device's merged (U, V) is
    the Eq. 8 sum over its neighbor set (self included).

    ``payload_precision`` selects the wire format of the exchanged
    payloads ("f32" exact, "f16"/"int8" block-quantized one-shot —
    see ``repro.fleet.quantize``; the error-feedback stateful variant
    is ``fleet_merge_quantized``)."""
    uv = (None if payload_precision == "f32"
          else _codec_uv(states, payload_precision, ridge))
    return _merge_body(states, topology, ridge, uv=uv)


def _kernel_merge_from_w(
    states: OSELMState,
    topology: Topology,
    w: jnp.ndarray,
    ridge: float,
    interpret: bool,
) -> OSELMState:
    """Kernel-family merge of a pre-packed stacked payload ``w = [U | V]``
    (possibly codec'd): the dispatch half of ``fleet_merge_kernel``."""
    from repro.kernels.topology_merge import (
        banded_merge_solve,
        dense_mix,
        from_uv_solve,
        segment_sum_mix,
    )

    n = states.p.shape[-1]
    n_dev = topology.n_devices

    if topology.kind == "banded" and not topology.band_closed:
        p, beta = banded_merge_solve(w, topology.hops, ridge=ridge, interpret=interpret)
        return states.replace(beta=beta, p=p)

    if topology.kind == "segment":
        sums = segment_sum_mix(
            w, topology.cluster_ids, topology.n_clusters, interpret=interpret
        )
        if topology.head_exchange:
            total = sums.sum(0, keepdims=True)
            p, beta = from_uv_solve(
                total[:, :, :n], total[:, :, n:], ridge=ridge, interpret=interpret
            )
            return states.replace(
                beta=_bcast(beta[0], n_dev), p=_bcast(p[0], n_dev)
            )
        cids = jnp.asarray(topology.cluster_ids)
        pc, betac = from_uv_solve(
            sums[:, :, :n], sums[:, :, n:], ridge=ridge, interpret=interpret
        )
        return states.replace(beta=betac[cids], p=pc[cids])

    if topology.is_fully_connected:  # closed ring / all-ones dense mask
        total = w.sum(0, keepdims=True)
        p, beta = from_uv_solve(
            total[:, :, :n], total[:, :, n:], ridge=ridge, interpret=interpret
        )
        return states.replace(beta=_bcast(beta[0], n_dev), p=_bcast(p[0], n_dev))

    mixed = dense_mix(w, topology.dense_matrix(), interpret=interpret)
    p, beta = from_uv_solve(
        mixed[:, :, :n], mixed[:, :, n:], ridge=ridge, interpret=interpret
    )
    return states.replace(beta=beta, p=p)


@partial(jax.jit, static_argnames=("topology", "ridge", "interpret", "payload_precision"))
def fleet_merge_kernel(
    states: OSELMState,
    topology: Topology,
    *,
    ridge: float = 0.0,
    interpret: bool = True,
    payload_precision: str = "f32",
) -> OSELMState:
    """``fleet_merge`` on the Pallas merge-kernel family: the stacked
    [U | V] payload is mixed by the sparsity-aware kernels and solved by
    the fused Gauss-Jordan kernel (``repro.kernels.topology_merge``);
    on the open ring the mix and solve are ONE kernel, so merged (U, V)
    never round-trips through HBM. ``interpret=True`` runs on CPU;
    pass False on TPU to lower via Mosaic. ``payload_precision`` applies
    the one-shot wire codec (f16 cast or the fused Pallas
    ``quantize_pack`` for int8) before the mix."""
    uv = fleet_to_uv(states, ridge=ridge)
    w = jnp.concatenate([uv.u, uv.v], axis=2)  # stacked [U | V] payloads
    if payload_precision == "int8":
        from repro.fleet.quantize import dequantize_tiles
        from repro.kernels.quantize_pack import quantize_pack

        codes, scales, _ = quantize_pack(uv.u, uv.v, interpret=interpret)
        w = dequantize_tiles(codes, scales)
    elif payload_precision != "f32":
        w = quantize_roundtrip(w, payload_precision)
    return _kernel_merge_from_w(states, topology, w, ridge, interpret)


def _masked_merge_body(
    states: OSELMState,
    topology: Topology,
    mask: jnp.ndarray,
    ridge: float,
    uv: UV | None = None,
    receive: jnp.ndarray | None = None,
) -> OSELMState:
    """Participation-masked Eq. 8 merge. ``mask`` is a traced (D,) 0/1
    vector: devices with mask 0 neither contribute their (U, V) to any
    neighbor's sum nor receive the merged model (they keep their own
    (P, β) untouched). Because the mask is a runtime operand, gating a
    device in or out between rounds never retraces the merge. ``uv``
    optionally injects pre-codec'd payloads. ``receive`` optionally
    widens the set of devices that DOWNLOAD the merged model beyond the
    contributors (robust quarantine distrusts a device's payload while
    still serving it the fleet model); None keeps the symmetric
    contribute-and-receive semantics."""
    if uv is None:
        uv = fleet_to_uv(states, ridge=ridge)
    mf = mask.astype(uv.u.dtype)
    wu = uv.u * mf[:, None, None]
    wv = uv.v * mf[:, None, None]
    n_dev = topology.n_devices

    if topology.kind == "segment":
        cids = jnp.asarray(topology.cluster_ids)
        su = jax.ops.segment_sum(wu, cids, num_segments=topology.n_clusters)
        sv = jax.ops.segment_sum(wv, cids, num_segments=topology.n_clusters)
        if topology.head_exchange:
            p, beta = _solve_uv(su.sum(0), sv.sum(0), ridge)
            merged = states.replace(beta=_bcast(beta, n_dev), p=_bcast(p, n_dev))
        else:
            pc, betac = jax.vmap(partial(_solve_uv, ridge=ridge))(su, sv)
            merged = states.replace(beta=betac[cids], p=pc[cids])
    elif topology.is_fully_connected:
        p, beta = _solve_uv(wu.sum(0), wv.sum(0), ridge)
        merged = states.replace(beta=_bcast(beta, n_dev), p=_bcast(p, n_dev))
    else:
        mixed = UV(u=topology.mix(wu), v=topology.mix(wv))
        merged = fleet_from_uv(states, mixed, ridge=ridge)

    kf = mf if receive is None else receive.astype(mf.dtype)
    keep = (kf > 0)[:, None, None]
    return states.replace(
        beta=jnp.where(keep, merged.beta, states.beta),
        p=jnp.where(keep, merged.p, states.p),
    )


@partial(jax.jit, static_argnames=("topology", "ridge", "payload_precision"))
def fleet_merge_masked(
    states: OSELMState,
    topology: Topology,
    mask: jnp.ndarray,
    *,
    ridge: float = 0.0,
    payload_precision: str = "f32",
) -> OSELMState:
    """``fleet_merge`` with a runtime participation mask — the merge
    governor's quarantine primitive (drifted devices are masked out of
    the topology without recompiling). An all-ones mask reproduces
    ``fleet_merge`` exactly. Use ``ridge > 0`` so a cluster whose
    members are all quarantined still solves a well-posed (discarded)
    system. ``payload_precision`` applies the one-shot wire codec to
    the participating payloads."""
    uv = (None if payload_precision == "f32"
          else _codec_uv(states, payload_precision, ridge))
    return _masked_merge_body(states, topology, jnp.asarray(mask), ridge, uv=uv)


def _masked_kernel_merge_from_w(
    states: OSELMState,
    topology: Topology,
    mask: jnp.ndarray,
    w: jnp.ndarray,
    ridge: float,
    interpret: bool,
    receive: jnp.ndarray | None = None,
) -> OSELMState:
    """Kernel-family masked merge of a pre-packed (possibly codec'd)
    stacked payload ``w``: the dispatch half of
    ``fleet_merge_masked_kernel``. ``receive`` widens the download set
    exactly as in ``_masked_merge_body``."""
    from repro.kernels.topology_merge import (
        banded_merge_solve,
        dense_mix,
        from_uv_solve,
        masked_segment_sum_mix,
    )

    n = states.p.shape[-1]
    n_dev = topology.n_devices
    mf = mask.astype(w.dtype)

    if topology.kind == "segment":
        sums = masked_segment_sum_mix(
            w, topology.cluster_ids, mf, topology.n_clusters, interpret=interpret
        )
        if topology.head_exchange:
            total = sums.sum(0, keepdims=True)
            p, beta = from_uv_solve(
                total[:, :, :n], total[:, :, n:], ridge=ridge, interpret=interpret
            )
            merged = states.replace(
                beta=_bcast(beta[0], n_dev), p=_bcast(p[0], n_dev)
            )
        else:
            cids = jnp.asarray(topology.cluster_ids)
            pc, betac = from_uv_solve(
                sums[:, :, :n], sums[:, :, n:], ridge=ridge, interpret=interpret
            )
            merged = states.replace(beta=betac[cids], p=pc[cids])
    else:
        wm = w * mf[:, None, None]
        if topology.kind == "banded" and not topology.band_closed:
            p, beta = banded_merge_solve(
                wm, topology.hops, ridge=ridge, interpret=interpret
            )
            merged = states.replace(beta=beta, p=p)
        elif topology.is_fully_connected:
            total = wm.sum(0, keepdims=True)
            p, beta = from_uv_solve(
                total[:, :, :n], total[:, :, n:], ridge=ridge, interpret=interpret
            )
            merged = states.replace(beta=_bcast(beta[0], n_dev), p=_bcast(p[0], n_dev))
        else:
            mixed = dense_mix(wm, topology.dense_matrix(), interpret=interpret)
            p, beta = from_uv_solve(
                mixed[:, :, :n], mixed[:, :, n:], ridge=ridge, interpret=interpret
            )
            merged = states.replace(beta=beta, p=p)

    kf = mf if receive is None else receive.astype(mf.dtype)
    keep = (kf > 0)[:, None, None]
    return states.replace(
        beta=jnp.where(keep, merged.beta, states.beta),
        p=jnp.where(keep, merged.p, states.p),
    )


@partial(jax.jit, static_argnames=("topology", "ridge", "interpret", "payload_precision"))
def fleet_merge_masked_kernel(
    states: OSELMState,
    topology: Topology,
    mask: jnp.ndarray,
    *,
    ridge: float = 0.0,
    interpret: bool = True,
    payload_precision: str = "f32",
) -> OSELMState:
    """``fleet_merge_masked`` through the Pallas merge-kernel family:
    segment topologies gate participation *inside* the segment-sum
    kernel (``masked_segment_sum_mix``, scalar-prefetched mask — the
    masked payload stack never exists in HBM); banded/dense paths fold
    the mask into the payload before the existing kernels.
    ``payload_precision`` applies the one-shot wire codec (fused Pallas
    ``quantize_pack`` for int8) before the mix."""
    uv = fleet_to_uv(states, ridge=ridge)
    w = jnp.concatenate([uv.u, uv.v], axis=2)  # stacked [U | V] payloads
    if payload_precision == "int8":
        from repro.fleet.quantize import dequantize_tiles
        from repro.kernels.quantize_pack import quantize_pack

        codes, scales, _ = quantize_pack(uv.u, uv.v, interpret=interpret)
        w = dequantize_tiles(codes, scales)
    elif payload_precision != "f32":
        w = quantize_roundtrip(w, payload_precision)
    return _masked_kernel_merge_from_w(
        states, topology, jnp.asarray(mask), w, ridge, interpret
    )


def _quantized_merge_body(
    states: OSELMState,
    topology: Topology,
    residual: jnp.ndarray | None,
    payload_precision: str,
    ridge: float,
    mask: jnp.ndarray | None,
    fp_mask: jnp.ndarray | None,
    kernel: bool,
    interpret: bool,
) -> tuple[OSELMState, jnp.ndarray | None]:
    """Unjitted body of ``fleet_merge_quantized`` (the runtime embeds it
    in its own compile-once tick closures)."""
    uv = fleet_to_uv(states, ridge=ridge)
    n = uv.u.shape[1]
    w = jnp.concatenate([uv.u, uv.v], axis=2)
    roundtrip = None
    if kernel and payload_precision == "int8":
        from repro.fleet.quantize import dequantize_tiles
        from repro.kernels.quantize_pack import quantize_pack

        codes, scales, _ = quantize_pack(uv.u, uv.v, residual, interpret=interpret)
        roundtrip = dequantize_tiles(codes, scales)
    w_pub, new_resid = apply_codec(
        w, payload_precision, residual=residual, fp_mask=fp_mask,
        participate=mask, roundtrip=roundtrip,
    )
    uv_pub = UV(u=w_pub[:, :, :n], v=w_pub[:, :, n:])
    if mask is None:
        merged = (
            _kernel_merge_from_w(states, topology, w_pub, ridge, interpret)
            if kernel else _merge_body(states, topology, ridge, uv=uv_pub)
        )
    else:
        mask = jnp.asarray(mask)
        merged = (
            _masked_kernel_merge_from_w(states, topology, mask, w_pub, ridge, interpret)
            if kernel else _masked_merge_body(states, topology, mask, ridge, uv=uv_pub)
        )
    return merged, new_resid


@partial(
    jax.jit,
    static_argnames=("topology", "payload_precision", "ridge", "kernel", "interpret"),
)
def fleet_merge_quantized(
    states: OSELMState,
    topology: Topology,
    *,
    residual: jnp.ndarray | None,
    payload_precision: str = "int8",
    ridge: float = 0.0,
    mask: jnp.ndarray | None = None,
    fp_mask: jnp.ndarray | None = None,
    kernel: bool = False,
    interpret: bool = True,
) -> tuple[OSELMState, jnp.ndarray | None]:
    """The stateful lossy merge round: every participating device
    publishes its error-feedback-compensated quantized payload, the
    topology mixes the published payloads (self-contribution included —
    all members of a fully-connected merge still receive the identical
    model, preserving the solve-per-equivalence-class structure), and
    the per-device residual accumulators advance. Returns
    ``(merged_states, residual')``.

    - ``residual`` — (D, Ñ, Ñ+m) error-feedback backlog from
      ``repro.fleet.quantize.init_residual`` (None = zero backlog,
      one-shot semantics).
    - ``mask`` — optional participation gate, exactly
      ``fleet_merge_masked``; non-participants' residuals are untouched.
    - ``fp_mask`` — optional per-device full-precision override
      (quarantine-risk devices ship exact f32; their residual clears).
    - ``kernel=True`` — publish through the fused Pallas
      ``quantize_pack`` (int8) and merge through the kernel family.
    """
    return _quantized_merge_body(
        states, topology, residual, payload_precision, ridge, mask, fp_mask,
        kernel, interpret,
    )


@jax.jit
def fleet_score(states: OSELMState, x: jnp.ndarray) -> jnp.ndarray:
    """Per-device anomaly scores on shared eval data: (D, k)."""
    return jax.vmap(lambda s: ae_score(s, x))(states)


def _rounds_body(
    states: OSELMState,
    chunks: jnp.ndarray,
    topology: Topology,
    ridge: float,
    kernel: bool,
    backend: str,
    interpret: bool | None,
) -> OSELMState:
    """Compile-once train→merge loop: one ``lax.scan`` over the round
    axis (chunks: (rounds, D, per, feat)) instead of a Python loop
    re-dispatching two jits per round. ``kernel=True`` ingests each
    round's chunk through the fused ``fleet_ingest`` family."""

    def body(st, chunk):
        if kernel:
            from repro.kernels.fleet_ingest import fleet_ingest

            st, _ = fleet_ingest(st, chunk, backend=backend, interpret=interpret)
        else:
            st = _fleet_train(st, chunk)
        return _merge_body(st, topology, ridge), None

    out, _ = jax.lax.scan(body, states, chunks)
    return out


_ROUNDS_STATIC = ("topology", "ridge", "kernel", "backend", "interpret")
_ROUNDS_SCAN = {
    # donate=True lets XLA reuse the input fleet buffers for the scan
    # carry (the CPU backend ignores donation, with a warning)
    True: partial(
        jax.jit, static_argnames=_ROUNDS_STATIC, donate_argnums=(0,)
    )(_rounds_body),
    False: partial(jax.jit, static_argnames=_ROUNDS_STATIC)(_rounds_body),
}


def fleet_train_rounds(
    states: OSELMState,
    streams: jnp.ndarray,
    topology: Topology,
    *,
    rounds: int,
    ridge: float = 0.0,
    donate: bool = False,
    kernel: bool = False,
    backend: str = "auto",
    interpret: bool | None = None,
) -> OSELMState:
    """The paper's "repeatedly applied to synchronize" mode at fleet
    scale: chunk each stream into ``rounds`` pieces, train a chunk,
    merge over the topology, repeat. Synchronous (no staleness) —
    see ``repro.fleet.staleness.fleet_train_async`` for the lagged
    variant.

    The whole loop is a single jitted ``lax.scan`` (compiled once per
    (shape, topology)). Pass ``donate=True`` on accelerator backends to
    donate the input state buffers to the scan — halves peak state
    memory, but invalidates the caller's ``states`` pytree.

    .. note:: When ``steps % rounds != 0`` the tail ``steps % rounds``
       samples of every stream are **dropped** (each round trains on
       exactly ``steps // rounds`` samples); a warning is logged when
       that truncation is nonzero. With ``kernel=True`` a second
       warning fires when the fused lowering pads each round's
       ``steps // rounds``-sample window up to its tile/block size —
       padded slots are masked identity steps (they never update P/β),
       so that padding is wasted work, never a result change.
    """
    streams = jnp.asarray(streams)
    n_dev, steps, feat = streams.shape
    if not 1 <= rounds <= steps:
        raise ValueError(f"need 1 <= rounds={rounds} <= steps={steps}")
    per = steps // rounds
    tail = steps - rounds * per
    if tail:
        log.warning(
            "fleet_train_rounds: steps=%d not divisible by rounds=%d — "
            "dropping the tail %d samples of every device stream",
            steps, rounds, tail,
        )
    if kernel:
        from repro.kernels.fleet_ingest import validate_shared_basis

        validate_shared_basis(states)  # concrete here, pre-jit
        _warn_ingest_padding(per, backend, "fleet_train_rounds")
    chunks = (
        streams[:, : rounds * per]
        .reshape(n_dev, rounds, per, feat)
        .transpose(1, 0, 2, 3)
    )
    return _ROUNDS_SCAN[donate](
        states, chunks, topology, ridge, kernel, backend, interpret
    )


def device_state(states: OSELMState, idx: int) -> OSELMState:
    """Slice one device's state out of the stacked fleet."""
    return jax.tree.map(lambda l: l[idx], states)
