"""Sharded fleet merges: psum-of-segment-sums over mesh axes.

`repro.fleet` simulates the whole fleet as one stacked pytree;
`repro.federated.mesh_federation` runs one device per mesh shard. This
module combines them: the stacked device axis is sharded across mesh
devices (``repro.launch.sharding.fleet_shardings``), each shard
segment-sums its *local* devices' (U, V) into per-cluster partials, and
ONE ``jax.lax.psum`` of the (n_clusters, Ñ, Ñ+m) partials completes
Eq. 8 globally — the per-shard collective is O(clusters), never
O(devices), which is what lets a 10k-device fleet merge over a handful
of TPU shards without all-gathering 10k payloads.

Supported merge structures are the ones whose result is cluster-wise
constant (star, hierarchical, all-to-all, closed ring): those are
exactly the topologies whose collective compresses to cluster
aggregates. The open ring's neighbor sums straddle shard boundaries;
it stays on the single-process ``fleet_merge`` / halo-exchange future
work.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import OSELMState
from repro.federated.compat import revary, shard_map_compat as _shard_map
from repro.fleet.fleet import _bcast, _solve_uv, fleet_to_uv
from repro.fleet.topology import Topology


def _merge_cids(topology: Topology) -> tuple[np.ndarray, int, bool]:
    """(cluster_ids, n_clusters, cluster_isolated) for the topologies
    whose merged model is cluster-wise constant."""
    if topology.kind == "segment":
        return (
            np.asarray(topology.cluster_ids, np.int32),
            topology.n_clusters,
            not topology.head_exchange,
        )
    if topology.is_fully_connected:  # all_to_all / closed ring: one cluster
        return np.zeros(topology.n_devices, np.int32), 1, False
    raise NotImplementedError(
        f"sharded merge needs a cluster-wise-constant topology; "
        f"{topology.name!r} (kind={topology.kind!r}) mixes per-device "
        "neighbor sets across shard boundaries"
    )


def fleet_merge_sharded(
    states: OSELMState,
    topology: Topology,
    mesh: Mesh,
    axes: Sequence[str] = ("data",),
    *,
    ridge: float = 0.0,
) -> OSELMState:
    """Cooperative update of a mesh-sharded stacked fleet.

    ``states`` leaves carry a leading device axis sharded over ``axes``
    (shard it with ``repro.launch.sharding.shard_fleet``). Each shard
    computes local per-cluster (U, V) partial sums, one psum of the
    O(clusters)-sized partials completes the Eq. 8 sum, and each shard
    solves + broadcasts locally. Returns the merged fleet with the same
    sharding.
    """
    cids, n_clusters, isolated = _merge_cids(topology)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    if topology.n_devices % n_shards:
        raise ValueError(
            f"n_devices={topology.n_devices} not divisible by {n_shards} shards"
        )
    spec = P(tuple(axes))

    def body(st: OSELMState, cids_local: jnp.ndarray) -> OSELMState:
        n_local = cids_local.shape[0]
        uv = fleet_to_uv(st, ridge=ridge)  # this shard's devices only
        su = jax.ops.segment_sum(uv.u, cids_local, num_segments=n_clusters)
        sv = jax.ops.segment_sum(uv.v, cids_local, num_segments=n_clusters)
        su = jax.lax.psum(su, tuple(axes))  # O(clusters) per-shard collective
        sv = jax.lax.psum(sv, tuple(axes))
        if isolated:
            pc, betac = jax.vmap(lambda u, v: _solve_uv(u, v, ridge))(su, sv)
            p, beta = pc[cids_local], betac[cids_local]
        else:
            p1, beta1 = _solve_uv(su.sum(0), sv.sum(0), ridge)
            p, beta = _bcast(p1, n_local), _bcast(beta1, n_local)
        return st.replace(
            beta=revary(beta.astype(st.beta.dtype), axes),
            p=revary(p.astype(st.p.dtype), axes),
        )

    fn = _shard_map(body, mesh, in_specs=(spec, spec), out_specs=spec)
    return jax.jit(fn)(states, jnp.asarray(cids))
