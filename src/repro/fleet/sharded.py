"""Sharded fleet training and merges over mesh axes.

`repro.fleet` simulates the whole fleet as one stacked pytree;
`repro.federated.mesh_federation` runs one device per mesh shard. This
module combines them: the stacked device axis is sharded across mesh
devices (``repro.launch.sharding.fleet_shardings``), and both halves
of a federation round run shard-locally:

- ``fleet_train_sharded`` — per-tick ingest is embarrassingly parallel
  over devices, so the vmap+scan train loop (or the fused
  ``fleet_ingest`` kernel family, ``kernel=True``) runs under
  ``shard_map`` with NO collectives at all: each shard trains only its
  resident devices, which is the multi-host deployment where ticks
  arrive per-shard (the ROADMAP's multi-host-ingest item).
- ``fleet_merge_sharded`` — each shard segment-sums its *local*
  devices' (U, V) into per-cluster partials, and ONE ``jax.lax.psum``
  of the (n_clusters, Ñ, Ñ+m) partials completes Eq. 8 globally — the
  per-shard collective is O(clusters), never O(devices), which is what
  lets a 10k-device fleet merge over a handful of TPU shards without
  all-gathering 10k payloads. Cluster-wise-constant topologies (star,
  hierarchical, all-to-all, closed ring) take that psum path; the open
  ring takes a **halo exchange**: each shard ``ppermute``s its ``hops``
  edge (U, V) payload blocks to the adjacent shards (O(hops) payloads
  per shard, never the fleet), then forms its devices' banded neighbor
  sums from the extended local block — so banded merges compose with
  sharded fleets end-to-end.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import OSELMState
from repro.federated.compat import revary, shard_map_compat as _shard_map
from repro.fleet.fleet import _bcast, _fleet_train, _solve_uv, fleet_to_uv
from repro.fleet.topology import Topology


def _merge_cids(topology: Topology) -> tuple[np.ndarray, int, bool]:
    """(cluster_ids, n_clusters, cluster_isolated) for the topologies
    whose merged model is cluster-wise constant."""
    if topology.kind == "segment":
        return (
            np.asarray(topology.cluster_ids, np.int32),
            topology.n_clusters,
            not topology.head_exchange,
        )
    if topology.is_fully_connected:  # all_to_all / closed ring: one cluster
        return np.zeros(topology.n_devices, np.int32), 1, False
    raise NotImplementedError(
        f"sharded merge needs a cluster-wise-constant topology or an "
        f"open ring; {topology.name!r} (kind={topology.kind!r}) mixes "
        "per-device neighbor sets across shard boundaries"
    )


def _mesh_axis_size(mesh: Mesh, axes: Sequence[str]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


# jitted shard_map callables keyed by their closure parameters —
# jax.jit's cache is keyed on the function OBJECT, so wrapping a fresh
# closure per call would re-trace/re-compile every tick of the serve
# loop these functions are the hot path of
_SHARDED_JIT_CACHE: dict[tuple, object] = {}


def _cached_sharded_jit(key: tuple, build):
    fn = _SHARDED_JIT_CACHE.get(key)
    if fn is None:
        fn = _SHARDED_JIT_CACHE[key] = jax.jit(build())
    return fn


def fleet_train_sharded(
    states: OSELMState,
    streams: jnp.ndarray,
    mesh: Mesh,
    axes: Sequence[str] = ("data",),
    *,
    kernel: bool = False,
    backend: str = "auto",
    interpret: bool | None = None,
) -> OSELMState:
    """Per-shard tick ingest of a mesh-sharded stacked fleet.

    ``states`` leaves and ``streams`` (D, T, n) carry a leading device
    axis sharded over ``axes``; every shard trains only its local
    devices (k=1 sequential updates) — zero collectives, so ingest
    scales linearly with shard count. ``kernel=True`` runs each shard's
    local ingest through the fused ``fleet_ingest`` family, same
    dispatch as ``fleet_train(kernel=True)``. Returns the trained fleet
    with the same sharding.
    """
    n_shards = _mesh_axis_size(mesh, axes)
    n_dev = states.beta.shape[0]
    if n_dev % n_shards:
        raise ValueError(f"n_devices={n_dev} not divisible by {n_shards} shards")
    spec = P(tuple(axes))
    from repro.kernels.fleet_ingest import resolve_backend, validate_shared_basis

    if kernel:
        validate_shared_basis(states)  # concrete here, pre-shard_map
    resolved = resolve_backend(backend)

    def build():
        def body(st: OSELMState, xs: jnp.ndarray) -> OSELMState:
            if kernel:
                from repro.kernels.fleet_ingest import fleet_ingest

                st, _ = fleet_ingest(st, xs, backend=resolved, interpret=interpret)
            else:
                st = _fleet_train(st, xs)
            return st

        return _shard_map(
            body, mesh, in_specs=(spec, spec), out_specs=spec,
            # pallas_call has no shard_map replication rule; the body is
            # per-shard-local anyway (no collectives), so the check adds
            # nothing here
            check_rep=not (kernel and resolved == "pallas"),
        )

    fn = _cached_sharded_jit(
        ("train", mesh, tuple(axes), kernel, resolved, interpret), build
    )
    return fn(states, jnp.asarray(streams))


def _halo_ring_merge_body(
    st: OSELMState,
    axis: str,
    n_shards: int,
    hops: int,
    ridge: float,
) -> OSELMState:
    """Open-ring merge of one shard's local devices with a halo
    exchange: ``ppermute`` ships the ``hops`` edge payload blocks to
    each neighboring shard (the only cross-shard traffic — O(hops)
    payloads per shard), after which every local device's ≤2·hops+1
    banded neighbor sum is shard-local. Devices are laid out
    contiguously per shard (device d lives on shard d // L), so the
    global ring order is (shard, local) lexicographic."""
    uv = fleet_to_uv(st, ridge=ridge)
    w = jnp.concatenate([uv.u, uv.v], axis=2)  # (L, Ñ, Ñ+m) local payloads
    if hops > 0:
        fwd = [(i, (i + 1) % n_shards) for i in range(n_shards)]
        bwd = [(i, (i - 1) % n_shards) for i in range(n_shards)]
        # halo from the LEFT neighbor shard: its last `hops` devices
        left = jax.lax.ppermute(w[-hops:], axis, fwd)
        # halo from the RIGHT neighbor shard: its first `hops` devices
        right = jax.lax.ppermute(w[:hops], axis, bwd)
        ext = jnp.concatenate([left, w, right], axis=0)  # (L + 2·hops, ...)
    else:  # hops=0 band: each device merges only itself (w[-0:] would
        ext = w  # be the WHOLE block, shipping a bogus full-shard halo)
    n_local = w.shape[0]
    mixed = ext[:n_local]
    for off in range(1, 2 * hops + 1):
        mixed = mixed + ext[off : off + n_local]
    n = uv.u.shape[1]
    p, beta = jax.vmap(lambda u, v: _solve_uv(u, v, ridge))(
        mixed[:, :, :n], mixed[:, :, n:]
    )
    return st.replace(
        beta=revary(beta.astype(st.beta.dtype), (axis,)),
        p=revary(p.astype(st.p.dtype), (axis,)),
    )


def fleet_merge_sharded(
    states: OSELMState,
    topology: Topology,
    mesh: Mesh,
    axes: Sequence[str] = ("data",),
    *,
    ridge: float = 0.0,
) -> OSELMState:
    """Cooperative update of a mesh-sharded stacked fleet.

    ``states`` leaves carry a leading device axis sharded over ``axes``
    (shard it with ``repro.launch.sharding.shard_fleet``). Each shard
    computes local per-cluster (U, V) partial sums, one psum of the
    O(clusters)-sized partials completes the Eq. 8 sum, and each shard
    solves + broadcasts locally. Open-ring (banded) topologies instead
    take the halo-exchange path: ``ppermute`` of the ``hops`` edge
    payload blocks between adjacent shards, then shard-local banded
    sums + per-device solves. Returns the merged fleet with the same
    sharding.
    """
    n_shards = _mesh_axis_size(mesh, axes)
    if topology.n_devices % n_shards:
        raise ValueError(
            f"n_devices={topology.n_devices} not divisible by {n_shards} shards"
        )
    spec = P(tuple(axes))

    if topology.kind == "banded" and not topology.band_closed:
        if len(axes) != 1:
            raise NotImplementedError(
                "open-ring halo exchange shards over exactly one mesh axis"
            )
        n_local = topology.n_devices // n_shards
        if topology.hops > n_local:
            raise ValueError(
                f"halo exchange needs hops={topology.hops} <= devices per "
                f"shard ({n_local}): a wider band straddles non-adjacent "
                "shards — use fewer shards or the single-process merge"
            )
        fn = _cached_sharded_jit(
            ("halo", mesh, tuple(axes), topology, ridge),
            lambda: _shard_map(
                lambda st: _halo_ring_merge_body(
                    st, axes[0], n_shards, topology.hops, ridge
                ),
                mesh, in_specs=(spec,), out_specs=spec,
            ),
        )
        return fn(states)

    cids, n_clusters, isolated = _merge_cids(topology)

    def body(st: OSELMState, cids_local: jnp.ndarray) -> OSELMState:
        n_local = cids_local.shape[0]
        uv = fleet_to_uv(st, ridge=ridge)  # this shard's devices only
        su = jax.ops.segment_sum(uv.u, cids_local, num_segments=n_clusters)
        sv = jax.ops.segment_sum(uv.v, cids_local, num_segments=n_clusters)
        su = jax.lax.psum(su, tuple(axes))  # O(clusters) per-shard collective
        sv = jax.lax.psum(sv, tuple(axes))
        if isolated:
            pc, betac = jax.vmap(lambda u, v: _solve_uv(u, v, ridge))(su, sv)
            p, beta = pc[cids_local], betac[cids_local]
        else:
            p1, beta1 = _solve_uv(su.sum(0), sv.sum(0), ridge)
            p, beta = _bcast(p1, n_local), _bcast(beta1, n_local)
        return st.replace(
            beta=revary(beta.astype(st.beta.dtype), axes),
            p=revary(p.astype(st.p.dtype), axes),
        )

    fn = _cached_sharded_jit(
        ("merge", mesh, tuple(axes), topology, ridge),
        lambda: _shard_map(body, mesh, in_specs=(spec, spec), out_specs=spec),
    )
    return fn(states, jnp.asarray(cids))


# ------------------------------------------- inter-cohort tier-2 reduction


def _tree_fold(stack: jnp.ndarray) -> jnp.ndarray:
    """Pairwise binary-tree sum over the leading axis — the reduction
    shape the cohort-head overlay actually ships (⌈log₂ n⌉ rounds of
    pairwise exchanges), and the summation order the paged merge's
    ≤1e-5 agreement with flat ``fleet_merge`` is stated against."""
    while stack.shape[0] > 1:
        n = stack.shape[0]
        even = stack[0 : n - (n % 2) : 2] + stack[1::2]
        if n % 2:
            even = jnp.concatenate([even, stack[n - 1 :]], axis=0)
        stack = even
    return stack[0]


def cohort_tree_reduce(
    partials: jnp.ndarray,
    mesh: Mesh | None = None,
    axes: Sequence[str] = ("data",),
) -> jnp.ndarray:
    """Tier-2 of the two-tier cohort merge: reduce the stacked
    per-cohort partial (U, V) sums ``(n_cohorts, R, C) → (R, C)``.

    Eq. 8 is a sum, so the inter-cohort tier is pure reduction — on a
    single device an explicit pairwise binary tree (``_tree_fold``), on
    a mesh the cohort axis is sharded over ``axes``, each shard folds
    its resident cohorts locally, and ONE ``psum`` of the O(Ñ(Ñ+m))
    partial completes the tree — the collective never scales with the
    number of cohorts, let alone devices."""
    partials = jnp.asarray(partials)
    if mesh is None:
        fn = _cached_sharded_jit(("cohort_tree", partials.shape), lambda: _tree_fold)
        return fn(partials)
    n_shards = _mesh_axis_size(mesh, axes)
    if partials.shape[0] % n_shards:
        raise ValueError(
            f"n_cohorts={partials.shape[0]} not divisible by "
            f"{n_shards} mesh shards"
        )

    def body(local: jnp.ndarray) -> jnp.ndarray:
        return jax.lax.psum(_tree_fold(local), tuple(axes))

    fn = _cached_sharded_jit(
        ("cohort_psum", mesh, tuple(axes), partials.shape),
        lambda: _shard_map(
            body, mesh, in_specs=(P(tuple(axes)),), out_specs=P()
        ),
    )
    return fn(partials)
