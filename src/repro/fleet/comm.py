"""Per-round communication-cost accounting for fleet topologies.

Reproduces the paper's communication claim at fleet scale: one
cooperative update ships Ñ(Ñ+m) floats per payload (``payload_nbytes``,
matching ``UV.nbytes`` / ``Payload.nbytes``) *once*, independent of how
much data was trained — versus R-round FedAvg shipping the full model
weights every round (``fedavg_total_cost``).

Topology costs come from ``Topology.payloads_per_round`` (see
``repro.fleet.topology``): star and hierarchical trade the all-to-all
D(D−1) payload pattern for O(D) traffic — the Jung et al. (Sensors
2024) hierarchical clustering cuts D2D traffic by ~75% vs flat
server-based FedAvg in their deployment, and the same structure holds
here exactly because the merge is a sum.
"""
from __future__ import annotations

import dataclasses

from repro.fleet.quantize import payload_precision_nbytes
from repro.fleet.topology import Topology


def payload_nbytes(
    n_hidden: int, n_out: int, itemsize: int = 4, *, precision: str | None = None
) -> int:
    """The paper's per-payload cost: Ñ(Ñ+m) values — U is (Ñ, Ñ), V is
    (Ñ, m). ``precision`` overrides the raw ``itemsize`` with the wire
    codec's exact accounting (int8 adds the per-tile f32 scales —
    see ``repro.fleet.quantize``)."""
    if precision is not None:
        return payload_precision_nbytes(n_hidden, n_out, precision)
    return n_hidden * (n_hidden + n_out) * itemsize


def model_nbytes(n_features: int, n_hidden: int, n_out: int, itemsize: int = 4) -> int:
    """Full SLFN weights (α, b, β) — what FedAvg must ship per round."""
    return (n_features * n_hidden + n_hidden + n_hidden * n_out) * itemsize


@dataclasses.dataclass(frozen=True)
class RoundCost:
    """One merge round's traffic for a topology."""

    topology: str
    n_devices: int
    payloads: int
    bytes_total: int
    precision: str = "f32"      # wire format of the counted payloads

    @property
    def bytes_per_device(self) -> float:
        return self.bytes_total / max(self.n_devices, 1)


def topology_round_cost(
    topology: Topology,
    n_hidden: int,
    n_out: int,
    itemsize: int = 4,
    *,
    precision: str = "f32",
) -> RoundCost:
    """Traffic of ONE cooperative update over ``topology``. With a
    non-f32 ``precision`` every payload is counted at the quantized
    wire size (mixed-precision rounds — some devices shipping f32, the
    rest int8 — are blended by ``MergeGovernor.round_bytes``)."""
    # f32 keeps the raw-itemsize path so callers can still model e.g.
    # f64 wires; lossy precisions use the codec's exact accounting
    nbytes = payload_nbytes(
        n_hidden, n_out, itemsize,
        precision=None if precision == "f32" else precision,
    )
    return RoundCost(
        topology=topology.name,
        n_devices=topology.n_devices,
        payloads=topology.payloads_per_round,
        bytes_total=topology.payloads_per_round * nbytes,
        precision=precision,
    )


def fedavg_total_cost(
    n_devices: int,
    rounds: int,
    n_features: int,
    n_hidden: int,
    n_out: int,
    itemsize: int = 4,
) -> RoundCost:
    """R-round FedAvg baseline: every round each device uploads its
    model and downloads the average (2 transfers/device/round)."""
    nbytes = model_nbytes(n_features, n_hidden, n_out, itemsize)
    payloads = 2 * n_devices * rounds
    return RoundCost(
        topology=f"fedavg_r{rounds}",
        n_devices=n_devices,
        payloads=payloads,
        bytes_total=payloads * nbytes,
    )
