"""Async-staleness model for repeated cooperative updates.

The paper's cooperative update can be "repeatedly applied to
synchronize" devices. In a real fleet the exchanged intermediate
results are not fresh: a device merges peers' (U, V) payloads that lag
by transport/queueing delay. Because Eq. 8 is a plain sum, staleness is
modeled *exactly* by summing lagged snapshots of the published payload
versions — no gradient-staleness approximation is needed.

Model: training proceeds in rounds. Each round every device
  1. trains on its next stream chunk (k=1 sequential steps),
  2. publishes its fresh (U, V) — version r,
  3. merges its OWN fresh (U, V) with each neighbor j's payload of
     version max(0, r − lag[j]) — ``lag[j]`` is device j's publication
     delay in rounds (uplink latency, duty-cycling, ...).

``lag = 0`` everywhere reproduces the synchronous
``fleet_train_rounds`` exactly (tested); growing lags exercise the
realistic skew regime the ROADMAP's async serving work targets.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import UV, OSELMState
from repro.fleet.fleet import fleet_from_uv, fleet_to_uv, fleet_train
from repro.fleet.topology import Topology


@dataclasses.dataclass(frozen=True)
class StalenessSchedule:
    """Per-device publication lags, in merge rounds."""

    lags: np.ndarray  # (D,) int, >= 0

    def __post_init__(self) -> None:
        lags = np.asarray(self.lags)
        if lags.ndim != 1:
            raise ValueError(f"lags must be a (D,) vector, got shape {lags.shape}")
        if lags.size and lags.min() < 0:
            raise ValueError(f"lags must be >= 0, got min {lags.min()}")

    @property
    def max_lag(self) -> int:
        return int(self.lags.max())

    @staticmethod
    def uniform(n_devices: int, lag: int) -> "StalenessSchedule":
        return StalenessSchedule(np.full(n_devices, lag, dtype=np.int32))

    @staticmethod
    def random(
        n_devices: int, max_lag: int, *, seed: int = 0, stragglers: float = 0.0
    ) -> "StalenessSchedule":
        """Lags ~ Uniform{0..max_lag}; a ``stragglers`` fraction of
        devices is pinned to the maximum lag (slow uplinks)."""
        rng = np.random.default_rng(seed)
        lags = rng.integers(0, max_lag + 1, size=n_devices).astype(np.int32)
        n_straggle = int(round(stragglers * n_devices))
        if n_straggle:
            idx = rng.choice(n_devices, size=n_straggle, replace=False)
            lags[idx] = max_lag
        return StalenessSchedule(lags)


def _lagged_gather(hist: jnp.ndarray, lags: jnp.ndarray, r: int) -> jnp.ndarray:
    """hist: (L, D, ...) ring of published versions, slot r%L holding the
    freshest. Returns each source device's payload at version r−lag[j],
    clamped to version 0.

    The ring must hold at least ``max(lags) + 1`` versions; a shorter
    ring would alias version r−lag onto a *newer* slot and silently
    serve fresher payloads than the schedule claims. Validated whenever
    ``lags`` is a concrete array (it is a trace-time constant in every
    in-repo caller)."""
    n_hist = hist.shape[0]
    if not isinstance(lags, jax.core.Tracer):
        max_lag = int(np.max(np.asarray(lags))) if np.asarray(lags).size else 0
        if max_lag >= n_hist:
            raise ValueError(
                f"staleness history holds {n_hist} published versions but the "
                f"schedule lags up to {max_lag} rounds; need history >= "
                f"{max_lag + 1} or the ring aliases fresh payloads"
            )
    versions = jnp.maximum(r - lags, 0)
    slots = versions % n_hist
    return hist[slots, jnp.arange(hist.shape[1])]


def fleet_train_async(
    states: OSELMState,
    streams: jnp.ndarray,
    topology: Topology,
    schedule: StalenessSchedule,
    *,
    rounds: int,
    ridge: float = 0.0,
    history: int | None = None,
) -> OSELMState:
    """Round-based fleet training where merges see stale neighbor
    payloads according to ``schedule``. With all-zero lags this equals
    ``fleet_train_rounds`` on the same topology.

    ``history`` sizes the published-version ring (default: exactly
    ``max_lag + 1``, the minimum). A ring shorter than the schedule's
    lags is a hard error, not a silent clip."""
    streams = jnp.asarray(streams)
    n_dev, steps, feat = streams.shape
    if n_dev != topology.n_devices or n_dev != len(schedule.lags):
        raise ValueError("device-count mismatch between streams/topology/schedule")
    if not 1 <= rounds <= steps:
        raise ValueError(f"need 1 <= rounds={rounds} <= steps={steps}")
    per = steps // rounds
    chunks = streams[:, : rounds * per].reshape(n_dev, rounds, per, feat)

    lags = jnp.asarray(schedule.lags)
    n_hist = schedule.max_lag + 1 if history is None else history
    if n_hist <= schedule.max_lag:
        raise ValueError(
            f"history={n_hist} cannot represent lags up to {schedule.max_lag}; "
            f"need history >= {schedule.max_lag + 1}"
        )
    # dense mask works for every topology kind; the diagonal is handled
    # separately so a device always merges its own FRESH statistics
    m = jnp.asarray(topology.dense_matrix())
    m_off = m - jnp.eye(n_dev, dtype=m.dtype)

    hist_u = hist_v = None  # (L, D, Ñ, Ñ) / (L, D, Ñ, m) published versions

    @jax.jit
    def merge_round(states, hist_u, hist_v, r):
        fresh = fleet_to_uv(states, ridge=ridge)
        hist_u = hist_u.at[r % n_hist].set(fresh.u)
        hist_v = hist_v.at[r % n_hist].set(fresh.v)
        stale_u = _lagged_gather(hist_u, lags, r)
        stale_v = _lagged_gather(hist_v, lags, r)
        merged = UV(
            u=fresh.u + jnp.einsum("ij,j...->i...", m_off, stale_u),
            v=fresh.v + jnp.einsum("ij,j...->i...", m_off, stale_v),
        )
        return fleet_from_uv(states, merged, ridge=ridge), hist_u, hist_v

    for r in range(rounds):
        states = fleet_train(states, chunks[:, r])
        if hist_u is None:
            uv0 = fleet_to_uv(states, ridge=ridge)
            hist_u = jnp.zeros((n_hist,) + uv0.u.shape, uv0.u.dtype)
            hist_v = jnp.zeros((n_hist,) + uv0.v.shape, uv0.v.dtype)
            # version-0 backfill: before anyone has published, peers see
            # the round-0 payloads (clamped), not zeros
            hist_u = jnp.broadcast_to(uv0.u[None], hist_u.shape)
            hist_v = jnp.broadcast_to(uv0.v[None], hist_v.shape)
        states, hist_u, hist_v = merge_round(states, hist_u, hist_v, jnp.int32(r))
    return states
