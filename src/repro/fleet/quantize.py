"""Block-quantized (U, V) merge payloads with error feedback.

The paper's economy claim is that exchanging the E²LM intermediate
results (U, V) is cheap; this module makes it ~4× cheaper again by
quantizing the stacked merge payload w = [U | V] before it is
"shipped" (mixed), exactly the selective-resource framing of the
Pareto-FL line of work (Sensors 2024):

- **per-tile int8 block quantization** — the payload's column axis is
  tiled in ``TILE_COLS``-wide blocks; each (device, tile) slab is
  quantized symmetrically against its own absolute maximum and shipped
  as int8 codes plus ONE f32 scale per tile (``quantize_tiles`` /
  ``dequantize_tiles``). U (a Gram matrix) and V live at very
  different magnitudes, so per-tile scales are what keep the merged
  solve well-conditioned — a single global scale would crush V.
- **f16 payloads** — a straight half-precision round-trip; no scales.
- **error feedback** — quantization error is never discarded: each
  device accumulates the residual ``r ← (w + r) − dq(q(w + r))`` and
  adds it back before the NEXT publish, so the published payload
  sequence telescopes to the true sequence and repeated lossy merges
  stay unbiased (the classic EF-compression argument, applied to the
  state exchange).
- **mixed-precision rounds** — ``apply_codec`` takes a per-device
  ``fp_mask``: flagged (quarantine-risk) devices publish exact f32
  payloads (and their residual backlog is cleared — the exact state
  supersedes it), stable devices publish int8. Participation masking
  composes: a masked-out device publishes nothing and its residual is
  untouched.

The Pallas fusion of this codec into the merge pack lives in
``repro.kernels.quantize_pack`` (this module is its XLA reference);
byte accounting for mixed-precision rounds is in ``repro.fleet.comm``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import OSELMState

__all__ = [
    "PRECISIONS",
    "ITEMSIZE",
    "TILE_COLS",
    "apply_codec",
    "dequantize_tiles",
    "init_residual",
    "n_col_tiles",
    "payload_precision_nbytes",
    "quantize_roundtrip",
    "quantize_tiles",
    "validate_precision",
]

PRECISIONS = ("f32", "f16", "int8")
ITEMSIZE = {"f32": 4, "f16": 2, "int8": 1}
TILE_COLS = 128          # one scale per (device, 128-column) payload slab
SCALE_ITEMSIZE = 4       # per-tile scales ship as f32
INT8_MAX = 127.0


def validate_precision(precision: str) -> None:
    if precision not in PRECISIONS:
        raise ValueError(
            f"unknown payload precision {precision!r}; have {PRECISIONS}"
        )


def n_col_tiles(n_cols: int, tile_cols: int = TILE_COLS) -> int:
    """Number of quantization tiles (= per-payload scales) for a
    payload with ``n_cols`` columns."""
    return -(-n_cols // tile_cols)


def payload_precision_nbytes(
    n_hidden: int, n_out: int, precision: str, *, tile_cols: int = TILE_COLS
) -> int:
    """Bytes ONE (U, V) payload ships at ``precision``: Ñ(Ñ+m) codes at
    the precision's itemsize, plus one f32 scale per column tile for
    int8 (f32/f16 ship no scales)."""
    validate_precision(precision)
    numel = n_hidden * (n_hidden + n_out)
    if precision == "int8":
        return numel + n_col_tiles(n_hidden + n_out, tile_cols) * SCALE_ITEMSIZE
    return numel * ITEMSIZE[precision]


# --------------------------------------------------------------- tile codec


def quantize_tiles(
    x: jnp.ndarray, *, tile_cols: int = TILE_COLS
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tile int8 quantization of a stacked (D, R, C)
    payload. Returns ``(codes, scales)``: int8 codes of the input shape
    and one f32 scale per (device, column-tile), so
    ``scales.shape == (D, ceil(C / tile_cols))``. An all-zero tile gets
    scale 1.0 (codes 0) rather than a 0-divide."""
    d, r, c = x.shape
    nt = n_col_tiles(c, tile_cols)
    cp = nt * tile_cols
    xp = jnp.pad(x, ((0, 0), (0, 0), (0, cp - c)))
    xt = xp.reshape(d, r, nt, tile_cols)
    amax = jnp.max(jnp.abs(xt), axis=(1, 3))                     # (D, nt)
    scales = jnp.where(amax > 0, amax / INT8_MAX, 1.0).astype(jnp.float32)
    q = jnp.round(xt / scales[:, None, :, None])
    codes = jnp.clip(q, -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return codes.reshape(d, r, cp)[:, :, :c], scales


def dequantize_tiles(
    codes: jnp.ndarray, scales: jnp.ndarray, *, tile_cols: int = TILE_COLS
) -> jnp.ndarray:
    """Inverse of ``quantize_tiles``: codes (D, R, C) int8 + per-tile
    scales (D, nt) → f32 payload (D, R, C)."""
    d, r, c = codes.shape
    nt = scales.shape[1]
    cp = nt * tile_cols
    ct = jnp.pad(codes, ((0, 0), (0, 0), (0, cp - c))).reshape(d, r, nt, tile_cols)
    out = ct.astype(jnp.float32) * scales[:, None, :, None]
    return out.reshape(d, r, cp)[:, :, :c]


def quantize_roundtrip(
    x: jnp.ndarray, precision: str, *, tile_cols: int = TILE_COLS
) -> jnp.ndarray:
    """What the network delivers: the payload after one quantize →
    dequantize trip at ``precision`` (identity for f32)."""
    validate_precision(precision)
    if precision == "f32":
        return x
    if precision == "f16":
        return x.astype(jnp.float16).astype(jnp.float32)
    codes, scales = quantize_tiles(x, tile_cols=tile_cols)
    return dequantize_tiles(codes, scales, tile_cols=tile_cols)


# ---------------------------------------------------- error-feedback codec


def apply_codec(
    w: jnp.ndarray,
    precision: str,
    *,
    residual: jnp.ndarray | None = None,
    fp_mask: jnp.ndarray | None = None,
    participate: jnp.ndarray | None = None,
    tile_cols: int = TILE_COLS,
    roundtrip: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray | None]:
    """One publish step of the lossy payload exchange.

    ``w`` is the stacked (D, R, C) payload [U | V]. Returns
    ``(published, residual')``:

    - quantized devices publish ``dq(q(w + residual))`` and carry the
      new error ``(w + residual) − published`` forward (error feedback);
    - ``fp_mask`` devices (quarantine-risk) publish exact ``w`` and
      their residual resets to 0 — the exact state supersedes any
      backlog;
    - ``participate``-masked-out devices publish nothing this round
      (their ``published`` row is their exact ``w``, which the merge
      mask zeroes anyway) and their residual is untouched.

    ``roundtrip`` optionally injects a precomputed dequantized payload
    (the Pallas ``quantize_pack`` kernel's output) so the kernel and
    XLA paths share this blending logic. With ``residual=None`` the
    codec is one-shot (no feedback state): residual' is still returned
    (from a zero backlog) so callers can opt in later.
    """
    validate_precision(precision)
    if precision == "f32":
        return w, residual
    r0 = jnp.zeros_like(w) if residual is None else residual
    x = w + r0
    if roundtrip is None:
        roundtrip = quantize_roundtrip(x, precision, tile_cols=tile_cols)
    published = roundtrip
    new_r = x - roundtrip
    if fp_mask is not None:
        fp = jnp.asarray(fp_mask).astype(bool)[:, None, None]
        published = jnp.where(fp, w, published)
        new_r = jnp.where(fp, 0.0, new_r)
    if participate is not None:
        live = jnp.asarray(participate).astype(bool)[:, None, None]
        published = jnp.where(live, published, w)
        new_r = jnp.where(live, new_r, r0)
    return published, new_r


def init_residual(states: OSELMState) -> jnp.ndarray:
    """A zeroed error-feedback accumulator for a stacked fleet: one
    (Ñ, Ñ+m) payload residual per device."""
    d, n = states.p.shape[0], states.p.shape[-1]
    m = states.beta.shape[-1]
    return jnp.zeros((d, n, n + m), jnp.float32)
