"""repro.fleet — fleet-scale federation simulator.

Simulates hundreds-to-thousands of virtual OS-ELM edge devices in one
process as a single stacked pytree (``vmap`` over devices, ``scan``
over streams), with topology-aware cooperative updates (all-to-all /
star / ring gossip / hierarchical clusters), an async-staleness model
for repeated synchronization under realistic payload lag, a non-IID
stream partitioner with drift injection, and per-round communication
accounting.

The ROADMAP's scaling line is built in: sparse topology mixing that
never forms the D×D mask (``topology``), the Pallas banded/segment
merge-kernel path fused with the Eq. 8 solve (``fleet_merge_kernel`` /
``repro.kernels.topology_merge``), and mesh-sharded merges that lower
to a psum of O(clusters) segment sums per shard (``sharded``).
Serve-loop integration still builds on the stacked-(U, V) layout
defined here.
"""
from repro.fleet.arena import (
    CohortMerger,
    CohortSchedule,
    FleetArena,
    TierCost,
    cohort_round_cost,
    init_arena,
)
from repro.fleet.faults import FAULT_KINDS, FaultInjector, FaultSpec
from repro.fleet.robust import (
    RobustConfig,
    finite_payload_mask,
    fleet_merge_robust,
    payload_clip,
    payload_outlier_scores,
    robust_merge_from_w,
)
from repro.fleet.comm import (
    RoundCost,
    fedavg_total_cost,
    model_nbytes,
    payload_nbytes,
    topology_round_cost,
)
from repro.fleet.fleet import (
    device_state,
    fleet_from_uv,
    fleet_merge,
    fleet_merge_kernel,
    fleet_merge_masked,
    fleet_merge_masked_kernel,
    fleet_merge_quantized,
    fleet_score,
    fleet_to_uv,
    fleet_train,
    fleet_train_rounds,
    init_fleet,
)
from repro.fleet.quantize import (
    apply_codec,
    dequantize_tiles,
    init_residual,
    payload_precision_nbytes,
    quantize_roundtrip,
    quantize_tiles,
)
from repro.fleet.sharded import (
    cohort_tree_reduce,
    fleet_merge_sharded,
    fleet_train_sharded,
)
from repro.fleet.partition import (
    DriftEvent,
    FleetStreams,
    make_fleet_streams,
    random_drift_schedule,
)
from repro.fleet.staleness import StalenessSchedule, fleet_train_async
from repro.fleet.topology import (
    TOPOLOGIES,
    Topology,
    all_to_all,
    hierarchical,
    make_topology,
    ring,
    star,
)

__all__ = [
    "CohortMerger", "CohortSchedule", "FleetArena", "TierCost",
    "cohort_round_cost", "cohort_tree_reduce", "init_arena",
    "FAULT_KINDS", "FaultInjector", "FaultSpec",
    "RobustConfig", "finite_payload_mask", "fleet_merge_robust",
    "payload_clip", "payload_outlier_scores", "robust_merge_from_w",
    "RoundCost", "fedavg_total_cost", "model_nbytes", "payload_nbytes",
    "topology_round_cost",
    "device_state", "fleet_from_uv", "fleet_merge", "fleet_merge_kernel",
    "fleet_merge_masked", "fleet_merge_masked_kernel", "fleet_merge_quantized",
    "fleet_merge_sharded",
    "fleet_to_uv", "fleet_score", "fleet_train", "fleet_train_rounds",
    "fleet_train_sharded", "init_fleet",
    "apply_codec", "dequantize_tiles", "init_residual",
    "payload_precision_nbytes", "quantize_roundtrip", "quantize_tiles",
    "DriftEvent", "FleetStreams", "make_fleet_streams", "random_drift_schedule",
    "StalenessSchedule", "fleet_train_async",
    "TOPOLOGIES", "Topology", "all_to_all", "hierarchical", "make_topology",
    "ring", "star",
]
