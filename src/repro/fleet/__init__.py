"""repro.fleet — fleet-scale federation simulator.

Simulates hundreds-to-thousands of virtual OS-ELM edge devices in one
process as a single stacked pytree (``vmap`` over devices, ``scan``
over streams), with topology-aware cooperative updates (all-to-all /
star / ring gossip / hierarchical clusters), an async-staleness model
for repeated synchronization under realistic payload lag, a non-IID
stream partitioner with drift injection, and per-round communication
accounting.

This is the substrate for the ROADMAP's scaling line: sharded fleets
over mesh axes, Pallas segment-sum merge kernels, and serve-loop
integration all build on the stacked-(U, V) layout defined here.
"""
from repro.fleet.comm import (
    RoundCost,
    fedavg_total_cost,
    model_nbytes,
    payload_nbytes,
    topology_round_cost,
)
from repro.fleet.fleet import (
    device_state,
    fleet_from_uv,
    fleet_merge,
    fleet_score,
    fleet_to_uv,
    fleet_train,
    fleet_train_rounds,
    init_fleet,
)
from repro.fleet.partition import (
    DriftEvent,
    FleetStreams,
    make_fleet_streams,
    random_drift_schedule,
)
from repro.fleet.staleness import StalenessSchedule, fleet_train_async
from repro.fleet.topology import (
    TOPOLOGIES,
    Topology,
    all_to_all,
    hierarchical,
    make_topology,
    ring,
    star,
)

__all__ = [
    "RoundCost", "fedavg_total_cost", "model_nbytes", "payload_nbytes",
    "topology_round_cost",
    "device_state", "fleet_from_uv", "fleet_merge", "fleet_score",
    "fleet_to_uv", "fleet_train", "fleet_train_rounds", "init_fleet",
    "DriftEvent", "FleetStreams", "make_fleet_streams", "random_drift_schedule",
    "StalenessSchedule", "fleet_train_async",
    "TOPOLOGIES", "Topology", "all_to_all", "hierarchical", "make_topology",
    "ring", "star",
]
