"""Host-side fleet arena, cohort schedule, and the two-tier cohort merge.

Every layer since PR 1 assumed the whole stacked fleet is ONE resident
device array, which caps D at device memory (benchmarks topped out at
D=4096). This module removes that assumption for D ≫ 10⁵:

- ``FleetArena`` — the per-device OS-ELM state (P, β) lives in host
  numpy, (D, Ñ, Ñ) + (D, Ñ, m); the random SLFN basis (α, b) is stored
  ONCE (Eq. 8 requires all devices to share it, so replicating it per
  device — the stacked-fleet layout — is pure waste at arena scale).
  At Ñ=4, m=8 one million devices is ~192 MB of arena — host memory,
  not HBM. ``page()`` views a cohort's slice as an ``OSELMState`` whose
  2-D shared basis streams through the fused ingest kernel family
  unchanged (``fleet_ingest`` reads the basis via ``_shared_basis``,
  which passes an unstacked (n, Ñ) basis through without broadcast).
- ``CohortSchedule`` — which contiguous device block is resident when:
  D must divide into equal cohorts so every page has the same shape and
  the jitted per-page closures compile once.
- ``CohortMerger`` — Eq. 8 as a two-tier tree. Tier 1 (intra-cohort)
  masked segment sums of the resident page's (U, V) payloads — the
  Pallas ``masked_segment_sum_mix`` kernel or its XLA twin. Tier 2
  (inter-cohort) reduces the O(clusters)-sized partials: a pairwise
  binary tree / mesh psum (``repro.fleet.sharded.cohort_tree_reduce``)
  for cluster-wise-constant topologies, a boundary-halo exchange for
  the open ring. Because the cooperative update is a SUM, the tree
  reorders but never changes the result (≤1e-5 vs flat
  ``fleet_merge``, asserted in tests/test_cohort.py).
- ``cohort_round_cost`` — per-tier payload/byte accounting: tier 1
  stays inside a cohort (cheap, local links), tier 2 is what crosses
  the cohort-head overlay (the traffic that matters at fleet scale).

The hierarchical/location-clustered structure mirrors Jung et al.
(Sensors 2024): devices cluster to a head, heads exchange aggregates —
here cohorts are the residency unit and clusters the topology unit,
and the merge handles clusters nesting inside, spanning, or straddling
cohort boundaries identically (partial sums just add up).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import OSELMState, init_oselm, init_slfn
from repro.core.elm import SLFNParams, invert_u
from repro.fleet.comm import payload_nbytes
from repro.fleet.fleet import _solve_uv
from repro.fleet.sharded import cohort_tree_reduce
from repro.fleet.topology import Topology

__all__ = [
    "FleetArena",
    "CohortSchedule",
    "CohortMerger",
    "TierCost",
    "cohort_round_cost",
    "init_arena",
]


# ------------------------------------------------------------------ arena


@dataclasses.dataclass
class FleetArena:
    """Host-resident fleet state: shared basis once, (P, β) per device."""

    alpha: np.ndarray        # (n_features, Ñ) shared random basis
    bias: np.ndarray         # (Ñ,)
    p: np.ndarray            # (D, Ñ, Ñ) float32
    beta: np.ndarray         # (D, Ñ, m) float32
    activation: str = "sigmoid"
    forget: float = 1.0

    def __post_init__(self) -> None:
        if self.p.ndim != 3 or self.beta.ndim != 3:
            raise ValueError(
                f"arena (P, β) must be (D, Ñ, ·): got {self.p.shape}, "
                f"{self.beta.shape}"
            )
        if self.p.shape[0] != self.beta.shape[0]:
            raise ValueError(
                f"P and β disagree on D: {self.p.shape[0]} vs "
                f"{self.beta.shape[0]}"
            )

    @property
    def n_devices(self) -> int:
        return self.p.shape[0]

    @property
    def n_hidden(self) -> int:
        return self.p.shape[1]

    @property
    def n_out(self) -> int:
        return self.beta.shape[2]

    @property
    def n_features(self) -> int:
        return self.alpha.shape[0]

    @property
    def nbytes(self) -> int:
        return int(
            self.alpha.nbytes + self.bias.nbytes
            + self.p.nbytes + self.beta.nbytes
        )

    @classmethod
    def from_fleet(cls, states: OSELMState) -> "FleetArena":
        """Adopt a stacked resident fleet (basis must be shared — it is
        checked, because Eq. 8 is meaningless otherwise)."""
        alpha = np.asarray(states.params.alpha)
        bias = np.asarray(states.params.bias)
        if alpha.ndim == 3:
            if not (np.all(alpha == alpha[:1]) and np.all(bias == bias[:1])):
                raise ValueError(
                    "stacked fleet does not share its SLFN basis — the "
                    "arena stores the basis once, and Eq. 8 merges are "
                    "only meaningful over a shared basis"
                )
            alpha, bias = alpha[0], bias[0]
        return cls(
            alpha=alpha.copy(),
            bias=bias.copy(),
            p=np.asarray(states.p, np.float32).copy(),
            beta=np.asarray(states.beta, np.float32).copy(),
            activation=states.activation,
            forget=states.forget,
        )

    def page(self, lo: int, hi: int) -> OSELMState:
        """The cohort's slice as an ``OSELMState`` with the UNSTACKED
        shared basis — numpy views, zero copies; the fused ingest
        lowerings consume this shape directly (no per-device basis
        broadcast is ever materialized)."""
        return OSELMState(
            params=SLFNParams(alpha=self.alpha, bias=self.bias),
            beta=self.beta[lo:hi],
            p=self.p[lo:hi],
            activation=self.activation,
            forget=self.forget,
        )

    def write_page(
        self,
        lo: int,
        hi: int,
        p,
        beta,
        where: np.ndarray | None = None,
    ) -> None:
        """Scatter a computed page back (``where`` row-masks the write —
        unserved / non-receiving devices keep their arena state)."""
        p = np.asarray(p, np.float32)
        beta = np.asarray(beta, np.float32)
        if where is None:
            self.p[lo:hi] = p
            self.beta[lo:hi] = beta
        else:
            w = np.asarray(where, bool)
            self.p[lo:hi][w] = p[w]
            self.beta[lo:hi][w] = beta[w]

    def to_fleet(self) -> OSELMState:
        """Materialize the full stacked fleet (basis broadcast per
        device) — for evaluation and differential tests at small D;
        at arena scale this is exactly the layout the arena exists to
        avoid."""
        d = self.n_devices
        return OSELMState(
            params=SLFNParams(
                alpha=jnp.broadcast_to(self.alpha, (d,) + self.alpha.shape),
                bias=jnp.broadcast_to(self.bias, (d,) + self.bias.shape),
            ),
            beta=jnp.asarray(self.beta),
            p=jnp.asarray(self.p),
            activation=self.activation,
            forget=self.forget,
        )


def init_arena(
    key: jax.Array,
    n_devices: int,
    n_features: int,
    n_hidden: int,
    x_init_fn,
    *,
    cohort_size: int,
    activation: str = "sigmoid",
    ridge: float = 0.0,
    forget: float = 1.0,
) -> FleetArena:
    """Paged ``init_fleet``: one shared ``init_slfn`` basis, then Eq. 13
    per-cohort — ``x_init_fn(lo, hi) -> (hi-lo, n_init, n_features)``
    supplies each cohort's boot chunks, so the full (D, n_init, n)
    array never exists. One jitted init per page shape."""
    if n_hidden >= n_features:
        raise ValueError(
            f"autoencoder needs a bottleneck: Ñ={n_hidden} >= n={n_features}"
        )
    schedule = CohortSchedule(n_devices, cohort_size)
    params = init_slfn(key, n_features, n_hidden)

    @jax.jit
    def _init(x0):
        def one(x):
            return init_oselm(
                params, x, x,
                activation=activation, ridge=ridge, forget=forget,
            )

        st = jax.vmap(one)(x0)
        return st.p, st.beta

    p = beta = None
    for lo, hi in schedule.bounds():
        pc, bc = _init(jnp.asarray(x_init_fn(lo, hi), jnp.float32))
        if p is None:
            p = np.empty((n_devices,) + pc.shape[1:], np.float32)
            beta = np.empty((n_devices,) + bc.shape[1:], np.float32)
        p[lo:hi] = np.asarray(pc)
        beta[lo:hi] = np.asarray(bc)
    return FleetArena(
        alpha=np.asarray(params.alpha),
        bias=np.asarray(params.bias),
        p=p,
        beta=beta,
        activation=activation,
        forget=forget,
    )


# --------------------------------------------------------------- schedule


@dataclasses.dataclass(frozen=True)
class CohortSchedule:
    """Which contiguous device block is device-resident when.

    Equal cohorts (D divisible by ``cohort_size``) keep every page the
    same shape, so the per-page jits compile exactly once.
    ``active_per_tick=None`` serves every cohort every tick; an integer
    round-robins that many cohorts per tick (the remaining devices'
    state stays untouched in the arena — they still contribute to
    merge rounds, they just are not serving new samples)."""

    n_devices: int
    cohort_size: int
    active_per_tick: int | None = None

    def __post_init__(self) -> None:
        if not 1 <= self.cohort_size <= self.n_devices:
            raise ValueError(
                f"need 1 <= cohort_size <= D: {self.cohort_size} vs "
                f"D={self.n_devices}"
            )
        if self.n_devices % self.cohort_size:
            raise ValueError(
                f"D={self.n_devices} not divisible by cohort_size="
                f"{self.cohort_size}: ragged pages would retrace the "
                "per-page jits"
            )
        if self.active_per_tick is not None and not (
            1 <= self.active_per_tick <= self.n_cohorts
        ):
            raise ValueError(
                f"active_per_tick={self.active_per_tick} outside "
                f"[1, {self.n_cohorts}]"
            )

    @property
    def n_cohorts(self) -> int:
        return self.n_devices // self.cohort_size

    def bounds(self, k: int | None = None):
        """(lo, hi) of cohort ``k``, or all cohorts' bounds in order."""
        if k is not None:
            return k * self.cohort_size, (k + 1) * self.cohort_size
        return [
            (i * self.cohort_size, (i + 1) * self.cohort_size)
            for i in range(self.n_cohorts)
        ]

    def active(self, tick: int) -> list[int]:
        """Cohorts served on ``tick`` (round-robin window)."""
        n = self.n_cohorts
        a = self.active_per_tick
        if a is None or a >= n:
            return list(range(n))
        start = (tick * a) % n
        return [(start + i) % n for i in range(a)]


# ----------------------------------------------------------- tier costs


@dataclasses.dataclass(frozen=True)
class TierCost:
    """One two-tier merge round's traffic, split by tier. Tier 1 is the
    device↔cohort-aggregator traffic that never leaves a cohort; tier 2
    is what crosses the cohort-head overlay (tree / halo) — the number
    that must stay O(cohorts·clusters), never O(devices)."""

    topology: str
    n_devices: int
    n_cohorts: int
    tier1_payloads: int
    tier2_payloads: int
    payload_bytes: int

    @property
    def bytes_tier1(self) -> int:
        return self.tier1_payloads * self.payload_bytes

    @property
    def bytes_tier2(self) -> int:
        return self.tier2_payloads * self.payload_bytes

    @property
    def bytes_total(self) -> int:
        return self.bytes_tier1 + self.bytes_tier2


def cohort_round_cost(
    topology: Topology,
    schedule: CohortSchedule,
    n_hidden: int,
    n_out: int,
    *,
    itemsize: int = 4,
    precision: str = "f32",
) -> TierCost:
    """Per-tier payload counts of ONE two-tier cooperative update.

    - Cluster-wise-constant topologies (star / all-to-all / closed ring
      / head-exchange hierarchical): every non-aggregator device ships
      up + downloads down inside its cohort (tier 1 = 2(D − n_cohorts));
      cohort heads run a pairwise reduction tree and broadcast back
      (tier 2 = 2(n_cohorts − 1)).
    - Isolated hierarchical clusters: members exchange with their
      cluster head (tier 1 = 2(D − n_clusters)); tier 2 is only the
      straddle traffic — a cluster spanning c > 1 cohorts ships c − 1
      partial sums (and downloads) across the overlay; clusters nested
      inside one cohort cost tier 2 nothing.
    - Open ring: the band is local, so tier 1 is the in-cohort share of
      the flat ring traffic and tier 2 the 2·hops payload halo each
      cohort boundary exchanges per direction.
    """
    if topology.n_devices != schedule.n_devices:
        raise ValueError(
            f"topology D={topology.n_devices} vs schedule "
            f"D={schedule.n_devices}"
        )
    nb = payload_nbytes(
        n_hidden, n_out, itemsize,
        precision=None if precision == "f32" else precision,
    )
    d, nc = schedule.n_devices, schedule.n_cohorts
    if topology.kind == "segment" and not topology.head_exchange:
        cids = np.asarray(topology.cluster_ids)
        incidences = sum(
            len(np.unique(cids[lo:hi])) for lo, hi in schedule.bounds()
        )
        tier1 = 2 * (d - topology.n_clusters)
        tier2 = 2 * (incidences - topology.n_clusters)
    elif topology.kind == "banded" and not topology.band_closed:
        tier2 = 2 * topology.hops * nc
        tier1 = max(topology.payloads_per_round - tier2, 0)
    elif topology.is_fully_connected or topology.kind == "segment":
        tier1 = 2 * (d - nc)
        tier2 = 2 * (nc - 1)
    else:
        raise NotImplementedError(
            f"no two-tier decomposition for topology {topology.name!r} "
            f"(kind={topology.kind!r})"
        )
    return TierCost(
        topology=topology.name,
        n_devices=d,
        n_cohorts=nc,
        tier1_payloads=int(tier1),
        tier2_payloads=int(tier2),
        payload_bytes=int(nb),
    )


# ------------------------------------------------------- two-tier merge


class CohortMerger:
    """Eq. 8 over a paged arena, one cohort page resident at a time.

    Modes, chosen from the topology:

    - ``global`` (star / all-to-all / closed ring / head-exchange
      hierarchical — any merged model that is fleet-wide constant):
      tier 1 reduces each page to ONE (Ñ, Ñ+m) masked partial sum
      (Pallas ``masked_segment_sum_mix`` with a single segment, or the
      XLA sum), tier 2 folds the (n_cohorts, Ñ, Ñ+m) stack through
      ``cohort_tree_reduce`` (pairwise tree, or psum over a mesh), and
      one §4.2 solve serves every participant.
    - ``clusters`` (isolated hierarchical): tier 1 segment-sums each
      page over its LOCAL cluster ids; tier 2 scatter-adds the per-page
      partials into the global (n_clusters, Ñ, Ñ+m) accumulator —
      clusters that straddle a cohort boundary just contribute from
      both pages (a sum is a sum); per-cluster solves, then each page
      gathers its devices' cluster solutions back.
    - ``ring`` (open banded): each page extends itself with ``hops``
      pre-merge halo rows from both neighbors (snapshotted before any
      page writes back, so in-place scatters never leak merged state
      into a later page's halo), forms the banded window sums over the
      extended block, and solves per device — the paged twin of the
      sharded ``ppermute`` halo exchange.

    ``kernel="auto"`` follows the repo's dispatch convention: Pallas on
    TPU, XLA elsewhere (the Pallas interpreter on CPU is a correctness
    tool, not a fast path). All per-page callables are jitted once per
    page shape (and, for ``clusters``, per unique local-cluster-id
    pattern); participation masks are traced operands, so governor
    gating never retraces — same contract as the resident merge.
    """

    def __init__(
        self,
        topology: Topology,
        schedule: CohortSchedule,
        *,
        ridge: float = 0.0,
        kernel: bool | str = "auto",
        interpret: bool | None = None,
        mesh=None,
        mesh_axes=("data",),
    ) -> None:
        if topology.n_devices != schedule.n_devices:
            raise ValueError(
                f"topology D={topology.n_devices} vs schedule "
                f"D={schedule.n_devices}"
            )
        self.topology = topology
        self.schedule = schedule
        self.ridge = float(ridge)
        on_tpu = jax.default_backend() == "tpu"
        if kernel == "auto":
            kernel = on_tpu
        self.kernel = bool(kernel)
        self.interpret = (not on_tpu) if interpret is None else interpret
        self.mesh = mesh
        self.mesh_axes = tuple(mesh_axes)
        self._jits: dict = {}

        if topology.kind == "segment" and not topology.head_exchange:
            self.mode = "clusters"
            cids = np.asarray(topology.cluster_ids, np.int64)
            if cids.shape[0] != topology.n_devices or np.any(np.diff(cids) < 0):
                raise ValueError(
                    "cluster_ids must be sorted/contiguous (as built by "
                    "fleet.topology.hierarchical) — the paged segment "
                    "sums assume each page's clusters are a contiguous "
                    "id range"
                )
            self._cids = cids
            # per cohort: local ids (offset to 0) + the global offset;
            # k_max pads every page's partial to one static shape so a
            # single trace serves all pages sharing a local-id pattern
            self._locals = []
            k_max = 1
            for lo, hi in schedule.bounds():
                sl = cids[lo:hi]
                off = int(sl[0])
                local = (sl - off).astype(np.int32)
                k_max = max(k_max, int(local[-1]) + 1)
                self._locals.append((off, local))
            self._k_max = k_max
        elif topology.kind == "banded" and not topology.band_closed:
            self.mode = "ring"
            if 2 * topology.hops >= topology.n_devices:
                raise ValueError("open band wider than the fleet")
        elif topology.is_fully_connected or topology.kind == "segment":
            self.mode = "global"
        else:
            raise NotImplementedError(
                f"two-tier merge needs a cluster-wise-constant topology "
                f"or an open ring; {topology.name!r} "
                f"(kind={topology.kind!r}) mixes per-device neighbor "
                "sets that do not decompose over cohorts"
            )

    # -- payload math shared by every mode: the resident fleet_to_uv,
    # minus the per-device basis (a page's basis is the one shared copy)
    def _w_of(self, p, beta):
        u = jax.vmap(lambda pp: invert_u(pp, ridge=self.ridge))(p)
        u = 0.5 * (u + jnp.swapaxes(u, -1, -2))
        v = u @ beta
        return jnp.concatenate([u, v], axis=-1)

    def _page_partial_fn(self, local_cids: np.ndarray, n_segments: int):
        """Jitted tier-1 partial: (page p, β, mask) → (n_segments, Ñ,
        Ñ+m) masked segment sums. Cached per local-id pattern — evenly
        nested clusters share one pattern across all pages."""
        key = ("partial", local_cids.tobytes(), n_segments)
        fn = self._jits.get(key)
        if fn is not None:
            return fn
        use_kernel, interpret = self.kernel, self.interpret

        def partial(p, beta, mask):
            w = self._w_of(p, beta)
            if use_kernel:
                from repro.kernels.topology_merge import masked_segment_sum_mix

                return masked_segment_sum_mix(
                    w, local_cids, mask, n_segments, interpret=interpret
                )
            wm = w * mask.astype(w.dtype)[:, None, None]
            return jax.ops.segment_sum(
                wm, jnp.asarray(local_cids), num_segments=n_segments
            )

        fn = self._jits[key] = jax.jit(partial)
        return fn

    def _solve_fn(self, batched: bool):
        key = ("solve", batched)
        fn = self._jits.get(key)
        if fn is not None:
            return fn
        ridge, use_kernel, interpret = self.ridge, self.kernel, self.interpret

        def solve(u, v):
            if use_kernel:
                from repro.kernels.topology_merge import from_uv_solve

                if not batched:
                    pc, bc = from_uv_solve(
                        u[None], v[None], ridge=ridge, interpret=interpret
                    )
                    return pc[0], bc[0]
                return from_uv_solve(u, v, ridge=ridge, interpret=interpret)
            if not batched:
                return _solve_uv(u, v, ridge)
            return jax.vmap(lambda uu, vv: _solve_uv(uu, vv, ridge))(u, v)

        fn = self._jits[key] = jax.jit(solve)
        return fn

    def jit_cache_sizes(self) -> dict[str, int]:
        return {
            "_".join(str(k) for k in key if isinstance(key, tuple)): (
                fn._cache_size() if hasattr(fn, "_cache_size") else -1
            )
            for key, fn in self._jits.items()
        }

    # ------------------------------------------------------------- merge

    def merge(self, arena: FleetArena, mask: np.ndarray) -> TierCost:
        """One participation-masked two-tier cooperative update, in
        place on the arena. Devices with mask 0 neither contribute nor
        receive (their arena rows are untouched) — identical semantics
        to the resident ``fleet_merge_masked``. Returns the round's
        per-tier cost."""
        mask = np.asarray(mask, bool)
        if mask.shape != (arena.n_devices,):
            raise ValueError(
                f"mask shape {mask.shape} != (D={arena.n_devices},)"
            )
        if self.mode == "ring":
            self._merge_ring(arena, mask)
        elif self.mode == "clusters":
            self._merge_clusters(arena, mask)
        else:
            self._merge_global(arena, mask)
        return cohort_round_cost(
            self.topology, self.schedule, arena.n_hidden, arena.n_out
        )

    def _merge_global(self, arena: FleetArena, mask: np.ndarray) -> None:
        zeros = np.zeros(self.schedule.cohort_size, np.int32)
        partial_fn = self._page_partial_fn(zeros, 1)
        parts = []
        for lo, hi in self.schedule.bounds():
            parts.append(partial_fn(
                jnp.asarray(arena.p[lo:hi]),
                jnp.asarray(arena.beta[lo:hi]),
                jnp.asarray(mask[lo:hi], jnp.float32),
            )[0])
        total = cohort_tree_reduce(
            jnp.stack(parts), self.mesh, self.mesh_axes
        )
        nh = arena.n_hidden
        p1, b1 = self._solve_fn(batched=False)(total[:, :nh], total[:, nh:])
        p1, b1 = np.asarray(p1), np.asarray(b1)
        for lo, hi in self.schedule.bounds():
            m = mask[lo:hi]
            arena.p[lo:hi][m] = p1
            arena.beta[lo:hi][m] = b1

    def _merge_clusters(self, arena: FleetArena, mask: np.ndarray) -> None:
        nh, m_out = arena.n_hidden, arena.n_out
        acc = np.zeros(
            (self.topology.n_clusters, nh, nh + m_out), np.float32
        )
        for (lo, hi), (off, local) in zip(
            self.schedule.bounds(), self._locals
        ):
            part = self._page_partial_fn(local, self._k_max)(
                jnp.asarray(arena.p[lo:hi]),
                jnp.asarray(arena.beta[lo:hi]),
                jnp.asarray(mask[lo:hi], jnp.float32),
            )
            k_here = int(local[-1]) + 1
            acc[off : off + k_here] += np.asarray(part)[:k_here]
        pc, bc = self._solve_fn(batched=True)(
            jnp.asarray(acc[:, :, :nh]), jnp.asarray(acc[:, :, nh:])
        )
        pc, bc = np.asarray(pc), np.asarray(bc)
        for lo, hi in self.schedule.bounds():
            m = mask[lo:hi]
            gcids = self._cids[lo:hi]
            arena.p[lo:hi][m] = pc[gcids[m]]
            arena.beta[lo:hi][m] = bc[gcids[m]]

    def _ring_page_fn(self):
        key = ("ring_page",)
        fn = self._jits.get(key)
        if fn is not None:
            return fn
        hops = self.topology.hops
        c = self.schedule.cohort_size
        solve = self._solve_fn(batched=True)

        def page(p_ext, beta_ext, mask_ext):
            w = self._w_of(p_ext, beta_ext)
            w = w * mask_ext.astype(w.dtype)[:, None, None]
            # offsets descending to match Topology.mix's roll order
            mixed = w[2 * hops : 2 * hops + c]
            for o in range(2 * hops - 1, -1, -1):
                mixed = mixed + w[o : o + c]
            nh = p_ext.shape[-1]
            return solve(mixed[:, :, :nh], mixed[:, :, nh:])

        fn = self._jits[key] = jax.jit(page)
        return fn

    def _merge_ring(self, arena: FleetArena, mask: np.ndarray) -> None:
        d = arena.n_devices
        hops = self.topology.hops
        page_fn = self._ring_page_fn()
        # pre-merge halo snapshot: each page's window sums must read its
        # neighbors' PRE-merge payloads even after those pages already
        # scattered their merged state back into the arena
        halos = []
        for lo, hi in self.schedule.bounds():
            ids = np.concatenate(
                [np.arange(lo - hops, lo), np.arange(hi, hi + hops)]
            ) % d
            halos.append((
                arena.p[ids].copy(), arena.beta[ids].copy(), mask[ids].copy()
            ))
        for (lo, hi), (hp, hb, hm) in zip(self.schedule.bounds(), halos):
            p_ext = np.concatenate([hp[:hops], arena.p[lo:hi], hp[hops:]])
            b_ext = np.concatenate([hb[:hops], arena.beta[lo:hi], hb[hops:]])
            m_ext = np.concatenate([hm[:hops], mask[lo:hi], hm[hops:]])
            pc, bc = page_fn(
                jnp.asarray(p_ext), jnp.asarray(b_ext),
                jnp.asarray(m_ext, jnp.float32),
            )
            arena.write_page(lo, hi, pc, bc, where=mask[lo:hi])
