"""npz-based pytree checkpointing (orbax is unavailable offline).

Flattens a pytree with ``jax.tree_util.tree_flatten_with_path``, stores
leaves in a single compressed ``.npz`` plus a key manifest, and restores
into an identical tree structure. Device arrays are fetched to host;
restore re-places onto the default device (the training loop re-shards
via its jitted step's in_shardings).

Includes a small retention-managed ``CheckpointManager`` (keep-last-N,
atomic rename) — enough substrate for the example training driver and
the federated edge-device state (OS-ELM P/β are plain arrays).
"""
from __future__ import annotations

import json
import logging
import os
import tempfile
import zipfile
from pathlib import Path
from typing import Any

import jax
import numpy as np

PyTree = Any

log = logging.getLogger(__name__)

_SEP = "␟"  # symbol-for-unit-separator: never in key names


def _path_str(path) -> str:
    parts = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            parts.append(str(e.key))
        elif isinstance(e, jax.tree_util.SequenceKey):
            parts.append(str(e.idx))
        elif isinstance(e, jax.tree_util.GetAttrKey):
            parts.append(str(e.name))
        else:
            parts.append(str(e))
    return _SEP.join(parts)


def save_pytree(tree: PyTree, path: str | os.PathLike) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    keys = []
    for i, (kp, leaf) in enumerate(flat):
        name = f"leaf_{i}"
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "biufc":  # bf16 etc. — npz can't store them
            arr = arr.astype(np.float32)
        arrays[name] = arr
        keys.append(_path_str(kp))
    tmp = tempfile.NamedTemporaryFile(
        dir=path.parent, suffix=".tmp", delete=False
    )
    try:
        np.savez_compressed(tmp, __keys__=np.asarray(json.dumps(keys)), **arrays)
        tmp.close()
        os.replace(tmp.name, path)  # atomic
    finally:
        if os.path.exists(tmp.name):
            os.unlink(tmp.name)


def load_pytree(template: PyTree, path: str | os.PathLike) -> PyTree:
    """Restore into the structure of ``template`` (shapes must match)."""
    with np.load(path, allow_pickle=False) as data:
        leaves = [data[f"leaf_{i}"] for i in range(len(data.files) - 1)]
    flat, treedef = jax.tree_util.tree_flatten(template)
    if len(flat) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves; template expects {len(flat)}"
        )
    import jax.numpy as jnp

    restored = []
    for l, t in zip(leaves, flat):
        if isinstance(t, np.ndarray):
            # host-side leaves (counters, ledgers) stay numpy — int64
            # survives exactly instead of being truncated by jnp
            restored.append(np.asarray(l, dtype=t.dtype))
        elif hasattr(t, "dtype"):
            restored.append(jnp.asarray(l).astype(t.dtype))
        else:
            restored.append(l)
    return jax.tree_util.tree_unflatten(treedef, restored)


class CheckpointManager:
    """Retention-managed snapshot directory.

    Writes are atomic (tmp + ``os.replace``), so a crash mid-save can
    never leave a truncated file under a real checkpoint name — the
    restore walk-back stays as the last line of defense against the
    disk itself lying. ``keep``/``keep_last`` bounds the directory to
    the N newest snapshots so long soaks don't accumulate unbounded
    state; ``keep=None`` retains everything."""

    def __init__(
        self,
        directory: str | os.PathLike,
        *,
        keep: int | None = 3,
        keep_last: int | None = None,
    ) -> None:
        self.dir = Path(directory)
        # keep_last is the retention spelling the serving stack uses;
        # both names set the same knob, keep_last wins if both passed
        self.keep = keep_last if keep_last is not None else keep
        if self.keep is not None and self.keep < 1:
            raise ValueError(f"retention must keep >= 1 snapshot, got {self.keep}")
        self.dir.mkdir(parents=True, exist_ok=True)

    def save(self, step: int, tree: PyTree) -> Path:
        p = self.dir / f"ckpt_{step:08d}.npz"
        save_pytree(tree, p)
        self._gc()
        return p

    def latest_step(self) -> int | None:
        steps = sorted(
            int(p.stem.split("_")[1]) for p in self.dir.glob("ckpt_*.npz")
        )
        return steps[-1] if steps else None

    def restore(self, template: PyTree, step: int | None = None) -> tuple[PyTree, int]:
        """Restore the requested (or latest readable) checkpoint.

        A crash can leave the newest snapshot truncated or corrupt
        (``save_pytree``'s rename is atomic, but the disk under it may
        not be). With ``step=None`` the manager walks backwards from the
        latest checkpoint, warning and falling back past any unreadable
        file, so a recovering runtime resumes from the newest snapshot
        that actually loads. An explicitly requested ``step`` still
        fails loudly — the caller asked for that exact state."""
        if step is not None:
            return load_pytree(template, self.dir / f"ckpt_{step:08d}.npz"), step
        steps = sorted(
            (int(p.stem.split("_")[1]) for p in self.dir.glob("ckpt_*.npz")),
            reverse=True,
        )
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        last_err: Exception | None = None
        for s in steps:
            path = self.dir / f"ckpt_{s:08d}.npz"
            try:
                return load_pytree(template, path), s
            except (OSError, ValueError, KeyError, EOFError,
                    zipfile.BadZipFile, json.JSONDecodeError) as e:
                log.warning(
                    "checkpoint %s is unreadable (%s: %s) — falling back to "
                    "the previous step", path.name, type(e).__name__, e,
                )
                last_err = e
        raise FileNotFoundError(
            f"no readable checkpoint in {self.dir} "
            f"({len(steps)} candidates, all unreadable)"
        ) from last_err

    def _gc(self) -> None:
        # a *.tmp in the directory is a previous process's interrupted
        # save — junk by construction (the atomic rename never happened)
        for turd in self.dir.glob("*.tmp"):
            turd.unlink(missing_ok=True)
        if self.keep is None:
            return
        ckpts = sorted(self.dir.glob("ckpt_*.npz"))
        for old in ckpts[: -self.keep]:
            old.unlink()
