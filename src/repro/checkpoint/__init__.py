from repro.checkpoint.npz_store import load_pytree, save_pytree, CheckpointManager

__all__ = ["load_pytree", "save_pytree", "CheckpointManager"]
