"""Byzantine-robust cooperative merges under fault injection — ours.

The paper's Eq. 8 merge sums every neighbor's (U, V) verbatim, so one
device shipping a scaled/negated payload poisons the whole equivalence
class. This harness measures the trimmed/clipped robust merge
(``repro.fleet.robust``) against that failure mode with deterministic
fault schedules (``repro.fleet.faults``) at 10% Byzantine:

1. **clean** — the preset with no faults through the naive merge: the
   lock every robust claim is stated against;
2. **robust** — 10% of devices ship ×−25 payloads, trimmed merge
   (``RobustConfig(trim=1)``): honest-device post-merge AUC must stay
   within ``AUC_BAND`` of the clean lock;
3. **naive** — the same attack through the plain masked merge: the
   honest-device AUC must measurably degrade (below lock − AUC_BAND),
   or the robust arm is defending against nothing.

The two smoke presets cover both robust reduction paths: ``driving`` on
a ring exercises the banded trimmed gather, ``har`` on a star the
cluster-segment trimmed sum (head exchange).

4. **chaos** — NaN payloads plus a mid-soak crash: the runtime soaks
   with 10% of devices emitting non-finite (U, V) (every one must be
   rejected by the finite guard, never merged), is killed between
   snapshots, loses its NEWEST snapshot to corruption, restores off the
   previous one, and replays to the end. The replayed tail must be
   tick-identical to an uninterrupted reference run (losses, drift
   flags, merge decisions, robust scores, rejected-payload counts) and
   the restored runtime must still be compile-once.

Artifacts: ``BENCH_robust_fleet.json`` (written before the asserts) and
a ``BENCH_history.jsonl`` entry — wall-clocks are regression-gated and
the per-preset ``*_robust_vs_naive_ratio`` keys gate as
higher-is-better (the defense margin must not silently shrink).

    PYTHONPATH=src python benchmarks/robust_fleet.py [--smoke|--full]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import tempfile
import time
from pathlib import Path

import jax
import numpy as np

if __package__ in (None, ""):  # `python benchmarks/robust_fleet.py` from repo root
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.history import record_and_gate
from repro.fleet.faults import FaultSpec
from repro.fleet.robust import RobustConfig
from repro.obs import TelemetryConfig
from repro.runtime.governor import GovernorConfig
from repro.runtime.runtime import FleetRuntime, RuntimeConfig
from repro.scenarios import make_scenario, run_scenario, scenario_topology

MERGE_EVERY = 16
AUC_BAND = 0.03            # robust arm must stay inside; naive must fall below
BYZANTINE = FaultSpec(kind="scale", frac=0.1, magnitude=-25.0, seed=7)

# preset → (sizes, topology, topology_kwargs): ring drives the banded
# trimmed gather, star the cluster-segment trimmed sum — both robust
# reduction paths. A band must hold > 2·trim participants for the trim
# to engage, so the bigger full-grid ring widens its gossip band to
# cover its 2-attacker trim budget (2·2+1 = 5 > 4).
SMOKE_GRID = {
    "driving": ({"n_devices": 10, "ticks": 80}, "ring", {}),
    "har": ({"n_devices": 20, "ticks": 80}, "star", {}),
}
FULL_GRID = {
    "driving": ({"n_devices": 20, "ticks": 120}, "ring", {"hops": 2}),
    "har": ({"n_devices": 30, "ticks": 120}, "star", {}),
}

CHAOS_SIZES = {"n_devices": 10, "ticks": 64}
CHAOS_SNAPSHOT_EVERY = 16
CHAOS_KILL_TICK = 40       # between snapshots: restore must rewind, then replay
CHAOS_NAN = FaultSpec(kind="nan", frac=0.1, start_tick=8, seed=3)


def run_grid(grid: dict, *, seed: int = 0) -> dict:
    """Every preset through all three arms on its topology. The scenario
    is built once per preset (``faults`` does not shape the streams), so
    every arm trains the identical fleet on the identical data — the
    deltas are the attack and the defense, nothing else. The claims are
    stated over the HONEST device set (neither Byzantine nor drifted),
    identical across arms."""
    rows = {}
    for name, (sizes, topology, topo_kwargs) in grid.items():
        spec = make_scenario(name, **sizes)
        spec_byz = dataclasses.replace(spec, faults=(BYZANTINE,))
        sc = spec.build()
        # the trimmed mean tolerates at most `trim` adversaries per
        # reduction group — size the budget to the attack (the classic
        # f < trim assumption; har's star puts both attackers in one
        # segment, where trim=1 would leave one extreme in the mean)
        n_byz = len(spec_byz.fault_devices())
        arms: dict[str, dict] = {}
        aucs: dict[str, np.ndarray] = {}
        for arm, (arm_spec, robust) in {
            "clean": (spec, None),
            "robust": (spec_byz, RobustConfig(trim=max(1, n_byz))),
            "naive": (spec_byz, None),
        }.items():
            t0 = time.perf_counter()
            res = run_scenario(
                arm_spec, topology, topology_kwargs=topo_kwargs or None,
                merge_every=MERGE_EVERY, key_seed=seed,
                scenario=sc, robust=robust,
                telemetry=TelemetryConfig(),  # in-memory sink per arm
            )
            aucs[arm] = res.merged_aucs
            tel = res.telemetry
            rep_nonfinite = int(sum(r.nonfinite_payloads for r in res.reports))
            # the sink's counters and the tick reports are two views of
            # the SAME events — if they disagree, instrumentation lies
            assert tel["nonfinite_payloads_total"] == rep_nonfinite, (
                name, arm, tel["nonfinite_payloads_total"], rep_nonfinite,
            )
            assert tel["merge_rounds"] == res.merges, (name, arm, tel)
            arms[arm] = {
                **res.auc_summary(),
                "merges": res.merges,
                "comm_bytes": res.comm_bytes,
                "nonfinite_payloads": rep_nonfinite,
                "tick_p50_us": tel["tick_latency"]["p50_s"] * 1e6,
                "wall_seconds": time.perf_counter() - t0,
            }
        honest = [
            d for d in range(spec.n_devices)
            if d not in set(spec_byz.fault_devices())
            and d not in {ev.device for ev in spec.drift_schedule()}
        ]
        honest_auc = {a: float(aucs[a][honest].mean()) for a in aucs}
        rows[name] = {
            "preset": name,
            "topology": topology,
            "sizes": sizes,
            "byzantine_devices": list(spec_byz.fault_devices()),
            "honest_devices": honest,
            "honest_merged_auc": honest_auc,
            "robust_margin": honest_auc["robust"] - honest_auc["clean"],
            "naive_margin": honest_auc["naive"] - honest_auc["clean"],
            "arms": arms,
        }
    return rows


def chaos_recovery(*, seed: int = 0) -> dict:
    """NaN payloads + mid-soak crash + corrupt-newest-snapshot restore,
    replayed against an uninterrupted reference run."""
    spec = dataclasses.replace(
        make_scenario("driving", **CHAOS_SIZES), faults=(CHAOS_NAN,)
    )
    sc = spec.build()
    key = jax.random.PRNGKey(seed)
    topo = scenario_topology("star", spec.n_devices)
    feed = sc.feed()
    ticks = spec.ticks

    def config(snapshot_dir=None, telemetry_dir=None):
        return RuntimeConfig(
            topology=topo, ridge=spec.ridge, detector=spec.detector,
            governor=GovernorConfig(merge_every=MERGE_EVERY),
            robust=RobustConfig(trim=1), faults=spec.fault_injector(),
            snapshot_every=CHAOS_SNAPSHOT_EVERY if snapshot_dir else None,
            snapshot_dir=snapshot_dir,
            telemetry=TelemetryConfig(dir=telemetry_dir),
        )

    t0 = time.perf_counter()
    # uninterrupted reference (in-memory sink: the continuity baseline)
    ref = FleetRuntime(sc.init_fleet(key), config())
    ref_reports = ref.run(feed)
    ref_summary = ref.finalize_telemetry()

    with tempfile.TemporaryDirectory() as tmp:
        tel_dir = str(Path(tmp) / "telemetry")
        # the run that dies: killed between snapshots at CHAOS_KILL_TICK
        doomed = FleetRuntime(sc.init_fleet(key), config(tmp, tel_dir))
        doomed.run(feed, ticks=CHAOS_KILL_TICK)
        # NaN rounds before the kill must already have flight dumps
        doomed_dumps = list(doomed.telemetry.flight.dumps)
        assert doomed_dumps, "no flight dump before the crash"
        del doomed  # the "crash"

        # the crash also tore the newest snapshot — restore must warn
        # and fall back to the previous step, not die
        snaps = sorted(Path(tmp).glob("ckpt_*.npz"))
        newest = snaps[-1]
        newest.write_bytes(newest.read_bytes()[:128])

        revived = FleetRuntime(sc.init_fleet(key), config(tmp, tel_dir))
        restored_tick = revived.restore()
        # snapshots carry the registry + flight ring: the revived sink
        # resumes mid-count instead of rebooting to zero
        restored_ticks_counter = int(revived.telemetry.ticks.value)
        replay_reports = [
            revived.tick(feed.tick_batch(t)) for t in range(restored_tick, ticks)
        ]
        revived_summary = revived.finalize_telemetry()
        flight_dumps = [str(Path(p).name) for p in doomed_dumps]
    wall = time.perf_counter() - t0

    # the replayed tail must be indistinguishable from the reference
    ref_tail = ref_reports[restored_tick:]
    mismatches = []
    for r_ref, r_new in zip(ref_tail, replay_reports):
        same = (
            np.allclose(r_ref.losses, r_new.losses, rtol=0, atol=1e-6)
            and np.array_equal(r_ref.drifted, r_new.drifted)
            and r_ref.decision.merge == r_new.decision.merge
            and r_ref.nonfinite_payloads == r_new.nonfinite_payloads
            and (
                (r_ref.robust_scores is None) == (r_new.robust_scores is None)
                and (
                    r_ref.robust_scores is None
                    or np.allclose(r_ref.robust_scores, r_new.robust_scores,
                                   rtol=0, atol=1e-5)
                )
            )
        )
        if not same:
            mismatches.append(r_ref.tick)
    beta_err = float(
        np.max(np.abs(np.asarray(ref.states.beta) - np.asarray(revived.states.beta)))
    )
    return {
        "ticks": ticks,
        "kill_tick": CHAOS_KILL_TICK,
        "restored_tick": restored_tick,
        "corrupted_newest_snapshot": True,
        "nonfinite_rejected_ref": int(
            sum(r.nonfinite_payloads for r in ref_reports)
        ),
        "nonfinite_rejected_replay": int(
            sum(r.nonfinite_payloads for r in replay_reports)
        ),
        "tick_mismatches": mismatches,
        "final_beta_max_abs_err": beta_err,
        "jit_cache_sizes": revived.assert_compile_once(),
        "restored_ticks_counter": restored_ticks_counter,
        "flight_dumps_before_crash": flight_dumps,
        "telemetry_continuity": {
            "ref_ticks": ref_summary["ticks"],
            "revived_ticks": revived_summary["ticks"],
            "ref_nonfinite": ref_summary["nonfinite_payloads_total"],
            "revived_nonfinite": revived_summary["nonfinite_payloads_total"],
            "ref_merge_rounds": ref_summary["merge_rounds"],
            "revived_merge_rounds": revived_summary["merge_rounds"],
        },
        "wall_seconds": wall,
    }


def run_bench(*, smoke: bool = True, seed: int = 0) -> dict:
    grid = SMOKE_GRID if smoke else FULL_GRID
    return {
        "backend": jax.default_backend(),
        "smoke": smoke,
        "merge_every": MERGE_EVERY,
        "auc_band": AUC_BAND,
        "attack": {"kind": BYZANTINE.kind, "frac": BYZANTINE.frac,
                   "magnitude": BYZANTINE.magnitude},
        "presets": run_grid(grid, seed=seed),
        "chaos": chaos_recovery(seed=seed),
    }


def main(
    smoke: bool = True,
    out_path: str = "BENCH_robust_fleet.json",
    history_path: str = "BENCH_history.jsonl",
) -> list[str]:
    report = run_bench(smoke=smoke)
    # persist BEFORE asserting — a failed claim still leaves the artifact
    with open(out_path, "w") as fh:
        json.dump(report, fh, indent=2)

    lines = []
    metrics: dict[str, float] = {}
    for name, row in report["presets"].items():
        auc = row["honest_merged_auc"]
        for arm in ("clean", "robust", "naive"):
            r = row["arms"][arm]
            wall_us = r["wall_seconds"] * 1e6
            metrics[f"{name}_{arm}_us"] = wall_us
            lines.append(
                f"robust_fleet/{name}/{arm},{wall_us:.1f},"
                f"topo={row['topology']};honest_auc={auc[arm]:.3f};"
                f"merges={r['merges']};nonfinite={r['nonfinite_payloads']}"
            )
        # higher-is-better history gate: the defense margin over the
        # naive merge must not silently shrink across runs
        metrics[f"{name}_robust_vs_naive_ratio"] = auc["robust"] / max(
            auc["naive"], 1e-9
        )

    chaos = report["chaos"]
    metrics["chaos_recovery_us"] = chaos["wall_seconds"] * 1e6
    lines.append(
        f"robust_fleet/chaos,{chaos['wall_seconds'] * 1e6:.1f},"
        f"restored_tick={chaos['restored_tick']};"
        f"nonfinite_rejected={chaos['nonfinite_rejected_ref']};"
        f"tick_mismatches={len(chaos['tick_mismatches'])};"
        f"beta_err={chaos['final_beta_max_abs_err']:.2e}"
    )

    # ---- the robustness claims, mechanically
    for name, row in report["presets"].items():
        auc, arms = row["honest_merged_auc"], row["arms"]
        assert row["byzantine_devices"], f"{name}: attack resolved no victims"
        for arm in ("clean", "robust", "naive"):
            assert arms[arm]["merges"] >= 1, f"{name}/{arm}: no merges admitted"
        # the defense holds: honest devices stay inside the clean band
        assert abs(auc["robust"] - auc["clean"]) <= AUC_BAND, (
            f"{name}: robust honest AUC {auc['robust']:.3f} outside "
            f"±{AUC_BAND} of clean lock {auc['clean']:.3f}"
        )
        # the attack is real: the naive merge measurably degrades
        assert auc["naive"] < auc["clean"] - AUC_BAND, (
            f"{name}: naive honest AUC {auc['naive']:.3f} did not degrade "
            f"below clean lock {auc['clean']:.3f} − {AUC_BAND} — the attack "
            f"is too weak to validate the defense"
        )
    # ---- crash-recovery claims
    assert chaos["nonfinite_rejected_ref"] > 0, "NaN arm rejected no payloads"
    assert (
        chaos["nonfinite_rejected_replay"] > 0
    ), "replayed tail rejected no payloads"
    assert not chaos["tick_mismatches"], (
        f"replay diverged from reference at ticks {chaos['tick_mismatches']}"
    )
    assert chaos["final_beta_max_abs_err"] <= 1e-5, chaos["final_beta_max_abs_err"]
    assert chaos["restored_tick"] < chaos["kill_tick"], (
        "restore did not rewind past the corrupted snapshot"
    )
    # telemetry continuity: the restored registry resumed mid-count (not
    # from zero) and the replayed run's final counters equal the
    # uninterrupted reference's — kill/corrupt/restore is invisible in
    # the metrics, exactly like it is in the model state
    assert chaos["restored_ticks_counter"] == chaos["restored_tick"], chaos
    cont = chaos["telemetry_continuity"]
    assert cont["revived_ticks"] == cont["ref_ticks"], cont
    assert cont["revived_nonfinite"] == cont["ref_nonfinite"], cont
    assert cont["revived_merge_rounds"] == cont["ref_merge_rounds"], cont
    assert chaos["flight_dumps_before_crash"], chaos

    lines.append(
        f"# robust_fleet claims ok — 10% Byzantine held to ±{AUC_BAND} on "
        f"{sorted(report['presets'])}; naive degraded; crash/restore "
        f"tick-identical from tick {chaos['restored_tick']} → {out_path}"
    )
    # wall-clocks include scenario builds + compiles: gate generously;
    # the _ratio keys gate higher-is-better regardless of threshold
    record_and_gate("robust_fleet", metrics, path=history_path, threshold=0.5)
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI chaos grid — 2 presets × 3 arms + crash/restore "
             "(this IS the acceptance configuration)",
    )
    ap.add_argument("--full", action="store_true",
                    help="bigger fleets, longer soaks")
    ap.add_argument("--out", default="BENCH_robust_fleet.json")
    args = ap.parse_args()
    for line in main(smoke=not args.full, out_path=args.out):
        print(line)
    print(f"# robust_fleet ok ({'smoke' if not args.full else 'full'})")
