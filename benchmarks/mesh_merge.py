"""Beyond-paper: the cooperative model update as a mesh collective.

N host devices each train an OS-ELM autoencoder on a different HAR
pattern; ONE psum pair merges them (the paper's 2-device exchange,
generalized to N). Validates that the psum merge equals the sequential
pairwise merge and measures the jitted program latency.

Run under XLA_FLAGS=--xla_force_host_platform_device_count=8 for a
meaningful device count (benchmarks/run.py does this in-process only if
jax is not yet initialized).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import edge_config, normalized_dataset, timed
from repro.core import (
    cooperative_update,
    init_oselm,
    init_slfn,
    oselm_loss,
    to_uv,
)
from repro.data.pipeline import make_sharded_streams
from repro.federated import mesh_cooperative_update, mesh_federated_train


def run(n_hidden: int = 64, steps: int = 200, seed: int = 0) -> dict:
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    ds = normalized_dataset("har", seed=seed)
    streams = make_sharded_streams(ds, n_dev, steps + 2 * n_hidden, seed=seed)
    ecfg = edge_config("har")

    params = init_slfn(jax.random.PRNGKey(seed), ds.n_features, n_hidden)
    states = []
    for s in range(n_dev):
        x0 = jnp.asarray(streams.xs[s, : 2 * n_hidden])
        states.append(
            init_oselm(params, x0, x0, activation="identity", ridge=ecfg.ridge)
        )
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
    xs_rest = jnp.asarray(streams.xs[:, 2 * n_hidden:])

    merged = mesh_federated_train(stacked, xs_rest, mesh, ("data",), ridge=ecfg.ridge)

    # reference: sequential pairwise merge on device 0
    import repro.core as core
    host_states = [
        core.oselm_train_sequential(states[s], xs_rest[s], xs_rest[s])
        for s in range(n_dev)
    ]
    ref = cooperative_update(host_states[0], *[to_uv(s) for s in host_states[1:]])

    beta_mesh = np.asarray(merged.beta[0])
    diff = float(np.max(np.abs(beta_mesh - np.asarray(ref.beta))))

    # merged model covers every pattern
    losses = {}
    st0 = jax.tree.map(lambda l: l[0], merged)
    for pat in range(ds.n_classes):
        xp = jnp.asarray(ds.pattern(pat)[:32])
        losses[ds.class_names[pat]] = float(oselm_loss(st0, xp, xp).mean())

    merge_us = timed(
        lambda st: mesh_cooperative_update(st, mesh, ("data",), ridge=ecfg.ridge),
        merged, warmup=1, iters=5,
    )
    return {
        "n_devices": n_dev,
        "beta_diff_vs_pairwise": diff,
        "losses": losses,
        "psum_merge_us": merge_us,
    }


def main() -> list[str]:
    r = run()
    assert r["beta_diff_vs_pairwise"] < 0.05, r
    return [
        f"mesh_merge/har,{r['psum_merge_us']:.1f},"
        f"devices={r['n_devices']};beta_diff={r['beta_diff_vs_pairwise']:.2e};"
        f"max_pattern_loss={max(r['losses'].values()):.4f}"
    ]


if __name__ == "__main__":
    for l in main():
        print(l)
