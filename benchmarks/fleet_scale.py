"""Fleet-scale federation benchmark — devices × topology grid.

For each (n_devices, topology) cell: simulate the whole fleet in one
process (stacked pytree, vmap+scan), run one cooperative update over
the topology, and report

  - merge wall-clock (jitted, µs/call),
  - per-round communication bytes (payloads × Ñ(Ñ+m)·4, vs an R-round
    FedAvg baseline shipping full SLFN weights),
  - post-merge anomaly ROC-AUC, evaluated with the paper's §5.3.1
    protocol: the fleet trains on a *subset* of the normal patterns so
    the held-out pattern stays anomalous.

Asserted claims:
  - every fully-connected topology's merged model matches all-to-all,
  - the O(D)-traffic topologies (star, hierarchical) beat 10-round
    FedAvg bytes at every fleet size — the paper's one-shot Ñ(Ñ+m)
    claim; all-to-all D2D grows as D(D−1) payloads, which is exactly
    why Jung-style hierarchical clustering matters at fleet scale, and
    hierarchical must always undercut all-to-all,
  - post-merge AUC stays above 0.8 on the HAR-like dataset.

``--merge-bench`` instead microbenchmarks the merge round itself:
sparse topology mixing (banded roll-sum for ring, segment-sum +
broadcast for star/hierarchical — the structure the Pallas
``topology_merge`` kernels exploit) against the dense D×D einsum
baseline at D ∈ {256, 1024, 4096}, plus the cluster-level §4.2 solve
against D per-device solves. Wall-clock (jitted XLA on this backend) +
analytic FLOPs/bytes accounting are written to ``BENCH_fleet_merge.json``
and the sparse paths are asserted to beat dense at D ≥ 1024.

    PYTHONPATH=src python benchmarks/fleet_scale.py [--smoke|--merge-bench]
    PYTHONPATH=src python -m benchmarks.fleet_scale [--smoke|--merge-bench]

``--smoke`` shrinks the grid to seconds for CI and also emits the
merge-bench JSON artifact (smaller grid, D ≤ 1024).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):  # `python benchmarks/fleet_scale.py` from repo root
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import normalized_dataset, timed
from repro.data import AnomalyDataset
from repro.data.metrics import roc_auc
from repro.data.pipeline import anomaly_eval_arrays, train_test_split
from repro.fleet import (
    all_to_all,
    fedavg_total_cost,
    fleet_merge,
    fleet_score,
    fleet_train,
    hierarchical,
    init_fleet,
    make_fleet_streams,
    ring,
    star,
    topology_round_cost,
)

N_HIDDEN = 32          # narrower than paper Table 3's HAR width: keeps the
                       # 256-device einsum merge CPU-friendly; AUC holds
N_KEEP = 2             # patterns the fleet trains on; the rest stay anomalous
FEDAVG_ROUNDS = 10     # the R-round baseline the paper compares against


def _topologies(n_devices: int) -> list:
    return [
        all_to_all(n_devices),
        star(n_devices),
        ring(n_devices, hops=2),
        hierarchical(n_devices, max(1, n_devices // 8)),
    ]


def _train_subset(ds: AnomalyDataset, keep: int) -> AnomalyDataset:
    """Restrict to the first ``keep`` classes so the rest stay anomalous
    at eval time."""
    mask = ds.y < keep
    return AnomalyDataset(ds.name, ds.x[mask], ds.y[mask], ds.class_names[:keep])


def run(device_grid: tuple[int, ...] = (64, 256), steps: int = 64, seed: int = 0) -> list[dict]:
    ds = normalized_dataset("har", seed=seed, samples_per_class=150)
    train, test = train_test_split(ds, 0.8, seed=seed)
    keep = N_KEEP
    train_sub = _train_subset(train, keep)
    x_eval, y_eval = anomaly_eval_arrays(test, list(range(keep)), seed=seed)
    x_eval = jax.numpy.asarray(x_eval)

    results = []
    for n_dev in device_grid:
        fs = make_fleet_streams(
            train_sub, n_dev, steps, n_init=2 * N_HIDDEN, seed=seed
        )
        fleet0 = init_fleet(
            jax.random.PRNGKey(seed), n_dev, ds.n_features, N_HIDDEN,
            fs.x_init, activation="identity", ridge=1e-3,
        )
        fleet0 = fleet_train(fleet0, fs.xs)

        ref_beta = None
        for topo in _topologies(n_dev):
            merged = fleet_merge(fleet0, topo, ridge=1e-3)
            merge_us = timed(
                lambda f, t=topo: fleet_merge(f, t, ridge=1e-3),
                fleet0, warmup=1, iters=5,
            )
            cost = topology_round_cost(topo, N_HIDDEN, ds.n_features)

            if topo.name == "all_to_all":
                ref_beta = np.asarray(merged.beta)
            beta_diff = (
                float(np.max(np.abs(np.asarray(merged.beta) - ref_beta)))
                if topo.is_fully_connected else float("nan")
            )

            # post-merge AUC on a sample of devices (scores are cheap,
            # roc_auc is a host-side rank statistic)
            n_probe = min(n_dev, 16)
            scores = np.asarray(fleet_score(merged, x_eval)[:n_probe])
            aucs = [roc_auc(scores[d], y_eval) for d in range(n_probe)]
            results.append({
                "n_devices": n_dev,
                "topology": topo.name,
                "merge_us": merge_us,
                "payloads": cost.payloads,
                "bytes": cost.bytes_total,
                "beta_diff_vs_all_to_all": beta_diff,
                "auc_mean": float(np.mean(aucs)),
                "auc_min": float(np.min(aucs)),
            })
        results.append({
            "n_devices": n_dev,
            "topology": f"fedavg_r{FEDAVG_ROUNDS}",
            "merge_us": float("nan"),
            "payloads": (c := fedavg_total_cost(
                n_dev, FEDAVG_ROUNDS, ds.n_features, N_HIDDEN, ds.n_features
            )).payloads,
            "bytes": c.bytes_total,
            "beta_diff_vs_all_to_all": float("nan"),
            "auc_mean": float("nan"),
            "auc_min": float("nan"),
        })
    return results


def main(device_grid: tuple[int, ...] = (64, 256)) -> list[str]:
    results = run(device_grid=device_grid)
    lines = []
    by_size: dict[int, dict[str, dict]] = {}
    for r in results:
        by_size.setdefault(r["n_devices"], {})[r["topology"]] = r
        lines.append(
            f"fleet_scale/{r['topology']}/d{r['n_devices']},"
            f"{r['merge_us']:.1f},"
            f"payloads={r['payloads']};bytes={r['bytes']};"
            f"auc={r['auc_mean']:.3f};beta_diff={r['beta_diff_vs_all_to_all']:.2e}"
        )
    for n_dev, cells in by_size.items():
        fedavg_bytes = cells[f"fedavg_r{FEDAVG_ROUNDS}"]["bytes"]
        for name, r in cells.items():
            if name.startswith("fedavg"):
                continue
            # fully-connected topologies must reproduce the Eq. 8 sum
            if not np.isnan(r["beta_diff_vs_all_to_all"]):
                assert r["beta_diff_vs_all_to_all"] < 5e-2, r
            assert r["auc_mean"] > 0.8, r
        # one-shot (U,V) exchange beats R-round FedAvg traffic on the
        # O(D) topologies; hierarchical always undercuts flat all-to-all
        for name in ("star", "hierarchical"):
            assert cells[name]["bytes"] < fedavg_bytes, (cells[name], fedavg_bytes)
        assert cells["hierarchical"]["bytes"] < cells["all_to_all"]["bytes"]
    return lines


# ---------------------------------------------------------------- merge bench

MERGE_GRID = (256, 1024, 4096)   # the tentpole's D sweep
MERGE_GRID_SMOKE = (256, 1024)   # CI still covers the asserted D=1024 win
DENSE_LIMIT = 1024               # dense einsum beyond this is accounted, not timed


def _mix_flops_bytes(topo, n_dev: int, f: int) -> tuple[int, int]:
    """Analytic cost of one sparse mix of a (D, Ñ, Ñ+m) payload stack
    (f = Ñ(Ñ+m) floats per payload). Bytes are the minimum HBM traffic
    the adjacency structure requires (each payload read once — ideal
    band/cluster reuse), the apples-to-apples bound the dense baseline
    is also given."""
    if topo.kind == "banded":
        n_off = min(2 * topo.hops + 1, n_dev)
        flops = (n_off - 1) * n_dev * f
        nbytes = 4 * (n_dev * f + n_dev * f)  # read stack once + write
    elif topo.kind == "segment":
        # the per-device merged (U, V) is never materialized: the C
        # cluster aggregates ARE the merge result, consumed directly by
        # the cluster-level solve (fleet._merge_body)
        c = topo.n_clusters
        flops = (n_dev - c) * f + (c - 1) * f * (1 if topo.head_exchange else 0)
        nbytes = 4 * (n_dev * f + c * f)
    else:  # dense
        flops = 2 * n_dev * n_dev * f
        nbytes = 4 * (n_dev * f + n_dev * n_dev + n_dev * f)
    return int(flops), int(nbytes)


def _dense_flops_bytes(n_dev: int, f: int) -> tuple[int, int]:
    return 2 * n_dev * n_dev * f, 4 * (n_dev * f + n_dev * n_dev + n_dev * f)


def _n_solves(topo) -> int:
    """§4.2 solves per merge round after cluster-level dispatch: one per
    equivalence class of merged models."""
    if topo.is_fully_connected:
        return 1
    if topo.kind == "segment":
        return topo.n_clusters
    return topo.n_devices


def merge_bench(
    device_grid: tuple[int, ...] = MERGE_GRID,
    n_hidden: int = N_HIDDEN,
    n_features: int = 48,
    dense_limit: int = DENSE_LIMIT,
) -> dict:
    """Sparse-vs-dense merge-round microbenchmark (see module docstring)."""
    f = n_hidden * (n_hidden + n_features)
    rows = []
    for n_dev in device_grid:
        key = jax.random.PRNGKey(n_dev)
        w = jax.random.normal(key, (n_dev, n_hidden, n_hidden + n_features))
        # synthetic SPD merged-U stack for the solve comparison
        h = jax.random.normal(key, (n_dev, 2 * n_hidden, n_hidden))
        u = jnp.einsum("dkn,dkm->dnm", h, h) + 1e-2 * jnp.eye(n_hidden)
        v = w[:, :, n_hidden:]

        per_device_solve = jax.jit(
            lambda u, v: jax.vmap(
                lambda a, b: jax.scipy.linalg.cho_solve(
                    jax.scipy.linalg.cho_factor(a), b
                )
            )(u, v)
        )
        one_solve = jax.jit(
            lambda u, v: jax.scipy.linalg.cho_solve(
                jax.scipy.linalg.cho_factor(u[0]), v[0]
            )
        )
        solve_all_us = timed(per_device_solve, u, v, warmup=1, iters=5)
        solve_one_us = timed(one_solve, u, v, warmup=1, iters=5)

        for topo in (ring(n_dev, hops=2), hierarchical(n_dev, max(1, n_dev // 8)),
                     star(n_dev)):
            if topo.kind == "segment":
                # what fleet_merge executes: aggregates only, no
                # per-device merged-UV materialization
                cids = jnp.asarray(topo.cluster_ids)

                def sparse_fn(x, t=topo, c=cids):
                    s = jax.ops.segment_sum(x, c, num_segments=t.n_clusters)
                    return s.sum(0) if t.head_exchange else s
            else:
                def sparse_fn(x, t=topo):
                    return t.mix(x)
            sparse_us = timed(jax.jit(sparse_fn), w, warmup=1, iters=5)
            if n_dev <= dense_limit:
                m = jnp.asarray(topo.dense_matrix())
                dense_us = timed(
                    jax.jit(lambda x, mm=m: jnp.einsum("ij,j...->i...", mm, x)),
                    w, warmup=1, iters=5,
                )
            else:
                dense_us = None
            flops_sparse, bytes_sparse = _mix_flops_bytes(topo, n_dev, f)
            flops_dense, bytes_dense = _dense_flops_bytes(n_dev, f)
            n_solves = _n_solves(topo)
            rows.append({
                "n_devices": n_dev,
                "topology": topo.name,
                "mix_us_sparse": sparse_us,
                "mix_us_dense": dense_us,
                "mix_speedup": (dense_us / sparse_us) if dense_us else None,
                "flops_sparse": flops_sparse,
                "flops_dense": flops_dense,
                "bytes_sparse": bytes_sparse,
                "bytes_dense": bytes_dense,
                "payloads": topo.payloads_per_round,
                "solves": n_solves,
                "solve_us_per_device_path": solve_all_us,
                "solve_us_clustered_path": (
                    solve_one_us if n_solves == 1 else
                    solve_all_us * n_solves / n_dev
                ),
            })
    return {
        "n_hidden": n_hidden,
        "n_features": n_features,
        "payload_floats": f,
        "backend": jax.default_backend(),
        "device_grid": list(device_grid),
        "rows": rows,
    }


def merge_bench_main(
    device_grid: tuple[int, ...] = MERGE_GRID, out_path: str = "BENCH_fleet_merge.json"
) -> list[str]:
    report = merge_bench(device_grid=device_grid)
    # persist the measurements BEFORE asserting on them, so a perf
    # regression still leaves the artifact needed to debug it
    with open(out_path, "w") as fh:
        json.dump(report, fh, indent=2)
    lines = []
    for r in report["rows"]:
        dense = f"{r['mix_us_dense']:.1f}" if r["mix_us_dense"] else "n/a"
        lines.append(
            f"fleet_merge/{r['topology']}/d{r['n_devices']},"
            f"{r['mix_us_sparse']:.1f},"
            f"dense_us={dense};flops_ratio={r['flops_dense'] / r['flops_sparse']:.0f};"
            f"bytes_ratio={r['bytes_dense'] / r['bytes_sparse']:.1f};"
            f"solves={r['solves']}"
        )
        # sparsity must win in the accounting at every size...
        assert r["flops_sparse"] < r["flops_dense"], r
        assert r["bytes_sparse"] < r["bytes_dense"], r
        # ...and on the wall-clock of the jitted XLA paths at scale
        if r["mix_us_dense"] is not None and r["n_devices"] >= 1024:
            assert r["mix_us_sparse"] < r["mix_us_dense"], r
    lines.append(f"# merge-bench artifact → {out_path}")
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny grid (8/16 devices, few steps) for CI smoke testing; "
             "also emits the merge-bench JSON artifact",
    )
    ap.add_argument(
        "--merge-bench", action="store_true",
        help="sparse-vs-dense merge microbenchmark (D up to 4096) + JSON artifact",
    )
    ap.add_argument(
        "--merge-out", default="BENCH_fleet_merge.json",
        help="path of the merge-bench JSON artifact",
    )
    args = ap.parse_args()
    if args.merge_bench:
        for line in merge_bench_main(MERGE_GRID, args.merge_out):
            print(line)
        print(f"# fleet_scale merge-bench ok — grid {MERGE_GRID}")
        sys.exit(0)
    grid = (8, 16) if args.smoke else (64, 256)
    for line in main(device_grid=grid):
        print(line)
    if args.smoke:
        for line in merge_bench_main(MERGE_GRID_SMOKE, args.merge_out):
            print(line)
    print(f"# fleet_scale ok — grid {grid}")
