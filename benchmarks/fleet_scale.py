"""Fleet-scale federation benchmark — devices × topology grid.

For each (n_devices, topology) cell: simulate the whole fleet in one
process (stacked pytree, vmap+scan), run one cooperative update over
the topology, and report

  - merge wall-clock (jitted, µs/call),
  - per-round communication bytes (payloads × Ñ(Ñ+m)·4, vs an R-round
    FedAvg baseline shipping full SLFN weights),
  - post-merge anomaly ROC-AUC, evaluated with the paper's §5.3.1
    protocol: the fleet trains on a *subset* of the normal patterns so
    the held-out pattern stays anomalous.

Asserted claims:
  - every fully-connected topology's merged model matches all-to-all,
  - the O(D)-traffic topologies (star, hierarchical) beat 10-round
    FedAvg bytes at every fleet size — the paper's one-shot Ñ(Ñ+m)
    claim; all-to-all D2D grows as D(D−1) payloads, which is exactly
    why Jung-style hierarchical clustering matters at fleet scale, and
    hierarchical must always undercut all-to-all,
  - post-merge AUC stays above 0.8 on the HAR-like dataset.

    PYTHONPATH=src python benchmarks/fleet_scale.py [--smoke]
    PYTHONPATH=src python -m benchmarks.fleet_scale [--smoke]

``--smoke`` shrinks the grid to seconds for CI; the default grid runs a
>=256-device simulation on CPU in one process.
"""
from __future__ import annotations

import argparse
import os
import sys

import jax
import numpy as np

if __package__ in (None, ""):  # `python benchmarks/fleet_scale.py` from repo root
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import normalized_dataset, timed
from repro.data import AnomalyDataset
from repro.data.metrics import roc_auc
from repro.data.pipeline import anomaly_eval_arrays, train_test_split
from repro.fleet import (
    all_to_all,
    fedavg_total_cost,
    fleet_merge,
    fleet_score,
    fleet_train,
    hierarchical,
    init_fleet,
    make_fleet_streams,
    ring,
    star,
    topology_round_cost,
)

N_HIDDEN = 32          # narrower than paper Table 3's HAR width: keeps the
                       # 256-device einsum merge CPU-friendly; AUC holds
N_KEEP = 2             # patterns the fleet trains on; the rest stay anomalous
FEDAVG_ROUNDS = 10     # the R-round baseline the paper compares against


def _topologies(n_devices: int) -> list:
    return [
        all_to_all(n_devices),
        star(n_devices),
        ring(n_devices, hops=2),
        hierarchical(n_devices, max(1, n_devices // 8)),
    ]


def _train_subset(ds: AnomalyDataset, keep: int) -> AnomalyDataset:
    """Restrict to the first ``keep`` classes so the rest stay anomalous
    at eval time."""
    mask = ds.y < keep
    return AnomalyDataset(ds.name, ds.x[mask], ds.y[mask], ds.class_names[:keep])


def run(device_grid: tuple[int, ...] = (64, 256), steps: int = 64, seed: int = 0) -> list[dict]:
    ds = normalized_dataset("har", seed=seed, samples_per_class=150)
    train, test = train_test_split(ds, 0.8, seed=seed)
    keep = N_KEEP
    train_sub = _train_subset(train, keep)
    x_eval, y_eval = anomaly_eval_arrays(test, list(range(keep)), seed=seed)
    x_eval = jax.numpy.asarray(x_eval)

    results = []
    for n_dev in device_grid:
        fs = make_fleet_streams(
            train_sub, n_dev, steps, n_init=2 * N_HIDDEN, seed=seed
        )
        fleet0 = init_fleet(
            jax.random.PRNGKey(seed), n_dev, ds.n_features, N_HIDDEN,
            fs.x_init, activation="identity", ridge=1e-3,
        )
        fleet0 = fleet_train(fleet0, fs.xs)

        ref_beta = None
        for topo in _topologies(n_dev):
            merged = fleet_merge(fleet0, topo, ridge=1e-3)
            merge_us = timed(
                lambda f, t=topo: fleet_merge(f, t, ridge=1e-3),
                fleet0, warmup=1, iters=5,
            )
            cost = topology_round_cost(topo, N_HIDDEN, ds.n_features)

            if topo.name == "all_to_all":
                ref_beta = np.asarray(merged.beta)
            beta_diff = (
                float(np.max(np.abs(np.asarray(merged.beta) - ref_beta)))
                if topo.is_fully_connected else float("nan")
            )

            # post-merge AUC on a sample of devices (scores are cheap,
            # roc_auc is a host-side rank statistic)
            n_probe = min(n_dev, 16)
            scores = np.asarray(fleet_score(merged, x_eval)[:n_probe])
            aucs = [roc_auc(scores[d], y_eval) for d in range(n_probe)]
            results.append({
                "n_devices": n_dev,
                "topology": topo.name,
                "merge_us": merge_us,
                "payloads": cost.payloads,
                "bytes": cost.bytes_total,
                "beta_diff_vs_all_to_all": beta_diff,
                "auc_mean": float(np.mean(aucs)),
                "auc_min": float(np.min(aucs)),
            })
        results.append({
            "n_devices": n_dev,
            "topology": f"fedavg_r{FEDAVG_ROUNDS}",
            "merge_us": float("nan"),
            "payloads": (c := fedavg_total_cost(
                n_dev, FEDAVG_ROUNDS, ds.n_features, N_HIDDEN, ds.n_features
            )).payloads,
            "bytes": c.bytes_total,
            "beta_diff_vs_all_to_all": float("nan"),
            "auc_mean": float("nan"),
            "auc_min": float("nan"),
        })
    return results


def main(device_grid: tuple[int, ...] = (64, 256)) -> list[str]:
    results = run(device_grid=device_grid)
    lines = []
    by_size: dict[int, dict[str, dict]] = {}
    for r in results:
        by_size.setdefault(r["n_devices"], {})[r["topology"]] = r
        lines.append(
            f"fleet_scale/{r['topology']}/d{r['n_devices']},"
            f"{r['merge_us']:.1f},"
            f"payloads={r['payloads']};bytes={r['bytes']};"
            f"auc={r['auc_mean']:.3f};beta_diff={r['beta_diff_vs_all_to_all']:.2e}"
        )
    for n_dev, cells in by_size.items():
        fedavg_bytes = cells[f"fedavg_r{FEDAVG_ROUNDS}"]["bytes"]
        for name, r in cells.items():
            if name.startswith("fedavg"):
                continue
            # fully-connected topologies must reproduce the Eq. 8 sum
            if not np.isnan(r["beta_diff_vs_all_to_all"]):
                assert r["beta_diff_vs_all_to_all"] < 5e-2, r
            assert r["auc_mean"] > 0.8, r
        # one-shot (U,V) exchange beats R-round FedAvg traffic on the
        # O(D) topologies; hierarchical always undercuts flat all-to-all
        for name in ("star", "hierarchical"):
            assert cells[name]["bytes"] < fedavg_bytes, (cells[name], fedavg_bytes)
        assert cells["hierarchical"]["bytes"] < cells["all_to_all"]["bytes"]
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny grid (8/16 devices, few steps) for CI smoke testing",
    )
    args = ap.parse_args()
    grid = (8, 16) if args.smoke else (64, 256)
    for line in main(device_grid=grid):
        print(line)
    print(f"# fleet_scale ok — grid {grid}")
