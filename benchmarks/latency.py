"""Paper Table 4 — training / prediction / merging latencies [msec].

OS-ELM (Ñ=64 and Ñ=128, 561 features as in the paper) vs BP-NN3-FL.
The paper's claims:
  • OS-ELM merging latency > training > prediction, grows with Ñ (the
    Ñ×Ñ inverse dominates);
  • OS-ELM's merge runs ONCE, while BP-NN3-FL pays its merge every one
    of R=50 communication rounds → one-shot wins on total cost.
Absolute times differ from the paper's Core i5 (we're on 2 vCPUs and
jitted JAX vs NumPy); the *ordering and structure* are what reproduce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.baselines import bpnn3_config, init_bpnn
from repro.baselines.bpnn import bpnn_loss
from repro.baselines.fedavg import average_params
from repro.core import (
    ae_score,
    cooperative_update,
    init_autoencoder,
    oselm_step_k1,
    to_uv,
)
from repro.optim import adam


def oselm_latencies(n_features: int = 561, n_hidden: int = 64, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (4 * n_hidden, n_features))
    st = init_autoencoder(key, n_features, n_hidden, x, activation="identity", ridge=1e-3)
    x1 = x[0]

    train_fn = jax.jit(lambda s, xi: oselm_step_k1(s, xi, xi))
    pred_fn = jax.jit(lambda s, xi: ae_score(s, xi[None, :]))
    uv = to_uv(st)
    merge_fn = jax.jit(cooperative_update)

    return {
        "train_ms": timed(train_fn, st, x1) / 1e3,
        "predict_ms": timed(pred_fn, st, x1) / 1e3,
        "merge_ms": timed(merge_fn, st, uv) / 1e3,
    }


def bpnn_fl_latencies(n_features: int = 561, n_hidden: int = 64, seed: int = 0):
    cfg = bpnn3_config(n_features, n_hidden, batch=1, epochs=1)
    key = jax.random.PRNGKey(seed)
    params = init_bpnn(key, cfg)
    opt = adam(cfg.lr)
    opt_state = opt.init(params)
    x1 = jax.random.normal(key, (1, n_features))

    @jax.jit
    def train1(p, s, xb):
        g = jax.grad(bpnn_loss)(p, cfg, xb)
        return opt.update(g, s, p)

    @jax.jit
    def pred(p, xb):
        return bpnn_loss(p, cfg, xb)

    pb = [jax.tree.map(jnp.copy, params) for _ in range(2)]

    @jax.jit
    def merge(a, b):
        return average_params([a, b])

    return {
        "train_ms": timed(train1, params, opt_state, x1) / 1e3,
        "predict_ms": timed(pred, params, x1) / 1e3,
        "merge_per_round_ms": timed(merge, pb[0], pb[1]) / 1e3,
        "rounds": 50,
    }


def run(n_hidden: int) -> dict:
    os_lat = oselm_latencies(n_hidden=n_hidden)
    bp_lat = bpnn_fl_latencies(n_hidden=n_hidden)
    return {
        "n_hidden": n_hidden,
        "oselm": os_lat,
        "bpnn3_fl": bp_lat,
        "oselm_total_merge_ms": os_lat["merge_ms"],                 # one-shot
        "fl_total_merge_ms": bp_lat["merge_per_round_ms"] * bp_lat["rounds"],
    }


def main() -> list[str]:
    lines = []
    r64 = run(64)
    r128 = run(128)
    # Table-4 structural claims. Sub-ms predict/train orderings jitter on
    # shared 2-vCPU machines, so only the robust one-shot-vs-R-rounds
    # claim is asserted; the full latency rows are reported for the table.
    assert r64["oselm_total_merge_ms"] < r64["fl_total_merge_ms"]      # one-shot wins
    assert r128["oselm_total_merge_ms"] < r128["fl_total_merge_ms"]
    for r in (r64, r128):
        o = r["oselm"]
        lines.append(
            f"latency/oselm_N{r['n_hidden']},{o['train_ms']*1e3:.1f},"
            f"train={o['train_ms']:.3f}ms;pred={o['predict_ms']:.3f}ms;"
            f"merge={o['merge_ms']:.3f}ms;fl_total_merge={r['fl_total_merge_ms']:.1f}ms"
        )
    return lines


if __name__ == "__main__":
    for l in main():
        print(l)
