"""Paper §5 headline tables — the proposed one-shot cooperative update
vs BP-NN and R-round FedAvg at matched communication rounds.

For every registered paper-analog scenario (``driving`` / ``har`` /
``mnist_like``, ``repro.scenarios``) the harness:

1. drives the scenario end-to-end through ``FleetRuntime`` on each
   topology (smoke: ring + star — the paper's D2D gossip and its
   Fig. 4/5 server exchange; full grid adds all_to_all +
   hierarchical), reporting per-device (local, pre-merge) and
   post-merge ON/IN-style ROC-AUC through the shared scenario
   evaluation path (``repro.scenarios.evaluate``);
2. trains the BP-NN3 autoencoder baseline (``repro.baselines.bpnn``)
   on the pooled normal-phase data — the centralized comparison point
   of Figs. 10/11/15/16;
3. runs BP-NN3-FL (``repro.baselines.fedavg``) over the same
   per-device normal-phase streams for R = (the runtime's admitted
   merge count) rounds — the matched-rounds federated baseline — and
   compares communication: FedAvg ships the full SLFN-equivalent model
   2·D times per round, the proposed method ships Ñ(Ñ+m) payloads over
   the topology only when the governor admits a merge.

Asserted claims (the acceptance bar):
  - all scenarios run green end-to-end through the runtime on every
    requested topology (≥1 admitted merge, finite AUCs, compile-once);
  - on at least one scenario the merged model's clean-device AUC is
    within 0.02 of the BP-NN baseline on EVERY topology of the grid;
  - that scenario's cooperative updates ship ≥5× fewer bytes than
    R-round FedAvg at matched rounds (asserted for every sparse
    topology; the full grid's all_to_all is the paper's deliberately
    expensive D2D baseline and is reported, not asserted).

Artifacts: ``BENCH_paper_eval.json`` (full report) plus a
``BENCH_history.jsonl`` entry per run — wall-clock keys are gated by
``benchmarks.history.check_regression`` (generous 50% threshold: a
scenario wall includes dataset synthesis and compiles).

    PYTHONPATH=src python benchmarks/paper_eval.py [--smoke|--full]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

if __package__ in (None, ""):  # `python benchmarks/paper_eval.py` from repo root
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.history import record_and_gate
from repro.baselines import bpnn3_config, run_fedavg, train_bpnn
from repro.baselines.fedavg import FedAvgConfig
from repro.fleet.comm import fedavg_total_cost
from repro.obs import TelemetryConfig
from repro.scenarios import SCENARIOS, bpnn_auc, make_scenario, run_scenario

MERGE_EVERY = 16
BPNN_HIDDEN = 128          # BP-NN3 width (its model is what FedAvg ships)
BPNN_EPOCHS = 6
AUC_MARGIN = 0.02          # "as accurately as BP-NN": within this margin
COMM_FACTOR = 5.0          # proposed ships ≥5× fewer bytes than FedAvg
# the quantized-payload path (int8/f16 block codec with error feedback,
# repro.fleet.quantize) must clear a per-precision bar: int8 (~4× the
# f32 wire) carries the ROADMAP's ≥60× target; f16 is a flat 2×
COMM_FACTOR_QUANTIZED = 60.0
COMM_FACTOR_BY_PRECISION = {"f32": COMM_FACTOR, "f16": 10.0,
                            "int8": COMM_FACTOR_QUANTIZED}

SMOKE_SIZES = {"n_devices": 8, "ticks": 80}
FULL_SIZES = {"n_devices": 24, "ticks": 120}
SMOKE_TOPOLOGIES = ("ring", "star")
FULL_TOPOLOGIES = ("ring", "star", "hierarchical", "all_to_all")
# the full-mesh D2D exchange is the paper's expensive baseline — its
# comm ratio is reported but never asserted against COMM_FACTOR
UNASSERTED_TOPOLOGIES = ("all_to_all",)


def _normal_phase_pool(sc) -> tuple[np.ndarray, list[np.ndarray]]:
    """Training data the baselines may see: every device's NORMAL-phase
    samples (drifted tails train the proposed fleet too, but handing
    the anomalous concept to a baseline would corrupt its comparison).
    Returns (pooled (N, F) for BP-NN, per-device list for FedAvg)."""
    mask = sc.streams.pattern_of_device < sc.spec.n_normal
    per_device = [sc.streams.xs[d][mask[d]] for d in range(sc.spec.n_devices)]
    return np.concatenate(per_device), per_device


def eval_scenario(
    name: str,
    sizes: dict,
    topologies: tuple[str, ...],
    *,
    seed: int = 0,
    payload_precision: str = "f32",
) -> dict:
    """One scenario row of the headline table."""
    spec = make_scenario(name, **sizes)
    sc = spec.build()

    rows: dict[str, dict] = {}
    for topo in topologies:
        t0 = time.perf_counter()
        res = run_scenario(
            spec, topo, merge_every=MERGE_EVERY, key_seed=seed, scenario=sc,
            payload_precision=payload_precision,
            telemetry=TelemetryConfig(),
        )
        wall = time.perf_counter() - t0
        # the sink's ledger must agree with the governor's: same bytes,
        # same admitted rounds — one instrumentation surface, no forks
        tel = res.telemetry
        assert tel is not None and tel["bytes_total"] == res.comm_bytes, (
            f"{name}/{topo}: telemetry bytes {tel and tel['bytes_total']} "
            f"!= governor ledger {res.comm_bytes}"
        )
        assert tel["merge_rounds"] == res.merges, (
            f"{name}/{topo}: telemetry rounds {tel['merge_rounds']} "
            f"!= governor merges {res.merges}"
        )
        det = res.detection
        rows[topo] = {
            **res.auc_summary(),
            "merges": res.merges,
            "comm_bytes": res.comm_bytes,
            "bytes_per_merge": res.comm_bytes / max(res.merges, 1),
            "detection_delay_mean": det["delay_mean"],
            "missed_detections": len(det["missed"]),
            "false_positives": len(det["false_positives"]),
            "wall_seconds": wall,
            "tick_p50_us": tel["tick_latency"]["p50_s"] * 1e6,
            "tick_p99_us": tel["tick_latency"]["p99_s"] * 1e6,
        }

    # ---- BP-NN3 centralized baseline on the pooled normal-phase data
    x_pool, per_device = _normal_phase_pool(sc)
    cfg = bpnn3_config(sc.n_features, BPNN_HIDDEN, batch=8, epochs=BPNN_EPOCHS)
    t0 = time.perf_counter()
    params = train_bpnn(jax.random.PRNGKey(seed), cfg, x_pool)
    bp_auc = bpnn_auc(params, cfg, sc.x_eval, sc.y_eval)
    bp_wall = time.perf_counter() - t0

    # ---- BP-NN3-FL at MATCHED rounds. The AUC comparison trains once
    # at the grid's max merge count; each topology's comm ratio uses
    # FedAvg bytes at THAT topology's own admitted merge count, so the
    # ratio really is bytes-per-matched-round.
    rounds = max(1, max(rows[t]["merges"] for t in topologies))
    t0 = time.perf_counter()
    fa_params = run_fedavg(
        jax.random.PRNGKey(seed + 1), cfg, per_device,
        FedAvgConfig(rounds=rounds, local_epochs=1),
    )
    fa_auc = bpnn_auc(fa_params, cfg, sc.x_eval, sc.y_eval)
    fa_wall = time.perf_counter() - t0
    fa_bytes = fedavg_total_cost(
        spec.n_devices, rounds, sc.n_features, BPNN_HIDDEN, sc.n_features
    ).bytes_total
    for topo in topologies:
        r = rows[topo]
        matched = fedavg_total_cost(
            spec.n_devices, max(r["merges"], 1), sc.n_features,
            BPNN_HIDDEN, sc.n_features,
        ).bytes_total
        r["fedavg_bytes_matched"] = matched
        r["comm_ratio_vs_fedavg"] = matched / max(r["comm_bytes"], 1)

    return {
        "scenario": name,
        "n_devices": spec.n_devices,
        "ticks": spec.ticks,
        "n_features": sc.n_features,
        "n_hidden": spec.n_hidden,
        "payload_precision": payload_precision,
        "topologies": rows,
        "bpnn": {"auc": bp_auc, "hidden": BPNN_HIDDEN, "epochs": BPNN_EPOCHS,
                 "wall_seconds": bp_wall},
        "fedavg": {"auc": fa_auc, "rounds": rounds, "bytes": fa_bytes,
                   "wall_seconds": fa_wall},
    }


def check_claims(
    report: dict,
    topologies: tuple[str, ...],
    *,
    comm_factor: float = COMM_FACTOR,
) -> dict:
    """The mechanical form of the paper's headline claims.
    ``comm_factor`` is the per-topology comm bar — the base ≥5× for f32
    payloads, the ROADMAP's ≥60× for the quantized wire formats."""
    asserted = [t for t in topologies if t not in UNASSERTED_TOPOLOGIES]
    green = {}
    matches = []
    for name, row in report["scenarios"].items():
        for topo, r in row["topologies"].items():
            green[f"{name}/{topo}"] = bool(
                r["merges"] >= 1
                and np.isfinite(r["merged_auc_mean"])
                and np.isfinite(r["local_auc_mean"])
            )
        bp = row["bpnn"]["auc"]
        near_bp = all(
            row["topologies"][t]["clean_merged_auc_mean"] >= bp - AUC_MARGIN
            for t in asserted
        )
        cheap = all(
            row["topologies"][t]["comm_ratio_vs_fedavg"] >= comm_factor
            for t in asserted
        )
        if near_bp and cheap:
            matches.append(name)
    return {
        "all_green": all(green.values()),
        "green": green,
        "auc_and_comm_scenarios": matches,
    }


def run_bench(
    *, smoke: bool = True, seed: int = 0, payload_precision: str = "f32"
) -> dict:
    sizes = SMOKE_SIZES if smoke else FULL_SIZES
    topologies = SMOKE_TOPOLOGIES if smoke else FULL_TOPOLOGIES
    comm_factor = COMM_FACTOR_BY_PRECISION[payload_precision]
    scenarios = {
        name: eval_scenario(
            name, sizes, topologies, seed=seed,
            payload_precision=payload_precision,
        )
        # fault-carrying presets (e.g. "adversarial") are
        # benchmarks/robust_fleet.py's job — this grid is the CLEAN
        # paper comparison, and the hardened merge boundary the faults
        # arm is (by design) incompatible with quantized payloads
        for name in sorted(SCENARIOS)
        if not SCENARIOS[name]().faults
    }
    report = {
        "backend": jax.default_backend(),
        "smoke": smoke,
        "merge_every": MERGE_EVERY,
        "auc_margin": AUC_MARGIN,
        "comm_factor": comm_factor,
        "payload_precision": payload_precision,
        "scenarios": scenarios,
    }
    report["claims"] = check_claims(report, topologies, comm_factor=comm_factor)
    return report


def main(
    smoke: bool = True,
    out_path: str | None = None,
    history_path: str = "BENCH_history.jsonl",
    payload_precision: str = "f32",
) -> list[str]:
    quantized = payload_precision != "f32"
    if out_path is None:
        # the int8 artifact keeps the CI-facing _q name; f16 gets its
        # own file (and history bench) so the two lossy precisions never
        # cross-trip each other's comm-ratio baselines
        out_path = {
            "f32": "BENCH_paper_eval.json",
            "f16": "BENCH_paper_eval_f16.json",
            "int8": "BENCH_paper_eval_q.json",
        }[payload_precision]
    report = run_bench(smoke=smoke, payload_precision=payload_precision)
    # persist BEFORE asserting — a failed claim still leaves the artifact
    with open(out_path, "w") as fh:
        json.dump(report, fh, indent=2)

    bench_name = {
        "f32": "paper_eval", "f16": "paper_eval_f16", "int8": "paper_eval_q"
    }[payload_precision]
    lines = []
    metrics: dict[str, float] = {}
    for name, row in report["scenarios"].items():
        bp, fa = row["bpnn"]["auc"], row["fedavg"]["auc"]
        # gate on the per-SCENARIO total (runtime grid + both baselines):
        # per-topology walls shuffle compile/build costs between rows run
        # to run, but the scenario total is stable
        metrics[f"{name}_total_us"] = 1e6 * (
            sum(r["wall_seconds"] for r in row["topologies"].values())
            + row["bpnn"]["wall_seconds"] + row["fedavg"]["wall_seconds"]
        )
        for topo, r in row["topologies"].items():
            wall_us = r["wall_seconds"] * 1e6
            metrics[f"{name}_{topo}_clean_auc"] = r["clean_merged_auc_mean"]
            if quantized:
                # _ratio-suffixed keys are history-gated as
                # higher-is-better: a shrinking comm ratio fails the run
                metrics[f"{name}_{topo}_comm_ratio"] = r["comm_ratio_vs_fedavg"]
            lines.append(
                f"{bench_name}/{name}/{topo},{wall_us:.1f},"
                f"local={r['local_auc_mean']:.3f};"
                f"merged={r['merged_auc_mean']:.3f};"
                f"clean={r['clean_merged_auc_mean']:.3f};"
                f"bpnn={bp:.3f};fedavg_r{row['fedavg']['rounds']}={fa:.3f};"
                f"merges={r['merges']};"
                f"bytes_per_merge={r['bytes_per_merge']:.0f};"
                f"comm_x={r['comm_ratio_vs_fedavg']:.1f}"
            )

    claims = report["claims"]
    # all scenarios green end-to-end through the runtime on every topology
    assert claims["all_green"], claims["green"]
    # ≥1 scenario matches BP-NN within the margin AND beats FedAvg's
    # matched-rounds bytes by the precision's comm bar (f32 ≥5×,
    # f16 ≥10×, int8 ≥60×) on every asserted topology
    assert claims["auc_and_comm_scenarios"], report["scenarios"]
    lines.append(
        f"# {bench_name} claims ok (payload={payload_precision}, "
        f"comm_factor={report['comm_factor']:g}) — AUC+comm scenarios: "
        f"{claims['auc_and_comm_scenarios']} → {out_path}"
    )
    # history gate AFTER the claims: a wall-clock regression should not
    # mask (or be masked by) a paper-claim failure. The quantized run
    # gates tighter (25%) and additionally on the comm-ratio keys.
    record_and_gate(
        bench_name, metrics, path=history_path,
        threshold=0.25 if quantized else 0.5,
    )
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI grid — all three scenarios through the runtime on "
             "ring + star (this IS the acceptance configuration)",
    )
    ap.add_argument("--full", action="store_true",
                    help="the full topology grid (slow; bigger fleets)")
    ap.add_argument(
        "--payload-precision", default="f32", choices=("f32", "f16", "int8"),
        help="merge-payload wire format; non-f32 raises the asserted "
             "comm bar to the precision's quantized target (int8 ≥60×, "
             "f16 ≥10×) and writes its own BENCH artifact",
    )
    ap.add_argument("--out", default=None,
                    help="report path (default depends on precision)")
    args = ap.parse_args()
    for line in main(
        smoke=not args.full, out_path=args.out,
        payload_precision=args.payload_precision,
    ):
        print(line)
    print(
        f"# paper_eval ok ({'smoke' if not args.full else 'full'} grid, "
        f"payload={args.payload_precision})"
    )
