"""Paper Figs. 6/7 — loss values before/after the cooperative model update.

Scenario (§5.2): Device-A trains on pattern p_A, Device-B on p_B; after
A merges B's intermediate results, p_B's loss on A collapses while p_A
stays low. Run for the driving dataset (normal vs aggressive) and the
HAR dataset (sitting vs laying), plus BP-NN3 reference bars.
"""
from __future__ import annotations

import jax

from benchmarks.common import edge_config, normalized_dataset, train_edge_device
from repro.data.pipeline import train_test_split
from repro.scenarios.evaluate import pattern_loss_rows


SCENARIOS = {
    "driving": ("normal", "aggressive"),
    "har": ("sitting", "laying"),
}


def run(dataset: str = "driving", seed: int = 0) -> dict:
    ds = normalized_dataset(dataset, seed=seed)
    train, test = train_test_split(ds, 0.8, seed=seed)
    ecfg = edge_config(dataset)
    p_a, p_b = SCENARIOS[dataset]
    key = jax.random.PRNGKey(seed)

    dev_a = train_edge_device(train, p_a, key=key, ecfg=ecfg, seed=seed)
    dev_b = train_edge_device(train, p_b, key=key, ecfg=ecfg, seed=seed + 1)

    # per-pattern loss bars through the shared scenario evaluation path
    rows = pattern_loss_rows(dev_a, dev_b, test, limit=64)

    # the paper's claims, checked mechanically. Note the driving
    # 'aggressive' pattern is intrinsically high-entropy (volatile
    # Markov process → noisy transition tables), so the post-merge loss
    # is compared against Device-B's own loss (perfect knowledge
    # transfer) rather than an absolute collapse factor.
    claims = {
        # A inherits B's competence on p_B (Fig. 6/7 red bar ≈ blue bar)
        "pB_transferred": rows[p_b]["A_after"] < 2.0 * rows[p_b]["B"] + 1e-6,
        # and improves substantially over its own pre-merge loss
        "pB_improved": rows[p_b]["A_after"] < 0.6 * rows[p_b]["A_before"],
        # p_A stays normal (may rise slightly — Fig. 6 note)
        "pA_stays_low": rows[p_a]["A_after"] < 3 * max(rows[p_a]["A_before"], 1e-6),
    }
    return {"dataset": dataset, "rows": rows, "claims": claims}


def main() -> list[str]:
    lines = []
    for dsname in SCENARIOS:
        out = run(dsname)
        ok = all(out["claims"].values())
        p_a, p_b = SCENARIOS[dsname]
        r = out["rows"][p_b]
        lines.append(
            f"merge_loss/{dsname},{0:.1f},"
            f"pB_before={r['A_before']:.4f};pB_after={r['A_after']:.4f};claims_ok={ok}"
        )
        assert ok, f"paper claim violated: {out}"
    return lines


if __name__ == "__main__":
    for l in main():
        print(l)
