"""Ablation — detector quality/cost vs hidden width Ñ (extends the
paper's Table-4 Ñ ∈ {64,128} axis with the accuracy dimension).

For Ñ ∈ {16, 32, 64, 128, 256}: post-merge ROC-AUC on HAR (two-device
scenario averaged over three pattern pairs) and the U/V payload size —
the accuracy/communication trade the paper leaves implicit.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import normalized_dataset, train_edge_device
from repro.configs.oselm_edge import EdgeConfig
from repro.core import ae_score, cooperative_update, to_uv
from repro.data.metrics import roc_auc
from repro.data.pipeline import anomaly_eval_arrays, train_test_split

PAIRS = [(3, 5), (0, 4), (1, 3)]  # (sitting,laying), (walking,standing), ...


def run(widths=(16, 32, 64, 128, 256), seed: int = 0) -> list[dict]:
    ds = normalized_dataset("har", seed=seed, samples_per_class=420)
    train, test = train_test_split(ds, 0.8, seed=seed)
    out = []
    for nh in widths:
        ecfg = EdgeConfig("har", ds.n_features, nh, "identity", ridge=1e-2)
        aucs = []
        for pa, pb in PAIRS:
            key = jax.random.PRNGKey(seed)
            dev_a = train_edge_device(train, pa, key=key, ecfg=ecfg, seed=seed)
            dev_b = train_edge_device(train, pb, key=key, ecfg=ecfg, seed=seed + 7)
            merged = cooperative_update(dev_a, to_uv(dev_b))
            x, y = anomaly_eval_arrays(test, [pa, pb], seed=seed)
            aucs.append(roc_auc(np.asarray(ae_score(merged, x)), y))
        payload = 4 * (nh * nh + nh * ds.n_features)
        out.append({"n_hidden": nh, "auc": float(np.mean(aucs)), "payload_bytes": payload})
    return out


def main() -> list[str]:
    rows = run()
    # wider is (weakly) better until saturation; payload grows quadratically+linearly
    assert rows[-1]["auc"] >= rows[0]["auc"] - 0.05
    return [
        f"ablation_hidden/N{r['n_hidden']},{0:.1f},auc={r['auc']:.3f};payload={r['payload_bytes']}B"
        for r in rows
    ]


if __name__ == "__main__":
    for l in main():
        print(l)
