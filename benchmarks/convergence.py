"""Paper Fig. 18 — convergence of loss: one-shot merging vs sequential
training.

Device-A is trained on 'laying', Device-B on 'walking' (HAR, Ñ=128).
The merge transfers A's knowledge to B instantly; conventional
sequential training of the laying pattern on B needs ~hundreds of
updates to reach the same loss. We report the crossover count and the
implied latency ratio (paper: 650 × 0.794 ms vs one 21.8 ms merge).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import edge_config, normalized_dataset, train_edge_device
from repro.core import ae_score, ae_train_step, cooperative_update, to_uv
from repro.data.pipeline import make_pattern_stream, train_test_split


def run(seed: int = 0, eval_every: int = 50, max_updates: int = 2000) -> dict:
    ds = normalized_dataset("har", seed=seed)
    train, test = train_test_split(ds, 0.8, seed=seed)
    ecfg = edge_config("har")  # Ñ=128 as in §5.5
    key = jax.random.PRNGKey(seed)

    dev_a = train_edge_device(train, "laying", key=key, ecfg=ecfg, seed=seed)
    dev_b = train_edge_device(train, "walking", key=key, ecfg=ecfg, seed=seed + 1)
    x_eval = jnp.asarray(test.pattern("laying")[:64])

    # one-shot merge: B absorbs A
    merged = cooperative_update(dev_b, to_uv(dev_a))
    merge_loss = float(ae_score(merged, x_eval).mean())

    # conventional sequential training of laying on B
    stream = make_pattern_stream(train, "laying", seed=seed + 2)
    stream = np.concatenate([stream] * (max_updates // len(stream) + 1))[:max_updates]
    st = dev_b
    curve = []
    crossover = None
    step_fn = jax.jit(ae_train_step)
    for i in range(max_updates):
        st = step_fn(st, jnp.asarray(stream[i]))
        if (i + 1) % eval_every == 0:
            l = float(ae_score(st, x_eval).mean())
            curve.append((i + 1, l))
            if crossover is None and l <= merge_loss * 1.1:
                crossover = i + 1
                break
    return {
        "merge_loss": merge_loss,
        "curve": curve,
        "crossover_updates": crossover,
        "loss_before": float(ae_score(dev_b, x_eval).mean()),
    }


def main() -> list[str]:
    r = run()
    assert r["merge_loss"] < r["loss_before"] / 5, r
    cross = r["crossover_updates"]
    assert cross is None or cross >= 50  # merge is not beaten instantly
    return [
        f"convergence/har,{0:.1f},"
        f"merge_loss={r['merge_loss']:.4f};before={r['loss_before']:.4f};"
        f"crossover_updates={cross}"
    ]


if __name__ == "__main__":
    for l in main():
        print(l)
