"""Pallas kernel micro-bench (interpret mode on CPU — structural only;
real perf numbers require a TPU). Reports µs/call + achieved GFLOP/s
of the jnp reference path for context."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.core import init_oselm, init_slfn
from repro.kernels import hidden_proj, matmul_atb, oselm_step_k1_kernel, rank1_add
from repro.kernels.ref import atb_ref, hidden_proj_ref


def main() -> list[str]:
    lines = []
    key = jax.random.PRNGKey(0)
    m, k, n = 256, 561, 128
    x = jax.random.normal(key, (m, k))
    a = jax.random.normal(key, (k, n))
    b = jax.random.normal(key, (n,))

    us = timed(lambda: hidden_proj(x, a, b, activation="sigmoid"), iters=5)
    ref_us = timed(jax.jit(lambda: hidden_proj_ref(x, a, b, "sigmoid")), iters=5)
    gf = 2 * m * k * n / (ref_us * 1e-6) / 1e9
    lines.append(f"kernel/hidden_proj_interp,{us:.0f},ref_us={ref_us:.0f};ref_gflops={gf:.2f}")

    h = jax.random.normal(key, (512, 128))
    us = timed(lambda: matmul_atb(h, h), iters=5)
    ref_us = timed(jax.jit(lambda: atb_ref(h, h)), iters=5)
    lines.append(f"kernel/uv_accum_interp,{us:.0f},ref_us={ref_us:.0f}")

    p = jnp.eye(128) * 0.5
    u = jax.random.normal(key, (128,))
    us = timed(lambda: rank1_add(p, u, u, -0.3), iters=5)
    lines.append(f"kernel/rank1_add_interp,{us:.0f},")

    params = init_slfn(key, 561, 128)
    x0 = jax.random.normal(key, (256, 561))
    st = init_oselm(params, x0, x0, activation="sigmoid", ridge=1e-3)
    us = timed(lambda: oselm_step_k1_kernel(st, x0[0], x0[0]), iters=3)
    lines.append(f"kernel/oselm_step_fused_interp,{us:.0f},")
    return lines


if __name__ == "__main__":
    for l in main():
        print(l)
