"""Benchmark trajectory: append-only JSONL history + regression gate.

Every harness run appends one summary line per benchmark to
``BENCH_history.jsonl`` ({bench, timestamp, backend, metrics}) so the
wall-clock trajectory of the hot paths survives across runs — locally
across working sessions, in CI across workflow runs (the file is
persisted through the actions cache).

``check_regression`` compares a fresh set of gated metrics against the
MOST RECENT prior entry of the same benchmark on the same backend:
keys ending in ``_us`` are wall-clocks (lower is better — fail on a
>``threshold`` slowdown) and keys ending in ``_ratio`` are
efficiency ratios like the comm-vs-FedAvg factor (higher is better —
fail on a >``threshold`` shrink). The first run of a benchmark seeds
the baseline (nothing to compare against); a metric that disappears or
appears is ignored — only like-for-like keys gate.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax

DEFAULT_PATH = "BENCH_history.jsonl"
DEFAULT_THRESHOLD = 0.25  # fail on >25% wall-clock regression


def _load_last(path: str | Path, bench: str, backend: str) -> dict | None:
    """Most recent prior entry for (bench, backend) that is usable as a
    baseline, or None. Entries recorded by a FAILING gate carry
    ``"regressed": true`` and are skipped — a regression that fired must
    not ratchet the baseline to the regressed level on the next run."""
    p = Path(path)
    if not p.exists():
        return None
    last = None
    for line in p.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            entry = json.loads(line)
        except json.JSONDecodeError:
            continue  # a torn write must not wedge every future run
        if (
            entry.get("bench") == bench
            and entry.get("backend") == backend
            and not entry.get("regressed", False)
        ):
            last = entry
    return last


def record(
    bench: str,
    metrics: dict[str, float],
    *,
    path: str | Path = DEFAULT_PATH,
    regressed: bool = False,
) -> dict | None:
    """Append one history line; returns the PREVIOUS baseline entry for
    the same (bench, backend) — what ``check_regression`` gates against
    — or None when this run seeds it. ``regressed=True`` marks the
    entry as a failing run's measurement: kept for debugging, never
    served as a future baseline."""
    backend = jax.default_backend()
    prev = _load_last(path, bench, backend)
    entry = {
        "bench": bench,
        "timestamp": time.time(),
        "backend": backend,
        "metrics": {k: float(v) for k, v in metrics.items()},
    }
    if regressed:
        entry["regressed"] = True
    with open(path, "a") as fh:
        fh.write(json.dumps(entry) + "\n")
    return prev


def check_regression(
    prev: dict | None,
    metrics: dict[str, float],
    *,
    threshold: float = DEFAULT_THRESHOLD,
) -> list[str]:
    """Regression report vs a prior entry.

    Compares every shared key ending in ``_us`` (wall-clock, lower is
    better: fail when more than ``threshold`` slower) or ``_ratio``
    (efficiency, higher is better: fail when more than ``threshold``
    smaller). Returns one line per failing metric. Empty list = pass
    (including the baseline-seeding first run, prev=None)."""
    if prev is None:
        return []
    failures = []
    for key, new_val in metrics.items():
        old_val = prev.get("metrics", {}).get(key)
        if old_val is None or old_val <= 0:
            continue
        ratio = float(new_val) / float(old_val)
        if key.endswith("_us") and ratio > 1.0 + threshold:
            failures.append(
                f"{key}: {old_val:.1f}us -> {float(new_val):.1f}us "
                f"({(ratio - 1.0) * 100:.0f}% slower, limit "
                f"{threshold * 100:.0f}%)"
            )
        elif key.endswith("_ratio") and ratio < 1.0 - threshold:
            failures.append(
                f"{key}: {old_val:.1f}x -> {float(new_val):.1f}x "
                f"({(1.0 - ratio) * 100:.0f}% smaller, limit "
                f"{threshold * 100:.0f}%)"
            )
    return failures


def record_and_gate(
    bench: str,
    metrics: dict[str, float],
    *,
    path: str | Path = DEFAULT_PATH,
    threshold: float = DEFAULT_THRESHOLD,
) -> None:
    """Append to the history and raise if any wall-clock metric
    regressed >``threshold`` vs the previous same-backend baseline. The
    fresh measurements are persisted even when the gate fires (marked
    ``regressed`` so they never become a baseline themselves), so a
    regression leaves the data needed to debug it WITHOUT the next
    re-run silently passing against the slowed-down numbers."""
    prev = _load_last(path, bench, jax.default_backend())
    failures = check_regression(prev, metrics, threshold=threshold)
    record(bench, metrics, path=path, regressed=bool(failures))
    if failures:
        raise AssertionError(
            f"{bench}: wall-clock regression vs previous history entry — "
            + "; ".join(failures)
        )
