"""Cohort-paged fleet benchmark — the million-device arena runtime.

Every earlier benchmark kept the stacked fleet device-resident, capping
D near 10⁴. This one drives ``CohortFleetRuntime``: per-device (P, β)
in a host-side ``FleetArena``, cohorts streamed through the fused
ingest double-buffered, and Eq. 8 as the two-tier tree (intra-cohort
segment sums, O(cohorts) inter-cohort reduction). Measured at
D = 131072 (the ``--smoke`` leg CI runs — still past the 10⁵ bar) and
D = 1048576 devices (``--full``), Ñ=4 / n=8 / B=4 — the paper's tiny
on-device autoencoder at fleet scale.

Asserted claims:
  - correctness first: at D=64 the paged runtime's TickReport stream
    (losses, drift flags, merge decisions) matches the resident
    ``FleetRuntime`` tick-by-tick, and the two-tier merged fleet state
    agrees with the flat resident merge to ≤1e-5;
  - the scale runtime is compile-once (``assert_compile_once``) — the
    page jits trace exactly once across all cohorts and ticks;
  - tier-2 (cross-cohort overlay) traffic is O(cohorts), not
    O(devices): the star round at D=131072 ships 2·(cohorts−1)
    payloads across the overlay vs 2·(D−1) for the flat round.

Reported per scale point: paged tick wall-clock, virtual devices/sec
through ingest, the two-tier merge wall-clock, and bytes/round per
tier. Appends to ``BENCH_history.jsonl``; standalone/CI runs gate >25%
wall-clock regressions (``_us``) and tier-2 reduction shrink
(``_ratio``) against the previous same-backend entry.

    PYTHONPATH=src python benchmarks/fleet_cohort.py [--smoke|--full]
    PYTHONPATH=src python -m benchmarks.fleet_cohort
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

if __package__ in (None, ""):  # `python benchmarks/fleet_cohort.py` from repo root
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.history import record, record_and_gate
from repro.fleet import FleetArena, cohort_round_cost, init_fleet, init_arena, star
from repro.runtime import (
    CohortFleetRuntime,
    DetectorConfig,
    FleetRuntime,
    GovernorConfig,
    RuntimeConfig,
)

N_HIDDEN = 4          # the paper's on-device autoencoder is tiny — the
N_FEATURES = 8        # point of arena scale is D, not model width
BATCH = 4             # samples per device per tick
N_INIT = 16           # Eq. 13 boot chunk per device
COHORT = 16384        # resident page: 16k devices ≈ 3 MB of (P, β)
SMOKE_D = 131072      # 2¹⁷ — the CI leg, already past the 10⁵ bar
FULL_D = 1048576      # 2²⁰ — the ROADMAP's million-device claim
TIMED_TICKS = 4
TIMED_MERGES = 3
PARITY_D, PARITY_C, PARITY_TICKS = 64, 16, 8


def _paged_config(d: int) -> RuntimeConfig:
    return RuntimeConfig(
        topology=star(d),
        ridge=1e-2,
        detector=DetectorConfig(warmup=4, warmup_skip=1),
        governor=GovernorConfig(merge_every=4),
        use_ingest_kernel=True,
        ingest_backend="xla" if jax.default_backend() != "tpu" else "auto",
    )


def check_parity(seed: int = 0) -> float:
    """Paged vs resident differential at D=64: identical TickReport
    stream, ≤1e-5 fleet state after merges. Returns the max |β| gap."""
    d, c = PARITY_D, PARITY_C
    key = jax.random.PRNGKey(seed)
    x0 = jax.random.normal(key, (d, N_INIT, N_FEATURES)) * 0.5
    states = init_fleet(
        jax.random.PRNGKey(seed + 1), d, N_FEATURES, N_HIDDEN, x0, ridge=1e-2
    )
    cfg = _paged_config(d)
    resident = FleetRuntime(states, cfg)
    paged = CohortFleetRuntime(FleetArena.from_fleet(states), cfg, cohort_size=c)
    rng = np.random.default_rng(seed + 2)
    for t in range(PARITY_TICKS):
        batch = rng.normal(scale=0.5, size=(d, BATCH, N_FEATURES)).astype(np.float32)
        r1 = resident.tick(batch)
        r2 = paged.tick(batch)
        np.testing.assert_allclose(r1.losses, r2.losses, rtol=1e-5, atol=1e-6)
        assert np.array_equal(r1.drifted, r2.drifted), t
        assert r1.decision == r2.decision, (t, r1.decision, r2.decision)
    # the acceptance claim proper: ONE two-tier round vs ONE flat
    # resident round over the SAME fleet state agrees ≤ 1e-5 (the sum
    # tree only reorders f32 accumulation)
    from repro.fleet import fleet_merge_masked

    arena2 = FleetArena.from_fleet(resident.states)
    ones = np.ones(d, bool)
    paged.merger.merge(arena2, ones)
    flat = fleet_merge_masked(
        resident.states, cfg.topology, ones, ridge=cfg.ridge
    )
    gap = float(np.abs(np.asarray(flat.beta) - arena2.beta).max())
    assert gap <= 1e-5, f"two-tier merge diverged from flat: {gap}"
    # end-to-end drift after PARITY_TICKS ticks (training re-amplifies
    # the per-round reorder noise) stays within a few ULP-mults of it
    e2e = float(
        np.abs(np.asarray(resident.states.beta) - paged.arena.beta).max()
    )
    assert e2e <= 5e-5, f"paged runtime drifted from resident: {e2e}"
    return gap


def run_scale(n_devices: int, seed: int = 0) -> dict:
    """Time the paged runtime at scale: ingest ticks + one two-tier
    merge round over a host arena that never exists as a stacked fleet."""
    sched_cohort = min(COHORT, n_devices)
    rng = np.random.default_rng(seed)
    boot = rng.normal(scale=0.5, size=(sched_cohort, N_INIT, N_FEATURES)).astype(
        np.float32
    )

    t0 = time.perf_counter()
    arena = init_arena(
        jax.random.PRNGKey(seed), n_devices, N_FEATURES, N_HIDDEN,
        lambda lo, hi: boot[: hi - lo],
        cohort_size=sched_cohort, ridge=1e-2,
    )
    init_seconds = time.perf_counter() - t0

    cfg = _paged_config(n_devices)
    rt = CohortFleetRuntime(arena, cfg, cohort_size=sched_cohort)
    window = rng.normal(
        scale=0.5, size=(sched_cohort, BATCH, N_FEATURES)
    ).astype(np.float32)
    batch_fn = lambda lo, hi: window[: hi - lo]  # noqa: E731

    rt.tick(batch_fn, allow_merge=False)  # compile the page jits
    # best-of floors (the serve_ingress idiom): shared-box load noise
    # swings single-run wall-clock far past the 25% history gate
    tick_us = []
    for _ in range(TIMED_TICKS):
        t0 = time.perf_counter()
        rt.tick(batch_fn, allow_merge=False)
        tick_us.append((time.perf_counter() - t0) * 1e6)
    tick_best_us = float(np.min(tick_us))

    ones = np.ones(n_devices, bool)
    merge_us = []
    for _ in range(TIMED_MERGES):
        t0 = time.perf_counter()
        cost = rt.merger.merge(arena, ones)
        merge_us.append((time.perf_counter() - t0) * 1e6)
    merge_best_us = float(np.min(merge_us))
    rt.assert_compile_once()

    # tier accounting: the overlay (tier 2) must be O(cohorts); the flat
    # star round ships 2(D−1) payloads where the two-tier round's
    # overlay ships 2(cohorts−1)
    acct = cohort_round_cost(
        cfg.topology, rt.schedule, N_HIDDEN, N_FEATURES
    )
    assert acct.tier2_payloads <= 2 * rt.schedule.n_cohorts, acct
    flat_payloads = cfg.topology.payloads_per_round
    tier2_reduction = flat_payloads / max(acct.tier2_payloads, 1)

    return {
        "n_devices": n_devices,
        "cohort_size": sched_cohort,
        "n_cohorts": rt.schedule.n_cohorts,
        "batch": BATCH,
        "arena_mb": arena.nbytes / 2**20,
        "init_seconds": init_seconds,
        "tick_us": tick_best_us,
        "devices_per_sec": n_devices / (tick_best_us * 1e-6),
        "samples_per_sec": n_devices * BATCH / (tick_best_us * 1e-6),
        "merge_us": merge_best_us,
        "tier1_bytes_per_round": cost.bytes_tier1,
        "tier2_bytes_per_round": cost.bytes_tier2,
        "flat_bytes_per_round": flat_payloads * acct.payload_bytes,
        "tier2_reduction": tier2_reduction,
    }


def main(
    device_grid: tuple[int, ...] = (SMOKE_D,),
    out_path: str = "BENCH_fleet_cohort.json",
    history_path: str = "BENCH_history.jsonl",
    gate: bool = False,
) -> list[str]:
    parity_gap = check_parity()
    rows = [run_scale(d) for d in device_grid]
    report = {
        "n_hidden": N_HIDDEN,
        "n_features": N_FEATURES,
        "batch": BATCH,
        "backend": jax.default_backend(),
        "parity_beta_gap": parity_gap,
        "rows": rows,
    }
    with open(out_path, "w") as fh:
        json.dump(report, fh, indent=2)

    lines = [
        f"fleet_cohort/parity_d{PARITY_D},nan,"
        f"max_beta_gap={parity_gap:.2e};bound=1e-5"
    ]
    metrics: dict[str, float] = {}
    for r in rows:
        d = r["n_devices"]
        lines.append(
            f"fleet_cohort/d{d},"
            f"{r['tick_us']:.1f},"
            f"devices_per_sec={r['devices_per_sec']:.0f};"
            f"arena_mb={r['arena_mb']:.0f};"
            f"merge_us={r['merge_us']:.1f};"
            f"tier1_bytes={r['tier1_bytes_per_round']};"
            f"tier2_bytes={r['tier2_bytes_per_round']};"
            f"tier2_reduction={r['tier2_reduction']:.0f}x"
        )
        metrics[f"paged_tick_d{d}_us"] = r["tick_us"]
        metrics[f"two_tier_merge_d{d}_us"] = r["merge_us"]
        metrics[f"tier2_reduction_d{d}_ratio"] = r["tier2_reduction"]
        # the overlay traffic claim, mechanically: tier 2 carries orders
        # of magnitude fewer bytes than the flat round at every scale
        assert r["tier2_bytes_per_round"] * 100 < r["flat_bytes_per_round"], r
    if gate:
        record_and_gate("fleet_cohort", metrics, path=history_path)
    else:
        record("fleet_cohort", metrics, path=history_path)
    lines.append(
        f"# cohort-bench artifact → {out_path} (history → {history_path})"
    )
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI leg: D=131072 (≥10⁵ devices) only",
    )
    ap.add_argument(
        "--full", action="store_true",
        help="the million-device point: D=1048576",
    )
    ap.add_argument("--out", default="BENCH_fleet_cohort.json")
    ap.add_argument("--history", default="BENCH_history.jsonl")
    args = ap.parse_args()
    grid = (SMOKE_D, FULL_D) if args.full else (SMOKE_D,)
    for line in main(grid, args.out, args.history, gate=True):
        print(line)
    print(f"# fleet_cohort ok — grid {grid}")
